//! Differential harness pinning streaming (chunked, suspend/resume)
//! execution to one-shot runs.
//!
//! The contract (see `Menage::run_chunk_into`): all cross-chunk state is
//! the membrane plane — potentials plus the Neumaier error sidecar —
//! because MEM_E drains fully within each step and spikes propagate
//! through the core chain *within* a step. A chunk seam is therefore an
//! ordinary step boundary, and splitting any event stream at arbitrary
//! chunk boundaries must be **bit-identical** to one-shot execution over
//! the concatenated train: every layer's spike train, the modeled cycle
//! total, every core's folded `CoreStats`, and the hardware fault
//! counters — in ideal *and* non-ideal analog mode, on dense *and*
//! compressed-conv models, monolithic *and* sharded. The same holds for
//! lane-resident sessions (`Menage::run_session_chunks_into`) under
//! arbitrary interleavings with other sessions, and end-to-end over the
//! serving layer's SESSION_OPEN/CHUNK/OUT frames.

use std::time::Duration;

use menage::accel::{Menage, RunOutput};
use menage::analog::AnalogParams;
use menage::config::{AcceleratorConfig, ModelConfig};
use menage::fault::FaultPlan;
use menage::mapping::Strategy;
use menage::serve::{Client, ServeConfig, Server};
use menage::shard::ShardedMenage;
use menage::snn::{ConvSpec, QuantNetwork, SpikeTrain};
use menage::util::prop;
use menage::util::rng::Rng;

fn model(sizes: &[usize], t: usize) -> ModelConfig {
    ModelConfig {
        name: "stream-diff".into(),
        layer_sizes: sizes.to_vec(),
        timesteps: t,
        beta: 0.9,
        v_threshold: 1.0,
        v_reset: 0.0,
    }
}

fn accel(cores: usize, m: usize, n: usize) -> AcceleratorConfig {
    let mut c = AcceleratorConfig::accel1();
    c.num_cores = cores;
    c.a_neurons_per_core = m;
    c.a_syns_per_core = m;
    c.virtual_per_a_neuron = n;
    c
}

/// Random chunk boundaries over a `t`-step train: `n` cuts drawn with
/// replacement (duplicates produce legal 0-step chunks), plus the ends.
fn random_cuts(rng: &mut Rng, t: usize, n: usize) -> Vec<usize> {
    let mut cuts: Vec<usize> = (0..n).map(|_| rng.below(t + 1)).collect();
    cuts.push(0);
    cuts.push(t);
    cuts.sort_unstable();
    cuts
}

/// Split `input` at `cuts` (a sorted 0..=T boundary list) into chunks.
fn chunks_of(input: &SpikeTrain, cuts: &[usize]) -> Vec<SpikeTrain> {
    cuts.windows(2).map(|w| input.slice_steps(w[0]..w[1])).collect()
}

/// Concatenate per-chunk outputs back into one-shot shape: per layer, the
/// chunk trains joined in order; cycles summed.
fn concat_outputs(outs: &[RunOutput], layers: usize) -> (u64, Vec<SpikeTrain>) {
    let mut cycles = 0u64;
    let mut trains: Vec<SpikeTrain> = Vec::new();
    for (k, out) in outs.iter().enumerate() {
        cycles += out.cycles;
        if k == 0 {
            trains = out.trains.clone();
        } else {
            for (l, t) in out.trains.iter().enumerate() {
                trains[l].spikes.extend(t.spikes.iter().cloned());
            }
        }
    }
    assert_eq!(trains.len(), layers);
    (cycles, trains)
}

/// The core assertion: a chip fed `input` chunk-by-chunk (resuming the
/// membrane plane between chunks) is bit-identical to a fresh chip's
/// one-shot run — monolithic and sharded, with optional hardware faults.
/// Returns an error string for the property driver.
fn assert_chunked_equals_one_shot(
    net: &QuantNetwork,
    cfg: &AcceleratorConfig,
    analog: &AnalogParams,
    faults: Option<&FaultPlan>,
    num_shards: usize,
    input: &SpikeTrain,
    cuts: &[usize],
    tag: &str,
) -> Result<(), String> {
    let mut golden_chip = Menage::build(net, cfg, Strategy::IlpFlow, analog, 7)
        .map_err(|e| format!("{tag}: mono build: {e}"))?;
    let mut chunked_chip = golden_chip.clone();
    if let Some(plan) = faults {
        golden_chip.install_faults(plan);
        chunked_chip.install_faults(plan);
    }
    let golden = golden_chip.run(input).map_err(|e| format!("{tag}: one-shot run: {e}"))?;

    let chunks = chunks_of(input, cuts);
    let mut outs: Vec<RunOutput> = Vec::new();
    for (k, chunk) in chunks.iter().enumerate() {
        let mut out = RunOutput::default();
        chunked_chip
            .run_chunk_into(chunk, k > 0, &mut out)
            .map_err(|e| format!("{tag}: chunk {k}: {e}"))?;
        outs.push(out);
    }
    let (cycles, trains) = concat_outputs(&outs, golden.trains.len());
    if cycles != golden.cycles {
        return Err(format!("{tag}: chunked cycles {cycles} != one-shot {}", golden.cycles));
    }
    for (l, (a, b)) in trains.iter().zip(&golden.trains).enumerate() {
        if a.spikes != b.spikes {
            return Err(format!("{tag}: layer {l} spike trains diverge (cuts {cuts:?})"));
        }
    }
    for (l, (cc, gc)) in chunked_chip.cores.iter().zip(&golden_chip.cores).enumerate() {
        if cc.stats != gc.stats {
            return Err(format!(
                "{tag}: core {l} CoreStats diverge:\n chunked: {:?}\n one-shot: {:?}",
                cc.stats, gc.stats
            ));
        }
    }
    if chunked_chip.inputs_processed != golden_chip.inputs_processed {
        return Err(format!(
            "{tag}: chunked inputs_processed {} != one-shot {} (a chunked stream is ONE input)",
            chunked_chip.inputs_processed, golden_chip.inputs_processed
        ));
    }
    if chunked_chip.fault_counters() != golden_chip.fault_counters() {
        return Err(format!(
            "{tag}: fault counters diverge: chunked {:?} vs one-shot {:?}",
            chunked_chip.fault_counters(),
            golden_chip.fault_counters()
        ));
    }

    // Sharded chunked execution against the same monolithic golden.
    if num_shards > 0 {
        let mut sharded = ShardedMenage::build(net, cfg, Strategy::IlpFlow, analog, 7, num_shards)
            .map_err(|e| format!("{tag}: sharded build: {e}"))?;
        if let Some(plan) = faults {
            sharded.install_faults(plan);
        }
        let mut outs: Vec<RunOutput> = Vec::new();
        for (k, chunk) in chunks.iter().enumerate() {
            let mut out = RunOutput::default();
            sharded
                .run_chunk_into(chunk, k > 0, &mut out)
                .map_err(|e| format!("{tag}: sharded chunk {k}: {e}"))?;
            outs.push(out);
        }
        let (cycles, trains) = concat_outputs(&outs, golden.trains.len());
        if cycles != golden.cycles {
            return Err(format!(
                "{tag}: sharded chunked cycles {cycles} != one-shot {}",
                golden.cycles
            ));
        }
        for (l, (a, b)) in trains.iter().zip(&golden.trains).enumerate() {
            if a.spikes != b.spikes {
                return Err(format!("{tag}: sharded layer {l} trains diverge (cuts {cuts:?})"));
            }
        }
        let scores: Vec<_> = sharded.shards.iter().flat_map(|s| &s.cores).collect();
        for (l, (sc, gc)) in scores.iter().zip(&golden_chip.cores).enumerate() {
            if sc.stats != gc.stats {
                return Err(format!("{tag}: sharded core {l} CoreStats diverge"));
            }
        }
        if sharded.fault_counters() != golden_chip.fault_counters() {
            return Err(format!("{tag}: sharded fault counters diverge"));
        }
    }
    Ok(())
}

/// Randomized dense models × chunk boundaries, ideal analog mode,
/// monolithic + sharded.
#[test]
fn prop_chunked_bit_identical_ideal() {
    prop::check_n("chunked-vs-one-shot-ideal", 10, |rng| {
        let l0 = 8 + rng.below(20);
        let l1 = 4 + rng.below(12);
        let l2 = 2 + rng.below(8);
        let mcfg = model(&[l0, l1, l2], 3 + rng.below(6));
        let net = QuantNetwork::random(&mcfg, 0.3 + rng.f64() * 0.5, rng);
        let cfg = accel(2, 2 + rng.below(4), 2 + rng.below(4));
        let t = 2 + rng.below(10);
        let input = SpikeTrain::bernoulli(l0, t, 0.05 + rng.f64() * 0.4, rng);
        let ncuts = 1 + rng.below(4);
        let cuts = random_cuts(rng, t, ncuts);
        let shards = 1 + rng.below(2);
        assert_chunked_equals_one_shot(
            &net,
            &cfg,
            &AnalogParams::ideal(),
            None,
            shards,
            &input,
            &cuts,
            &format!("ideal k={shards}"),
        )
    });
}

/// Same property in non-ideal analog mode: resuming must carry the
/// Neumaier error sidecar too, or accumulated compensation is lost at
/// every chunk seam and the trains drift.
#[test]
fn prop_chunked_bit_identical_nonideal() {
    prop::check_n("chunked-vs-one-shot-nonideal", 6, |rng| {
        let l0 = 8 + rng.below(16);
        let l1 = 4 + rng.below(10);
        let l2 = 2 + rng.below(6);
        let mcfg = model(&[l0, l1, l2], 3 + rng.below(5));
        let net = QuantNetwork::random(&mcfg, 0.3 + rng.f64() * 0.4, rng);
        let cfg = accel(2, 2 + rng.below(3), 2 + rng.below(3));
        let t = 2 + rng.below(8);
        let input = SpikeTrain::bernoulli(l0, t, 0.05 + rng.f64() * 0.35, rng);
        let ncuts = 1 + rng.below(4);
        let cuts = random_cuts(rng, t, ncuts);
        let shards = 1 + rng.below(2);
        assert_chunked_equals_one_shot(
            &net,
            &cfg,
            &AnalogParams::paper(),
            None,
            shards,
            &input,
            &cuts,
            &format!("nonideal k={shards}"),
        )
    });
}

/// Compressed-conv models (generator-based synapse rows) and injected
/// hardware faults: the chunk seam must preserve the per-event fault RNG
/// stream and the conv sweep accounting, both analog modes.
#[test]
fn chunked_conv_and_faulted_bit_identity() {
    let spec = ConvSpec {
        in_channels: 2,
        in_h: 6,
        in_w: 6,
        out_channels: 3,
        kernel_h: 3,
        kernel_w: 3,
        stride: 1,
        padding: 1,
    };
    let mut rng = Rng::new(61);
    let net = QuantNetwork::random_conv("stream-conv", &[spec], 4, 6, 0.3, &mut rng).unwrap();
    let cfg = accel(net.layers.len(), 3, 3);
    let dim = net.input_dim();
    let plan = FaultPlan {
        seed: 99,
        stuck_row_frac: 0.3,
        dead_slot_frac: 0.2,
        bit_flip_p: 0.05,
        drift_scale: 1.5,
    };
    for analog in [AnalogParams::ideal(), AnalogParams::paper()] {
        for faults in [None, Some(&plan)] {
            let t = 6;
            let input = SpikeTrain::bernoulli(dim, t, 0.3, &mut rng);
            let cuts = random_cuts(&mut rng, t, 3);
            assert_chunked_equals_one_shot(
                &net,
                &cfg,
                &analog,
                faults,
                0, // conv models shard along the layer chain; mono suffices here
                &input,
                &cuts,
                &format!("conv faults={}", faults.is_some()),
            )
            .unwrap();
        }
    }
    // The fault plan actually bites (the faulted identity is not vacuous).
    let mut chip = Menage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 7).unwrap();
    chip.install_faults(&plan);
    chip.run(&SpikeTrain::bernoulli(dim, 6, 0.3, &mut rng)).unwrap();
    let (stuck, dead, flips) = chip.fault_counters();
    assert!(stuck + dead + flips > 0, "fault plan never fired");
}

/// Boundary edge cases: a single chunk (resume never taken), one chunk
/// per step, 0-step chunks between every real chunk, and an entirely
/// empty train.
#[test]
fn chunk_boundary_edge_cases() {
    let mcfg = model(&[20, 12, 6], 6);
    let mut rng = Rng::new(71);
    let net = QuantNetwork::random(&mcfg, 0.5, &mut rng);
    let cfg = accel(2, 4, 4);
    let t = 7;
    let input = SpikeTrain::bernoulli(20, t, 0.3, &mut rng);
    let per_step: Vec<usize> = (0..=t).collect();
    let with_empties: Vec<usize> = vec![0, 0, 2, 2, 2, 5, t, t];
    for analog in [AnalogParams::ideal(), AnalogParams::paper()] {
        for (name, cuts) in [
            ("single", vec![0, t]),
            ("per-step", per_step.clone()),
            ("with-empties", with_empties.clone()),
        ] {
            assert_chunked_equals_one_shot(
                &net,
                &cfg,
                &analog,
                None,
                2,
                &input,
                &cuts,
                &format!("edge {name}"),
            )
            .unwrap();
        }
        // 0-step everything: chunking an empty train is legal and inert.
        let empty = SpikeTrain::new(20, 0);
        assert_chunked_equals_one_shot(
            &net,
            &cfg,
            &analog,
            None,
            2,
            &empty,
            &[0, 0, 0],
            "edge empty-train",
        )
        .unwrap();
    }
}

/// Lane-resident sessions under arbitrary interleaving: three sessions
/// sharing one chip's lanes, their chunks dispatched in mixed rounds,
/// must each be bit-identical to a dedicated chip running that session's
/// concatenated train one-shot — and after folding every session lane,
/// the shared chip's totals carry exactly the sum of the dedicated
/// chips' work. Monolithic and sharded hosts.
#[test]
fn interleaved_session_lanes_match_dedicated_chips() {
    let mcfg = model(&[24, 14, 6], 6);
    let mut rng = Rng::new(81);
    let net = QuantNetwork::random(&mcfg, 0.5, &mut rng);
    let cfg = accel(2, 4, 3);
    for analog in [AnalogParams::ideal(), AnalogParams::paper()] {
        // Per-session full trains and chunk boundary lists.
        let trains: Vec<SpikeTrain> = (0..3)
            .map(|s| SpikeTrain::bernoulli(24, 5 + s, 0.1 + 0.1 * s as f64, &mut rng))
            .collect();
        let all_cuts: Vec<Vec<usize>> = trains
            .iter()
            .map(|tr| random_cuts(&mut rng, tr.timesteps(), 2))
            .collect();
        let all_chunks: Vec<Vec<SpikeTrain>> =
            trains.iter().zip(&all_cuts).map(|(tr, c)| chunks_of(tr, c)).collect();

        let mono0 = Menage::build(&net, &cfg, Strategy::IlpFlow, &analog, 7).unwrap();
        let mut host = mono0.clone();
        let mut sharded_host =
            ShardedMenage::build(&net, &cfg, Strategy::IlpFlow, &analog, 7, 2).unwrap();
        for lane in 0..3 {
            host.open_session_lane(lane);
            sharded_host.open_session_lane(lane);
        }
        // Interleave: each round dispatches the next pending chunk of a
        // varying subset of sessions (strictly ascending lanes per call).
        let mut next = [0usize; 3];
        let mut got: Vec<Vec<RunOutput>> = vec![Vec::new(), Vec::new(), Vec::new()];
        let mut sgot: Vec<Vec<RunOutput>> = vec![Vec::new(), Vec::new(), Vec::new()];
        let mut round = 0usize;
        loop {
            let mut jobs: Vec<(usize, &SpikeTrain)> = Vec::new();
            for lane in 0..3 {
                // Stagger: lane participates in a round unless skipped by
                // a deterministic pattern, so rounds mix subsets.
                if next[lane] < all_chunks[lane].len() && (round + lane) % 3 != 2 {
                    jobs.push((lane, &all_chunks[lane][next[lane]]));
                }
            }
            if jobs.is_empty() {
                if (0..3).all(|l| next[l] >= all_chunks[l].len()) {
                    break;
                }
                round += 1;
                continue;
            }
            let mut outs = Vec::new();
            host.run_session_chunks_into(&jobs, &mut outs).unwrap();
            let mut souts = Vec::new();
            sharded_host.run_session_chunks_into(&jobs, &mut souts).unwrap();
            for (j, &(lane, _)) in jobs.iter().enumerate() {
                got[lane].push(outs[j].clone());
                sgot[lane].push(souts[j].clone());
                next[lane] += 1;
            }
            round += 1;
        }

        // Each session vs a dedicated one-shot chip.
        let mut dedicated_macs = 0u64;
        for lane in 0..3 {
            let mut dedicated = mono0.clone();
            let golden = dedicated.run(&trains[lane]).unwrap();
            for (tag, outs) in [("mono", &got[lane]), ("sharded", &sgot[lane])] {
                let (cycles, ctrains) = concat_outputs(outs, golden.trains.len());
                assert_eq!(cycles, golden.cycles, "{tag} session {lane}: cycles");
                for (l, (a, b)) in ctrains.iter().zip(&golden.trains).enumerate() {
                    assert_eq!(a.spikes, b.spikes, "{tag} session {lane} layer {l}");
                }
            }
            // Per-lane stats equal the dedicated chip's scalar stats.
            for (l, (hc, dc)) in host.cores.iter().zip(&dedicated.cores).enumerate() {
                assert_eq!(hc.lane_stats(lane), &dc.stats, "session {lane} core {l}: stats");
            }
            dedicated_macs += dedicated.total_macs();
        }

        // Folding every lane surfaces the summed work on the shared hosts.
        for lane in 0..3 {
            host.fold_session_lane(lane);
            sharded_host.fold_session_lane(lane);
        }
        assert_eq!(host.total_macs(), dedicated_macs, "mono host folded MACs");
        assert_eq!(sharded_host.total_macs(), dedicated_macs, "sharded host folded MACs");
        assert_eq!(host.inputs_processed, 3);
        assert_eq!(sharded_host.inputs_processed, 3);
    }
}

// ---------------------------------------------------------------------
// Serve-layer sessions over loopback TCP.
// ---------------------------------------------------------------------

fn serve_chip() -> Menage {
    let mcfg = model(&[30, 16, 8], 6);
    let cfg = accel(2, 4, 4);
    let mut rng = Rng::new(8);
    let net = QuantNetwork::random(&mcfg, 0.5, &mut rng);
    Menage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 2).unwrap()
}

/// End-to-end: a session streamed over SESSION_CHUNK frames answers
/// bit-identically to one-shot in-process execution of the concatenated
/// train — chunk cycle deltas sum to the one-shot cycle total, the
/// concatenated chunk outputs equal the one-shot output train, and the
/// final running prediction is the one-shot prediction. Monolithic and
/// sharded servers, several concurrent sessions per server.
#[test]
fn served_sessions_bit_identical_to_one_shot() {
    let chip = serve_chip();
    let mcfg = model(&[30, 16, 8], 6);
    let mut rng = Rng::new(8);
    let net = QuantNetwork::random(&mcfg, 0.5, &mut rng);
    let cfg = accel(2, 4, 4);
    let sharded =
        ShardedMenage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 2, 2)
            .unwrap();
    let scfg = || ServeConfig {
        workers: 2,
        lanes_per_worker: 2,
        session_lanes: 4,
        ..ServeConfig::default()
    };
    let mono_server = Server::start(&chip, "127.0.0.1:0", scfg()).unwrap();
    let sharded_server = Server::start_sharded(&sharded, "127.0.0.1:0", scfg()).unwrap();

    for (which, addr) in
        [("mono", mono_server.local_addr()), ("sharded", sharded_server.local_addr())]
    {
        let mut client = Client::connect(addr).unwrap();
        for s in 0..3u64 {
            let mut rng = Rng::new(900 + s);
            let t = 5 + s as usize;
            let full = SpikeTrain::bernoulli(30, t, 0.25, &mut rng);
            let mut local = serve_chip();
            let golden = local.run(&full).unwrap();

            client.open_session(s).unwrap();
            let cuts = random_cuts(&mut rng, t, 2);
            let mut cycles = 0u64;
            let mut out_train = SpikeTrain::new(golden.output().num_neurons, 0);
            let mut last_predicted = 0u32;
            for (seq, chunk) in chunks_of(&full, &cuts).iter().enumerate() {
                let out = client.session_chunk(s, seq as u64, chunk).unwrap();
                cycles += out.chunk_cycles;
                out_train.spikes.extend(out.output.spikes.iter().cloned());
                last_predicted = out.predicted;
            }
            client.close_session(s).unwrap();

            assert_eq!(cycles, golden.cycles, "{which} session {s}: cycles");
            assert_eq!(out_train.spikes, golden.output().spikes, "{which} session {s}: output");
            assert_eq!(
                last_predicted as usize,
                golden.predicted_class(),
                "{which} session {s}: prediction"
            );
        }
    }

    // Session work is visible on the shutdown chips (stats folded on
    // close, not lost with the lane).
    let chips = mono_server.shutdown();
    assert!(chips.iter().map(|c| c.total_macs()).sum::<u64>() > 0);
    // 3 sessions = 3 logical inputs on the session host chip.
    assert_eq!(chips.iter().map(|c| c.inputs_processed).sum::<u64>(), 3);
    sharded_server.shutdown();
}

/// Pipelined session chunks (send-ahead without waiting) arrive in strict
/// seq order and still match one-shot execution; stateless INFER traffic
/// on the same server never perturbs resident session lanes.
#[test]
fn pipelined_session_chunks_with_concurrent_infer_traffic() {
    let chip = serve_chip();
    let server = Server::start(
        &chip,
        "127.0.0.1:0",
        ServeConfig { workers: 2, lanes_per_worker: 2, session_lanes: 2, ..ServeConfig::default() },
    )
    .unwrap();
    let addr = server.local_addr();

    let mut rng = Rng::new(907);
    let t = 8;
    let full = SpikeTrain::bernoulli(30, t, 0.25, &mut rng);
    let mut local = serve_chip();
    let golden = local.run(&full).unwrap();
    let chunks = chunks_of(&full, &[0, 3, 3, 5, t]);

    // A background connection hammers the stateless path meanwhile.
    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let bg_stop = stop.clone();
    let bg = std::thread::spawn(move || {
        let mut c = Client::connect(addr).unwrap();
        let mut rng = Rng::new(911);
        let mut n = 0u32;
        while !bg_stop.load(std::sync::atomic::Ordering::Relaxed) {
            let train = SpikeTrain::bernoulli(30, 3, 0.3, &mut rng);
            c.infer(&train).unwrap();
            n += 1;
        }
        n
    });

    let mut client = Client::connect(addr).unwrap();
    client.open_session(42).unwrap();
    for (seq, chunk) in chunks.iter().enumerate() {
        client.send_session_chunk(42, seq as u64, chunk).unwrap();
    }
    let mut cycles = 0u64;
    let mut out_train = SpikeTrain::new(golden.output().num_neurons, 0);
    let mut seen = 0u64;
    while (seen as usize) < chunks.len() {
        match client.recv_reply().unwrap() {
            menage::serve::Reply::SessionOut(out) => {
                assert_eq!(out.sid, 42);
                assert_eq!(out.seq, seen, "SESSION_OUT frames must arrive in seq order");
                cycles += out.chunk_cycles;
                out_train.spikes.extend(out.output.spikes.iter().cloned());
                seen += 1;
            }
            other => panic!("unexpected reply {other:?}"),
        }
    }
    client.close_session(42).unwrap();
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let bg_n = bg.join().unwrap();
    assert!(bg_n > 0, "background INFER traffic never ran");

    assert_eq!(cycles, golden.cycles, "pipelined session: cycles");
    assert_eq!(out_train.spikes, golden.output().spikes, "pipelined session: output");
    server.shutdown();
}

/// Idle-timeout eviction: an abandoned session's lane is reclaimed (a new
/// session can open at capacity 1), its work survives into the server's
/// chip totals, and a late chunk for the evicted sid gets a clean
/// BadRequest rather than stale lane state.
#[test]
fn idle_sessions_are_evicted_and_their_work_survives() {
    let chip = serve_chip();
    let server = Server::start(
        &chip,
        "127.0.0.1:0",
        ServeConfig {
            workers: 1,
            lanes_per_worker: 1,
            session_lanes: 1,
            session_idle: Duration::from_millis(100),
            poll_interval: Duration::from_millis(20),
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let mut client = Client::connect(server.local_addr()).unwrap();
    let mut rng = Rng::new(917);

    client.open_session(1).unwrap();
    let chunk = SpikeTrain::bernoulli(30, 4, 0.3, &mut rng);
    client.session_chunk(1, 0, &chunk).unwrap();
    // Let the idle sweep reclaim the lane (never closed explicitly).
    std::thread::sleep(Duration::from_millis(400));

    // The lane is free again: a new session opens at capacity 1...
    client.open_session(2).unwrap();
    client.session_chunk(2, 0, &chunk).unwrap();
    // ...and the evicted sid is gone (clean error, not stale state).
    let err = client.session_chunk(1, 1, &chunk).unwrap_err().to_string();
    assert!(err.contains("bad_request"), "{err}");
    client.close_session(2).unwrap();

    let chips = server.shutdown();
    // Both sessions' work is in the totals: the evicted lane was folded
    // before reuse, the closed one on close.
    assert_eq!(chips.iter().map(|c| c.inputs_processed).sum::<u64>(), 2);
    assert!(chips.iter().map(|c| c.total_macs()).sum::<u64>() > 0);
}
