//! Cross-module integration tests: the full mapping→distill→simulate→energy
//! pipeline on in-process models, plus property tests over the pipeline
//! invariants. (PJRT/golden tests that need `make artifacts` live in
//! `e2e_golden.rs`.)

use menage::accel::Menage;
use menage::analog::AnalogParams;
use menage::config::{AcceleratorConfig, ModelConfig};
use menage::coordinator::Coordinator;
use menage::datasets::{Dataset, DatasetKind};
use menage::energy::{report, EnergyModel};
use menage::mapping::{distill_network, map_network, Strategy};
use menage::snn::{reference_forward, QuantNetwork, SpikeTrain};
use menage::trace::MemoryTrace;
use menage::util::prop;
use menage::util::rng::Rng;

fn model(sizes: &[usize], t: usize) -> ModelConfig {
    ModelConfig {
        name: "itest".into(),
        layer_sizes: sizes.to_vec(),
        timesteps: t,
        beta: 0.9,
        v_threshold: 1.0,
        v_reset: 0.0,
    }
}

fn accel(cores: usize, m: usize, n: usize) -> AcceleratorConfig {
    let mut c = AcceleratorConfig::accel1();
    c.num_cores = cores;
    c.a_neurons_per_core = m;
    c.a_syns_per_core = m;
    c.virtual_per_a_neuron = n;
    c
}

#[test]
fn pipeline_nmnist_shape_end_to_end() {
    // Full N-MNIST geometry (2312-200-100-40-10) on Accel₁, synthetic
    // weights + events, golden equivalence per layer.
    let mcfg = model(&[2312, 200, 100, 40, 10], 8);
    let mut rng = Rng::new(1);
    let net = QuantNetwork::random(&mcfg, 0.5, &mut rng);
    let cfg = AcceleratorConfig::accel1();
    let mut chip =
        Menage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 7).unwrap();
    let ds = Dataset::new(DatasetKind::NMnist, 9, 8);
    for sample in ds.balanced_split(5, 0) {
        let golden = reference_forward(&net, &sample.events).unwrap();
        let out = chip.run(&sample.events).unwrap();
        assert!(out.matches_reference(&golden));
    }
    // Energy model produces sane numbers on the real geometry.
    let eff = report(&chip, &EnergyModel::paper_90nm(cfg.clock_hz));
    assert!(eff.tops_per_watt > 0.1 && eff.tops_per_watt < 100.0);
    // Trace covers all 4 cores with the right series length.
    let tr = MemoryTrace::from_chip(&chip, "nmnist_syn", 8, 5);
    assert_eq!(tr.cores.len(), 4);
    assert!(tr.cores.iter().all(|c| c.kb_per_step.len() == 8));
}

#[test]
fn accel2_geometry_multi_round_layers() {
    // CIFAR-small geometry forces multi-round on the 1000-neuron layer:
    // 20×32 = 640 capacitors < 1000.
    let mcfg = model(&[512, 1000, 500, 200, 100, 10], 4);
    let mut rng = Rng::new(2);
    let net = QuantNetwork::random(&mcfg, 0.6, &mut rng);
    let cfg = AcceleratorConfig::accel2();
    let chip =
        Menage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 3).unwrap();
    assert!(chip.cores[0].rounds() >= 2, "1000 neurons must need ≥2 rounds");
    assert_eq!(chip.cores.len(), 5);
}

#[test]
fn distilled_images_capacity_checked_against_paper_configs() {
    // The trained N-MNIST network must FIT Accel₁'s published memories:
    // 400 KB weight SRAM per core at 50% sparsity.
    let mcfg = model(&[2312, 200, 100, 40, 10], 4);
    let mut rng = Rng::new(3);
    let net = QuantNetwork::random(&mcfg, 0.5, &mut rng);
    let cfg = AcceleratorConfig::accel1();
    let mappings = map_network(&net, &cfg, Strategy::IlpFlow).unwrap();
    let images = distill_network(&net, &mappings, &cfg).unwrap();
    for (img, layer) in images.iter().zip(&net.layers) {
        assert!(img.weight_mem.len() <= cfg.weight_capacity());
        assert_eq!(img.weight_mem.len(), layer.nnz());
    }
}

#[test]
fn coordinator_multiworker_equals_reference() {
    let mcfg = model(&[40, 24, 10], 6);
    let mut rng = Rng::new(4);
    let net = QuantNetwork::random(&mcfg, 0.4, &mut rng);
    let cfg = accel(2, 4, 8);
    let chip = Menage::build(&net, &cfg, Strategy::Greedy, &AnalogParams::ideal(), 5).unwrap();
    let mut coord = Coordinator::new(&chip, 3);
    let inputs: Vec<(SpikeTrain, Option<usize>)> = (0..9)
        .map(|s| {
            let mut r = Rng::new(50 + s);
            let mut st = SpikeTrain::new(40, 6);
            for step in st.spikes.iter_mut() {
                for i in 0..40 {
                    if r.bernoulli(0.2) {
                        step.push(i as u32);
                    }
                }
            }
            (st, None)
        })
        .collect();
    let golden: Vec<usize> = inputs
        .iter()
        .map(|(st, _)| reference_forward(&net, st).unwrap().predicted_class())
        .collect();
    let res = coord.run_batch(inputs).unwrap();
    for (r, g) in res.iter().zip(&golden) {
        assert_eq!(r.predicted, *g);
    }
    coord.shutdown();
}

#[test]
fn prop_full_pipeline_equivalence() {
    // Property: for random model geometries, accel configs, strategies and
    // inputs, the ideal-mode chip equals the reference bit-exactly.
    prop::check_n("pipeline-equivalence", 12, |rng| {
        let l1 = 8 + rng.below(24);
        let l2 = 4 + rng.below(16);
        let l3 = 2 + rng.below(8);
        let t = 3 + rng.below(6);
        let mcfg = model(&[l1, l2, l3], t);
        let mut netrng = rng.fork(1);
        let net = QuantNetwork::random(&mcfg, 0.3 + rng.f64() * 0.4, &mut netrng);
        let cfg = accel(2, 2 + rng.below(4), 2 + rng.below(6));
        let strat = [Strategy::IlpFlow, Strategy::Greedy, Strategy::FirstFit, Strategy::RoundRobin]
            [rng.below(4)];
        let mut chip = Menage::build(&net, &cfg, strat, &AnalogParams::ideal(), rng.next_u64())
            .map_err(|e| e.to_string())?;
        let mut st = SpikeTrain::new(l1, t);
        for step in st.spikes.iter_mut() {
            for i in 0..l1 {
                if rng.bernoulli(0.25) {
                    step.push(i as u32);
                }
            }
        }
        let golden = reference_forward(&net, &st).map_err(|e| e.to_string())?;
        let out = chip.run(&st).map_err(|e| e.to_string())?;
        if !out.matches_reference(&golden) {
            return Err(format!(
                "divergence: sizes {l1}/{l2}/{l3} t={t} strat={}",
                strat.name()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_energy_report_invariants() {
    prop::check_n("energy-invariants", 10, |rng| {
        let mcfg = model(&[20 + rng.below(30), 10 + rng.below(10), 4], 4);
        let mut netrng = rng.fork(2);
        let net = QuantNetwork::random(&mcfg, 0.5, &mut netrng);
        let cfg = accel(2, 3, 4);
        let mut chip =
            Menage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 1)
                .map_err(|e| e.to_string())?;
        let mut st = SpikeTrain::new(net.input_dim(), 4);
        for step in st.spikes.iter_mut() {
            for i in 0..net.input_dim() {
                if rng.bernoulli(0.3) {
                    step.push(i as u32);
                }
            }
        }
        chip.run(&st).map_err(|e| e.to_string())?;
        let eff = report(&chip, &EnergyModel::paper_90nm(cfg.clock_hz));
        let b = &eff.breakdown;
        for (name, v) in [
            ("mac", b.analog_mac),
            ("neuron", b.analog_neuron),
            ("wsram", b.weight_sram),
            ("snsram", b.sn_sram),
            ("e2a", b.e2a_sram),
            ("eventmem", b.event_mem),
            ("ctrl", b.controller),
            ("static", b.static_leak),
        ] {
            if !(v.is_finite() && v >= 0.0) {
                return Err(format!("{name} component invalid: {v}"));
            }
        }
        if eff.total_ops != 2 * chip.total_macs() {
            return Err("ops accounting broken".into());
        }
        Ok(())
    });
}

#[test]
fn dropped_events_accounted_under_tiny_event_mem() {
    let mcfg = model(&[60, 20, 5], 5);
    let mut rng = Rng::new(6);
    let net = QuantNetwork::random(&mcfg, 0.3, &mut rng);
    let mut cfg = accel(2, 4, 5);
    cfg.event_mem_depth = 4; // pathological backpressure
    let mut chip =
        Menage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 1).unwrap();
    let mut st = SpikeTrain::new(60, 5);
    for step in st.spikes.iter_mut() {
        for i in 0..60 {
            step.push(i as u32); // saturate
        }
    }
    chip.run(&st).unwrap();
    let drops: u64 = chip.cores.iter().map(|c| c.stats.dropped_events).sum();
    assert!(drops > 0, "tiny MEM_E must drop events");
}

#[test]
fn strategy_changes_layout_not_semantics() {
    let mcfg = model(&[50, 30, 10], 6);
    let mut rng = Rng::new(7);
    let net = QuantNetwork::random(&mcfg, 0.5, &mut rng);
    let cfg = accel(2, 5, 4);
    let mut st = SpikeTrain::new(50, 6);
    let mut r = Rng::new(77);
    for step in st.spikes.iter_mut() {
        for i in 0..50 {
            if r.bernoulli(0.3) {
                step.push(i as u32);
            }
        }
    }
    let mut outputs = Vec::new();
    let mut cycles = Vec::new();
    for strat in [Strategy::IlpFlow, Strategy::Greedy, Strategy::FirstFit, Strategy::RoundRobin] {
        let mut chip = Menage::build(&net, &cfg, strat, &AnalogParams::ideal(), 1).unwrap();
        let out = chip.run(&st).unwrap();
        outputs.push(out.output().spikes.clone());
        cycles.push(out.cycles);
    }
    assert!(outputs.windows(2).all(|w| w[0] == w[1]), "semantics differ");
    // Cycle counts are allowed (expected!) to differ — balance matters.
    assert!(cycles.iter().any(|&c| c != cycles[0]) || cycles.len() < 2 || true);
}
