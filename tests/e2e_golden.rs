//! PJRT golden-model integration tests — require `make artifacts` (and,
//! for the live-PJRT case, a build with the `pjrt` cargo feature).
//!
//! These are environment-gated so `cargo test -q` reflects *simulator*
//! health, not missing artifacts or an absent PJRT runtime:
//!
//! * artifacts missing (fresh checkout, no `make artifacts`) → each test
//!   prints a skip message and passes;
//! * `MENAGE_SKIP_E2E=1` → skipped unconditionally;
//! * built without the `pjrt` feature → the live-PJRT test skips itself
//!   (the recorded-golden tests still run when artifacts exist).
//!
//! With artifacts and a `pjrt` build these are the real cross-language
//! check: rust cycle-accurate simulator ≡ recorded python goldens ≡ live
//! PJRT-executed JAX/Pallas model.

use menage::accel::Menage;
use menage::analog::AnalogParams;
use menage::config::AcceleratorConfig;
use menage::mapping::Strategy;
use menage::runtime::{artifacts_dir, cpu_client, pjrt_available, GoldenModel};
use menage::snn::{reference_forward, QuantNetwork, SpikeTrain};
use menage::util::tensorfile::TensorFile;

struct Eval {
    net: QuantNetwork,
    inputs: Vec<SpikeTrain>,
    labels: Vec<usize>,
    golden_counts: Vec<Vec<f32>>,
}

fn load(base: &str, limit: usize) -> Option<Eval> {
    let dir = artifacts_dir();
    let tf = TensorFile::load(dir.join(format!("{base}.weights.mtz"))).ok()?;
    let net = QuantNetwork::from_tensorfile(base, &tf).ok()?;
    let etf = TensorFile::load(dir.join(format!("{base}.eval.mtz"))).ok()?;
    let ev = etf.get("events").ok()?;
    let dims = ev.dims().to_vec();
    let raw = ev.as_u8().ok()?;
    let labels = etf.get("labels").ok()?.as_i32().ok()?;
    let gc = etf.get("golden_counts").ok()?.as_f32().ok()?;
    let (n, t, d) = (dims[0].min(limit), dims[1], dims[2]);
    let classes = gc.len() / dims[0];
    let mut inputs = Vec::new();
    let mut golden_counts = Vec::new();
    for i in 0..n {
        let mut st = SpikeTrain::new(d, t);
        for (ti, step) in st.spikes.iter_mut().enumerate() {
            for j in 0..d {
                if raw[i * t * d + ti * d + j] != 0 {
                    step.push(j as u32);
                }
            }
        }
        inputs.push(st);
        golden_counts.push(gc[i * classes..(i + 1) * classes].to_vec());
    }
    Some(Eval {
        net,
        inputs,
        labels: labels[..n].iter().map(|&l| l as usize).collect(),
        golden_counts,
    })
}

macro_rules! require_artifacts {
    ($base:expr, $limit:expr) => {{
        if std::env::var("MENAGE_SKIP_E2E").map(|v| v == "1").unwrap_or(false) {
            eprintln!("skipping: MENAGE_SKIP_E2E=1");
            return;
        }
        match load($base, $limit) {
            Some(e) => e,
            None => {
                eprintln!(
                    "skipping: artifacts for {} missing under {} (run `make artifacts` \
                     or set MENAGE_ARTIFACTS)",
                    $base,
                    artifacts_dir().display()
                );
                return;
            }
        }
    }};
}

/// The rust reference model must reproduce python's recorded golden counts
/// exactly (same f32 arithmetic on both sides).
#[test]
fn reference_matches_recorded_python_goldens() {
    let e = require_artifacts!("nmnist", 12);
    for ((st, gc), i) in e.inputs.iter().zip(&e.golden_counts).zip(0..) {
        let out = reference_forward(&e.net, st).unwrap();
        let counts = out.output().counts();
        for (c, (&r, &g)) in counts.iter().zip(gc).enumerate() {
            assert_eq!(
                *&(r as f32),
                g,
                "sample {i} class {c}: rust {r} vs python {g}"
            );
        }
    }
}

/// The cycle-accurate simulator must agree with the recorded goldens.
#[test]
fn simulator_matches_recorded_goldens() {
    let e = require_artifacts!("nmnist", 12);
    let cfg = AcceleratorConfig::accel1();
    let mut chip =
        Menage::build(&e.net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 7).unwrap();
    for ((st, gc), i) in e.inputs.iter().zip(&e.golden_counts).zip(0..) {
        let out = chip.run(st).unwrap();
        let counts = out.output().counts();
        for (c, (&r, &g)) in counts.iter().zip(gc).enumerate() {
            assert_eq!(r as f32, g, "sample {i} class {c}");
        }
    }
}

/// Live PJRT execution of the lowered HLO must agree with the simulator.
#[test]
fn pjrt_golden_agrees_with_simulator() {
    if !pjrt_available() {
        eprintln!(
            "skipping: built without the `pjrt` cargo feature (simulator-only build)"
        );
        return;
    }
    let e = require_artifacts!("nmnist", 8);
    let client = cpu_client().unwrap();
    let gm = GoldenModel::load(
        &client,
        artifacts_dir().join("nmnist.hlo.txt"),
        e.net.timesteps,
        e.net.input_dim(),
        e.net.output_dim(),
    )
    .unwrap();
    let cfg = AcceleratorConfig::accel1();
    let mut chip =
        Menage::build(&e.net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 7).unwrap();
    for st in &e.inputs {
        let sim = chip.run(st).unwrap();
        let pjrt_counts = gm.run(st).unwrap();
        let sim_counts: Vec<f32> =
            sim.output().counts().iter().map(|&c| c as f32).collect();
        assert_eq!(sim_counts, pjrt_counts, "simulator vs PJRT divergence");
    }
}

/// cifar_small artifacts run on the Accel₂ design point.
#[test]
fn cifar_small_on_accel2() {
    let e = require_artifacts!("cifar_small", 6);
    let cfg = AcceleratorConfig::accel2();
    let mut chip =
        Menage::build(&e.net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 7).unwrap();
    assert!(chip.cores[0].rounds() >= 2, "1000-neuron layer needs rounds");
    let mut agree = 0;
    for (st, gc) in e.inputs.iter().zip(&e.golden_counts) {
        let out = chip.run(st).unwrap();
        let pred = out.predicted_class();
        let py_pred = gc
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap().then(b.0.cmp(&a.0)))
            .unwrap()
            .0;
        if pred == py_pred {
            agree += 1;
        }
    }
    assert_eq!(agree, e.inputs.len(), "simulator vs python goldens");
    let _ = e.labels;
}
