//! Property tests for the activity-tracked sweep's dirty-slot invariant
//! (`neuracore.rs` §activity-tracked sweep), in both sequential and lane
//! mode.
//!
//! Invariant: after any step, every slot whose dirty flag is clear holds
//! exactly the quiescent fixed point — `mem == v_reset`, `acc == 0` — so
//! skipping its sweep arithmetic is provably a no-op. The oracle is a twin
//! core with `force_dense_sweep` (the pre-perf-pass dense sweep): stepping
//! both in lockstep, the fast core's full slot state must match the
//! oracle's bit-for-bit, dirty or not.

use menage::analog::AnalogParams;
use menage::config::AcceleratorConfig;
use menage::mapping::{distill, map_layer, Strategy};
use menage::neuracore::NeuraCore;
use menage::snn::{LifParams, QuantLayer, SpikeTrain};
use menage::util::prop;
use menage::util::rng::Rng;

fn accel(m: usize, n: usize) -> AcceleratorConfig {
    let mut c = AcceleratorConfig::accel1();
    c.a_neurons_per_core = m;
    c.a_syns_per_core = m;
    c.virtual_per_a_neuron = n;
    c
}

fn random_layer(in_dim: usize, out_dim: usize, lif: LifParams, rng: &mut Rng) -> QuantLayer {
    let mut w = vec![0i8; in_dim * out_dim];
    for x in w.iter_mut() {
        if !rng.bernoulli(0.5) {
            *x = rng.range_inclusive(-127, 127) as i8;
        }
    }
    QuantLayer::new(in_dim, out_dim, w, 0.02, lif).unwrap()
}

fn build_core_with(
    layer: &QuantLayer,
    cfg: &AcceleratorConfig,
    dense: bool,
    analog: &AnalogParams,
) -> NeuraCore {
    let mp = map_layer(layer, cfg, Strategy::IlpFlow).unwrap();
    let img = distill(layer, &mp, cfg).unwrap();
    let mut rng = Rng::new(99);
    let mut core = NeuraCore::new(0, img, layer.lif, analog, cfg, &mut rng).unwrap();
    core.force_dense_sweep = dense;
    core
}

fn build_core(layer: &QuantLayer, cfg: &AcceleratorConfig, dense: bool) -> NeuraCore {
    build_core_with(layer, cfg, dense, &AnalogParams::ideal())
}

/// Check the invariant for one round's slot dump against the oracle's.
fn check_round(
    fast: &[(f32, i32, bool)],
    oracle: &[(f32, i32, bool)],
    v_reset: f32,
    ctx: &str,
) -> Result<(), String> {
    if fast.len() != oracle.len() {
        return Err(format!("{ctx}: slot count mismatch"));
    }
    for (slot, (&(mem, acc, dirty), &(omem, oacc, _))) in
        fast.iter().zip(oracle.iter()).enumerate()
    {
        // Oracle agreement for every slot (dense sweep recomputes all).
        if mem.to_bits() != omem.to_bits() || acc != oacc {
            return Err(format!(
                "{ctx}: slot {slot} diverges from dense oracle: \
                 ({mem}, {acc}) vs ({omem}, {oacc})"
            ));
        }
        // The invariant proper: clean ⇒ quiescent fixed point.
        if !dirty && (mem.to_bits() != v_reset.to_bits() || acc != 0) {
            return Err(format!(
                "{ctx}: slot {slot} is clean but not quiescent (mem={mem}, acc={acc})"
            ));
        }
    }
    Ok(())
}

/// Sequential mode: invariant holds after every step of a random run.
#[test]
fn prop_sequential_dirty_slot_invariant() {
    prop::check_n("dirty-slot-sequential", 16, |rng| {
        let lif = LifParams { beta: 0.9, v_threshold: 1.0, v_reset: 0.0 };
        let in_dim = 8 + rng.below(25);
        let out_dim = 4 + rng.below(20);
        let layer = random_layer(in_dim, out_dim, lif, rng);
        let cfg = accel(2 + rng.below(3), 1 + rng.below(4));
        let mut fast = build_core(&layer, &cfg, false);
        let mut oracle = build_core(&layer, &cfg, true);
        assert!(fast.sweep_skip_enabled(), "β·0 == 0 must enable the skip");
        let t = 4 + rng.below(6);
        let input = SpikeTrain::bernoulli(in_dim, t, 0.05 + rng.f64() * 0.3, rng);
        for step in 0..t {
            fast.push_events(&input.spikes[step]);
            oracle.push_events(&input.spikes[step]);
            let a = fast.step();
            let b = oracle.step();
            if a != b {
                return Err(format!("step {step}: outputs diverge"));
            }
            for round in 0..fast.rounds() {
                check_round(
                    &fast.slot_states(round),
                    &oracle.slot_states(round),
                    lif.v_reset,
                    &format!("step {step} round {round}"),
                )?;
            }
        }
        Ok(())
    });
}

/// Lane mode: the invariant holds per lane after every step, against a
/// dense-sweep lane oracle stepped in lockstep.
#[test]
fn prop_lane_dirty_slot_invariant() {
    prop::check_n("dirty-slot-lanes", 12, |rng| {
        let lif = LifParams { beta: 0.9, v_threshold: 1.0, v_reset: 0.0 };
        let in_dim = 8 + rng.below(25);
        let out_dim = 4 + rng.below(20);
        let layer = random_layer(in_dim, out_dim, lif, rng);
        let cfg = accel(2 + rng.below(3), 1 + rng.below(4));
        let mut fast = build_core(&layer, &cfg, false);
        let mut oracle = build_core(&layer, &cfg, true);
        let b = 2 + rng.below(4);
        fast.ensure_lanes(b);
        oracle.ensure_lanes(b);
        let t = 3 + rng.below(5);
        let inputs: Vec<SpikeTrain> = (0..b)
            .map(|_| SpikeTrain::bernoulli(in_dim, t, rng.f64() * 0.35, rng))
            .collect();
        let active: Vec<usize> = (0..b).collect();
        let mut bufs_a: Vec<Vec<u32>> = vec![Vec::new(); b];
        let mut bufs_b: Vec<Vec<u32>> = vec![Vec::new(); b];
        for step in 0..t {
            for i in 0..b {
                fast.push_events_lane(i, &inputs[i].spikes[step]);
                oracle.push_events_lane(i, &inputs[i].spikes[step]);
            }
            fast.step_lanes_into(&active, &mut bufs_a);
            oracle.step_lanes_into(&active, &mut bufs_b);
            if bufs_a != bufs_b {
                return Err(format!("step {step}: lane outputs diverge"));
            }
            for lane in 0..b {
                for round in 0..fast.rounds() {
                    check_round(
                        &fast.lane_slot_states(lane, round),
                        &oracle.lane_slot_states(lane, round),
                        lif.v_reset,
                        &format!("step {step} lane {lane} round {round}"),
                    )?;
                }
            }
        }
        Ok(())
    });
}

/// Non-ideal analog mode: the unified sweep applies the Kahan error
/// sidecar, hold droop, and the rail clamp for dirty slots, and
/// `v_reset == 0` is still a quiescent fixed point under the paper's
/// parameters — the skip stays enabled and the invariant must hold in
/// lane mode against a dense-sweep oracle stepped in lockstep (both run
/// the same unified engine, so agreement is bit-for-bit).
#[test]
fn prop_nonideal_lane_dirty_slot_invariant() {
    prop::check_n("dirty-slot-lanes-nonideal", 8, |rng| {
        let lif = LifParams { beta: 0.9, v_threshold: 1.0, v_reset: 0.0 };
        let in_dim = 8 + rng.below(20);
        let out_dim = 4 + rng.below(16);
        let layer = random_layer(in_dim, out_dim, lif, rng);
        let cfg = accel(2 + rng.below(3), 1 + rng.below(4));
        let paper = AnalogParams::paper();
        let mut fast = build_core_with(&layer, &cfg, false, &paper);
        let mut oracle = build_core_with(&layer, &cfg, true, &paper);
        assert!(
            fast.sweep_skip_enabled(),
            "v_reset == 0 must stay a fixed point under paper non-idealities"
        );
        let b = 2 + rng.below(3);
        fast.ensure_lanes(b);
        oracle.ensure_lanes(b);
        let t = 3 + rng.below(5);
        let inputs: Vec<SpikeTrain> = (0..b)
            .map(|_| SpikeTrain::bernoulli(in_dim, t, rng.f64() * 0.3, rng))
            .collect();
        let active: Vec<usize> = (0..b).collect();
        let mut bufs_a: Vec<Vec<u32>> = vec![Vec::new(); b];
        let mut bufs_b: Vec<Vec<u32>> = vec![Vec::new(); b];
        for step in 0..t {
            for i in 0..b {
                fast.push_events_lane(i, &inputs[i].spikes[step]);
                oracle.push_events_lane(i, &inputs[i].spikes[step]);
            }
            fast.step_lanes_into(&active, &mut bufs_a);
            oracle.step_lanes_into(&active, &mut bufs_b);
            if bufs_a != bufs_b {
                return Err(format!("step {step}: lane outputs diverge"));
            }
            for lane in 0..b {
                for round in 0..fast.rounds() {
                    check_round(
                        &fast.lane_slot_states(lane, round),
                        &oracle.lane_slot_states(lane, round),
                        lif.v_reset,
                        &format!("step {step} lane {lane} round {round}"),
                    )?;
                }
            }
        }
        Ok(())
    });
}

/// Streaming suspend/resume: a session chunk boundary is just a step
/// seam (no membrane reset), so a lane suspended mid-stream and resumed
/// later must uphold the clean ⇒ quiescent-fixed-point invariant across
/// the seam — including when a *sibling* lane is recycled with
/// `reset_lane` at the boundary (the session pool's lane-reuse path),
/// checked against a `force_dense_sweep` oracle stepped in lockstep, in
/// ideal and paper analog modes.
#[test]
fn prop_suspend_resume_preserves_dirty_slot_invariant() {
    prop::check_n("dirty-slot-suspend-resume", 8, |rng| {
        let lif = LifParams { beta: 0.9, v_threshold: 1.0, v_reset: 0.0 };
        let in_dim = 8 + rng.below(20);
        let out_dim = 4 + rng.below(16);
        let layer = random_layer(in_dim, out_dim, lif, rng);
        let cfg = accel(2 + rng.below(3), 1 + rng.below(4));
        let analog =
            if rng.bernoulli(0.5) { AnalogParams::ideal() } else { AnalogParams::paper() };
        let mut fast = build_core_with(&layer, &cfg, false, &analog);
        let mut oracle = build_core_with(&layer, &cfg, true, &analog);
        assert!(fast.sweep_skip_enabled());
        let b = 2;
        fast.ensure_lanes(b);
        oracle.ensure_lanes(b);
        let active: Vec<usize> = (0..b).collect();
        let mut bufs_a: Vec<Vec<u32>> = vec![Vec::new(); b];
        let mut bufs_b: Vec<Vec<u32>> = vec![Vec::new(); b];
        let mut drive = |fast: &mut NeuraCore,
                         oracle: &mut NeuraCore,
                         inputs: &[SpikeTrain],
                         bufs_a: &mut Vec<Vec<u32>>,
                         bufs_b: &mut Vec<Vec<u32>>,
                         phase: &str|
         -> Result<(), String> {
            let t = inputs[0].timesteps();
            for step in 0..t {
                for i in 0..b {
                    fast.push_events_lane(i, &inputs[i].spikes[step]);
                    oracle.push_events_lane(i, &inputs[i].spikes[step]);
                }
                fast.step_lanes_into(&active, &mut bufs_a[..]);
                oracle.step_lanes_into(&active, &mut bufs_b[..]);
                if bufs_a != bufs_b {
                    return Err(format!("{phase} step {step}: lane outputs diverge"));
                }
                for lane in 0..b {
                    for round in 0..fast.rounds() {
                        check_round(
                            &fast.lane_slot_states(lane, round),
                            &oracle.lane_slot_states(lane, round),
                            lif.v_reset,
                            &format!("{phase} step {step} lane {lane} round {round}"),
                        )?;
                    }
                }
            }
            Ok(())
        };

        // Chunk 1: both lanes stream live sessions.
        let mk = |rng: &mut Rng, t: usize| -> Vec<SpikeTrain> {
            (0..b).map(|_| SpikeTrain::bernoulli(in_dim, t, 0.1 + rng.f64() * 0.3, rng)).collect()
        };
        let t1 = 2 + rng.below(4);
        let c1 = mk(rng, t1);
        drive(&mut fast, &mut oracle, &c1, &mut bufs_a, &mut bufs_b, "chunk1")?;

        // Boundary: lane 0 suspends (state kept); lane 1's session ends
        // and its slot is recycled for a new occupant.
        fast.reset_lane(1);
        oracle.reset_lane(1);
        for round in 0..fast.rounds() {
            for (slot, &(mem, acc, dirty)) in fast.lane_slot_states(1, round).iter().enumerate()
            {
                if mem.to_bits() != lif.v_reset.to_bits() || acc != 0 {
                    return Err(format!(
                        "recycled lane round {round} slot {slot} not quiescent \
                         (mem={mem}, acc={acc})"
                    ));
                }
                if dirty {
                    return Err(format!(
                        "recycled lane round {round} slot {slot} dirty under sweep-skip — \
                         the new session would pay dense sweeps for a quiescent lane"
                    ));
                }
            }
        }

        // Chunk 2: lane 0 resumes its suspended membranes, lane 1 starts
        // a fresh session — the seam must be invisible to the invariant.
        let t2 = 2 + rng.below(4);
        let c2 = mk(rng, t2);
        drive(&mut fast, &mut oracle, &c2, &mut bufs_a, &mut bufs_b, "chunk2")
    });
}

/// When `v_reset` is not a fixed point of the leak, skipping must be
/// disabled (every slot permanently dirty) and the invariant is vacuous —
/// but the dense oracle must still agree bit-for-bit.
#[test]
fn nonzero_v_reset_disables_skip_everywhere() {
    let lif = LifParams { beta: 0.9, v_threshold: 1.0, v_reset: 0.25 };
    let mut rng = Rng::new(33);
    let layer = random_layer(20, 12, lif, &mut rng);
    let cfg = accel(4, 4);
    let mut fast = build_core(&layer, &cfg, false);
    assert!(!fast.sweep_skip_enabled());
    let mut oracle = build_core(&layer, &cfg, true);
    let input = SpikeTrain::bernoulli(20, 8, 0.2, &mut rng);
    for step in 0..8 {
        fast.push_events(&input.spikes[step]);
        oracle.push_events(&input.spikes[step]);
        assert_eq!(fast.step(), oracle.step(), "step {step}");
        for round in 0..fast.rounds() {
            let states = fast.slot_states(round);
            assert!(
                states.iter().all(|&(_, _, dirty)| dirty),
                "step {step}: skip disabled ⇒ every slot stays dirty"
            );
            for (s, (&(m, a, _), &(om, oa, _))) in states
                .iter()
                .zip(oracle.slot_states(round).iter())
                .enumerate()
            {
                assert_eq!(m.to_bits(), om.to_bits(), "step {step} slot {s}");
                assert_eq!(a, oa, "step {step} slot {s}");
            }
        }
    }
}
