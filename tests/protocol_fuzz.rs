//! Property/fuzz suite for the wire-protocol parser: no byte stream —
//! random soup, truncated valid traffic, or bit-mutated frames — may
//! panic [`FrameReader`] or the payload decoders. Outcomes are confined
//! to (a) correctly decoded frames, (b) a clean end-of-stream, or (c) a
//! typed `io::Error`; valid frames *before* a corruption point must still
//! come through intact.
//!
//! Complements the deterministic malformed-frame cases in
//! `tests/serve_roundtrip.rs` (which pin server *behavior*); this suite
//! pins parser *totality* under the seeded property driver
//! (`menage::util::prop`) so failures reproduce by seed.

use std::io::Cursor;

use menage::serve::protocol::{
    decode_stats_reply, write_frame, ErrorCode, ErrorFrame, FrameKind, FrameReader,
    InferRequest, InferResponse, SessionChunkFrame, SessionIdFrame, SessionOutFrame,
    DEFAULT_MAX_FRAME_LEN,
};
use menage::snn::SpikeTrain;
use menage::util::prop::check_n;
use menage::util::rng::Rng;

/// Pull frames out of `bytes` until end-of-stream or the first error.
/// Returns the frames successfully read and the terminal error, if any.
fn drain(bytes: &[u8], max_frame_len: usize) -> (usize, Option<std::io::Error>) {
    let mut cur = Cursor::new(bytes);
    let mut fr = FrameReader::new(max_frame_len);
    let mut frames = 0usize;
    loop {
        match fr.read_frame(&mut cur) {
            Ok(Some(_)) => frames += 1,
            Ok(None) => return (frames, None),
            Err(e) => return (frames, Some(e)),
        }
    }
}

/// A syntactically valid multi-frame stream with mixed kinds and
/// heterogeneous spike trains. Returns the bytes, the frame count, and
/// each frame's end offset (a frame boundary table for truncation tests).
fn valid_stream(rng: &mut Rng) -> (Vec<u8>, usize, Vec<usize>) {
    let mut buf = Vec::new();
    let mut ends = Vec::new();
    let k = 1 + rng.below(5);
    for i in 0..k {
        match rng.below(8) {
            0 => {
                let req = InferRequest {
                    id: i as u64,
                    deadline_ms: rng.below(1_000) as u32,
                    label: if rng.bernoulli(0.5) { Some(rng.below(10) as u32) } else { None },
                    train: SpikeTrain::bernoulli(1 + rng.below(40), rng.below(8), 0.3, rng),
                };
                write_frame(&mut buf, FrameKind::InferRequest, &req.encode()).unwrap();
            }
            1 => {
                let resp = InferResponse {
                    id: i as u64,
                    predicted: rng.below(10) as u32,
                    cycles: rng.next_u64() >> 32,
                    server_micros: rng.below(1_000_000) as u64,
                    output: SpikeTrain::bernoulli(1 + rng.below(12), rng.below(6), 0.4, rng),
                };
                write_frame(&mut buf, FrameKind::InferResponse, &resp.encode()).unwrap();
            }
            2 => {
                let e = ErrorFrame::new(i as u64, ErrorCode::Overload, "server busy");
                write_frame(&mut buf, FrameKind::Error, &e.encode()).unwrap();
            }
            3 => {
                let f = SessionIdFrame { sid: rng.next_u64() };
                let kind = if rng.bernoulli(0.5) {
                    FrameKind::SessionOpen
                } else {
                    FrameKind::SessionClose
                };
                write_frame(&mut buf, kind, &f.encode()).unwrap();
            }
            4 => {
                let f = SessionChunkFrame {
                    sid: rng.next_u64(),
                    seq: rng.below(64) as u64,
                    chunk: SpikeTrain::bernoulli(1 + rng.below(40), rng.below(8), 0.3, rng),
                };
                write_frame(&mut buf, FrameKind::SessionChunk, &f.encode()).unwrap();
            }
            5 => {
                let f = SessionOutFrame {
                    sid: rng.next_u64(),
                    seq: rng.below(64) as u64,
                    chunk_cycles: rng.next_u64() >> 32,
                    predicted: rng.below(10) as u32,
                    output: SpikeTrain::bernoulli(1 + rng.below(12), rng.below(6), 0.4, rng),
                };
                write_frame(&mut buf, FrameKind::SessionOut, &f.encode()).unwrap();
            }
            _ => write_frame(&mut buf, FrameKind::Ping, &[]).unwrap(),
        }
        ends.push(buf.len());
    }
    (buf, k, ends)
}

/// Random byte soup: the reader must terminate with frames/EOF/error —
/// never panic, never loop forever.
#[test]
fn random_byte_soup_never_panics() {
    check_n("protocol-random-soup", 256, |rng| {
        let n = rng.below(4_096);
        let bytes: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        let (_frames, _err) = drain(&bytes, 1 << 16);
        Ok(())
    });
}

/// Every truncation of a valid stream yields exactly the frames whose
/// bytes fully arrived; a cut mid-frame is a clean error (or a resumable
/// wait), and the untruncated stream drains completely.
#[test]
fn truncated_valid_streams_decode_complete_prefix() {
    check_n("protocol-truncation", 256, |rng| {
        let (buf, k, ends) = valid_stream(rng);
        let cut = rng.below(buf.len() + 1);
        let whole = ends.iter().filter(|&&e| e <= cut).count();
        let (frames, err) = drain(&buf[..cut], DEFAULT_MAX_FRAME_LEN);
        if frames != whole {
            return Err(format!(
                "cut at {cut}/{}: decoded {frames} frames, {whole} fully present",
                buf.len()
            ));
        }
        let at_boundary = cut == 0 || ends.contains(&cut);
        if at_boundary && err.is_some() {
            return Err(format!("boundary cut at {cut} errored: {err:?}"));
        }
        if cut == buf.len() && (frames != k || err.is_some()) {
            return Err(format!("full stream: {frames}/{k} frames, err {err:?}"));
        }
        Ok(())
    });
}

/// Bit-mutated valid streams: frames before the first mutated byte still
/// decode; after it, anything goes except a panic or a runaway read.
#[test]
fn bit_mutated_streams_never_panic() {
    check_n("protocol-bit-mutation", 256, |rng| {
        let (mut buf, _k, ends) = valid_stream(rng);
        let flips = 1 + rng.below(8);
        let mut first_mutated = buf.len();
        for _ in 0..flips {
            let i = rng.below(buf.len());
            buf[i] ^= 1 << rng.below(8);
            first_mutated = first_mutated.min(i);
        }
        let intact = ends.iter().filter(|&&e| e <= first_mutated).count();
        let (frames, _err) = drain(&buf, DEFAULT_MAX_FRAME_LEN);
        if frames < intact {
            return Err(format!(
                "lost intact prefix: {frames} decoded, {intact} frames precede the \
                 first mutation at byte {first_mutated}"
            ));
        }
        Ok(())
    });
}

/// The payload decoders are total over arbitrary bytes: truncated,
/// oversized-count, and garbage payloads return `Err`, never panic, and
/// never allocate from an unvalidated length field.
#[test]
fn payload_decoders_total_over_random_bytes() {
    check_n("protocol-decoder-soup", 512, |rng| {
        let n = rng.below(512);
        let bytes: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        let _ = InferRequest::decode(&bytes);
        let _ = InferResponse::decode(&bytes);
        let _ = ErrorFrame::decode(&bytes);
        let _ = decode_stats_reply(&bytes);
        let _ = SessionIdFrame::decode(&bytes);
        let _ = SessionChunkFrame::decode(&bytes);
        let _ = SessionOutFrame::decode(&bytes);
        Ok(())
    });
}

/// Mutating a well-formed SESSION_CHUNK payload (post-framing) either
/// decodes to *some* valid chunk or errors — session decoders validate
/// their trains exactly like the one-shot request decoder.
#[test]
fn mutated_session_payloads_decode_or_error() {
    check_n("protocol-session-mutation", 256, |rng| {
        let f = SessionChunkFrame {
            sid: rng.next_u64(),
            seq: rng.below(1_000) as u64,
            chunk: SpikeTrain::bernoulli(1 + rng.below(30), 1 + rng.below(6), 0.3, rng),
        };
        let mut payload = f.encode();
        let i = rng.below(payload.len());
        payload[i] ^= 1 << rng.below(8);
        if let Ok(back) = SessionChunkFrame::decode(&payload) {
            back.chunk
                .validate()
                .map_err(|e| format!("decoder accepted an invalid chunk train: {e}"))?;
        }
        let out = SessionOutFrame {
            sid: rng.next_u64(),
            seq: rng.below(1_000) as u64,
            chunk_cycles: rng.next_u64() >> 32,
            predicted: rng.below(10) as u32,
            output: SpikeTrain::bernoulli(1 + rng.below(12), 1 + rng.below(6), 0.4, rng),
        };
        let mut payload = out.encode();
        let i = rng.below(payload.len());
        payload[i] ^= 1 << rng.below(8);
        if let Ok(back) = SessionOutFrame::decode(&payload) {
            back.output
                .validate()
                .map_err(|e| format!("decoder accepted an invalid output train: {e}"))?;
        }
        Ok(())
    });
}

/// Mutating a well-formed INFER_REQUEST payload (post-framing) either
/// decodes to *some* valid request or errors — the decoder's validation
/// can't be bypassed by single-bit damage.
#[test]
fn mutated_request_payloads_decode_or_error() {
    check_n("protocol-request-mutation", 256, |rng| {
        let req = InferRequest {
            id: rng.next_u64(),
            deadline_ms: rng.below(10_000) as u32,
            label: None,
            train: SpikeTrain::bernoulli(1 + rng.below(30), 1 + rng.below(6), 0.3, rng),
        };
        let mut payload = req.encode();
        let i = rng.below(payload.len());
        payload[i] ^= 1 << rng.below(8);
        if let Ok(back) = InferRequest::decode(&payload) {
            back.train
                .validate()
                .map_err(|e| format!("decoder accepted an invalid train: {e}"))?;
        }
        Ok(())
    });
}

/// A frame claiming a length beyond the reader's cap is rejected as an
/// error (no unbounded buffering), for every cap below the claim.
#[test]
fn oversized_frame_length_rejected_without_allocation() {
    check_n("protocol-length-cap", 64, |rng| {
        let mut buf = Vec::new();
        let payload = vec![0u8; 64];
        write_frame(&mut buf, FrameKind::InferRequest, &payload).unwrap();
        // Mutate the length field (bytes 4..8) to an absurd claim.
        let claim = (1u32 << 24) + rng.below(1 << 24) as u32;
        buf[4..8].copy_from_slice(&claim.to_le_bytes());
        let (frames, err) = drain(&buf, 1 << 16);
        if frames != 0 || err.is_none() {
            return Err(format!("oversized claim {claim} accepted ({frames} frames)"));
        }
        Ok(())
    });
}
