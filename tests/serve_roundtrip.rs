//! Loopback integration suite for the TCP serving subsystem.
//!
//! The acceptance bar: a ≥256-request run over ≥8 concurrent connections
//! with zero dropped/mismatched responses, server-side outputs
//! **bit-identical** to in-process [`Menage::run`] for the same inputs
//! (predicted class, modeled cycles, and the full output spike train).
//! Plus the failure envelope: malformed/truncated frames must not kill
//! the server, overload must reject explicitly under a tiny in-flight
//! cap, deadlines must expire, and graceful shutdown must drain in-flight
//! work rather than drop it.

use std::io::Write as _;
use std::net::TcpStream;
use std::time::Duration;

use menage::accel::Menage;
use menage::analog::AnalogParams;
use menage::config::{AcceleratorConfig, ModelConfig};
use menage::mapping::Strategy;
use menage::serve::protocol::{write_frame, ErrorCode, FrameKind, STATS_VERSION};
use menage::serve::{Client, Reply, ServeConfig, Server};
use menage::snn::SpikeTrain;
use menage::util::json::Json;
use menage::util::rng::Rng;

fn test_chip() -> Menage {
    let mcfg = ModelConfig {
        name: "serve-test".into(),
        layer_sizes: vec![30, 16, 8],
        timesteps: 6,
        beta: 0.9,
        v_threshold: 1.0,
        v_reset: 0.0,
    };
    let mut cfg = AcceleratorConfig::accel1();
    cfg.num_cores = 2;
    cfg.a_neurons_per_core = 4;
    cfg.a_syns_per_core = 4;
    cfg.virtual_per_a_neuron = 4;
    let mut rng = Rng::new(8);
    let net = menage::snn::QuantNetwork::random(&mcfg, 0.5, &mut rng);
    Menage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 2).unwrap()
}

/// Deterministic per-(connection, request) input with heterogeneous train
/// lengths (T cycles through 1..=6 while the model was trained at T=6 —
/// the serving path must handle both shorter and full-length trains).
fn train_for(conn: usize, i: usize) -> SpikeTrain {
    let mut rng = Rng::new(9_000 + conn as u64 * 101 + i as u64);
    let t = 1 + (conn + i) % 6;
    SpikeTrain::bernoulli(30, t, 0.25, &mut rng)
}

fn start_server(cfg: ServeConfig) -> Server {
    let chip = test_chip();
    Server::start(&chip, "127.0.0.1:0", cfg).unwrap()
}

/// The acceptance-criteria run: 256 requests over 8 concurrent
/// connections (pipelined, heterogeneous lengths), every response
/// bit-identical to an in-process `Menage::run` of the same input.
#[test]
fn concurrent_roundtrip_bit_identical_to_in_process() {
    const CONNS: usize = 8;
    const PER_CONN: usize = 32; // 256 total
    const PIPELINE: usize = 4;

    // In-process golden results, computed on a private chip.
    let mut local = test_chip();
    let mut golden: Vec<Vec<(usize, u64, SpikeTrain)>> = Vec::new();
    for c in 0..CONNS {
        let mut per = Vec::new();
        for i in 0..PER_CONN {
            let out = local.run(&train_for(c, i)).unwrap();
            per.push((out.predicted_class(), out.cycles, out.output().clone()));
        }
        golden.push(per);
    }

    let server = start_server(ServeConfig {
        workers: 2,
        lanes_per_worker: 4,
        fill_wait: Duration::from_micros(500),
        ..ServeConfig::default()
    });
    let addr = server.local_addr();

    let threads: Vec<_> = golden
        .into_iter()
        .enumerate()
        .map(|(c, expected)| {
            std::thread::spawn(move || {
                let mut client = Client::connect(addr).unwrap();
                let mut outstanding: Vec<u64> = Vec::new();
                let mut sent = 0usize;
                let mut got = 0usize;
                while got < PER_CONN {
                    while sent < PER_CONN && outstanding.len() < PIPELINE {
                        let id = client.send_infer(&train_for(c, sent), 0, None).unwrap();
                        assert_eq!(id as usize, sent, "client ids are sequential");
                        outstanding.push(id);
                        sent += 1;
                    }
                    match client.recv_reply().unwrap() {
                        Reply::Infer(r) => {
                            let i = r.id as usize;
                            assert!(
                                outstanding.contains(&r.id),
                                "conn {c}: unexpected/duplicate response id {i}"
                            );
                            outstanding.retain(|&x| x != r.id);
                            let (pred, cycles, ref output) = expected[i];
                            assert_eq!(r.predicted as usize, pred, "conn {c} req {i}: class");
                            assert_eq!(r.cycles, cycles, "conn {c} req {i}: cycles");
                            assert_eq!(&r.output, output, "conn {c} req {i}: output train");
                            got += 1;
                        }
                        other => panic!("conn {c}: unexpected reply {other:?}"),
                    }
                }
                assert!(outstanding.is_empty(), "conn {c}: dropped responses");
            })
        })
        .collect();
    for t in threads {
        t.join().expect("connection thread failed");
    }

    let metrics = server.metrics();
    let chips = server.shutdown();
    use std::sync::atomic::Ordering;
    assert_eq!(metrics.completed.load(Ordering::Relaxed), (CONNS * PER_CONN) as u64);
    assert_eq!(metrics.rejected_overload.load(Ordering::Relaxed), 0);
    assert_eq!(metrics.dropped_responses.load(Ordering::Relaxed), 0);
    assert_eq!(metrics.protocol_errors.load(Ordering::Relaxed), 0);
    // Every served input is visible on the returned worker chips.
    let total: u64 = chips.iter().map(|ch| ch.inputs_processed).sum();
    assert_eq!(total, (CONNS * PER_CONN) as u64);
}

/// Garbage bytes (bad magic) must close only that connection — with an
/// ERROR Malformed answer where possible — while the server keeps serving
/// other clients.
#[test]
fn malformed_frames_reject_without_killing_server() {
    let server = start_server(ServeConfig::default());
    let addr = server.local_addr();

    // Raw garbage: not even a valid header.
    let mut raw = TcpStream::connect(addr).unwrap();
    raw.write_all(&[0xFFu8; 64]).unwrap();
    raw.flush().unwrap();
    // The server answers ERROR Malformed and closes; tolerate either a
    // clean read of that frame or an immediate reset.
    let mut fr = menage::serve::protocol::FrameReader::new(1 << 20);
    match fr.read_frame(&mut raw) {
        Ok(Some(f)) => {
            assert_eq!(FrameKind::from_u8(f.kind), Some(FrameKind::Error));
            let ef = menage::serve::protocol::ErrorFrame::decode(&f.payload).unwrap();
            assert_eq!(ef.code, ErrorCode::Malformed);
        }
        Ok(None) | Err(_) => {} // connection torn down before the frame
    }

    // A valid INFER_REQUEST whose payload is garbage: well-framed, so the
    // server answers BadRequest and KEEPS the connection.
    let mut c = Client::connect(addr).unwrap();
    {
        // Reach the raw stream by sending through a second raw socket.
        let mut s = TcpStream::connect(addr).unwrap();
        write_frame(&mut s, FrameKind::InferRequest, &[1, 2, 3]).unwrap();
        let mut fr = menage::serve::protocol::FrameReader::new(1 << 20);
        let f = fr.read_frame(&mut s).unwrap().unwrap();
        assert_eq!(FrameKind::from_u8(f.kind), Some(FrameKind::Error));
        let ef = menage::serve::protocol::ErrorFrame::decode(&f.payload).unwrap();
        assert_eq!(ef.code, ErrorCode::BadRequest);
        // Same connection still serves a valid request.
        let mut rng = Rng::new(1);
        let train = SpikeTrain::bernoulli(30, 3, 0.3, &mut rng);
        let req = menage::serve::protocol::InferRequest {
            id: 77,
            deadline_ms: 0,
            label: None,
            train,
        };
        write_frame(&mut s, FrameKind::InferRequest, &req.encode()).unwrap();
        let f = fr.read_frame(&mut s).unwrap().unwrap();
        assert_eq!(FrameKind::from_u8(f.kind), Some(FrameKind::InferResponse));
        let resp = menage::serve::protocol::InferResponse::decode(&f.payload).unwrap();
        assert_eq!(resp.id, 77);
    }

    // Unknown frame kind: answered with Unsupported, connection kept.
    {
        let mut s = TcpStream::connect(addr).unwrap();
        let mut header = [0u8; 8];
        header[0..2].copy_from_slice(&menage::serve::protocol::MAGIC.to_le_bytes());
        header[2] = menage::serve::protocol::VERSION;
        header[3] = 0xEE; // no such kind
        s.write_all(&header).unwrap();
        s.flush().unwrap();
        let mut fr = menage::serve::protocol::FrameReader::new(1 << 20);
        let f = fr.read_frame(&mut s).unwrap().unwrap();
        let ef = menage::serve::protocol::ErrorFrame::decode(&f.payload).unwrap();
        assert_eq!(ef.code, ErrorCode::Unsupported);
    }

    // Through all of that, a normal client still gets service.
    let r = c.infer(&train_for(0, 0)).unwrap();
    assert!((r.predicted as usize) < 8);
    let metrics = server.metrics();
    server.shutdown();
    use std::sync::atomic::Ordering;
    assert!(metrics.protocol_errors.load(Ordering::Relaxed) >= 1);
    assert!(metrics.rejected_bad_request.load(Ordering::Relaxed) >= 1);
}

/// A connection dropped mid-frame must not wedge or kill the server.
#[test]
fn truncated_frame_then_disconnect_leaves_server_healthy() {
    let server = start_server(ServeConfig::default());
    let addr = server.local_addr();
    {
        let mut s = TcpStream::connect(addr).unwrap();
        // First half of a valid frame, then vanish.
        let mut full = Vec::new();
        let mut rng = Rng::new(2);
        let req = menage::serve::protocol::InferRequest {
            id: 1,
            deadline_ms: 0,
            label: None,
            train: SpikeTrain::bernoulli(30, 4, 0.3, &mut rng),
        };
        write_frame(&mut full, FrameKind::InferRequest, &req.encode()).unwrap();
        s.write_all(&full[..full.len() / 2]).unwrap();
        s.flush().unwrap();
    } // dropped here
    std::thread::sleep(Duration::from_millis(100));
    let mut c = Client::connect(addr).unwrap();
    let r = c.infer(&train_for(1, 1)).unwrap();
    assert!((r.predicted as usize) < 8);
    server.shutdown();
}

/// Admission control: with an in-flight cap of 1, a second request
/// arriving while a heavy one runs is rejected with ERROR Overload — an
/// explicit, immediate reject, not silent queueing.
#[test]
fn overload_rejects_beyond_in_flight_cap() {
    let server = start_server(ServeConfig {
        workers: 1,
        lanes_per_worker: 1,
        max_in_flight: 1,
        fill_wait: Duration::ZERO,
        ..ServeConfig::default()
    });
    let addr = server.local_addr();
    let mut c = Client::connect(addr).unwrap();
    // Heavy: ~1500 busy steps dominates any scheduling jitter.
    let mut rng = Rng::new(3);
    let heavy = SpikeTrain::bernoulli(30, 1500, 0.5, &mut rng);
    let light = SpikeTrain::bernoulli(30, 2, 0.2, &mut rng);
    let heavy_id = c.send_infer(&heavy, 0, None).unwrap();
    let light_id = c.send_infer(&light, 0, None).unwrap();
    let (mut got_ok, mut got_overload) = (false, false);
    for _ in 0..2 {
        match c.recv_reply().unwrap() {
            Reply::Infer(r) => {
                assert_eq!(r.id, heavy_id);
                got_ok = true;
            }
            Reply::Error(e) => {
                assert_eq!(e.id, light_id);
                assert_eq!(e.code, ErrorCode::Overload, "{}", e.message);
                got_overload = true;
            }
            other => panic!("unexpected {other:?}"),
        }
    }
    assert!(got_ok && got_overload);
    let metrics = server.metrics();
    server.shutdown();
    use std::sync::atomic::Ordering;
    assert_eq!(metrics.rejected_overload.load(Ordering::Relaxed), 1);
    assert_eq!(metrics.completed.load(Ordering::Relaxed), 1);
}

/// A request whose deadline lapses before its result is routed gets
/// ERROR DeadlineExceeded instead of the (discarded) result.
#[test]
fn deadline_exceeded_is_reported() {
    let server = start_server(ServeConfig {
        workers: 1,
        lanes_per_worker: 1,
        ..ServeConfig::default()
    });
    let addr = server.local_addr();
    let mut c = Client::connect(addr).unwrap();
    let mut rng = Rng::new(4);
    // Heavy enough that 1 ms is long gone by completion.
    let heavy = SpikeTrain::bernoulli(30, 3000, 0.5, &mut rng);
    let err = c.infer_with_deadline(&heavy, 1).unwrap_err().to_string();
    assert!(err.contains("deadline_exceeded"), "{err}");
    let metrics = server.metrics();
    server.shutdown();
    use std::sync::atomic::Ordering;
    assert_eq!(metrics.deadline_expired.load(Ordering::Relaxed), 1);
}

/// STATS must report the model block (what loadgen synthesizes inputs
/// from) and live counters.
#[test]
fn stats_frame_reports_model_and_counters() {
    let server = start_server(ServeConfig::default());
    let mut c = Client::connect(server.local_addr()).unwrap();
    c.ping().unwrap();
    let before = c.stats().unwrap();
    let model = before.get("model").unwrap();
    assert_eq!(model.get("input_dim").unwrap().as_usize().unwrap(), 30);
    assert_eq!(model.get("timesteps").unwrap().as_usize().unwrap(), 6);
    assert_eq!(model.get("classes").unwrap().as_usize().unwrap(), 8);
    assert_eq!(
        before.get("counters").unwrap().get("completed").unwrap().as_usize().unwrap(),
        0
    );
    c.infer(&train_for(2, 0)).unwrap();
    let after = c.stats().unwrap();
    assert_eq!(
        after.get("counters").unwrap().get("completed").unwrap().as_usize().unwrap(),
        1
    );
    assert!(after.get("latency_us").unwrap().get("p50").unwrap().as_f64().unwrap() > 0.0);
    assert!(
        after.get("counters").unwrap().get("events_in").unwrap().as_usize().unwrap() > 0
    );
    server.shutdown();
}

/// Recursively collect every key path of a JSON tree ("a.b", "arr[].k").
/// Arrays contribute `[]` and recurse into their first element (rows are
/// homogeneous); an empty array pins just the `arr[]` path itself.
fn schema_paths(j: &Json, prefix: &str, out: &mut Vec<String>) {
    match j {
        Json::Obj(map) => {
            for (k, v) in map {
                let p = if prefix.is_empty() { k.clone() } else { format!("{prefix}.{k}") };
                schema_paths(v, &p, out);
            }
        }
        Json::Arr(a) => {
            let p = format!("{prefix}[]");
            match a.first() {
                Some(first) => schema_paths(first, &p, out),
                None => out.push(p),
            }
        }
        _ => out.push(prefix.to_string()),
    }
}

/// Golden STATS schema: the full key-path set of a (monolithic) server's
/// snapshot is pinned, exactly. Adding, renaming, or removing any field is
/// a deliberate act: bump [`STATS_VERSION`] and update this list in the
/// same change, so pollers (`menage top`, `loadgen --profile`) never read
/// silently drifted shapes.
#[test]
fn stats_snapshot_schema_is_pinned() {
    let server = start_server(ServeConfig::default());
    let mut c = Client::connect(server.local_addr()).unwrap();
    for i in 0..3 {
        c.infer(&train_for(4, i)).unwrap();
    }
    let stats = c.stats().unwrap();
    assert_eq!(
        stats.get("stats_version").unwrap().as_usize().unwrap() as u64,
        STATS_VERSION
    );
    let mut paths = Vec::new();
    schema_paths(&stats, "", &mut paths);
    paths.sort();
    let expected = vec![
        "counters.accepted",
        "counters.chaos_injected",
        "counters.completed",
        "counters.connections_active",
        "counters.connections_opened",
        "counters.deadline_expired",
        "counters.dropped_responses",
        "counters.events_in",
        "counters.protocol_errors",
        "counters.rejected_bad_request",
        "counters.rejected_overload",
        "counters.total_cycles",
        "counters.worker_errors",
        "faults.dead_slot_hits",
        "faults.events_bit_flipped",
        "faults.stuck_row_hits",
        "in_flight",
        "lane_occupancy.capacity",
        "lane_occupancy.dispatches",
        "lane_occupancy.max",
        "lane_occupancy.mean",
        "latency_us.count",
        "latency_us.max",
        "latency_us.mean",
        "latency_us.p50",
        "latency_us.p90",
        "latency_us.p99",
        "model.classes",
        "model.input_dim",
        "model.timesteps",
        "profile.cores[].core",
        "profile.cores[].cycles",
        "profile.cores[].events",
        "profile.cores[].fire_ops",
        "profile.cores[].integrations",
        "profile.cores[].macs",
        "profile.cores[].shard",
        "profile.cores[].sn_rows",
        "profile.cores[].spikes",
        "profile.shards[].cycles",
        "profile.shards[].events",
        "profile.shards[].fire_ops",
        "profile.shards[].integrations",
        "profile.shards[].macs",
        "profile.shards[].shard",
        "profile.shards[].sn_rows",
        "profile.shards[].spikes",
        "profile.slowest[].dispatch_us",
        "profile.slowest[].egress_us",
        "profile.slowest[].id",
        "profile.slowest[].queue_us",
        "profile.slowest[].step_us",
        "profile.slowest[].total_us",
        "profile.stages.admit.count",
        "profile.stages.admit.max",
        "profile.stages.admit.mean",
        "profile.stages.admit.p50",
        "profile.stages.admit.p90",
        "profile.stages.admit.p99",
        "profile.stages.dispatch.count",
        "profile.stages.dispatch.max",
        "profile.stages.dispatch.mean",
        "profile.stages.dispatch.p50",
        "profile.stages.dispatch.p90",
        "profile.stages.dispatch.p99",
        "profile.stages.egress.count",
        "profile.stages.egress.max",
        "profile.stages.egress.mean",
        "profile.stages.egress.p50",
        "profile.stages.egress.p90",
        "profile.stages.egress.p99",
        "profile.stages.queue.count",
        "profile.stages.queue.max",
        "profile.stages.queue.mean",
        "profile.stages.queue.p50",
        "profile.stages.queue.p90",
        "profile.stages.queue.p99",
        "profile.stages.step.count",
        "profile.stages.step.max",
        "profile.stages.step.mean",
        "profile.stages.step.p50",
        "profile.stages.step.p90",
        "profile.stages.step.p99",
        "queue_depth",
        "recovery.requests_failed",
        "recovery.requests_resubmitted",
        "recovery.worker_panics",
        "recovery.workers_respawned",
        "sessions.capacity",
        "sessions.chunks",
        "sessions.closed",
        "sessions.evicted",
        "sessions.opened",
        "sessions.rejected",
        "sessions.resident",
        "stats_version",
        "throughput.events_per_s",
        "throughput.requests_per_s",
        "uptime_s",
    ];
    assert_eq!(
        paths, expected,
        "STATS schema drifted — bump STATS_VERSION and update this golden list"
    );
    server.shutdown();
}

/// Graceful shutdown drains: requests in flight when shutdown begins are
/// still answered (through the coordinator's drain/salvage path) before
/// connections close; afterwards the listener is gone.
#[test]
fn graceful_shutdown_drains_in_flight() {
    const N: usize = 6;
    let server = start_server(ServeConfig {
        workers: 1,
        lanes_per_worker: 2,
        max_in_flight: 64,
        ..ServeConfig::default()
    });
    let addr = server.local_addr();

    let (ingested_tx, ingested_rx) = std::sync::mpsc::channel::<()>();
    let client_thread = std::thread::spawn(move || {
        let mut c = Client::connect(addr).unwrap();
        let mut rng = Rng::new(5);
        // One heavy request keeps the worker busy so the rest are still
        // queued/in-flight when shutdown starts.
        c.send_infer(&SpikeTrain::bernoulli(30, 1200, 0.5, &mut rng), 0, None).unwrap();
        for i in 1..N {
            c.send_infer(&train_for(3, i), 0, None).unwrap();
        }
        // PING after the requests: its PONG proves the reader ingested
        // everything above (frames are processed in order).
        c.ping().unwrap();
        ingested_tx.send(()).unwrap();
        // Now collect every response; shutdown must not drop any.
        let mut got = 0usize;
        while got < N {
            match c.recv_reply().unwrap() {
                Reply::Infer(_) => got += 1,
                other => panic!("unexpected reply during drain: {other:?}"),
            }
        }
        got
    });

    ingested_rx.recv_timeout(Duration::from_secs(30)).unwrap();
    let chips = server.shutdown(); // drains the N in-flight requests
    assert_eq!(client_thread.join().unwrap(), N, "responses lost in shutdown drain");
    let total: u64 = chips.iter().map(|ch| ch.inputs_processed).sum();
    assert_eq!(total, N as u64);
    // The listener is gone: connecting now must fail (allow a beat for the
    // OS to tear the socket down).
    std::thread::sleep(Duration::from_millis(50));
    assert!(TcpStream::connect(addr).is_err(), "server still accepting after shutdown");
}

/// A sharded server answers bit-identically to a monolithic one and its
/// STATS snapshot carries the per-shard topology block plus the
/// lane-occupancy gauges (reported, and bounded by the configured L).
#[test]
fn sharded_server_stats_and_bit_identity() {
    use menage::shard::ShardedMenage;
    let mcfg = ModelConfig {
        name: "serve-shard".into(),
        layer_sizes: vec![30, 16, 8],
        timesteps: 6,
        beta: 0.9,
        v_threshold: 1.0,
        v_reset: 0.0,
    };
    let mut cfg = AcceleratorConfig::accel1();
    cfg.num_cores = 2;
    cfg.a_neurons_per_core = 4;
    cfg.a_syns_per_core = 4;
    cfg.virtual_per_a_neuron = 4;
    let mut rng = Rng::new(8);
    let net = menage::snn::QuantNetwork::random(&mcfg, 0.5, &mut rng);
    let sharded =
        ShardedMenage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 2, 2)
            .unwrap();
    let lanes = 4usize;
    let server = Server::start_sharded(
        &sharded,
        "127.0.0.1:0",
        ServeConfig { workers: 2, lanes_per_worker: lanes, ..ServeConfig::default() },
    )
    .unwrap();
    let mut client = Client::connect(server.local_addr()).unwrap();

    // Bit-identity over the wire vs in-process monolithic execution.
    let mut local = test_chip();
    for i in 0..12 {
        let train = train_for(1, i);
        let golden = local.run(&train).unwrap();
        let reply = client.infer(&train).unwrap();
        assert_eq!(reply.predicted as usize, golden.predicted_class(), "request {i}");
        assert_eq!(reply.cycles, golden.cycles, "request {i}");
        assert_eq!(&reply.output, golden.output(), "request {i}");
    }

    let stats = client.stats().unwrap();
    // Per-shard topology block.
    let shards = stats.get("shards").unwrap();
    let menage::util::json::Json::Arr(arr) = shards else {
        panic!("shards block must be an array, got {shards:?}");
    };
    assert_eq!(arr.len(), 2);
    assert_eq!(arr[0].get("layer_lo").unwrap().as_usize().unwrap(), 0);
    assert_eq!(arr[0].get("input_dim").unwrap().as_usize().unwrap(), 30);
    assert_eq!(arr[1].get("output_dim").unwrap().as_usize().unwrap(), 8);
    assert!(arr[1].get("cut_cost_in").unwrap().as_usize().unwrap() > 0);
    // Lane-occupancy gauges: present, bounded by L.
    let occ = stats.get("lane_occupancy").unwrap();
    assert_eq!(occ.get("capacity").unwrap().as_usize().unwrap(), lanes);
    assert!(occ.get("dispatches").unwrap().as_usize().unwrap() > 0);
    let mean = occ.get("mean").unwrap().as_f64().unwrap();
    assert!((1.0..=lanes as f64).contains(&mean), "mean occupancy {mean}");
    let max = occ.get("max").unwrap().as_usize().unwrap();
    assert!((1..=lanes).contains(&max), "max occupancy {max}");
    // Execution profile (observability plane): versioned, with per-shard
    // counters attributing the 12 requests' work to both pipeline shards.
    assert_eq!(
        stats.get("stats_version").unwrap().as_usize().unwrap() as u64,
        STATS_VERSION
    );
    let profile = stats.get("profile").unwrap();
    let prof_shards = profile.get("shards").unwrap().as_arr().unwrap();
    assert_eq!(prof_shards.len(), 2);
    for row in prof_shards {
        assert!(
            row.get("cycles").unwrap().as_usize().unwrap() > 0,
            "every pipeline shard runs every request: {row}"
        );
        assert!(row.get("macs").unwrap().as_usize().unwrap() > 0, "{row}");
    }
    let prof_cores = profile.get("cores").unwrap().as_arr().unwrap();
    assert!(prof_cores.len() >= 2);
    let mapped: std::collections::BTreeSet<usize> = prof_cores
        .iter()
        .map(|r| r.get("shard").unwrap().as_usize().unwrap())
        .collect();
    assert_eq!(mapped.into_iter().collect::<Vec<_>>(), vec![0, 1], "cores span both shards");
    // Every routed response recorded one step-stage span.
    assert_eq!(
        profile.get("stages").unwrap().get("step").unwrap().get("count").unwrap()
            .as_usize().unwrap(),
        stats.get("counters").unwrap().get("completed").unwrap().as_usize().unwrap()
    );

    let chips = server.shutdown();
    assert_eq!(chips.len(), 2);
    let total: u64 = chips.iter().map(|c| c.inputs_processed).sum();
    assert_eq!(total, 12);
}

/// A monolithic server's STATS has the occupancy gauges too (and no
/// shards block) — the follow-up's unit bar: occupancy is reported and
/// bounded by L even on the un-sharded path.
#[test]
fn monolithic_stats_report_lane_occupancy() {
    let lanes = 4usize;
    let server = start_server(ServeConfig {
        workers: 2,
        lanes_per_worker: lanes,
        ..ServeConfig::default()
    });
    let mut client = Client::connect(server.local_addr()).unwrap();
    for i in 0..6 {
        client.infer(&train_for(2, i)).unwrap();
    }
    let stats = client.stats().unwrap();
    assert!(stats.get("shards").is_err(), "monolithic server must not report shards");
    let occ = stats.get("lane_occupancy").unwrap();
    assert_eq!(occ.get("capacity").unwrap().as_usize().unwrap(), lanes);
    let mean = occ.get("mean").unwrap().as_f64().unwrap();
    assert!((1.0..=lanes as f64).contains(&mean), "mean occupancy {mean}");
    assert!(occ.get("max").unwrap().as_usize().unwrap() <= lanes);
    // Monolithic profile: all cores map to shard 0 and the run's work is
    // attributed (MACs accumulate across the 6 requests).
    let profile = stats.get("profile").unwrap();
    let prof_cores = profile.get("cores").unwrap().as_arr().unwrap();
    assert_eq!(prof_cores.len(), 2, "test chip has 2 cores");
    for row in prof_cores {
        assert_eq!(row.get("shard").unwrap().as_usize().unwrap(), 0);
    }
    let prof_shards = profile.get("shards").unwrap().as_arr().unwrap();
    assert_eq!(prof_shards.len(), 1);
    assert!(prof_shards[0].get("macs").unwrap().as_usize().unwrap() > 0);
    server.shutdown();
}

/// Streaming-session lifecycle over the wire, pinned against the STATS
/// `sessions` block: open/chunk/close each move exactly one counter, a
/// duplicate OPEN and an unknown-sid CHUNK are BadRequest *without*
/// disturbing the resident session, and a sequence gap evicts — after
/// which the sid is gone (further chunks are unknown-session errors).
#[test]
fn session_lifecycle_counters_and_sequencing() {
    let server = start_server(ServeConfig {
        session_lanes: 2,
        ..ServeConfig::default()
    });
    let mut c = Client::connect(server.local_addr()).unwrap();

    // v3 snapshot accepted by the validating poller; sessions block idle.
    let stats = c.stats_versioned().unwrap();
    let s = stats.get("sessions").unwrap();
    assert_eq!(s.get("capacity").unwrap().as_usize().unwrap(), 2);
    assert_eq!(s.get("opened").unwrap().as_usize().unwrap(), 0);
    assert_eq!(s.get("resident").unwrap().as_usize().unwrap(), 0);

    c.open_session(7).unwrap();
    // Unknown sid: rejected, and session 7 is untouched.
    let err = c.session_chunk(99, 0, &train_for(0, 0)).unwrap_err().to_string();
    assert!(err.contains("[bad_request]"), "{err}");
    let o0 = c.session_chunk(7, 0, &train_for(0, 0)).unwrap();
    assert_eq!((o0.sid, o0.seq), (7, 0));
    assert!((o0.predicted as usize) < 8);
    let o1 = c.session_chunk(7, 1, &train_for(0, 1)).unwrap();
    assert_eq!((o1.sid, o1.seq), (7, 1));
    // Duplicate OPEN: BadRequest, but the resident session keeps running.
    let err = c.open_session(7).unwrap_err().to_string();
    assert!(err.contains("[bad_request]"), "{err}");
    c.session_chunk(7, 2, &train_for(0, 2)).unwrap();
    c.close_session(7).unwrap();

    // Sequence gap on a fresh session: evicted, then unknown.
    c.open_session(8).unwrap();
    let err = c.session_chunk(8, 5, &train_for(0, 3)).unwrap_err().to_string();
    assert!(err.contains("[bad_request]") && err.contains("expected 0"), "{err}");
    let err = c.session_chunk(8, 0, &train_for(0, 4)).unwrap_err().to_string();
    assert!(err.contains("[bad_request]"), "evicted sid must be unknown: {err}");

    let stats = c.stats_versioned().unwrap();
    let s = stats.get("sessions").unwrap();
    assert_eq!(s.get("opened").unwrap().as_usize().unwrap(), 2);
    assert_eq!(s.get("closed").unwrap().as_usize().unwrap(), 1);
    assert_eq!(s.get("evicted").unwrap().as_usize().unwrap(), 1);
    assert_eq!(s.get("rejected").unwrap().as_usize().unwrap(), 0);
    assert_eq!(s.get("chunks").unwrap().as_usize().unwrap(), 3);
    assert_eq!(s.get("resident").unwrap().as_usize().unwrap(), 0);
    server.shutdown();
}

/// Session admission control: at `session_lanes` capacity a further OPEN
/// is ERROR Overload (counted in `sessions.rejected`), and closing a
/// session frees its lane for the next occupant.
#[test]
fn session_open_overloads_at_lane_capacity() {
    let server = start_server(ServeConfig {
        session_lanes: 1,
        ..ServeConfig::default()
    });
    let mut c = Client::connect(server.local_addr()).unwrap();
    c.open_session(1).unwrap();
    let err = c.open_session(2).unwrap_err().to_string();
    assert!(err.contains("[overload]"), "{err}");
    c.close_session(1).unwrap();
    c.open_session(2).unwrap();
    c.close_session(2).unwrap();
    let stats = c.stats().unwrap();
    let s = stats.get("sessions").unwrap();
    assert_eq!(s.get("opened").unwrap().as_usize().unwrap(), 2);
    assert_eq!(s.get("rejected").unwrap().as_usize().unwrap(), 1);
    server.shutdown();
}

/// `Client::stats_versioned` fails loudly on a version mismatch — pinned
/// against a minimal fake server answering STATS with a stale snapshot.
#[test]
fn stats_versioned_rejects_stale_server() {
    use menage::serve::protocol::{encode_stats_reply, FrameReader};
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let fake = std::thread::spawn(move || {
        let (mut s, _) = listener.accept().unwrap();
        let mut fr = FrameReader::new(1 << 20);
        loop {
            match fr.read_frame(&mut s) {
                Ok(Some(f)) if FrameKind::from_u8(f.kind) == Some(FrameKind::Stats) => {
                    let stale = Json::obj(vec![("stats_version", 2usize.into())]);
                    write_frame(&mut s, FrameKind::StatsReply, &encode_stats_reply(&stale))
                        .unwrap();
                }
                Ok(Some(_)) => {}
                Ok(None) | Err(_) => break,
            }
        }
    });
    let mut c = Client::connect(addr).unwrap();
    let err = c.stats_versioned().unwrap_err().to_string();
    assert!(err.contains("stats_version 2"), "{err}");
    assert!(err.contains(&format!("expects {STATS_VERSION}")), "{err}");
    drop(c);
    fake.join().unwrap();
}

/// SHUTDOWN frame: refused by default, honored (and visible to the
/// embedding loop) when enabled — the `loadgen --shutdown-server` path.
#[test]
fn remote_shutdown_gated_by_config() {
    let server = start_server(ServeConfig::default());
    let mut c = Client::connect(server.local_addr()).unwrap();
    let err = c.request_shutdown().unwrap_err().to_string();
    assert!(err.contains("unsupported"), "{err}");
    assert!(!server.remote_shutdown_requested());
    server.shutdown();

    let server = start_server(ServeConfig {
        allow_remote_shutdown: true,
        ..ServeConfig::default()
    });
    let mut c = Client::connect(server.local_addr()).unwrap();
    c.request_shutdown().unwrap();
    assert!(server.remote_shutdown_requested());
    server.shutdown();
}
