//! Failure-injection tests: corrupted artifacts, undersized hardware,
//! hostile inputs — the system must fail *loudly and precisely*, never
//! silently compute garbage.

use menage::accel::Menage;
use menage::analog::AnalogParams;
use menage::config::{AcceleratorConfig, ModelConfig};
use menage::coordinator::Coordinator;
use menage::mapping::{distill, map_layer, map_network, Strategy};
use menage::snn::{LifParams, QuantLayer, QuantNetwork, SpikeTrain};
use menage::util::rng::Rng;
use menage::util::tensorfile::{Tensor, TensorFile};

fn net(sizes: &[usize]) -> QuantNetwork {
    let cfg = ModelConfig {
        name: "fi".into(),
        layer_sizes: sizes.to_vec(),
        timesteps: 4,
        beta: 0.9,
        v_threshold: 1.0,
        v_reset: 0.0,
    };
    let mut rng = Rng::new(5);
    QuantNetwork::random(&cfg, 0.5, &mut rng)
}

#[test]
fn truncated_weight_file_rejected() {
    let tf = net(&[20, 10, 4]).to_tensorfile();
    let bytes = tf.to_bytes();
    for cut in [1usize, 8, 40, bytes.len() / 2, bytes.len() - 1] {
        let res = TensorFile::from_bytes(&bytes[..cut]);
        assert!(res.is_err(), "truncation at {cut} accepted");
    }
}

#[test]
fn weight_file_with_missing_tensors_rejected() {
    // Drop scale0: loading must fail with a message naming the layer.
    let mut tf = net(&[20, 10]).to_tensorfile();
    tf.tensors.remove("scale0");
    let err = QuantNetwork::from_tensorfile("x", &tf).unwrap_err().to_string();
    assert!(err.contains("scale") || err.contains("layer 0"), "{err}");
}

#[test]
fn weight_file_with_wrong_lif_arity_rejected() {
    let mut tf = net(&[20, 10]).to_tensorfile();
    tf.insert("meta_lif", Tensor::F32 { dims: vec![2], data: vec![0.9, 1.0] });
    assert!(QuantNetwork::from_tensorfile("x", &tf).is_err());
}

#[test]
fn mismatched_layer_dims_rejected() {
    // Hand-build a network whose dims don't chain.
    let l0 = QuantLayer::new(8, 4, vec![1; 32], 0.1, LifParams::default()).unwrap();
    let l1 = QuantLayer::new(5, 2, vec![1; 10], 0.1, LifParams::default()).unwrap();
    let bad = QuantNetwork { name: "bad".into(), layers: vec![l0, l1], timesteps: 3 };
    assert!(bad.validate().is_err());
    // And the chip builder surfaces it.
    let cfg = AcceleratorConfig::accel1();
    assert!(Menage::build(&bad, &cfg, Strategy::Greedy, &AnalogParams::ideal(), 1).is_err());
}

#[test]
fn undersized_weight_sram_rejected_at_distill() {
    let n = net(&[64, 48]);
    let mut cfg = AcceleratorConfig::accel1();
    cfg.num_cores = 1;
    cfg.weight_mem_bytes = 16; // absurd
    let mp = map_layer(&n.layers[0], &cfg, Strategy::Greedy).unwrap();
    let err = distill(&n.layers[0], &mp, &cfg).unwrap_err().to_string();
    assert!(err.contains("weight"), "{err}");
}

#[test]
fn too_few_cores_rejected_at_map() {
    let n = net(&[16, 12, 8, 4, 2, 2]); // 5 layers
    let mut cfg = AcceleratorConfig::accel1(); // 4 cores
    cfg.a_neurons_per_core = 4;
    cfg.virtual_per_a_neuron = 4;
    let err = map_network(&n, &cfg, Strategy::Greedy).unwrap_err().to_string();
    assert!(err.contains("MX-NEURACORE"), "{err}");
}

#[test]
fn wrong_input_dims_rejected_at_run() {
    let n = net(&[20, 10]);
    let mut cfg = AcceleratorConfig::accel1();
    cfg.num_cores = 1;
    cfg.a_neurons_per_core = 4;
    cfg.virtual_per_a_neuron = 4;
    let mut chip =
        Menage::build(&n, &cfg, Strategy::Greedy, &AnalogParams::ideal(), 1).unwrap();
    assert!(chip.run(&SpikeTrain::new(21, 4)).is_err()); // wrong width
    // Wrong timestep count is fine (the chip follows the input), but
    // out-of-range spike indices inside a malformed train must not panic
    // the dispatch (they address no MEM_E2A entry).
    let mut st = SpikeTrain::new(20, 4);
    st.spikes[0] = vec![19]; // valid edge
    chip.run(&st).unwrap();
}

#[test]
fn event_storm_saturates_gracefully() {
    // Every input neuron firing every step with a tiny MEM_E: events are
    // dropped and counted; the run still completes and stays deterministic.
    let n = net(&[100, 10]);
    let mut cfg = AcceleratorConfig::accel1();
    cfg.num_cores = 1;
    cfg.a_neurons_per_core = 2;
    cfg.virtual_per_a_neuron = 8;
    cfg.event_mem_depth = 16;
    let mut chip =
        Menage::build(&n, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 1).unwrap();
    let mut st = SpikeTrain::new(100, 4);
    for step in st.spikes.iter_mut() {
        step.extend(0..100u32);
    }
    let a = chip.run(&st).unwrap();
    let drops: u64 = chip.cores.iter().map(|c| c.stats.dropped_events).sum();
    assert_eq!(drops, 4 * (100 - 16));
    let b = chip.run(&st).unwrap();
    assert_eq!(a.output().spikes, b.output().spikes, "drops must be deterministic");
}

#[test]
fn zero_fanout_limit_reports_unassigned() {
    let layer = QuantLayer::new(2, 4, vec![1; 8], 0.1, LifParams::default()).unwrap();
    let mut cfg = AcceleratorConfig::accel1();
    cfg.fanout_limit = 0;
    let mp = map_layer(&layer, &cfg, Strategy::IlpFlow).unwrap();
    assert_eq!(mp.assigned_count(), 0);
    assert_eq!(mp.unassigned.len(), 4, "all active neurons must be reported");
}

/// Build a small coordinator service plus a generator of valid requests
/// for the salvage-lifecycle tests below.
fn salvage_service() -> (Coordinator, impl Fn(u64) -> SpikeTrain) {
    let n = net(&[20, 10]);
    let mut cfg = AcceleratorConfig::accel1();
    cfg.num_cores = 1;
    cfg.a_neurons_per_core = 4;
    cfg.virtual_per_a_neuron = 4;
    let chip = Menage::build(&n, &cfg, Strategy::Greedy, &AnalogParams::ideal(), 1).unwrap();
    let coord = Coordinator::with_lanes(&chip, 2, 3);
    let make = |seed: u64| {
        let mut rng = Rng::new(seed);
        SpikeTrain::bernoulli(20, 4, 0.3, &mut rng)
    };
    (coord, make)
}

/// Salvage lifecycle, part 1: after a *successful* drain the salvage
/// buffer is empty — successes travel through the drain's return value,
/// never through the side channel.
#[test]
fn salvage_empty_after_successful_drain() {
    let (mut coord, make) = salvage_service();
    for s in 0..5 {
        coord.submit(make(s), None);
    }
    let res = coord.drain().unwrap();
    assert_eq!(res.len(), 5);
    assert!(
        coord.take_salvaged_responses().is_empty(),
        "successful drain must not populate salvage"
    );
    coord.shutdown();
}

/// Salvage lifecycle, part 2: an induced worker failure (malformed
/// request mid-batch) makes drain fail, and every completed response of
/// that batch is recoverable — exactly once — via salvage.
#[test]
fn salvage_populated_after_induced_worker_failure() {
    let (mut coord, make) = salvage_service();
    for s in 0..3 {
        coord.submit(make(s), None);
    }
    coord.submit(SpikeTrain::new(99, 4), None); // wrong width → worker Err
    for s in 3..6 {
        coord.submit(make(s), None);
    }
    assert!(coord.drain().is_err(), "malformed request must fail the drain");
    let salvaged = coord.take_salvaged_responses();
    assert_eq!(salvaged.len(), 6, "all completed responses must be salvageable");
    assert!(
        salvaged.windows(2).all(|w| w[0].id < w[1].id),
        "salvage must preserve submission order"
    );
    assert!(
        coord.take_salvaged_responses().is_empty(),
        "salvage is take-once, not a cache"
    );
    coord.shutdown();
}

/// Salvage lifecycle, part 3: responses never leak across batches — a
/// failing batch's salvage does not contaminate the next batch's drain,
/// and an un-taken salvage is overwritten (not appended to) by the next
/// failure.
#[test]
fn salvage_never_leaks_across_batches() {
    let (mut coord, make) = salvage_service();
    // Batch 1 fails with 2 successes salvageable — deliberately NOT taken.
    coord.submit(SpikeTrain::new(99, 4), None);
    coord.submit(make(0), None);
    coord.submit(make(1), None);
    assert!(coord.drain().is_err());
    // Batch 2 is clean: its drain returns exactly its own 3 responses,
    // with none of batch 1's salvage mixed in — and the clean drain
    // discards the stale un-taken salvage entirely.
    let first_clean_id = 3;
    for s in 0..3 {
        coord.submit(make(10 + s), None);
    }
    let res = coord.drain().unwrap();
    assert_eq!(res.len(), 3, "stale salvage leaked into a clean drain");
    assert!(res.iter().all(|r| r.id >= first_clean_id), "batch-1 response resurfaced");
    assert!(
        coord.take_salvaged_responses().is_empty(),
        "stale salvage must not survive a successful drain"
    );
    // Batch 3 fails again: fresh salvage, holding only its own batch.
    coord.submit(SpikeTrain::new(99, 4), None);
    coord.submit(make(20), None);
    assert!(coord.drain().is_err());
    let salvaged = coord.take_salvaged_responses();
    assert_eq!(salvaged.len(), 1, "salvage must hold only the latest failing batch");
    assert!(salvaged[0].id > first_clean_id);
    coord.shutdown();
}

#[test]
fn corrupt_toml_config_rejected_with_line_info() {
    let err = AcceleratorConfig::from_toml("[accelerator]\nnum_cores = banana")
        .unwrap_err()
        .to_string();
    assert!(err.contains("line 2") || err.contains("num_cores"), "{err}");
    // Semantic garbage (valid syntax) also rejected.
    assert!(AcceleratorConfig::from_toml("[accelerator]\nnum_cores = 0").is_err());
    assert!(AcceleratorConfig::from_toml("[accelerator]\nweight_bits = 99").is_err());
}

/// Worker panics mid-drain: supervision salvages the dead worker's held
/// batch (resubmit once, then typed error), respawns the worker, and the
/// drain still terminates with exactly-one-response accounting intact.
#[test]
fn drain_with_panicking_workers_answers_every_request() {
    use std::sync::atomic::Ordering;
    const N: u64 = 9;
    let (mut coord, make) = salvage_service();
    coord.inject_worker_panics(3); // every 3rd stolen batch dies
    for s in 0..N {
        coord.submit(make(s), None);
    }
    let answered = match coord.drain() {
        Ok(res) => res.len(),
        // A request whose retry was also lost arrives as a typed error;
        // the successes are salvageable, never silently dropped.
        Err(_) => coord.take_salvaged_responses().len(),
    };
    let recovery = coord.recovery();
    let failed = recovery.requests_failed.load(Ordering::Relaxed) as usize;
    assert_eq!(
        answered + failed,
        N as usize,
        "exactly-one-response broke: {answered} answered + {failed} typed errors"
    );
    assert!(recovery.worker_panics.load(Ordering::Relaxed) > 0, "trigger never fired");
    assert!(recovery.workers_respawned.load(Ordering::Relaxed) > 0);
    assert!(recovery.requests_resubmitted.load(Ordering::Relaxed) > 0);
    // Disarmed, the healed pool serves a clean batch again.
    coord.inject_worker_panics(0);
    for s in 0..3 {
        coord.submit(make(100 + s), None);
    }
    let res = coord.drain().expect("healed coordinator must serve cleanly");
    assert_eq!(res.len(), 3);
    assert!(res.iter().all(|r| r.id >= N), "stale response leaked into clean batch");
    coord.shutdown();
}

/// Coordinator shutdown is bounded even when every worker keeps dying:
/// held requests become typed errors, never a hang, and fewer (possibly
/// zero) chips come back.
#[test]
fn coordinator_shutdown_bounded_with_dying_workers() {
    use std::sync::atomic::Ordering;
    use std::time::Instant;
    let (mut coord, make) = salvage_service();
    coord.inject_worker_panics(1); // every stolen batch dies
    for s in 0..4 {
        coord.submit(make(s), None);
    }
    // Every request fails typed (first loss resubmits, the retry dies too).
    assert!(coord.drain().is_err());
    assert!(coord.take_salvaged_responses().is_empty());
    let recovery = coord.recovery();
    assert_eq!(recovery.requests_failed.load(Ordering::Relaxed), 4);
    let t0 = Instant::now();
    let _chips = coord.shutdown(); // must return, dead workers and all
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(30),
        "shutdown not bounded with dying workers"
    );
}

/// Server shutdown stays bounded when its worker pool keeps panicking:
/// every accepted request is answered with a typed Internal error first,
/// and `Server::shutdown` returns instead of wedging on dead threads.
#[test]
fn server_shutdown_bounded_after_worker_panics() {
    use menage::fault::SystemChaos;
    use menage::serve::protocol::ErrorCode;
    use menage::serve::{Client, Reply, ServeConfig, Server};
    use std::time::{Duration, Instant};

    let n = net(&[20, 10]);
    let mut cfg = AcceleratorConfig::accel1();
    cfg.num_cores = 1;
    cfg.a_neurons_per_core = 4;
    cfg.virtual_per_a_neuron = 4;
    let chip = Menage::build(&n, &cfg, Strategy::Greedy, &AnalogParams::ideal(), 1).unwrap();
    let server = Server::start(
        &chip,
        "127.0.0.1:0",
        ServeConfig {
            workers: 1,
            lanes_per_worker: 2,
            chaos: SystemChaos { worker_panic_every: 1, ..SystemChaos::default() },
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let mut c = Client::connect(server.local_addr()).unwrap();
    let mut rng = Rng::new(11);
    let mut ids = Vec::new();
    for _ in 0..3 {
        let train = SpikeTrain::bernoulli(20, 3, 0.3, &mut rng);
        ids.push(c.send_infer(&train, 0, None).unwrap());
    }
    for _ in 0..ids.len() {
        match c
            .recv_reply_timeout(Duration::from_secs(30))
            .expect("connection died")
            .expect("request unanswered: server wedged on panicking workers")
        {
            Reply::Error(e) => {
                assert!(ids.contains(&e.id), "error for unknown id {}", e.id);
                ids.retain(|&x| x != e.id);
                assert_eq!(e.code, ErrorCode::Internal, "{}", e.message);
            }
            other => panic!("every-batch panics cannot produce {other:?}"),
        }
    }
    assert!(ids.is_empty());
    let t0 = Instant::now();
    let _chips = server.shutdown();
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "Server::shutdown not bounded with panicked workers"
    );
}

#[test]
fn nonideal_analog_never_panics_on_extremes() {
    // Saturating packets, negative storms, denormal scales: the non-ideal
    // path must clamp, not explode.
    let l = QuantLayer::new(
        4,
        4,
        vec![127, -128, 127, -128, 127, -128, 127, -128, 1, -1, 1, -1, 0, 0, 0, 1],
        1e-30, // pathological scale
        LifParams { beta: 1.0, v_threshold: 1.0, v_reset: 0.0 },
    )
    .unwrap();
    let netw = QuantNetwork { name: "ex".into(), layers: vec![l], timesteps: 6 };
    let mut cfg = AcceleratorConfig::accel1();
    cfg.num_cores = 1;
    cfg.a_neurons_per_core = 2;
    cfg.virtual_per_a_neuron = 2;
    let mut chip =
        Menage::build(&netw, &cfg, Strategy::Greedy, &AnalogParams::paper(), 3).unwrap();
    let mut st = SpikeTrain::new(4, 6);
    for step in st.spikes.iter_mut() {
        step.extend(0..4u32);
    }
    let out = chip.run(&st).unwrap();
    assert!(out.output().total_spikes() <= 4 * 6);
}
