//! Differential harness pinning multi-chip sharded execution to the
//! monolithic engine.
//!
//! The contract (see `menage::shard` module docs): for any model that fits
//! one chip, `ShardedMenage` over any shard count must produce
//! **bit-identical** layer spike trains, modeled cycles, and per-core
//! `CoreStats` to `Menage::run` — in ideal *and* non-ideal analog mode
//! (cores are built from the same per-layer mappings and the same RNG
//! stream, and visited in the same global order per step). The suite
//! drives randomized models × shard counts × inputs through that
//! assertion, sequentially and lane-batched, plus the edge cases the
//! acceptance criteria name: 1 shard, shards > layers, and the
//! capacity-constrained partitions. Models too deep for one chip — where
//! no monolithic oracle exists — are pinned to the reference model
//! instead.

use menage::accel::Menage;
use menage::analog::AnalogParams;
use menage::config::{AcceleratorConfig, ModelConfig};
use menage::coordinator::Coordinator;
use menage::mapping::{partition_layers, ShardLimits, Strategy};
use menage::shard::ShardedMenage;
use menage::snn::{reference_forward, QuantNetwork, SpikeTrain};
use menage::util::prop;
use menage::util::rng::Rng;

fn model(sizes: &[usize], t: usize) -> ModelConfig {
    ModelConfig {
        name: "shard-diff".into(),
        layer_sizes: sizes.to_vec(),
        timesteps: t,
        beta: 0.9,
        v_threshold: 1.0,
        v_reset: 0.0,
    }
}

fn accel(cores: usize, m: usize, n: usize) -> AcceleratorConfig {
    let mut c = AcceleratorConfig::accel1();
    c.num_cores = cores;
    c.a_neurons_per_core = m;
    c.a_syns_per_core = m;
    c.virtual_per_a_neuron = n;
    c
}

/// The core assertion: a sharded pipeline over `num_shards` chips is
/// bit-identical to the monolithic chip — every layer train, the modeled
/// cycles, and every core's folded `CoreStats`, per input AND accumulated
/// across the whole input sequence. Returns an error string for the
/// property driver.
fn assert_sharded_equals_monolithic(
    net: &QuantNetwork,
    cfg: &AcceleratorConfig,
    analog: &AnalogParams,
    num_shards: usize,
    inputs: &[SpikeTrain],
    tag: &str,
) -> Result<(), String> {
    let mono0 = Menage::build(net, cfg, Strategy::IlpFlow, analog, 7)
        .map_err(|e| format!("{tag}: mono build failed: {e}"))?;
    let sharded0 = ShardedMenage::build(net, cfg, Strategy::IlpFlow, analog, 7, num_shards)
        .map_err(|e| format!("{tag}: sharded build failed: {e}"))?;
    if num_shards <= net.layers.len() && sharded0.num_shards() != num_shards {
        return Err(format!(
            "{tag}: asked for {num_shards} shards, got {}",
            sharded0.num_shards()
        ));
    }

    // Accumulating instances: the folded-stats comparison at the end.
    let mut mono_acc = mono0.clone();
    let mut sharded_acc = sharded0.clone();
    for (k, input) in inputs.iter().enumerate() {
        // Fresh instances: per-input equality (trains + cycles + stats).
        let mut mono = mono0.clone();
        let mut sharded = sharded0.clone();
        let mout = mono.run(input).map_err(|e| format!("{tag}: mono run: {e}"))?;
        let sout = sharded.run(input).map_err(|e| format!("{tag}: sharded run: {e}"))?;
        if mout.cycles != sout.cycles {
            return Err(format!(
                "{tag}: input {k}: sharded cycles {} != monolithic {}",
                sout.cycles, mout.cycles
            ));
        }
        if mout.trains.len() != sout.trains.len() {
            return Err(format!("{tag}: input {k}: layer count diverges"));
        }
        for (l, (a, b)) in sout.trains.iter().zip(&mout.trains).enumerate() {
            if a.spikes != b.spikes {
                return Err(format!("{tag}: input {k}: layer {l} spike trains diverge"));
            }
        }
        let scores: Vec<_> = sharded.shards.iter().flat_map(|s| &s.cores).collect();
        for (l, (sc, mc)) in scores.iter().zip(&mono.cores).enumerate() {
            if sc.stats != mc.stats {
                return Err(format!(
                    "{tag}: input {k}: core {l} CoreStats diverge:\n sharded: {:?}\n mono:    {:?}",
                    sc.stats, mc.stats
                ));
            }
        }
        mono_acc.run(input).map_err(|e| e.to_string())?;
        sharded_acc.run(input).map_err(|e| e.to_string())?;
    }
    // Folded across the whole sequence (cumulative counters, the energy
    // model's input) — and through into_monolithic, the stats carrier the
    // coordinator hands back.
    if (sharded_acc.analog_energy() - mono_acc.analog_energy()).abs()
        > 1e-9 * mono_acc.analog_energy().abs().max(1e-30)
    {
        return Err(format!("{tag}: accumulated analog energy diverges"));
    }
    let reassembled = sharded_acc.into_monolithic();
    if reassembled.inputs_processed != inputs.len() as u64 {
        return Err(format!(
            "{tag}: reassembled inputs_processed {} != {}",
            reassembled.inputs_processed,
            inputs.len()
        ));
    }
    for (l, (sc, mc)) in reassembled.cores.iter().zip(&mono_acc.cores).enumerate() {
        if sc.stats != mc.stats {
            return Err(format!("{tag}: folded core {l} CoreStats diverge after {} inputs", inputs.len()));
        }
    }
    Ok(())
}

fn rand_inputs(rng: &mut Rng, dim: usize, t_max: usize, count: usize) -> Vec<SpikeTrain> {
    (0..count)
        .map(|_| {
            let t = rng.below(t_max + 1);
            let rate = 0.05 + rng.f64() * 0.4;
            SpikeTrain::bernoulli(dim, t, rate, rng)
        })
        .collect()
}

/// Randomized models × shard counts × inputs, ideal analog mode.
#[test]
fn prop_sharded_bit_identical_ideal() {
    prop::check_n("sharded-vs-monolithic-ideal", 10, |rng| {
        let l0 = 8 + rng.below(20);
        let l1 = 4 + rng.below(12);
        let l2 = 3 + rng.below(8);
        let l3 = 2 + rng.below(6);
        let mcfg = model(&[l0, l1, l2, l3], 3 + rng.below(6));
        let net = QuantNetwork::random(&mcfg, 0.3 + rng.f64() * 0.5, rng);
        let cfg = accel(3, 2 + rng.below(4), 2 + rng.below(4));
        let shards = 1 + rng.below(3); // 1..=3 over 3 layers
        let count = 1 + rng.below(3);
        let inputs = rand_inputs(rng, l0, 8, count);
        assert_sharded_equals_monolithic(
            &net,
            &cfg,
            &AnalogParams::ideal(),
            shards,
            &inputs,
            &format!("ideal k={shards}"),
        )
    });
}

/// Same property in non-ideal analog mode: the C2C mismatch draws come
/// from one RNG stream consumed in monolithic core order, so even the
/// per-engine mismatch state is bit-identical.
#[test]
fn prop_sharded_bit_identical_nonideal() {
    prop::check_n("sharded-vs-monolithic-nonideal", 6, |rng| {
        let l0 = 8 + rng.below(16);
        let l1 = 4 + rng.below(10);
        let l2 = 2 + rng.below(6);
        let mcfg = model(&[l0, l1, l2], 3 + rng.below(5));
        let net = QuantNetwork::random(&mcfg, 0.3 + rng.f64() * 0.4, rng);
        let cfg = accel(2, 2 + rng.below(3), 2 + rng.below(3));
        let shards = 1 + rng.below(2); // 1..=2 over 2 layers
        let count = 1 + rng.below(3);
        let inputs = rand_inputs(rng, l0, 6, count);
        assert_sharded_equals_monolithic(
            &net,
            &cfg,
            &AnalogParams::paper(),
            shards,
            &inputs,
            &format!("nonideal k={shards}"),
        )
    });
}

/// The acceptance-criteria edge cases: 1 shard (the degenerate pipeline)
/// and shards > layers (clamped to one layer per chip) both stay
/// bit-identical, in both analog modes; an empty (0-step) train is a
/// valid input.
#[test]
fn shard_count_edge_cases() {
    let mcfg = model(&[20, 12, 8, 4], 6);
    let mut rng = Rng::new(11);
    let net = QuantNetwork::random(&mcfg, 0.5, &mut rng);
    let cfg = accel(3, 4, 4);
    let mut inputs = rand_inputs(&mut rng, 20, 8, 2);
    inputs.push(SpikeTrain::new(20, 0)); // empty train
    inputs.push(SpikeTrain::new(20, 4)); // quiescent train
    for analog in [AnalogParams::ideal(), AnalogParams::paper()] {
        for shards in [1usize, 2, 3, 99] {
            assert_sharded_equals_monolithic(
                &net,
                &cfg,
                &analog,
                shards,
                &inputs,
                &format!("edge k={shards}"),
            )
            .unwrap();
        }
    }
    // shards > layers really did clamp to one layer per chip.
    let sharded =
        ShardedMenage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 7, 99)
            .unwrap();
    assert_eq!(sharded.num_shards(), 3);
    for chip in &sharded.shards {
        assert_eq!(chip.cores.len(), 1);
    }
}

/// Lane-batched sharded execution: per-lane outputs, cycles, and per-core
/// per-lane stats bit-identical to sequential monolithic runs on fresh
/// chips — the same contract `tests/lanes_differential.rs` pins for the
/// monolithic engine, lifted across chips (both modes).
#[test]
fn sharded_lanes_match_monolithic_sequential() {
    let mcfg = model(&[24, 14, 8, 4], 6);
    let mut rng = Rng::new(21);
    let net = QuantNetwork::random(&mcfg, 0.5, &mut rng);
    let cfg = accel(3, 4, 3);
    for analog in [AnalogParams::ideal(), AnalogParams::paper()] {
        let mono0 = Menage::build(&net, &cfg, Strategy::IlpFlow, &analog, 7).unwrap();
        let mut sharded =
            ShardedMenage::build(&net, &cfg, Strategy::IlpFlow, &analog, 7, 2).unwrap();
        // Heterogeneous lengths, including an empty lane.
        let mut inputs = rand_inputs(&mut rng, 24, 9, 4);
        inputs.push(SpikeTrain::new(24, 0));
        let louts = sharded.run_lanes(&inputs).unwrap();
        assert_eq!(louts.len(), inputs.len());
        for (i, input) in inputs.iter().enumerate() {
            let mut seq = mono0.clone();
            let sout = seq.run(input).unwrap();
            assert_eq!(louts[i].cycles, sout.cycles, "lane {i}: cycles");
            for (l, (a, b)) in louts[i].trains.iter().zip(&sout.trains).enumerate() {
                assert_eq!(a.spikes, b.spikes, "lane {i} layer {l}");
            }
            let cores: Vec<_> = sharded.shards.iter().flat_map(|s| &s.cores).collect();
            for (l, (sc, mc)) in cores.iter().zip(&seq.cores).enumerate() {
                assert_eq!(sc.lane_stats(i), &mc.stats, "lane {i} core {l}: stats");
            }
        }
        assert_eq!(sharded.inputs_processed, inputs.len() as u64);
    }
}

/// The coordinator's sharded backend: predictions, cycles, and output
/// trains bit-identical to the monolithic coordinator under lane packing,
/// with the shutdown chips carrying the served work.
#[test]
fn sharded_coordinator_matches_monolithic() {
    let mcfg = model(&[30, 16, 8], 6);
    let mut rng = Rng::new(31);
    let net = QuantNetwork::random(&mcfg, 0.5, &mut rng);
    let cfg = accel(2, 4, 4);
    let mono = Menage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 7).unwrap();
    let sharded =
        ShardedMenage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 7, 2)
            .unwrap();
    let ins: Vec<(SpikeTrain, Option<usize>)> = (0..20)
        .map(|s| {
            let mut r = Rng::new(500 + s as u64);
            (SpikeTrain::bernoulli(30, 6, 0.25, &mut r), Some(s % 8))
        })
        .collect();

    let mut plain = Coordinator::new(&mono, 1);
    let baseline = plain.run_batch(ins.clone()).unwrap();
    plain.shutdown();

    let mut coord = Coordinator::sharded_with_lanes_wait(
        &sharded,
        2,
        4,
        std::time::Duration::from_micros(200),
    );
    let res = coord.run_batch(ins).unwrap();
    assert_eq!(res.len(), baseline.len());
    for (r, b) in res.iter().zip(&baseline) {
        assert_eq!(r.id, b.id);
        assert_eq!(r.predicted, b.predicted, "request {}", r.id);
        assert_eq!(r.cycles, b.cycles, "request {}", r.id);
        assert_eq!(r.output, b.output, "request {}", r.id);
    }
    // Occupancy gauges live on the sharded path too.
    assert!(coord.metrics.mean_lane_occupancy() >= 1.0);
    assert!(coord.metrics.max_lane_occupancy.load(std::sync::atomic::Ordering::Relaxed) <= 4);
    let chips = coord.shutdown();
    assert_eq!(chips.len(), 2);
    // Reassembled monolithic-shaped carriers: full layer chain each.
    for chip in &chips {
        assert_eq!(chip.cores.len(), 2);
    }
    let total: u64 = chips.iter().map(|c| c.inputs_processed).sum();
    assert_eq!(total, 20);
    let macs: u64 = chips.iter().map(|c| c.total_macs()).sum();
    assert!(macs > 0, "sharded lane work invisible after shutdown fold");
}

/// Capacity scaling: a model deeper than one chip runs only sharded —
/// pinned against the reference model, and the partitioner's plan
/// respects the per-chip core limit (validated plus spot-checked here).
#[test]
fn deep_model_runs_sharded_and_matches_reference() {
    let mcfg = model(&[16, 12, 10, 8, 6, 4, 4], 5); // 6 layers
    let mut rng = Rng::new(41);
    let net = QuantNetwork::random(&mcfg, 0.4, &mut rng);
    let cfg = accel(2, 4, 4); // 2 cores/chip → needs ≥3 shards
    assert!(Menage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 7).is_err());
    let plan = partition_layers(&net, 3, &ShardLimits::from_accel(&cfg)).unwrap();
    for r in plan.ranges() {
        assert!(r.len() <= 2, "plan shard wider than the chip: {r:?}");
    }
    let mut sharded =
        ShardedMenage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 7, 3)
            .unwrap();
    for seed in 0..3 {
        let st = SpikeTrain::bernoulli(16, 5, 0.3, &mut Rng::new(70 + seed));
        let golden = reference_forward(&net, &st).unwrap();
        let out = sharded.run(&st).unwrap();
        assert!(out.matches_reference(&golden), "seed {seed}");
        // Lane path agrees with the sequential sharded path too.
        let louts = sharded.run_lanes(std::slice::from_ref(&st)).unwrap();
        assert_eq!(louts[0].trains.last().unwrap().spikes, out.output().spikes);
    }
}
