//! Distributed bit-identity suite: the first execution path where
//! sharded-vs-monolithic identity must survive a real network.
//!
//! Same model, same seed, same fault plan: an in-process
//! [`ShardedMenage`] vs. 2–3 loopback `shard-host` servers driven by
//! [`RemoteShardPipeline`] must agree on classifier trains, modeled
//! cycles, per-cut `boundary_events`, folded per-core `CoreStats`, and
//! fault counters — in ideal AND non-ideal analog mode, with ≥ 2
//! timesteps in flight per link (the pipeline actually pipelines).
//! Fault-plan identity holds because realization derives only from
//! (seed, core index), and cores keep their monolithic index across the
//! process boundary. Failure semantics are pinned too: a killed host
//! surfaces as a typed error naming the shard within the io deadline,
//! never a hang; a sequence gap earns `BadRequest` and a closed
//! connection.

use std::time::{Duration, Instant};

use menage::analog::AnalogParams;
use menage::config::{AcceleratorConfig, ModelConfig};
use menage::fault::FaultPlan;
use menage::mapping::Strategy;
use menage::serve::protocol::ShardStepFrame;
use menage::serve::{
    Client, ErrorCode, RemoteShardConfig, RemoteShardPipeline, Reply, ShardHostConfig,
    ShardHostServer,
};
use menage::shard::ShardedMenage;
use menage::snn::{QuantNetwork, SpikeTrain};
use menage::util::json::Json;
use menage::util::rng::Rng;

fn model(sizes: &[usize], t: usize) -> ModelConfig {
    ModelConfig {
        name: "dist".into(),
        layer_sizes: sizes.to_vec(),
        timesteps: t,
        beta: 0.9,
        v_threshold: 1.0,
        v_reset: 0.0,
    }
}

fn accel(cores: usize) -> AcceleratorConfig {
    let mut c = AcceleratorConfig::accel1();
    c.num_cores = cores;
    c.a_neurons_per_core = 4;
    c.a_syns_per_core = 4;
    c.virtual_per_a_neuron = 4;
    c
}

/// Build the full sharded pipeline twice from the same (net, seed, fault
/// plan) — one copy runs in-process, the other is sliced across hosts —
/// and start one loopback `ShardHostServer` per shard.
fn spawn_hosts(
    net: &QuantNetwork,
    cfg: &AcceleratorConfig,
    analog: &AnalogParams,
    num_shards: usize,
    faults: &FaultPlan,
) -> (ShardedMenage, Vec<ShardHostServer>, Vec<String>) {
    let mut local = ShardedMenage::build(net, cfg, Strategy::IlpFlow, analog, 7, num_shards)
        .expect("in-process build");
    local.install_faults(faults);
    let mut hosted = ShardedMenage::build(net, cfg, Strategy::IlpFlow, analog, 7, num_shards)
        .expect("hosted build");
    hosted.install_faults(faults);
    let mut hosts = Vec::new();
    let mut addrs = Vec::new();
    for k in 0..hosted.shards.len() {
        let h = ShardHostServer::start(&hosted, k, "127.0.0.1:0", ShardHostConfig::default())
            .expect("start host");
        addrs.push(h.local_addr().to_string());
        hosts.push(h);
    }
    (local, hosts, addrs)
}

fn inputs(dim: usize, t: usize, count: usize, seed: u64) -> Vec<SpikeTrain> {
    let mut rng = Rng::new(seed);
    (0..count).map(|_| SpikeTrain::bernoulli(dim, t, 0.3, &mut rng)).collect()
}

fn num(j: &Json, key: &str) -> u64 {
    j.get(key).unwrap().as_usize().unwrap() as u64
}

/// Poll a host until every connection has closed and folded its stats
/// (connection teardown is asynchronous on the host side).
fn wait_quiesced(host: &ShardHostServer) -> Json {
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        let j = host.stats_json();
        if num(j.get("host").unwrap(), "connections_active") == 0 {
            return j;
        }
        assert!(Instant::now() < deadline, "host never quiesced");
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// The tentpole assertion, both analog modes × with/without a fault plan.
#[test]
fn distributed_matches_in_process_ideal_nonideal_and_faulted() {
    let scenarios: [(&str, AnalogParams, FaultPlan); 3] = [
        ("ideal", AnalogParams::ideal(), FaultPlan::default()),
        ("nonideal", AnalogParams::paper(), FaultPlan::default()),
        (
            "ideal+faults",
            AnalogParams::ideal(),
            FaultPlan::parse("seed=9,stuck=0.2,dead=0.2,flip=0.01").unwrap(),
        ),
    ];
    for (tag, analog, faults) in scenarios {
        let mcfg = model(&[20, 14, 10, 8, 6, 4], 6);
        let mut rng = Rng::new(3);
        let net = QuantNetwork::random(&mcfg, 0.5, &mut rng);
        let cfg = accel(2); // 6 layers / 2 cores per chip → 3 hosts
        let (mut local, hosts, addrs) = spawn_hosts(&net, &cfg, &analog, 3, &faults);
        let mut pipeline = RemoteShardPipeline::connect(
            &addrs,
            RemoteShardConfig { window: 2, ..RemoteShardConfig::default() },
        )
        .expect("connect pipeline");
        assert_eq!(pipeline.num_shards(), 3, "{tag}");
        assert_eq!(pipeline.input_dim(), local.input_dim(), "{tag}");

        let samples = inputs(20, 6, 4, 50);
        let mut lout = menage::accel::RunOutput::default();
        let mut rout = menage::accel::RunOutput::default();
        for (i, st) in samples.iter().enumerate() {
            local.run_into(st, &mut lout).unwrap();
            pipeline.run_into(st, &mut rout).unwrap();
            // The driver returns the classifier train only; the in-process
            // run returns every layer. Last layer must match spike for
            // spike, and the reassembled synchronous clock must agree.
            assert_eq!(
                rout.trains[0].spikes,
                lout.trains.last().unwrap().spikes,
                "{tag}: input {i}: classifier trains diverge"
            );
            assert_eq!(rout.cycles, lout.cycles, "{tag}: input {i}: cycles diverge");
        }

        // Per-cut wire traffic: the driver's distinct-source accounting
        // must equal the in-process boundary_events, spike for spike.
        let stats = pipeline.stats();
        assert_eq!(
            stats.boundary_events_vec(),
            local.boundary_events,
            "{tag}: boundary events diverge"
        );
        // The pipeline genuinely overlapped timesteps: ≥ 2 in flight on
        // every link (window 2, T=6 — the send-preferring scheduler fills
        // the window before it ever blocks).
        for (k, depth) in stats.max_in_flight_vec().iter().enumerate() {
            assert!(*depth >= 2, "{tag}: link {k} max in-flight {depth} < 2");
        }

        // Close the driver's connections so every host folds its session
        // stats, then compare folded CoreStats and fault counters.
        drop(pipeline);
        let mut flat_local = local.shards.iter().flat_map(|s| &s.cores);
        let mut fault_totals = (0u64, 0u64, 0u64);
        for (k, host) in hosts.iter().enumerate() {
            let j = wait_quiesced(host);
            let cores = j.get("cores").unwrap().as_arr().unwrap();
            for (c, cj) in cores.iter().enumerate() {
                let lc = flat_local.next().expect("local core");
                let s = &lc.stats;
                let pairs: [(&str, u64); 11] = [
                    ("cycles", s.cycles),
                    ("events_dispatched", s.events_dispatched),
                    ("sn_rows_read", s.sn_rows_read),
                    ("macs", s.macs),
                    ("integrations", s.integrations),
                    ("fire_ops", s.fire_ops),
                    ("spikes_out", s.spikes_out),
                    ("dropped_events", s.dropped_events),
                    ("stuck_row_hits", s.stuck_row_hits),
                    ("dead_slot_hits", s.dead_slot_hits),
                    ("events_bit_flipped", s.events_bit_flipped),
                ];
                for (key, want) in pairs {
                    assert_eq!(
                        num(cj, key),
                        want,
                        "{tag}: host {k} core {c}: {key} diverges"
                    );
                }
            }
            let f = j.get("faults").unwrap();
            fault_totals.0 += num(f, "stuck_row_hits");
            fault_totals.1 += num(f, "dead_slot_hits");
            fault_totals.2 += num(f, "events_bit_flipped");
        }
        assert!(flat_local.next().is_none(), "{tag}: host core count mismatch");
        assert_eq!(fault_totals, local.fault_counters(), "{tag}: fault counters diverge");
        if tag == "ideal+faults" {
            assert!(local.has_faults(), "{tag}: fault plan did not install");
        }
        for h in hosts {
            h.shutdown();
        }
    }
}

/// The wire STATS frame itself (not the in-process accessor) carries the
/// probe-able shard block — what `--remote-shards` validates against —
/// and the host counters move.
#[test]
fn host_stats_frame_describes_the_shard() {
    let mcfg = model(&[16, 10, 6, 4], 5);
    let mut rng = Rng::new(5);
    let net = QuantNetwork::random(&mcfg, 0.5, &mut rng);
    let (_, hosts, addrs) = spawn_hosts(
        &net,
        &accel(2),
        &AnalogParams::ideal(),
        2,
        &FaultPlan::default(),
    );
    let mut total_cores = 0;
    for (k, addr) in addrs.iter().enumerate() {
        let mut c = Client::connect(addr.as_str()).unwrap();
        let j = c.stats().unwrap();
        let shard = j.get("shard").unwrap();
        assert_eq!(num(shard, "index"), k as u64);
        assert_eq!(num(shard, "num_shards"), 2);
        let cores = num(shard, "cores");
        assert!(cores >= 1, "host {k} hosts no cores");
        total_cores += cores;
        let m = j.get("model").unwrap();
        assert_eq!(num(m, "timesteps"), 5);
        if k == 0 {
            assert_eq!(num(m, "input_dim"), 16);
        } else {
            assert_eq!(num(m, "classes"), 4);
        }
        c.ping().unwrap();
    }
    assert_eq!(total_cores, 3, "hosts must cover every layer exactly once");
    for h in hosts {
        h.shutdown();
    }
}

/// A sequence gap is a typed `BadRequest` (with a reconnect hint), and
/// the host closes the stream — its chip state can't be trusted after a
/// divergence.
#[test]
fn sequence_gap_yields_bad_request_and_close() {
    let mcfg = model(&[12, 8, 4], 4);
    let mut rng = Rng::new(8);
    let net = QuantNetwork::random(&mcfg, 0.5, &mut rng);
    let (_, hosts, addrs) = spawn_hosts(
        &net,
        &accel(2),
        &AnalogParams::ideal(),
        2,
        &FaultPlan::default(),
    );
    let mut c = Client::connect(addrs[0].as_str()).unwrap();
    let mut frontier = SpikeTrain::new(12, 1);
    frontier.spikes[0] = vec![0, 3, 7];
    c.send_shard_step(&ShardStepFrame { seq: 5, step: 0, frontier }).unwrap();
    match c.recv_reply().unwrap() {
        Reply::Error(e) => {
            assert_eq!(e.code, ErrorCode::BadRequest);
            assert!(e.message.contains("seq"), "unhelpful message: {}", e.message);
        }
        other => panic!("expected BadRequest, got {other:?}"),
    }
    // Host hung up after the violation: the next read sees a closed stream.
    assert!(c.recv_reply().is_err());
    for h in hosts {
        h.shutdown();
    }
}

/// Kill one shard-host mid-stream: the driver must surface a typed error
/// naming the dead shard within the io deadline — not hang.
#[test]
fn killed_host_is_a_typed_error_within_the_deadline() {
    let mcfg = model(&[16, 10, 6, 4], 5);
    let mut rng = Rng::new(13);
    let net = QuantNetwork::random(&mcfg, 0.5, &mut rng);
    let (_, mut hosts, addrs) = spawn_hosts(
        &net,
        &accel(2),
        &AnalogParams::ideal(),
        2,
        &FaultPlan::default(),
    );
    let io_timeout = Duration::from_millis(500);
    let mut pipeline = RemoteShardPipeline::connect(
        &addrs,
        RemoteShardConfig { window: 2, io_timeout, ..RemoteShardConfig::default() },
    )
    .unwrap();
    let st = SpikeTrain::bernoulli(16, 5, 0.3, &mut Rng::new(60));
    pipeline.run(&st).expect("healthy pipeline runs");

    // Kill the downstream host; its connections are severed.
    hosts.remove(1).shutdown();
    let t0 = Instant::now();
    let err = pipeline.run(&st).expect_err("dead host must fail the run");
    let elapsed = t0.elapsed();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("shard-host 1"),
        "error does not name the dead shard: {msg}"
    );
    // Bounded: one io_timeout of ack-waiting plus scheduling slack —
    // nowhere near a hang (reconnect backoff would add ~10 × 50 ms if the
    // failure surfaces at connect time instead).
    assert!(
        elapsed < Duration::from_secs(10),
        "driver took {elapsed:?} to report a dead host"
    );
    for h in hosts {
        h.shutdown();
    }
}
