//! Differential test harness pinning lane execution to the sequential
//! engine.
//!
//! The contract (see the `engine` module docs): for any batch of inputs,
//! `Menage::run_lanes(&[s0..sB])` must produce, per lane, **bit-identical**
//! layer spike trains, modeled cycles, and per-lane [`CoreStats`] to
//! running that lane's input through `Menage::run` on a fresh chip — in
//! ideal *and* non-ideal analog mode, since both paths are the same
//! unified engine at different strides. The suite drives randomized
//! models/batches plus the edge cases (empty train, all-lanes-quiescent,
//! single lane, B greater than the coordinator's worker count) through
//! that assertion, and pins the non-ideal Kahan sidecar to the
//! fixed-order per-event oracle (`force_legacy_error_oracle`, the
//! pre-refactor arithmetic) within the documented tolerance.

use menage::accel::Menage;
use menage::analog::AnalogParams;
use menage::config::{AcceleratorConfig, ModelConfig};
use menage::coordinator::Coordinator;
use menage::mapping::Strategy;
use menage::neuracore::CoreStats;
use menage::snn::{reference_forward, QuantNetwork, SpikeTrain};
use menage::util::prop;
use menage::util::rng::Rng;

fn model(sizes: &[usize], t: usize) -> ModelConfig {
    ModelConfig {
        name: "lanes".into(),
        layer_sizes: sizes.to_vec(),
        timesteps: t,
        beta: 0.9,
        v_threshold: 1.0,
        v_reset: 0.0,
    }
}

fn accel(cores: usize, m: usize, n: usize) -> AcceleratorConfig {
    let mut c = AcceleratorConfig::accel1();
    c.num_cores = cores;
    c.a_neurons_per_core = m;
    c.a_syns_per_core = m;
    c.virtual_per_a_neuron = n;
    c
}

fn build_chip(net: &QuantNetwork, cfg: &AcceleratorConfig) -> Menage {
    Menage::build(net, cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 7).unwrap()
}

fn build_chip_nonideal(net: &QuantNetwork, cfg: &AcceleratorConfig) -> Menage {
    Menage::build(net, cfg, Strategy::IlpFlow, &AnalogParams::paper(), 7).unwrap()
}

/// The core assertion: lane `i` of `run_lanes` ≡ `run` on a fresh clone.
/// Returns an error string (for the property driver) instead of panicking.
fn assert_lanes_equal_sequential(
    chip: &Menage,
    inputs: &[SpikeTrain],
    tag: &str,
) -> Result<(), String> {
    let mut laned = chip.clone();
    let louts = laned
        .run_lanes(inputs)
        .map_err(|e| format!("{tag}: run_lanes failed: {e}"))?;
    if louts.len() != inputs.len() {
        return Err(format!("{tag}: {} outputs for {} lanes", louts.len(), inputs.len()));
    }
    for (i, input) in inputs.iter().enumerate() {
        let mut seq = chip.clone();
        let sout = seq.run(input).map_err(|e| format!("{tag}: run failed: {e}"))?;
        if louts[i].cycles != sout.cycles {
            return Err(format!(
                "{tag}: lane {i} cycles {} != sequential {}",
                louts[i].cycles, sout.cycles
            ));
        }
        for (l, (a, b)) in louts[i].trains.iter().zip(&sout.trains).enumerate() {
            if a.spikes != b.spikes {
                return Err(format!("{tag}: lane {i} layer {l} spike trains diverge"));
            }
        }
        for (l, (lc, sc)) in laned.cores.iter().zip(&seq.cores).enumerate() {
            if lc.lane_stats(i) != &sc.stats {
                return Err(format!(
                    "{tag}: lane {i} core {l} CoreStats diverge:\n lanes: {:?}\n seq:   {:?}",
                    lc.lane_stats(i),
                    sc.stats
                ));
            }
        }
    }
    // Energy: MAC counts are integers (exact); the joule totals are float
    // sums taken in a different association order across lanes, so compare
    // with a tight relative tolerance rather than bits.
    let le: f64 = laned.analog_energy();
    let se: f64 = se_total(chip, inputs);
    if (le - se).abs() > 1e-9 * se.abs().max(1e-30) {
        return Err(format!("{tag}: lane energy {le} != sequential total {se}"));
    }
    Ok(())
}

/// Total analog energy of running each input on a fresh sequential chip.
fn se_total(chip: &Menage, inputs: &[SpikeTrain]) -> f64 {
    inputs
        .iter()
        .map(|input| {
            let mut c = chip.clone();
            c.run(input).unwrap();
            c.analog_energy()
        })
        .sum()
}

/// Randomized models × batch widths × activity rates.
#[test]
fn prop_lanes_bit_identical_to_sequential() {
    prop::check_n("lanes-vs-sequential", 12, |rng| {
        let l0 = 8 + rng.below(24);
        let l1 = 4 + rng.below(16);
        let l2 = 2 + rng.below(8);
        let mcfg = model(&[l0, l1, l2], 4 + rng.below(8));
        let net = QuantNetwork::random(&mcfg, 0.3 + rng.f64() * 0.5, rng);
        let m = 2 + rng.below(4);
        let n = 1 + rng.below(4);
        let cfg = accel(2, m, n);
        let chip = Menage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 7)
            .map_err(|e| e.to_string())?;
        let b = 1 + rng.below(6);
        let inputs: Vec<SpikeTrain> = (0..b)
            .map(|_| {
                SpikeTrain::bernoulli(l0, mcfg.timesteps, rng.f64() * 0.4, rng)
            })
            .collect();
        assert_lanes_equal_sequential(&chip, &inputs, &format!("b={b} m={m} n={n}"))
    });
}

/// Shared-event regime: every lane carries the *same* sample — the case
/// the one-CSR-walk-per-event optimization targets — must stay exact.
#[test]
fn identical_samples_across_lanes() {
    let mcfg = model(&[30, 16, 8], 8);
    let mut rng = Rng::new(11);
    let net = QuantNetwork::random(&mcfg, 0.5, &mut rng);
    let chip = build_chip(&net, &accel(2, 4, 4));
    let sample = SpikeTrain::bernoulli(30, 8, 0.25, &mut rng);
    let inputs = vec![sample; 6];
    assert_lanes_equal_sequential(&chip, &inputs, "shared-sample").unwrap();
}

/// Edge case: the batch contains an empty (zero-timestep) train.
#[test]
fn empty_train_lane() {
    let mcfg = model(&[20, 10, 4], 6);
    let mut rng = Rng::new(12);
    let net = QuantNetwork::random(&mcfg, 0.5, &mut rng);
    let chip = build_chip(&net, &accel(2, 3, 4));
    let inputs = vec![
        SpikeTrain::bernoulli(20, 6, 0.3, &mut rng),
        SpikeTrain::new(20, 0),
        SpikeTrain::bernoulli(20, 6, 0.1, &mut rng),
    ];
    assert_lanes_equal_sequential(&chip, &inputs, "empty-train").unwrap();
    // The empty lane really did nothing.
    let mut laned = chip.clone();
    let louts = laned.run_lanes(&inputs).unwrap();
    assert_eq!(louts[1].cycles, 0);
    assert_eq!(louts[1].trains.last().unwrap().timesteps(), 0);
    for core in &laned.cores {
        assert_eq!(core.lane_stats(1), &CoreStats::default());
    }
}

/// Edge case: every lane is quiescent (steps run, no events anywhere).
/// Sweep/reassignment cycle charges must still match sequentially.
#[test]
fn all_lanes_quiescent() {
    let mcfg = model(&[20, 18, 4], 5);
    let mut rng = Rng::new(13);
    let net = QuantNetwork::random(&mcfg, 0.5, &mut rng);
    // Capacity 8 < 18 forces multi-round on the middle layer, so the
    // per-round reassignment cost is exercised with zero activity.
    let chip = build_chip(&net, &accel(2, 2, 4));
    let inputs = vec![SpikeTrain::new(20, 5), SpikeTrain::new(20, 5), SpikeTrain::new(20, 5)];
    assert_lanes_equal_sequential(&chip, &inputs, "quiescent").unwrap();
    let mut laned = chip.clone();
    let louts = laned.run_lanes(&inputs).unwrap();
    for o in &louts {
        assert!(o.cycles > 0, "sweep/reassignment cycles must accrue");
        assert_eq!(o.trains.last().unwrap().total_spikes(), 0);
    }
}

/// Edge case: a single lane is exactly the sequential engine.
#[test]
fn single_lane_equals_sequential() {
    let mcfg = model(&[25, 12, 6], 7);
    let mut rng = Rng::new(14);
    let net = QuantNetwork::random(&mcfg, 0.4, &mut rng);
    let chip = build_chip(&net, &accel(2, 4, 4));
    let inputs = vec![SpikeTrain::bernoulli(25, 7, 0.2, &mut rng)];
    assert_lanes_equal_sequential(&chip, &inputs, "single-lane").unwrap();
}

/// Duplicate events inside a step (a caller may inject the same source
/// several times): the coalesced shared walk must match per-event
/// dispatch in both outputs and ×multiplicity accounting.
#[test]
fn duplicate_events_coalesced_vs_forced_per_event() {
    let mcfg = model(&[20, 10, 4], 5);
    let mut rng = Rng::new(15);
    let net = QuantNetwork::random(&mcfg, 0.4, &mut rng);
    let chip = build_chip(&net, &accel(2, 3, 4));
    let mut with_dups = SpikeTrain::bernoulli(20, 5, 0.2, &mut rng);
    with_dups.duplicate_events(); // every event twice, unsorted tail
    let inputs = vec![with_dups.clone(), SpikeTrain::bernoulli(20, 5, 0.3, &mut rng)];

    let mut fast = chip.clone();
    let fast_outs = fast.run_lanes(&inputs).unwrap();
    let mut slow = chip.clone();
    for core in slow.cores.iter_mut() {
        core.force_per_event_dispatch = true;
    }
    let slow_outs = slow.run_lanes(&inputs).unwrap();
    for i in 0..inputs.len() {
        assert_eq!(fast_outs[i].cycles, slow_outs[i].cycles, "lane {i}: cycles");
        for (a, b) in fast_outs[i].trains.iter().zip(&slow_outs[i].trains) {
            assert_eq!(a.spikes, b.spikes, "lane {i}");
        }
        for (lc, sc) in fast.cores.iter().zip(&slow.cores) {
            assert_eq!(lc.lane_stats(i), sc.lane_stats(i), "lane {i}: stats");
        }
    }
}

/// Lane outputs also agree with the bit-exact reference model (transitive
/// with the sequential equivalence, but cheap to assert directly).
#[test]
fn lanes_match_reference_model() {
    let mcfg = model(&[24, 14, 6], 8);
    let mut rng = Rng::new(16);
    let net = QuantNetwork::random(&mcfg, 0.5, &mut rng);
    let chip = build_chip(&net, &accel(2, 4, 4));
    let inputs: Vec<SpikeTrain> =
        (0..4).map(|_| SpikeTrain::bernoulli(24, 8, 0.25, &mut rng)).collect();
    let mut laned = chip.clone();
    let louts = laned.run_lanes(&inputs).unwrap();
    for (i, input) in inputs.iter().enumerate() {
        let golden = reference_forward(&net, input).unwrap();
        assert!(louts[i].matches_reference(&golden), "lane {i} diverges from reference");
    }
}

/// Repeated `run_lanes` calls on one chip are independent (membranes reset
/// between batches, stats accumulate per lane slot) — mirroring the
/// sequential `repeated_runs_are_independent` guarantee.
#[test]
fn repeated_lane_batches_are_independent() {
    let mcfg = model(&[20, 10, 4], 6);
    let mut rng = Rng::new(17);
    let net = QuantNetwork::random(&mcfg, 0.5, &mut rng);
    let mut chip = build_chip(&net, &accel(2, 3, 4));
    let a_in: Vec<SpikeTrain> =
        (0..3).map(|_| SpikeTrain::bernoulli(20, 6, 0.3, &mut rng)).collect();
    let noise: Vec<SpikeTrain> =
        (0..3).map(|_| SpikeTrain::bernoulli(20, 6, 0.5, &mut rng)).collect();
    let a = chip.run_lanes(&a_in).unwrap();
    let _ = chip.run_lanes(&noise).unwrap();
    let c = chip.run_lanes(&a_in).unwrap();
    for i in 0..3 {
        assert_eq!(a[i].cycles, c[i].cycles);
        assert_eq!(
            a[i].trains.last().unwrap().spikes,
            c[i].trains.last().unwrap().spikes
        );
    }
}

/// Non-ideal analog mode batches through the same shared walk: per-lane
/// outputs, cycles, and CoreStats are bit-identical to fresh sequential
/// chips (same mismatch seeds), because the sequential engine is the
/// unified engine's L=1 instantiation — there is no swap fallback left
/// to diverge.
#[test]
fn prop_nonideal_lanes_bit_identical_to_sequential() {
    prop::check_n("nonideal-lanes-vs-sequential", 8, |rng| {
        let l0 = 8 + rng.below(20);
        let l1 = 4 + rng.below(12);
        let l2 = 2 + rng.below(6);
        let mcfg = model(&[l0, l1, l2], 4 + rng.below(6));
        let net = QuantNetwork::random(&mcfg, 0.3 + rng.f64() * 0.5, rng);
        let cfg = accel(2, 2 + rng.below(3), 1 + rng.below(4));
        let chip = Menage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::paper(), 7)
            .map_err(|e| e.to_string())?;
        let b = 1 + rng.below(5);
        let inputs: Vec<SpikeTrain> = (0..b)
            .map(|_| SpikeTrain::bernoulli(l0, mcfg.timesteps, rng.f64() * 0.35, rng))
            .collect();
        assert_lanes_equal_sequential(&chip, &inputs, &format!("nonideal b={b}"))
    });
}

/// Non-ideal + duplicate events: the ×multiplicity Kahan error fold must
/// stay bit-identical between lane-shared and sequential execution (both
/// coalesce identically), and within the documented tolerance of the
/// fixed-order per-event oracle.
#[test]
fn nonideal_duplicates_shared_vs_sequential_and_oracle() {
    let mcfg = model(&[24, 12, 6], 6);
    let mut rng = Rng::new(19);
    let net = QuantNetwork::random(&mcfg, 0.4, &mut rng);
    let chip = build_chip_nonideal(&net, &accel(2, 3, 4));
    let mut a = SpikeTrain::bernoulli(24, 6, 0.25, &mut rng);
    a.duplicate_events();
    let inputs = vec![a, SpikeTrain::bernoulli(24, 6, 0.2, &mut rng)];
    assert_lanes_equal_sequential(&chip, &inputs, "nonideal-dups").unwrap();

    // Fixed-order oracle: per-event dispatch with the pre-refactor
    // uncompensated error arithmetic. For these fixed seeds the spike
    // trains agree exactly; the membrane-level tolerance statement lives
    // in `neuracore`'s oracle test (engine::NONIDEAL_ORACLE_TOLERANCE).
    let mut fast = chip.clone();
    let fast_outs = fast.run_lanes(&inputs).unwrap();
    let mut oracle = chip.clone();
    for core in oracle.cores.iter_mut() {
        core.force_legacy_error_oracle = true;
    }
    let oracle_outs = oracle.run_lanes(&inputs).unwrap();
    for i in 0..inputs.len() {
        assert_eq!(
            fast_outs[i].cycles, oracle_outs[i].cycles,
            "lane {i}: accounting must not depend on the error representation"
        );
        for (l, (x, y)) in fast_outs[i].trains.iter().zip(&oracle_outs[i].trains).enumerate()
        {
            assert_eq!(x.spikes, y.spikes, "lane {i} layer {l}: beyond oracle tolerance");
        }
    }
}

/// B greater than the coordinator's worker count: requests pack into the
/// W×L lane grid, every one completes, and predictions are
/// reference-exact.
#[test]
fn coordinator_b_exceeds_worker_count() {
    let mcfg = model(&[30, 16, 8], 6);
    let mut rng = Rng::new(18);
    let net = QuantNetwork::random(&mcfg, 0.5, &mut rng);
    let chip = build_chip(&net, &accel(2, 4, 4));
    let mut coord = Coordinator::with_lanes(&chip, 2, 6);
    let ins: Vec<(SpikeTrain, Option<usize>)> = (0..30)
        .map(|s| {
            let mut r = Rng::new(900 + s as u64);
            (SpikeTrain::bernoulli(30, 6, 0.25, &mut r), Some(s % 8))
        })
        .collect();
    let golden: Vec<usize> = ins
        .iter()
        .map(|(st, _)| reference_forward(&net, st).unwrap().predicted_class())
        .collect();
    let res = coord.run_batch(ins).unwrap();
    assert_eq!(res.len(), 30);
    for (i, r) in res.iter().enumerate() {
        assert_eq!(r.id, i as u64);
        assert_eq!(r.predicted, golden[i], "request {i}");
    }
    coord.shutdown();
}
