//! Chaos suite: the acceptance gate for fault injection + self-healing.
//!
//! Hardware plane: a seeded [`FaultPlan`] must realize deterministically
//! (same plan → bit-identical faulty runs, monolithic or sharded), and an
//! empty plan must leave execution bit-identical to a fault-free chip.
//!
//! System plane: with chaos injection armed, every accepted request still
//! gets **exactly one** response (a result or a typed error), the server
//! never wedges, and clients recover lost responses / torn connections by
//! retrying. Fault counters must surface in the STATS frame.

use std::time::{Duration, Instant};

use menage::accel::Menage;
use menage::analog::AnalogParams;
use menage::config::{AcceleratorConfig, ModelConfig};
use menage::fault::{FaultPlan, SystemChaos};
use menage::mapping::Strategy;
use menage::serve::protocol::ErrorCode;
use menage::serve::{Client, Reply, ServeConfig, Server};
use menage::shard::ShardedMenage;
use menage::snn::{QuantNetwork, SpikeTrain};
use menage::util::rng::Rng;

fn test_net() -> QuantNetwork {
    let mcfg = ModelConfig {
        name: "chaos-test".into(),
        layer_sizes: vec![30, 16, 8],
        timesteps: 6,
        beta: 0.9,
        v_threshold: 1.0,
        v_reset: 0.0,
    };
    let mut rng = Rng::new(8);
    QuantNetwork::random(&mcfg, 0.5, &mut rng)
}

fn test_cfg() -> AcceleratorConfig {
    let mut cfg = AcceleratorConfig::accel1();
    cfg.num_cores = 2;
    cfg.a_neurons_per_core = 4;
    cfg.a_syns_per_core = 4;
    cfg.virtual_per_a_neuron = 4;
    cfg
}

fn test_chip() -> Menage {
    Menage::build(&test_net(), &test_cfg(), Strategy::IlpFlow, &AnalogParams::ideal(), 2)
        .unwrap()
}

/// An aggressive plan: dense enough that this seed realizes every fault
/// class on a 2-core chip (deterministic — not a statistical bet once the
/// seed is fixed).
fn aggressive_plan() -> FaultPlan {
    FaultPlan {
        seed: 5,
        stuck_row_frac: 0.5,
        dead_slot_frac: 0.4,
        bit_flip_p: 0.1,
        drift_scale: 1.5,
    }
}

fn train_for(i: usize) -> SpikeTrain {
    let mut rng = Rng::new(700 + i as u64);
    SpikeTrain::bernoulli(30, 1 + i % 6, 0.3, &mut rng)
}

/// Same plan, two independently built chips → bit-identical faulty
/// outputs and identical fault counters; the counters actually move.
#[test]
fn fault_plan_realizes_deterministically() {
    let plan = aggressive_plan();
    let mut a = test_chip();
    let mut b = test_chip();
    a.install_faults(&plan);
    b.install_faults(&plan);
    assert!(a.has_faults() && b.has_faults());
    for i in 0..6 {
        let st = train_for(i);
        let oa = a.run(&st).unwrap();
        let ob = b.run(&st).unwrap();
        assert_eq!(oa.trains, ob.trains, "input {i}: faulty runs diverged");
        assert_eq!(oa.cycles, ob.cycles, "input {i}: cycles diverged");
    }
    assert_eq!(a.fault_counters(), b.fault_counters());
    let (stuck, dead, flips) = a.fault_counters();
    assert!(
        stuck + dead + flips > 0,
        "aggressive plan injected nothing (stuck {stuck}, dead {dead}, flips {flips})"
    );
    for (i, core) in a.cores.iter().enumerate() {
        assert!(core.has_faults(), "core {i} missed the plan");
    }
}

/// Installing the empty plan is a no-op: outputs and every `CoreStats`
/// stay bit-identical to a chip that never heard of faults.
#[test]
fn empty_plan_is_bit_identical_to_fault_free() {
    let mut plain = test_chip();
    let mut installed = test_chip();
    installed.install_faults(&FaultPlan::default());
    assert!(!installed.has_faults());
    for i in 0..6 {
        let st = train_for(i);
        let op = plain.run(&st).unwrap();
        let oi = installed.run(&st).unwrap();
        assert_eq!(op.trains, oi.trains, "input {i}");
        assert_eq!(op.cycles, oi.cycles, "input {i}");
    }
    for (a, b) in plain.cores.iter().zip(&installed.cores) {
        assert_eq!(a.stats, b.stats, "CoreStats diverged under the empty plan");
    }
    assert_eq!(installed.fault_counters(), (0, 0, 0));
}

/// Sharding does not move the silicon: the same plan on a monolithic chip
/// and a 2-shard pipeline realizes identical defects and, run over the
/// same inputs in the same order, produces bit-identical faulty outputs
/// and counters (cores keep their global index through the split).
#[test]
fn sharded_faults_bit_identical_to_monolithic() {
    let net = test_net();
    let cfg = test_cfg();
    let plan = aggressive_plan();
    let mut mono =
        Menage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 2).unwrap();
    let mut sharded =
        ShardedMenage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 2, 2)
            .unwrap();
    mono.install_faults(&plan);
    sharded.install_faults(&plan);
    assert!(sharded.has_faults());
    for i in 0..6 {
        let st = train_for(i);
        let om = mono.run(&st).unwrap();
        let os = sharded.run(&st).unwrap();
        assert_eq!(om.trains, os.trains, "input {i}: sharded faulty run diverged");
        assert_eq!(om.cycles, os.cycles, "input {i}: cycles diverged");
    }
    assert_eq!(mono.fault_counters(), sharded.fault_counters());
}

/// With worker panics injected every Nth request, every accepted request
/// still gets exactly one reply — a result, or a typed Internal error for
/// the retry-also-lost case — and the server keeps serving afterwards.
#[test]
fn injected_worker_panics_never_lose_a_request() {
    const N: usize = 24;
    let chip = test_chip();
    let server = Server::start(
        &chip,
        "127.0.0.1:0",
        ServeConfig {
            workers: 2,
            lanes_per_worker: 2,
            chaos: SystemChaos { worker_panic_every: 5, ..SystemChaos::default() },
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let mut c = Client::connect(server.local_addr()).unwrap();
    let mut outstanding = Vec::new();
    let (mut sent, mut answered, mut failed) = (0usize, 0usize, 0usize);
    while answered + failed < N {
        while sent < N && outstanding.len() < 8 {
            let id = c.send_infer(&train_for(sent), 0, None).unwrap();
            outstanding.push(id);
            sent += 1;
        }
        match c
            .recv_reply_timeout(Duration::from_secs(20))
            .expect("connection died under worker panics")
            .expect("no reply within 20s: a request was lost")
        {
            Reply::Infer(r) => {
                assert!(outstanding.contains(&r.id), "duplicate response {}", r.id);
                outstanding.retain(|&x| x != r.id);
                answered += 1;
            }
            Reply::Error(e) => {
                assert!(outstanding.contains(&e.id), "error for unknown id {}", e.id);
                assert_eq!(e.code, ErrorCode::Internal, "{}", e.message);
                outstanding.retain(|&x| x != e.id);
                failed += 1;
            }
            other => panic!("unexpected reply {other:?}"),
        }
    }
    assert!(outstanding.is_empty());
    assert_eq!(answered + failed, N, "exactly-once accounting broke");

    let recovery = server.recovery();
    use std::sync::atomic::Ordering;
    assert!(
        recovery.worker_panics.load(Ordering::Relaxed) > 0,
        "panic trigger never fired"
    );
    assert!(
        recovery.workers_respawned.load(Ordering::Relaxed) > 0,
        "no worker was respawned"
    );
    // The server is still healthy: a fresh request round-trips.
    let r = c.recv_reply_timeout(Duration::from_millis(50)); // drain nothing
    assert!(matches!(r, Ok(None)), "unexpected extra frame: {r:?}");
    let reply = c.infer(&train_for(0)).unwrap();
    assert!((reply.predicted as usize) < 8);
    server.shutdown();
}

/// Responses dropped at the router are recovered by client-side retry:
/// the request is resent under a fresh id and eventually answered.
#[test]
fn dropped_responses_recovered_by_retry() {
    const N: usize = 8;
    let chip = test_chip();
    let server = Server::start(
        &chip,
        "127.0.0.1:0",
        ServeConfig {
            workers: 1,
            lanes_per_worker: 2,
            chaos: SystemChaos { drop_response_every: 4, ..SystemChaos::default() },
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let mut c = Client::connect(server.local_addr()).unwrap();
    let mut retries = 0usize;
    for i in 0..N {
        let train = train_for(i);
        let mut id = c.send_infer(&train, 0, None).unwrap();
        let mut abandoned: Vec<u64> = Vec::new();
        let deadline = Instant::now() + Duration::from_secs(30);
        'req: loop {
            assert!(Instant::now() < deadline, "request {i} never answered");
            match c.recv_reply_timeout(Duration::from_millis(400)).unwrap() {
                Some(Reply::Infer(r)) if r.id == id => break 'req,
                Some(Reply::Infer(r)) => {
                    assert!(abandoned.contains(&r.id), "unknown id {}", r.id);
                }
                Some(other) => panic!("unexpected reply {other:?}"),
                None => {
                    // Window expired: presume the response was dropped and
                    // resend under a fresh id.
                    abandoned.push(id);
                    id = c.send_infer(&train, 0, None).unwrap();
                    retries += 1;
                }
            }
        }
    }
    assert!(retries > 0, "drop trigger never forced a retry");
    let metrics = server.metrics();
    use std::sync::atomic::Ordering;
    assert!(metrics.dropped_responses.load(Ordering::Relaxed) > 0);
    assert!(metrics.chaos_injected.load(Ordering::Relaxed) > 0);
    server.shutdown();
}

/// Connections reset mid-frame are recovered by reconnecting; no request
/// is abandoned.
#[test]
fn connection_resets_recovered_by_reconnect() {
    const N: usize = 9;
    let chip = test_chip();
    let server = Server::start(
        &chip,
        "127.0.0.1:0",
        ServeConfig {
            workers: 1,
            lanes_per_worker: 2,
            chaos: SystemChaos { reset_conn_every: 3, ..SystemChaos::default() },
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let addr = server.local_addr();
    let mut c = Client::connect(addr).unwrap();
    let mut reconnects = 0usize;
    for i in 0..N {
        let train = train_for(i);
        let mut attempts = 0;
        loop {
            attempts += 1;
            assert!(attempts <= 10, "request {i} unrecoverable after {attempts} attempts");
            match c.infer(&train) {
                Ok(r) => {
                    assert!((r.predicted as usize) < 8);
                    break;
                }
                Err(_) => {
                    // Torn connection (chaos reset): reconnect and retry.
                    c = Client::connect_retry(addr, 20, Duration::from_millis(25)).unwrap();
                    reconnects += 1;
                }
            }
        }
    }
    assert!(reconnects > 0, "reset trigger never tore the connection");
    let metrics = server.metrics();
    use std::sync::atomic::Ordering;
    assert!(metrics.chaos_injected.load(Ordering::Relaxed) > 0);
    server.shutdown();
}

/// Hardware fault counters and recovery counters surface in the STATS
/// frame while the server runs.
#[test]
fn stats_frame_reports_fault_and_recovery_counters() {
    let mut chip = test_chip();
    chip.install_faults(&aggressive_plan());
    let server = Server::start(
        &chip,
        "127.0.0.1:0",
        ServeConfig { workers: 1, lanes_per_worker: 2, ..ServeConfig::default() },
    )
    .unwrap();
    let mut c = Client::connect(server.local_addr()).unwrap();
    for i in 0..4 {
        c.infer(&train_for(i)).unwrap();
    }
    // Workers publish counter deltas after each batch; poll briefly.
    let deadline = Instant::now() + Duration::from_secs(10);
    let total = loop {
        let stats = c.stats().unwrap();
        let recovery = stats.get("recovery").unwrap();
        assert_eq!(recovery.get("worker_panics").unwrap().as_usize().unwrap(), 0);
        let faults = stats.get("faults").unwrap();
        let total = faults.get("stuck_row_hits").unwrap().as_usize().unwrap()
            + faults.get("dead_slot_hits").unwrap().as_usize().unwrap()
            + faults.get("events_bit_flipped").unwrap().as_usize().unwrap();
        if total > 0 || Instant::now() > deadline {
            break total;
        }
        std::thread::sleep(Duration::from_millis(25));
    };
    assert!(total > 0, "fault counters never surfaced in STATS");
    server.shutdown();
}
