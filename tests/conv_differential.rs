//! Differential harness pinning compressed conv execution to the
//! dense-expansion oracle.
//!
//! The contract (see `menage::engine::convgen`): a chip built from a
//! network with compressed conv layers must be **bit-identical** — every
//! layer spike train, the modeled cycles, and the complete per-core
//! [`CoreStats`] — to a chip built from `expand_convs()` of the same
//! network under the same config, seed, and analog mode. Both
//! representations take the same canonical mapping, the generated row
//! blocks are structurally equal to the distilled expansion's MEM_S&N
//! rows, and the dispatcher is representation-blind past the fetch, so
//! identity holds in sequential, lane-batched (ideal and non-ideal),
//! sharded, and faulted modes. The suite drives randomized
//! kernels/strides/paddings plus the edge cases through that assertion,
//! and covers the capacity story: the same conv chain needs fewer shards
//! (and ≥10× fewer weight bytes at CIFAR10-DVS scale) compressed.

use menage::accel::Menage;
use menage::analog::AnalogParams;
use menage::config::AcceleratorConfig;
use menage::fault::FaultPlan;
use menage::mapping::{layer_weight_bytes, partition_layers, ShardLimits, Strategy};
use menage::shard::ShardedMenage;
use menage::snn::{reference_forward, ConvSpec, QuantNetwork, SpikeTrain};
use menage::util::prop;
use menage::util::rng::Rng;

fn accel(cores: usize, m: usize, n: usize) -> AcceleratorConfig {
    let mut c = AcceleratorConfig::accel1();
    c.num_cores = cores;
    c.a_neurons_per_core = m;
    c.a_syns_per_core = m;
    c.virtual_per_a_neuron = n;
    c
}

/// A random conv chain at test scale: 1–2 compressed conv layers plus a
/// dense classifier head, with randomized geometry.
fn random_conv_net(rng: &mut Rng) -> QuantNetwork {
    let in_channels = 1 + rng.below(2);
    let side = 5 + rng.below(4);
    let stride = 1 + rng.below(2);
    let padding = rng.below(2);
    let k = 2 + rng.below(2);
    let c1 = ConvSpec {
        in_channels,
        in_h: side,
        in_w: side,
        out_channels: 2 + rng.below(2),
        kernel_h: k,
        kernel_w: k,
        stride,
        padding,
    };
    let mut specs = vec![c1];
    // Half the time, chain a second conv over the first one's output map.
    if rng.bernoulli(0.5) && c1.out_h() >= 3 && c1.out_w() >= 3 {
        specs.push(ConvSpec {
            in_channels: c1.out_channels,
            in_h: c1.out_h(),
            in_w: c1.out_w(),
            out_channels: 2,
            kernel_h: 3,
            kernel_w: 3,
            stride: 1,
            padding: 1,
        });
    }
    let sparsity = 0.2 + rng.f64() * 0.5;
    QuantNetwork::random_conv("conv-diff", &specs, 4, 4 + rng.below(5), sparsity, rng).unwrap()
}

/// The core assertion: compressed and expanded chips built identically are
/// bit-identical over an input sequence — sequentially (accumulating
/// stats) and lane-batched over the whole set at once. Returns an error
/// string for the property driver.
fn assert_compressed_equals_expanded(
    net: &QuantNetwork,
    cfg: &AcceleratorConfig,
    analog: &AnalogParams,
    faults: Option<&FaultPlan>,
    inputs: &[SpikeTrain],
    tag: &str,
) -> Result<(), String> {
    let dense = net.expand_convs().map_err(|e| format!("{tag}: expand: {e}"))?;
    let build = |n: &QuantNetwork| -> Result<Menage, String> {
        let mut chip = Menage::build(n, cfg, Strategy::IlpFlow, analog, 7)
            .map_err(|e| format!("{tag}: build: {e}"))?;
        if let Some(plan) = faults {
            chip.install_faults(plan);
        }
        Ok(chip)
    };
    let mut comp = build(net)?;
    let mut exp = build(&dense)?;

    // Sequential, stats accumulating across the sequence.
    for (i, input) in inputs.iter().enumerate() {
        let a = comp.run(input).map_err(|e| format!("{tag}: run: {e}"))?;
        let b = exp.run(input).map_err(|e| format!("{tag}: oracle run: {e}"))?;
        if a.cycles != b.cycles {
            return Err(format!("{tag}: input {i} cycles {} != {}", a.cycles, b.cycles));
        }
        for (l, (ta, tb)) in a.trains.iter().zip(&b.trains).enumerate() {
            if ta.spikes != tb.spikes {
                return Err(format!("{tag}: input {i} layer {l} trains diverge"));
            }
        }
    }
    for (l, (ca, cb)) in comp.cores.iter().zip(&exp.cores).enumerate() {
        if ca.stats != cb.stats {
            return Err(format!(
                "{tag}: core {l} CoreStats diverge:\n comp: {:?}\n exp:  {:?}",
                ca.stats, cb.stats
            ));
        }
    }
    if comp.fault_counters() != exp.fault_counters() {
        return Err(format!("{tag}: fault counters diverge"));
    }

    // Lane-batched over the whole input set on fresh chips.
    if !inputs.is_empty() {
        let mut lcomp = build(net)?;
        let mut lexp = build(&dense)?;
        let oa = lcomp.run_lanes(inputs).map_err(|e| format!("{tag}: lanes: {e}"))?;
        let ob = lexp.run_lanes(inputs).map_err(|e| format!("{tag}: oracle lanes: {e}"))?;
        for i in 0..inputs.len() {
            if oa[i].cycles != ob[i].cycles {
                return Err(format!("{tag}: lane {i} cycles diverge"));
            }
            for (l, (ta, tb)) in oa[i].trains.iter().zip(&ob[i].trains).enumerate() {
                if ta.spikes != tb.spikes {
                    return Err(format!("{tag}: lane {i} layer {l} trains diverge"));
                }
            }
            for (l, (ca, cb)) in lcomp.cores.iter().zip(&lexp.cores).enumerate() {
                if ca.lane_stats(i) != cb.lane_stats(i) {
                    return Err(format!("{tag}: lane {i} core {l} CoreStats diverge"));
                }
            }
        }
        lcomp.fold_lane_stats();
        lexp.fold_lane_stats();
        for (l, (ca, cb)) in lcomp.cores.iter().zip(&lexp.cores).enumerate() {
            if ca.stats != cb.stats {
                return Err(format!("{tag}: folded core {l} CoreStats diverge"));
            }
        }
    }
    Ok(())
}

/// Randomized kernels × strides × paddings × sparsities, ideal analog,
/// sequential + lane-batched. Also cross-checks the compressed chip
/// against the bit-exact reference model.
#[test]
fn prop_conv_compressed_bit_identical_ideal() {
    prop::check_n("conv-compressed-vs-expanded", 10, |rng| {
        let net = random_conv_net(rng);
        let m = 2 + rng.below(3);
        let n = 2 + rng.below(4);
        let cfg = accel(net.layers.len(), m, n);
        let t = net.timesteps;
        let dim = net.input_dim();
        let inputs: Vec<SpikeTrain> = (0..1 + rng.below(4))
            .map(|_| SpikeTrain::bernoulli(dim, t, rng.f64() * 0.35, rng))
            .collect();
        let tag = format!("m={m} n={n} layers={}", net.layers.len());
        assert_compressed_equals_expanded(
            &net,
            &cfg,
            &AnalogParams::ideal(),
            None,
            &inputs,
            &tag,
        )?;
        let mut chip = Menage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 7)
            .map_err(|e| e.to_string())?;
        for input in &inputs {
            let golden = reference_forward(&net, input).map_err(|e| e.to_string())?;
            let out = chip.run(input).map_err(|e| e.to_string())?;
            if !out.matches_reference(&golden) {
                return Err(format!("{tag}: compressed chip diverges from reference"));
            }
        }
        Ok(())
    });
}

/// Non-ideal analog mode: same mismatch seeds on both chips, so the Kahan
/// error sidecar folds identical deposit sequences — bit-identity must
/// survive the analog model, sequentially and lane-batched.
#[test]
fn prop_conv_compressed_bit_identical_nonideal() {
    prop::check_n("conv-compressed-vs-expanded-nonideal", 6, |rng| {
        let net = random_conv_net(rng);
        let cfg = accel(net.layers.len(), 2 + rng.below(3), 2 + rng.below(3));
        let t = net.timesteps;
        let dim = net.input_dim();
        let inputs: Vec<SpikeTrain> = (0..1 + rng.below(3))
            .map(|_| SpikeTrain::bernoulli(dim, t, rng.f64() * 0.3, rng))
            .collect();
        assert_compressed_equals_expanded(
            &net,
            &cfg,
            &AnalogParams::paper(),
            None,
            &inputs,
            "nonideal",
        )
    });
}

/// Edge cases: an empty (zero-timestep) train, an all-quiescent input, and
/// a single spike — sweep/reload accounting with no or minimal activity.
#[test]
fn conv_edge_inputs() {
    let spec = ConvSpec {
        in_channels: 2,
        in_h: 6,
        in_w: 6,
        out_channels: 3,
        kernel_h: 3,
        kernel_w: 3,
        stride: 1,
        padding: 1,
    };
    let mut rng = Rng::new(51);
    let net = QuantNetwork::random_conv("conv-edge", &[spec], 4, 6, 0.3, &mut rng).unwrap();
    // Capacity 8 < 108 conv outputs: deep multi-round coverage.
    let cfg = accel(2, 2, 4);
    let dim = net.input_dim();
    let mut single = SpikeTrain::new(dim, 6);
    single.spikes[2].push((dim / 2) as u32);
    let inputs = vec![
        SpikeTrain::new(dim, 0),
        SpikeTrain::new(dim, 6),
        single,
        SpikeTrain::bernoulli(dim, 6, 0.25, &mut rng),
    ];
    assert_compressed_equals_expanded(
        &net,
        &cfg,
        &AnalogParams::ideal(),
        None,
        &inputs,
        "edges",
    )
    .unwrap();
}

/// Duplicate events and the forced per-event dispatch knob: ×multiplicity
/// accounting through the generator fetch must match the CSR path.
#[test]
fn conv_duplicate_events_and_per_event_knob() {
    let mut rng = Rng::new(52);
    let net = random_conv_net(&mut rng);
    let cfg = accel(net.layers.len(), 3, 3);
    let dim = net.input_dim();
    let mut dup = SpikeTrain::bernoulli(dim, net.timesteps, 0.25, &mut rng);
    dup.duplicate_events();
    let inputs = vec![dup, SpikeTrain::bernoulli(dim, net.timesteps, 0.2, &mut rng)];
    assert_compressed_equals_expanded(
        &net,
        &cfg,
        &AnalogParams::ideal(),
        None,
        &inputs,
        "dups",
    )
    .unwrap();

    // Forced per-event dispatch on both chips stays identical too.
    let dense = net.expand_convs().unwrap();
    let mut comp = Menage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 7).unwrap();
    let mut exp = Menage::build(&dense, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 7).unwrap();
    for chip in [&mut comp, &mut exp] {
        for core in chip.cores.iter_mut() {
            core.force_per_event_dispatch = true;
        }
    }
    let a = comp.run(&inputs[0]).unwrap();
    let b = exp.run(&inputs[0]).unwrap();
    assert_eq!(a.cycles, b.cycles);
    for (ca, cb) in comp.cores.iter().zip(&exp.cores) {
        assert_eq!(ca.stats, cb.stats);
    }
}

/// Sharded execution: the compressed pipeline over every feasible shard
/// count is bit-identical to the expanded sharded pipeline AND to the
/// compressed monolithic chip.
#[test]
fn conv_sharded_matches_expanded_and_monolithic() {
    let mut rng = Rng::new(53);
    let specs = [
        ConvSpec {
            in_channels: 2,
            in_h: 8,
            in_w: 8,
            out_channels: 3,
            kernel_h: 3,
            kernel_w: 3,
            stride: 2,
            padding: 1,
        },
        ConvSpec {
            in_channels: 3,
            in_h: 4,
            in_w: 4,
            out_channels: 3,
            kernel_h: 3,
            kernel_w: 3,
            stride: 1,
            padding: 1,
        },
    ];
    let net = QuantNetwork::random_conv("conv-shard", &specs, 4, 6, 0.3, &mut rng).unwrap();
    let dense = net.expand_convs().unwrap();
    let cfg = accel(net.layers.len(), 3, 4);
    let inputs: Vec<SpikeTrain> = (0..3)
        .map(|_| SpikeTrain::bernoulli(net.input_dim(), 6, 0.25, &mut rng))
        .collect();
    let analog = AnalogParams::ideal();
    let mut mono = Menage::build(&net, &cfg, Strategy::IlpFlow, &analog, 7).unwrap();
    for num_shards in 1..=net.layers.len() {
        let mut sc = ShardedMenage::build(&net, &cfg, Strategy::IlpFlow, &analog, 7, num_shards)
            .unwrap();
        let mut se = ShardedMenage::build(&dense, &cfg, Strategy::IlpFlow, &analog, 7, num_shards)
            .unwrap();
        for (i, input) in inputs.iter().enumerate() {
            let a = sc.run(input).unwrap();
            let b = se.run(input).unwrap();
            let m = mono.run(input).unwrap();
            assert_eq!(a.cycles, b.cycles, "shards={num_shards} input {i}");
            assert_eq!(a.cycles, m.cycles, "shards={num_shards} input {i} vs monolithic");
            for ((ta, tb), tm) in a.trains.iter().zip(&b.trains).zip(&m.trains) {
                assert_eq!(ta.spikes, tb.spikes, "shards={num_shards} input {i}");
                assert_eq!(ta.spikes, tm.spikes, "shards={num_shards} input {i}");
            }
        }
    }
}

/// Hardware faults: the same fault plan realizes the same silicon defects
/// on both representations (per-core seeds), and since the generated
/// entries equal the distilled entries, every stuck-row suppression,
/// dead-slot discard, bit-flip, and drift deposit lands identically.
#[test]
fn conv_faulted_bit_identity() {
    let mut rng = Rng::new(54);
    let net = random_conv_net(&mut rng);
    let cfg = accel(net.layers.len(), 3, 3);
    let dim = net.input_dim();
    let inputs: Vec<SpikeTrain> = (0..3)
        .map(|_| SpikeTrain::bernoulli(dim, net.timesteps, 0.3, &mut rng))
        .collect();
    let plan = FaultPlan {
        seed: 99,
        stuck_row_frac: 0.3,
        dead_slot_frac: 0.2,
        bit_flip_p: 0.05,
        drift_scale: 1.5,
    };
    assert_compressed_equals_expanded(
        &net,
        &cfg,
        &AnalogParams::ideal(),
        Some(&plan),
        &inputs,
        "faulted-ideal",
    )
    .unwrap();
    assert_compressed_equals_expanded(
        &net,
        &cfg,
        &AnalogParams::paper(),
        Some(&plan),
        &inputs,
        "faulted-nonideal",
    )
    .unwrap();
    // The plan actually bites (fault identity above is not vacuous).
    let mut chip =
        Menage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 7).unwrap();
    chip.install_faults(&plan);
    for input in &inputs {
        chip.run(input).unwrap();
    }
    let (stuck, dead, flips) = chip.fault_counters();
    assert!(stuck + dead + flips > 0, "fault plan never fired");
}

/// The capacity story (ISSUE acceptance): under a per-chip weight budget
/// sized to the largest expanded layer, the expanded chain only fits
/// multi-chip while the compressed chain fits a single shard — and at
/// CIFAR10-DVS scale the conv layer's weight bytes drop ≥10×.
#[test]
fn conv_compression_needs_fewer_shards_and_10x_less_weight_sram() {
    let mut rng = Rng::new(55);
    // CIFAR10-DVS geometry: 2 polarity channels × 32×32, two conv layers,
    // 10-class head.
    let specs = [
        ConvSpec {
            in_channels: 2,
            in_h: 32,
            in_w: 32,
            out_channels: 8,
            kernel_h: 3,
            kernel_w: 3,
            stride: 2,
            padding: 1,
        },
        ConvSpec {
            in_channels: 8,
            in_h: 16,
            in_w: 16,
            out_channels: 8,
            kernel_h: 3,
            kernel_w: 3,
            stride: 2,
            padding: 1,
        },
    ];
    let net = QuantNetwork::random_conv("cifar10dvs", &specs, 10, 8, 0.3, &mut rng).unwrap();
    let dense = net.expand_convs().unwrap();
    let w_comp = layer_weight_bytes(&net, 8);
    let w_exp = layer_weight_bytes(&dense, 8);
    // ≥10× on every conv layer (the head is shared and unchanged).
    for i in 0..specs.len() {
        assert!(
            w_exp[i] >= 10 * w_comp[i],
            "layer {i}: expanded {} < 10× compressed {}",
            w_exp[i],
            w_comp[i]
        );
        assert_eq!(w_comp[i], specs[i].kernel_len());
    }
    assert_eq!(w_comp[specs.len()], w_exp[specs.len()]);

    // Budget = the largest expanded layer: each expanded layer still fits
    // a chip alone, but no chip can take two — the expanded chain is
    // forced multi-shard. The compressed chain (kernels + head) fits one.
    let budget = *w_exp.iter().max().unwrap();
    assert!(
        w_comp.iter().sum::<usize>() <= budget,
        "compressed chain should fit the budget whole"
    );
    let limits = |budget| ShardLimits {
        max_layers_per_shard: net.layers.len(),
        chip_weight_budget: Some(budget),
        weight_bits: 8,
    };
    let min_shards = |n: &QuantNetwork| -> Option<usize> {
        (1..=n.layers.len()).find(|&k| partition_layers(n, k, &limits(budget)).is_ok())
    };
    let k_comp = min_shards(&net).expect("compressed chain must partition");
    let k_exp = min_shards(&dense).expect("expanded chain must partition");
    assert_eq!(k_comp, 1, "compressed chain should fit a single chip");
    assert!(
        k_exp > k_comp,
        "expanded chain should need more shards ({k_exp}) than compressed ({k_comp})"
    );
}
