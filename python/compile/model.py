"""Layer-2 JAX SNN model: training forward/backward + quantized inference.

Two views of the same network:

* **Training view** (`snn_forward_train`) — float weights, surrogate
  gradient through the spike nonlinearity (fast-sigmoid, as in SNNTorch),
  BPTT via `lax.scan`. Used by `train.py` (Algorithm 1, step 1).
* **Inference view** (`snn_forward_quant`) — int8 weights + per-layer
  scales, calling the Layer-1 Pallas kernel per layer per step. This is
  the function `aot.py` lowers to HLO text for the rust runtime, and its
  arithmetic is what the rust accelerator simulator must reproduce.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels.lif_step import lif_step
from .kernels.ref import lif_step_ref

# LIF constants shared with the rust side (ModelConfig defaults).
BETA = 0.9
V_TH = 1.0
V_RESET = 0.0


def init_params(layer_sizes, key, w_std=None, gain=1.0):
    """He-style init of float weights, list of ``[out, in]`` arrays.

    `gain` > 1 keeps deep SNNs alive: spiking layers attenuate activity
    (only supra-threshold sums propagate), so plain He init silences layer
    3+ — scaling the init restores per-layer firing (measured in
    tests/test_model.py::test_deep_network_stays_alive).
    """
    params = []
    for nin, nout in zip(layer_sizes[:-1], layer_sizes[1:]):
        key, sub = jax.random.split(key)
        std = w_std or gain * (2.0 / nin) ** 0.5
        params.append(jax.random.normal(sub, (nout, nin), jnp.float32) * std)
    return params


# Fast-sigmoid surrogate slope. SNNTorch's default 25 is fine for shallow
# nets but starves gradients through the 5-layer CIFAR10-DVS MLP (measured:
# training collapses to silence); 5.0 trains both of Table I's topologies.
SURROGATE_SLOPE = 5.0


@jax.custom_jvp
def spike_fn(v):
    """Heaviside spike with fast-sigmoid surrogate gradient."""
    return (v >= V_TH).astype(jnp.float32)


@spike_fn.defjvp
def _spike_fn_jvp(primals, tangents):
    (v,), (dv,) = primals, tangents
    out = (v >= V_TH).astype(jnp.float32)
    surr = 1.0 / (SURROGATE_SLOPE * jnp.abs(v - V_TH) + 1.0) ** 2
    return out, surr * dv


def snn_forward_train(params, events):
    """Training forward: float weights, surrogate spikes.

    Args:
      params: list of f32 ``[out, in]`` weights.
      events: f32 ``[T, in]`` input spike raster.

    Returns:
      ``(logits f32 [n_classes], spike_counts list)`` — logits are output
      spike counts (rate decoding).
    """
    sizes = [p.shape[0] for p in params]

    def step(carry, x_t):
        vs = carry
        new_vs = []
        s = x_t
        outs = []
        for w, v in zip(params, vs):
            cur = w @ s
            v_new = BETA * v + cur
            spk = spike_fn(v_new)
            v_next = jnp.where(spk > 0, V_RESET, v_new)
            new_vs.append(v_next)
            s = spk
            outs.append(spk)
        return new_vs, outs[-1]

    v0 = [jnp.zeros((n,), jnp.float32) for n in sizes]
    _, out_spikes = jax.lax.scan(step, v0, events)
    return out_spikes.sum(axis=0), out_spikes


def loss_fn(params, events, label):
    """Cross-entropy on spike-count logits (rate decoding)."""
    logits, _ = snn_forward_train(params, events)
    logp = jax.nn.log_softmax(logits)
    return -logp[label]


@functools.partial(jax.jit, static_argnames=())
def batched_loss(params, events_b, labels_b):
    losses = jax.vmap(lambda e, l: loss_fn(params, e, l))(events_b, labels_b)
    return losses.mean()


grad_fn = jax.jit(jax.value_and_grad(batched_loss))


@functools.partial(jax.jit, static_argnames=())
def predict_train(params, events_b):
    logits = jax.vmap(lambda e: snn_forward_train(params, e)[0])(events_b)
    return logits.argmax(axis=-1)


# ---------------------------------------------------------------------------
# Quantized inference (the function that gets AOT-lowered for rust).
# ---------------------------------------------------------------------------


def snn_forward_quant(qparams, events, *, use_pallas=True, interpret=True):
    """Quantized inference forward.

    Args:
      qparams: list of ``(w_q int8 [out,in], scale f32 scalar)``.
      events: f32 ``[T, in]``.
      use_pallas: route the per-layer step through the Pallas kernel
        (True for the artifact path) or the jnp oracle (golden checks).

    Returns:
      ``(counts f32 [n_classes], out_spikes f32 [T, n_classes])``.
    """
    sizes = [w.shape[0] for w, _ in qparams]
    kernel = lif_step if use_pallas else None

    def step(vs, x_t):
        new_vs = []
        s = x_t
        for (w_q, scale), v in zip(qparams, vs):
            if kernel is not None:
                spk, v_next = kernel(
                    w_q, s, v, scale, BETA, V_TH, V_RESET, interpret=interpret
                )
            else:
                spk, v_next = lif_step_ref(w_q, s, v, scale, BETA, V_TH, V_RESET)
            new_vs.append(v_next)
            s = spk
        return new_vs, s

    v0 = [jnp.zeros((n,), jnp.float32) for n in sizes]
    _, out_spikes = jax.lax.scan(step, v0, events)
    return out_spikes.sum(axis=0), out_spikes


def make_inference_fn(qparams, *, use_pallas=True, interpret=True):
    """Close over quantized weights: returns ``f(events) -> (counts, spikes)``
    suitable for `jax.jit(...).lower()` — weights become HLO constants, so
    the rust runtime only feeds the event raster."""

    def fn(events):
        return snn_forward_quant(
            qparams, events, use_pallas=use_pallas, interpret=interpret
        )

    return fn
