"""Layer-2 JAX SNN model: training forward/backward + quantized inference.

Two views of the same network:

* **Training view** (`snn_forward_train`) — float weights, surrogate
  gradient through the spike nonlinearity (fast-sigmoid, as in SNNTorch),
  BPTT via `lax.scan`. Used by `train.py` (Algorithm 1, step 1).
* **Inference view** (`snn_forward_quant`) — int8 weights + per-layer
  scales, calling the Layer-1 Pallas kernel per layer per step. This is
  the function `aot.py` lowers to HLO text for the rust runtime, and its
  arithmetic is what the rust accelerator simulator must reproduce.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.lif_step import lif_step
from .kernels.ref import lif_step_ref

# LIF constants shared with the rust side (ModelConfig defaults).
BETA = 0.9
V_TH = 1.0
V_RESET = 0.0


def init_params(layer_sizes, key, w_std=None, gain=1.0):
    """He-style init of float weights, list of ``[out, in]`` arrays.

    `gain` > 1 keeps deep SNNs alive: spiking layers attenuate activity
    (only supra-threshold sums propagate), so plain He init silences layer
    3+ — scaling the init restores per-layer firing (measured in
    tests/test_model.py::test_deep_network_stays_alive).
    """
    params = []
    for nin, nout in zip(layer_sizes[:-1], layer_sizes[1:]):
        key, sub = jax.random.split(key)
        std = w_std or gain * (2.0 / nin) ** 0.5
        params.append(jax.random.normal(sub, (nout, nin), jnp.float32) * std)
    return params


# ---------------------------------------------------------------------------
# Compressed convolutional layers (python twin of rust `snn::ConvSpec`).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """Geometry of a compressed conv layer.

    The trainable parameter is the kernel ``[oc, ic, kh, kw]`` — stored once
    per layer instead of once per output position. Training runs on the dense
    expansion so gradients from every tile accumulate back into the shared
    kernel taps (true weight sharing), and the export writes only the kernel
    (``k{i}`` + ``conv{i}``), which the rust mapper re-expands on demand.
    """

    in_channels: int
    in_h: int
    in_w: int
    out_channels: int
    kernel_h: int
    kernel_w: int
    stride: int = 1
    padding: int = 0

    @property
    def out_h(self) -> int:
        return (self.in_h + 2 * self.padding - self.kernel_h) // self.stride + 1

    @property
    def out_w(self) -> int:
        return (self.in_w + 2 * self.padding - self.kernel_w) // self.stride + 1

    @property
    def in_dim(self) -> int:
        return self.in_channels * self.in_h * self.in_w

    @property
    def out_dim(self) -> int:
        return self.out_channels * self.out_h * self.out_w

    @property
    def kernel_shape(self) -> tuple[int, int, int, int]:
        return (self.out_channels, self.in_channels, self.kernel_h, self.kernel_w)


@functools.lru_cache(maxsize=None)
def conv_index_map(spec: ConvSpec):
    """``(rows, cols, taps)`` index arrays for densifying a kernel.

    Mirrors the rust enumeration (snn.rs `ConvSpec::for_each_target`):
    dst = (oc·out_h + oy)·out_w + ox, src = (ic·in_h + iy)·in_w + ix,
    tap = ((oc·ic_n + ic)·kh + ky)·kw + kx. Each (dst, src) pair is hit by
    at most one tap, so a plain scatter reproduces the dense matrix.
    """
    rows, cols, taps = [], [], []
    for oc in range(spec.out_channels):
        for oy in range(spec.out_h):
            for ox in range(spec.out_w):
                dst = (oc * spec.out_h + oy) * spec.out_w + ox
                for ic in range(spec.in_channels):
                    for ky in range(spec.kernel_h):
                        iy = oy * spec.stride + ky - spec.padding
                        if not 0 <= iy < spec.in_h:
                            continue
                        for kx in range(spec.kernel_w):
                            ix = ox * spec.stride + kx - spec.padding
                            if not 0 <= ix < spec.in_w:
                                continue
                            rows.append(dst)
                            cols.append((ic * spec.in_h + iy) * spec.in_w + ix)
                            taps.append(
                                ((oc * spec.in_channels + ic) * spec.kernel_h + ky)
                                * spec.kernel_w
                                + kx
                            )
    return (
        np.asarray(rows, np.int32),
        np.asarray(cols, np.int32),
        np.asarray(taps, np.int32),
    )


def expand_conv(kernel, spec: ConvSpec):
    """Densify a kernel to the ``[out_dim, in_dim]`` matrix (rust
    `QuantLayer::expand_conv` oracle). Differentiable w.r.t. the kernel:
    the scatter's transpose gathers every tile's gradient into the taps."""
    rows, cols, taps = conv_index_map(spec)
    k = jnp.asarray(kernel)
    dense = jnp.zeros((spec.out_dim, spec.in_dim), k.dtype)
    return dense.at[rows, cols].set(k.reshape(-1)[taps])


def init_conv_params(layer_sizes, convs, key, gain=1.0):
    """He-style init for a mixed conv/dense stack. `convs` has one entry
    per layer: a ConvSpec (trainable kernel, fan-in ic·kh·kw) or None
    (dense ``[out, in]`` matrix, as in `init_params`)."""
    params = []
    for (nin, nout), spec in zip(zip(layer_sizes[:-1], layer_sizes[1:]), convs):
        key, sub = jax.random.split(key)
        if spec is not None:
            assert spec.in_dim == nin and spec.out_dim == nout, (spec, nin, nout)
            fan_in = spec.in_channels * spec.kernel_h * spec.kernel_w
            std = gain * (2.0 / fan_in) ** 0.5
            params.append(jax.random.normal(sub, spec.kernel_shape, jnp.float32) * std)
        else:
            std = gain * (2.0 / nin) ** 0.5
            params.append(jax.random.normal(sub, (nout, nin), jnp.float32) * std)
    return params


def densify_qparams(qparams, convs=None):
    """Expand quantized conv kernels to dense int8 ``[out, in]`` matrices so
    `snn_forward_quant` / the AOT lowering see the uniform dense shape. The
    per-tensor scale is unchanged — expansion only replicates taps."""
    convs = convs or (None,) * len(qparams)
    out = []
    for (w, s), spec in zip(qparams, convs):
        if spec is not None:
            w = np.asarray(expand_conv(np.asarray(w), spec))
        out.append((w, s))
    return out


# Fast-sigmoid surrogate slope. SNNTorch's default 25 is fine for shallow
# nets but starves gradients through the 5-layer CIFAR10-DVS MLP (measured:
# training collapses to silence); 5.0 trains both of Table I's topologies.
SURROGATE_SLOPE = 5.0


@jax.custom_jvp
def spike_fn(v):
    """Heaviside spike with fast-sigmoid surrogate gradient."""
    return (v >= V_TH).astype(jnp.float32)


@spike_fn.defjvp
def _spike_fn_jvp(primals, tangents):
    (v,), (dv,) = primals, tangents
    out = (v >= V_TH).astype(jnp.float32)
    surr = 1.0 / (SURROGATE_SLOPE * jnp.abs(v - V_TH) + 1.0) ** 2
    return out, surr * dv


def snn_forward_train(params, events, convs=None):
    """Training forward: float weights, surrogate spikes.

    Args:
      params: list of f32 weights — ``[out, in]`` dense, or a conv kernel
        ``[oc, ic, kh, kw]`` where `convs` carries a ConvSpec.
      events: f32 ``[T, in]`` input spike raster.
      convs: optional per-layer tuple of ConvSpec-or-None; conv layers are
        densified via `expand_conv` before the scan (once, not per step).

    Returns:
      ``(logits f32 [n_classes], spike_counts list)`` — logits are output
      spike counts (rate decoding).
    """
    convs = convs or (None,) * len(params)
    weights = [expand_conv(p, c) if c is not None else p for p, c in zip(params, convs)]
    sizes = [w.shape[0] for w in weights]

    def step(carry, x_t):
        vs = carry
        new_vs = []
        s = x_t
        outs = []
        for w, v in zip(weights, vs):
            cur = w @ s
            v_new = BETA * v + cur
            spk = spike_fn(v_new)
            v_next = jnp.where(spk > 0, V_RESET, v_new)
            new_vs.append(v_next)
            s = spk
            outs.append(spk)
        return new_vs, outs[-1]

    v0 = [jnp.zeros((n,), jnp.float32) for n in sizes]
    _, out_spikes = jax.lax.scan(step, v0, events)
    return out_spikes.sum(axis=0), out_spikes


def loss_fn(params, events, label, convs=None):
    """Cross-entropy on spike-count logits (rate decoding)."""
    logits, _ = snn_forward_train(params, events, convs)
    logp = jax.nn.log_softmax(logits)
    return -logp[label]


@functools.partial(jax.jit, static_argnames=())
def batched_loss(params, events_b, labels_b):
    losses = jax.vmap(lambda e, l: loss_fn(params, e, l))(events_b, labels_b)
    return losses.mean()


grad_fn = jax.jit(jax.value_and_grad(batched_loss))


@functools.partial(jax.jit, static_argnames=())
def predict_train(params, events_b):
    logits = jax.vmap(lambda e: snn_forward_train(params, e)[0])(events_b)
    return logits.argmax(axis=-1)


@functools.lru_cache(maxsize=None)
def make_train_fns(convs=None):
    """Jitted ``(grad_fn, predict_fn)`` for a mixed conv/dense stack.

    `convs` is a hashable tuple of ConvSpec-or-None per layer (or None for
    all-dense, where the pair matches the module-level `grad_fn` /
    `predict_train`). Cached so repeated calls reuse the jit traces.
    """

    def _batched_loss(params, events_b, labels_b):
        losses = jax.vmap(lambda e, l: loss_fn(params, e, l, convs))(events_b, labels_b)
        return losses.mean()

    grad = jax.jit(jax.value_and_grad(_batched_loss))

    @jax.jit
    def predict(params, events_b):
        logits = jax.vmap(lambda e: snn_forward_train(params, e, convs)[0])(events_b)
        return logits.argmax(axis=-1)

    return grad, predict


# ---------------------------------------------------------------------------
# Quantized inference (the function that gets AOT-lowered for rust).
# ---------------------------------------------------------------------------


def snn_forward_quant(qparams, events, *, use_pallas=True, interpret=True):
    """Quantized inference forward.

    Args:
      qparams: list of ``(w_q int8 [out,in], scale f32 scalar)``.
      events: f32 ``[T, in]``.
      use_pallas: route the per-layer step through the Pallas kernel
        (True for the artifact path) or the jnp oracle (golden checks).

    Returns:
      ``(counts f32 [n_classes], out_spikes f32 [T, n_classes])``.
    """
    sizes = [w.shape[0] for w, _ in qparams]
    kernel = lif_step if use_pallas else None

    def step(vs, x_t):
        new_vs = []
        s = x_t
        for (w_q, scale), v in zip(qparams, vs):
            if kernel is not None:
                spk, v_next = kernel(
                    w_q, s, v, scale, BETA, V_TH, V_RESET, interpret=interpret
                )
            else:
                spk, v_next = lif_step_ref(w_q, s, v, scale, BETA, V_TH, V_RESET)
            new_vs.append(v_next)
            s = spk
        return new_vs, s

    v0 = [jnp.zeros((n,), jnp.float32) for n in sizes]
    _, out_spikes = jax.lax.scan(step, v0, events)
    return out_spikes.sum(axis=0), out_spikes


def make_inference_fn(qparams, *, use_pallas=True, interpret=True):
    """Close over quantized weights: returns ``f(events) -> (counts, spikes)``
    suitable for `jax.jit(...).lower()` — weights become HLO constants, so
    the rust runtime only feeds the event raster."""

    def fn(events):
        return snn_forward_quant(
            qparams, events, use_pallas=use_pallas, interpret=interpret
        )

    return fn
