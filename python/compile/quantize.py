"""Algorithm 1 step 2: unstructured L1 pruning + 8-bit post-training
quantization, in JAX/numpy.

Matches the paper's flow exactly: train dense -> zero the globally (per
layer) smallest-|w| fraction -> symmetric per-tensor int8 quantization
(scale = max|w| / 127). The quantized (w_q, scale) pairs feed both the
AOT-lowered inference function and the rust mapper via the ``.mtz`` export.
"""

from __future__ import annotations

import numpy as np


def prune_l1(params: list[np.ndarray], frac: float) -> list[np.ndarray]:
    """Zero the smallest-magnitude `frac` of weights in each layer."""
    assert 0.0 <= frac <= 1.0
    out = []
    for w in params:
        w = np.asarray(w, dtype=np.float32).copy()
        k = int(round(w.size * frac))
        if k > 0:
            thresh = np.partition(np.abs(w).ravel(), k - 1)[k - 1]
            w[np.abs(w) <= thresh] = 0.0
        out.append(w)
    return out


def quantize_int8(params: list[np.ndarray]) -> list[tuple[np.ndarray, np.float32]]:
    """Symmetric per-tensor int8 PTQ: ``w ≈ w_q * scale``."""
    q = []
    for w in params:
        w = np.asarray(w, dtype=np.float32)
        max_abs = float(np.max(np.abs(w))) if w.size else 0.0
        scale = np.float32(max_abs / 127.0) if max_abs > 0 else np.float32(1.0)
        w_q = np.clip(np.round(w / scale), -127, 127).astype(np.int8)
        q.append((w_q, scale))
    return q


def dequantize(qparams):
    """Inverse for error analysis: float reconstructions."""
    return [w_q.astype(np.float32) * scale for w_q, scale in qparams]


def quant_error(params, qparams) -> float:
    """Max relative reconstruction error across layers (sanity metric)."""
    errs = []
    for w, wd in zip(params, dequantize(qparams)):
        denom = max(1e-9, float(np.max(np.abs(w))))
        errs.append(float(np.max(np.abs(w - wd))) / denom)
    return max(errs) if errs else 0.0


def sparsity(params: list[np.ndarray]) -> float:
    """Fraction of zero weights across all layers."""
    total = sum(int(np.asarray(w).size) for w in params)
    zeros = sum(int((np.asarray(w) == 0).sum()) for w in params)
    return zeros / max(1, total)
