"""Writer/reader for the ``.mtz`` binary tensor container.

Byte-level twin of ``rust/src/util/tensorfile.rs`` (see its header for the
format). Little-endian, magic ``MTZ1``.
"""

from __future__ import annotations

import struct

import numpy as np

_MAGIC = b"MTZ1"
_DTYPE_TAG = {np.dtype("float32"): 0, np.dtype("int8"): 1, np.dtype("int32"): 2, np.dtype("uint8"): 3}
_TAG_DTYPE = {v: k for k, v in _DTYPE_TAG.items()}


def save(path: str, tensors: dict[str, np.ndarray]) -> None:
    """Write `tensors` to `path` (keys sorted for determinism)."""
    with open(path, "wb") as f:
        f.write(_MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name in sorted(tensors):
            arr = np.ascontiguousarray(tensors[name])
            if arr.dtype not in _DTYPE_TAG:
                raise ValueError(f"{name}: unsupported dtype {arr.dtype}")
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", _DTYPE_TAG[arr.dtype], arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<Q", d))
            f.write(arr.tobytes())


def load(path: str) -> dict[str, np.ndarray]:
    """Read a ``.mtz`` file back into a dict of arrays."""
    with open(path, "rb") as f:
        data = f.read()
    if data[:4] != _MAGIC:
        raise ValueError("bad magic")
    off = 4
    (count,) = struct.unpack_from("<I", data, off)
    off += 4
    out: dict[str, np.ndarray] = {}
    for _ in range(count):
        (nlen,) = struct.unpack_from("<I", data, off)
        off += 4
        name = data[off : off + nlen].decode("utf-8")
        off += nlen
        tag, ndim = struct.unpack_from("<BB", data, off)
        off += 2
        dims = struct.unpack_from(f"<{ndim}Q", data, off)
        off += 8 * ndim
        dtype = _TAG_DTYPE[tag]
        n = int(np.prod(dims)) if ndim else 1
        nbytes = n * dtype.itemsize
        arr = np.frombuffer(data[off : off + nbytes], dtype=dtype).reshape(dims)
        off += nbytes
        out[name] = arr
    if off != len(data):
        raise ValueError("trailing bytes")
    return out
