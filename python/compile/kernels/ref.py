"""Pure-jnp correctness oracles for the Pallas kernels (Layer 1).

These are the bit-for-bit references the pytest suite checks the kernels
against, and they double as the arithmetic definition the rust simulator's
``snn::reference_forward`` mirrors (integer weight sum -> one f32 scale
multiply -> LIF update).
"""

from __future__ import annotations

import jax.numpy as jnp


def c2c_matmul_ref(w_q: jnp.ndarray, spikes: jnp.ndarray, scale) -> jnp.ndarray:
    """Synaptic current through the C2C ladder array (paper eq. 2).

    Args:
      w_q: int8 quantized weights ``[out, in]``.
      spikes: f32 spike vector ``[in]`` (0/1 entries; rate-coded pulses).
      scale: f32 scalar dequantization scale.

    Returns:
      f32 ``[out]`` currents: ``(w_q @ spikes) * scale``.

    The sum is exact in f32 because ``|sum over active w_q| < 2^24`` holds
    for all supported layer widths — matching the ideal analog C2C charge
    sum on the integration capacitor.
    """
    acc = jnp.matmul(w_q.astype(jnp.float32), spikes.astype(jnp.float32))
    return acc * jnp.float32(scale)


def lif_step_ref(
    w_q: jnp.ndarray,
    spikes: jnp.ndarray,
    v: jnp.ndarray,
    scale,
    beta: float,
    v_th: float,
    v_reset: float,
):
    """One discrete-time LIF layer step (the A-NEURON sweep semantics).

    ``v' = beta * v + (w_q @ spikes) * scale``; fire where ``v' >= v_th``;
    fired neurons reset to ``v_reset``.

    Returns ``(spikes_out f32 [out], v_next f32 [out])``.
    """
    cur = c2c_matmul_ref(w_q, spikes, scale)
    v_new = jnp.float32(beta) * v + cur
    fired = (v_new >= v_th).astype(jnp.float32)
    v_next = jnp.where(v_new >= v_th, jnp.float32(v_reset), v_new)
    return fired, v_next
