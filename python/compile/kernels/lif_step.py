"""Layer-1 Pallas kernel: fused C2C-MAC + LIF update for one layer step.

This is the compute hot-spot of the rate-coded SNN (DESIGN.md
§Hardware-Adaptation): an int8-weight x f32-spike matmul fused with the
LIF membrane update so the membrane state never leaves VMEM between the
MAC and the threshold — the TPU rendering of MENAGE's
"C2C ladder next to the SRAM, capacitor inside the A-NEURON" structure.

Tiling: the grid walks output-neuron tiles (the virtual-neuron axis). Each
grid step keeps one `[TILE_OUT, in]` weight tile (int8, the "weight SRAM"),
the full spike vector, and a `[TILE_OUT]` membrane tile (the "capacitor
bank") resident in VMEM. For the paper's largest layer (32768 -> 1000,
int8) a 128-row tile is 128 x 32768 B = 4 MiB — comfortably inside a TPU
core's 16 MiB VMEM alongside the f32 operands.

The kernel MUST be lowered with ``interpret=True`` here: the CPU PJRT
client cannot execute Mosaic custom-calls (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Output-neuron tile (rows of the weight matrix per grid step).
TILE_OUT = 128


def _lif_kernel(w_ref, s_ref, v_ref, scale_ref, beta_ref, th_ref, reset_ref,
                spk_out_ref, v_out_ref):
    """One output tile: cur = (W_tile @ s) * scale; LIF update."""
    w = w_ref[...].astype(jnp.float32)          # [TILE_OUT, in] from int8
    s = s_ref[...]                               # [in]
    acc = jnp.dot(w, s)                          # MXU-shaped contraction
    cur = acc * scale_ref[0]
    v_new = beta_ref[0] * v_ref[...] + cur
    fired = v_new >= th_ref[0]
    spk_out_ref[...] = fired.astype(jnp.float32)
    v_out_ref[...] = jnp.where(fired, reset_ref[0], v_new)


@functools.partial(jax.jit, static_argnames=("interpret",))
def lif_step(w_q, spikes, v, scale, beta, v_th, v_reset, *, interpret: bool = True):
    """Fused LIF layer step.

    Args:
      w_q: int8 ``[out, in]`` quantized weights.
      spikes: f32 ``[in]`` input spike vector.
      v: f32 ``[out]`` membrane potentials.
      scale, beta, v_th, v_reset: f32 scalars (passed as 1-element arrays
        internally so they live in SMEM-like operands).
      interpret: keep True on CPU (Mosaic custom-calls don't run on the
        CPU PJRT client).

    Returns:
      ``(spikes_out f32 [out], v_next f32 [out])``.
    """
    out_dim, in_dim = w_q.shape
    grid = (pl.cdiv(out_dim, TILE_OUT),)
    as1 = lambda x: jnp.asarray([x], dtype=jnp.float32)  # noqa: E731

    return pl.pallas_call(
        _lif_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_OUT, in_dim), lambda i: (i, 0)),  # weight tile
            pl.BlockSpec((in_dim,), lambda i: (0,)),             # full spikes
            pl.BlockSpec((TILE_OUT,), lambda i: (i,)),           # membrane tile
            pl.BlockSpec((1,), lambda i: (0,)),                  # scale
            pl.BlockSpec((1,), lambda i: (0,)),                  # beta
            pl.BlockSpec((1,), lambda i: (0,)),                  # v_th
            pl.BlockSpec((1,), lambda i: (0,)),                  # v_reset
        ],
        out_specs=[
            pl.BlockSpec((TILE_OUT,), lambda i: (i,)),
            pl.BlockSpec((TILE_OUT,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((out_dim,), jnp.float32),
            jax.ShapeDtypeStruct((out_dim,), jnp.float32),
        ],
        interpret=interpret,
    )(w_q, spikes, v, as1(scale), as1(beta), as1(v_th), as1(v_reset))
