"""Layer-1 Pallas kernel: standalone C2C-ladder matmul (paper eq. 2).

Computes the synaptic currents ``(w_q @ spikes) * scale`` through the
bit-decomposed C2C transfer function: each weight is reconstructed as
``sign(w) * sum_i bit_i(|w|) * 2^(i-8) * 256`` — numerically identical to
``w`` for ideal ladders, but written so a per-bit mismatch vector can be
injected to study capacitor-mismatch sensitivity (the `bit_gain` operand;
ones = ideal).

Used by the ablation benches and the pytest suite; the production model
path uses the fused `lif_step` kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_OUT = 128
NBITS = 8


def _c2c_kernel(w_ref, s_ref, scale_ref, gain_ref, out_ref):
    w = w_ref[...].astype(jnp.float32)  # [tile, in]
    s = s_ref[...]
    sign = jnp.sign(w)
    mag = jnp.abs(w)
    # Bit-decompose |w| through the ladder: sum_i bit_i * 2^(i-8) * gain_i.
    acc = jnp.zeros_like(w)
    for i in range(NBITS):
        bit = jnp.floor(mag / (2.0 ** i)) % 2.0
        acc = acc + bit * (2.0 ** (i - NBITS)) * gain_ref[i]
    w_eff = sign * acc * (2.0 ** NBITS)  # back to weight units
    out_ref[...] = jnp.dot(w_eff, s) * scale_ref[0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def c2c_matmul(w_q, spikes, scale, bit_gain=None, *, interpret: bool = True):
    """C2C-ladder synaptic current.

    Args:
      w_q: int8 ``[out, in]``.
      spikes: f32 ``[in]``.
      scale: f32 scalar.
      bit_gain: optional f32 ``[8]`` per-bit ladder gains (ones = ideal
        C2C; perturb to model capacitor mismatch).
      interpret: keep True on CPU.

    Returns:
      f32 ``[out]`` currents.
    """
    out_dim, in_dim = w_q.shape
    if bit_gain is None:
        bit_gain = jnp.ones((NBITS,), jnp.float32)
    grid = (pl.cdiv(out_dim, TILE_OUT),)
    return pl.pallas_call(
        _c2c_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_OUT, in_dim), lambda i: (i, 0)),
            pl.BlockSpec((in_dim,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((NBITS,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((TILE_OUT,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((out_dim,), jnp.float32),
        interpret=interpret,
    )(w_q, spikes, jnp.asarray([scale], jnp.float32), bit_gain)
