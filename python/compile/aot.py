"""AOT compile path: train → prune → quantize → export (Algorithm 1).

Artifacts written to ``--out`` (default ``../artifacts``):

* ``<model>.hlo.txt``     — the quantized inference function (Pallas-fused,
  weights baked as constants) lowered to HLO **text** — the interchange
  format the rust runtime's xla_extension 0.5.1 accepts (jax ≥ 0.5 emits
  protos with 64-bit ids that it rejects; the text parser reassigns ids —
  see /opt/xla-example/README.md).
* ``<model>.weights.mtz`` — quantized weights + scales + LIF metadata, read
  by the rust mapper (`QuantNetwork::from_tensorfile`).
* ``<model>.eval.mtz``    — the held-out synthetic eval split (events,
  labels) plus the JAX model's own predictions, so rust can cross-check
  the simulator and the PJRT golden model on identical inputs.
* ``manifest.json``       — summary (accuracies, sparsity, shapes).

Python runs ONCE at build time; the rust binary is self-contained after.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import mtz
from . import train as trainmod
from .model import (
    BETA,
    V_RESET,
    V_TH,
    densify_qparams,
    make_inference_fn,
    snn_forward_quant,
)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the default printer elides big literals
    # as `constant({...})`, which would not round-trip through the rust
    # text parser — the baked weights must be printed in full.
    return comp.as_hlo_text(print_large_constants=True)


def export_model(name: str, result: dict, out_dir: str, log=print) -> dict:
    cfg = result["config"]
    qparams = result["qparams"]
    convs = result.get("conv_specs") or (None,) * len(qparams)
    os.makedirs(out_dir, exist_ok=True)

    # --- weights for the rust mapper -------------------------------------
    # Conv layers ship compressed: the kernel `k{i}` [oc,ic,kh,kw] plus its
    # geometry `conv{i}` [in_h,in_w,stride,padding] — the rust mapper
    # re-expands rows on demand, so the dense matrix never hits the wire.
    tensors: dict[str, np.ndarray] = {
        "meta_lif": np.asarray([BETA, V_TH, V_RESET], np.float32),
        "meta_timesteps": np.asarray([cfg.timesteps], np.int32),
    }
    for i, ((w_q, scale), spec) in enumerate(zip(qparams, convs)):
        if spec is not None:
            tensors[f"k{i}"] = np.asarray(w_q, np.int8).reshape(spec.kernel_shape)
            tensors[f"conv{i}"] = np.asarray(
                [spec.in_h, spec.in_w, spec.stride, spec.padding], np.int32
            )
        else:
            tensors[f"w{i}"] = w_q
        tensors[f"scale{i}"] = np.asarray([scale], np.float32)
    wpath = os.path.join(out_dir, f"{name}.weights.mtz")
    mtz.save(wpath, tensors)
    log(f"[aot] wrote {wpath}")

    # --- eval split + golden predictions ---------------------------------
    # Golden checks and the HLO lowering run on the dense expansion (the
    # same oracle the rust side pins its compressed path against).
    qp = [
        (jnp.asarray(w), jnp.float32(s)) for w, s in densify_qparams(qparams, convs)
    ]

    @jax.jit
    def golden_counts(e):
        counts, _ = snn_forward_quant(qp, e, use_pallas=False)
        return counts

    xs, ys = result["eval_x"], result["eval_y"]
    counts = np.stack(
        [np.asarray(golden_counts(jnp.asarray(x, jnp.float32))) for x in xs]
    )
    epath = os.path.join(out_dir, f"{name}.eval.mtz")
    mtz.save(
        epath,
        {
            "events": xs.astype(np.uint8),
            "labels": ys.astype(np.int32),
            "golden_counts": counts.astype(np.float32),
        },
    )
    log(f"[aot] wrote {epath}")

    # --- HLO text of the Pallas-fused inference function -----------------
    infer = make_inference_fn(qp, use_pallas=True, interpret=True)
    spec = jax.ShapeDtypeStruct((cfg.timesteps, cfg.layer_sizes[0]), jnp.float32)
    lowered = jax.jit(infer).lower(spec)
    hlo = to_hlo_text(lowered)
    hpath = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(hpath, "w") as f:
        f.write(hlo)
    log(f"[aot] wrote {hpath} ({len(hlo)/1e6:.1f} MB)")

    return {
        "name": name,
        "layer_sizes": list(cfg.layer_sizes),
        "timesteps": cfg.timesteps,
        "stored_weights": sum(int(np.asarray(w).size) for w, _ in qparams),
        "conv_layers": [i for i, s in enumerate(convs) if s is not None],
        "acc_dense": result["acc_dense"],
        "acc_quant": result["acc_quant"],
        "eval_samples": int(len(ys)),
        "hlo": os.path.basename(hpath),
        "weights": os.path.basename(wpath),
        "eval": os.path.basename(epath),
    }


MODELS = {
    "nmnist": trainmod.nmnist_quick,
    "cifar_small": trainmod.cifar_small_quick,
    "cifar_conv": trainmod.cifar_conv_quick,
}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default="nmnist,cifar_small")
    ap.add_argument("--steps", type=int, default=None, help="override train steps")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    manifest = {}
    for name in args.models.split(","):
        name = name.strip()
        if name not in MODELS:
            sys.exit(f"unknown model {name!r}; have {sorted(MODELS)}")
        cfg = MODELS[name]()
        cfg.seed = args.seed
        if args.steps is not None:
            cfg.steps = args.steps
        result = trainmod.run(cfg)
        manifest[name] = export_model(name, result, args.out)

    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"[aot] wrote {mpath}")


if __name__ == "__main__":
    main()
