"""Synthetic event-stream datasets (python twin of rust `datasets.rs`).

Same design as the rust generators — seven-segment digit saccades for the
N-MNIST stand-in, drifting oriented gratings for the CIFAR10-DVS stand-in —
with matched *statistics* (the training pipeline does not need bit-identical
streams with rust; cross-language identity is provided instead by exporting
the evaluation split to ``artifacts/*.eval.mtz``, which both sides read).
"""

from __future__ import annotations

import dataclasses

import numpy as np

_SEGMENTS = np.array(
    [
        # a  b  c  d  e  f  g
        [1, 1, 1, 1, 1, 1, 0],  # 0
        [0, 1, 1, 0, 0, 0, 0],  # 1
        [1, 1, 0, 1, 1, 0, 1],  # 2
        [1, 1, 1, 1, 0, 0, 1],  # 3
        [0, 1, 1, 0, 0, 1, 1],  # 4
        [1, 0, 1, 1, 0, 1, 1],  # 5
        [1, 0, 1, 1, 1, 1, 1],  # 6
        [1, 1, 1, 0, 0, 0, 0],  # 7
        [1, 1, 1, 1, 1, 1, 1],  # 8
        [1, 1, 1, 1, 0, 1, 1],  # 9
    ],
    dtype=bool,
)


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    """Geometry + rate parameters of one synthetic dataset."""

    name: str
    side: int
    noise_rate: float
    signal_rate: float

    @property
    def input_dim(self) -> int:
        return self.side * self.side * 2

    num_classes: int = 10


NMNIST = DatasetSpec(name="nmnist_syn", side=34, noise_rate=0.0015, signal_rate=0.35)
CIFAR10DVS = DatasetSpec(name="cifar10dvs_syn", side=128, noise_rate=0.004, signal_rate=0.55)
CIFAR10DVS_SMALL = DatasetSpec(
    name="cifar10dvs_small_syn", side=32, noise_rate=0.004, signal_rate=0.55
)


def digit_template(label: int, side: int) -> np.ndarray:
    """Seven-segment digit raster in [0,1], shape [side, side]."""
    img = np.zeros((side, side), dtype=np.float32)
    segs = _SEGMENTS[label]
    x0, x1 = side // 4, side - side // 4 - 1
    y0, y1 = side // 6, side - side // 6 - 1
    ym = (y0 + y1) // 2
    w = 2
    if segs[0]:
        img[y0 : y0 + w, x0 : x1 + 1] = 1.0
    if segs[3]:
        img[y1 - w + 1 : y1 + 1, x0 : x1 + 1] = 1.0
    if segs[6]:
        img[ym : ym + w, x0 : x1 + 1] = 1.0
    if segs[5]:
        img[y0 : ym + 1, x0 : x0 + w] = 1.0
    if segs[1]:
        img[y0 : ym + 1, x1 - w + 1 : x1 + 1] = 1.0
    if segs[4]:
        img[ym : y1 + 1, x0 : x0 + w] = 1.0
    if segs[2]:
        img[ym : y1 + 1, x1 - w + 1 : x1 + 1] = 1.0
    return img


def _shift(img: np.ndarray, ox: int, oy: int) -> np.ndarray:
    """Zero-padded integer shift."""
    out = np.zeros_like(img)
    side = img.shape[0]
    xs = slice(max(0, ox), min(side, side + ox))
    xd = slice(max(0, -ox), min(side, side - ox))
    ys = slice(max(0, oy), min(side, side + oy))
    yd = slice(max(0, -oy), min(side, side - oy))
    out[ys, xs] = img[yd, xd]
    return out


def gen_nmnist(spec: DatasetSpec, label: int, timesteps: int, rng: np.random.Generator) -> np.ndarray:
    """Synthetic N-MNIST sample: bool events ``[T, side*side*2]``."""
    side = spec.side
    template = digit_template(label, side)
    saccades = [(1, 0), (0, 1), (-1, -1)]
    per_phase = max(1, (timesteps + 2) // 3)
    events = np.zeros((timesteps, spec.input_dim), dtype=bool)
    for t in range(timesteps):
        phase = min(t // per_phase, 2)
        dx, dy = saccades[phase]
        tp = (t % per_phase) - per_phase // 2
        ox, oy = dx * tp // 3, dy * tp // 3
        here = _shift(template, ox, oy)
        ahead = _shift(template, ox - dx, oy - dy)
        diff = here - ahead
        p_on = spec.noise_rate + spec.signal_rate * np.clip(diff, 0, None) + 0.03 * here
        p_off = spec.noise_rate + spec.signal_rate * np.clip(-diff, 0, None)
        u = rng.random((2, side, side))
        on = u[0] < np.minimum(p_on, 0.95)
        off = u[1] < np.minimum(p_off, 0.95)
        events[t, : side * side] = on.ravel()
        events[t, side * side :] = off.ravel()
    return events


def gen_dvs_texture(
    spec: DatasetSpec, label: int, timesteps: int, rng: np.random.Generator
) -> np.ndarray:
    """Synthetic CIFAR10-DVS sample: bool events ``[T, side*side*2]``."""
    side = spec.side
    angle = label * np.pi / 10.0
    freq = 2.0 + (label % 5) * 1.5
    harmonic = 2.0 if label % 2 == 0 else 3.0
    c, s = np.cos(angle), np.sin(angle)
    vx, vy = rng.uniform(-1.5, 1.5, 2)
    phase0 = rng.uniform(0, 2 * np.pi)
    yy, xx = np.mgrid[0:side, 0:side].astype(np.float32)
    events = np.zeros((timesteps, spec.input_dim), dtype=bool)

    def grating(t):
        xf = (xx + vx * t) / side
        yf = (yy + vy * t) / side
        u = c * xf + s * yf
        v = -s * xf + c * yf
        return np.sin(2 * np.pi * freq * u + phase0) + 0.5 * np.sin(
            2 * np.pi * freq * harmonic * v
        )

    for t in range(timesteps):
        # Temporal derivative of the drifting grating creates the events.
        d = grating(t + 1) - grating(t)
        p_on = spec.noise_rate + spec.signal_rate * np.clip(d, 0, None)
        p_off = spec.noise_rate + spec.signal_rate * np.clip(-d, 0, None)
        u = rng.random((2, side, side))
        events[t, : side * side] = (u[0] < np.minimum(p_on, 0.95)).ravel()
        events[t, side * side :] = (u[1] < np.minimum(p_off, 0.95)).ravel()
    return events


def generate_split(
    spec: DatasetSpec, n: int, timesteps: int, seed: int
) -> tuple[np.ndarray, np.ndarray]:
    """Balanced split: ``(events bool [n, T, dim], labels int64 [n])``."""
    rng = np.random.default_rng(seed)
    gen = gen_nmnist if spec.side == 34 else gen_dvs_texture
    xs = np.zeros((n, timesteps, spec.input_dim), dtype=bool)
    ys = np.zeros((n,), dtype=np.int64)
    for i in range(n):
        label = i % spec.num_classes
        xs[i] = gen(spec, label, timesteps, rng)
        ys[i] = label
    return xs, ys
