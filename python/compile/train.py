"""Algorithm 1 steps 1–3: train, prune, quantize, extract (build-time only).

Trains the paper's MLP SNNs with surrogate-gradient BPTT (JAX twin of the
SNNTorch flow) on the synthetic event datasets, applies L1 pruning + 8-bit
PTQ, and reports accuracy before/after (Table I's accuracy rows).

optax is unavailable offline, so a minimal Adam lives here.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data as datamod
from . import quantize as q
from .model import (
    ConvSpec,
    batched_loss,
    densify_qparams,
    grad_fn,
    init_conv_params,
    init_params,
    make_train_fns,
    predict_train,
    snn_forward_quant,
)


@dataclasses.dataclass
class TrainConfig:
    layer_sizes: tuple
    timesteps: int
    train_samples: int
    test_samples: int
    batch: int
    steps: int
    lr: float = 1e-3
    prune_frac: float = 0.5
    seed: int = 0
    # init_params gain: >1 keeps deep spiking nets alive (see model.py).
    init_gain: float = 1.0
    # masked fine-tuning steps after pruning (recovers most of the drop).
    finetune_steps: int = 60
    # Per-layer ConvSpec-or-None; empty tuple = all dense. Conv layers
    # train a shared kernel and export compressed (k{i} + conv{i}).
    conv_specs: tuple = ()


def nmnist_quick() -> TrainConfig:
    """Quick preset: full N-MNIST topology, small synthetic corpus."""
    return TrainConfig(
        layer_sizes=(2312, 200, 100, 40, 10),
        timesteps=20,
        train_samples=240,
        test_samples=80,
        batch=16,
        steps=180,
    )


def cifar_small_quick() -> TrainConfig:
    """Quick preset: scaled-down CIFAR10-DVS topology (32×32 input)."""
    return TrainConfig(
        layer_sizes=(2048, 1000, 500, 200, 100, 10),
        timesteps=16,
        train_samples=200,
        test_samples=80,
        batch=8,
        steps=250,
        lr=5e-4,
        init_gain=3.0,
    )


def cifar_conv_quick() -> TrainConfig:
    """Quick preset: compressed conv stack on the 32×32 CIFAR10-DVS stand-in
    (2×32×32 → 8×16×16 → 8×8×8 → 10), mirroring rust `cifar_conv_specs()`.
    The two conv layers store 144 + 576 kernel taps instead of the 4.2M +
    1.0M dense entries their expansions would occupy."""
    c1 = ConvSpec(
        in_channels=2, in_h=32, in_w=32, out_channels=8,
        kernel_h=3, kernel_w=3, stride=2, padding=1,
    )
    c2 = ConvSpec(
        in_channels=8, in_h=16, in_w=16, out_channels=8,
        kernel_h=3, kernel_w=3, stride=2, padding=1,
    )
    return TrainConfig(
        layer_sizes=(2048, 2048, 512, 10),
        timesteps=16,
        train_samples=200,
        test_samples=80,
        batch=8,
        steps=250,
        lr=5e-4,
        init_gain=2.0,
        # Kernels are already tiny and every tap is shared across tiles —
        # pruning them trades disproportionate accuracy for nothing.
        prune_frac=0.2,
        conv_specs=(c1, c2, None),
    )


def spec_for(cfg: TrainConfig) -> datamod.DatasetSpec:
    dim = cfg.layer_sizes[0]
    for spec in (datamod.NMNIST, datamod.CIFAR10DVS, datamod.CIFAR10DVS_SMALL):
        if spec.input_dim == dim:
            return spec
    raise ValueError(f"no dataset spec with input dim {dim}")


class Adam:
    """Minimal Adam over a list of arrays."""

    def __init__(self, params, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
        self.lr, self.b1, self.b2, self.eps = lr, b1, b2, eps
        self.m = [jnp.zeros_like(p) for p in params]
        self.v = [jnp.zeros_like(p) for p in params]
        self.t = 0

    def step(self, params, grads):
        self.t += 1
        out = []
        for i, (p, g) in enumerate(zip(params, grads)):
            self.m[i] = self.b1 * self.m[i] + (1 - self.b1) * g
            self.v[i] = self.b2 * self.v[i] + (1 - self.b2) * g * g
            mhat = self.m[i] / (1 - self.b1 ** self.t)
            vhat = self.v[i] / (1 - self.b2 ** self.t)
            out.append(p - self.lr * mhat / (jnp.sqrt(vhat) + self.eps))
        return out


def accuracy_train_view(params, xs, ys, batch=32, predict=predict_train) -> float:
    correct = 0
    for i in range(0, len(xs), batch):
        xb = jnp.asarray(xs[i : i + batch], jnp.float32)
        pred = predict(params, xb)
        correct += int((np.asarray(pred) == ys[i : i + batch]).sum())
    return correct / len(xs)


def accuracy_quant_view(qparams, xs, ys, convs=None) -> float:
    """Quantized-inference accuracy (jnp oracle path, no pallas — fast).
    Conv kernels are densified first — the rust `expand_conv` oracle."""
    qp = [(jnp.asarray(w), jnp.float32(s)) for w, s in densify_qparams(qparams, convs)]

    @jax.jit
    def pred(e):
        counts, _ = snn_forward_quant(qp, e, use_pallas=False)
        return counts.argmax()

    correct = 0
    for x, y in zip(xs, ys):
        correct += int(pred(jnp.asarray(x, jnp.float32)) == y)
    return correct / len(xs)


def run(cfg: TrainConfig, log=print) -> dict:
    """Full Algorithm-1 pipeline. Returns a result dict with params,
    qparams, accuracies, and the eval split."""
    spec = spec_for(cfg)
    log(f"[train] dataset={spec.name} layers={cfg.layer_sizes} T={cfg.timesteps}")
    t0 = time.time()
    xs_tr, ys_tr = datamod.generate_split(spec, cfg.train_samples, cfg.timesteps, cfg.seed)
    xs_te, ys_te = datamod.generate_split(
        spec, cfg.test_samples, cfg.timesteps, cfg.seed + 10_000
    )
    log(f"[train] data generated in {time.time()-t0:.1f}s "
        f"(train rate {xs_tr.mean():.4f})")

    key = jax.random.PRNGKey(cfg.seed)
    convs = tuple(cfg.conv_specs) if cfg.conv_specs else None
    if convs:
        params = init_conv_params(cfg.layer_sizes, convs, key, gain=cfg.init_gain)
        step_grad, predict = make_train_fns(convs)
        stored = sum(int(np.asarray(p).size) for p in params)
        dense = sum(a * b for a, b in zip(cfg.layer_sizes[1:], cfg.layer_sizes[:-1]))
        log(f"[train] compressed conv stack: {stored} stored weights "
            f"(dense expansion would store {dense})")
    else:
        params = init_params(cfg.layer_sizes, key, gain=cfg.init_gain)
        step_grad, predict = grad_fn, predict_train
    opt = Adam(params, lr=cfg.lr)
    rng = np.random.default_rng(cfg.seed)
    t0 = time.time()
    losses = []
    for step in range(cfg.steps):
        idx = rng.integers(0, len(xs_tr), cfg.batch)
        xb = jnp.asarray(xs_tr[idx], jnp.float32)
        yb = jnp.asarray(ys_tr[idx])
        loss, grads = step_grad(params, xb, yb)
        params = opt.step(params, grads)
        losses.append(float(loss))
        if step % 25 == 0 or step == cfg.steps - 1:
            log(f"[train] step {step:4d} loss {float(loss):.4f} "
                f"({time.time()-t0:.0f}s)")

    acc_dense = accuracy_train_view(params, xs_te, ys_te, predict=predict)
    log(f"[train] dense accuracy: {acc_dense:.4f}")

    # Prune + quantize (Algorithm 1 step 2), with masked fine-tuning to
    # recover the pruning drop (zeros stay zero).
    pruned = q.prune_l1([np.asarray(p) for p in params], cfg.prune_frac)
    if cfg.finetune_steps > 0:
        masks = [jnp.asarray((w != 0).astype(np.float32)) for w in pruned]
        ft_params = [jnp.asarray(w) for w in pruned]
        ft_opt = Adam(ft_params, lr=cfg.lr * 0.5)
        for step in range(cfg.finetune_steps):
            idx = rng.integers(0, len(xs_tr), cfg.batch)
            xb = jnp.asarray(xs_tr[idx], jnp.float32)
            yb = jnp.asarray(ys_tr[idx])
            _, grads = step_grad(ft_params, xb, yb)
            ft_params = ft_opt.step(ft_params, grads)
            ft_params = [p * m for p, m in zip(ft_params, masks)]
        pruned = [np.asarray(p) for p in ft_params]
        log(f"[train] fine-tuned {cfg.finetune_steps} steps after pruning")
    qparams = q.quantize_int8(pruned)
    acc_quant = accuracy_quant_view(qparams, xs_te, ys_te, convs)
    log(f"[train] pruned+quantized accuracy: {acc_quant:.4f} "
        f"(sparsity {q.sparsity(pruned):.2f}, "
        f"qerr {q.quant_error(pruned, qparams):.4f})")

    return {
        "config": cfg,
        "spec": spec,
        "conv_specs": convs,
        "params": [np.asarray(p) for p in params],
        "qparams": qparams,
        "acc_dense": acc_dense,
        "acc_quant": acc_quant,
        "losses": losses,
        "eval_x": xs_te,
        "eval_y": ys_te,
    }
