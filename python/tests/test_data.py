"""Synthetic dataset tests: shapes, sparsity calibration, class signal."""

import numpy as np

from compile import data as D


def test_dims_match_paper_models():
    assert D.NMNIST.input_dim == 2312  # 34*34*2
    assert D.CIFAR10DVS.input_dim == 32768  # 128*128*2
    assert D.CIFAR10DVS_SMALL.input_dim == 2048


def test_split_shapes_and_balance():
    xs, ys = D.generate_split(D.NMNIST, 30, 6, seed=1)
    assert xs.shape == (30, 6, 2312) and xs.dtype == bool
    assert ys.shape == (30,)
    for c in range(10):
        assert (ys == c).sum() == 3


def test_determinism():
    a, _ = D.generate_split(D.NMNIST, 5, 4, seed=7)
    b, _ = D.generate_split(D.NMNIST, 5, 4, seed=7)
    assert (a == b).all()
    c, _ = D.generate_split(D.NMNIST, 5, 4, seed=8)
    assert (a != c).any()


def test_nmnist_sparser_than_cifar():
    nm, _ = D.generate_split(D.NMNIST, 10, 8, seed=2)
    cf, _ = D.generate_split(D.CIFAR10DVS_SMALL, 10, 8, seed=2)
    assert cf.mean() > 2.0 * nm.mean(), (cf.mean(), nm.mean())
    assert 0.001 < nm.mean() < 0.2
    assert cf.mean() < 0.5


def test_templates_distinct():
    t = [D.digit_template(c, 34) for c in range(10)]
    for i in range(10):
        for j in range(i + 1, 10):
            assert np.abs(t[i] - t[j]).sum() > 10.0


def test_classes_have_signal():
    """Per-class mean event maps must be distinguishable."""
    xs, ys = D.generate_split(D.NMNIST, 40, 6, seed=3)
    means = np.stack([xs[ys == c].mean(axis=(0, 1)) for c in (0, 1)])
    cos = (means[0] @ means[1]) / (
        np.linalg.norm(means[0]) * np.linalg.norm(means[1]) + 1e-9
    )
    assert cos < 0.95, f"classes 0/1 too similar: {cos}"


def test_events_are_sparse_bool_with_both_polarities():
    xs, _ = D.generate_split(D.CIFAR10DVS_SMALL, 5, 5, seed=4)
    side2 = 32 * 32
    on = xs[..., :side2].sum()
    off = xs[..., side2:].sum()
    assert on > 0 and off > 0
