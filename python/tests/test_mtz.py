"""`.mtz` container: roundtrip + binary-format invariants shared with rust."""

import numpy as np
import pytest

from compile import mtz


def _sample():
    return {
        "w0": np.arange(-3, 3, dtype=np.int8).reshape(2, 3),
        "scale0": np.asarray([0.03125], np.float32),
        "counts": np.asarray([0, -1, 2**31 - 1, 7], np.int32),
        "mask": np.asarray([[0, 1], [1, 0]], np.uint8),
    }


def test_roundtrip(tmp_path):
    p = str(tmp_path / "t.mtz")
    mtz.save(p, _sample())
    back = mtz.load(p)
    for k, v in _sample().items():
        assert back[k].dtype == v.dtype
        assert (back[k] == v).all()


def test_header_layout(tmp_path):
    """Pin the exact byte layout rust's tensorfile.rs parses."""
    p = str(tmp_path / "one.mtz")
    mtz.save(p, {"a": np.asarray([5], np.int8)})
    raw = open(p, "rb").read()
    assert raw[:4] == b"MTZ1"
    assert raw[4:8] == (1).to_bytes(4, "little")  # tensor count
    assert raw[8:12] == (1).to_bytes(4, "little")  # name length
    assert raw[12:13] == b"a"
    assert raw[13] == 1  # dtype tag i8
    assert raw[14] == 1  # ndim
    assert raw[15:23] == (1).to_bytes(8, "little")  # dim
    assert raw[23:] == b"\x05"


def test_rejects_bad_magic(tmp_path):
    p = str(tmp_path / "bad.mtz")
    open(p, "wb").write(b"XXXX")
    with pytest.raises(ValueError):
        mtz.load(p)


def test_rejects_unsupported_dtype(tmp_path):
    with pytest.raises(ValueError):
        mtz.save(str(tmp_path / "x.mtz"), {"f64": np.zeros(2, np.float64)})


def test_empty_tensor(tmp_path):
    p = str(tmp_path / "e.mtz")
    mtz.save(p, {"e": np.zeros((0, 5), np.float32)})
    back = mtz.load(p)
    assert back["e"].shape == (0, 5)
