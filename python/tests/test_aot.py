"""End-to-end AOT export test (tiny budget): train a few steps, export all
artifacts, reload, and verify the golden counts self-consistently."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import mtz
from compile import train as T
from compile.aot import export_model, to_hlo_text
from compile.model import make_inference_fn, snn_forward_quant


@pytest.fixture(scope="module")
def tiny_result():
    cfg = T.TrainConfig(
        layer_sizes=(2312, 32, 10),
        timesteps=6,
        train_samples=20,
        test_samples=10,
        batch=4,
        steps=3,
    )
    return T.run(cfg, log=lambda *a: None)


def test_export_writes_all_artifacts(tiny_result, tmp_path):
    out = str(tmp_path)
    meta = export_model("tiny", tiny_result, out, log=lambda *a: None)
    for key in ("hlo", "weights", "eval"):
        assert os.path.exists(os.path.join(out, meta[key]))
    assert meta["layer_sizes"] == [2312, 32, 10]

    # Weights reload consistently.
    w = mtz.load(os.path.join(out, meta["weights"]))
    assert w["meta_timesteps"][0] == 6
    assert w["w0"].shape == (32, 2312)
    assert w["w0"].dtype == np.int8
    assert np.allclose(w["meta_lif"], [0.9, 1.0, 0.0])

    # Eval golden counts match re-running the quantized model.
    ev = mtz.load(os.path.join(out, meta["eval"]))
    qp = [
        (jnp.asarray(w[f"w{i}"]), jnp.float32(w[f"scale{i}"][0])) for i in range(2)
    ]
    x0 = jnp.asarray(ev["events"][0], jnp.float32)
    counts, _ = snn_forward_quant(qp, x0, use_pallas=False)
    assert_allclose(np.asarray(counts), ev["golden_counts"][0], atol=0)


def test_hlo_text_is_loadable_format(tiny_result, tmp_path):
    """The HLO text must start with an HloModule header and bake weights
    (single parameter: the event raster)."""
    meta = export_model("tiny2", tiny_result, str(tmp_path), log=lambda *a: None)
    hlo = open(os.path.join(str(tmp_path), meta["hlo"])).read()
    assert hlo.startswith("HloModule")
    # Entry layout must have exactly one input (the event raster) — weights
    # are baked as constants. Nested computations (scan bodies) legitimately
    # have more parameters, so inspect the entry layout line only.
    header = hlo.splitlines()[0]
    assert "entry_computation_layout={(f32[6,2312]" in header, header
    assert header.count("f32[6,2312]") == 1


def test_pallas_and_oracle_paths_agree_on_eval(tiny_result):
    qp = [(jnp.asarray(w), jnp.float32(s)) for w, s in tiny_result["qparams"]]
    x = jnp.asarray(tiny_result["eval_x"][0], jnp.float32)
    c_pal, _ = snn_forward_quant(qp, x, use_pallas=True)
    c_ref, _ = snn_forward_quant(qp, x, use_pallas=False)
    assert_allclose(np.asarray(c_pal), np.asarray(c_ref), atol=0)


def test_lowered_hlo_executes_same_counts(tiny_result):
    """Execute the jitted inference fn and compare with the oracle — the
    same numbers the rust PJRT runtime must see."""
    qp = [(jnp.asarray(w), jnp.float32(s)) for w, s in tiny_result["qparams"]]
    infer = jax.jit(make_inference_fn(qp))
    x = jnp.asarray(tiny_result["eval_x"][1], jnp.float32)
    counts, _ = infer(x)
    ref, _ = snn_forward_quant(qp, x, use_pallas=False)
    assert_allclose(np.asarray(counts), np.asarray(ref), atol=0)
