"""L2 model tests: shapes, surrogate gradients, quantized-path agreement."""

import jax
import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from compile.model import (
    ConvSpec,
    batched_loss,
    densify_qparams,
    expand_conv,
    grad_fn,
    init_conv_params,
    init_params,
    make_inference_fn,
    make_train_fns,
    snn_forward_quant,
    snn_forward_train,
)
from compile.quantize import prune_l1, quantize_int8

SIZES = (50, 24, 10)


def _events(t=6, dim=50, rate=0.3, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray((rng.random((t, dim)) < rate).astype(np.float32))


def test_train_forward_shapes():
    params = init_params(SIZES, jax.random.PRNGKey(0))
    assert [p.shape for p in params] == [(24, 50), (10, 24)]
    logits, spikes = snn_forward_train(params, _events())
    assert logits.shape == (10,)
    assert spikes.shape == (6, 10)
    assert float(logits.sum()) == float(spikes.sum())


def test_surrogate_gradients_flow():
    params = init_params(SIZES, jax.random.PRNGKey(1), w_std=0.5)
    xb = jnp.stack([_events(seed=s) for s in range(4)])
    yb = jnp.asarray([0, 1, 2, 3])
    loss, grads = grad_fn(params, xb, yb)
    assert np.isfinite(float(loss))
    # Surrogate must produce non-zero gradients in every layer.
    for g in grads:
        assert float(jnp.abs(g).max()) > 0.0, "dead gradient"


def test_loss_decreases_on_overfit():
    """A few gradient steps on one batch must reduce the loss."""
    params = init_params(SIZES, jax.random.PRNGKey(2), w_std=0.5)
    xb = jnp.stack([_events(seed=s, rate=0.4) for s in range(4)])
    yb = jnp.asarray([1, 3, 5, 7])
    l0 = float(batched_loss(params, xb, yb))
    for _ in range(30):
        _, grads = grad_fn(params, xb, yb)
        params = [p - 0.5 * g for p, g in zip(params, grads)]
    l1 = float(batched_loss(params, xb, yb))
    assert l1 < l0, f"{l1} >= {l0}"


def _qparams(seed=3):
    params = init_params(SIZES, jax.random.PRNGKey(seed), w_std=0.5)
    qs = quantize_int8(prune_l1([np.asarray(p) for p in params], 0.3))
    return [(jnp.asarray(w), jnp.float32(s)) for w, s in qs]


def test_quant_forward_pallas_equals_oracle():
    qp = _qparams()
    ev = _events(rate=0.5)
    c_pal, s_pal = snn_forward_quant(qp, ev, use_pallas=True)
    c_ref, s_ref = snn_forward_quant(qp, ev, use_pallas=False)
    assert_allclose(np.asarray(c_pal), np.asarray(c_ref), atol=0)
    assert_allclose(np.asarray(s_pal), np.asarray(s_ref), atol=0)


def test_inference_fn_closure_matches_direct():
    qp = _qparams(4)
    ev = _events(seed=9)
    fn = make_inference_fn(qp)
    c1, _ = jax.jit(fn)(ev)
    c2, _ = snn_forward_quant(qp, ev, use_pallas=True)
    assert_allclose(np.asarray(c1), np.asarray(c2), atol=0)


def test_quant_forward_deterministic():
    qp = _qparams(5)
    ev = _events(seed=11)
    a, _ = snn_forward_quant(qp, ev, use_pallas=False)
    b, _ = snn_forward_quant(qp, ev, use_pallas=False)
    assert_allclose(np.asarray(a), np.asarray(b), atol=0)


def test_silent_input_no_spikes():
    qp = _qparams(6)
    ev = jnp.zeros((5, 50), jnp.float32)
    counts, spikes = snn_forward_quant(qp, ev, use_pallas=False)
    assert float(np.asarray(counts).sum()) == 0.0
    assert float(np.asarray(spikes).sum()) == 0.0


# ---------------------------------------------------------------------------
# Compressed conv layers.
# ---------------------------------------------------------------------------

_CONV = ConvSpec(
    in_channels=2, in_h=6, in_w=6, out_channels=3,
    kernel_h=3, kernel_w=3, stride=2, padding=1,
)


def test_expand_conv_matches_manual_enumeration():
    """Densified matrix must follow the rust snn.rs index math exactly."""
    rng = np.random.default_rng(7)
    s = _CONV
    k = rng.integers(-5, 6, s.kernel_shape).astype(np.int8)
    dense = np.asarray(expand_conv(k, s))
    assert dense.shape == (s.out_dim, s.in_dim)
    want = np.zeros_like(dense)
    for oc in range(s.out_channels):
        for oy in range(s.out_h):
            for ox in range(s.out_w):
                for ic in range(s.in_channels):
                    for ky in range(s.kernel_h):
                        for kx in range(s.kernel_w):
                            iy = oy * s.stride + ky - s.padding
                            ix = ox * s.stride + kx - s.padding
                            if 0 <= iy < s.in_h and 0 <= ix < s.in_w:
                                dst = (oc * s.out_h + oy) * s.out_w + ox
                                src = (ic * s.in_h + iy) * s.in_w + ix
                                want[dst, src] = k[oc, ic, ky, kx]
    assert (dense == want).all()


def test_conv_train_equals_dense_expansion():
    """Conv training forward == dense forward on the expanded matrix."""
    convs = (_CONV, None)
    sizes = (_CONV.in_dim, _CONV.out_dim, 4)
    params = init_conv_params(sizes, convs, jax.random.PRNGKey(3), gain=2.0)
    assert params[0].shape == _CONV.kernel_shape
    ev = _events(dim=_CONV.in_dim, rate=0.4, seed=5)
    logits_c, _ = snn_forward_train(params, ev, convs)
    dense = [expand_conv(params[0], _CONV), params[1]]
    logits_d, _ = snn_forward_train(dense, ev)
    assert_allclose(np.asarray(logits_c), np.asarray(logits_d), atol=0)


def test_conv_gradients_reach_kernel():
    convs = (_CONV, None)
    sizes = (_CONV.in_dim, _CONV.out_dim, 4)
    params = init_conv_params(sizes, convs, jax.random.PRNGKey(4), gain=2.0)
    g_fn, predict = make_train_fns(convs)
    xb = jnp.stack([_events(dim=_CONV.in_dim, rate=0.4, seed=s) for s in range(3)])
    yb = jnp.asarray([0, 1, 2])
    loss, grads = g_fn(params, xb, yb)
    assert np.isfinite(float(loss))
    assert grads[0].shape == _CONV.kernel_shape
    assert float(jnp.abs(grads[0]).max()) > 0.0, "dead kernel gradient"
    assert predict(params, xb).shape == (3,)


def test_densify_qparams_roundtrip_through_quant_forward():
    """Quantized conv kernel, densified, runs the standard quant forward."""
    rng = np.random.default_rng(8)
    convs = (_CONV, None)
    raw = [
        rng.normal(0, 0.5, _CONV.kernel_shape).astype(np.float32),
        rng.normal(0, 0.5, (4, _CONV.out_dim)).astype(np.float32),
    ]
    qp = densify_qparams(quantize_int8(raw), convs)
    assert qp[0][0].shape == (_CONV.out_dim, _CONV.in_dim)
    assert qp[0][0].dtype == np.int8
    ev = _events(dim=_CONV.in_dim, rate=0.5, seed=6)
    counts, spikes = snn_forward_quant(
        [(jnp.asarray(w), jnp.float32(s)) for w, s in qp], ev, use_pallas=False
    )
    assert counts.shape == (4,)
    assert float(np.asarray(spikes).sum()) >= 0.0
