"""Pruning + PTQ tests (Algorithm 1 step 2)."""

import hypothesis
import hypothesis.strategies as st
import numpy as np

from compile.quantize import dequantize, prune_l1, quant_error, quantize_int8, sparsity

hypothesis.settings.register_profile("ci", max_examples=25, deadline=None, derandomize=True)
hypothesis.settings.load_profile("ci")


def _params(seed=0, shapes=((30, 20), (10, 30))):
    rng = np.random.default_rng(seed)
    return [rng.normal(0, 0.5, s).astype(np.float32) for s in shapes]


@hypothesis.given(frac=st.floats(0.0, 1.0), seed=st.integers(0, 1000))
def test_prune_hits_target_fraction(frac, seed):
    params = _params(seed)
    pruned = prune_l1(params, frac)
    s = sparsity(pruned)
    assert s >= frac - 0.02, f"sparsity {s} < target {frac}"
    # Pruning keeps the largest magnitudes.
    for w, p in zip(params, pruned):
        kept = np.abs(w[p != 0])
        dropped = np.abs(w[(p == 0) & (w != 0)])
        if kept.size and dropped.size:
            assert kept.min() >= dropped.max() - 1e-6


def test_prune_zero_and_full():
    params = _params(1)
    assert sparsity(prune_l1(params, 0.0)) < 0.01
    assert sparsity(prune_l1(params, 1.0)) == 1.0


@hypothesis.given(seed=st.integers(0, 1000))
def test_quantize_roundtrip_error_bounded(seed):
    params = _params(seed)
    q = quantize_int8(params)
    # Symmetric int8: max error ≤ scale/2 → relative ≤ 1/(2·127) ≈ 0.4%.
    assert quant_error(params, q) <= 0.5 / 127.0 + 1e-6


def test_quantize_preserves_zeros():
    params = prune_l1(_params(2), 0.6)
    q = quantize_int8(params)
    for (w_q, _), p in zip(q, params):
        assert ((w_q == 0) == (p == 0)).all()


def test_quantize_range_and_dtype():
    q = quantize_int8(_params(3))
    for w_q, scale in q:
        assert w_q.dtype == np.int8
        assert w_q.min() >= -127 and w_q.max() <= 127
        assert scale > 0
        # The max-|w| weight maps to ±127.
        assert np.abs(w_q).max() == 127


def test_dequantize_shapes():
    params = _params(4)
    deq = dequantize(quantize_int8(params))
    for w, d in zip(params, deq):
        assert w.shape == d.shape
        assert d.dtype == np.float32


def test_all_zero_layer_quantizes_safely():
    q = quantize_int8([np.zeros((4, 4), np.float32)])
    w_q, scale = q[0]
    assert (w_q == 0).all()
    assert scale == 1.0
