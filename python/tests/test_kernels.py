"""L1 kernel correctness: Pallas vs pure-jnp oracle.

Hypothesis sweeps shapes, rates and LIF parameters — the CORE correctness
signal for the compile path (system prompt: hypothesis sweeps the kernel's
shapes/dtypes and assert_allclose against ref.py).
"""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile.kernels.c2c_matmul import c2c_matmul
from compile.kernels.lif_step import lif_step
from compile.kernels.ref import c2c_matmul_ref, lif_step_ref

hypothesis.settings.register_profile(
    "ci", max_examples=25, deadline=None, derandomize=True
)
hypothesis.settings.load_profile("ci")


def _case(seed, out_dim, in_dim, rate):
    rng = np.random.default_rng(seed)
    w = rng.integers(-127, 128, (out_dim, in_dim), dtype=np.int8)
    s = (rng.random(in_dim) < rate).astype(np.float32)
    v = rng.normal(0, 0.4, out_dim).astype(np.float32)
    return jnp.asarray(w), jnp.asarray(s), jnp.asarray(v)


@hypothesis.given(
    seed=st.integers(0, 2**31),
    out_dim=st.integers(1, 300),
    in_dim=st.integers(1, 400),
    rate=st.floats(0.0, 1.0),
)
def test_lif_step_matches_ref(seed, out_dim, in_dim, rate):
    w, s, v = _case(seed, out_dim, in_dim, rate)
    spk, vn = lif_step(w, s, v, 0.01, 0.9, 1.0, 0.0)
    spk_r, vn_r = lif_step_ref(w, s, v, 0.01, 0.9, 1.0, 0.0)
    assert_allclose(np.asarray(spk), np.asarray(spk_r), atol=0)
    assert_allclose(np.asarray(vn), np.asarray(vn_r), rtol=1e-6, atol=1e-6)


@hypothesis.given(
    seed=st.integers(0, 2**31),
    out_dim=st.integers(1, 300),
    in_dim=st.integers(1, 400),
    rate=st.floats(0.0, 1.0),
)
def test_c2c_matmul_matches_ref(seed, out_dim, in_dim, rate):
    w, s, _ = _case(seed, out_dim, in_dim, rate)
    out = c2c_matmul(w, s, 0.01)
    ref = c2c_matmul_ref(w, s, 0.01)
    assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


@hypothesis.given(
    seed=st.integers(0, 2**31),
    beta=st.floats(0.0, 1.0),
    th=st.floats(0.1, 3.0),
    reset=st.floats(-0.5, 0.05),
)
def test_lif_step_param_sweep(seed, beta, th, reset):
    hypothesis.assume(th > reset)
    w, s, v = _case(seed, 64, 96, 0.3)
    spk, vn = lif_step(w, s, v, 0.02, beta, th, reset)
    spk_r, vn_r = lif_step_ref(w, s, v, 0.02, beta, th, reset)
    assert_allclose(np.asarray(spk), np.asarray(spk_r), atol=0)
    assert_allclose(np.asarray(vn), np.asarray(vn_r), rtol=1e-5, atol=1e-6)


def test_lif_step_tile_boundaries():
    """Exercise out_dim below/at/above the 128-row tile."""
    for out_dim in (1, 127, 128, 129, 256, 300):
        w, s, v = _case(7, out_dim, 50, 0.5)
        spk, vn = lif_step(w, s, v, 0.01, 0.9, 1.0, 0.0)
        spk_r, vn_r = lif_step_ref(w, s, v, 0.01, 0.9, 1.0, 0.0)
        assert_allclose(np.asarray(spk), np.asarray(spk_r), atol=0)
        assert_allclose(np.asarray(vn), np.asarray(vn_r), rtol=1e-6, atol=1e-6)


def test_c2c_bit_gain_ideal_is_identity():
    w, s, _ = _case(3, 100, 80, 0.4)
    ideal = c2c_matmul(w, s, 0.01, bit_gain=jnp.ones(8))
    ref = c2c_matmul_ref(w, s, 0.01)
    assert_allclose(np.asarray(ideal), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_c2c_bit_gain_mismatch_perturbs_proportionally():
    w, s, _ = _case(11, 100, 80, 0.4)
    gains = jnp.asarray(1.0 + 0.002 * np.random.default_rng(0).standard_normal(8),
                        jnp.float32)
    real = np.asarray(c2c_matmul(w, s, 0.01, bit_gain=gains))
    ref = np.asarray(c2c_matmul_ref(w, s, 0.01))
    denom = np.maximum(np.abs(ref), 1e-3)
    assert np.max(np.abs(real - ref) / denom) < 0.05


def test_zero_spikes_give_zero_current():
    w, _, v = _case(5, 60, 40, 0.0)
    s = jnp.zeros(40, jnp.float32)
    spk, vn = lif_step(w, s, v, 0.01, 0.9, 1.0, 0.0)
    assert np.asarray(spk).sum() == 0
    assert_allclose(np.asarray(vn), 0.9 * np.asarray(v), rtol=1e-6)


def test_extreme_weights_saturate_correctly():
    """All-max weights with dense spikes: every neuron fires, resets."""
    w = jnp.full((32, 64), 127, jnp.int8)
    s = jnp.ones(64, jnp.float32)
    v = jnp.zeros(32, jnp.float32)
    spk, vn = lif_step(w, s, v, 0.01, 0.9, 1.0, 0.0)
    assert np.asarray(spk).sum() == 32
    assert_allclose(np.asarray(vn), 0.0, atol=0)
