# MENAGE — build/verify/bench entry points.
#
# `make verify` is the tier-1 gate plus the lane differential suites; run
# it before every commit. Bench targets regenerate the machine-readable
# perf artifacts (BENCH_hotpath.json) tracked across PRs.

CARGO ?= cargo

.PHONY: verify build test test-lanes bench-hotpath bench clean

verify: build test test-lanes

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

## The differential harness pinning lane execution to the sequential
## engine, plus the dirty-slot invariant properties (also covered by
## `test`; kept addressable so CI can surface them separately).
test-lanes:
	$(CARGO) test -q --test lanes_differential --test dirty_slot_invariant

bench-hotpath:
	$(CARGO) bench --bench hotpath

bench:
	$(CARGO) bench

clean:
	$(CARGO) clean
