# MENAGE — build/verify/bench entry points.
#
# `make verify` is the tier-1 gate plus the lane differential suites; run
# it before every commit. `make lint` is the CI style gate (rustfmt +
# clippy). Bench targets regenerate the machine-readable perf artifacts
# (BENCH_hotpath.json) tracked across PRs.

CARGO ?= cargo

.PHONY: verify build test test-lanes lint fmt clippy bench-hotpath bench clean

verify: build test test-lanes

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

## The differential harness pinning every execution path to the unified
## SoA engine (lane vs sequential, ideal and non-ideal, plus the
## dirty-slot invariant properties — also covered by `test`; kept
## addressable so CI can surface them separately).
test-lanes:
	$(CARGO) test -q --test lanes_differential --test dirty_slot_invariant

## CI style gate: formatting and clippy with warnings denied.
lint: fmt clippy

fmt:
	$(CARGO) fmt --check

clippy:
	$(CARGO) clippy -- -D warnings

## Regenerates BENCH_hotpath.json (SoA lane-major engine rows, including
## the non-ideal lane batching rows) — commit the refreshed file.
bench-hotpath:
	$(CARGO) bench --bench hotpath

## All benches; includes bench-hotpath's BENCH_hotpath.json regeneration.
bench:
	$(CARGO) bench

clean:
	$(CARGO) clean
