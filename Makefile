# MENAGE — build/verify/bench entry points.
#
# `make verify` is the tier-1 gate plus the lane differential suites; run
# it before every commit. `make lint` is the CI style gate (rustfmt +
# clippy). Bench targets regenerate the machine-readable perf artifacts
# (BENCH_hotpath.json) tracked across PRs.

CARGO ?= cargo
## Loopback port for the serve smoke test (override on collision).
SMOKE_PORT ?= 7471
## Loopback port for the chaos smoke test (override on collision).
CHAOS_PORT ?= 7473
## Loopback ports for the distributed-shard smoke test (override on collision).
DIST_PORT_A ?= 7475
DIST_PORT_B ?= 7476
## Loopback port for the observability smoke test (override on collision).
OBS_PORT ?= 7477
## Loopback port for the streaming-session smoke test (override on collision).
STREAM_PORT ?= 7479

.PHONY: verify build test test-lanes test-serve test-shard test-dist test-conv test-stream test-chaos chaos smoke-serve smoke-shard smoke-dist smoke-conv smoke-chaos smoke-obs smoke-stream lint fmt clippy bench-hotpath bench clean

verify: build test test-lanes test-shard test-dist test-conv test-stream

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

## The differential harness pinning every execution path to the unified
## SoA engine (lane vs sequential, ideal and non-ideal, plus the
## dirty-slot invariant properties — also covered by `test`; kept
## addressable so CI can surface them separately).
test-lanes:
	$(CARGO) test -q --test lanes_differential --test dirty_slot_invariant

## The serving-layer loopback integration suite (also covered by `test`;
## kept addressable so CI can surface it separately).
test-serve:
	$(CARGO) test -q --test serve_roundtrip

## The multi-chip sharding differential suite: sharded execution pinned
## bit-identical to the monolithic engine (also covered by `test`).
test-shard:
	$(CARGO) test -q --test shard_differential

## The distributed-shard identity suite: loopback shard-hosts pinned
## bit-identical to in-process sharded execution, plus the wire failure
## semantics (sequence gaps, killed hosts). Also covered by `test`.
test-dist:
	$(CARGO) test -q --test dist_identity

## The compressed-conv differential suite: generator-based row fetch
## pinned bit-identical to the dense expand_conv() oracle across
## sequential, lane-batched (ideal + non-ideal), sharded and faulted
## execution, plus the weight-SRAM capacity win. Also covered by `test`.
test-conv:
	$(CARGO) test -q --test conv_differential

## The streaming-session differential suite: chunked suspend/resume
## execution pinned bit-identical to one-shot runs at arbitrary chunk
## boundaries (engine + serve layer, mono + sharded, ideal + non-ideal,
## interleaved sessions, eviction accounting). Also covered by `test`.
test-stream:
	$(CARGO) test -q --test stream_differential

## Compressed-conv smoke: the CIFAR10-DVS e2e example runs every sample
## through the compressed chip AND the dense expand_conv() oracle chip and
## exits non-zero unless spike trains and cycle counts are bit-identical
## (synthetic fallback model when artifacts are absent, so it runs in CI).
smoke-conv: build
	$(CARGO) run --release --example cifar10dvs_e2e

## CLI-level distributed smoke, bounded runtime: two `shard-host`
## processes each serving one chip of the same 2-shard plan, driven by
## `simulate --remote-shards`; --check-monolithic exits non-zero unless
## every classifier train and cycle count is bit-identical to an
## in-process monolithic oracle. Hosts are killed afterwards (their
## --duration-secs is only the hang backstop).
smoke-dist: build
	./target/release/menage shard-host --synthetic --model nmnist \
		--shards 2 --shard-index 0 --addr 127.0.0.1:$(DIST_PORT_A) \
		--duration-secs 120 & \
	HOST_A=$$!; \
	./target/release/menage shard-host --synthetic --model nmnist \
		--shards 2 --shard-index 1 --addr 127.0.0.1:$(DIST_PORT_B) \
		--duration-secs 120 & \
	HOST_B=$$!; \
	sleep 1; \
	if ./target/release/menage simulate --synthetic --model nmnist \
		--samples 6 --remote-window 2 --check-monolithic \
		--remote-shards 127.0.0.1:$(DIST_PORT_A),127.0.0.1:$(DIST_PORT_B); then \
		kill $$HOST_A $$HOST_B 2>/dev/null; \
		wait $$HOST_A $$HOST_B 2>/dev/null || true; \
	else \
		kill $$HOST_A $$HOST_B 2>/dev/null; \
		wait $$HOST_A $$HOST_B 2>/dev/null; exit 1; \
	fi

## The robustness gate: wire-protocol fuzz, hardware fault-plan
## determinism, and the self-healing chaos suite (injected worker
## panics, dropped responses, connection resets, bounded shutdown with
## dead workers). The test half is also covered by `test`; kept
## addressable so CI surfaces it separately, then the CLI smoke drives
## the same machinery end-to-end.
chaos: test-chaos smoke-chaos

test-chaos:
	$(CARGO) test -q --test protocol_fuzz --test chaos --test failure_injection

## End-to-end self-healing smoke over loopback, bounded runtime: a server
## with BOTH planes of fault injection armed (analog hardware faults plus
## serving-layer chaos — worker panics, dropped responses, connection
## resets), driven by loadgen, which retries transient failures and exits
## non-zero only on terminal loss: a dropped/mismatched/unanswered
## request despite recovery.
smoke-chaos: build
	./target/release/menage serve --synthetic --model nmnist \
		--addr 127.0.0.1:$(CHAOS_PORT) --workers 2 --lanes 4 \
		--duration-secs 120 --allow-remote-shutdown \
		--faults seed=7,stuck=0.02,dead=0.01,flip=0.0005 \
		--chaos panic=40,drop=60,reset=90 & \
	SERVER_PID=$$!; \
	sleep 1; \
	if ./target/release/menage loadgen --addr 127.0.0.1:$(CHAOS_PORT) \
		--requests 256 --connections 8 --pipeline 4 --shutdown-server; then \
		wait $$SERVER_PID; \
	else \
		kill $$SERVER_PID 2>/dev/null; wait $$SERVER_PID 2>/dev/null; exit 1; \
	fi

## CLI-level sharding smoke, bounded runtime: run a small synthetic model
## through a 2-shard pipeline AND a monolithic oracle in one process;
## --check-monolithic exits non-zero unless every classifier train and
## cycle count is bit-identical.
smoke-shard: build
	./target/release/menage simulate --synthetic --model nmnist \
		--samples 6 --workers 2 --shards 2 --check-monolithic

## End-to-end serving smoke over loopback, bounded runtime: start
## `menage serve` on a synthetic model, drive it with `menage loadgen`
## (256 requests / 8 connections — the acceptance-criteria shape), which
## writes BENCH_serve.json and then gracefully shuts the server down via
## the SHUTDOWN frame. Fails if any response is dropped or mismatched.
smoke-serve: build
	./target/release/menage serve --synthetic --model nmnist \
		--addr 127.0.0.1:$(SMOKE_PORT) --workers 2 --lanes 4 \
		--duration-secs 120 --allow-remote-shutdown & \
	SERVER_PID=$$!; \
	sleep 1; \
	if ./target/release/menage loadgen --addr 127.0.0.1:$(SMOKE_PORT) \
		--requests 256 --connections 8 --pipeline 4 --shutdown-server; then \
		wait $$SERVER_PID; \
	else \
		kill $$SERVER_PID 2>/dev/null; wait $$SERVER_PID 2>/dev/null; exit 1; \
	fi

## Observability smoke over loopback, bounded runtime: serve a synthetic
## model, drive it with `loadgen --profile` (records the server's stage
## histograms + this run's per-core/per-shard execution-counter delta into
## BENCH_serve.json), then poll once with `menage top --once`, which exits
## non-zero unless the versioned STATS `profile` block is present and
## well-formed. The server is shut down via the SHUTDOWN frame afterwards.
smoke-obs: build
	./target/release/menage serve --synthetic --model nmnist \
		--addr 127.0.0.1:$(OBS_PORT) --workers 2 --lanes 4 \
		--duration-secs 120 --allow-remote-shutdown & \
	SERVER_PID=$$!; \
	sleep 1; \
	if ./target/release/menage loadgen --addr 127.0.0.1:$(OBS_PORT) \
		--requests 128 --connections 4 --pipeline 4 --profile \
		&& ./target/release/menage top --addr 127.0.0.1:$(OBS_PORT) --once \
		&& ./target/release/menage loadgen --addr 127.0.0.1:$(OBS_PORT) \
		--requests 4 --connections 1 --out /dev/null --shutdown-server; then \
		wait $$SERVER_PID; \
	else \
		kill $$SERVER_PID 2>/dev/null; wait $$SERVER_PID 2>/dev/null; exit 1; \
	fi

## Streaming-session smoke over loopback, bounded runtime: serve a
## synthetic model with session lanes enabled, drive it with
## `loadgen --stream` (concurrent sessions streaming chunked trains; the
## client re-derives every rolling prediction from the accumulated chunk
## outputs and exits non-zero on any mismatch or lost chunk — the
## integrity gate that proves lane state survives across chunks), then
## gracefully shut the server down via the SHUTDOWN frame.
smoke-stream: build
	./target/release/menage serve --synthetic --model nmnist \
		--addr 127.0.0.1:$(STREAM_PORT) --workers 2 --lanes 4 \
		--session-lanes 8 --duration-secs 120 --allow-remote-shutdown & \
	SERVER_PID=$$!; \
	sleep 1; \
	if ./target/release/menage loadgen --addr 127.0.0.1:$(STREAM_PORT) \
		--stream --requests 64 --connections 4 --chunk-timesteps 2 \
		--shutdown-server; then \
		wait $$SERVER_PID; \
	else \
		kill $$SERVER_PID 2>/dev/null; wait $$SERVER_PID 2>/dev/null; exit 1; \
	fi

## CI style gate: formatting and clippy with warnings denied.
lint: fmt clippy

fmt:
	$(CARGO) fmt --check

clippy:
	$(CARGO) clippy -- -D warnings

## Regenerates BENCH_hotpath.json (SoA lane-major engine rows, including
## the non-ideal lane batching rows) — commit the refreshed file.
bench-hotpath:
	$(CARGO) bench --bench hotpath

## All benches; includes bench-hotpath's BENCH_hotpath.json regeneration.
bench:
	$(CARGO) bench

clean:
	$(CARGO) clean
