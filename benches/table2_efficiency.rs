//! Table II reproduction: energy efficiency (TOPS/W) of Accel₁ on N-MNIST
//! and Accel₂ on CIFAR10-DVS, against the published prior-work rows.
//!
//! Loads the trained artifacts when available (falling back to synthetic
//! networks so `cargo bench` works standalone), runs each design point on
//! its workload through the cycle-accurate simulator, prices the counted
//! operations with the 90 nm energy model, and prints the paper's table
//! with a measured column.

use menage::accel::Menage;
use menage::analog::AnalogParams;
use menage::bench::Table;
use menage::config::{AcceleratorConfig, ModelConfig};
use menage::datasets::{Dataset, DatasetKind};
use menage::energy::{
    report, table2_baselines, EnergyModel, PAPER_ACCEL1_TOPS_W, PAPER_ACCEL2_TOPS_W,
};
use menage::mapping::Strategy;
use menage::runtime::artifacts_dir;
use menage::snn::{QuantNetwork, SpikeTrain};
use menage::util::rng::Rng;
use menage::util::tensorfile::TensorFile;

/// Load trained net or synthesize an equivalent one.
fn network(base: &str, mcfg: &ModelConfig) -> (QuantNetwork, bool) {
    match TensorFile::load(artifacts_dir().join(format!("{base}.weights.mtz")))
        .and_then(|tf| QuantNetwork::from_tensorfile(base, &tf))
    {
        Ok(n) => (n, true),
        Err(_) => {
            let mut rng = Rng::new(7);
            (QuantNetwork::random(mcfg, 0.5, &mut rng), false)
        }
    }
}

fn eval_inputs(base: &str, kind: DatasetKind, t: usize, n: usize) -> Vec<SpikeTrain> {
    if let Ok(tf) = TensorFile::load(artifacts_dir().join(format!("{base}.eval.mtz"))) {
        if let (Ok(ev), Ok(_)) = (tf.get("events"), tf.get("labels")) {
            let dims = ev.dims().to_vec();
            if dims[1] == t {
                let raw = ev.as_u8().unwrap();
                let (cnt, t, d) = (dims[0].min(n), dims[1], dims[2]);
                return (0..cnt)
                    .map(|i| {
                        let mut st = SpikeTrain::new(d, t);
                        for (ti, step) in st.spikes.iter_mut().enumerate() {
                            for j in 0..d {
                                if raw[i * t * d + ti * d + j] != 0 {
                                    step.push(j as u32);
                                }
                            }
                        }
                        st
                    })
                    .collect();
            }
        }
    }
    let ds = Dataset::new(kind, 5, t);
    ds.balanced_split(n, 0).into_iter().map(|s| s.events).collect()
}

fn measure(
    label: &str,
    base: &str,
    mcfg: &ModelConfig,
    cfg: &AcceleratorConfig,
    kind: DatasetKind,
    samples: usize,
) -> (f64, bool) {
    let (net, trained) = network(base, mcfg);
    let inputs = eval_inputs(base, kind, net.timesteps, samples);
    let mut chip =
        Menage::build(&net, cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 7).unwrap();
    for st in &inputs {
        chip.run(st).unwrap();
    }
    let eff = report(&chip, &EnergyModel::paper_90nm(cfg.clock_hz));
    eprintln!(
        "[{label}] {} samples, {} MACs, {:.3} µJ, {:.3} ms modeled → {:.2} TOPS/W \
         (breakdown: mac {:.1}% neuron {:.1}% wsram {:.1}% snsram {:.1}% ctrl {:.1}% static {:.1}%)",
        inputs.len(),
        chip.total_macs(),
        eff.breakdown.total() * 1e6,
        eff.seconds * 1e3,
        eff.tops_per_watt,
        100.0 * eff.breakdown.analog_mac / eff.breakdown.total(),
        100.0 * eff.breakdown.analog_neuron / eff.breakdown.total(),
        100.0 * eff.breakdown.weight_sram / eff.breakdown.total(),
        100.0 * eff.breakdown.sn_sram / eff.breakdown.total(),
        100.0 * eff.breakdown.controller / eff.breakdown.total(),
        100.0 * eff.breakdown.static_leak / eff.breakdown.total(),
    );
    (eff.tops_per_watt, trained)
}

fn main() {
    let (a1, t1) = measure(
        "accel1/nmnist",
        "nmnist",
        &ModelConfig::nmnist_mlp(),
        &AcceleratorConfig::accel1(),
        DatasetKind::NMnist,
        24,
    );
    let (a2, t2) = measure(
        "accel2/cifar",
        "cifar_small",
        &ModelConfig::cifar10dvs_mlp_small(),
        &AcceleratorConfig::accel2(),
        DatasetKind::Cifar10DvsSmall,
        16,
    );

    let mut t = Table::new(
        "Table II — comparison with prior work (TOPS/W)",
        &["Author", "Neural Ops", "TOPS/W", "Bits", "Tech", "Dataset", "#Neurons"],
    );
    t.row(&[
        "MENAGE (Accel₁) [measured]".into(),
        "Analog LIF".into(),
        format!("{a1:.2} (paper {PAPER_ACCEL1_TOPS_W})"),
        "8".into(),
        "90nm".into(),
        format!("N-MNIST{}", if t1 { "" } else { " (synthetic net)" }),
        "40".into(),
    ]);
    t.row(&[
        "MENAGE (Accel₂) [measured]".into(),
        "Analog LIF".into(),
        format!("{a2:.2} (paper {PAPER_ACCEL2_TOPS_W})"),
        "8".into(),
        "90nm".into(),
        format!("CIFAR10-DVS{}", if t2 { "" } else { " (synthetic net)" }),
        "100".into(),
    ]);
    for b in table2_baselines() {
        t.row(&[
            b.author.into(),
            b.neural_ops.into(),
            format!("{} (published)", b.tops_per_watt),
            b.bit_width.into(),
            b.technology.into(),
            b.dataset.into(),
            b.neurons.into(),
        ]);
    }
    t.print();

    println!(
        "\nShape checks: MENAGE > every published baseline ({}); Accel₂ > Accel₁ ({}); \
         Accel₂/Accel₁ ratio {:.1}× (paper: {:.1}×).",
        if a1.min(a2) > 1.88 { "holds" } else { "FAILS" },
        if a2 > a1 { "holds" } else { "FAILS" },
        a2 / a1,
        PAPER_ACCEL2_TOPS_W / PAPER_ACCEL1_TOPS_W,
    );
}
