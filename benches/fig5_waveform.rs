//! Figure 5 reproduction: A-NEURON circuit waveform (input, integration
//! voltage, output spike) plus verification of the paper's operating
//! point (97 nW, 6.72 ns).

use menage::analog::{ANeuron, AnalogParams};
use menage::bench::{ascii_chart, emit_series, Bencher};
use menage::util::rng::Rng;

fn main() {
    let mut an = ANeuron::new(1, AnalogParams::paper());
    an.enable_capture();
    let mut rng = Rng::new(11);
    for step in 0..60 {
        let packet = if (step / 10) % 2 == 0 && rng.bernoulli(0.8) {
            rng.uniform(0.2, 0.45)
        } else {
            0.0
        };
        an.process(0, packet, 1.0, 0.0);
        an.lif_leak(0.9);
    }
    let wf = an.waveform().to_vec();
    let t_ns: Vec<f64> = wf.iter().map(|p| p.t * 1e9).collect();
    let v_in: Vec<f64> = wf.iter().map(|p| p.v_in).collect();
    let v_integ: Vec<f64> = wf.iter().map(|p| p.v_integ).collect();
    let v_out: Vec<f64> = wf.iter().map(|p| p.v_out).collect();

    emit_series("fig5_input", &t_ns, &v_in);
    emit_series("fig5_integration", &t_ns, &v_integ);
    emit_series("fig5_output", &t_ns, &v_out);
    println!("{}", ascii_chart("fig5: integration voltage (V)", &v_integ, 8));
    println!("{}", ascii_chart("fig5: output spikes (V)", &v_out, 3));

    let pulses = v_out.iter().filter(|&&v| v > 0.5).count();
    let power_nw = an.average_power() * 1e9;
    println!(
        "operating point: {:.1} nW (paper 97 nW), {:.2} ns/op (paper 6.72 ns), \
         {pulses} output pulses",
        power_nw,
        an.params.neuron_delay * 1e9
    );
    assert!((power_nw - 97.0).abs() < 1.0, "power calibration drifted");

    // Timing: how fast the behavioural model simulates A-NEURON ops.
    let b = Bencher::default();
    let mut an2 = ANeuron::new(16, AnalogParams::paper());
    let r = b.run("aneuron_process_op", || an2.process(3, 0.1, 1.0, 0.0));
    println!(
        "simulation speed: {:.1} M A-NEURON ops/s",
        r.throughput(1.0) / 1e6
    );
}
