//! Hot-path microbenchmarks (§Perf): simulator event-dispatch throughput,
//! reference-model throughput, end-to-end sample latency at default and
//! low spike activity, and coordinator scaling — the numbers the
//! performance pass optimizes.
//!
//! Besides the human-readable `BENCH` lines, the run emits a
//! machine-readable `BENCH_hotpath.json` (into `MENAGE_BENCH_DIR` or the
//! working directory) so the perf trajectory is tracked across PRs:
//! regenerate with `cargo bench --bench hotpath` and commit the file.

use menage::accel::{Menage, RunOutput};
use menage::analog::AnalogParams;
use menage::bench::{emit_json_file, Bencher};
use menage::config::{AcceleratorConfig, ModelConfig};
use menage::coordinator::Coordinator;
use menage::datasets::{Dataset, DatasetKind};
use menage::mapping::{layer_weight_bytes, Strategy};
use menage::shard::ShardedMenage;
use menage::snn::{reference_forward, ConvSpec, QuantNetwork, SpikeTrain};
use menage::util::json::Json;
use menage::util::rng::Rng;

/// Synthetic spike train at a controlled rate (the low-activity sweep the
/// sparsity-aware engine is optimized for).
fn rate_input(dim: usize, timesteps: usize, rate: f64, seed: u64) -> SpikeTrain {
    let mut rng = Rng::new(seed);
    SpikeTrain::bernoulli(dim, timesteps, rate, &mut rng)
}

fn main() {
    let mut mcfg = ModelConfig::nmnist_mlp();
    mcfg.timesteps = 10;
    let mut rng = Rng::new(3);
    let net = QuantNetwork::random(&mcfg, 0.5, &mut rng);
    let cfg = AcceleratorConfig::accel1();
    let ds = Dataset::new(DatasetKind::NMnist, 5, mcfg.timesteps);
    let samples: Vec<SpikeTrain> =
        ds.balanced_split(8, 0).into_iter().map(|s| s.events).collect();
    let in_dim = net.input_dim();

    let b = Bencher::default();

    // Reference model (the digital golden): samples/s and synaptic events/s.
    let r_ref = b.run("reference_forward", || {
        reference_forward(&net, &samples[0]).unwrap()
    });
    let reference_sps = r_ref.throughput(1.0);
    println!("  reference: {reference_sps:.1} samples/s");

    // Cycle-accurate chip at the dataset's default activity: per-sample
    // latency and synaptic-event rate, on the allocation-free run path.
    let mut chip =
        Menage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 7).unwrap();
    let mut out = RunOutput::default();
    let mut i = 0usize;
    let r_chip = b.run("chip_run_sample", || {
        i = (i + 1) % samples.len();
        chip.run_into(&samples[i], &mut out).unwrap();
        out.cycles
    });
    let chip_sps = r_chip.throughput(1.0);
    let macs_per_run = chip.total_macs() as f64 / chip.inputs_processed as f64;
    let events_per_s = r_chip.throughput(macs_per_run);
    println!(
        "  simulator: {chip_sps:.1} samples/s, {:.1} M synaptic events/s (sim speed)",
        events_per_s / 1e6
    );

    // Low-activity regime (spike rate 0.03 ≤ 0.05): with the
    // activity-tracked sweep, cost must follow spikes, not model capacity.
    let low_rate = 0.03;
    let quiet: Vec<SpikeTrain> =
        (0..8).map(|s| rate_input(in_dim, mcfg.timesteps, low_rate, 100 + s)).collect();
    let mut chip_low =
        Menage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 7).unwrap();
    let mut j = 0usize;
    let r_low = b.run("chip_run_sample_low_activity", || {
        j = (j + 1) % quiet.len();
        chip_low.run_into(&quiet[j], &mut out).unwrap();
        out.cycles
    });
    let chip_low_sps = r_low.throughput(1.0);
    println!("  simulator @rate={low_rate}: {chip_low_sps:.1} samples/s");

    // Mapping (build-time path).
    b.run("menage_build_full", || {
        Menage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 7).unwrap()
    });

    // Lane execution vs sequential, batch of B. Two regimes:
    //  * shared-event: every lane carries the same sample — each distinct
    //    event's CSR walk is fetched once and serves all B lanes, so total
    //    cost should be sublinear in B;
    //  * distinct: B different samples — lanes still amortize whatever
    //    events overlap, the worst case for sharing.
    let lane_b = 8usize;
    let shared_batch: Vec<SpikeTrain> = vec![samples[0].clone(); lane_b];
    let distinct_batch: Vec<SpikeTrain> = (0..lane_b)
        .map(|k| samples[k % samples.len()].clone())
        .collect();

    let mut chip_seq =
        Menage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 7).unwrap();
    let r_seq = b.run("sequential_x8_shared_sample", || {
        for s in &shared_batch {
            chip_seq.run_into(s, &mut out).unwrap();
        }
    });
    let seq_sps = r_seq.throughput(lane_b as f64);

    let mut chip_lanes =
        Menage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 7).unwrap();
    let mut louts: Vec<RunOutput> = Vec::new();
    let r_lanes_shared = b.run("lanes_x8_shared_sample", || {
        chip_lanes.run_lanes_into(&shared_batch, &mut louts).unwrap();
    });
    let lanes_shared_sps = r_lanes_shared.throughput(lane_b as f64);
    let shared_speedup = r_lanes_shared.speedup_over(&r_seq);

    let mut chip_seq_d =
        Menage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 7).unwrap();
    let r_seq_d = b.run("sequential_x8_distinct_samples", || {
        for s in &distinct_batch {
            chip_seq_d.run_into(s, &mut out).unwrap();
        }
    });
    let mut chip_lanes_d =
        Menage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 7).unwrap();
    let r_lanes_distinct = b.run("lanes_x8_distinct_samples", || {
        chip_lanes_d.run_lanes_into(&distinct_batch, &mut louts).unwrap();
    });
    let lanes_distinct_sps = r_lanes_distinct.throughput(lane_b as f64);
    let distinct_speedup = r_lanes_distinct.speedup_over(&r_seq_d);
    println!(
        "  lanes x{lane_b}: shared-sample {shared_speedup:.2}× sequential \
         ({lanes_shared_sps:.1} samples/s), distinct {distinct_speedup:.2}× \
         ({lanes_distinct_sps:.1} samples/s)"
    );

    // Non-ideal analog lane batching — only possible since the unified
    // SoA engine made the error sidecar order-robust (previously every
    // non-ideal lane fell back to a serialized state-swap through the
    // sequential core). Mismatch studies now amortize the CSR walk too.
    let analog_paper = AnalogParams::paper();
    let mut chip_seq_ni =
        Menage::build(&net, &cfg, Strategy::IlpFlow, &analog_paper, 7).unwrap();
    let r_seq_ni = b.run("nonideal_sequential_x8_distinct_samples", || {
        for s in &distinct_batch {
            chip_seq_ni.run_into(s, &mut out).unwrap();
        }
    });
    let mut chip_lanes_ni =
        Menage::build(&net, &cfg, Strategy::IlpFlow, &analog_paper, 7).unwrap();
    let r_lanes_ni = b.run("nonideal_lanes_x8_distinct_samples", || {
        chip_lanes_ni.run_lanes_into(&distinct_batch, &mut louts).unwrap();
    });
    let nonideal_seq_sps = r_seq_ni.throughput(lane_b as f64);
    let nonideal_lanes_sps = r_lanes_ni.throughput(lane_b as f64);
    let nonideal_speedup = r_lanes_ni.speedup_over(&r_seq_ni);
    println!(
        "  non-ideal lanes x{lane_b}: {nonideal_speedup:.2}× sequential \
         ({nonideal_lanes_sps:.1} samples/s)"
    );

    // Multi-chip sharded pipeline (2 shards over the 4-layer model):
    // boundary frontiers forwarded chip-to-chip per step, outputs
    // bit-identical to the monolithic chip (tests/shard_differential.rs).
    // The interesting number is the overhead of the shard walk vs the
    // monolithic run loop on identical work.
    let mut chip_sharded =
        ShardedMenage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 7, 2)
            .unwrap();
    let mut si = 0usize;
    let r_sharded = b.run("sharded_x2_run_sample", || {
        si = (si + 1) % samples.len();
        chip_sharded.run_into(&samples[si], &mut out).unwrap();
        out.cycles
    });
    let sharded_sps = r_sharded.throughput(1.0);
    let sharded_vs_mono = r_sharded.speedup_over(&r_chip);
    println!(
        "  sharded x2: {sharded_sps:.1} samples/s ({sharded_vs_mono:.2}× monolithic; \
         cut traffic estimate {})",
        chip_sharded.plan.cut_cost
    );

    // Coordinator scaling on the work-stealing queue: 1 vs 4 workers over a
    // 256-sample batch. Coordinator::new (thread spawn + W chip clones) is
    // setup, NOT workload — it stays outside the timed region.
    let mut coord_sps = Vec::new();
    for workers in [1usize, 4] {
        let chip =
            Menage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 7).unwrap();
        let batch: Vec<(SpikeTrain, Option<usize>)> = (0..256)
            .map(|k| (samples[k % samples.len()].clone(), Some(0)))
            .collect();
        let mut coord = Coordinator::new(&chip, workers);
        let t0 = std::time::Instant::now();
        let res = coord.run_batch(batch).unwrap();
        let dt = t0.elapsed();
        coord.shutdown();
        let sps = res.len() as f64 / dt.as_secs_f64();
        coord_sps.push(sps);
        println!(
            "  coordinator x{workers}: {} samples in {dt:?} → {sps:.1} samples/s",
            res.len(),
        );
    }
    let scaling = coord_sps[1] / coord_sps[0];
    println!("  coordinator scaling 4w/1w: {scaling:.2}×");

    // Lane-packed coordinator: the same 256-sample batch over a 2×8
    // (worker, lane) grid — 16 request slots with only 2 model copies.
    let lane_packed_sps = {
        let chip =
            Menage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 7).unwrap();
        let batch: Vec<(SpikeTrain, Option<usize>)> = (0..256)
            .map(|k| (samples[k % samples.len()].clone(), Some(0)))
            .collect();
        let mut coord = Coordinator::with_lanes(&chip, 2, 8);
        let t0 = std::time::Instant::now();
        let res = coord.run_batch(batch).unwrap();
        let dt = t0.elapsed();
        coord.shutdown();
        let sps = res.len() as f64 / dt.as_secs_f64();
        println!(
            "  coordinator 2w×8L lane-packed: {} samples in {dt:?} → {sps:.1} samples/s",
            res.len(),
        );
        sps
    };

    // Compressed conv synapses vs the dense expand_conv() oracle. Behaviour
    // is bit-identical (tests/conv_differential.rs), so the interesting
    // numbers are the generator-based row fetch's throughput against the
    // CSR walk over the expanded matrix, and the weight-SRAM footprint
    // ratio that lets CIFAR10-DVS-scale conv stacks fit on-chip.
    let c1 = ConvSpec {
        in_channels: 2,
        in_h: 32,
        in_w: 32,
        out_channels: 8,
        kernel_h: 3,
        kernel_w: 3,
        stride: 2,
        padding: 1,
    };
    let c2 = ConvSpec { in_channels: 8, in_h: 16, in_w: 16, ..c1 };
    let mut crng = Rng::new(9);
    let conv_net =
        QuantNetwork::random_conv("cifar10dvs_conv", &[c1, c2], 10, mcfg.timesteps, 0.5, &mut crng)
            .unwrap();
    let conv_oracle = conv_net.expand_convs().unwrap();
    let cfg2 = AcceleratorConfig::accel2();
    let conv_inputs: Vec<SpikeTrain> = (0..4)
        .map(|s| rate_input(conv_net.input_dim(), conv_net.timesteps, 0.1, 200 + s))
        .collect();
    let mut chip_conv =
        Menage::build(&conv_net, &cfg2, Strategy::IlpFlow, &AnalogParams::ideal(), 7).unwrap();
    let mut ci = 0usize;
    let r_conv = b.run("conv_compressed_run_sample", || {
        ci = (ci + 1) % conv_inputs.len();
        chip_conv.run_into(&conv_inputs[ci], &mut out).unwrap();
        out.cycles
    });
    let mut chip_conv_exp =
        Menage::build(&conv_oracle, &cfg2, Strategy::IlpFlow, &AnalogParams::ideal(), 7).unwrap();
    let mut ce = 0usize;
    let r_conv_exp = b.run("conv_expanded_run_sample", || {
        ce = (ce + 1) % conv_inputs.len();
        chip_conv_exp.run_into(&conv_inputs[ce], &mut out).unwrap();
        out.cycles
    });
    let conv_sps = r_conv.throughput(1.0);
    let conv_exp_sps = r_conv_exp.throughput(1.0);
    let conv_vs_expanded = r_conv.speedup_over(&r_conv_exp);
    let conv_wb: usize = layer_weight_bytes(&conv_net, cfg2.weight_bits).iter().sum();
    let conv_wb_exp: usize = layer_weight_bytes(&conv_oracle, cfg2.weight_bits).iter().sum();
    let footprint_ratio = conv_wb_exp as f64 / conv_wb as f64;
    println!(
        "  conv compressed: {conv_sps:.1} samples/s ({conv_vs_expanded:.2}× expanded's \
         {conv_exp_sps:.1}), weight SRAM {conv_wb} B vs {conv_wb_exp} B \
         ({footprint_ratio:.0}× smaller)"
    );

    emit_json_file(
        "BENCH_hotpath.json",
        &Json::obj(vec![
            ("bench", "hotpath".into()),
            ("model", net.name.as_str().into()),
            ("timesteps", mcfg.timesteps.into()),
            ("reference_samples_per_s", reference_sps.into()),
            ("chip_samples_per_s", chip_sps.into()),
            ("chip_synaptic_events_per_s", events_per_s.into()),
            ("low_activity_rate", low_rate.into()),
            ("chip_low_activity_samples_per_s", chip_low_sps.into()),
            (
                "lanes",
                Json::obj(vec![
                    ("engine", "soa-lane-major".into()),
                    ("batch", lane_b.into()),
                    ("sequential_shared_samples_per_s", seq_sps.into()),
                    ("lanes_shared_samples_per_s", lanes_shared_sps.into()),
                    ("speedup_shared", shared_speedup.into()),
                    ("lanes_distinct_samples_per_s", lanes_distinct_sps.into()),
                    ("speedup_distinct", distinct_speedup.into()),
                    ("nonideal_sequential_samples_per_s", nonideal_seq_sps.into()),
                    ("nonideal_lanes_samples_per_s", nonideal_lanes_sps.into()),
                    ("speedup_nonideal", nonideal_speedup.into()),
                ]),
            ),
            (
                "sharded",
                Json::obj(vec![
                    ("shards", 2usize.into()),
                    ("cut_cost", (chip_sharded.plan.cut_cost as usize).into()),
                    ("samples_per_s", sharded_sps.into()),
                    ("speedup_over_monolithic", sharded_vs_mono.into()),
                ]),
            ),
            (
                "conv",
                Json::obj(vec![
                    ("model", conv_net.name.as_str().into()),
                    ("stored_weights_compressed", conv_net.stored_weights().into()),
                    ("stored_weights_expanded", conv_oracle.stored_weights().into()),
                    ("weight_bytes_compressed", conv_wb.into()),
                    ("weight_bytes_expanded", conv_wb_exp.into()),
                    ("footprint_ratio", footprint_ratio.into()),
                    ("compressed_samples_per_s", conv_sps.into()),
                    ("expanded_samples_per_s", conv_exp_sps.into()),
                    ("speedup_vs_expanded", conv_vs_expanded.into()),
                ]),
            ),
            (
                "coordinator",
                Json::obj(vec![
                    ("batch", 256usize.into()),
                    ("workers_1_samples_per_s", coord_sps[0].into()),
                    ("workers_4_samples_per_s", coord_sps[1].into()),
                    ("scaling_4w_over_1w", scaling.into()),
                    ("lane_packed_2w_8l_samples_per_s", lane_packed_sps.into()),
                ]),
            ),
        ]),
    );
}
