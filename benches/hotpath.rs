//! Hot-path microbenchmarks (§Perf): simulator event-dispatch throughput,
//! reference-model throughput, end-to-end sample latency, and coordinator
//! scaling — the numbers the performance pass optimizes and EXPERIMENTS.md
//! §Perf records.

use menage::accel::Menage;
use menage::analog::AnalogParams;
use menage::bench::Bencher;
use menage::config::{AcceleratorConfig, ModelConfig};
use menage::coordinator::Coordinator;
use menage::datasets::{Dataset, DatasetKind};
use menage::mapping::Strategy;
use menage::snn::{reference_forward, QuantNetwork, SpikeTrain};
use menage::util::rng::Rng;

fn main() {
    let mut mcfg = ModelConfig::nmnist_mlp();
    mcfg.timesteps = 10;
    let mut rng = Rng::new(3);
    let net = QuantNetwork::random(&mcfg, 0.5, &mut rng);
    let cfg = AcceleratorConfig::accel1();
    let ds = Dataset::new(DatasetKind::NMnist, 5, mcfg.timesteps);
    let samples: Vec<SpikeTrain> =
        ds.balanced_split(8, 0).into_iter().map(|s| s.events).collect();

    let b = Bencher::default();

    // Reference model (the digital golden): samples/s and synaptic events/s.
    let r = b.run("reference_forward", || {
        reference_forward(&net, &samples[0]).unwrap()
    });
    println!("  reference: {:.1} samples/s", r.throughput(1.0));

    // Cycle-accurate chip: per-sample latency and synaptic-event rate.
    let mut chip =
        Menage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 7).unwrap();
    let mut i = 0usize;
    let r = b.run("chip_run_sample", || {
        i = (i + 1) % samples.len();
        chip.run(&samples[i]).unwrap()
    });
    let macs_per_run = chip.total_macs() as f64 / chip.inputs_processed as f64;
    println!(
        "  simulator: {:.1} samples/s, {:.1} M synaptic events/s (sim speed)",
        r.throughput(1.0),
        r.throughput(macs_per_run) / 1e6
    );

    // Mapping (build-time path).
    b.run("menage_build_full", || {
        Menage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 7).unwrap()
    });

    // Coordinator scaling: 1 vs 4 workers on a 256-sample batch.
    for workers in [1usize, 4] {
        let chip =
            Menage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 7).unwrap();
        let batch: Vec<(SpikeTrain, Option<usize>)> = (0..256)
            .map(|k| (samples[k % samples.len()].clone(), Some(0)))
            .collect();
        let t0 = std::time::Instant::now();
        let mut coord = Coordinator::new(&chip, workers);
        let res = coord.run_batch(batch).unwrap();
        let dt = t0.elapsed();
        coord.shutdown();
        println!(
            "  coordinator x{workers}: {} samples in {dt:?} → {:.1} samples/s",
            res.len(),
            res.len() as f64 / dt.as_secs_f64()
        );
    }
}
