//! Table I reproduction: model details and training parameters, plus the
//! measured accuracy-before/after-compression rows from the python
//! pipeline (artifacts/manifest.json, written by `make artifacts`).

use menage::bench::Table;
use menage::config::ModelConfig;
use menage::runtime::artifacts_dir;
use menage::util::json::Json;

fn main() {
    let nm = ModelConfig::nmnist_mlp();
    let cf = ModelConfig::cifar10dvs_mlp();

    let mut t = Table::new(
        "Table I — details of the models and their training parameters",
        &["Attribute", "N-MNIST", "CIFAR10-DVS"],
    );
    t.row(&[
        "Number of Parameters".into(),
        format!("{:.2} M (paper: 0.49 M)", nm.num_params() as f64 / 1e6),
        format!("{:.1} M (paper: 33.4 M)", cf.num_params() as f64 / 1e6),
    ]);
    t.row(&[
        "Hidden Layers".into(),
        "3 (200/100/40)".into(),
        "4 (1000/500/200/100)".into(),
    ]);
    t.row(&["Output Neurons".into(), "10".into(), "10".into()]);
    t.row(&["Learning Rate".into(), "1e-3".into(), "5e-4 (paper: 1e-3)".into()]);
    t.row(&[
        "Pruning".into(),
        "L1 unstructured, 50%".into(),
        "L1 unstructured, 50%".into(),
    ]);
    t.row(&[
        "Quantization".into(),
        "8-bit post-training".into(),
        "8-bit post-training".into(),
    ]);
    t.print();

    // Measured accuracy rows (quick-budget synthetic-data training).
    let manifest = artifacts_dir().join("manifest.json");
    match std::fs::read_to_string(&manifest).ok().and_then(|s| Json::parse(&s).ok()) {
        Some(j) => {
            let mut acc = Table::new(
                "Accuracy before/after prune+quant (synthetic data, quick budget)",
                &["model", "dense", "pruned+quantized", "paper (real data)"],
            );
            for (name, paper) in [
                ("nmnist", "94.75% → 94.1%"),
                ("cifar_small", "65.38% → 65.03%"),
            ] {
                if let Some(m) = j.opt(name) {
                    let dense = m.get("acc_dense").and_then(|v| v.as_f64()).unwrap_or(f64::NAN);
                    let quant = m.get("acc_quant").and_then(|v| v.as_f64()).unwrap_or(f64::NAN);
                    acc.row(&[
                        name.into(),
                        format!("{:.1}%", dense * 100.0),
                        format!("{:.1}%", quant * 100.0),
                        paper.into(),
                    ]);
                }
            }
            acc.print();
            println!(
                "\nNote: absolute accuracies are not comparable (synthetic event\n\
                 data, minutes-scale training); the reproduced *shape* is the\n\
                 small compression drop on N-MNIST. See EXPERIMENTS.md §Table I."
            );
        }
        None => println!("(manifest.json not found — run `make artifacts` for accuracy rows)"),
    }
}
