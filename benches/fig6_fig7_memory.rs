//! Figures 6 & 7 reproduction: average MEM_S&N utilization per time step
//! while one input streams through Accel₁ (N-MNIST) and Accel₂
//! (CIFAR10-DVS), per MX-NEURACORE.
//!
//! The paper's headline observations to reproduce:
//!   * utilization stays low most of the time (event sparsity);
//!   * bursts appear at specific steps/layers when many spikes coincide;
//!   * CIFAR10-DVS ≫ N-MNIST activity and hence memory usage.

use menage::accel::Menage;
use menage::analog::AnalogParams;
use menage::bench::{ascii_chart, emit_series};
use menage::config::{AcceleratorConfig, ModelConfig};
use menage::datasets::{Dataset, DatasetKind};
use menage::mapping::Strategy;
use menage::runtime::artifacts_dir;
use menage::snn::{QuantNetwork, SpikeTrain};
use menage::trace::MemoryTrace;
use menage::util::rng::Rng;
use menage::util::tensorfile::TensorFile;

fn network(base: &str, mcfg: &ModelConfig) -> QuantNetwork {
    TensorFile::load(artifacts_dir().join(format!("{base}.weights.mtz")))
        .and_then(|tf| QuantNetwork::from_tensorfile(base, &tf))
        .unwrap_or_else(|_| {
            let mut rng = Rng::new(7);
            QuantNetwork::random(mcfg, 0.5, &mut rng)
        })
}

fn eval_inputs(base: &str, kind: DatasetKind, t: usize, n: usize) -> Vec<SpikeTrain> {
    if let Ok(tf) = TensorFile::load(artifacts_dir().join(format!("{base}.eval.mtz"))) {
        if let Ok(ev) = tf.get("events") {
            let dims = ev.dims().to_vec();
            if dims[1] == t {
                let raw = ev.as_u8().unwrap();
                let (cnt, t, d) = (dims[0].min(n), dims[1], dims[2]);
                return (0..cnt)
                    .map(|i| {
                        let mut st = SpikeTrain::new(d, t);
                        for (ti, step) in st.spikes.iter_mut().enumerate() {
                            for j in 0..d {
                                if raw[i * t * d + ti * d + j] != 0 {
                                    step.push(j as u32);
                                }
                            }
                        }
                        st
                    })
                    .collect();
            }
        }
    }
    let ds = Dataset::new(kind, 5, t);
    ds.balanced_split(n, 0).into_iter().map(|s| s.events).collect()
}

fn run_fig(
    fig: &str,
    base: &str,
    mcfg: &ModelConfig,
    cfg: &AcceleratorConfig,
    kind: DatasetKind,
    samples: usize,
) -> MemoryTrace {
    let net = network(base, mcfg);
    let inputs = eval_inputs(base, kind, net.timesteps, samples);
    let mut chip =
        Menage::build(&net, cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 7).unwrap();
    for st in &inputs {
        chip.run(st).unwrap();
    }
    let trace = MemoryTrace::from_chip(&chip, kind.name(), net.timesteps, inputs.len());
    println!(
        "\n== {fig}: {} on {} ({} samples averaged) ==",
        kind.name(),
        cfg.name,
        inputs.len()
    );
    for core in &trace.cores {
        let x: Vec<f64> = (0..core.kb_per_step.len()).map(|i| i as f64).collect();
        emit_series(&format!("{fig}_core{}", core.core), &x, &core.kb_per_step);
        println!(
            "{}",
            ascii_chart(
                &format!("{fig} core {} MEM_S&N KB/step", core.core),
                &core.kb_per_step,
                5
            )
        );
    }
    println!("mean {:.1} KB, peak {:.1} KB", trace.mean_kb(), trace.peak_kb());
    trace
}

fn main() {
    let f6 = run_fig(
        "fig6",
        "nmnist",
        &ModelConfig::nmnist_mlp(),
        &AcceleratorConfig::accel1(),
        DatasetKind::NMnist,
        16,
    );
    let f7 = run_fig(
        "fig7",
        "cifar_small",
        &ModelConfig::cifar10dvs_mlp_small(),
        &AcceleratorConfig::accel2(),
        DatasetKind::Cifar10DvsSmall,
        12,
    );

    println!("\n== shape checks ==");
    println!(
        "CIFAR10-DVS mean ({:.1} KB) > N-MNIST mean ({:.1} KB): {}",
        f7.mean_kb(),
        f6.mean_kb(),
        if f7.mean_kb() > f6.mean_kb() { "holds" } else { "FAILS" }
    );
    println!(
        "bursty (peak/mean) — fig6: {:.1}×, fig7: {:.1}×",
        f6.peak_kb() / f6.mean_kb().max(1e-9),
        f7.peak_kb() / f7.mean_kb().max(1e-9)
    );
}
