//! Mapping ablation (DESIGN.md X2): the paper's ILP against greedy /
//! first-fit / round-robin baselines, plus exact-vs-flow optimality
//! certification and solver timing.

use menage::bench::{Bencher, Table};
use menage::config::AcceleratorConfig;
use menage::mapping::{in_degrees, map_layer, Strategy};
use menage::snn::{LifParams, QuantLayer};
use menage::util::rng::Rng;

fn random_layer(in_dim: usize, out_dim: usize, sparsity: f64, seed: u64) -> QuantLayer {
    let mut rng = Rng::new(seed);
    let mut w = vec![0i8; in_dim * out_dim];
    for x in w.iter_mut() {
        if !rng.bernoulli(sparsity) {
            *x = rng.range_inclusive(-127, 127) as i8;
        }
    }
    QuantLayer::new(in_dim, out_dim, w, 0.01, LifParams::default()).unwrap()
}

fn main() {
    // --- quality: balance + rounds on the N-MNIST layer-0-like instance --
    let layer = random_layer(400, 200, 0.5, 3);
    let cfg = AcceleratorConfig::accel1(); // M=10, N=16, capacity 160 < 200
    let mut t = Table::new(
        "Mapping strategies on a 400→200 layer (Accel₁ geometry, 2 rounds)",
        &["strategy", "rounds", "assigned", "peak engine load", "balance vs ILP"],
    );
    let in_deg = in_degrees(&layer);
    let total_load: usize = in_deg.iter().sum();
    let ideal = total_load as f64 / (2 * cfg.a_neurons_per_core) as f64;
    let mut flow_peak = 0usize;
    for strat in [Strategy::IlpFlow, Strategy::Greedy, Strategy::FirstFit, Strategy::RoundRobin] {
        let mp = map_layer(&layer, &cfg, strat).unwrap();
        mp.validate(&layer, &cfg).unwrap();
        let peak = mp.peak_engine_load(&layer, cfg.a_neurons_per_core);
        if strat == Strategy::IlpFlow {
            flow_peak = peak;
        }
        t.row(&[
            strat.name().into(),
            mp.rounds.len().to_string(),
            mp.assigned_count().to_string(),
            format!("{peak} (ideal ≈ {ideal:.0})"),
            format!("{:.2}×", peak as f64 / ideal),
        ]);
    }
    t.print();
    println!("ILP(flow) peak load {flow_peak} vs ideal {ideal:.0}");

    // --- optimality: flow matches the exact eqs. (3)-(7) B&B ------------
    let mut cert = Table::new(
        "Exact-ILP certification (small instances)",
        &["instance", "exact assigned", "flow assigned", "exact B&B nodes", "agree"],
    );
    for seed in 0..4u64 {
        let l = random_layer(12, 10, 0.4, seed);
        let mut small = AcceleratorConfig::accel1();
        small.a_neurons_per_core = 3;
        small.a_syns_per_core = 3;
        small.virtual_per_a_neuron = 2;
        let exact = map_layer(&l, &small, Strategy::IlpExact).unwrap();
        let flow = map_layer(&l, &small, Strategy::IlpFlow).unwrap();
        cert.row(&[
            format!("12→10 seed {seed}"),
            exact.assigned_count().to_string(),
            flow.assigned_count().to_string(),
            exact.solver_nodes.to_string(),
            (exact.assigned_count() == flow.assigned_count()).to_string(),
        ]);
        assert_eq!(exact.assigned_count(), flow.assigned_count());
    }
    cert.print();

    // --- solver timing ----------------------------------------------------
    let b = Bencher::default();
    println!();
    let layer_small = random_layer(100, 60, 0.5, 9);
    let cfg_small = {
        let mut c = AcceleratorConfig::accel1();
        c.virtual_per_a_neuron = 8;
        c
    };
    b.run("map_flow_100x60", || {
        map_layer(&layer_small, &cfg_small, Strategy::IlpFlow).unwrap()
    });
    b.run("map_greedy_100x60", || {
        map_layer(&layer_small, &cfg_small, Strategy::Greedy).unwrap()
    });
    let layer_big = random_layer(2312, 200, 0.5, 10);
    let r = b.run("map_flow_nmnist_l0", || {
        map_layer(&layer_big, &AcceleratorConfig::accel1(), Strategy::IlpFlow).unwrap()
    });
    println!(
        "production mapper on the N-MNIST input layer: {:.1} ms/solve",
        r.mean.as_secs_f64() * 1e3
    );
}
