//! Serving-path microbenchmarks: wire codec throughput (the per-request
//! encode/decode cost the host-side event-delivery path pays) and a
//! loopback end-to-end round trip.
//!
//! The machine-readable serving artifact (`BENCH_serve.json`) is emitted
//! by `menage loadgen` (see `make smoke-serve`), which measures a real
//! multi-connection run; this bench prints `BENCH` lines for the codec
//! and single-connection layers underneath it.

use std::time::Duration;

use menage::bench::Bencher;
use menage::config::ModelConfig;
use menage::serve::protocol::{
    encode_frame, Frame, FrameKind, FrameReader, InferRequest, DEFAULT_MAX_FRAME_LEN,
};
use menage::serve::{Client, ServeConfig, Server};
use menage::snn::{QuantNetwork, SpikeTrain};
use menage::util::rng::Rng;

fn main() {
    let b = Bencher::default();
    let mut rng = Rng::new(17);

    // Codec: a realistic request train (NMNIST-sized, 10 steps, 10% rate).
    let train = SpikeTrain::bernoulli(2312, 10, 0.1, &mut rng);
    let spikes = train.total_spikes() as f64;
    let r_enc = b.run("wire_encode_train", || {
        let mut out = Vec::with_capacity(train.wire_len());
        train.write_wire(&mut out);
        out
    });
    println!(
        "  encode: {:.1} M spikes/s",
        r_enc.throughput(spikes) / 1e6
    );
    let mut wire = Vec::new();
    train.write_wire(&mut wire);
    let r_dec = b.run("wire_decode_train", || SpikeTrain::read_wire(&wire).unwrap());
    println!(
        "  decode(+validate): {:.1} M spikes/s",
        r_dec.throughput(spikes) / 1e6
    );

    // Frame layer: request encode → frame → reassembly → decode.
    let req = InferRequest { id: 1, deadline_ms: 0, label: None, train: train.clone() };
    let framed = encode_frame(FrameKind::InferRequest, &req.encode());
    let r_frame = b.run("frame_roundtrip", || {
        let mut fr = FrameReader::new(DEFAULT_MAX_FRAME_LEN);
        let Frame { payload, .. } =
            fr.read_frame(&mut std::io::Cursor::new(&framed)).unwrap().unwrap();
        InferRequest::decode(&payload).unwrap()
    });
    println!("  frame roundtrip: {:.1}k frames/s", r_frame.throughput(1.0) / 1e3);

    // Observability plane: the per-request cost route_response pays to
    // record four stage spans + offer the trace to the slow ring (the ring
    // is kept full so the common rejected-offer fast path dominates).
    let stages = menage::obs::StageHistograms::default();
    let ring = menage::obs::SlowTraceRing::default();
    for i in 0..64 {
        ring.offer(menage::obs::TraceRecord {
            id: i,
            total_us: 1_000_000 + i,
            queue_us: 1,
            dispatch_us: 1,
            step_us: 1,
            egress_us: 1,
        });
    }
    let mut i = 0u64;
    let r_obs = b.run("obs_record_stages", || {
        i += 1;
        stages.queue.record_micros(i % 512);
        stages.dispatch.record_micros(i % 64);
        stages.step.record_micros(i % 4096);
        stages.egress.record_micros(i % 32);
        ring.offer(menage::obs::TraceRecord {
            id: i,
            total_us: i % 4096, // always below the ring floor → fast path
            queue_us: i % 512,
            dispatch_us: i % 64,
            step_us: i % 4096,
            egress_us: i % 32,
        });
    });
    println!("  obs record: {:.1} M records/s", r_obs.throughput(1.0) * 1e-6);

    // Loopback end-to-end: one synchronous client against a small chip.
    let mut mcfg = ModelConfig::nmnist_mlp();
    mcfg.timesteps = 10;
    let mut rng = Rng::new(3);
    let net = QuantNetwork::random(&mcfg, 0.5, &mut rng);
    let chip = menage::accel::Menage::build(
        &net,
        &menage::config::AcceleratorConfig::accel1(),
        menage::mapping::Strategy::IlpFlow,
        &menage::analog::AnalogParams::ideal(),
        7,
    )
    .unwrap();
    let server = Server::start(
        &chip,
        "127.0.0.1:0",
        ServeConfig {
            workers: 2,
            lanes_per_worker: 4,
            fill_wait: Duration::from_micros(200),
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let mut client = Client::connect(server.local_addr()).unwrap();
    let input = SpikeTrain::bernoulli(net.input_dim(), 10, 0.1, &mut rng);
    let r_rt = b.run("loopback_sync_infer", || client.infer(&input).unwrap());
    println!(
        "  loopback sync: {:.1} req/s (1 connection, unpipelined)",
        r_rt.throughput(1.0)
    );
    drop(client);
    server.shutdown();
    println!("(run `make smoke-serve` for the multi-connection BENCH_serve.json numbers)");
}
