//! CIFAR10-DVS end-to-end driver on the Accel₂ design point, running the
//! **compressed convolutional** model: conv layers store one kernel
//! (`oc·ic·kh·kw` taps) instead of a dense `[out,in]` matrix, and the
//! engines regenerate each MEM_S&N row arithmetically at dispatch time.
//!
//! The driver always builds a second chip from the dense `expand_conv()`
//! oracle and gates on bit-identical behaviour — spike trains *and* cycle
//! counts must agree on every sample, else the process exits non-zero
//! (`make smoke-conv` rides on this). Prefers the trained
//! `cifar_conv.weights.mtz` artifact when present and falls back to a
//! synthetic compressed net of the same topology, so the gate also runs in
//! artifact-free CI checkouts.
//!
//! ```bash
//! cargo run --release --example cifar10dvs_e2e        # synthetic fallback
//! make artifacts && cargo run --release --example cifar10dvs_e2e
//! ```

use menage::accel::Menage;
use menage::analog::AnalogParams;
use menage::config::AcceleratorConfig;
use menage::energy::{report, EnergyModel, PAPER_ACCEL2_TOPS_W};
use menage::mapping::{layer_weight_bytes, Strategy};
use menage::runtime::artifacts_dir;
use menage::snn::{ConvSpec, QuantNetwork, SpikeTrain};
use menage::util::rng::Rng;
use menage::util::tensorfile::TensorFile;

/// The conv stack the python `cifar_conv` preset trains: 2×32×32 events →
/// 8×16×16 → 8×8×8, both 3×3 stride-2 pad-1 (matches `--model cifar_conv`
/// in the CLI).
fn conv_specs() -> Vec<ConvSpec> {
    let c1 = ConvSpec {
        in_channels: 2,
        in_h: 32,
        in_w: 32,
        out_channels: 8,
        kernel_h: 3,
        kernel_w: 3,
        stride: 2,
        padding: 1,
    };
    let c2 = ConvSpec { in_channels: 8, in_h: 16, in_w: 16, ..c1 };
    vec![c1, c2]
}

fn random_input(dim: usize, t: usize, rate: f64, seed: u64) -> SpikeTrain {
    let mut rng = Rng::new(seed);
    let mut st = SpikeTrain::new(dim, t);
    for step in st.spikes.iter_mut() {
        for j in 0..dim {
            if rng.bernoulli(rate) {
                step.push(j as u32);
            }
        }
    }
    st
}

/// Load the trained artifact if present, else synthesize the same topology.
fn load_model(n_inputs: usize) -> anyhow::Result<(QuantNetwork, Vec<SpikeTrain>, Vec<Option<usize>>)> {
    let dir = artifacts_dir();
    let wpath = dir.join("cifar_conv.weights.mtz");
    if wpath.exists() {
        let net = QuantNetwork::from_tensorfile("cifar_conv", &TensorFile::load(&wpath)?)?;
        let etf = TensorFile::load(dir.join("cifar_conv.eval.mtz"))?;
        let events = etf.get("events")?;
        let dims = events.dims().to_vec();
        let raw = events.as_u8()?;
        let labels = etf.get("labels")?.as_i32()?;
        let (n, t, d) = (dims[0].min(n_inputs), dims[1], dims[2]);
        let mut inputs = Vec::with_capacity(n);
        for i in 0..n {
            let mut st = SpikeTrain::new(d, t);
            for (ti, step) in st.spikes.iter_mut().enumerate() {
                for j in 0..d {
                    if raw[i * t * d + ti * d + j] != 0 {
                        step.push(j as u32);
                    }
                }
            }
            inputs.push(st);
        }
        let labels = labels.iter().take(n).map(|&l| Some(l as usize)).collect();
        println!("model: trained artifact {}", wpath.display());
        return Ok((net, inputs, labels));
    }
    println!("model: synthetic (no {} — run `make artifacts`)", wpath.display());
    let mut rng = Rng::new(7);
    let net = QuantNetwork::random_conv("cifar10dvs_conv", &conv_specs(), 10, 16, 0.5, &mut rng)?;
    let dim = net.layers[0].in_dim;
    let inputs =
        (0..n_inputs).map(|i| random_input(dim, net.timesteps, 0.25, 100 + i as u64)).collect();
    Ok((net, inputs, vec![None; n_inputs]))
}

fn main() -> anyhow::Result<()> {
    let (net, inputs, labels) = load_model(16)?;
    let oracle = net.expand_convs()?;
    let cfg = AcceleratorConfig::accel2();

    println!(
        "cifar10dvs conv model: {} stored weights ({} dense), T={}",
        net.stored_weights(),
        oracle.stored_weights(),
        net.timesteps
    );
    let wb_c = layer_weight_bytes(&net, cfg.weight_bits);
    let wb_e = layer_weight_bytes(&oracle, cfg.weight_bits);
    for (i, (c, e)) in wb_c.iter().zip(&wb_e).enumerate() {
        let kind = if net.layers[i].is_compressed() { "conv" } else { "dense" };
        println!("  layer {i} ({kind}): {c} B compressed vs {e} B expanded");
    }
    let (tot_c, tot_e) = (wb_c.iter().sum::<usize>(), wb_e.iter().sum::<usize>());
    println!(
        "weight SRAM: {:.1} KB vs {:.1} KB expanded ({:.0}× smaller)",
        tot_c as f64 / 1024.0,
        tot_e as f64 / 1024.0,
        tot_e as f64 / tot_c as f64
    );

    let mut chip = Menage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 7)?;
    let mut oracle_chip = Menage::build(&oracle, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 7)?;

    // --- the gate: compressed must be bit-identical to the dense oracle ---
    let n = inputs.len();
    let mut correct = 0usize;
    let t0 = std::time::Instant::now();
    for (i, (st, label)) in inputs.iter().zip(&labels).enumerate() {
        let a = chip.run(st)?;
        let b = oracle_chip.run(st)?;
        if a.trains != b.trains || a.cycles != b.cycles {
            eprintln!(
                "DIVERGENCE at sample {i}: compressed (pred {}, {} cycles) vs \
                 expanded (pred {}, {} cycles)",
                a.predicted_class(),
                a.cycles,
                b.predicted_class(),
                b.cycles
            );
            std::process::exit(1);
        }
        if *label == Some(a.predicted_class()) {
            correct += 1;
        }
    }
    let wall = t0.elapsed();
    println!("\n== cifar10dvs conv on accel2 ==");
    println!("gate:        PASS — {n} samples bit-identical to the expand_conv oracle");
    if labels.iter().any(|l| l.is_some()) {
        println!("accuracy:    {:.4} ({correct}/{n})", correct as f64 / n as f64);
    }
    println!(
        "throughput:  {:.1} samples/s on each representation (wall {wall:?})",
        2.0 * n as f64 / wall.as_secs_f64()
    );
    let eff = report(&chip, &EnergyModel::paper_90nm(cfg.clock_hz));
    println!(
        "TOPS/W:      {:.2}  (paper Accel₂: {PAPER_ACCEL2_TOPS_W})",
        eff.tops_per_watt
    );
    for (l, core) in chip.cores.iter().enumerate() {
        println!(
            "core {l}: {} rounds, {} SN rows, {} weight bytes (oracle {})",
            core.rounds(),
            core.image_sn_rows(),
            core.weight_bytes(),
            oracle_chip.cores[l].weight_bytes()
        );
    }
    Ok(())
}
