//! CIFAR10-DVS end-to-end driver: the paper's second (larger, denser)
//! workload on the Accel₂ design point — 20 A-NEURONs × 32 virtual
//! neurons per core, 5 MX-NEURACOREs.
//!
//! Uses the scaled-down CIFAR10-DVS artifact (`cifar_small`, 32×32 input;
//! the full 128×128 model is identical code but ~30 min of CPU training —
//! see DESIGN.md). Reports the same metrics as nmnist_e2e plus the
//! activity comparison the paper's Figures 6–7 rest on.
//!
//! ```bash
//! make artifacts && cargo run --release --example cifar10dvs_e2e
//! ```

use anyhow::Context;
use menage::accel::Menage;
use menage::analog::AnalogParams;
use menage::config::AcceleratorConfig;
use menage::coordinator::Coordinator;
use menage::energy::{report, EnergyModel, PAPER_ACCEL2_TOPS_W};
use menage::mapping::Strategy;
use menage::runtime::artifacts_dir;
use menage::snn::{QuantNetwork, SpikeTrain};
use menage::trace::MemoryTrace;
use menage::util::tensorfile::TensorFile;

fn main() -> anyhow::Result<()> {
    let dir = artifacts_dir();
    let tf = TensorFile::load(dir.join("cifar_small.weights.mtz"))
        .context("run `make artifacts` first")?;
    let net = QuantNetwork::from_tensorfile("cifar_small", &tf)?;
    println!(
        "cifar10dvs(small) model: {} params / {} nnz, T={}",
        net.num_params(),
        net.nnz(),
        net.timesteps
    );

    let etf = TensorFile::load(dir.join("cifar_small.eval.mtz"))?;
    let events = etf.get("events")?;
    let dims = events.dims().to_vec();
    let raw = events.as_u8()?;
    let labels = etf.get("labels")?.as_i32()?;
    let (n, t, d) = (dims[0].min(40), dims[1], dims[2]);
    let mut inputs = Vec::with_capacity(n);
    for i in 0..n {
        let mut st = SpikeTrain::new(d, t);
        for (ti, step) in st.spikes.iter_mut().enumerate() {
            for j in 0..d {
                if raw[i * t * d + ti * d + j] != 0 {
                    step.push(j as u32);
                }
            }
        }
        inputs.push(st);
    }
    let input_rate = inputs
        .iter()
        .map(|s| s.rate())
        .sum::<f64>()
        / inputs.len() as f64;
    println!("eval: {n} samples, input spike rate {input_rate:.4}");

    let cfg = AcceleratorConfig::accel2();
    let chip = Menage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 7)?;
    for (l, core) in chip.cores.iter().enumerate() {
        println!(
            "core {l}: {} rounds, {} SN rows, {} weight bytes",
            core.rounds(),
            core.image_sn_rows(),
            core.weight_bytes()
        );
    }
    let mut coord = Coordinator::new(&chip, 4);
    let t0 = std::time::Instant::now();
    let batch: Vec<(SpikeTrain, Option<usize>)> = inputs
        .iter()
        .zip(labels)
        .map(|(st, &l)| (st.clone(), Some(l as usize)))
        .collect();
    let responses = coord.run_batch(batch)?;
    let wall = t0.elapsed();

    let correct = responses
        .iter()
        .filter(|r| r.label == Some(r.predicted))
        .count();
    let chips = coord.shutdown();
    let merged = chips.into_iter().next().unwrap();

    println!("\n== cifar10dvs(small) on accel2 ==");
    println!("accuracy:    {:.4} ({correct}/{n})", correct as f64 / n as f64);
    println!(
        "throughput:  {:.1} samples/s (wall {wall:?})",
        n as f64 / wall.as_secs_f64()
    );
    let eff = report(&merged, &EnergyModel::paper_90nm(cfg.clock_hz));
    println!(
        "TOPS/W:      {:.2}  (paper Accel₂: {PAPER_ACCEL2_TOPS_W})",
        eff.tops_per_watt
    );
    let trace = MemoryTrace::from_chip(&merged, "cifar10dvs_syn", t, n / 4);
    println!(
        "MEM_S&N:     mean {:.1} KB, peak {:.1} KB",
        trace.mean_kb(),
        trace.peak_kb()
    );
    println!(
        "\nThe paper's Figs 6–7 contrast: CIFAR10-DVS event rate ({input_rate:.3}) \
         drives much higher memory traffic than N-MNIST — compare with \
         nmnist_e2e's trace output."
    );
    Ok(())
}
