//! End-to-end N-MNIST driver (DESIGN.md §5) — the full-system validation:
//!
//! 1. load the JAX-trained, L1-pruned, 8-bit-quantized weights
//!    (`artifacts/nmnist.weights.mtz`, produced by `make artifacts`);
//! 2. ILP-map onto Accel₁ and distill the controller memories;
//! 3. run the exported eval split through the cycle-accurate simulator via
//!    the multi-worker coordinator;
//! 4. cross-check every prediction against (a) the golden counts the
//!    python pipeline recorded and (b) the JAX model executed live through
//!    PJRT from rust;
//! 5. report accuracy, throughput, TOPS/W and the memory-trace summary.
//!
//! ```bash
//! make artifacts && cargo run --release --example nmnist_e2e
//! ```

use anyhow::Context;
use menage::accel::Menage;
use menage::analog::AnalogParams;
use menage::config::AcceleratorConfig;
use menage::coordinator::Coordinator;
use menage::energy::{report, EnergyModel, PAPER_ACCEL1_TOPS_W};
use menage::mapping::Strategy;
use menage::runtime::{artifacts_dir, cpu_client, pjrt_available, GoldenModel};
use menage::snn::{QuantNetwork, SpikeTrain};
use menage::trace::MemoryTrace;
use menage::util::tensorfile::TensorFile;

fn main() -> anyhow::Result<()> {
    let dir = artifacts_dir();
    let tf = TensorFile::load(dir.join("nmnist.weights.mtz"))
        .context("run `make artifacts` first")?;
    let net = QuantNetwork::from_tensorfile("nmnist", &tf)?;
    println!(
        "nmnist model: {} params / {} nnz (sparsity {:.2}), T={}",
        net.num_params(),
        net.nnz(),
        net.sparsity(),
        net.timesteps
    );

    // Eval split exported by aot.py.
    let etf = TensorFile::load(dir.join("nmnist.eval.mtz"))?;
    let events = etf.get("events")?;
    let dims = events.dims().to_vec();
    let raw = events.as_u8()?;
    let labels = etf.get("labels")?.as_i32()?;
    let golden_counts = etf.get("golden_counts")?.as_f32()?;
    let (n, t, d) = (dims[0], dims[1], dims[2]);
    let classes = golden_counts.len() / n;
    let mut inputs = Vec::with_capacity(n);
    for i in 0..n {
        let mut st = SpikeTrain::new(d, t);
        for (ti, step) in st.spikes.iter_mut().enumerate() {
            for j in 0..d {
                if raw[i * t * d + ti * d + j] != 0 {
                    step.push(j as u32);
                }
            }
        }
        inputs.push(st);
    }
    println!("eval split: {n} samples of {t}×{d} events");

    // Build the chip and the coordinator.
    let cfg = AcceleratorConfig::accel1();
    let chip = Menage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 7)?;
    let mut coord = Coordinator::new(&chip, 4);
    let t0 = std::time::Instant::now();
    let batch: Vec<(SpikeTrain, Option<usize>)> = inputs
        .iter()
        .zip(labels)
        .map(|(st, &l)| (st.clone(), Some(l as usize)))
        .collect();
    let responses = coord.run_batch(batch)?;
    let wall = t0.elapsed();

    // Cross-check 1: recorded golden counts (python's own predictions).
    let mut agree_recorded = 0usize;
    for (i, resp) in responses.iter().enumerate() {
        let row = &golden_counts[i * classes..(i + 1) * classes];
        let py_pred = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap().then(b.0.cmp(&a.0)))
            .unwrap()
            .0;
        if py_pred == resp.predicted {
            agree_recorded += 1;
        }
    }

    // Cross-check 2: live PJRT execution of the lowered HLO (skipped, not
    // fatal, on a build without the `pjrt` feature).
    let check = if pjrt_available() { inputs.len().min(16) } else { 0 };
    let mut agree_live = 0usize;
    if check > 0 {
        let client = cpu_client()?;
        let gm = GoldenModel::load(
            &client,
            dir.join("nmnist.hlo.txt"),
            t,
            d,
            classes,
        )?;
        for (st, resp) in inputs.iter().zip(&responses).take(check) {
            if gm.predict(st)? == resp.predicted {
                agree_live += 1;
            }
        }
    } else {
        eprintln!("live PJRT cross-check skipped: built without the `pjrt` feature");
    }

    let correct = responses
        .iter()
        .filter(|r| r.label == Some(r.predicted))
        .count();
    let chips = coord.shutdown();
    let mut merged = chips.into_iter().next().unwrap();
    let _ = &mut merged;

    println!("\n== nmnist end-to-end ==");
    println!("accuracy:             {:.4} ({correct}/{n})", correct as f64 / n as f64);
    println!("vs recorded golden:   {agree_recorded}/{n} agree");
    if check > 0 {
        println!("vs live PJRT golden:  {agree_live}/{check} agree");
    } else {
        println!("vs live PJRT golden:  skipped (no `pjrt` build/artifacts)");
    }
    println!(
        "throughput:           {:.1} samples/s (wall {wall:?})",
        n as f64 / wall.as_secs_f64()
    );
    let eff = report(&merged, &EnergyModel::paper_90nm(cfg.clock_hz));
    println!(
        "TOPS/W (this worker): {:.2}  (paper Accel₁: {PAPER_ACCEL1_TOPS_W})",
        eff.tops_per_watt
    );
    let trace = MemoryTrace::from_chip(&merged, "nmnist_syn", t, n / 4);
    println!(
        "MEM_S&N utilization:  mean {:.1} KB, peak {:.1} KB",
        trace.mean_kb(),
        trace.peak_kb()
    );

    anyhow::ensure!(agree_recorded == n, "simulator diverged from recorded golden");
    anyhow::ensure!(agree_live == check, "simulator diverged from live PJRT golden");
    println!("\nOK: all layers compose — simulator ≡ JAX/Pallas model.");
    Ok(())
}
