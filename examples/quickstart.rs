//! Quickstart: build a small model, map it onto Accel₁, run a few synthetic
//! inputs, check the simulator against the bit-exact reference model.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! No artifacts needed — everything is generated in-process.

use menage::accel::Menage;
use menage::analog::AnalogParams;
use menage::config::{AcceleratorConfig, ModelConfig};
use menage::datasets::{Dataset, DatasetKind};
use menage::energy::{report, EnergyModel};
use menage::mapping::Strategy;
use menage::snn::{reference_forward, QuantNetwork};
use menage::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // 1. A model config: N-MNIST topology from the paper's Table I.
    let mut mcfg = ModelConfig::nmnist_mlp();
    mcfg.timesteps = 10;

    // 2. A random quantized network (swap in QuantNetwork::from_tensorfile
    //    to load the python-trained weights from artifacts/).
    let mut rng = Rng::new(42);
    let net = QuantNetwork::random(&mcfg, 0.5, &mut rng);
    println!("network: {} params, sparsity {:.2}", net.num_params(), net.sparsity());

    // 3. Map + distill + load onto Accel₁ with the ILP(flow) mapper.
    let cfg = AcceleratorConfig::accel1();
    let mut chip = Menage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 7)?;
    for (l, core) in chip.cores.iter().enumerate() {
        println!(
            "core {l}: {} rounds, {} MEM_S&N rows, {} weight bytes",
            core.rounds(),
            core.image_sn_rows(),
            core.weight_bytes()
        );
    }

    // 4. Run synthetic N-MNIST events and cross-check with the reference.
    let ds = Dataset::new(DatasetKind::NMnist, 3, mcfg.timesteps);
    let mut agree = 0;
    for sample in ds.balanced_split(10, 0) {
        let out = chip.run(&sample.events)?;
        let golden = reference_forward(&net, &sample.events)?;
        assert!(
            out.matches_reference(&golden),
            "simulator must match the reference bit-exactly in ideal mode"
        );
        agree += 1;
        println!(
            "label {} → predicted {} ({} cycles, {} output spikes)",
            sample.label,
            out.predicted_class(),
            out.cycles,
            out.output().total_spikes()
        );
    }
    println!("\n{agree}/10 runs matched the reference spike-for-spike");

    // 5. Energy report.
    let eff = report(&chip, &EnergyModel::paper_90nm(cfg.clock_hz));
    println!(
        "energy {:.3} µJ over {} MACs → {:.2} TOPS/W",
        eff.breakdown.total() * 1e6,
        chip.total_macs(),
        eff.tops_per_watt
    );
    Ok(())
}
