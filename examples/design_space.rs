//! Design-space exploration: sweep the virtual-neuron count N and the
//! A-NEURON count M around the paper's design points and report
//! utilization, rounds, cycles and TOPS/W — the quantitative backing for
//! the paper's §III-A virtual-neuron argument ("modeling more than one
//! neuron in each physically designed neuron engine").
//!
//! ```bash
//! cargo run --release --example design_space
//! ```

use menage::accel::Menage;
use menage::analog::AnalogParams;
use menage::bench::Table;
use menage::config::{AcceleratorConfig, ModelConfig};
use menage::datasets::{Dataset, DatasetKind};
use menage::energy::{report, EnergyModel};
use menage::mapping::Strategy;
use menage::snn::QuantNetwork;
use menage::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let mut mcfg = ModelConfig::nmnist_mlp();
    mcfg.timesteps = 10;
    let mut rng = Rng::new(3);
    let net = QuantNetwork::random(&mcfg, 0.5, &mut rng);
    let ds = Dataset::new(DatasetKind::NMnist, 5, mcfg.timesteps);
    let samples = ds.balanced_split(10, 0);

    let mut table = Table::new(
        "Virtual-neuron design sweep (N-MNIST workload, M=10 A-NEURONs)",
        &["N virt", "capacity", "rounds L0", "cycles/sample", "TOPS/W", "energy µJ"],
    );

    for n_virt in [1usize, 4, 8, 16, 32, 64] {
        let mut cfg = AcceleratorConfig::accel1();
        cfg.virtual_per_a_neuron = n_virt;
        // Exploration headroom: extreme design points need more MEM_S&N
        // rows than the Accel₁ silicon provisions (that capacity pressure
        // is itself a finding — see the table).
        cfg.memsn_rows = 1 << 20;
        let mut chip =
            Menage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 1)?;
        let mut total_cycles = 0u64;
        for s in &samples {
            total_cycles += chip.run(&s.events)?.cycles;
        }
        let eff = report(&chip, &EnergyModel::paper_90nm(cfg.clock_hz));
        table.row(&[
            n_virt.to_string(),
            cfg.core_capacity().to_string(),
            chip.cores[0].rounds().to_string(),
            (total_cycles / samples.len() as u64).to_string(),
            format!("{:.2}", eff.tops_per_watt),
            format!("{:.3}", eff.breakdown.total() * 1e6),
        ]);
    }
    table.print();
    println!(
        "\nReading: more virtual neurons per A-NEURON → fewer rounds → fewer\n\
         event replays → fewer cycles and higher efficiency, until a single\n\
         round suffices (the paper's N=16 choice for Accel₁); beyond that,\n\
         extra capacitors are idle area."
    );

    // Second sweep: A-NEURON count at fixed capacity (M × N = 160).
    let mut table2 = Table::new(
        "Engine-count sweep at fixed capacity M×N = 160",
        &["M engines", "N virt", "cycles/sample", "TOPS/W"],
    );
    for (m, n) in [(2usize, 80usize), (5, 32), (10, 16), (20, 8), (40, 4)] {
        let mut cfg = AcceleratorConfig::accel1();
        cfg.a_neurons_per_core = m;
        cfg.a_syns_per_core = m;
        cfg.virtual_per_a_neuron = n;
        cfg.memsn_rows = 1 << 20; // see above

        let mut chip =
            Menage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 1)?;
        let mut total_cycles = 0u64;
        for s in &samples {
            total_cycles += chip.run(&s.events)?.cycles;
        }
        let eff = report(&chip, &EnergyModel::paper_90nm(cfg.clock_hz));
        table2.row(&[
            m.to_string(),
            n.to_string(),
            (total_cycles / samples.len() as u64).to_string(),
            format!("{:.2}", eff.tops_per_watt),
        ]);
    }
    table2.print();
    println!(
        "\nReading: more engines drain MEM_S&N rows faster (row columns are\n\
         processed in parallel) but each row read costs M columns of SRAM\n\
         energy — the M=10/N=16 point balances the two, matching Accel₁."
    );
    Ok(())
}
