//! Figure 5 reproduction: SPICE-like waveform of the A-NEURON circuit —
//! input packets, integration (op-amp 1) voltage, output (comparator)
//! pulses — rendered as ASCII charts and optionally dumped as JSON.
//!
//! ```bash
//! cargo run --release --example waveform [-- out.json]
//! ```

use menage::analog::{ANeuron, AnalogParams};
use menage::bench::ascii_chart;
use menage::util::json::Json;
use menage::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let mut an = ANeuron::new(1, AnalogParams::paper());
    an.enable_capture();
    let mut rng = Rng::new(11);

    // Drive a pulse train like the paper's Fig. 5 stimulus: bursts of
    // sub-threshold packets punctuated by idle (leak-only) periods.
    for step in 0..60 {
        let packet = if (step / 10) % 2 == 0 && rng.bernoulli(0.8) {
            rng.uniform(0.2, 0.45)
        } else {
            0.0
        };
        an.process(0, packet, 1.0, 0.0);
        an.lif_leak(0.9);
    }

    let wf = an.waveform().to_vec();
    println!(
        "captured {} points over {:.1} ns; average power {:.1} nW (paper: 97 nW), \
         op latency {:.2} ns (paper: 6.72 ns)",
        wf.len(),
        an.now * 1e9,
        an.average_power() * 1e9,
        an.params.neuron_delay * 1e9
    );

    let v_in: Vec<f64> = wf.iter().map(|p| p.v_in).collect();
    let v_integ: Vec<f64> = wf.iter().map(|p| p.v_integ).collect();
    let v_out: Vec<f64> = wf.iter().map(|p| p.v_out).collect();
    println!("\n{}", ascii_chart("input packets (V)", &v_in, 6));
    println!("{}", ascii_chart("integration voltage (V)", &v_integ, 8));
    println!("{}", ascii_chart("output spikes (V)", &v_out, 4));

    let spikes = v_out.iter().filter(|&&v| v > 0.5).count();
    println!("output pulses: {spikes}");

    if let Some(out) = std::env::args().nth(1) {
        let j = Json::Arr(
            wf.iter()
                .map(|p| {
                    Json::obj(vec![
                        ("t_ns", (p.t * 1e9).into()),
                        ("v_in", p.v_in.into()),
                        ("v_integ", p.v_integ.into()),
                        ("v_out", p.v_out.into()),
                    ])
                })
                .collect(),
        );
        std::fs::write(&out, j.to_string())?;
        println!("wrote {out}");
    }
    Ok(())
}
