//! Vendored, dependency-free subset of the `anyhow` crate.
//!
//! The hermetic build environment has no crates.io access, so this crate
//! re-implements exactly the surface the workspace uses:
//!
//! * [`Error`] — boxed message + source chain, `Send + Sync`
//! * [`Result<T>`] with the `Error` default type parameter
//! * [`anyhow!`], [`bail!`], [`ensure!`] — format-string constructors
//! * [`Context`] — `.context(..)` / `.with_context(..)` on both
//!   `Result<T, E: Into<Error>>` and `Option<T>`
//! * blanket `From<E: std::error::Error + Send + Sync + 'static>` so `?`
//!   converts std errors (io, parse, utf8, …) transparently
//!
//! Formatting matches the real crate closely enough for logs and tests:
//! `{}` prints the outermost message, `{:#}` prints the whole chain joined
//! by `": "`, and `{:?}` prints the message plus a `Caused by:` list.
//!
//! Like the real crate, [`Error`] deliberately does **not** implement
//! `std::error::Error` — that would conflict with the blanket `From`.

use std::fmt;

/// Error type: an outermost message plus the chain of causes beneath it.
pub struct Error {
    /// `chain[0]` is the outermost (most recent context) message.
    chain: Vec<String>,
}

impl Error {
    /// Construct from a printable message.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { chain: vec![m.to_string()] }
    }

    /// Wrap with an outer context message (used by [`Context`]).
    pub fn wrap_msg(mut self, ctx: String) -> Self {
        self.chain.insert(0, ctx);
        self
    }

    /// The `chain[0]` outermost message.
    pub fn root_message(&self) -> &str {
        &self.chain[0]
    }

    /// Iterate the message chain, outermost first (mirrors
    /// `anyhow::Error::chain` loosely — yields strings, not errors).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — full chain, colon-joined (anyhow-compatible).
            write!(f, "{}", self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                if self.chain.len() > 2 {
                    write!(f, "\n    {i}: {c}")?;
                } else {
                    write!(f, "\n    {c}")?;
                }
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` alias with the usual default error parameter.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors, on both `Result` and `Option`.
pub trait Context<T> {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    /// Wrap the error value with lazily-evaluated context.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().wrap_msg(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap_msg(f().to_string()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(
                "condition failed: {}",
                stringify!($cond)
            ));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn from_std_error_and_display() {
        let e: Error = io_err().into();
        assert_eq!(e.to_string(), "missing");
    }

    #[test]
    fn context_chains_and_alternate_format() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening config").unwrap_err();
        assert_eq!(e.to_string(), "opening config");
        assert_eq!(format!("{e:#}"), "opening config: missing");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by"), "{dbg}");
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        assert_eq!(v.context("absent").unwrap_err().to_string(), "absent");
        assert_eq!(Some(3u8).with_context(|| "x").unwrap(), 3);
    }

    #[test]
    fn macros() {
        fn f(fail: bool) -> Result<u32> {
            ensure!(!fail, "flag was {fail}");
            Ok(7)
        }
        assert_eq!(f(false).unwrap(), 7);
        assert_eq!(f(true).unwrap_err().to_string(), "flag was true");
        fn g() -> Result<()> {
            bail!("code {}", 42);
        }
        assert_eq!(g().unwrap_err().to_string(), "code 42");
        let e = anyhow!("v={}", 1);
        assert_eq!(e.to_string(), "v=1");
    }

    #[test]
    fn question_mark_converts() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("5").unwrap(), 5);
        assert!(parse("x").is_err());
    }
}
