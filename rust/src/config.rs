//! Configuration system: accelerator, model and run configs.
//!
//! Configs are plain structs with the paper's two presets (Accel₁ / Accel₂,
//! §IV-A) and can be loaded from a TOML-subset file parsed by the in-tree
//! [`toml_lite`] parser (sections, `key = value` with strings, numbers,
//! booleans and flat arrays — exactly what accelerator configs need).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

/// Hardware description of one MENAGE instance (Figure 1).
#[derive(Debug, Clone, PartialEq)]
pub struct AcceleratorConfig {
    /// Human-readable name ("accel1", "accel2", ...).
    pub name: String,
    /// Number of MX-NEURACORE engines (one per model layer).
    pub num_cores: usize,
    /// A-NEURON engines per MX-NEURACORE (paper: M).
    pub a_neurons_per_core: usize,
    /// Storage capacitors (virtual neurons) per A-NEURON (paper: N).
    pub virtual_per_a_neuron: usize,
    /// A-SYN engines per MX-NEURACORE (C2C ladder multipliers). The paper
    /// pairs one A-SYN bank with the A-NEURON bank; we keep it explicit.
    pub a_syns_per_core: usize,
    /// Total weight SRAM per MX-NEURACORE, in bytes (8-bit weights).
    pub weight_mem_bytes: usize,
    /// System clock (paper: 103.2 MHz from the MX-NEURACORE simulation).
    pub clock_hz: f64,
    /// Event-memory (MEM_E) depth, in events.
    pub event_mem_depth: usize,
    /// MEM_S&N row count per core.
    pub memsn_rows: usize,
    /// Per-source-neuron fan-out limit used by ILP constraint (7).
    pub fanout_limit: usize,
    /// Weight bit width (paper: 8).
    pub weight_bits: u32,
    /// Technology node label (reporting only; paper: 90nm).
    pub tech_node: String,
}

impl AcceleratorConfig {
    /// Accel₁ (paper §IV-A): 4 MX-NEURACOREs, 10 A-NEURONs × 16 virtual
    /// neurons, 400 KB weight SRAM per core — sized for the N-MNIST MLP.
    pub fn accel1() -> Self {
        Self {
            name: "accel1".into(),
            num_cores: 4,
            a_neurons_per_core: 10,
            virtual_per_a_neuron: 16,
            a_syns_per_core: 10,
            weight_mem_bytes: 400 * 1024,
            clock_hz: 103.2e6,
            event_mem_depth: 4096,
            memsn_rows: 65536,
            fanout_limit: 4096,
            weight_bits: 8,
            tech_node: "90nm".into(),
        }
    }

    /// Accel₂ (paper §IV-A): 5 MX-NEURACOREs, 20 A-NEURONs × 32 virtual
    /// neurons, 20 MB weight SRAM per core — sized for the CIFAR10-DVS MLP.
    pub fn accel2() -> Self {
        Self {
            name: "accel2".into(),
            num_cores: 5,
            a_neurons_per_core: 20,
            virtual_per_a_neuron: 32,
            a_syns_per_core: 20,
            weight_mem_bytes: 20 * 1024 * 1024,
            clock_hz: 103.2e6,
            event_mem_depth: 65536,
            memsn_rows: 1 << 21,
            fanout_limit: 65536,
            weight_bits: 8,
            tech_node: "90nm".into(),
        }
    }

    /// Virtual-neuron capacity of one core: M × N model neurons
    /// simultaneously resident.
    pub fn core_capacity(&self) -> usize {
        self.a_neurons_per_core * self.virtual_per_a_neuron
    }

    /// Weight SRAM capacity in weights.
    pub fn weight_capacity(&self) -> usize {
        self.weight_mem_bytes * 8 / self.weight_bits as usize
    }

    /// Clock period in seconds.
    pub fn clock_period(&self) -> f64 {
        1.0 / self.clock_hz
    }

    /// Validate internal consistency.
    pub fn validate(&self) -> Result<()> {
        if self.num_cores == 0
            || self.a_neurons_per_core == 0
            || self.virtual_per_a_neuron == 0
            || self.a_syns_per_core == 0
        {
            bail!("{}: all engine counts must be positive", self.name);
        }
        if self.clock_hz <= 0.0 {
            bail!("{}: clock must be positive", self.name);
        }
        if !(1..=16).contains(&self.weight_bits) {
            bail!("{}: weight_bits must be in 1..=16", self.name);
        }
        if self.event_mem_depth == 0 || self.memsn_rows == 0 {
            bail!("{}: memories must be non-empty", self.name);
        }
        Ok(())
    }

    /// Load from a TOML-subset file (section `[accelerator]`).
    pub fn from_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::from_toml(&text)
    }

    /// Parse from TOML-subset text.
    pub fn from_toml(text: &str) -> Result<Self> {
        let doc = toml_lite::parse(text)?;
        let s = doc.section("accelerator")?;
        let base = match s.get_str("preset").ok() {
            Some("accel1") => Self::accel1(),
            Some("accel2") => Self::accel2(),
            Some(other) => bail!("unknown preset {other:?}"),
            None => Self::accel1(),
        };
        let cfg = Self {
            name: s.get_str("name").map(str::to_string).unwrap_or(base.name),
            num_cores: s.get_usize("num_cores").unwrap_or(base.num_cores),
            a_neurons_per_core: s
                .get_usize("a_neurons_per_core")
                .unwrap_or(base.a_neurons_per_core),
            virtual_per_a_neuron: s
                .get_usize("virtual_per_a_neuron")
                .unwrap_or(base.virtual_per_a_neuron),
            a_syns_per_core: s.get_usize("a_syns_per_core").unwrap_or(base.a_syns_per_core),
            weight_mem_bytes: s.get_usize("weight_mem_bytes").unwrap_or(base.weight_mem_bytes),
            clock_hz: s.get_f64("clock_hz").unwrap_or(base.clock_hz),
            event_mem_depth: s.get_usize("event_mem_depth").unwrap_or(base.event_mem_depth),
            memsn_rows: s.get_usize("memsn_rows").unwrap_or(base.memsn_rows),
            fanout_limit: s.get_usize("fanout_limit").unwrap_or(base.fanout_limit),
            weight_bits: s.get_usize("weight_bits").map(|v| v as u32).unwrap_or(base.weight_bits),
            tech_node: s.get_str("tech_node").map(str::to_string).unwrap_or(base.tech_node),
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

/// Model (network) description — layer widths plus LIF parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    /// Layer widths including input and output, e.g. `[2312, 200, 100, 40, 10]`.
    pub layer_sizes: Vec<usize>,
    /// Simulation time steps per input.
    pub timesteps: usize,
    /// LIF leak factor β (discrete-time: v ← βv + i).
    pub beta: f64,
    /// Firing threshold.
    pub v_threshold: f64,
    /// Reset potential.
    pub v_reset: f64,
}

impl ModelConfig {
    /// N-MNIST MLP from Table I: input 34×34×2 = 2312, hidden 200/100/40,
    /// output 10 (0.49 M parameters).
    pub fn nmnist_mlp() -> Self {
        Self {
            name: "nmnist_mlp".into(),
            layer_sizes: vec![2312, 200, 100, 40, 10],
            timesteps: 30,
            beta: 0.9,
            v_threshold: 1.0,
            v_reset: 0.0,
        }
    }

    /// CIFAR10-DVS MLP from Table I: input 128×128×2 = 32768, hidden
    /// 1000/500/200/100, output 10 (33.4 M parameters).
    pub fn cifar10dvs_mlp() -> Self {
        Self {
            name: "cifar10dvs_mlp".into(),
            layer_sizes: vec![32768, 1000, 500, 200, 100, 10],
            timesteps: 50,
            beta: 0.9,
            v_threshold: 1.0,
            v_reset: 0.0,
        }
    }

    /// A scaled-down CIFAR10-DVS variant (16× smaller input) used by quick
    /// tests and CI so the full pipeline stays exercisable in seconds.
    pub fn cifar10dvs_mlp_small() -> Self {
        Self {
            name: "cifar10dvs_mlp_small".into(),
            layer_sizes: vec![2048, 1000, 500, 200, 100, 10],
            timesteps: 20,
            beta: 0.9,
            v_threshold: 1.0,
            v_reset: 0.0,
        }
    }

    /// Number of weight parameters (dense).
    pub fn num_params(&self) -> usize {
        self.layer_sizes.windows(2).map(|w| w[0] * w[1]).sum()
    }

    /// Number of synaptic layers.
    pub fn num_layers(&self) -> usize {
        self.layer_sizes.len().saturating_sub(1)
    }

    pub fn validate(&self) -> Result<()> {
        if self.layer_sizes.len() < 2 {
            bail!("{}: need at least input and output layers", self.name);
        }
        if self.layer_sizes.iter().any(|&s| s == 0) {
            bail!("{}: zero-width layer", self.name);
        }
        if self.timesteps == 0 {
            bail!("{}: timesteps must be positive", self.name);
        }
        if !(0.0..=1.0).contains(&self.beta) {
            bail!("{}: beta must be in [0,1]", self.name);
        }
        if self.v_threshold <= self.v_reset {
            bail!("{}: threshold must exceed reset", self.name);
        }
        Ok(())
    }

    /// Parse from TOML-subset text (section `[model]`).
    pub fn from_toml(text: &str) -> Result<Self> {
        let doc = toml_lite::parse(text)?;
        let s = doc.section("model")?;
        let base = match s.get_str("preset").ok() {
            Some("nmnist_mlp") => Self::nmnist_mlp(),
            Some("cifar10dvs_mlp") => Self::cifar10dvs_mlp(),
            Some("cifar10dvs_mlp_small") => Self::cifar10dvs_mlp_small(),
            Some(other) => bail!("unknown preset {other:?}"),
            None => Self::nmnist_mlp(),
        };
        let cfg = Self {
            name: s.get_str("name").map(str::to_string).unwrap_or(base.name),
            layer_sizes: s.get_usize_arr("layer_sizes").unwrap_or(base.layer_sizes),
            timesteps: s.get_usize("timesteps").unwrap_or(base.timesteps),
            beta: s.get_f64("beta").unwrap_or(base.beta),
            v_threshold: s.get_f64("v_threshold").unwrap_or(base.v_threshold),
            v_reset: s.get_f64("v_reset").unwrap_or(base.v_reset),
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

/// TOML subset parser: `[section]` headers; `key = value` where value is a
/// string, number, boolean, or flat array of numbers. Comments with `#`.
pub mod toml_lite {
    use super::*;

    /// A parsed value.
    #[derive(Debug, Clone, PartialEq)]
    pub enum Value {
        Str(String),
        Num(f64),
        Bool(bool),
        Arr(Vec<f64>),
    }

    /// One `[section]`.
    #[derive(Debug, Clone, Default)]
    pub struct Section {
        pub entries: BTreeMap<String, Value>,
    }

    impl Section {
        pub fn get(&self, key: &str) -> Result<&Value> {
            self.entries.get(key).ok_or_else(|| anyhow!("missing key {key:?}"))
        }
        pub fn get_str(&self, key: &str) -> Result<&str> {
            match self.get(key)? {
                Value::Str(s) => Ok(s),
                v => bail!("{key}: expected string, got {v:?}"),
            }
        }
        pub fn get_f64(&self, key: &str) -> Result<f64> {
            match self.get(key)? {
                Value::Num(n) => Ok(*n),
                v => bail!("{key}: expected number, got {v:?}"),
            }
        }
        pub fn get_usize(&self, key: &str) -> Result<usize> {
            let n = self.get_f64(key)?;
            if n < 0.0 || n.fract() != 0.0 {
                bail!("{key}: expected non-negative integer, got {n}");
            }
            Ok(n as usize)
        }
        pub fn get_bool(&self, key: &str) -> Result<bool> {
            match self.get(key)? {
                Value::Bool(b) => Ok(*b),
                v => bail!("{key}: expected bool, got {v:?}"),
            }
        }
        pub fn get_usize_arr(&self, key: &str) -> Result<Vec<usize>> {
            match self.get(key)? {
                Value::Arr(a) => a
                    .iter()
                    .map(|&n| {
                        if n < 0.0 || n.fract() != 0.0 {
                            bail!("{key}: array element {n} is not a non-negative integer")
                        } else {
                            Ok(n as usize)
                        }
                    })
                    .collect(),
                v => bail!("{key}: expected array, got {v:?}"),
            }
        }
    }

    /// A parsed document.
    #[derive(Debug, Clone, Default)]
    pub struct Doc {
        pub sections: BTreeMap<String, Section>,
    }

    impl Doc {
        pub fn section(&self, name: &str) -> Result<&Section> {
            self.sections.get(name).ok_or_else(|| {
                anyhow!("missing section [{name}] (have: {:?})", self.sections.keys())
            })
        }
    }

    /// Parse a TOML-subset document.
    pub fn parse(text: &str) -> Result<Doc> {
        let mut doc = Doc::default();
        let mut current = String::new();
        doc.sections.insert(String::new(), Section::default());
        for (ln, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow!("line {}: unterminated section header", ln + 1))?
                    .trim()
                    .to_string();
                doc.sections.entry(name.clone()).or_default();
                current = name;
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected `key = value`", ln + 1))?;
            let key = k.trim().to_string();
            let value = parse_value(v.trim())
                .with_context(|| format!("line {}: value for {key:?}", ln + 1))?;
            doc.sections.get_mut(&current).unwrap().entries.insert(key, value);
        }
        Ok(doc)
    }

    fn strip_comment(line: &str) -> &str {
        // '#' inside quoted strings is respected.
        let mut in_str = false;
        for (i, c) in line.char_indices() {
            match c {
                '"' => in_str = !in_str,
                '#' if !in_str => return &line[..i],
                _ => {}
            }
        }
        line
    }

    fn parse_value(v: &str) -> Result<Value> {
        if let Some(inner) = v.strip_prefix('"') {
            let s = inner
                .strip_suffix('"')
                .ok_or_else(|| anyhow!("unterminated string"))?;
            return Ok(Value::Str(s.to_string()));
        }
        if v == "true" {
            return Ok(Value::Bool(true));
        }
        if v == "false" {
            return Ok(Value::Bool(false));
        }
        if let Some(inner) = v.strip_prefix('[') {
            let inner = inner
                .strip_suffix(']')
                .ok_or_else(|| anyhow!("unterminated array"))?
                .trim();
            if inner.is_empty() {
                return Ok(Value::Arr(vec![]));
            }
            let xs: Result<Vec<f64>> = inner
                .split(',')
                .map(|s| {
                    s.trim()
                        .replace('_', "")
                        .parse::<f64>()
                        .map_err(|_| anyhow!("bad number {s:?}"))
                })
                .collect();
            return Ok(Value::Arr(xs?));
        }
        v.replace('_', "")
            .parse::<f64>()
            .map(Value::Num)
            .map_err(|_| anyhow!("cannot parse value {v:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_paper() {
        let a1 = AcceleratorConfig::accel1();
        assert_eq!(a1.num_cores, 4);
        assert_eq!(a1.a_neurons_per_core, 10);
        assert_eq!(a1.virtual_per_a_neuron, 16);
        assert_eq!(a1.weight_mem_bytes, 400 * 1024);
        assert_eq!(a1.core_capacity(), 160);
        a1.validate().unwrap();

        let a2 = AcceleratorConfig::accel2();
        assert_eq!(a2.num_cores, 5);
        assert_eq!(a2.a_neurons_per_core, 20);
        assert_eq!(a2.virtual_per_a_neuron, 32);
        assert_eq!(a2.weight_mem_bytes, 20 * 1024 * 1024);
        assert_eq!(a2.core_capacity(), 640);
        a2.validate().unwrap();
        assert!((a1.clock_hz - 103.2e6).abs() < 1.0);
    }

    #[test]
    fn model_param_counts_match_table1() {
        let m = ModelConfig::nmnist_mlp();
        // 2312·200 + 200·100 + 100·40 + 40·10 = 486 800 ≈ 0.49 M
        assert_eq!(m.num_params(), 486_800);
        assert_eq!(m.num_layers(), 4);
        m.validate().unwrap();

        let c = ModelConfig::cifar10dvs_mlp();
        // 32768·1000 + 1000·500 + 500·200 + 200·100 + 100·10 = 33 389 000 ≈ 33.4 M
        assert_eq!(c.num_params(), 33_389_000);
        assert_eq!(c.num_layers(), 5);
        c.validate().unwrap();
    }

    #[test]
    fn validation_catches_nonsense() {
        let mut a = AcceleratorConfig::accel1();
        a.num_cores = 0;
        assert!(a.validate().is_err());
        let mut a = AcceleratorConfig::accel1();
        a.weight_bits = 0;
        assert!(a.validate().is_err());
        let mut m = ModelConfig::nmnist_mlp();
        m.layer_sizes = vec![10];
        assert!(m.validate().is_err());
        let mut m = ModelConfig::nmnist_mlp();
        m.beta = 1.5;
        assert!(m.validate().is_err());
        let mut m = ModelConfig::nmnist_mlp();
        m.v_threshold = -1.0;
        assert!(m.validate().is_err());
    }

    #[test]
    fn toml_lite_parses() {
        let doc = toml_lite::parse(
            r#"
            # top comment
            [accelerator]
            name = "custom"      # trailing comment
            num_cores = 4
            clock_hz = 103.2e6
            layer = [1, 2, 3]
            flag = true
            "#,
        )
        .unwrap();
        let s = doc.section("accelerator").unwrap();
        assert_eq!(s.get_str("name").unwrap(), "custom");
        assert_eq!(s.get_usize("num_cores").unwrap(), 4);
        assert_eq!(s.get_f64("clock_hz").unwrap(), 103.2e6);
        assert_eq!(s.get_usize_arr("layer").unwrap(), vec![1, 2, 3]);
        assert!(s.get_bool("flag").unwrap());
        assert!(s.get("missing").is_err());
        assert!(doc.section("nope").is_err());
    }

    #[test]
    fn toml_lite_rejects_malformed() {
        assert!(toml_lite::parse("[unterminated").is_err());
        assert!(toml_lite::parse("key value").is_err());
        assert!(toml_lite::parse("k = [1, 2").is_err());
        assert!(toml_lite::parse("k = \"oops").is_err());
        assert!(toml_lite::parse("k = nope").is_err());
    }

    #[test]
    fn accelerator_from_toml_with_preset_and_overrides() {
        let cfg = AcceleratorConfig::from_toml(
            r#"
            [accelerator]
            preset = "accel2"
            name = "accel2_wide"
            a_neurons_per_core = 40
            "#,
        )
        .unwrap();
        assert_eq!(cfg.name, "accel2_wide");
        assert_eq!(cfg.a_neurons_per_core, 40);
        assert_eq!(cfg.num_cores, 5); // inherited from accel2
        assert!(AcceleratorConfig::from_toml("[accelerator]\npreset = \"zzz\"").is_err());
    }

    #[test]
    fn model_from_toml() {
        let m = ModelConfig::from_toml(
            r#"
            [model]
            preset = "nmnist_mlp"
            timesteps = 10
            layer_sizes = [100, 20, 10]
            "#,
        )
        .unwrap();
        assert_eq!(m.timesteps, 10);
        assert_eq!(m.layer_sizes, vec![100, 20, 10]);
        assert_eq!(m.num_params(), 2200);
    }

    #[test]
    fn hash_inside_string_not_comment() {
        let doc = toml_lite::parse("[s]\nk = \"a#b\"").unwrap();
        assert_eq!(doc.section("s").unwrap().get_str("k").unwrap(), "a#b");
    }
}
