//! Integer linear programming substrate.
//!
//! The paper solves its neuron-assignment ILP (eqs. 3–7) with PuLP/CBC.
//! Nothing like that exists in this environment, so we implement the solver
//! stack ourselves:
//!
//! * [`lp`] — a two-phase primal simplex solver over a dense tableau with
//!   Bland anti-cycling. Adequate for the per-layer relaxations that the
//!   branch & bound explores (hundreds of variables).
//! * [`branch_bound`] — best-first branch & bound on fractional variables,
//!   producing provably optimal integer solutions for small/medium models.
//! * [`mcmf`] — a min-cost max-flow solver (successive shortest paths with
//!   Johnson potentials). The MENAGE assignment collapses — after exploiting
//!   capacitor symmetry — to a transportation problem whose constraint
//!   matrix is totally unimodular, so the flow solution *is* the ILP
//!   optimum. This is the scalable path used for the CIFAR10-DVS layers
//!   (~10⁵–10⁶ raw binaries).
//!
//! The [`Problem`] builder is deliberately tiny and explicit; the mapping
//! layer is its only in-crate consumer, but the API is general enough for
//! the ablation benches to pose arbitrary side problems.

pub mod branch_bound;
pub mod lp;
pub mod mcmf;


/// Variable identifier (index into the problem's variable vector).
pub type VarId = usize;

/// Optimization direction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Sense {
    Minimize,
    Maximize,
}

/// Linear constraint comparison operator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Cmp {
    /// `Σ aᵢxᵢ ≤ b`
    Le,
    /// `Σ aᵢxᵢ = b`
    Eq,
    /// `Σ aᵢxᵢ ≥ b`
    Ge,
}

/// Variable domain.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Domain {
    /// Continuous in `[lo, hi]`.
    Continuous { lo: f64, hi: f64 },
    /// Integer in `[lo, hi]` (inclusive).
    Integer { lo: i64, hi: i64 },
    /// Binary `{0, 1}` — shorthand for `Integer { 0, 1 }`.
    Binary,
}

impl Domain {
    /// Lower bound as f64.
    pub fn lo(&self) -> f64 {
        match *self {
            Domain::Continuous { lo, .. } => lo,
            Domain::Integer { lo, .. } => lo as f64,
            Domain::Binary => 0.0,
        }
    }
    /// Upper bound as f64.
    pub fn hi(&self) -> f64 {
        match *self {
            Domain::Continuous { hi, .. } => hi,
            Domain::Integer { hi, .. } => hi as f64,
            Domain::Binary => 1.0,
        }
    }
    /// Whether the domain requires integrality.
    pub fn is_integer(&self) -> bool {
        !matches!(self, Domain::Continuous { .. })
    }
}

/// A sparse linear constraint `Σ coeff·var  (≤ | = | ≥)  rhs`.
#[derive(Debug, Clone)]
pub struct Constraint {
    pub terms: Vec<(VarId, f64)>,
    pub cmp: Cmp,
    pub rhs: f64,
    /// Optional human-readable tag (used in infeasibility reports).
    pub name: String,
}

/// An ILP/LP problem under construction.
#[derive(Debug, Clone, Default)]
pub struct Problem {
    pub sense: Option<Sense>,
    /// Objective coefficients, one per variable (0 when untouched).
    pub objective: Vec<f64>,
    /// Constant term of the objective (book-keeping only).
    pub objective_offset: f64,
    pub domains: Vec<Domain>,
    pub names: Vec<String>,
    pub constraints: Vec<Constraint>,
}

impl Problem {
    /// Empty minimization problem.
    pub fn minimize() -> Self {
        Self { sense: Some(Sense::Minimize), ..Default::default() }
    }

    /// Empty maximization problem.
    pub fn maximize() -> Self {
        Self { sense: Some(Sense::Maximize), ..Default::default() }
    }

    /// Add a variable; returns its id.
    pub fn add_var(&mut self, name: impl Into<String>, domain: Domain, obj_coeff: f64) -> VarId {
        let id = self.domains.len();
        self.domains.push(domain);
        self.names.push(name.into());
        self.objective.push(obj_coeff);
        id
    }

    /// Add a binary variable with the given objective coefficient.
    pub fn add_binary(&mut self, name: impl Into<String>, obj_coeff: f64) -> VarId {
        self.add_var(name, Domain::Binary, obj_coeff)
    }

    /// Add a constraint; duplicate variable ids in `terms` are summed.
    pub fn add_constraint(
        &mut self,
        name: impl Into<String>,
        terms: Vec<(VarId, f64)>,
        cmp: Cmp,
        rhs: f64,
    ) {
        debug_assert!(terms.iter().all(|&(v, _)| v < self.domains.len()));
        self.constraints.push(Constraint { terms, cmp, rhs, name: name.into() });
    }

    /// Cardinality equality `Σ vars = k` — the "pick exactly k" constraint
    /// (e.g. the shard partitioner's cut-count budget).
    pub fn add_exactly_k(&mut self, name: impl Into<String>, vars: &[VarId], k: f64) {
        let terms = vars.iter().map(|&v| (v, 1.0)).collect();
        self.add_constraint(name, terms, Cmp::Eq, k);
    }

    /// Set-cover constraint `Σ vars ≥ 1` — "at least one of these" (e.g.
    /// a sliding capacity window that must contain a cut).
    pub fn add_cover(&mut self, name: impl Into<String>, vars: &[VarId]) {
        let terms = vars.iter().map(|&v| (v, 1.0)).collect();
        self.add_constraint(name, terms, Cmp::Ge, 1.0);
    }

    /// Number of variables.
    pub fn num_vars(&self) -> usize {
        self.domains.len()
    }

    /// Number of constraints.
    pub fn num_constraints(&self) -> usize {
        self.constraints.len()
    }

    /// Evaluate the objective (including the constant offset) at `x`.
    pub fn objective_value(&self, x: &[f64]) -> f64 {
        self.objective_offset
            + self.objective.iter().zip(x).map(|(c, v)| c * v).sum::<f64>()
    }

    /// Check feasibility of an assignment within `tol`.
    pub fn is_feasible(&self, x: &[f64], tol: f64) -> bool {
        if x.len() != self.num_vars() {
            return false;
        }
        for (i, d) in self.domains.iter().enumerate() {
            if x[i] < d.lo() - tol || x[i] > d.hi() + tol {
                return false;
            }
            if d.is_integer() && (x[i] - x[i].round()).abs() > tol {
                return false;
            }
        }
        self.constraints.iter().all(|c| {
            let lhs: f64 = c.terms.iter().map(|&(v, a)| a * x[v]).sum();
            match c.cmp {
                Cmp::Le => lhs <= c.rhs + tol,
                Cmp::Eq => (lhs - c.rhs).abs() <= tol,
                Cmp::Ge => lhs >= c.rhs - tol,
            }
        })
    }
}

/// Solver termination status.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Status {
    Optimal,
    Infeasible,
    Unbounded,
    /// Node/iteration limit hit; `Solution::x` holds the incumbent if any.
    LimitReached,
}

/// Solution of an LP or ILP solve.
#[derive(Debug, Clone)]
pub struct Solution {
    pub status: Status,
    pub objective: f64,
    pub x: Vec<f64>,
    /// Branch-and-bound statistics (0 for pure LP solves).
    pub nodes_explored: usize,
}

impl Solution {
    pub fn infeasible(n: usize) -> Self {
        Self { status: Status::Infeasible, objective: f64::INFINITY, x: vec![0.0; n], nodes_explored: 0 }
    }

    /// Value of variable `v` rounded to the nearest integer.
    pub fn int(&self, v: VarId) -> i64 {
        self.x[v].round() as i64
    }

    /// Whether variable `v` is (rounded) one.
    pub fn is_one(&self, v: VarId) -> bool {
        self.x[v] > 0.5
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn problem_builder_basics() {
        let mut p = Problem::minimize();
        let x = p.add_binary("x", 1.0);
        let y = p.add_var("y", Domain::Continuous { lo: 0.0, hi: 10.0 }, 2.0);
        p.add_constraint("c0", vec![(x, 1.0), (y, 1.0)], Cmp::Ge, 1.0);
        assert_eq!(p.num_vars(), 2);
        assert_eq!(p.num_constraints(), 1);
        assert!(p.is_feasible(&[1.0, 0.0], 1e-9));
        assert!(!p.is_feasible(&[0.0, 0.5], 1e-9)); // y=0.5 fine but constraint ok... x binary 0 ok, 0+0.5<1 -> infeasible
        assert_eq!(p.objective_value(&[1.0, 3.0]), 7.0);
    }

    #[test]
    fn domain_bounds() {
        assert_eq!(Domain::Binary.lo(), 0.0);
        assert_eq!(Domain::Binary.hi(), 1.0);
        assert!(Domain::Binary.is_integer());
        let d = Domain::Integer { lo: -3, hi: 7 };
        assert_eq!(d.lo(), -3.0);
        assert_eq!(d.hi(), 7.0);
        let c = Domain::Continuous { lo: 0.5, hi: 2.5 };
        assert!(!c.is_integer());
    }

    #[test]
    fn cardinality_and_cover_helpers() {
        let mut p = Problem::minimize();
        let vars: Vec<VarId> = (0..4).map(|i| p.add_binary(format!("v{i}"), 1.0)).collect();
        p.add_exactly_k("pick2", &vars, 2.0);
        p.add_cover("one-of-front", &vars[..2]);
        assert_eq!(p.num_constraints(), 2);
        assert!(p.is_feasible(&[1.0, 0.0, 1.0, 0.0], 1e-9));
        assert!(!p.is_feasible(&[1.0, 1.0, 1.0, 0.0], 1e-9)); // three picked
        assert!(!p.is_feasible(&[0.0, 0.0, 1.0, 1.0], 1e-9)); // cover violated
    }

    #[test]
    fn feasibility_checks_integrality() {
        let mut p = Problem::minimize();
        let x = p.add_var("x", Domain::Integer { lo: 0, hi: 5 }, 1.0);
        p.add_constraint("c", vec![(x, 1.0)], Cmp::Le, 4.0);
        assert!(p.is_feasible(&[3.0], 1e-9));
        assert!(!p.is_feasible(&[2.5], 1e-9));
        assert!(!p.is_feasible(&[5.0], 1e-9)); // violates c
    }
}
