//! Best-first branch & bound over the simplex relaxation.
//!
//! Classic LP-based B&B: solve the relaxation, pick the most-fractional
//! integer variable, branch `x ≤ ⌊v⌋` / `x ≥ ⌈v⌉`, prune by bound against
//! the incumbent. Node order is best-bound-first (min-heap on relaxation
//! objective for minimization).
//!
//! This is the exact path for MENAGE's small per-layer mapping ILPs and for
//! the unit/property tests that cross-check the min-cost-flow fast path.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::lp::solve_relaxation;
use super::{Problem, Sense, Solution, Status};

const INT_TOL: f64 = 1e-6;

/// Branch & bound configuration.
#[derive(Debug, Clone)]
pub struct BnbConfig {
    /// Maximum number of explored nodes before returning the incumbent.
    pub max_nodes: usize,
    /// Absolute optimality gap at which a node is pruned.
    pub gap_tol: f64,
}

impl Default for BnbConfig {
    fn default() -> Self {
        Self { max_nodes: 200_000, gap_tol: 1e-6 }
    }
}

struct Node {
    /// Bound of the node's relaxation (in minimization form).
    bound: f64,
    /// Bound overrides accumulated along the branch: (var, lo, hi).
    overrides: Vec<(usize, f64, f64)>,
}

impl PartialEq for Node {
    fn eq(&self, other: &Self) -> bool {
        self.bound == other.bound
    }
}
impl Eq for Node {}
impl PartialOrd for Node {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Node {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; reverse for best(lowest)-bound-first.
        other.bound.partial_cmp(&self.bound).unwrap_or(Ordering::Equal)
    }
}

/// Solve `p` to integer optimality (within the node budget).
pub fn solve(p: &Problem, cfg: &BnbConfig) -> Solution {
    let flip = match p.sense.unwrap_or(Sense::Minimize) {
        Sense::Minimize => 1.0,
        Sense::Maximize => -1.0,
    };
    let n = p.num_vars();

    let root = solve_relaxation(p, &[]);
    match root.status {
        Status::Infeasible => return Solution::infeasible(n),
        Status::Unbounded => {
            return Solution {
                status: Status::Unbounded,
                objective: -flip * f64::INFINITY,
                x: vec![0.0; n],
                nodes_explored: 1,
            }
        }
        _ => {}
    }

    let mut heap = BinaryHeap::new();
    heap.push(Node { bound: flip * root.objective, overrides: vec![] });

    let mut incumbent: Option<Solution> = None;
    let mut best = f64::INFINITY; // minimization form
    let mut nodes = 0usize;

    while let Some(node) = heap.pop() {
        if node.bound >= best - cfg.gap_tol {
            continue; // pruned by bound
        }
        nodes += 1;
        if nodes > cfg.max_nodes {
            break;
        }
        let rel = solve_relaxation(p, &node.overrides);
        if rel.status != Status::Optimal {
            continue;
        }
        let bound = flip * rel.objective;
        if bound >= best - cfg.gap_tol {
            continue;
        }
        // Most-fractional integer variable.
        let mut branch_var: Option<(usize, f64)> = None;
        let mut best_frac = INT_TOL;
        for v in 0..n {
            if p.domains[v].is_integer() {
                let f = (rel.x[v] - rel.x[v].round()).abs();
                if f > best_frac {
                    best_frac = f;
                    branch_var = Some((v, rel.x[v]));
                }
            }
        }
        match branch_var {
            None => {
                // Integral — candidate incumbent.
                if bound < best {
                    best = bound;
                    let mut x = rel.x.clone();
                    for (v, xv) in x.iter_mut().enumerate() {
                        if p.domains[v].is_integer() {
                            *xv = xv.round();
                        }
                    }
                    incumbent = Some(Solution {
                        status: Status::Optimal,
                        objective: p.objective_value(&x),
                        x,
                        nodes_explored: nodes,
                    });
                }
            }
            Some((v, val)) => {
                let floor = val.floor();
                let mut lo_ov = node.overrides.clone();
                lo_ov.push((v, f64::NEG_INFINITY, floor));
                heap.push(Node { bound, overrides: lo_ov });
                let mut hi_ov = node.overrides;
                hi_ov.push((v, floor + 1.0, f64::INFINITY));
                heap.push(Node { bound, overrides: hi_ov });
            }
        }
    }

    match incumbent {
        Some(mut s) => {
            s.nodes_explored = nodes;
            if nodes > cfg.max_nodes {
                s.status = Status::LimitReached;
            }
            s
        }
        None => {
            if nodes > cfg.max_nodes {
                Solution {
                    status: Status::LimitReached,
                    objective: f64::INFINITY,
                    x: vec![0.0; n],
                    nodes_explored: nodes,
                }
            } else {
                Solution::infeasible(n)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ilp::{Cmp, Domain};

    fn near(a: f64, b: f64) -> bool {
        (a - b).abs() < 1e-5
    }

    #[test]
    fn knapsack_small() {
        // max 10a + 13b + 7c s.t. 3a + 4b + 2c <= 6, binary
        let mut p = Problem::maximize();
        let a = p.add_binary("a", 10.0);
        let b = p.add_binary("b", 13.0);
        let c = p.add_binary("c", 7.0);
        p.add_constraint("w", vec![(a, 3.0), (b, 4.0), (c, 2.0)], Cmp::Le, 6.0);
        let s = solve(&p, &BnbConfig::default());
        assert_eq!(s.status, Status::Optimal);
        assert!(near(s.objective, 20.0), "obj={}", s.objective); // b + c
        assert!(s.is_one(b) && s.is_one(c) && !s.is_one(a));
    }

    #[test]
    fn integer_rounding_matters() {
        // max x s.t. 2x <= 7, x integer -> x = 3 (LP gives 3.5)
        let mut p = Problem::maximize();
        let x = p.add_var("x", Domain::Integer { lo: 0, hi: 100 }, 1.0);
        p.add_constraint("c", vec![(x, 2.0)], Cmp::Le, 7.0);
        let s = solve(&p, &BnbConfig::default());
        assert_eq!(s.status, Status::Optimal);
        assert_eq!(s.int(x), 3);
    }

    #[test]
    fn infeasible_ilp() {
        // x + y = 1.5 with x,y binary is LP-feasible but IP-infeasible... —
        // actually x=1,y=0.5 LP feasible; integer infeasible.
        let mut p = Problem::minimize();
        let x = p.add_binary("x", 1.0);
        let y = p.add_binary("y", 1.0);
        p.add_constraint("c", vec![(x, 1.0), (y, 1.0)], Cmp::Eq, 1.5);
        let s = solve(&p, &BnbConfig::default());
        assert_eq!(s.status, Status::Infeasible);
    }

    #[test]
    fn assignment_3x3_exact() {
        // Costs: min trace assignment.
        let cost = [[4.0, 2.0, 8.0], [4.0, 3.0, 7.0], [3.0, 1.0, 6.0]];
        let mut p = Problem::minimize();
        let mut v = [[0usize; 3]; 3];
        for (i, vi) in v.iter_mut().enumerate() {
            for (j, vij) in vi.iter_mut().enumerate() {
                *vij = p.add_binary(format!("x{i}{j}"), cost[i][j]);
            }
        }
        for i in 0..3 {
            p.add_constraint(
                format!("row{i}"),
                (0..3).map(|j| (v[i][j], 1.0)).collect(),
                Cmp::Eq,
                1.0,
            );
            p.add_constraint(
                format!("col{i}"),
                (0..3).map(|j| (v[j][i], 1.0)).collect(),
                Cmp::Eq,
                1.0,
            );
        }
        let s = solve(&p, &BnbConfig::default());
        assert_eq!(s.status, Status::Optimal);
        // Optimal: (0,1)=2,(1,0)=4? rows to cols: r0->c1 (2), r1->c0 (4), r2->c2 (6) = 12
        // alt: r0->c0(4), r1->c2(7), r2->c1(1) = 12. Either way 12... check 11:
        // r0->c1(2), r1->c2(7), r2->c0(3) = 12. min is 12? r0c0 4 r1c1 3 r2c2 6 = 13.
        assert!(near(s.objective, 12.0), "obj={}", s.objective);
        assert!(p.is_feasible(&s.x, 1e-6));
    }

    #[test]
    fn respects_gap_and_returns_feasible() {
        // Bigger knapsack; verify feasibility of result.
        let w = [5.0, 4.0, 6.0, 3.0, 7.0, 2.0, 8.0, 1.0];
        let val = [10.0, 40.0, 30.0, 50.0, 35.0, 25.0, 45.0, 5.0];
        let mut p = Problem::maximize();
        let vars: Vec<_> =
            (0..8).map(|i| p.add_binary(format!("x{i}"), val[i])).collect();
        p.add_constraint(
            "cap",
            vars.iter().enumerate().map(|(i, &v)| (v, w[i])).collect(),
            Cmp::Le,
            15.0,
        );
        let s = solve(&p, &BnbConfig::default());
        assert_eq!(s.status, Status::Optimal);
        assert!(p.is_feasible(&s.x, 1e-6));
        // Greedy by density: x3(3,50) x1(4,40) x5(2,25) x7(1,5) = 10w/120v, +x2? w 16 no.
        // Try x3,x1,x5,x7 =120 w=10, add x0(5,10) w=15 v=130.
        assert!(s.objective >= 130.0 - 1e-6, "obj={}", s.objective);
    }
}
