//! Two-phase primal simplex over a dense tableau.
//!
//! This is the LP relaxation engine underneath [`super::branch_bound`]. The
//! per-layer relaxations MENAGE's mapper poses are small (≤ a few thousand
//! nonzeros), so a dense tableau with Bland's anti-cycling rule is simple,
//! robust, and fast enough; the large instances never reach this code —
//! they take the min-cost-flow fast path in [`super::mcmf`].
//!
//! Standard-form handling:
//! * every variable `x` with domain `[lo, hi]` is shifted to `x' = x - lo ≥ 0`
//!   and, when `hi < ∞`, given an upper-bound row `x' ≤ hi - lo`;
//! * `≤` rows get a slack, `≥` rows get a surplus + artificial, `=` rows get
//!   an artificial;
//! * phase 1 minimizes the artificial sum, phase 2 the true objective.

use super::{Cmp, Problem, Sense, Solution, Status};

const EPS: f64 = 1e-9;

/// Solve the LP relaxation of `p` (integrality dropped).
///
/// `overrides` optionally tightens variable bounds (used by branch & bound
/// to impose branching decisions without copying the problem).
pub fn solve_relaxation(p: &Problem, overrides: &[(usize, f64, f64)]) -> Solution {
    let n = p.num_vars();
    let mut lo = vec![0.0f64; n];
    let mut hi = vec![0.0f64; n];
    for i in 0..n {
        lo[i] = p.domains[i].lo();
        hi[i] = p.domains[i].hi();
    }
    for &(v, l, h) in overrides {
        lo[v] = lo[v].max(l);
        hi[v] = hi[v].min(h);
        if lo[v] > hi[v] + EPS {
            return Solution::infeasible(n);
        }
    }

    // Build rows: original constraints with shifted variables, then
    // upper-bound rows for finite hi.
    struct Row {
        coeffs: Vec<f64>, // dense over structural vars
        cmp: Cmp,
        rhs: f64,
    }
    let mut rows: Vec<Row> = Vec::with_capacity(p.constraints.len() + n);
    for c in &p.constraints {
        let mut coeffs = vec![0.0; n];
        let mut shift = 0.0;
        for &(v, a) in &c.terms {
            coeffs[v] += a;
        }
        for v in 0..n {
            shift += coeffs[v] * lo[v];
        }
        rows.push(Row { coeffs, cmp: c.cmp, rhs: c.rhs - shift });
    }
    for v in 0..n {
        if hi[v].is_finite() {
            let mut coeffs = vec![0.0; n];
            coeffs[v] = 1.0;
            rows.push(Row { coeffs, cmp: Cmp::Le, rhs: hi[v] - lo[v] });
        }
    }

    // Normalize rhs ≥ 0 by flipping rows.
    for r in rows.iter_mut() {
        if r.rhs < 0.0 {
            for a in r.coeffs.iter_mut() {
                *a = -*a;
            }
            r.rhs = -r.rhs;
            r.cmp = match r.cmp {
                Cmp::Le => Cmp::Ge,
                Cmp::Ge => Cmp::Le,
                Cmp::Eq => Cmp::Eq,
            };
        }
    }

    let m = rows.len();
    // Column layout: [structural n][slack/surplus s][artificial a][rhs]
    let mut n_slack = 0usize;
    let mut n_art = 0usize;
    for r in &rows {
        match r.cmp {
            Cmp::Le => n_slack += 1,
            Cmp::Ge => {
                n_slack += 1;
                n_art += 1;
            }
            Cmp::Eq => n_art += 1,
        }
    }
    let total = n + n_slack + n_art;
    let mut t = vec![vec![0.0f64; total + 1]; m]; // tableau rows
    let mut basis = vec![0usize; m];
    let mut s_idx = n;
    let mut a_idx = n + n_slack;
    let mut art_cols: Vec<usize> = Vec::with_capacity(n_art);
    for (i, r) in rows.iter().enumerate() {
        t[i][..n].copy_from_slice(&r.coeffs);
        t[i][total] = r.rhs;
        match r.cmp {
            Cmp::Le => {
                t[i][s_idx] = 1.0;
                basis[i] = s_idx;
                s_idx += 1;
            }
            Cmp::Ge => {
                t[i][s_idx] = -1.0;
                s_idx += 1;
                t[i][a_idx] = 1.0;
                basis[i] = a_idx;
                art_cols.push(a_idx);
                a_idx += 1;
            }
            Cmp::Eq => {
                t[i][a_idx] = 1.0;
                basis[i] = a_idx;
                art_cols.push(a_idx);
                a_idx += 1;
            }
        }
    }

    // Objective in minimization form over shifted variables.
    let flip = match p.sense.unwrap_or(Sense::Minimize) {
        Sense::Minimize => 1.0,
        Sense::Maximize => -1.0,
    };
    let mut cost = vec![0.0f64; total];
    for v in 0..n {
        cost[v] = flip * p.objective[v];
    }

    // --- Phase 1 ---
    if n_art > 0 {
        let mut c1 = vec![0.0f64; total];
        for &a in &art_cols {
            c1[a] = 1.0;
        }
        let ok = simplex(&mut t, &mut basis, &c1, total);
        if !ok {
            return Solution::infeasible(n);
        }
        // Objective value of phase 1 = sum of artificials at basis.
        let obj1: f64 = basis
            .iter()
            .enumerate()
            .filter(|(_, &b)| art_cols.contains(&b))
            .map(|(i, _)| t[i][total])
            .sum();
        if obj1 > 1e-7 {
            return Solution::infeasible(n);
        }
        // Drive remaining artificial basics out if possible.
        for i in 0..m {
            if art_cols.contains(&basis[i]) && t[i][total].abs() <= 1e-7 {
                if let Some(j) = (0..n + n_slack).find(|&j| t[i][j].abs() > EPS) {
                    pivot(&mut t, &mut basis, i, j);
                }
            }
        }
    }

    // --- Phase 2 --- (forbid artificial columns by huge cost)
    for &a in &art_cols {
        cost[a] = 1e12;
    }
    let ok = simplex(&mut t, &mut basis, &cost, total);
    if !ok {
        // Unbounded in phase 2.
        return Solution {
            status: Status::Unbounded,
            objective: f64::NEG_INFINITY * flip,
            x: vec![0.0; n],
            nodes_explored: 0,
        };
    }

    let mut xshift = vec![0.0f64; total];
    for i in 0..m {
        xshift[basis[i]] = t[i][total];
    }
    let mut x = vec![0.0f64; n];
    for v in 0..n {
        x[v] = xshift[v] + lo[v];
        // Clean numerical dust.
        if (x[v] - x[v].round()).abs() < 1e-9 {
            x[v] = x[v].round();
        }
    }
    let objective = p.objective_value(&x);
    Solution { status: Status::Optimal, objective, x, nodes_explored: 0 }
}

/// In-place primal simplex with Bland's rule. Returns false on unbounded.
fn simplex(t: &mut [Vec<f64>], basis: &mut [usize], cost: &[f64], total: usize) -> bool {
    let m = t.len();
    let mut iters = 0usize;
    let max_iters = 50_000 + 200 * (m + total);
    loop {
        iters += 1;
        if iters > max_iters {
            // Degenerate stall; accept current (feasible) basis.
            return true;
        }
        // Reduced costs: c_j - c_B B⁻¹ A_j, computed from the tableau
        // (tableau rows already hold B⁻¹A).
        let mut entering = None;
        for j in 0..total {
            if basis.contains(&j) {
                continue;
            }
            let mut rc = cost[j];
            for i in 0..m {
                rc -= cost[basis[i]] * t[i][j];
            }
            if rc < -1e-8 {
                entering = Some(j); // Bland: first improving index
                break;
            }
        }
        let Some(j) = entering else { return true };
        // Ratio test (Bland tie-break on basis index).
        let mut leave: Option<usize> = None;
        let mut best = f64::INFINITY;
        for i in 0..m {
            if t[i][j] > EPS {
                let ratio = t[i][total] / t[i][j];
                if ratio < best - EPS
                    || (ratio < best + EPS
                        && leave.map_or(true, |l| basis[i] < basis[l]))
                {
                    best = ratio;
                    leave = Some(i);
                }
            }
        }
        let Some(i) = leave else { return false }; // unbounded
        pivot(t, basis, i, j);
    }
}

fn pivot(t: &mut [Vec<f64>], basis: &mut [usize], row: usize, col: usize) {
    let m = t.len();
    let width = t[0].len();
    let pv = t[row][col];
    debug_assert!(pv.abs() > EPS);
    for j in 0..width {
        t[row][j] /= pv;
    }
    for i in 0..m {
        if i != row {
            let f = t[i][col];
            if f.abs() > EPS {
                for j in 0..width {
                    t[i][j] -= f * t[row][j];
                }
            }
        }
    }
    basis[row] = col;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ilp::Domain;

    fn near(a: f64, b: f64) -> bool {
        (a - b).abs() < 1e-6
    }

    #[test]
    fn simple_max_lp() {
        // max 3x + 2y s.t. x + y <= 4, x + 3y <= 6, x,y in [0, inf)
        let mut p = Problem::maximize();
        let x = p.add_var("x", Domain::Continuous { lo: 0.0, hi: f64::INFINITY }, 3.0);
        let y = p.add_var("y", Domain::Continuous { lo: 0.0, hi: f64::INFINITY }, 2.0);
        p.add_constraint("c1", vec![(x, 1.0), (y, 1.0)], Cmp::Le, 4.0);
        p.add_constraint("c2", vec![(x, 1.0), (y, 3.0)], Cmp::Le, 6.0);
        let s = solve_relaxation(&p, &[]);
        assert_eq!(s.status, Status::Optimal);
        assert!(near(s.objective, 12.0), "obj={}", s.objective); // x=4, y=0
        assert!(near(s.x[x], 4.0));
        assert!(near(s.x[y], 0.0));
    }

    #[test]
    fn min_with_ge_constraints() {
        // min 2x + 3y s.t. x + y >= 10, x >= 2, y >= 3
        let mut p = Problem::minimize();
        let x = p.add_var("x", Domain::Continuous { lo: 2.0, hi: f64::INFINITY }, 2.0);
        let y = p.add_var("y", Domain::Continuous { lo: 3.0, hi: f64::INFINITY }, 3.0);
        p.add_constraint("sum", vec![(x, 1.0), (y, 1.0)], Cmp::Ge, 10.0);
        let s = solve_relaxation(&p, &[]);
        assert_eq!(s.status, Status::Optimal);
        assert!(near(s.objective, 2.0 * 7.0 + 3.0 * 3.0), "obj={}", s.objective); // x=7,y=3
    }

    #[test]
    fn equality_constraint() {
        // min x + y s.t. x + 2y = 8, 0<=x,y<=10
        let mut p = Problem::minimize();
        let x = p.add_var("x", Domain::Continuous { lo: 0.0, hi: 10.0 }, 1.0);
        let y = p.add_var("y", Domain::Continuous { lo: 0.0, hi: 10.0 }, 1.0);
        p.add_constraint("eq", vec![(x, 1.0), (y, 2.0)], Cmp::Eq, 8.0);
        let s = solve_relaxation(&p, &[]);
        assert_eq!(s.status, Status::Optimal);
        assert!(near(s.objective, 4.0)); // x=0, y=4
    }

    #[test]
    fn infeasible_lp() {
        let mut p = Problem::minimize();
        let x = p.add_var("x", Domain::Continuous { lo: 0.0, hi: 1.0 }, 1.0);
        p.add_constraint("c", vec![(x, 1.0)], Cmp::Ge, 2.0);
        let s = solve_relaxation(&p, &[]);
        assert_eq!(s.status, Status::Infeasible);
    }

    #[test]
    fn unbounded_lp() {
        let mut p = Problem::maximize();
        let x = p.add_var("x", Domain::Continuous { lo: 0.0, hi: f64::INFINITY }, 1.0);
        p.add_constraint("c", vec![(x, -1.0)], Cmp::Le, 0.0); // -x <= 0 always true
        let s = solve_relaxation(&p, &[]);
        assert_eq!(s.status, Status::Unbounded);
    }

    #[test]
    fn bound_overrides_apply() {
        let mut p = Problem::maximize();
        let x = p.add_var("x", Domain::Continuous { lo: 0.0, hi: 10.0 }, 1.0);
        let s = solve_relaxation(&p, &[(x, 0.0, 3.0)]);
        assert_eq!(s.status, Status::Optimal);
        assert!(near(s.x[x], 3.0));
        // Conflicting override -> infeasible
        let s = solve_relaxation(&p, &[(x, 5.0, 3.0)]);
        assert_eq!(s.status, Status::Infeasible);
    }

    #[test]
    fn shifted_lower_bounds() {
        // min x s.t. x >= -5 (domain), x >= -3 (row) -> x = -3
        let mut p = Problem::minimize();
        let x = p.add_var("x", Domain::Continuous { lo: -5.0, hi: 5.0 }, 1.0);
        p.add_constraint("c", vec![(x, 1.0)], Cmp::Ge, -3.0);
        let s = solve_relaxation(&p, &[]);
        assert_eq!(s.status, Status::Optimal);
        assert!(near(s.x[x], -3.0), "x={}", s.x[x]);
    }

    #[test]
    fn degenerate_assignment_relaxation_is_integral() {
        // 2 items, 2 bins, capacities 1 — LP relaxation of an assignment
        // problem is integral (totally unimodular).
        let mut p = Problem::minimize();
        let mut v = vec![];
        for i in 0..2 {
            for j in 0..2 {
                v.push(p.add_var(format!("x{i}{j}"), Domain::Continuous { lo: 0.0, hi: 1.0 }, if i == j { 0.0 } else { 1.0 }));
            }
        }
        for i in 0..2 {
            p.add_constraint(
                format!("assign{i}"),
                vec![(v[i * 2], 1.0), (v[i * 2 + 1], 1.0)],
                Cmp::Eq,
                1.0,
            );
        }
        for j in 0..2 {
            p.add_constraint(format!("cap{j}"), vec![(v[j], 1.0), (v[2 + j], 1.0)], Cmp::Le, 1.0);
        }
        let s = solve_relaxation(&p, &[]);
        assert_eq!(s.status, Status::Optimal);
        assert!(near(s.objective, 0.0));
        for &vi in &v {
            assert!(near(s.x[vi], s.x[vi].round()));
        }
    }
}
