//! Min-cost max-flow: successive shortest augmenting paths with Johnson
//! potentials (Dijkstra on reduced costs).
//!
//! Why it lives in the ILP module: the MENAGE assignment ILP (eqs. 3–7) is,
//! after collapsing the capacitor index k (capacitors within one A-NEURON
//! are interchangeable), a transportation problem
//!
//! ```text
//!   source ── (cap 1, cost c_ij) ──> neuron i ──> engine j ── (cap N) ──> sink
//! ```
//!
//! whose constraint matrix is totally unimodular; the integral min-cost
//! flow equals the ILP optimum. This is how the CIFAR10-DVS layers
//! (10⁵–10⁶ raw binaries) are solved in milliseconds instead of hours.

/// A directed edge in the flow network.
#[derive(Debug, Clone)]
struct Edge {
    to: usize,
    cap: i64,
    cost: i64,
    /// Index of the reverse edge in `graph[to]`.
    rev: usize,
}

/// Min-cost max-flow network.
#[derive(Debug, Clone, Default)]
pub struct McmfGraph {
    graph: Vec<Vec<Edge>>,
}

/// Result of a flow computation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FlowResult {
    pub flow: i64,
    pub cost: i64,
}

impl McmfGraph {
    /// Network with `n` nodes and no edges.
    pub fn new(n: usize) -> Self {
        Self { graph: vec![Vec::new(); n] }
    }

    /// Number of nodes.
    pub fn len(&self) -> usize {
        self.graph.len()
    }

    /// Whether the network has no nodes.
    pub fn is_empty(&self) -> bool {
        self.graph.is_empty()
    }

    /// Add edge `from -> to` with capacity `cap` and unit cost `cost`.
    /// Returns a handle `(from, index)` usable with [`Self::edge_flow`].
    pub fn add_edge(&mut self, from: usize, to: usize, cap: i64, cost: i64) -> (usize, usize) {
        assert!(from < self.graph.len() && to < self.graph.len());
        assert!(from != to, "self-loops unsupported");
        let fwd = self.graph[from].len();
        let bwd = self.graph[to].len();
        self.graph[from].push(Edge { to, cap, cost, rev: bwd });
        self.graph[to].push(Edge { to: from, cap: 0, cost: -cost, rev: fwd });
        (from, fwd)
    }

    /// Flow currently on the edge returned by [`Self::add_edge`].
    pub fn edge_flow(&self, handle: (usize, usize)) -> i64 {
        let e = &self.graph[handle.0][handle.1];
        // Flow = residual capacity on the reverse edge.
        self.graph[e.to][e.rev].cap
    }

    /// Push up to `limit` units of flow from `s` to `t`, minimizing cost.
    ///
    /// Costs may be negative as long as the initial graph has no negative
    /// cycle; a Bellman–Ford pass seeds the potentials.
    pub fn min_cost_flow(&mut self, s: usize, t: usize, limit: i64) -> FlowResult {
        let n = self.graph.len();
        let mut potential = vec![0i64; n];

        // Bellman–Ford to initialize potentials (handles negative costs).
        {
            let mut dist = vec![i64::MAX / 4; n];
            dist[s] = 0;
            for _ in 0..n {
                let mut changed = false;
                for u in 0..n {
                    if dist[u] >= i64::MAX / 4 {
                        continue;
                    }
                    for e in &self.graph[u] {
                        if e.cap > 0 && dist[u] + e.cost < dist[e.to] {
                            dist[e.to] = dist[u] + e.cost;
                            changed = true;
                        }
                    }
                }
                if !changed {
                    break;
                }
            }
            potential = dist;
        }

        let mut total_flow = 0i64;
        let mut total_cost = 0i64;

        while total_flow < limit {
            // Dijkstra on reduced costs.
            let mut dist = vec![i64::MAX; n];
            let mut prev: Vec<Option<(usize, usize)>> = vec![None; n];
            let mut heap = std::collections::BinaryHeap::new();
            dist[s] = 0;
            heap.push(std::cmp::Reverse((0i64, s)));
            while let Some(std::cmp::Reverse((d, u))) = heap.pop() {
                if d > dist[u] {
                    continue;
                }
                for (ei, e) in self.graph[u].iter().enumerate() {
                    if e.cap > 0 && potential[u] < i64::MAX / 4 && potential[e.to] < i64::MAX / 4 {
                        let nd = d + e.cost + potential[u] - potential[e.to];
                        if nd < dist[e.to] {
                            dist[e.to] = nd;
                            prev[e.to] = Some((u, ei));
                            heap.push(std::cmp::Reverse((nd, e.to)));
                        }
                    }
                }
            }
            if dist[t] == i64::MAX {
                break; // no augmenting path
            }
            for v in 0..n {
                if dist[v] < i64::MAX && potential[v] < i64::MAX / 4 {
                    potential[v] += dist[v];
                }
            }
            // Bottleneck along the path.
            let mut push = limit - total_flow;
            let mut v = t;
            while let Some((u, ei)) = prev[v] {
                push = push.min(self.graph[u][ei].cap);
                v = u;
            }
            // Apply.
            let mut v = t;
            while let Some((u, ei)) = prev[v] {
                let rev = self.graph[u][ei].rev;
                self.graph[u][ei].cap -= push;
                self.graph[v][rev].cap += push;
                total_cost += push * self.graph[u][ei].cost;
                v = u;
            }
            total_flow += push;
        }

        FlowResult { flow: total_flow, cost: total_cost }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_path() {
        let mut g = McmfGraph::new(3);
        g.add_edge(0, 1, 5, 2);
        g.add_edge(1, 2, 3, 1);
        let r = g.min_cost_flow(0, 2, 10);
        assert_eq!(r, FlowResult { flow: 3, cost: 9 });
    }

    #[test]
    fn chooses_cheaper_path_first() {
        // Two parallel 0->2 paths: via 1 (cost 1+1), direct (cost 5).
        let mut g = McmfGraph::new(3);
        let e_direct = g.add_edge(0, 2, 10, 5);
        g.add_edge(0, 1, 2, 1);
        g.add_edge(1, 2, 2, 1);
        let r = g.min_cost_flow(0, 2, 3);
        assert_eq!(r.flow, 3);
        assert_eq!(r.cost, 2 * 2 + 1 * 5);
        assert_eq!(g.edge_flow(e_direct), 1);
    }

    #[test]
    fn respects_limit() {
        let mut g = McmfGraph::new(2);
        g.add_edge(0, 1, 100, 1);
        let r = g.min_cost_flow(0, 1, 7);
        assert_eq!(r, FlowResult { flow: 7, cost: 7 });
    }

    #[test]
    fn assignment_via_flow_matches_bnb() {
        // Same 3x3 assignment as the B&B test; optimal cost 12.
        let cost = [[4i64, 2, 8], [4, 3, 7], [3, 1, 6]];
        // nodes: 0 = s, 1..=3 rows, 4..=6 cols, 7 = t
        let mut g = McmfGraph::new(8);
        for i in 0..3 {
            g.add_edge(0, 1 + i, 1, 0);
            g.add_edge(4 + i, 7, 1, 0);
        }
        for i in 0..3 {
            for j in 0..3 {
                g.add_edge(1 + i, 4 + j, 1, cost[i][j]);
            }
        }
        let r = g.min_cost_flow(0, 7, 3);
        assert_eq!(r, FlowResult { flow: 3, cost: 12 });
    }

    #[test]
    fn negative_costs_ok() {
        // Profitable edge (negative cost) must be exploited.
        let mut g = McmfGraph::new(3);
        g.add_edge(0, 1, 1, -5);
        g.add_edge(1, 2, 1, 2);
        g.add_edge(0, 2, 1, 0);
        let r = g.min_cost_flow(0, 2, 2);
        assert_eq!(r.flow, 2);
        assert_eq!(r.cost, -3);
    }

    #[test]
    fn disconnected_sink() {
        let mut g = McmfGraph::new(4);
        g.add_edge(0, 1, 5, 1);
        // node 2,3 disconnected
        let r = g.min_cost_flow(0, 3, 5);
        assert_eq!(r.flow, 0);
    }

    #[test]
    fn transportation_capacity_saturation() {
        // 5 units demand, two "engines" with caps 2 and 3 and costs 1, 2.
        let mut g = McmfGraph::new(4);
        g.add_edge(0, 1, 2, 1);
        g.add_edge(0, 2, 3, 2);
        g.add_edge(1, 3, 2, 0);
        g.add_edge(2, 3, 3, 0);
        let r = g.min_cost_flow(0, 3, 5);
        assert_eq!(r, FlowResult { flow: 5, cost: 2 + 6 });
    }
}
