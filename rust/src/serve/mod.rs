//! Network serving subsystem: a dependency-free TCP inference service.
//!
//! The paper positions MENAGE as a general-purpose edge inference
//! platform, and host-side event *delivery* — not core compute — is the
//! usual end-to-end bottleneck for neuromorphic accelerators. This module
//! is that missing layer: it turns the in-process library
//! ([`crate::accel::Menage`] behind [`crate::coordinator::Coordinator`])
//! into a network service, std-only (the container vendors only the
//! `anyhow` shim; no tokio, no serde).
//!
//! * [`protocol`] — the length-prefixed binary wire protocol (frame
//!   layout, typed messages, incremental [`protocol::FrameReader`]).
//! * [`codec`] — bounds-checked little-endian (de)serialization
//!   primitives, including the [`crate::snn::SpikeTrain`] wire form.
//! * [`server`] — the multi-threaded server: per-connection readers feed
//!   the coordinator's shared queue, so `with_lanes_wait` micro-batches
//!   requests **across sockets** into lane-packed dispatches; admission
//!   control (bounded in-flight + explicit overload reject), per-request
//!   deadlines, graceful drain on shutdown.
//! * [`client`] — blocking client library (sync and pipelined).
//! * [`metrics`] — the lock-free per-request metrics registry served over
//!   the STATS frame, including the [`crate::obs`] observability plane:
//!   per-stage trace-span histograms, per-core/per-shard execution
//!   counters, and the slowest-trace ring behind the versioned `profile`
//!   block (`protocol::STATS_VERSION`; rendered live by `menage top`).
//! * [`session`] — server-side streaming sessions: one pool thread pins
//!   a chip lane per open session so SESSION_CHUNK frames resume from the
//!   suspended membrane state, bit-identical to a one-shot run over the
//!   concatenated train (`tests/stream_differential.rs`).
//! * [`shard_host`] — serve ONE chip of a [`crate::mapping::ShardPlan`]
//!   over the same protocol (`menage shard-host`), so a sharded pipeline
//!   can span processes.
//! * [`remote_shard`] — the distributed-pipeline driver: one [`Client`]
//!   per shard host, streaming boundary frontiers link-to-link with a
//!   bounded number of timesteps in flight per link.
//!
//! CLI entry points: `menage serve` (stand up a server; add
//! `--remote-shards host:port,...` to execute on shard hosts),
//! `menage shard-host` (host one shard), and `menage loadgen` (drive a
//! server over loopback and emit `BENCH_serve.json`). End-to-end
//! behaviour — including bit-identical outputs vs in-process execution —
//! is pinned by `tests/serve_roundtrip.rs` and `tests/dist_identity.rs`.

pub mod client;
pub mod codec;
pub mod metrics;
pub mod protocol;
pub mod remote_shard;
pub mod server;
pub mod session;
pub mod shard_host;

pub use client::{backoff_schedule, Client, InferReply, Reply};
pub use metrics::ServeMetrics;
pub use protocol::{ErrorCode, FrameKind};
pub use remote_shard::{RemoteLinkStats, RemoteShardConfig, RemoteShardPipeline};
pub use server::{ModelInfo, ServeConfig, Server};
pub use session::{SessionCounters, SessionPool};
pub use shard_host::{ShardHostConfig, ShardHostServer};
