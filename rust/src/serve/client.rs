//! Blocking client library for the wire protocol.
//!
//! One [`Client`] wraps one TCP connection. Two usage shapes:
//!
//! * **Synchronous** — [`Client::infer`] sends a request and blocks until
//!   its reply arrives. Simplest; one request in flight per connection.
//! * **Pipelined** — [`Client::send_infer`] / [`Client::recv_reply`] let a
//!   caller keep several requests outstanding on one socket (replies may
//!   arrive in any order; correlate by id). The load generator uses this
//!   to keep the server's admission window full.

use std::net::{TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::snn::SpikeTrain;
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::protocol::{
    decode_stats_reply, write_frame, ErrorCode, ErrorFrame, Frame, FrameKind, FrameReader,
    InferRequest, InferResponse, SessionChunkFrame, SessionIdFrame, SessionOutFrame,
    ShardAckFrame, ShardStepFrame, DEFAULT_MAX_FRAME_LEN,
};

/// Exponential backoff schedule with jitter: attempt `i` waits
/// `min(cap, base·2^i)` scaled by a jitter factor uniform in `[0.5, 1.0)`
/// drawn from a seeded [`Rng`] — so retries from many clients (e.g. the
/// load generator's N connections racing one server start) spread out
/// instead of stampeding in lockstep, while any given seed reproduces its
/// schedule exactly (pinned by unit test).
pub fn backoff_schedule(
    attempts: usize,
    base: Duration,
    cap: Duration,
    seed: u64,
) -> Vec<Duration> {
    let mut rng = Rng::new(seed ^ 0x9E37_79B9_7F4A_7C15);
    (0..attempts)
        .map(|i| {
            let full = base
                .as_nanos()
                .saturating_mul(1u128 << i.min(32) as u32)
                .min(cap.as_nanos());
            let jitter = 0.5 + 0.5 * rng.f64();
            Duration::from_nanos((full as f64 * jitter).min(u64::MAX as f64) as u64)
        })
        .collect()
}

/// A successfully decoded INFER_RESPONSE (see [`InferResponse`]).
pub type InferReply = InferResponse;

/// Decode one server frame into a [`Reply`].
fn decode_reply(kind: u8, payload: &[u8]) -> Result<Reply> {
    Ok(match FrameKind::from_u8(kind) {
        Some(FrameKind::InferResponse) => Reply::Infer(InferResponse::decode(payload)?),
        Some(FrameKind::Error) => Reply::Error(ErrorFrame::decode(payload)?),
        Some(FrameKind::Pong) => Reply::Pong,
        Some(FrameKind::StatsReply) => Reply::Stats(decode_stats_reply(payload)?),
        Some(FrameKind::ShardAck) => Reply::ShardAck(ShardAckFrame::decode(payload)?),
        // Session acks are the request frame echoed back (s→c direction).
        Some(FrameKind::SessionOpen) => Reply::SessionOpened(SessionIdFrame::decode(payload)?),
        Some(FrameKind::SessionClose) => Reply::SessionClosed(SessionIdFrame::decode(payload)?),
        Some(FrameKind::SessionOut) => Reply::SessionOut(SessionOutFrame::decode(payload)?),
        other => bail!("unexpected frame from server: {other:?} (kind byte {kind})"),
    })
}

/// Everything a server can send back.
#[derive(Debug, Clone)]
pub enum Reply {
    Infer(InferReply),
    Error(ErrorFrame),
    Pong,
    Stats(Json),
    /// A shard-host's per-timestep result (distributed pipeline link).
    ShardAck(ShardAckFrame),
    /// SESSION_OPEN ack: the session's lane is pinned server-side.
    SessionOpened(SessionIdFrame),
    /// SESSION_CLOSE ack: the lane is folded and freed.
    SessionClosed(SessionIdFrame),
    /// One streamed chunk's result (per-chunk cycles + rolling predicted).
    SessionOut(SessionOutFrame),
}

/// Blocking connection to a `menage serve` instance.
pub struct Client {
    stream: TcpStream,
    reader: FrameReader,
    next_id: u64,
}

impl Client {
    pub fn connect(addr: impl ToSocketAddrs) -> Result<Self> {
        let stream = TcpStream::connect(addr).context("connecting to inference server")?;
        stream.set_nodelay(true).ok();
        Ok(Self { stream, reader: FrameReader::new(DEFAULT_MAX_FRAME_LEN), next_id: 0 })
    }

    /// [`Self::connect`] with retries — for racing a server that is still
    /// binding (the loadgen-vs-serve startup in `make smoke-serve`).
    /// Retries follow [`backoff_schedule`] with base `delay`, capped at
    /// 16× `delay`. The seed mixes the process id with a per-call counter
    /// so concurrent callers — including threads of one process —
    /// desynchronize; callers that need a reproducible schedule use
    /// [`Self::connect_backoff`] with an explicit seed.
    pub fn connect_retry(
        addr: impl ToSocketAddrs + Copy,
        attempts: usize,
        delay: Duration,
    ) -> Result<Self> {
        static CALL: AtomicU64 = AtomicU64::new(0);
        let seed = (std::process::id() as u64)
            ^ CALL.fetch_add(1, Ordering::Relaxed).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Self::connect_backoff(addr, attempts, delay, delay * 16, seed)
    }

    /// [`Self::connect`] retried along an explicit jittered
    /// [`backoff_schedule`] — callers with many concurrent connections
    /// (the load generator) pass distinct seeds so their retry storms
    /// spread out.
    pub fn connect_backoff(
        addr: impl ToSocketAddrs + Copy,
        attempts: usize,
        base: Duration,
        cap: Duration,
        seed: u64,
    ) -> Result<Self> {
        let schedule = backoff_schedule(attempts.max(1), base, cap, seed);
        let mut last = None;
        for (i, delay) in schedule.iter().enumerate() {
            match Self::connect(addr) {
                Ok(c) => return Ok(c),
                Err(e) => last = Some(e),
            }
            // The delay buys another attempt; after the final failure
            // there is none, so sleeping would only add up to `cap` of
            // dead latency before the caller sees the error.
            if i + 1 < schedule.len() {
                std::thread::sleep(*delay);
            }
        }
        Err(last.unwrap())
    }

    /// Send one inference request without waiting for the reply; returns
    /// the correlation id. `deadline_ms` of 0 means no deadline.
    pub fn send_infer(
        &mut self,
        train: &SpikeTrain,
        deadline_ms: u32,
        label: Option<u32>,
    ) -> Result<u64> {
        let id = self.next_id;
        self.next_id += 1;
        let req = InferRequest { id, deadline_ms, label, train: train.clone() };
        write_frame(&mut self.stream, FrameKind::InferRequest, &req.encode())
            .context("sending INFER_REQUEST")?;
        Ok(id)
    }

    /// Send one pipeline-timestep frontier to a shard-host without waiting
    /// for the SHARD_ACK — the distributed driver keeps several steps in
    /// flight per link and collects acks with [`Self::recv_reply_timeout`].
    pub fn send_shard_step(&mut self, step: &ShardStepFrame) -> Result<()> {
        write_frame(&mut self.stream, FrameKind::ShardStep, &step.encode())
            .context("sending SHARD_STEP")?;
        Ok(())
    }

    /// Block until the next server frame and decode it.
    pub fn recv_reply(&mut self) -> Result<Reply> {
        loop {
            match self.reader.read_frame(&mut self.stream) {
                Ok(Some(Frame { kind, payload })) => return decode_reply(kind, &payload),
                Ok(None) => bail!("server closed the connection"),
                // A read timeout left armed on the socket (e.g. a failed
                // restore in [`Self::recv_reply_timeout`]) must not
                // masquerade as connection loss: resume the read —
                // [`FrameReader`] keeps any partial frame across the
                // interruption, so no bytes are lost.
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    continue
                }
                Err(e) => return Err(e).context("reading server frame"),
            }
        }
    }

    /// [`Self::recv_reply`] bounded by a socket read timeout: `Ok(None)`
    /// when the window expires with no complete frame (the connection
    /// stays usable — [`FrameReader`] keeps any partial frame and resumes
    /// on the next call), `Err` on connection loss or protocol violation.
    /// The load generator uses this to detect lost responses without
    /// wedging on a dead or chaos-injected server.
    pub fn recv_reply_timeout(&mut self, timeout: Duration) -> Result<Option<Reply>> {
        self.stream
            .set_read_timeout(Some(timeout))
            .context("setting read timeout")?;
        let r = match self.reader.read_frame(&mut self.stream) {
            Ok(Some(Frame { kind, payload })) => decode_reply(kind, &payload).map(Some),
            Ok(None) => Err(anyhow::anyhow!("server closed the connection")),
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                Ok(None)
            }
            Err(e) => Err(e).context("reading server frame"),
        };
        // Restore the blocking socket. A failure here used to be swallowed
        // with `.ok()`, leaving the timeout armed so the *next* plain
        // `recv_reply` could misreport an idle wait as connection loss.
        // Retry once; if the restore still fails and this call has nothing
        // better to report, surface it (a decoded reply or a prior error
        // takes precedence — `recv_reply` now resumes across a stale
        // timeout, so the socket stays usable either way).
        let restored = self
            .stream
            .set_read_timeout(None)
            .or_else(|_| self.stream.set_read_timeout(None));
        match (r, restored) {
            (Ok(None), Err(e)) => Err(e).context("restoring blocking read mode"),
            (r, _) => r,
        }
    }

    /// Synchronous inference: send, then block for this request's reply.
    /// A server-sent ERROR for this id becomes an `Err` naming the code.
    pub fn infer(&mut self, train: &SpikeTrain) -> Result<InferReply> {
        self.infer_with_deadline(train, 0)
    }

    /// [`Self::infer`] with a relative deadline in milliseconds.
    pub fn infer_with_deadline(&mut self, train: &SpikeTrain, deadline_ms: u32) -> Result<InferReply> {
        let id = self.send_infer(train, deadline_ms, None)?;
        loop {
            match self.recv_reply()? {
                Reply::Infer(r) if r.id == id => return Ok(r),
                Reply::Error(e) if e.id == id => {
                    bail!("server rejected request {id}: [{}] {}", e.code.name(), e.message)
                }
                Reply::Error(e) if e.code == ErrorCode::Malformed => {
                    // Connection-level fault: the server is closing us.
                    bail!("connection error from server: {}", e.message)
                }
                // A stale reply (e.g. from an abandoned pipelined request)
                // or an unsolicited Pong: skip and keep waiting.
                _ => continue,
            }
        }
    }

    /// Query the server's metrics snapshot (includes the `model` block a
    /// load generator needs to synthesize inputs). Call only with no
    /// inference replies outstanding on this connection.
    pub fn stats(&mut self) -> Result<Json> {
        write_frame(&mut self.stream, FrameKind::Stats, &[]).context("sending STATS")?;
        match self.recv_reply()? {
            Reply::Stats(j) => Ok(j),
            Reply::Error(e) => bail!("STATS failed: [{}] {}", e.code.name(), e.message),
            other => bail!("expected STATS_REPLY, got {other:?}"),
        }
    }

    /// [`Self::stats`] with schema validation: fails loudly unless the
    /// snapshot carries `stats_version ==`
    /// [`super::protocol::STATS_VERSION`]. Structured pollers (`menage
    /// top`, `loadgen --profile`) use this so shape drift is a typed error
    /// at the first poll, never silently-null fields in a dashboard.
    pub fn stats_versioned(&mut self) -> Result<Json> {
        let j = self.stats()?;
        let want = super::protocol::STATS_VERSION;
        match j.get("stats_version").ok().and_then(|v| v.as_usize().ok()) {
            Some(got) if got as u64 == want => Ok(j),
            Some(got) => bail!(
                "server reports stats_version {got}, this client expects {want} — \
                 upgrade whichever side is older"
            ),
            None => bail!(
                "server's STATS snapshot carries no stats_version (pre-v{want} server) — \
                 this poller needs a server with the profile block"
            ),
        }
    }

    /// Open a streaming session: the server pins a chip lane whose
    /// membrane state persists across [`Self::session_chunk`] calls until
    /// [`Self::close_session`] (or eviction). Blocks for the open-ack; a
    /// full server answers `ERROR Overload` (no free session lane).
    pub fn open_session(&mut self, sid: u64) -> Result<()> {
        let f = SessionIdFrame { sid };
        write_frame(&mut self.stream, FrameKind::SessionOpen, &f.encode())
            .context("sending SESSION_OPEN")?;
        loop {
            match self.recv_reply()? {
                Reply::SessionOpened(ack) if ack.sid == sid => return Ok(()),
                Reply::Error(e) if e.id == sid => {
                    bail!("SESSION_OPEN {sid} refused: [{}] {}", e.code.name(), e.message)
                }
                _ => continue,
            }
        }
    }

    /// Send one SESSION_CHUNK without waiting for its SESSION_OUT — the
    /// pipelined shape (`seq` must be strict from 0; collect replies with
    /// [`Self::recv_reply`] / [`Self::recv_reply_timeout`]).
    pub fn send_session_chunk(&mut self, sid: u64, seq: u64, chunk: &SpikeTrain) -> Result<()> {
        let f = SessionChunkFrame { sid, seq, chunk: chunk.clone() };
        write_frame(&mut self.stream, FrameKind::SessionChunk, &f.encode())
            .context("sending SESSION_CHUNK")?;
        Ok(())
    }

    /// Synchronous chunk: send, then block for this `(sid, seq)`'s
    /// SESSION_OUT. A server-sent ERROR for this sid becomes an `Err`
    /// (after which the session is gone — evicted server-side).
    pub fn session_chunk(
        &mut self,
        sid: u64,
        seq: u64,
        chunk: &SpikeTrain,
    ) -> Result<SessionOutFrame> {
        self.send_session_chunk(sid, seq, chunk)?;
        loop {
            match self.recv_reply()? {
                Reply::SessionOut(out) if out.sid == sid && out.seq == seq => return Ok(out),
                Reply::Error(e) if e.id == sid => {
                    bail!("SESSION_CHUNK {sid}/{seq} failed: [{}] {}", e.code.name(), e.message)
                }
                _ => continue,
            }
        }
    }

    /// Close a streaming session (blocks for the close-ack); the server
    /// folds the lane's stats into its chip totals and frees the lane.
    pub fn close_session(&mut self, sid: u64) -> Result<()> {
        let f = SessionIdFrame { sid };
        write_frame(&mut self.stream, FrameKind::SessionClose, &f.encode())
            .context("sending SESSION_CLOSE")?;
        loop {
            match self.recv_reply()? {
                Reply::SessionClosed(ack) if ack.sid == sid => return Ok(()),
                Reply::Error(e) if e.id == sid => {
                    bail!("SESSION_CLOSE {sid} failed: [{}] {}", e.code.name(), e.message)
                }
                _ => continue,
            }
        }
    }

    /// Liveness round-trip.
    pub fn ping(&mut self) -> Result<()> {
        write_frame(&mut self.stream, FrameKind::Ping, &[]).context("sending PING")?;
        match self.recv_reply()? {
            Reply::Pong => Ok(()),
            Reply::Error(e) => bail!("PING failed: [{}] {}", e.code.name(), e.message),
            other => bail!("expected PONG, got {other:?}"),
        }
    }

    /// Ask the server to begin a graceful shutdown (requires the server's
    /// `allow_remote_shutdown`; acked with PONG).
    pub fn request_shutdown(&mut self) -> Result<()> {
        write_frame(&mut self.stream, FrameKind::Shutdown, &[]).context("sending SHUTDOWN")?;
        match self.recv_reply()? {
            Reply::Pong => Ok(()),
            Reply::Error(e) => bail!("SHUTDOWN refused: [{}] {}", e.code.name(), e.message),
            other => bail!("expected shutdown ack, got {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_schedule_grows_caps_and_jitters() {
        let base = Duration::from_millis(10);
        let cap = Duration::from_millis(160);
        let sched = backoff_schedule(8, base, cap, 42);
        assert_eq!(sched.len(), 8);
        for (i, &d) in sched.iter().enumerate() {
            // Envelope: jitter ∈ [0.5, 1.0) around min(cap, base·2^i).
            let full = std::cmp::min(cap, base * (1u32 << i.min(16)));
            assert!(d >= full / 2, "attempt {i}: {d:?} below jitter floor {:?}", full / 2);
            assert!(d <= full, "attempt {i}: {d:?} above envelope {full:?}");
        }
        // The envelope doubles until the cap: the later delays must sit at
        // the cap's jitter band, strictly above the first delay.
        assert!(sched[7] >= cap / 2);
        assert!(sched[0] < cap / 2, "first delay should be near base, got {:?}", sched[0]);
    }

    #[test]
    fn backoff_schedule_is_deterministic_per_seed() {
        let base = Duration::from_millis(5);
        let cap = Duration::from_secs(1);
        assert_eq!(backoff_schedule(6, base, cap, 7), backoff_schedule(6, base, cap, 7));
        // Different seeds jitter differently (with overwhelming likelihood
        // over 6 draws — this is a fixed-seed check, not a statistical one).
        assert_ne!(backoff_schedule(6, base, cap, 7), backoff_schedule(6, base, cap, 8));
    }

    #[test]
    fn backoff_schedule_edge_shapes() {
        // Zero attempts → empty; zero base → all-zero delays (busy retry).
        assert!(backoff_schedule(0, Duration::from_millis(1), Duration::from_secs(1), 1)
            .is_empty());
        let zeros = backoff_schedule(4, Duration::ZERO, Duration::from_secs(1), 1);
        assert!(zeros.iter().all(|d| d.is_zero()));
        // Huge attempt counts must not overflow the shift.
        let long = backoff_schedule(80, Duration::from_millis(1), Duration::from_millis(50), 3);
        assert!(long.iter().all(|&d| d <= Duration::from_millis(50)));
    }

    #[test]
    fn connect_backoff_skips_sleep_after_final_attempt() {
        // One attempt with a huge base delay: the old code slept the full
        // jittered delay (≥ 5 s here) after the only — and final — failed
        // connect before returning. The fix returns immediately.
        let t0 = std::time::Instant::now();
        let r = Client::connect_backoff(
            "127.0.0.1:1",
            1,
            Duration::from_secs(10),
            Duration::from_secs(10),
            11,
        );
        assert!(r.is_err());
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "slept after the final attempt: {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn connect_backoff_sleeps_exactly_n_minus_1_delays() {
        // The schedule is deterministic per seed, so the expected total
        // sleep is computable exactly: attempts=3 must sleep the first two
        // delays (lower bound) but not the third (upper bound).
        let (base, cap, seed) = (Duration::from_millis(80), Duration::from_millis(80), 13);
        let sched = backoff_schedule(3, base, cap, seed);
        let lower: Duration = sched[..2].iter().sum();
        let upper: Duration = sched.iter().sum();
        let t0 = std::time::Instant::now();
        let r = Client::connect_backoff("127.0.0.1:1", 3, base, cap, seed);
        assert!(r.is_err());
        let elapsed = t0.elapsed();
        assert!(elapsed >= lower, "fewer than N−1 sleeps: {elapsed:?} < {lower:?}");
        assert!(elapsed < upper, "slept after the final attempt: {elapsed:?} >= {upper:?}");
    }

    /// Loopback socket pair with the client wrapped in [`Client`]; the raw
    /// server side lets tests inject frames byte by byte.
    fn loopback_client() -> (Client, TcpStream) {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = Client::connect(addr).unwrap();
        let (server_side, _) = listener.accept().unwrap();
        (client, server_side)
    }

    #[test]
    fn recv_reply_resumes_across_stale_read_timeout() {
        use std::io::Write;
        let (mut client, mut server_side) = loopback_client();
        // Simulate the failure mode the fix targets: a read timeout left
        // armed on the socket (as if `recv_reply_timeout`'s restore had
        // failed). The blocking receive must ride across the spurious
        // WouldBlock wake-ups — including one that lands mid-frame — and
        // deliver the reply instead of reporting connection loss.
        client.stream.set_read_timeout(Some(Duration::from_millis(5))).unwrap();
        let frame = crate::serve::protocol::encode_frame(FrameKind::Pong, &[]);
        let writer = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(40));
            server_side.write_all(&frame[..3]).unwrap(); // partial header
            server_side.flush().unwrap();
            std::thread::sleep(Duration::from_millis(40));
            server_side.write_all(&frame[3..]).unwrap();
            server_side.flush().unwrap();
            server_side // keep the connection open until joined
        });
        assert!(matches!(client.recv_reply().unwrap(), Reply::Pong));
        drop(writer.join().unwrap());
    }

    #[test]
    fn recv_reply_timeout_then_blocking_recv_still_works() {
        let (mut client, mut server_side) = loopback_client();
        // Quiet window: expires with no frame, connection stays usable.
        assert!(client
            .recv_reply_timeout(Duration::from_millis(20))
            .unwrap()
            .is_none());
        // The restored blocking socket must then wait indefinitely — well
        // past the previous 20 ms window — for a real reply.
        let writer = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(60));
            write_frame(&mut server_side, FrameKind::Pong, &[]).unwrap();
            server_side
        });
        assert!(matches!(client.recv_reply().unwrap(), Reply::Pong));
        drop(writer.join().unwrap());
    }

    #[test]
    fn connect_backoff_fails_after_schedule_on_dead_port() {
        // Port 1 on loopback is essentially never listening; the call must
        // return the last connect error, not hang or panic.
        let t0 = std::time::Instant::now();
        let r = Client::connect_backoff(
            "127.0.0.1:1",
            2,
            Duration::from_millis(1),
            Duration::from_millis(2),
            9,
        );
        assert!(r.is_err());
        assert!(t0.elapsed() < Duration::from_secs(30));
    }
}
