//! Blocking client library for the wire protocol.
//!
//! One [`Client`] wraps one TCP connection. Two usage shapes:
//!
//! * **Synchronous** — [`Client::infer`] sends a request and blocks until
//!   its reply arrives. Simplest; one request in flight per connection.
//! * **Pipelined** — [`Client::send_infer`] / [`Client::recv_reply`] let a
//!   caller keep several requests outstanding on one socket (replies may
//!   arrive in any order; correlate by id). The load generator uses this
//!   to keep the server's admission window full.

use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::snn::SpikeTrain;
use crate::util::json::Json;

use super::protocol::{
    decode_stats_reply, write_frame, ErrorCode, ErrorFrame, Frame, FrameKind, FrameReader,
    InferRequest, InferResponse, DEFAULT_MAX_FRAME_LEN,
};

/// A successfully decoded INFER_RESPONSE (see [`InferResponse`]).
pub type InferReply = InferResponse;

/// Everything a server can send back.
#[derive(Debug, Clone)]
pub enum Reply {
    Infer(InferReply),
    Error(ErrorFrame),
    Pong,
    Stats(Json),
}

/// Blocking connection to a `menage serve` instance.
pub struct Client {
    stream: TcpStream,
    reader: FrameReader,
    next_id: u64,
}

impl Client {
    pub fn connect(addr: impl ToSocketAddrs) -> Result<Self> {
        let stream = TcpStream::connect(addr).context("connecting to inference server")?;
        stream.set_nodelay(true).ok();
        Ok(Self { stream, reader: FrameReader::new(DEFAULT_MAX_FRAME_LEN), next_id: 0 })
    }

    /// [`Self::connect`] with retries — for racing a server that is still
    /// binding (the loadgen-vs-serve startup in `make smoke-serve`).
    pub fn connect_retry(addr: impl ToSocketAddrs + Copy, attempts: usize, delay: Duration) -> Result<Self> {
        let mut last = None;
        for _ in 0..attempts.max(1) {
            match Self::connect(addr) {
                Ok(c) => return Ok(c),
                Err(e) => last = Some(e),
            }
            std::thread::sleep(delay);
        }
        Err(last.unwrap())
    }

    /// Send one inference request without waiting for the reply; returns
    /// the correlation id. `deadline_ms` of 0 means no deadline.
    pub fn send_infer(
        &mut self,
        train: &SpikeTrain,
        deadline_ms: u32,
        label: Option<u32>,
    ) -> Result<u64> {
        let id = self.next_id;
        self.next_id += 1;
        let req = InferRequest { id, deadline_ms, label, train: train.clone() };
        write_frame(&mut self.stream, FrameKind::InferRequest, &req.encode())
            .context("sending INFER_REQUEST")?;
        Ok(id)
    }

    /// Block until the next server frame and decode it.
    pub fn recv_reply(&mut self) -> Result<Reply> {
        let Frame { kind, payload } = match self.reader.read_frame(&mut self.stream) {
            Ok(Some(f)) => f,
            Ok(None) => bail!("server closed the connection"),
            Err(e) => return Err(e).context("reading server frame"),
        };
        Ok(match FrameKind::from_u8(kind) {
            Some(FrameKind::InferResponse) => Reply::Infer(InferResponse::decode(&payload)?),
            Some(FrameKind::Error) => Reply::Error(ErrorFrame::decode(&payload)?),
            Some(FrameKind::Pong) => Reply::Pong,
            Some(FrameKind::StatsReply) => Reply::Stats(decode_stats_reply(&payload)?),
            other => bail!("unexpected frame from server: {other:?} (kind byte {kind})"),
        })
    }

    /// Synchronous inference: send, then block for this request's reply.
    /// A server-sent ERROR for this id becomes an `Err` naming the code.
    pub fn infer(&mut self, train: &SpikeTrain) -> Result<InferReply> {
        self.infer_with_deadline(train, 0)
    }

    /// [`Self::infer`] with a relative deadline in milliseconds.
    pub fn infer_with_deadline(&mut self, train: &SpikeTrain, deadline_ms: u32) -> Result<InferReply> {
        let id = self.send_infer(train, deadline_ms, None)?;
        loop {
            match self.recv_reply()? {
                Reply::Infer(r) if r.id == id => return Ok(r),
                Reply::Error(e) if e.id == id => {
                    bail!("server rejected request {id}: [{}] {}", e.code.name(), e.message)
                }
                Reply::Error(e) if e.code == ErrorCode::Malformed => {
                    // Connection-level fault: the server is closing us.
                    bail!("connection error from server: {}", e.message)
                }
                // A stale reply (e.g. from an abandoned pipelined request)
                // or an unsolicited Pong: skip and keep waiting.
                _ => continue,
            }
        }
    }

    /// Query the server's metrics snapshot (includes the `model` block a
    /// load generator needs to synthesize inputs). Call only with no
    /// inference replies outstanding on this connection.
    pub fn stats(&mut self) -> Result<Json> {
        write_frame(&mut self.stream, FrameKind::Stats, &[]).context("sending STATS")?;
        match self.recv_reply()? {
            Reply::Stats(j) => Ok(j),
            Reply::Error(e) => bail!("STATS failed: [{}] {}", e.code.name(), e.message),
            other => bail!("expected STATS_REPLY, got {other:?}"),
        }
    }

    /// Liveness round-trip.
    pub fn ping(&mut self) -> Result<()> {
        write_frame(&mut self.stream, FrameKind::Ping, &[]).context("sending PING")?;
        match self.recv_reply()? {
            Reply::Pong => Ok(()),
            Reply::Error(e) => bail!("PING failed: [{}] {}", e.code.name(), e.message),
            other => bail!("expected PONG, got {other:?}"),
        }
    }

    /// Ask the server to begin a graceful shutdown (requires the server's
    /// `allow_remote_shutdown`; acked with PONG).
    pub fn request_shutdown(&mut self) -> Result<()> {
        write_frame(&mut self.stream, FrameKind::Shutdown, &[]).context("sending SHUTDOWN")?;
        match self.recv_reply()? {
            Reply::Pong => Ok(()),
            Reply::Error(e) => bail!("SHUTDOWN refused: [{}] {}", e.code.name(), e.message),
            other => bail!("expected shutdown ack, got {other:?}"),
        }
    }
}
