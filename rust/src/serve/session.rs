//! Server-side streaming sessions: suspend/resume inference over the wire.
//!
//! A session pins one **lane** of a dedicated backend clone for the
//! lifetime of a client-side spike stream: each SESSION_CHUNK runs through
//! the chip *without* resetting membranes first, so the lane's
//! `SoaState` column (membrane / Neumaier error sidecar / dirty flags)
//! carries across chunks and the concatenated stream is bit-identical to
//! a one-shot [`Menage::run`] over the whole train
//! (`tests/stream_differential.rs`).
//!
//! Topology: one pool thread owns one [`Backend`] clone with up to
//! `capacity` session lanes. Connection readers decode session frames and
//! forward typed commands over an mpsc channel; the pool executes chunks
//! — batching chunks of *distinct* sessions that arrived together into a
//! single lane-packed dispatch — and queues replies directly on each
//! connection's bounded writer channel. Stateful work never touches the
//! stateless coordinator queue, so ordinary INFER traffic can neither
//! observe nor perturb resident membranes.
//!
//! Lifecycle and accounting invariants:
//!
//! * **Admission**: a SESSION_OPEN with no free lane is rejected with
//!   `ERROR Overload` (id = sid); the client retries or falls back to
//!   one-shot INFER.
//! * **Ordering**: chunk sequence numbers are strict from 0. A gap,
//!   replay, or reorder evicts the session with `ERROR BadRequest` — the
//!   membrane state would be silently wrong for any other policy. The
//!   connection itself stays usable.
//! * **Eviction folds stats first**: every eviction path (CLOSE, seq
//!   violation, connection teardown, idle timeout, pool shutdown) folds
//!   the lane's per-lane [`CoreStats`](crate::neuracore::CoreStats) into
//!   the chip totals *before* the lane is recycled, so session work can
//!   never vanish from the energy report. The pool's chip is handed back
//!   through [`SessionPool::shutdown`] and merged with the coordinator
//!   workers' chips.
//! * **Idle timeout**: a session with no chunk for `idle_timeout` is
//!   evicted silently (the client discovers it as `BadRequest
//!   unknown session` on its next chunk) so abandoned streams cannot pin
//!   lanes forever.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::accel::{Menage, RunOutput};
use crate::coordinator::Backend;
use crate::snn::SpikeTrain;
use crate::util::json::Json;

use super::metrics::ServeMetrics;
use super::protocol::{encode_frame, ErrorCode, ErrorFrame, FrameKind, SessionIdFrame, SessionOutFrame};
use super::server::queue_frame;

/// Commands a pool batch drains per wakeup before dispatching — bounds the
/// latency any one chunk can be delayed by arrivals behind it.
const CMD_BATCH: usize = 64;

/// Session counters for the STATS `sessions` block. Monotonic except
/// `resident`, which is the live lane-occupancy gauge.
#[derive(Debug, Default)]
pub struct SessionCounters {
    /// Sessions successfully opened (lane granted).
    pub opened: AtomicU64,
    /// Sessions closed by an explicit SESSION_CLOSE.
    pub closed: AtomicU64,
    /// Sessions evicted without a close: sequence violations, connection
    /// teardown, idle timeout.
    pub evicted: AtomicU64,
    /// SESSION_OPENs refused for lack of a free lane (`ERROR Overload`).
    pub rejected: AtomicU64,
    /// Chunks executed across all sessions.
    pub chunks: AtomicU64,
    /// Sessions currently resident (gauge, ≤ capacity).
    pub resident: AtomicU64,
}

/// One client→pool command. Replies go straight onto the submitting
/// connection's bounded writer channel (`tx`), never through the
/// coordinator's results router.
pub(crate) enum SessionCmd {
    Open { conn: u64, sid: u64, tx: SyncSender<Vec<u8>> },
    Chunk { conn: u64, sid: u64, seq: u64, chunk: SpikeTrain, tx: SyncSender<Vec<u8>> },
    Close { conn: u64, sid: u64, tx: SyncSender<Vec<u8>> },
    /// The connection's reader exited: evict every session it owned.
    ConnGone { conn: u64 },
}

/// Cloneable ingress handle the connection readers use, plus the counter
/// block the STATS snapshot reads.
#[derive(Clone)]
pub struct SessionHandle {
    tx: Sender<SessionCmd>,
    counters: Arc<SessionCounters>,
    capacity: usize,
}

impl SessionHandle {
    pub(crate) fn send(&self, cmd: SessionCmd) {
        // A closed channel means the pool is shutting down; the reader's
        // connection is about to die with it — nothing useful to report.
        let _ = self.tx.send(cmd);
    }

    pub fn counters(&self) -> &SessionCounters {
        &self.counters
    }

    /// The STATS `sessions` block.
    pub fn to_json(&self) -> Json {
        let c = &self.counters;
        let g = |a: &AtomicU64| -> Json { (a.load(Ordering::Relaxed) as usize).into() };
        Json::obj(vec![
            ("capacity", self.capacity.into()),
            ("opened", g(&c.opened)),
            ("closed", g(&c.closed)),
            ("evicted", g(&c.evicted)),
            ("rejected", g(&c.rejected)),
            ("chunks", g(&c.chunks)),
            ("resident", g(&c.resident)),
        ])
    }
}

/// A resident session: its pinned lane, sequencing state, and the
/// cumulative per-class spike counts the rolling `predicted` is read from.
struct SessionSlot {
    lane: usize,
    next_seq: u64,
    last_chunk: Instant,
    class_counts: Vec<u64>,
}

/// The session pool: one thread, one backend clone, `capacity` lanes.
/// Built by the server for local (mono/sharded) backends; absent on
/// remote-shard servers, whose readers answer session frames with
/// `ERROR Unsupported`.
pub struct SessionPool {
    handle: SessionHandle,
    stop: Arc<AtomicBool>,
    thread: Option<JoinHandle<Option<Menage>>>,
}

impl SessionPool {
    pub(crate) fn start(
        backend: Backend,
        metrics: Arc<ServeMetrics>,
        capacity: usize,
        idle_timeout: Duration,
        poll: Duration,
    ) -> Self {
        let counters = Arc::new(SessionCounters::default());
        let stop = Arc::new(AtomicBool::new(false));
        let (tx, rx) = mpsc::channel();
        let thread = {
            let counters = Arc::clone(&counters);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                pool_loop(backend, rx, &metrics, &counters, &stop, capacity, idle_timeout, poll)
            })
        };
        Self {
            handle: SessionHandle { tx, counters, capacity: capacity.max(1) },
            stop,
            thread: Some(thread),
        }
    }

    pub fn handle(&self) -> SessionHandle {
        self.handle.clone()
    }

    /// Stop the pool thread and hand back its chip: every resident
    /// session's lane stats are folded in, so merging this chip with the
    /// coordinator workers' chips accounts for all session work.
    pub fn shutdown(mut self) -> Option<Menage> {
        self.stop.store(true, Ordering::Relaxed);
        self.thread.take().and_then(|t| t.join().ok()).flatten()
    }
}

impl Drop for SessionPool {
    /// A dropped (not shut-down) pool must not leave its thread parked on
    /// the command channel; the thread is detached, not joined.
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
    }
}

fn send_session_error(
    m: &ServeMetrics,
    tx: &SyncSender<Vec<u8>>,
    sid: u64,
    code: ErrorCode,
    msg: impl Into<String>,
) {
    let ef = ErrorFrame::new(sid, code, msg);
    queue_frame(m, tx, encode_frame(FrameKind::Error, &ef.encode()));
}

/// One staged chunk job awaiting a lane-packed dispatch.
struct ChunkJob {
    key: (u64, u64),
    lane: usize,
    seq: u64,
    chunk: SpikeTrain,
    tx: SyncSender<Vec<u8>>,
}

struct PoolState<'a> {
    backend: Backend,
    metrics: &'a ServeMetrics,
    counters: &'a SessionCounters,
    sessions: HashMap<(u64, u64), SessionSlot>,
    /// Free lane indices; popped lowest-first so the lane grid grows only
    /// as far as the peak concurrency actually reached.
    free: Vec<usize>,
    idle_timeout: Duration,
}

impl PoolState<'_> {
    fn resident_gauge(&self) {
        self.counters.resident.store(self.sessions.len() as u64, Ordering::Relaxed);
    }

    /// Fold the lane's stats and recycle it. The fold-before-reuse order
    /// is the satellite-4 invariant: session work must survive into the
    /// chip totals no matter how the session ended.
    fn retire(&mut self, key: (u64, u64), closed: bool) {
        if let Some(slot) = self.sessions.remove(&key) {
            self.backend.fold_session_lane(slot.lane);
            self.free.push(slot.lane);
            if closed {
                self.counters.closed.fetch_add(1, Ordering::Relaxed);
            } else {
                self.counters.evicted.fetch_add(1, Ordering::Relaxed);
            }
            self.resident_gauge();
        }
    }

    fn open(&mut self, conn: u64, sid: u64, tx: &SyncSender<Vec<u8>>) {
        let key = (conn, sid);
        if self.sessions.contains_key(&key) {
            send_session_error(
                self.metrics,
                tx,
                sid,
                ErrorCode::BadRequest,
                format!("session {sid} is already open on this connection"),
            );
            return;
        }
        let Some(lane) = self.free.pop() else {
            self.counters.rejected.fetch_add(1, Ordering::Relaxed);
            send_session_error(
                self.metrics,
                tx,
                sid,
                ErrorCode::Overload,
                format!("no free session lane ({} resident)", self.sessions.len()),
            );
            return;
        };
        if let Err(e) = self.backend.open_session_lane(lane) {
            self.free.push(lane);
            send_session_error(self.metrics, tx, sid, ErrorCode::Internal, format!("{e:#}"));
            return;
        }
        let classes = match &self.backend {
            Backend::Mono(c) => c.cores.last().map_or(0, |core| core.out_dim()),
            Backend::Sharded(s) => s.output_dim(),
            Backend::Remote(_) => 0,
        };
        self.sessions.insert(
            key,
            SessionSlot {
                lane,
                next_seq: 0,
                last_chunk: Instant::now(),
                class_counts: vec![0u64; classes],
            },
        );
        self.counters.opened.fetch_add(1, Ordering::Relaxed);
        self.resident_gauge();
        // The open-ack is the request frame echoed back.
        let ack = SessionIdFrame { sid };
        queue_frame(self.metrics, tx, encode_frame(FrameKind::SessionOpen, &ack.encode()));
    }

    fn close(&mut self, conn: u64, sid: u64, tx: &SyncSender<Vec<u8>>) {
        let key = (conn, sid);
        if self.sessions.contains_key(&key) {
            self.retire(key, true);
            let ack = SessionIdFrame { sid };
            queue_frame(self.metrics, tx, encode_frame(FrameKind::SessionClose, &ack.encode()));
        } else {
            send_session_error(
                self.metrics,
                tx,
                sid,
                ErrorCode::BadRequest,
                format!("unknown session {sid}"),
            );
        }
    }

    fn conn_gone(&mut self, conn: u64) {
        let keys: Vec<(u64, u64)> =
            self.sessions.keys().filter(|k| k.0 == conn).copied().collect();
        for key in keys {
            self.retire(key, false);
        }
    }

    fn evict_idle(&mut self) {
        let idle = self.idle_timeout;
        let keys: Vec<(u64, u64)> = self
            .sessions
            .iter()
            .filter(|(_, s)| s.last_chunk.elapsed() > idle)
            .map(|(k, _)| *k)
            .collect();
        for key in keys {
            self.retire(key, false);
        }
    }

    /// Validate one chunk command against its session. `Ok` advances the
    /// sequence eagerly (the job WILL be dispatched by the caller);
    /// `Err(())` means the reply has already been sent.
    fn stage_chunk(
        &mut self,
        conn: u64,
        sid: u64,
        seq: u64,
        chunk: SpikeTrain,
        tx: SyncSender<Vec<u8>>,
    ) -> Result<ChunkJob, ()> {
        let key = (conn, sid);
        let width = self.backend.input_dim();
        let Some(slot) = self.sessions.get_mut(&key) else {
            send_session_error(
                self.metrics,
                &tx,
                sid,
                ErrorCode::BadRequest,
                format!("unknown session {sid} (never opened, or evicted)"),
            );
            return Err(());
        };
        if seq != slot.next_seq {
            let expect = slot.next_seq;
            self.retire(key, false);
            send_session_error(
                self.metrics,
                &tx,
                sid,
                ErrorCode::BadRequest,
                format!("chunk seq {seq}, expected {expect} — session evicted"),
            );
            return Err(());
        }
        if chunk.num_neurons != width {
            self.retire(key, false);
            send_session_error(
                self.metrics,
                &tx,
                sid,
                ErrorCode::BadRequest,
                format!(
                    "chunk has {} neurons, model expects {width} — session evicted",
                    chunk.num_neurons
                ),
            );
            return Err(());
        }
        slot.next_seq += 1;
        slot.last_chunk = Instant::now();
        let lane = slot.lane;
        Ok(ChunkJob { key, lane, seq, chunk, tx })
    }

    /// Run one lane-packed dispatch over staged jobs (distinct lanes) and
    /// reply per job with a SESSION_OUT carrying the chunk's cycles and
    /// the session-cumulative predicted class.
    fn dispatch(&mut self, mut jobs: Vec<ChunkJob>, outs: &mut Vec<RunOutput>) {
        if jobs.is_empty() {
            return;
        }
        jobs.sort_by_key(|j| j.lane);
        let inputs: Vec<(usize, &SpikeTrain)> =
            jobs.iter().map(|j| (j.lane, &j.chunk)).collect();
        match self.backend.run_session_chunks_into(&inputs, outs) {
            Ok(()) => {
                for (j, out) in jobs.iter().zip(outs.iter()) {
                    let slot = self
                        .sessions
                        .get_mut(&j.key)
                        .expect("staged job's session is resident");
                    for (class, n) in out.output().counts().into_iter().enumerate() {
                        slot.class_counts[class] += n as u64;
                    }
                    // Rolling decision over everything streamed so far,
                    // same tie-break as `SpikeTrain::argmax_class`.
                    let mut best = 0usize;
                    for (i, &v) in slot.class_counts.iter().enumerate() {
                        if v > slot.class_counts[best] {
                            best = i;
                        }
                    }
                    self.counters.chunks.fetch_add(1, Ordering::Relaxed);
                    self.metrics.total_cycles.fetch_add(out.cycles, Ordering::Relaxed);
                    self.metrics
                        .events_in
                        .fetch_add(j.chunk.total_spikes() as u64, Ordering::Relaxed);
                    let reply = SessionOutFrame {
                        sid: j.key.1,
                        seq: j.seq,
                        chunk_cycles: out.cycles,
                        predicted: best as u32,
                        output: out.output().clone(),
                    };
                    queue_frame(
                        self.metrics,
                        &j.tx,
                        encode_frame(FrameKind::SessionOut, &reply.encode()),
                    );
                }
            }
            Err(e) => {
                // Pre-validation makes this unreachable in practice; if the
                // engine does fail, the lanes' membrane state can no longer
                // be trusted — evict every session in the batch.
                for j in jobs {
                    self.retire(j.key, false);
                    send_session_error(
                        self.metrics,
                        &j.tx,
                        j.key.1,
                        ErrorCode::Internal,
                        format!("session chunk failed: {e:#}"),
                    );
                }
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn pool_loop(
    backend: Backend,
    rx: Receiver<SessionCmd>,
    metrics: &ServeMetrics,
    counters: &SessionCounters,
    stop: &AtomicBool,
    capacity: usize,
    idle_timeout: Duration,
    poll: Duration,
) -> Option<Menage> {
    let mut st = PoolState {
        backend,
        metrics,
        counters,
        sessions: HashMap::new(),
        free: (0..capacity.max(1)).rev().collect(),
        idle_timeout,
    };
    let mut outs: Vec<RunOutput> = Vec::new();
    let mut batch: Vec<SessionCmd> = Vec::new();
    while !stop.load(Ordering::Relaxed) {
        batch.clear();
        match rx.recv_timeout(poll) {
            Ok(cmd) => batch.push(cmd),
            Err(RecvTimeoutError::Timeout) => {
                st.evict_idle();
                continue;
            }
            Err(RecvTimeoutError::Disconnected) => break,
        }
        // Drain what arrived together so chunks of distinct sessions share
        // one lane-packed dispatch (bounded: fairness over completeness).
        while batch.len() < CMD_BATCH {
            match rx.try_recv() {
                Ok(cmd) => batch.push(cmd),
                Err(_) => break,
            }
        }
        // Commands run strictly in arrival order; only maximal runs of
        // chunk commands touching *distinct* sessions collapse into one
        // dispatch (a second chunk of the same session ends the run, so
        // per-session ordering — and therefore the seq contract — holds).
        let mut jobs: Vec<ChunkJob> = Vec::new();
        for cmd in batch.drain(..) {
            match cmd {
                SessionCmd::Chunk { conn, sid, seq, chunk, tx } => {
                    if jobs.iter().any(|j| j.key == (conn, sid)) {
                        st.dispatch(std::mem::take(&mut jobs), &mut outs);
                    }
                    if let Ok(job) = st.stage_chunk(conn, sid, seq, chunk, tx) {
                        jobs.push(job);
                    }
                }
                other => {
                    st.dispatch(std::mem::take(&mut jobs), &mut outs);
                    match other {
                        SessionCmd::Open { conn, sid, tx } => st.open(conn, sid, &tx),
                        SessionCmd::Close { conn, sid, tx } => st.close(conn, sid, &tx),
                        SessionCmd::ConnGone { conn } => st.conn_gone(conn),
                        SessionCmd::Chunk { .. } => unreachable!("handled above"),
                    }
                }
            }
        }
        st.dispatch(jobs, &mut outs);
        st.evict_idle();
    }
    // Wind-down: fold every resident lane, then any lane-path residue, so
    // the handed-back chip's core totals account for all session work.
    let keys: Vec<(u64, u64)> = st.sessions.keys().copied().collect();
    for key in keys {
        st.retire(key, false);
    }
    st.backend.fold_lane_stats();
    st.backend.into_chip()
}
