//! The TCP inference server.
//!
//! Thread topology (std-only; no async runtime in the vendor set — and the
//! backend is a CPU-bound simulator, so blocking threads around one shared
//! queue is the right shape anyway):
//!
//! ```text
//!                    ┌────────────┐   accept    ┌─────────────────────┐
//!  clients ─────────►│ accept loop│────────────►│ per-conn reader ×N  │
//!                    └────────────┘             │  decode → admit →   │
//!                                               │  SubmitHandle.submit│
//!                                               └──────────┬──────────┘
//!                                                          ▼
//!                                        [coordinator shared queue]
//!                                         W workers × L lanes, fill-wait
//!                                         micro-batching ACROSS sockets
//!                                                          │
//!                    ┌────────────┐   results channel      ▼
//!  clients ◄─────────│ writer ×N  │◄──────────── [router thread owns the
//!                    └────────────┘   id-keyed     Coordinator, recv_timeout
//!                                     pending map  loop, drain on shutdown]
//! ```
//!
//! Because every connection's reader submits into the *same* coordinator
//! queue, [`Coordinator::with_lanes_wait`]'s fill-wait workers micro-batch
//! requests from many sockets into one lane-packed dispatch — the
//! host-side event-delivery path scales with connections without cloning
//! model images.
//!
//! **Admission control:** a server-wide in-flight cap; a request over the
//! cap is answered immediately with `ERROR Overload` (explicit reject, not
//! silent queueing — the client decides whether to retry). Per-request
//! deadlines: a result that completes after its deadline is replaced by
//! `ERROR DeadlineExceeded`.
//!
//! **Graceful shutdown** ([`Server::shutdown`]): stop accepting, join the
//! readers (no new submissions), then the router drains everything still
//! in flight through [`Coordinator::drain`] — recovering completed
//! responses via the salvage path if a request in the final batch failed —
//! routes them to their connections, and only then joins the workers.

use std::collections::HashMap;
use std::io::{BufWriter, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::accel::Menage;
use crate::coordinator::{request_id_of_error, Backend, Coordinator, Response};
use crate::fault::{lock_recover, ChaosTrigger, RecoveryStats, SystemChaos};
use crate::shard::ShardedMenage;
use crate::util::json::Json;

use super::metrics::ServeMetrics;
use super::protocol::{
    encode_frame, encode_stats_reply, ErrorCode, ErrorFrame, FrameKind, FrameReader,
    InferRequest, InferResponse, SessionChunkFrame, SessionIdFrame, DEFAULT_MAX_FRAME_LEN, MAGIC,
    NO_ID,
};
use super::session::{SessionCmd, SessionHandle, SessionPool};

/// Serving knobs. `Default` is sized for tests and small deployments;
/// `menage serve` exposes each as a flag.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Coordinator workers (chip clones).
    pub workers: usize,
    /// Lanes per worker — the micro-batch width per dispatch.
    pub lanes_per_worker: usize,
    /// How long a worker that drained a shallow queue keeps collecting
    /// late arrivals before dispatching (adaptive lane packing). This is
    /// the knob that lets requests from *different* sockets share a lane
    /// batch under trickle traffic.
    pub fill_wait: Duration,
    /// Admission cap: requests admitted but not yet answered. Beyond it,
    /// `ERROR Overload`.
    pub max_in_flight: usize,
    /// Frame payload cap (protects allocations from hostile frames).
    pub max_frame_len: u32,
    /// Read-timeout/stop-flag poll granularity for reader and router
    /// threads; bounds shutdown latency, not throughput.
    pub poll_interval: Duration,
    /// Socket write timeout. A client that stops reading (full TCP
    /// window) stalls its writer thread at most this long per frame
    /// before the connection is dropped — so a dead-reader client can
    /// never hang [`Server::shutdown`]'s writer join.
    pub write_timeout: Duration,
    /// Honor the SHUTDOWN frame (used by `loadgen --shutdown-server` and
    /// the `make smoke-serve` flow; off unless explicitly enabled).
    pub allow_remote_shutdown: bool,
    /// Streaming-session lane cap: how many sessions can hold membrane
    /// state resident at once (the session pool's lane-grid width). A
    /// SESSION_OPEN past the cap is answered `ERROR Overload`.
    pub session_lanes: usize,
    /// Idle eviction: a resident session that has not received a chunk
    /// for this long is evicted (its lane stats folded, its lane freed).
    pub session_idle: Duration,
    /// Chaos injection knobs (worker panics, dropped/delayed responses,
    /// socket resets). Default is fully off: the production path pays one
    /// predicted-false branch per response.
    pub chaos: SystemChaos,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            lanes_per_worker: 4,
            fill_wait: Duration::from_micros(500),
            max_in_flight: 256,
            max_frame_len: DEFAULT_MAX_FRAME_LEN,
            poll_interval: Duration::from_millis(25),
            write_timeout: Duration::from_secs(10),
            allow_remote_shutdown: false,
            session_lanes: 8,
            session_idle: Duration::from_secs(60),
            chaos: SystemChaos::default(),
        }
    }
}

/// Cap on encoded frames queued per connection awaiting the socket write.
/// Bounds server memory against a client that pipelines requests but
/// never reads responses: once full, further frames for that connection
/// are dropped (counted in `dropped_responses`) rather than buffered
/// without limit — the client wasn't reading them anyway.
const WRITER_QUEUE_CAP: usize = 256;

/// What the server tells clients about the loaded model (STATS `model`
/// block) — enough for a load generator to synthesize valid inputs.
#[derive(Debug, Clone, Copy)]
pub struct ModelInfo {
    pub input_dim: usize,
    pub timesteps: usize,
    pub classes: usize,
}

impl ModelInfo {
    fn to_json(self) -> Json {
        Json::obj(vec![
            ("input_dim", self.input_dim.into()),
            ("timesteps", self.timesteps.into()),
            ("classes", self.classes.into()),
        ])
    }
}

/// Book-keeping for an admitted request awaiting its response.
struct Pending {
    /// The owning connection's (bounded) writer channel.
    tx: SyncSender<Vec<u8>>,
    /// The client's correlation id (coordinator ids are server-internal).
    client_id: u64,
    deadline: Option<Instant>,
    deadline_ms: u32,
    accepted: Instant,
}

/// State shared by the accept loop, connection readers, and the router.
struct Shared {
    cfg: ServeConfig,
    metrics: Arc<ServeMetrics>,
    handle: crate::coordinator::SubmitHandle,
    /// Coordinator id → response destination.
    pending: Mutex<HashMap<u64, Pending>>,
    /// Admitted-but-unanswered request count (the admission gauge; distinct
    /// from the coordinator's in-flight, which drops as soon as the router
    /// consumes a result).
    net_in_flight: AtomicUsize,
    stop_accept: AtomicBool,
    stop_readers: AtomicBool,
    router_stop: AtomicBool,
    remote_shutdown: AtomicBool,
    /// Set when the router detects all workers died (see
    /// [`quiesce_after_worker_death`]): the server no longer serves and
    /// the embedding loop should shut it down.
    quiesced: AtomicBool,
    /// The coordinator's worker-side gauges (lane occupancy), sampled by
    /// the STATS snapshot.
    coord_metrics: Arc<crate::coordinator::Metrics>,
    /// The coordinator's recovery/fault counters — the STATS `recovery`
    /// and `faults` blocks.
    recovery: Arc<RecoveryStats>,
    /// The coordinator's live per-core/per-shard execution profile — the
    /// `cores`/`shards` halves of the STATS `profile` block (empty for
    /// remote backends, whose cores profile host-side).
    profile: Arc<crate::obs::ProfilePlane>,
    /// Chaos triggers (armed from [`ServeConfig::chaos`]; disarmed = the
    /// production no-op).
    chaos_drop: ChaosTrigger,
    chaos_delay: ChaosTrigger,
    chaos_reset: ChaosTrigger,
    /// Static shard topology (sharded servers only) — reported verbatim
    /// as the STATS `shards` block.
    shards: Option<Json>,
    /// Live per-link gauges of a distributed backend (remote-shard
    /// servers only) — the STATS `remote_links` block: per-cut
    /// `boundary_events` and the in-flight depth/high-water per link.
    remote_links: Option<Arc<super::remote_shard::RemoteLinkStats>>,
    model: ModelInfo,
    started: Instant,
    readers: Mutex<Vec<JoinHandle<()>>>,
    writers: Mutex<Vec<JoinHandle<()>>>,
    /// Streaming-session ingress (absent on remote-shard servers, whose
    /// membrane state lives in the shard-host processes): readers forward
    /// decoded session commands here; the pool thread executes them and
    /// replies straight onto each connection's writer channel.
    sessions: Option<SessionHandle>,
    /// Connection-id allocator: session ids are scoped per connection, so
    /// every reader gets a unique id to key the pool's session table with.
    next_conn: AtomicU64,
}

impl Shared {
    fn stats_json(&self) -> Json {
        let mut j = self.metrics.to_json(
            self.started,
            self.handle.queue_depth(),
            self.net_in_flight.load(Ordering::Relaxed),
        );
        if let Json::Obj(map) = &mut j {
            // Schema version first (satellite of the observability PR):
            // pollers hard-fail on a mismatch instead of reading nulls.
            map.insert(
                "stats_version".to_string(),
                (super::protocol::STATS_VERSION as usize).into(),
            );
            map.insert("model".to_string(), self.model.to_json());
            // The observability plane: per-stage trace-span histograms,
            // per-core/per-shard execution counters (cumulative — pollers
            // diff successive snapshots for windowed rates), and the K
            // slowest complete traces.
            let (prof_cores, prof_shards) = self.profile.to_json();
            map.insert(
                "profile".to_string(),
                Json::obj(vec![
                    ("stages", self.metrics.stages.to_json()),
                    ("cores", prof_cores),
                    ("shards", prof_shards),
                    ("slowest", self.metrics.slowest.to_json()),
                ]),
            );
            // Lane occupancy (ROADMAP follow-up): how full micro-batches
            // actually run. `mean`/`max` are bounded by `capacity` (= the
            // configured lanes-per-worker L).
            let cm = &self.coord_metrics;
            let mean = cm.mean_lane_occupancy();
            map.insert(
                "lane_occupancy".to_string(),
                Json::obj(vec![
                    (
                        "capacity",
                        (cm.lane_capacity.load(Ordering::Relaxed) as usize).into(),
                    ),
                    (
                        "dispatches",
                        (cm.dispatches.load(Ordering::Relaxed) as usize).into(),
                    ),
                    ("mean", if mean.is_nan() { Json::Null } else { Json::Num(mean) }),
                    (
                        "max",
                        (cm.max_lane_occupancy.load(Ordering::Relaxed) as usize).into(),
                    ),
                ]),
            );
            if let Some(shards) = &self.shards {
                map.insert("shards".to_string(), shards.clone());
            }
            if let Some(links) = &self.remote_links {
                map.insert("remote_links".to_string(), links.to_json());
            }
            map.insert("recovery".to_string(), self.recovery.recovery_json());
            map.insert("faults".to_string(), self.recovery.faults_json());
            // Streaming-session lifecycle counters + resident-lane gauge
            // (STATS v3; absent on remote-shard servers, like `shards`).
            if let Some(sessions) = &self.sessions {
                map.insert("sessions".to_string(), sessions.to_json());
            }
        }
        j
    }
}

/// A running TCP inference server (see module docs). Bind with
/// [`Server::start`], stop with [`Server::shutdown`].
pub struct Server {
    local_addr: SocketAddr,
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
    router: Option<JoinHandle<Vec<Menage>>>,
    /// The streaming-session pool (local backends only); shut down after
    /// the router so its chip joins the returned worker chips.
    pool: Option<SessionPool>,
}

impl Server {
    /// Bind `addr` (port 0 picks an ephemeral port — read it back via
    /// [`Self::local_addr`]) and start serving `chip` with `cfg`.
    pub fn start(chip: &Menage, addr: impl ToSocketAddrs, cfg: ServeConfig) -> Result<Self> {
        // Bind before spawning workers: a bind failure (port in use) must
        // fail fast, not after cloning the model W times.
        let listener = TcpListener::bind(addr).context("binding server socket")?;
        let coord =
            Coordinator::with_lanes_wait(chip, cfg.workers, cfg.lanes_per_worker, cfg.fill_wait);
        let model = ModelInfo {
            input_dim: chip.cores[0].in_dim(),
            timesteps: chip.timesteps,
            classes: chip.cores.last().expect("chip has cores").out_dim(),
        };
        let sessions = Some(Backend::Mono(chip.clone()));
        Self::start_inner(coord, model, None, None, sessions, listener, cfg)
    }

    /// [`Self::start`] over a multi-chip sharded pipeline: every worker
    /// clones the whole [`ShardedMenage`], and the STATS snapshot gains a
    /// per-shard `shards` block (layer ranges, dims, estimated cut
    /// traffic). Wire-level outputs stay bit-identical to a monolithic
    /// server (`tests/shard_differential.rs` + `tests/serve_roundtrip.rs`).
    pub fn start_sharded(
        chip: &ShardedMenage,
        addr: impl ToSocketAddrs,
        cfg: ServeConfig,
    ) -> Result<Self> {
        let listener = TcpListener::bind(addr).context("binding server socket")?;
        let coord = Coordinator::sharded_with_lanes_wait(
            chip,
            cfg.workers,
            cfg.lanes_per_worker,
            cfg.fill_wait,
        );
        let model = ModelInfo {
            input_dim: chip.input_dim(),
            timesteps: chip.timesteps,
            classes: chip.output_dim(),
        };
        let sessions = Some(Backend::Sharded(chip.clone()));
        Self::start_inner(coord, model, Some(chip.shards_json()), None, sessions, listener, cfg)
    }

    /// [`Self::start`] over a **distributed** pipeline of `shard-host`
    /// processes ([`super::remote_shard::RemoteShardPipeline`]): every
    /// worker clones the pipeline and drives the remote chips over TCP.
    /// The STATS snapshot gains a `shards` block built from the probed
    /// topology and a live `remote_links` block (per-cut boundary events,
    /// in-flight depth per link). Wire-level outputs stay bit-identical
    /// to a local server over the same plan (`tests/dist_identity.rs`).
    pub fn start_remote(
        pipeline: &super::remote_shard::RemoteShardPipeline,
        addr: impl ToSocketAddrs,
        cfg: ServeConfig,
    ) -> Result<Self> {
        let listener = TcpListener::bind(addr).context("binding server socket")?;
        let coord = Coordinator::remote_with_lanes_wait(
            pipeline,
            cfg.workers,
            cfg.lanes_per_worker,
            cfg.fill_wait,
        );
        let model = ModelInfo {
            input_dim: pipeline.input_dim(),
            timesteps: pipeline.timesteps(),
            classes: pipeline.output_dim(),
        };
        // No session pool: the membrane state lives in the shard-host
        // processes, which this driver cannot pin to one client. Session
        // frames are answered with ERROR Unsupported.
        Self::start_inner(
            coord,
            model,
            Some(pipeline.topology_json()),
            Some(pipeline.stats()),
            None,
            listener,
            cfg,
        )
    }

    fn start_inner(
        coord: Coordinator,
        model: ModelInfo,
        shards: Option<Json>,
        remote_links: Option<Arc<super::remote_shard::RemoteLinkStats>>,
        session_backend: Option<Backend>,
        listener: TcpListener,
        cfg: ServeConfig,
    ) -> Result<Self> {
        let local_addr = listener.local_addr()?;
        // Non-blocking accept so the loop can poll the stop flag.
        listener.set_nonblocking(true)?;

        // Arm the chaos triggers from the config (all off by default). The
        // worker-panic trigger lives on the coordinator's RecoveryStats so
        // workers can check it without touching serve-layer state.
        let recovery = coord.recovery();
        recovery.panic_trigger.arm(cfg.chaos.worker_panic_every);
        let chaos_drop = ChaosTrigger::default();
        chaos_drop.arm(cfg.chaos.drop_response_every);
        let chaos_delay = ChaosTrigger::default();
        chaos_delay.arm(cfg.chaos.delay_response_every);
        let chaos_reset = ChaosTrigger::default();
        chaos_reset.arm(cfg.chaos.reset_conn_every);

        let metrics = Arc::new(ServeMetrics::default());
        let pool = session_backend.map(|backend| {
            SessionPool::start(
                backend,
                Arc::clone(&metrics),
                cfg.session_lanes,
                cfg.session_idle,
                cfg.poll_interval,
            )
        });
        let shared = Arc::new(Shared {
            handle: coord.handle(),
            coord_metrics: Arc::clone(&coord.metrics),
            recovery,
            profile: coord.profile(),
            chaos_drop,
            chaos_delay,
            chaos_reset,
            sessions: pool.as_ref().map(|p| p.handle()),
            next_conn: AtomicU64::new(0),
            cfg,
            metrics,
            pending: Mutex::new(HashMap::new()),
            net_in_flight: AtomicUsize::new(0),
            stop_accept: AtomicBool::new(false),
            stop_readers: AtomicBool::new(false),
            router_stop: AtomicBool::new(false),
            remote_shutdown: AtomicBool::new(false),
            quiesced: AtomicBool::new(false),
            shards,
            remote_links,
            model,
            started: Instant::now(),
            readers: Mutex::new(Vec::new()),
            writers: Mutex::new(Vec::new()),
        });

        let router = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || router_loop(coord, &shared))
        };
        let accept = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || accept_loop(listener, &shared))
        };
        Ok(Self { local_addr, shared, accept: Some(accept), router: Some(router), pool })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    pub fn metrics(&self) -> Arc<ServeMetrics> {
        Arc::clone(&self.shared.metrics)
    }

    /// The coordinator's recovery/fault counters (the STATS `recovery` and
    /// `faults` blocks) — lets embedders and the chaos suite observe
    /// worker panics, respawns, and hardware fault hits directly.
    pub fn recovery(&self) -> Arc<RecoveryStats> {
        Arc::clone(&self.shared.recovery)
    }

    /// Current metrics snapshot (same JSON a STATS frame returns).
    pub fn stats_json(&self) -> Json {
        self.shared.stats_json()
    }

    /// Requests admitted but not yet answered.
    pub fn in_flight(&self) -> usize {
        self.shared.net_in_flight.load(Ordering::Relaxed)
    }

    /// True once a client sent SHUTDOWN (only with
    /// [`ServeConfig::allow_remote_shutdown`]); the embedding loop — e.g.
    /// `menage serve` — polls this and calls [`Self::shutdown`].
    pub fn remote_shutdown_requested(&self) -> bool {
        self.shared.remote_shutdown.load(Ordering::Relaxed)
    }

    /// True if the server stopped serving because all simulator workers
    /// died (see [`quiesce_after_worker_death`]). The embedding loop
    /// should call [`Self::shutdown`] — which will propagate the worker
    /// panic loudly rather than keep a dead service up.
    pub fn quiesced(&self) -> bool {
        self.shared.quiesced.load(Ordering::Relaxed)
    }

    /// Graceful shutdown: stop accepting, stop readers (joined — no new
    /// submissions can race the drain), drain every admitted request
    /// through the coordinator and route the responses, then join workers
    /// and writers. Returns the worker chips with their accumulated stats
    /// (lane-served work folded in), as [`Coordinator::shutdown`] does.
    pub fn shutdown(mut self) -> Vec<Menage> {
        self.shutdown_inner().expect("server threads panicked")
    }

    fn shutdown_inner(&mut self) -> Option<Vec<Menage>> {
        self.shared.stop_accept.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept.take() {
            h.join().ok()?;
        }
        self.shared.stop_readers.store(true, Ordering::Relaxed);
        for h in std::mem::take(&mut *lock_recover(&self.shared.readers)) {
            h.join().ok()?;
        }
        // Readers are gone: the router can drain without racing ingress.
        self.shared.router_stop.store(true, Ordering::Relaxed);
        let mut chips = self.router.take()?.join().ok()?;
        // Session pool after the router (readers can no longer submit):
        // its chip — resident lanes folded — joins the worker chips, so
        // the energy report accounts for session-served work too.
        if let Some(pool) = self.pool.take() {
            chips.extend(pool.shutdown());
        }
        // The router cleared the pending map and the pool dropped its
        // queued commands, so every writer's channel is closed and each
        // writer exits after flushing.
        for h in std::mem::take(&mut *lock_recover(&self.shared.writers)) {
            h.join().ok()?;
        }
        Some(chips)
    }
}

impl Drop for Server {
    /// Best-effort: a dropped (not shut-down) server must not leave
    /// threads spinning. Flags are raised but threads are detached; prefer
    /// [`Self::shutdown`].
    fn drop(&mut self) {
        self.shared.stop_accept.store(true, Ordering::Relaxed);
        self.shared.stop_readers.store(true, Ordering::Relaxed);
        self.shared.router_stop.store(true, Ordering::Relaxed);
    }
}

fn accept_loop(listener: TcpListener, shared: &Arc<Shared>) {
    while !shared.stop_accept.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                if let Err(e) = spawn_connection(shared, stream) {
                    eprintln!("serve: failed to set up connection: {e:#}");
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => {
                eprintln!("serve: accept error: {e}");
                std::thread::sleep(Duration::from_millis(5));
            }
        }
    }
}

fn spawn_connection(shared: &Arc<Shared>, stream: TcpStream) -> Result<()> {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(shared.cfg.poll_interval))?;
    let write_half = stream.try_clone().context("cloning stream for writer")?;
    // Bounded (WRITER_QUEUE_CAP) so a non-reading client can't buffer
    // unlimited frames; the write timeout bounds how long the writer can
    // stall on the socket itself.
    write_half.set_write_timeout(Some(shared.cfg.write_timeout))?;
    let (tx, rx) = mpsc::sync_channel::<Vec<u8>>(WRITER_QUEUE_CAP);

    ServeMetrics::bump(&shared.metrics.connections_opened);
    ServeMetrics::bump(&shared.metrics.connections_active);

    let writer = {
        let shared = Arc::clone(shared);
        std::thread::spawn(move || {
            let mut w = BufWriter::new(write_half);
            while let Ok(frame) = rx.recv() {
                // Any write failure — including a write timeout on a stalled
                // client — abandons the connection: after a partial frame the
                // stream can't be resynchronized anyway. Later sends into the
                // channel are counted as dropped_responses by the senders.
                if w.write_all(&frame).and_then(|()| w.flush()).is_err() {
                    break;
                }
                // Chaos: reset this connection's socket — emit a short write
                // (a truncated frame header) and sever, so the peer observes
                // a mid-frame connection loss and must reconnect.
                if shared.chaos_reset.fire() {
                    ServeMetrics::bump(&shared.metrics.chaos_injected);
                    let _ = w.write_all(&MAGIC.to_le_bytes()).and_then(|()| w.flush());
                    break;
                }
            }
            if let Ok(s) = w.into_inner() {
                let _ = s.shutdown(Shutdown::Both);
            }
        })
    };

    let reader = {
        let shared = Arc::clone(shared);
        let conn = shared.next_conn.fetch_add(1, Ordering::Relaxed);
        std::thread::spawn(move || {
            reader_loop(&shared, conn, stream, &tx);
            // The reader is the only session submitter for this
            // connection, so once it exits the pool can safely evict the
            // connection's resident sessions (stats folded, lanes freed).
            if let Some(sessions) = &shared.sessions {
                sessions.send(SessionCmd::ConnGone { conn });
            }
            let m = &shared.metrics;
            m.connections_active.fetch_sub(1, Ordering::Relaxed);
        })
    };

    // Reap handles of connections that already finished while storing the
    // new ones, so a long-lived server's bookkeeping stays proportional to
    // *live* connections, not to every connection ever accepted. (Dropping
    // a finished handle is a no-op join-wise; unfinished ones are kept for
    // the shutdown joins.)
    let mut readers = lock_recover(&shared.readers);
    readers.retain(|h| !h.is_finished());
    readers.push(reader);
    drop(readers);
    let mut writers = lock_recover(&shared.writers);
    writers.retain(|h| !h.is_finished());
    writers.push(writer);
    Ok(())
}

/// Queue a frame on a connection's bounded writer channel. Non-blocking:
/// if the client's queue is full (it isn't reading) or its writer is gone,
/// the frame is dropped and counted — the router must never block on one
/// connection's egress.
pub(crate) fn queue_frame(m: &ServeMetrics, tx: &SyncSender<Vec<u8>>, frame: Vec<u8>) {
    match tx.try_send(frame) {
        Ok(()) => {}
        Err(TrySendError::Full(_)) | Err(TrySendError::Disconnected(_)) => {
            ServeMetrics::bump(&m.dropped_responses);
        }
    }
}

/// Send an ERROR frame (best-effort — the writer may already be gone).
fn send_error(
    m: &ServeMetrics,
    tx: &SyncSender<Vec<u8>>,
    id: u64,
    code: ErrorCode,
    msg: impl Into<String>,
) {
    let ef = ErrorFrame::new(id, code, msg);
    queue_frame(m, tx, encode_frame(FrameKind::Error, &ef.encode()));
}

fn reader_loop(shared: &Arc<Shared>, conn: u64, mut stream: TcpStream, tx: &SyncSender<Vec<u8>>) {
    let m = &shared.metrics;
    let mut fr = FrameReader::new(shared.cfg.max_frame_len);
    loop {
        if shared.stop_readers.load(Ordering::Relaxed) {
            return;
        }
        let frame = match fr.read_frame(&mut stream) {
            Ok(Some(f)) => f,
            Ok(None) => return, // client closed cleanly
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue
            }
            Err(e) => {
                // Framing violation or truncated stream: the connection can
                // no longer be trusted to be frame-aligned — answer and
                // close it. The server itself keeps serving.
                ServeMetrics::bump(&m.protocol_errors);
                send_error(m, tx, NO_ID, ErrorCode::Malformed, e.to_string());
                return;
            }
        };
        match FrameKind::from_u8(frame.kind) {
            Some(FrameKind::InferRequest) => handle_request(shared, tx, &frame.payload),
            Some(FrameKind::Ping) => {
                queue_frame(m, tx, encode_frame(FrameKind::Pong, &[]));
            }
            Some(FrameKind::Stats) => {
                let payload = encode_stats_reply(&shared.stats_json());
                queue_frame(m, tx, encode_frame(FrameKind::StatsReply, &payload));
            }
            Some(FrameKind::Shutdown) => {
                if shared.cfg.allow_remote_shutdown {
                    shared.remote_shutdown.store(true, Ordering::Relaxed);
                    queue_frame(m, tx, encode_frame(FrameKind::Pong, &[]));
                } else {
                    send_error(
                        m,
                        tx,
                        NO_ID,
                        ErrorCode::Unsupported,
                        "remote shutdown is disabled on this server",
                    );
                }
            }
            Some(FrameKind::SessionOpen) => {
                handle_session_control(shared, conn, tx, &frame.payload, true)
            }
            Some(FrameKind::SessionClose) => {
                handle_session_control(shared, conn, tx, &frame.payload, false)
            }
            Some(FrameKind::SessionChunk) => handle_session_chunk(shared, conn, tx, &frame.payload),
            // Well-framed but not something a client may send: answer and
            // keep the connection (frame alignment is intact).
            Some(other) => {
                send_error(
                    m,
                    tx,
                    NO_ID,
                    ErrorCode::Unsupported,
                    format!("unexpected frame kind {other:?} from client"),
                );
            }
            None => {
                send_error(
                    m,
                    tx,
                    NO_ID,
                    ErrorCode::Unsupported,
                    format!("unknown frame kind {}", frame.kind),
                );
            }
        }
    }
}

/// Decode and forward a SESSION_OPEN (`opening`) or SESSION_CLOSE to the
/// session pool. Servers without a pool (remote backends) answer
/// `ERROR Unsupported`; a payload that fails to decode is `BadRequest`
/// (the frame was well-delimited, so the connection stays usable).
fn handle_session_control(
    shared: &Arc<Shared>,
    conn: u64,
    tx: &SyncSender<Vec<u8>>,
    payload: &[u8],
    opening: bool,
) {
    let m = &shared.metrics;
    let Some(sessions) = &shared.sessions else {
        send_error(
            m,
            tx,
            NO_ID,
            ErrorCode::Unsupported,
            "this server does not host streaming sessions (remote backend)",
        );
        return;
    };
    match SessionIdFrame::decode(payload) {
        Ok(f) => sessions.send(if opening {
            SessionCmd::Open { conn, sid: f.sid, tx: tx.clone() }
        } else {
            SessionCmd::Close { conn, sid: f.sid, tx: tx.clone() }
        }),
        Err(e) => {
            ServeMetrics::bump(&m.rejected_bad_request);
            send_error(m, tx, NO_ID, ErrorCode::BadRequest, format!("{e:#}"));
        }
    }
}

fn handle_session_chunk(shared: &Arc<Shared>, conn: u64, tx: &SyncSender<Vec<u8>>, payload: &[u8]) {
    let m = &shared.metrics;
    let Some(sessions) = &shared.sessions else {
        send_error(
            m,
            tx,
            NO_ID,
            ErrorCode::Unsupported,
            "this server does not host streaming sessions (remote backend)",
        );
        return;
    };
    match SessionChunkFrame::decode(payload) {
        Ok(f) => sessions.send(SessionCmd::Chunk {
            conn,
            sid: f.sid,
            seq: f.seq,
            chunk: f.chunk,
            tx: tx.clone(),
        }),
        Err(e) => {
            ServeMetrics::bump(&m.rejected_bad_request);
            send_error(m, tx, NO_ID, ErrorCode::BadRequest, format!("{e:#}"));
        }
    }
}

fn handle_request(shared: &Arc<Shared>, tx: &SyncSender<Vec<u8>>, payload: &[u8]) {
    let m = &shared.metrics;
    // Trace-span anchor: the admit stage covers payload decode, width
    // check, admission control, and pending registration — everything on
    // the reader thread before the request becomes runnable.
    let admit_start = Instant::now();
    let req = match InferRequest::decode(payload) {
        Ok(r) => r,
        Err(e) => {
            // The frame was well-delimited, so the stream stays usable;
            // only this request is rejected.
            ServeMetrics::bump(&m.rejected_bad_request);
            send_error(m, tx, NO_ID, ErrorCode::BadRequest, format!("{e:#}"));
            return;
        }
    };
    if req.train.num_neurons != shared.model.input_dim {
        ServeMetrics::bump(&m.rejected_bad_request);
        send_error(
            m,
            tx,
            req.id,
            ErrorCode::BadRequest,
            format!(
                "input has {} neurons, model expects {}",
                req.train.num_neurons, shared.model.input_dim
            ),
        );
        return;
    }
    // Admission control: bounded in-flight with an explicit reject.
    let cur = shared.net_in_flight.fetch_add(1, Ordering::Relaxed);
    if cur >= shared.cfg.max_in_flight {
        shared.net_in_flight.fetch_sub(1, Ordering::Relaxed);
        ServeMetrics::bump(&m.rejected_overload);
        send_error(
            m,
            tx,
            req.id,
            ErrorCode::Overload,
            format!("{cur} requests in flight (cap {})", shared.cfg.max_in_flight),
        );
        return;
    }
    ServeMetrics::bump(&m.accepted);
    m.events_in.fetch_add(req.train.total_spikes() as u64, Ordering::Relaxed);
    let now = Instant::now();
    let deadline = (req.deadline_ms > 0).then(|| now + Duration::from_millis(req.deadline_ms as u64));
    // Register the pending entry BEFORE the request becomes runnable, so
    // the router can never receive a response for an unregistered id.
    let cid = shared.handle.reserve_id();
    lock_recover(&shared.pending).insert(
        cid,
        Pending {
            tx: tx.clone(),
            client_id: req.id,
            deadline,
            deadline_ms: req.deadline_ms,
            accepted: now,
        },
    );
    m.stages.admit.record_micros(admit_start.elapsed().as_micros() as u64);
    shared.handle.submit_reserved(cid, req.train, req.label.map(|l| l as usize));
}

/// The response router: owns the coordinator, consumes its results
/// channel, and forwards each response to the connection that submitted
/// the request. On shutdown it drains everything still in flight (salvage
/// path included) before handing the worker chips back.
fn router_loop(mut coord: Coordinator, shared: &Arc<Shared>) -> Vec<Menage> {
    while !shared.router_stop.load(Ordering::Relaxed) {
        match coord.recv_timeout(shared.cfg.poll_interval) {
            None => continue,
            Some(Ok(resp)) => route_response(shared, resp),
            Some(Err(e)) => {
                if !route_worker_error(shared, &e) {
                    // Terminal: the results channel is dead (all workers
                    // gone), so nothing pending can ever be answered.
                    // Quiesce loudly instead of wedging: stop ingesting,
                    // fail every pending request, and fall through to the
                    // shutdown path.
                    quiesce_after_worker_death(shared, &e);
                    break;
                }
            }
        }
    }
    // Shutdown drain: readers are already joined, so no submission can
    // race this. `drain` consumes every in-flight response; if one of the
    // final batch failed, the completed ones are recovered via the salvage
    // path rather than lost.
    match coord.drain() {
        Ok(responses) => {
            for r in responses {
                route_response(shared, r);
            }
        }
        Err(e) => {
            for r in coord.take_salvaged_responses() {
                route_response(shared, r);
            }
            if !route_worker_error(shared, &e) {
                quiesce_after_worker_death(shared, &e);
            }
        }
    }
    // Drop any leftover pending entries (e.g. additional failed requests
    // whose errors `drain` folded into one): closes their writer channels
    // so connection writers can exit; those clients see EOF.
    lock_recover(&shared.pending).clear();
    coord.shutdown()
}

fn route_response(shared: &Arc<Shared>, resp: Response) {
    let m = &shared.metrics;
    let Some(p) = lock_recover(&shared.pending).remove(&resp.id) else {
        ServeMetrics::bump(&m.dropped_responses);
        return;
    };
    shared.net_in_flight.fetch_sub(1, Ordering::Relaxed);
    let latency = p.accepted.elapsed();
    let micros = latency.as_micros() as u64;
    m.latency.record_micros(micros);
    ServeMetrics::bump(&m.completed);
    m.total_cycles.fetch_add(resp.cycles, Ordering::Relaxed);
    // Fold the worker-stamped trace spans into the per-stage histograms
    // and offer the complete trace to the slowest-trace ring (bounded;
    // lock-free reject once the tail floor is established).
    let queue_us = resp.queue_wait.as_micros() as u64;
    let dispatch_us = resp.dispatch_wait.as_micros() as u64;
    let step_us = resp.sim_latency.as_micros() as u64;
    let egress_us = resp.done.elapsed().as_micros() as u64;
    m.stages.queue.record_micros(queue_us);
    m.stages.dispatch.record_micros(dispatch_us);
    m.stages.step.record_micros(step_us);
    m.stages.egress.record_micros(egress_us);
    m.slowest.offer(crate::obs::TraceRecord {
        id: resp.id,
        total_us: micros,
        queue_us,
        dispatch_us,
        step_us,
        egress_us,
    });

    let frame = if p.deadline.is_some_and(|d| Instant::now() > d) {
        ServeMetrics::bump(&m.deadline_expired);
        let ef = ErrorFrame::new(
            p.client_id,
            ErrorCode::DeadlineExceeded,
            format!(
                "completed in {:.1}ms, after the {}ms deadline",
                latency.as_secs_f64() * 1e3,
                p.deadline_ms
            ),
        );
        encode_frame(FrameKind::Error, &ef.encode())
    } else {
        let reply = InferResponse {
            id: p.client_id,
            predicted: resp.predicted as u32,
            cycles: resp.cycles,
            server_micros: micros,
            output: resp.output,
        };
        encode_frame(FrameKind::InferResponse, &reply.encode())
    };
    // Chaos: drop / delay this response (disarmed in production — one
    // predicted-false branch each). A dropped response still cleared its
    // pending entry and in-flight slot above: the *server* stays coherent,
    // only the client is left waiting, which is exactly the failure mode
    // loadgen's transient/terminal accounting exists to classify.
    if shared.chaos_drop.fire() {
        ServeMetrics::bump(&m.dropped_responses);
        ServeMetrics::bump(&m.chaos_injected);
        return;
    }
    if shared.chaos_delay.fire() {
        ServeMetrics::bump(&m.chaos_injected);
        std::thread::sleep(Duration::from_millis(shared.cfg.chaos.delay_ms));
    }
    queue_frame(m, &p.tx, frame);
}

/// Route one worker error to its connection. Returns `false` for the one
/// error that cannot be attributed to a request — the terminal
/// "all workers terminated" — which the router must treat as fatal.
fn route_worker_error(shared: &Arc<Shared>, e: &anyhow::Error) -> bool {
    let m = &shared.metrics;
    ServeMetrics::bump(&m.worker_errors);
    // Worker errors carry a `request <id>:` prefix; attribute when we can.
    let Some(cid) = request_id_of_error(e) else {
        return false;
    };
    if let Some(p) = lock_recover(&shared.pending).remove(&cid) {
        shared.net_in_flight.fetch_sub(1, Ordering::Relaxed);
        send_error(m, &p.tx, p.client_id, ErrorCode::Internal, format!("{e:#}"));
    }
    true
}

/// All simulator workers are gone (e.g. a panic in the engine): no pending
/// request can ever complete. Stop accepting and reading, answer every
/// pending request with an Internal error, and let the server wind down —
/// a loud, observable failure instead of a silently wedged service that
/// keeps admitting work into a queue nobody consumes.
fn quiesce_after_worker_death(shared: &Arc<Shared>, e: &anyhow::Error) {
    eprintln!("serve: fatal: {e:#}; quiescing");
    shared.stop_accept.store(true, Ordering::Relaxed);
    shared.stop_readers.store(true, Ordering::Relaxed);
    shared.quiesced.store(true, Ordering::Relaxed);
    let m = &shared.metrics;
    let pending: Vec<Pending> =
        lock_recover(&shared.pending).drain().map(|(_, p)| p).collect();
    for p in pending {
        shared.net_in_flight.fetch_sub(1, Ordering::Relaxed);
        send_error(
            m,
            &p.tx,
            p.client_id,
            ErrorCode::Internal,
            format!("server lost its workers: {e:#}"),
        );
    }
}
