//! The length-prefixed binary wire protocol.
//!
//! Every frame is an 8-byte header followed by a payload:
//!
//! ```text
//! offset  size  field
//! 0       2     magic   0x454D (bytes "ME" on the wire; little-endian u16)
//! 2       1     version 0x01
//! 3       1     kind    (FrameKind)
//! 4       4     len     payload byte count, little-endian u32
//! 8       len   payload
//! ```
//!
//! Frame kinds and payloads (all integers little-endian; spike trains use
//! [`SpikeTrain::write_wire`]'s encoding):
//!
//! | kind | name           | dir  | payload |
//! |------|----------------|------|---------|
//! | 1    | INFER_REQUEST  | c→s  | `u64 id, u32 deadline_ms (0 = none), u32 label (u32::MAX = none), train` |
//! | 2    | INFER_RESPONSE | s→c  | `u64 id, u32 predicted, u64 cycles, u64 server_micros, output train` |
//! | 3    | ERROR          | s→c  | `u64 id (u64::MAX = none), u8 code, str message` |
//! | 4    | PING           | c→s  | empty |
//! | 5    | PONG           | s→c  | empty |
//! | 6    | STATS          | c→s  | empty |
//! | 7    | STATS_REPLY    | s→c  | `str json` (the metrics registry snapshot; schema pinned by [`STATS_VERSION`]) |
//! | 8    | SHUTDOWN       | c→s  | empty (honored only with `allow_remote_shutdown`; acked with PONG) |
//! | 9    | SHARD_STEP     | c→s  | `u64 seq, u32 step, frontier train (exactly 1 timestep)` |
//! | 10   | SHARD_ACK      | s→c  | `u64 seq, u32 step, u64 step_cycles, frontier train (exactly 1 timestep)` |
//! | 11   | SESSION_OPEN   | c→s, s→c | `u64 sid` (server echoes the frame back as the open-ack) |
//! | 12   | SESSION_CHUNK  | c→s  | `u64 sid, u64 seq, chunk train` |
//! | 13   | SESSION_OUT    | s→c  | `u64 sid, u64 seq, u64 chunk_cycles, u32 predicted, output chunk train` |
//! | 14   | SESSION_CLOSE  | c→s, s→c | `u64 sid` (server echoes the frame back as the close-ack) |
//!
//! SESSION_* frames implement **stateful streaming sessions**: a
//! SESSION_OPEN pins a server-side lane whose membrane state *persists*
//! across chunks (admission failures answer `ERROR Overload` with the
//! sid as the error id). Each SESSION_CHUNK carries the stream's next
//! event chunk under a strict per-session sequence number starting at 0
//! — a gap, replay, or reorder evicts the session with `ERROR
//! BadRequest` (the connection survives). Every chunk is answered by a
//! SESSION_OUT echoing `sid`/`seq` with the chunk's classifier-layer
//! output train, its modeled cycles, and the prediction over the
//! session's **cumulative** per-class spike counts. SESSION_CLOSE (or
//! connection teardown, or idle timeout) evicts the session and folds
//! its lane statistics into the chip totals. `sid` is scoped to its
//! connection.
//!
//! SHARD_STEP/SHARD_ACK carry one pipeline timestep between a distributed
//! driver and a `menage shard-host` process (see `serve::shard_host` /
//! `serve::remote_shard`): `seq` is a per-connection link sequence number
//! starting at 0 (gaps or reorders are protocol errors — a dropped
//! frontier must never silently desynchronize the pipeline), `step` is the
//! timestep index within the current input (step 0 begins a new input and
//! resets the shard's membrane state), and the train holds exactly that
//! step's boundary spike frontier.
//!
//! Framing errors (bad magic/version, oversized length, truncated stream)
//! are protocol-fatal for the connection: the server answers with an
//! `ERROR Malformed` frame where possible and closes that socket — the
//! byte stream can no longer be trusted to be frame-aligned. Errors
//! *inside* a well-delimited payload (bad train, unknown kind) are
//! per-request: the server answers with an ERROR frame and keeps the
//! connection alive. `tests/serve_roundtrip.rs` pins both behaviours.

use std::io::{self, Read, Write};

use anyhow::{bail, Result};

use crate::snn::SpikeTrain;
use crate::util::json::Json;

use super::codec::{put_str, put_u32, put_u64, put_u8, Cursor};

/// `"ME"` as a little-endian u16.
pub const MAGIC: u16 = 0x454D;
/// Wire protocol version; bumped on incompatible layout changes.
pub const VERSION: u8 = 1;
/// Version of the STATS_REPLY JSON snapshot, carried in the snapshot
/// itself as `"stats_version"` so pollers (`menage top`, `loadgen`) can
/// fail loudly on shape drift instead of silently reading nulls. History:
/// v1 = the pre-profile shape (no version field — absent means v1);
/// v2 = adds `stats_version` and the `profile` block (per-stage trace
/// histograms, per-core/per-shard execution counters, slowest traces),
/// and extends `remote_links` with ack/wire/wait attribution;
/// v3 = adds the `sessions` block (streaming-session open/close/evict/
/// reject counters and the resident-lane gauge).
pub const STATS_VERSION: u64 = 3;
/// Header bytes before the payload.
pub const HEADER_LEN: usize = 8;
/// Default cap on a single frame's payload (guards allocations; a server
/// can lower it via `ServeConfig::max_frame_len`).
pub const DEFAULT_MAX_FRAME_LEN: u32 = 16 << 20;
/// "No id" sentinel in ERROR frames (connection-level failures).
pub const NO_ID: u64 = u64::MAX;
/// "No label" sentinel in INFER_REQUEST frames.
pub const NO_LABEL: u32 = u32::MAX;

/// Frame discriminator (header byte 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum FrameKind {
    InferRequest = 1,
    InferResponse = 2,
    Error = 3,
    Ping = 4,
    Pong = 5,
    Stats = 6,
    StatsReply = 7,
    Shutdown = 8,
    ShardStep = 9,
    ShardAck = 10,
    SessionOpen = 11,
    SessionChunk = 12,
    SessionOut = 13,
    SessionClose = 14,
}

impl FrameKind {
    pub fn from_u8(v: u8) -> Option<Self> {
        Some(match v {
            1 => Self::InferRequest,
            2 => Self::InferResponse,
            3 => Self::Error,
            4 => Self::Ping,
            5 => Self::Pong,
            6 => Self::Stats,
            7 => Self::StatsReply,
            8 => Self::Shutdown,
            9 => Self::ShardStep,
            10 => Self::ShardAck,
            11 => Self::SessionOpen,
            12 => Self::SessionChunk,
            13 => Self::SessionOut,
            14 => Self::SessionClose,
            _ => return None,
        })
    }
}

/// Error codes carried by ERROR frames.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum ErrorCode {
    /// Frame-layer violation; the server closes the connection after this.
    Malformed = 1,
    /// Well-framed but unknown/unexpected frame kind.
    Unsupported = 2,
    /// Request decoded but is invalid for this model (e.g. wrong width).
    BadRequest = 3,
    /// Admission control: the in-flight cap is reached; retry later.
    Overload = 4,
    /// The request completed after its deadline; the result was discarded.
    DeadlineExceeded = 5,
    /// Simulator-side failure.
    Internal = 6,
    /// The server is draining and no longer accepts work.
    ShuttingDown = 7,
}

impl ErrorCode {
    pub fn from_u8(v: u8) -> Option<Self> {
        Some(match v {
            1 => Self::Malformed,
            2 => Self::Unsupported,
            3 => Self::BadRequest,
            4 => Self::Overload,
            5 => Self::DeadlineExceeded,
            6 => Self::Internal,
            7 => Self::ShuttingDown,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Malformed => "malformed",
            Self::Unsupported => "unsupported",
            Self::BadRequest => "bad_request",
            Self::Overload => "overload",
            Self::DeadlineExceeded => "deadline_exceeded",
            Self::Internal => "internal",
            Self::ShuttingDown => "shutting_down",
        }
    }
}

/// A received frame: raw kind byte (so unknown kinds survive to the
/// handler, which answers `ERROR Unsupported`) plus the payload.
#[derive(Debug, Clone)]
pub struct Frame {
    pub kind: u8,
    pub payload: Vec<u8>,
}

/// Serialize one frame to `w` (header + payload, then flush).
pub fn write_frame(w: &mut impl Write, kind: FrameKind, payload: &[u8]) -> io::Result<()> {
    let mut header = [0u8; HEADER_LEN];
    header[0..2].copy_from_slice(&MAGIC.to_le_bytes());
    header[2] = VERSION;
    header[3] = kind as u8;
    header[4..8].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    w.write_all(&header)?;
    w.write_all(payload)?;
    w.flush()
}

/// Encode a frame into a byte vector (what the server's per-connection
/// writer channel carries).
pub fn encode_frame(kind: FrameKind, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    let _ = write_frame(&mut out, kind, payload);
    out
}

/// Incremental frame parser over a byte stream.
///
/// Robust to read timeouts: a socket with `set_read_timeout` can return
/// `WouldBlock`/`TimedOut` *between* `read` calls at any point; the
/// partial bytes already buffered are kept, and the next
/// [`Self::read_frame`] call resumes exactly where it left off (a naive
/// `read_exact` would lose frame alignment on timeout). This is what lets
/// server readers poll a stop flag while blocked mid-frame.
pub struct FrameReader {
    buf: Vec<u8>,
    max_frame_len: u32,
}

impl FrameReader {
    pub fn new(max_frame_len: u32) -> Self {
        Self { buf: Vec::new(), max_frame_len }
    }

    /// Read until one full frame is buffered and return it.
    ///
    /// * `Ok(Some(frame))` — a frame (possibly of unknown kind).
    /// * `Ok(None)` — clean EOF at a frame boundary (peer closed).
    /// * `Err(WouldBlock | TimedOut)` — read timeout; buffered partial
    ///   data is preserved, call again.
    /// * `Err(InvalidData)` — framing violation (bad magic/version,
    ///   oversized payload) or EOF mid-frame; the stream is unsyncable.
    pub fn read_frame(&mut self, r: &mut impl Read) -> io::Result<Option<Frame>> {
        let mut chunk = [0u8; 4096];
        loop {
            if self.buf.len() >= HEADER_LEN {
                let magic = u16::from_le_bytes([self.buf[0], self.buf[1]]);
                if magic != MAGIC {
                    return Err(invalid(format!("bad frame magic {magic:#06x}")));
                }
                if self.buf[2] != VERSION {
                    return Err(invalid(format!("unsupported protocol version {}", self.buf[2])));
                }
                let len = u32::from_le_bytes(self.buf[4..8].try_into().unwrap());
                if len > self.max_frame_len {
                    return Err(invalid(format!(
                        "frame payload of {len} bytes exceeds cap {}",
                        self.max_frame_len
                    )));
                }
                let total = HEADER_LEN + len as usize;
                if self.buf.len() >= total {
                    let kind = self.buf[3];
                    let payload = self.buf[HEADER_LEN..total].to_vec();
                    self.buf.drain(..total);
                    return Ok(Some(Frame { kind, payload }));
                }
            }
            match r.read(&mut chunk) {
                Ok(0) => {
                    return if self.buf.is_empty() {
                        Ok(None)
                    } else {
                        Err(invalid(format!(
                            "connection closed mid-frame ({} bytes buffered)",
                            self.buf.len()
                        )))
                    };
                }
                Ok(n) => self.buf.extend_from_slice(&chunk[..n]),
                Err(e) => return Err(e),
            }
        }
    }
}

fn invalid(msg: String) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg)
}

// ---------------------------------------------------------------------------
// Typed messages.

/// INFER_REQUEST payload.
#[derive(Debug, Clone)]
pub struct InferRequest {
    /// Client-chosen correlation id, echoed in the response.
    pub id: u64,
    /// Relative deadline in milliseconds from server receipt; 0 = none. A
    /// result completing after its deadline is replaced by an
    /// `ERROR DeadlineExceeded` frame.
    pub deadline_ms: u32,
    /// Optional ground-truth label for server-side accuracy accounting.
    pub label: Option<u32>,
    pub train: SpikeTrain,
}

impl InferRequest {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(16 + self.train.wire_len());
        put_u64(&mut out, self.id);
        put_u32(&mut out, self.deadline_ms);
        put_u32(&mut out, self.label.unwrap_or(NO_LABEL));
        self.train.write_wire(&mut out);
        out
    }

    pub fn decode(payload: &[u8]) -> Result<Self> {
        let mut c = Cursor::new(payload);
        let id = c.u64("id")?;
        let deadline_ms = c.u32("deadline_ms")?;
        let label = match c.u32("label")? {
            NO_LABEL => None,
            l => Some(l),
        };
        let train = c.train("train")?;
        c.finish("INFER_REQUEST")?;
        Ok(Self { id, deadline_ms, label, train })
    }
}

/// INFER_RESPONSE payload.
#[derive(Debug, Clone)]
pub struct InferResponse {
    /// Echo of the request's correlation id.
    pub id: u64,
    pub predicted: u32,
    /// Modeled on-accelerator cycles (bit-identical to in-process runs).
    pub cycles: u64,
    /// Server-observed latency (accept → response routed), microseconds.
    pub server_micros: u64,
    /// The classifier output spike train — lets the client verify
    /// bit-identical execution, not just the argmax.
    pub output: SpikeTrain,
}

impl InferResponse {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(28 + self.output.wire_len());
        put_u64(&mut out, self.id);
        put_u32(&mut out, self.predicted);
        put_u64(&mut out, self.cycles);
        put_u64(&mut out, self.server_micros);
        self.output.write_wire(&mut out);
        out
    }

    pub fn decode(payload: &[u8]) -> Result<Self> {
        let mut c = Cursor::new(payload);
        let id = c.u64("id")?;
        let predicted = c.u32("predicted")?;
        let cycles = c.u64("cycles")?;
        let server_micros = c.u64("server_micros")?;
        let output = c.train("output")?;
        c.finish("INFER_RESPONSE")?;
        Ok(Self { id, predicted, cycles, server_micros, output })
    }
}

/// ERROR payload.
#[derive(Debug, Clone)]
pub struct ErrorFrame {
    /// Request id the error refers to, or [`NO_ID`] for connection-level
    /// failures.
    pub id: u64,
    pub code: ErrorCode,
    pub message: String,
}

impl ErrorFrame {
    pub fn new(id: u64, code: ErrorCode, message: impl Into<String>) -> Self {
        Self { id, code, message: message.into() }
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(13 + self.message.len());
        put_u64(&mut out, self.id);
        put_u8(&mut out, self.code as u8);
        put_str(&mut out, &self.message);
        out
    }

    pub fn decode(payload: &[u8]) -> Result<Self> {
        let mut c = Cursor::new(payload);
        let id = c.u64("id")?;
        let code_raw = c.u8("code")?;
        let Some(code) = ErrorCode::from_u8(code_raw) else {
            bail!("unknown error code {code_raw}");
        };
        let message = c.str("message")?.to_string();
        c.finish("ERROR")?;
        Ok(Self { id, code, message })
    }
}

/// SHARD_STEP payload: one pipeline timestep entering a shard-host.
#[derive(Debug, Clone)]
pub struct ShardStepFrame {
    /// Per-connection link sequence number, starting at 0 and
    /// incrementing by 1 per SHARD_STEP. The host verifies it exactly, so
    /// a dropped, duplicated, or reordered frontier surfaces as a typed
    /// protocol error instead of silently desynchronized membrane state.
    pub seq: u64,
    /// Timestep index within the current input. Step 0 begins a new input:
    /// the host resets its shard's membranes before applying the frontier.
    /// Any other value must be exactly `previous step + 1`.
    pub step: u32,
    /// The boundary spike frontier for exactly this step — a 1-timestep
    /// train whose width is the shard's input dimension.
    pub frontier: SpikeTrain,
}

impl ShardStepFrame {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(12 + self.frontier.wire_len());
        put_u64(&mut out, self.seq);
        put_u32(&mut out, self.step);
        self.frontier.write_wire(&mut out);
        out
    }

    pub fn decode(payload: &[u8]) -> Result<Self> {
        let mut c = Cursor::new(payload);
        let seq = c.u64("seq")?;
        let step = c.u32("step")?;
        let frontier = c.train("frontier")?;
        c.finish("SHARD_STEP")?;
        if frontier.timesteps() != 1 {
            bail!("SHARD_STEP frontier must carry exactly 1 timestep, got {}", frontier.timesteps());
        }
        Ok(Self { seq, step, frontier })
    }
}

/// SHARD_ACK payload: a shard-host's result for one pipeline timestep.
#[derive(Debug, Clone)]
pub struct ShardAckFrame {
    /// Echo of the SHARD_STEP's sequence number.
    pub seq: u64,
    /// Echo of the SHARD_STEP's step index.
    pub step: u32,
    /// Max per-core cycle delta across this shard for the step — the
    /// driver folds these with a per-step max across shards to reassemble
    /// the monolithic synchronous-clock cycle count bit-identically.
    pub step_cycles: u64,
    /// The shard's output frontier for this step (1-timestep train of the
    /// shard's output dimension) — the next link's SHARD_STEP payload, or
    /// the classifier output at the last shard.
    pub frontier: SpikeTrain,
}

impl ShardAckFrame {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(20 + self.frontier.wire_len());
        put_u64(&mut out, self.seq);
        put_u32(&mut out, self.step);
        put_u64(&mut out, self.step_cycles);
        self.frontier.write_wire(&mut out);
        out
    }

    pub fn decode(payload: &[u8]) -> Result<Self> {
        let mut c = Cursor::new(payload);
        let seq = c.u64("seq")?;
        let step = c.u32("step")?;
        let step_cycles = c.u64("step_cycles")?;
        let frontier = c.train("frontier")?;
        c.finish("SHARD_ACK")?;
        if frontier.timesteps() != 1 {
            bail!("SHARD_ACK frontier must carry exactly 1 timestep, got {}", frontier.timesteps());
        }
        Ok(Self { seq, step, step_cycles, frontier })
    }
}

/// SESSION_OPEN / SESSION_CLOSE payload: just the client-chosen session
/// id (scoped to the connection). The server echoes the same frame back
/// as the open-/close-ack.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SessionIdFrame {
    pub sid: u64,
}

impl SessionIdFrame {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(8);
        put_u64(&mut out, self.sid);
        out
    }

    pub fn decode(payload: &[u8]) -> Result<Self> {
        let mut c = Cursor::new(payload);
        let sid = c.u64("sid")?;
        c.finish("SESSION_OPEN/CLOSE")?;
        Ok(Self { sid })
    }
}

/// SESSION_CHUNK payload: the next event chunk of one streaming session.
#[derive(Debug, Clone)]
pub struct SessionChunkFrame {
    /// Session id (from the SESSION_OPEN), scoped to the connection.
    pub sid: u64,
    /// Strict per-session chunk sequence number, starting at 0 and
    /// incrementing by 1 — any gap, replay, or reorder evicts the session
    /// (membrane state would silently desynchronize otherwise).
    pub seq: u64,
    /// This chunk's events: a train of the model's input width whose
    /// timesteps extend the session's stream (may be any length ≥ 0).
    pub chunk: SpikeTrain,
}

impl SessionChunkFrame {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(16 + self.chunk.wire_len());
        put_u64(&mut out, self.sid);
        put_u64(&mut out, self.seq);
        self.chunk.write_wire(&mut out);
        out
    }

    pub fn decode(payload: &[u8]) -> Result<Self> {
        let mut c = Cursor::new(payload);
        let sid = c.u64("sid")?;
        let seq = c.u64("seq")?;
        let chunk = c.train("chunk")?;
        c.finish("SESSION_CHUNK")?;
        Ok(Self { sid, seq, chunk })
    }
}

/// SESSION_OUT payload: the incremental result for one session chunk.
#[derive(Debug, Clone)]
pub struct SessionOutFrame {
    /// Echo of the chunk's session id.
    pub sid: u64,
    /// Echo of the chunk's sequence number.
    pub seq: u64,
    /// Modeled on-accelerator cycles for exactly this chunk; summing them
    /// over a session reproduces the one-shot run's total bit-identically.
    pub chunk_cycles: u64,
    /// Prediction over the session's **cumulative** classifier spike
    /// counts (all chunks so far) — ties break to the lower class index,
    /// matching `SpikeTrain::argmax_class`.
    pub predicted: u32,
    /// The classifier layer's output train for exactly this chunk;
    /// concatenating them reproduces the one-shot output train.
    pub output: SpikeTrain,
}

impl SessionOutFrame {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(28 + self.output.wire_len());
        put_u64(&mut out, self.sid);
        put_u64(&mut out, self.seq);
        put_u64(&mut out, self.chunk_cycles);
        put_u32(&mut out, self.predicted);
        self.output.write_wire(&mut out);
        out
    }

    pub fn decode(payload: &[u8]) -> Result<Self> {
        let mut c = Cursor::new(payload);
        let sid = c.u64("sid")?;
        let seq = c.u64("seq")?;
        let chunk_cycles = c.u64("chunk_cycles")?;
        let predicted = c.u32("predicted")?;
        let output = c.train("output")?;
        c.finish("SESSION_OUT")?;
        Ok(Self { sid, seq, chunk_cycles, predicted, output })
    }
}

/// Encode a STATS_REPLY payload from the metrics snapshot.
pub fn encode_stats_reply(stats: &Json) -> Vec<u8> {
    let mut out = Vec::new();
    put_str(&mut out, &stats.to_string());
    out
}

/// Decode a STATS_REPLY payload back into JSON.
pub fn decode_stats_reply(payload: &[u8]) -> Result<Json> {
    let mut c = Cursor::new(payload);
    let s = c.str("stats json")?;
    let j = Json::parse(s)?;
    c.finish("STATS_REPLY")?;
    Ok(j)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn train() -> SpikeTrain {
        let mut rng = Rng::new(21);
        SpikeTrain::bernoulli(30, 6, 0.3, &mut rng)
    }

    #[test]
    fn frame_roundtrip_through_reader() {
        let req = InferRequest { id: 5, deadline_ms: 250, label: Some(3), train: train() };
        let mut wire = Vec::new();
        write_frame(&mut wire, FrameKind::InferRequest, &req.encode()).unwrap();
        write_frame(&mut wire, FrameKind::Ping, &[]).unwrap();
        let mut fr = FrameReader::new(DEFAULT_MAX_FRAME_LEN);
        let mut r = io::Cursor::new(wire);
        let f1 = fr.read_frame(&mut r).unwrap().unwrap();
        assert_eq!(FrameKind::from_u8(f1.kind), Some(FrameKind::InferRequest));
        let back = InferRequest::decode(&f1.payload).unwrap();
        assert_eq!(back.id, 5);
        assert_eq!(back.deadline_ms, 250);
        assert_eq!(back.label, Some(3));
        assert_eq!(back.train, req.train);
        let f2 = fr.read_frame(&mut r).unwrap().unwrap();
        assert_eq!(FrameKind::from_u8(f2.kind), Some(FrameKind::Ping));
        assert!(f2.payload.is_empty());
        assert!(fr.read_frame(&mut r).unwrap().is_none(), "clean EOF");
    }

    /// One byte at a time: the reader must reassemble frames across
    /// arbitrarily fragmented reads (TCP gives no message boundaries).
    #[test]
    fn reader_handles_fragmentation() {
        struct OneByte<'a>(&'a [u8], usize);
        impl Read for OneByte<'_> {
            fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
                if self.1 >= self.0.len() {
                    return Ok(0);
                }
                buf[0] = self.0[self.1];
                self.1 += 1;
                Ok(1)
            }
        }
        let resp = InferResponse {
            id: 9,
            predicted: 2,
            cycles: 12345,
            server_micros: 999,
            output: train(),
        };
        let mut wire = Vec::new();
        write_frame(&mut wire, FrameKind::InferResponse, &resp.encode()).unwrap();
        let mut fr = FrameReader::new(DEFAULT_MAX_FRAME_LEN);
        let mut r = OneByte(&wire, 0);
        let f = fr.read_frame(&mut r).unwrap().unwrap();
        let back = InferResponse::decode(&f.payload).unwrap();
        assert_eq!(back.cycles, 12345);
        assert_eq!(back.output, resp.output);
    }

    /// Timeouts mid-frame preserve buffered bytes; the next call resumes.
    #[test]
    fn reader_survives_interleaved_timeouts() {
        struct Flaky<'a> {
            data: &'a [u8],
            pos: usize,
            hiccup: bool,
        }
        impl Read for Flaky<'_> {
            fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
                self.hiccup = !self.hiccup;
                if self.hiccup {
                    return Err(io::Error::new(io::ErrorKind::WouldBlock, "timeout"));
                }
                if self.pos >= self.data.len() {
                    return Ok(0);
                }
                let n = buf.len().min(3).min(self.data.len() - self.pos);
                buf[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
                self.pos += n;
                Ok(n)
            }
        }
        let mut wire = Vec::new();
        write_frame(&mut wire, FrameKind::Stats, &[]).unwrap();
        write_frame(&mut wire, FrameKind::Pong, &[]).unwrap();
        let mut fr = FrameReader::new(DEFAULT_MAX_FRAME_LEN);
        let mut r = Flaky { data: &wire, pos: 0, hiccup: false };
        let mut kinds = Vec::new();
        loop {
            match fr.read_frame(&mut r) {
                Ok(Some(f)) => kinds.push(f.kind),
                Ok(None) => break,
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => continue,
                Err(e) => panic!("{e}"),
            }
        }
        assert_eq!(kinds, vec![FrameKind::Stats as u8, FrameKind::Pong as u8]);
    }

    #[test]
    fn reader_rejects_framing_violations() {
        // Bad magic.
        let mut fr = FrameReader::new(DEFAULT_MAX_FRAME_LEN);
        let garbage = [0u8; 16];
        let e = fr.read_frame(&mut io::Cursor::new(&garbage[..])).unwrap_err();
        assert_eq!(e.kind(), io::ErrorKind::InvalidData);
        // Bad version.
        let mut wire = Vec::new();
        write_frame(&mut wire, FrameKind::Ping, &[]).unwrap();
        wire[2] = 99;
        let mut fr = FrameReader::new(DEFAULT_MAX_FRAME_LEN);
        assert!(fr.read_frame(&mut io::Cursor::new(&wire[..])).is_err());
        // Oversized payload claim.
        let mut wire = Vec::new();
        write_frame(&mut wire, FrameKind::Ping, &[]).unwrap();
        wire[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
        let mut fr = FrameReader::new(1024);
        assert!(fr.read_frame(&mut io::Cursor::new(&wire[..])).is_err());
        // EOF mid-frame (truncated).
        let mut wire = Vec::new();
        write_frame(&mut wire, FrameKind::Error, &[0; 32]).unwrap();
        wire.truncate(20);
        let mut fr = FrameReader::new(DEFAULT_MAX_FRAME_LEN);
        let e = fr.read_frame(&mut io::Cursor::new(&wire[..])).unwrap_err();
        assert_eq!(e.kind(), io::ErrorKind::InvalidData);
    }

    #[test]
    fn error_frame_roundtrip() {
        let ef = ErrorFrame::new(NO_ID, ErrorCode::Overload, "429 busy");
        let back = ErrorFrame::decode(&ef.encode()).unwrap();
        assert_eq!(back.id, NO_ID);
        assert_eq!(back.code, ErrorCode::Overload);
        assert_eq!(back.message, "429 busy");
        assert!(ErrorFrame::decode(&ef.encode()[..5]).is_err());
        // Unknown code byte.
        let mut p = ef.encode();
        p[8] = 200;
        assert!(ErrorFrame::decode(&p).is_err());
    }

    #[test]
    fn stats_reply_roundtrip() {
        let j = Json::obj(vec![("completed", 12usize.into()), ("p50_us", 340.5.into())]);
        let back = decode_stats_reply(&encode_stats_reply(&j)).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn request_decode_rejects_trailing_garbage() {
        let req = InferRequest { id: 1, deadline_ms: 0, label: None, train: train() };
        let mut p = req.encode();
        p.push(0);
        assert!(InferRequest::decode(&p).is_err());
    }

    #[test]
    fn shard_step_and_ack_roundtrip() {
        let mut rng = Rng::new(4);
        let frontier = SpikeTrain::bernoulli(16, 1, 0.4, &mut rng);
        let step = ShardStepFrame { seq: 7, step: 3, frontier: frontier.clone() };
        let back = ShardStepFrame::decode(&step.encode()).unwrap();
        assert_eq!(back.seq, 7);
        assert_eq!(back.step, 3);
        assert_eq!(back.frontier, frontier);
        let ack =
            ShardAckFrame { seq: 7, step: 3, step_cycles: 4096, frontier: frontier.clone() };
        let back = ShardAckFrame::decode(&ack.encode()).unwrap();
        assert_eq!(back.seq, 7);
        assert_eq!(back.step, 3);
        assert_eq!(back.step_cycles, 4096);
        assert_eq!(back.frontier, frontier);
        // Trailing garbage is rejected.
        let mut p = step.encode();
        p.push(0);
        assert!(ShardStepFrame::decode(&p).is_err());
        // A multi-timestep train is not a frontier.
        let fat = SpikeTrain::bernoulli(16, 3, 0.4, &mut rng);
        let bad = ShardStepFrame { seq: 0, step: 0, frontier: fat.clone() };
        assert!(ShardStepFrame::decode(&bad.encode()).is_err());
        let bad = ShardAckFrame { seq: 0, step: 0, step_cycles: 0, frontier: fat };
        assert!(ShardAckFrame::decode(&bad.encode()).is_err());
    }

    #[test]
    fn session_frames_roundtrip() {
        let open = SessionIdFrame { sid: 42 };
        assert_eq!(SessionIdFrame::decode(&open.encode()).unwrap(), open);
        let chunk = SessionChunkFrame { sid: 42, seq: 3, chunk: train() };
        let back = SessionChunkFrame::decode(&chunk.encode()).unwrap();
        assert_eq!(back.sid, 42);
        assert_eq!(back.seq, 3);
        assert_eq!(back.chunk, chunk.chunk);
        let out = SessionOutFrame {
            sid: 42,
            seq: 3,
            chunk_cycles: 777,
            predicted: 2,
            output: train(),
        };
        let back = SessionOutFrame::decode(&out.encode()).unwrap();
        assert_eq!(back.sid, 42);
        assert_eq!(back.seq, 3);
        assert_eq!(back.chunk_cycles, 777);
        assert_eq!(back.predicted, 2);
        assert_eq!(back.output, out.output);
        // A 0-timestep chunk is a legal keepalive.
        let empty = SessionChunkFrame { sid: 1, seq: 0, chunk: SpikeTrain::new(30, 0) };
        assert_eq!(SessionChunkFrame::decode(&empty.encode()).unwrap().chunk.timesteps(), 0);
        // Trailing garbage is rejected on every session payload.
        let mut p = open.encode();
        p.push(0);
        assert!(SessionIdFrame::decode(&p).is_err());
        let mut p = chunk.encode();
        p.push(0);
        assert!(SessionChunkFrame::decode(&p).is_err());
        let mut p = out.encode();
        p.push(0);
        assert!(SessionOutFrame::decode(&p).is_err());
        // Truncated prefixes are rejected, never panic.
        let enc = chunk.encode();
        for cut in 0..enc.len() {
            assert!(SessionChunkFrame::decode(&enc[..cut]).is_err(), "cut {cut}");
        }
    }

    #[test]
    fn kind_and_code_tables_roundtrip() {
        for k in 1u8..=14 {
            assert_eq!(FrameKind::from_u8(k).unwrap() as u8, k);
        }
        assert!(FrameKind::from_u8(0).is_none());
        assert!(FrameKind::from_u8(15).is_none());
        for c in 1u8..=7 {
            let code = ErrorCode::from_u8(c).unwrap();
            assert_eq!(code as u8, c);
            assert!(!code.name().is_empty());
        }
        assert!(ErrorCode::from_u8(0).is_none());
    }
}
