//! Little-endian byte (de)serialization primitives for the wire protocol.
//!
//! [`super::protocol`] composes these into frames. Writers append to a
//! `Vec<u8>`; reading goes through [`Cursor`], which is bounds-checked
//! everywhere (a malformed payload yields an error, never a panic or an
//! out-of-bounds read) and tracks its position so fixed fields and
//! variable-length tails (spike trains, strings) can be mixed freely.

use anyhow::{bail, Result};

use crate::snn::SpikeTrain;

/// Append a `u8`.
pub fn put_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}

/// Append a little-endian `u32`.
pub fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Append a little-endian `u64`.
pub fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Append a `u32` length prefix followed by the raw bytes.
pub fn put_bytes(out: &mut Vec<u8>, b: &[u8]) {
    put_u32(out, b.len() as u32);
    out.extend_from_slice(b);
}

/// Append a `u32`-length-prefixed UTF-8 string.
pub fn put_str(out: &mut Vec<u8>, s: &str) {
    put_bytes(out, s.as_bytes());
}

/// Bounds-checked reader over a received payload.
pub struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        let Some(bytes) = self.buf.get(self.pos..self.pos + n) else {
            bail!(
                "payload truncated reading {what}: need {n} bytes at offset {}, have {}",
                self.pos,
                self.remaining()
            );
        };
        self.pos += n;
        Ok(bytes)
    }

    pub fn u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.take(1, what)?[0])
    }

    pub fn u32(&mut self, what: &str) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    pub fn u64(&mut self, what: &str) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    /// A `u32`-length-prefixed byte run.
    pub fn bytes(&mut self, what: &str) -> Result<&'a [u8]> {
        let n = self.u32(what)? as usize;
        self.take(n, what)
    }

    /// A `u32`-length-prefixed UTF-8 string.
    pub fn str(&mut self, what: &str) -> Result<&'a str> {
        let b = self.bytes(what)?;
        std::str::from_utf8(b).map_err(|e| anyhow::anyhow!("{what}: invalid UTF-8: {e}"))
    }

    /// A [`SpikeTrain`] in its wire encoding (fully validated — see
    /// [`SpikeTrain::read_wire`]).
    pub fn train(&mut self, what: &str) -> Result<SpikeTrain> {
        let (st, consumed) = SpikeTrain::read_wire(&self.buf[self.pos..])
            .map_err(|e| anyhow::anyhow!("{what}: {e:#}"))?;
        self.pos += consumed;
        Ok(st)
    }

    /// Assert the whole payload was consumed — trailing garbage in a
    /// fixed-layout frame means a framing bug or a corrupt sender.
    pub fn finish(&self, what: &str) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!(
                "{what}: {} trailing bytes after payload (frame length lies)",
                self.remaining()
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn scalar_roundtrip() {
        let mut b = Vec::new();
        put_u8(&mut b, 7);
        put_u32(&mut b, 0xDEAD_BEEF);
        put_u64(&mut b, u64::MAX - 1);
        put_str(&mut b, "héllo");
        put_bytes(&mut b, &[1, 2, 3]);
        let mut c = Cursor::new(&b);
        assert_eq!(c.u8("a").unwrap(), 7);
        assert_eq!(c.u32("b").unwrap(), 0xDEAD_BEEF);
        assert_eq!(c.u64("c").unwrap(), u64::MAX - 1);
        assert_eq!(c.str("d").unwrap(), "héllo");
        assert_eq!(c.bytes("e").unwrap(), &[1, 2, 3]);
        c.finish("frame").unwrap();
    }

    #[test]
    fn truncation_is_an_error() {
        let mut b = Vec::new();
        put_u64(&mut b, 42);
        let mut c = Cursor::new(&b[..5]);
        assert!(c.u64("x").is_err());
        // Length prefix promising more than the buffer holds.
        let mut b = Vec::new();
        put_u32(&mut b, 100);
        b.extend_from_slice(&[0; 10]);
        let mut c = Cursor::new(&b);
        assert!(c.bytes("blob").is_err());
    }

    #[test]
    fn trailing_bytes_rejected() {
        let mut b = Vec::new();
        put_u32(&mut b, 1);
        put_u32(&mut b, 2);
        let mut c = Cursor::new(&b);
        c.u32("only").unwrap();
        assert!(c.finish("frame").is_err());
    }

    #[test]
    fn train_embeds_between_fields() {
        let mut rng = Rng::new(3);
        let st = SpikeTrain::bernoulli(25, 5, 0.3, &mut rng);
        let mut b = Vec::new();
        put_u64(&mut b, 9);
        st.write_wire(&mut b);
        put_u32(&mut b, 77);
        let mut c = Cursor::new(&b);
        assert_eq!(c.u64("id").unwrap(), 9);
        assert_eq!(c.train("train").unwrap(), st);
        assert_eq!(c.u32("tail").unwrap(), 77);
        c.finish("frame").unwrap();
    }

    #[test]
    fn invalid_utf8_rejected() {
        let mut b = Vec::new();
        put_bytes(&mut b, &[0xFF, 0xFE]);
        let mut c = Cursor::new(&b);
        assert!(c.str("s").is_err());
    }
}
