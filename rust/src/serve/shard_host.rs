//! `menage shard-host` — serve ONE chip of a [`crate::mapping::ShardPlan`]
//! over the length-prefixed wire protocol, so a sharded pipeline can span
//! processes (and, once the TLS/auth story lands, machines).
//!
//! A host owns one pristine shard chip. Each accepted connection gets its
//! **own clone** of that chip — membrane and stats state are per-stream,
//! so concurrent drivers (or a driver reconnecting after a failure) can
//! never observe each other's partial state. The per-connection session:
//!
//! ```text
//! driver                                host (shard k)
//!   SHARD_STEP { seq, step, frontier } ──▶  step==0? reset membranes
//!                                           run frontier through cores
//!   ◀── SHARD_ACK { seq, step, cycles, out-frontier }
//! ```
//!
//! `seq` starts at 0 per connection and must increment by exactly 1;
//! `step` must be 0 (a new input — membranes reset, mirroring
//! [`crate::accel::Menage::run_into`]) or the previous step + 1. Any
//! violation — a gap, a replay, a wrong-width frontier — earns a typed
//! `BadRequest` ERROR and closes the connection, because a chip whose
//! stream diverged from its driver holds membrane state that can no
//! longer be trusted. The driver reconnects and replays from step 0.
//!
//! When a connection closes, its chip's accumulated [`CoreStats`] fold
//! into the host's aggregate registry (scalar sums, per-step series
//! appended), so STATS totals over all *closed* sessions remain
//! bit-comparable with an in-process [`ShardedMenage`]'s folded stats —
//! the distributed identity suite leans on exactly this.

use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::accel::Menage;
use crate::fault::lock_recover;
use crate::neuracore::CoreStats;
use crate::obs::{CoreSample, ProfilePlane};
use crate::shard::{distinct_sources, ShardedMenage};
use crate::util::json::Json;

use super::metrics::LatencyHistogram;
use super::protocol::{
    encode_stats_reply, write_frame, ErrorCode, ErrorFrame, FrameKind, FrameReader,
    ShardAckFrame, ShardStepFrame, DEFAULT_MAX_FRAME_LEN, NO_ID, STATS_VERSION,
};

/// Host knobs; `Default` matches the CLI defaults.
#[derive(Debug, Clone)]
pub struct ShardHostConfig {
    pub max_frame_len: u32,
    /// Read-timeout granularity: how often blocked connection threads
    /// check the stop flag.
    pub poll_interval: Duration,
    pub write_timeout: Duration,
    /// Honor SHUTDOWN frames (off by default, same as `serve`).
    pub allow_remote_shutdown: bool,
}

impl Default for ShardHostConfig {
    fn default() -> Self {
        Self {
            max_frame_len: DEFAULT_MAX_FRAME_LEN,
            poll_interval: Duration::from_millis(25),
            write_timeout: Duration::from_secs(10),
            allow_remote_shutdown: false,
        }
    }
}

/// Monotonic counters for the host's `host` STATS block.
#[derive(Debug, Default)]
struct HostCounters {
    connections_opened: AtomicU64,
    connections_active: AtomicU64,
    /// SHARD_STEP frames executed (acks sent).
    steps_executed: AtomicU64,
    /// step==0 frames seen — distinct inputs started.
    inputs_started: AtomicU64,
    /// Distinct frontier sources received — this host's inbound cut
    /// traffic, same accounting as `ShardedMenage::boundary_events`.
    boundary_events_in: AtomicU64,
    protocol_errors: AtomicU64,
}

struct HostShared {
    cfg: ShardHostConfig,
    /// The never-run shard chip every connection clones.
    pristine: Menage,
    index: usize,
    num_shards: usize,
    layer_lo: usize,
    layer_hi: usize,
    cut_cost_in: u64,
    timesteps: usize,
    /// Folded stats of every *closed* connection, per core (local index).
    agg: Mutex<Vec<CoreStats>>,
    /// Live per-core execution counters, delta-published after every
    /// executed step across **all** sessions (open ones included) —
    /// unlike `agg`, which only sees closed sessions. Every core maps to
    /// this host's shard index.
    live: ProfilePlane,
    /// Wall time per executed SHARD_STEP (receipt-validated → ack built):
    /// the host-side half of the driver's per-link `wire_us` — their gap
    /// is pure wire + queueing.
    step_wall: LatencyHistogram,
    counters: HostCounters,
    stop_accept: AtomicBool,
    stop_conns: AtomicBool,
    remote_shutdown: AtomicBool,
    started: Instant,
    conns: Mutex<Vec<JoinHandle<()>>>,
}

impl HostShared {
    fn input_dim(&self) -> usize {
        self.pristine.cores[0].in_dim()
    }

    fn output_dim(&self) -> usize {
        self.pristine.cores.last().expect("≥1 core").out_dim()
    }

    fn stats_json(&self) -> Json {
        let c = &self.counters;
        let load = |a: &AtomicU64| Json::Num(a.load(Ordering::Relaxed) as f64);
        let agg = lock_recover(&self.agg);
        let cores = Json::Arr(
            agg.iter()
                .enumerate()
                .map(|(i, s)| {
                    Json::obj(vec![
                        ("core", i.into()),
                        ("cycles", (s.cycles as usize).into()),
                        ("events_dispatched", (s.events_dispatched as usize).into()),
                        ("sn_rows_read", (s.sn_rows_read as usize).into()),
                        ("macs", (s.macs as usize).into()),
                        ("integrations", (s.integrations as usize).into()),
                        ("fire_ops", (s.fire_ops as usize).into()),
                        ("spikes_out", (s.spikes_out as usize).into()),
                        ("dropped_events", (s.dropped_events as usize).into()),
                        ("stuck_row_hits", (s.stuck_row_hits as usize).into()),
                        ("dead_slot_hits", (s.dead_slot_hits as usize).into()),
                        ("events_bit_flipped", (s.events_bit_flipped as usize).into()),
                    ])
                })
                .collect(),
        );
        let (stuck, dead, flipped) = agg.iter().fold((0u64, 0u64, 0u64), |t, s| {
            (t.0 + s.stuck_row_hits, t.1 + s.dead_slot_hits, t.2 + s.events_bit_flipped)
        });
        drop(agg);
        // Only the cores half of the live plane: a host serves exactly one
        // shard, so each core row's `shard` field already says which.
        let (live_cores, _) = self.live.to_json();
        Json::obj(vec![
            ("stats_version", (STATS_VERSION as usize).into()),
            ("uptime_s", Json::Num(self.started.elapsed().as_secs_f64())),
            // Probe-compatible `model` block (loadgen and the pipeline
            // driver both read it): a shard host's "model" is its slice.
            (
                "model",
                Json::obj(vec![
                    ("input_dim", self.input_dim().into()),
                    ("timesteps", self.timesteps.into()),
                    ("classes", self.output_dim().into()),
                ]),
            ),
            (
                "shard",
                Json::obj(vec![
                    ("index", self.index.into()),
                    ("num_shards", self.num_shards.into()),
                    ("layer_lo", self.layer_lo.into()),
                    ("layer_hi", self.layer_hi.into()),
                    ("cores", self.pristine.cores.len().into()),
                    ("input_dim", self.input_dim().into()),
                    ("output_dim", self.output_dim().into()),
                    ("cut_cost_in", (self.cut_cost_in as usize).into()),
                ]),
            ),
            (
                "host",
                Json::obj(vec![
                    ("connections_opened", load(&c.connections_opened)),
                    ("connections_active", load(&c.connections_active)),
                    ("steps_executed", load(&c.steps_executed)),
                    ("inputs_started", load(&c.inputs_started)),
                    ("boundary_events_in", load(&c.boundary_events_in)),
                    ("protocol_errors", load(&c.protocol_errors)),
                ]),
            ),
            ("cores", cores),
            (
                "profile",
                Json::obj(vec![
                    ("step_wall_us", self.step_wall.summary_json()),
                    ("cores", live_cores),
                ]),
            ),
            (
                "faults",
                Json::obj(vec![
                    ("stuck_row_hits", (stuck as usize).into()),
                    ("dead_slot_hits", (dead as usize).into()),
                    ("events_bit_flipped", (flipped as usize).into()),
                ]),
            ),
        ])
    }
}

/// A running shard host (module docs).
pub struct ShardHostServer {
    local_addr: std::net::SocketAddr,
    shared: Arc<HostShared>,
    accept: Option<JoinHandle<()>>,
}

impl ShardHostServer {
    /// Serve shard `index` of `sharded`'s plan on `addr`. The caller
    /// builds the *full* `ShardedMenage` (same seed, same fault plan) and
    /// this host clones out its slice — which is exactly what keeps the
    /// realized cores bit-identical to every other host's view of the
    /// plan and to an in-process run.
    pub fn start(
        sharded: &ShardedMenage,
        index: usize,
        addr: &str,
        cfg: ShardHostConfig,
    ) -> Result<Self> {
        if index >= sharded.shards.len() {
            bail!(
                "shard index {index} out of range: the plan has {} shards",
                sharded.shards.len()
            );
        }
        let pristine = sharded.shards[index].clone();
        let range = sharded.plan.ranges()[index].clone();
        let num_cores = pristine.cores.len();
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding shard-host listener on {addr}"))?;
        let local_addr = listener.local_addr().context("resolving bound address")?;
        listener.set_nonblocking(true).context("setting listener non-blocking")?;
        let timesteps = pristine.timesteps;
        let shared = Arc::new(HostShared {
            cfg,
            pristine,
            index,
            num_shards: sharded.shards.len(),
            layer_lo: range.start,
            layer_hi: range.end,
            cut_cost_in: if index == 0 { 0 } else { sharded.boundary_cost[index - 1] },
            timesteps,
            agg: Mutex::new(vec![CoreStats::default(); num_cores]),
            live: ProfilePlane::new(vec![index; num_cores]),
            step_wall: LatencyHistogram::default(),
            counters: HostCounters::default(),
            stop_accept: AtomicBool::new(false),
            stop_conns: AtomicBool::new(false),
            remote_shutdown: AtomicBool::new(false),
            started: Instant::now(),
            conns: Mutex::new(Vec::new()),
        });
        let accept = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || accept_loop(&shared, listener))
        };
        Ok(Self { local_addr, shared, accept: Some(accept) })
    }

    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.local_addr
    }

    /// Whether an honored SHUTDOWN frame arrived (the CLI polls this).
    pub fn remote_shutdown_requested(&self) -> bool {
        self.shared.remote_shutdown.load(Ordering::Relaxed)
    }

    /// Current STATS document (same shape the wire reply carries).
    pub fn stats_json(&self) -> Json {
        self.shared.stats_json()
    }

    /// Stop accepting, sever live connections, join all threads. Folds
    /// any still-open connection's stats on the way out.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.shared.stop_accept.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        self.shared.stop_conns.store(true, Ordering::Relaxed);
        let conns = std::mem::take(&mut *lock_recover(&self.shared.conns));
        for h in conns {
            let _ = h.join();
        }
    }
}

impl Drop for ShardHostServer {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

fn accept_loop(shared: &Arc<HostShared>, listener: TcpListener) {
    loop {
        if shared.stop_accept.load(Ordering::Relaxed) {
            return;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                shared.counters.connections_opened.fetch_add(1, Ordering::Relaxed);
                shared.counters.connections_active.fetch_add(1, Ordering::Relaxed);
                let conn = {
                    let shared = Arc::clone(shared);
                    std::thread::spawn(move || {
                        conn_loop(&shared, stream);
                        shared.counters.connections_active.fetch_sub(1, Ordering::Relaxed);
                    })
                };
                let mut conns = lock_recover(&shared.conns);
                conns.retain(|h| !h.is_finished());
                conns.push(conn);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    }
}

/// Best-effort typed error (the peer may already be gone).
fn send_host_error(stream: &mut TcpStream, code: ErrorCode, msg: impl Into<String>) {
    let ef = ErrorFrame::new(NO_ID, code, msg);
    let _ = write_frame(stream, FrameKind::Error, &ef.encode());
}

/// One connection = one chip session (single thread: the SHARD_STEP
/// window is bounded by the driver, so writing acks inline can never
/// deadlock against unread requests).
fn conn_loop(shared: &Arc<HostShared>, mut stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(shared.cfg.poll_interval));
    let _ = stream.set_write_timeout(Some(shared.cfg.write_timeout));
    let mut chip = shared.pristine.clone();
    let mut fr = FrameReader::new(shared.cfg.max_frame_len);
    // Per-connection stream state: next acceptable sequence number and the
    // last executed step (None = no step yet / expecting a fresh input).
    let mut expected_seq = 0u64;
    let mut last_step: Option<u32> = None;
    // Double-buffered frontier scratch, as in the in-process run loop.
    let mut carry: Vec<u32> = Vec::new();
    let mut scratch: Vec<u32> = Vec::new();
    // Last-published execution-profile sample per core (delta publishing
    // into the host's live plane, one sample per executed step).
    let mut prof_last = vec![CoreSample::default(); chip.cores.len()];
    let c = &shared.counters;
    loop {
        if shared.stop_conns.load(Ordering::Relaxed) {
            break;
        }
        let frame = match fr.read_frame(&mut stream) {
            Ok(Some(f)) => f,
            Ok(None) => break, // driver closed cleanly
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue
            }
            Err(e) => {
                c.protocol_errors.fetch_add(1, Ordering::Relaxed);
                send_host_error(&mut stream, ErrorCode::Malformed, e.to_string());
                break;
            }
        };
        match FrameKind::from_u8(frame.kind) {
            Some(FrameKind::ShardStep) => {
                let step = match ShardStepFrame::decode(&frame.payload) {
                    Ok(s) => s,
                    Err(e) => {
                        c.protocol_errors.fetch_add(1, Ordering::Relaxed);
                        send_host_error(&mut stream, ErrorCode::BadRequest, format!("{e:#}"));
                        break;
                    }
                };
                if let Err(msg) = check_step(shared, &chip, expected_seq, last_step, &step) {
                    c.protocol_errors.fetch_add(1, Ordering::Relaxed);
                    send_host_error(&mut stream, ErrorCode::BadRequest, msg);
                    break;
                }
                let frontier = &step.frontier.spikes[0];
                c.boundary_events_in
                    .fetch_add(distinct_sources(frontier), Ordering::Relaxed);
                if step.step == 0 {
                    // New input: independent classification, membranes
                    // reset — exactly `Menage::run_into`'s preamble.
                    for core in chip.cores.iter_mut() {
                        core.reset_membranes();
                    }
                    chip.inputs_processed += 1;
                    c.inputs_started.fetch_add(1, Ordering::Relaxed);
                }
                let wall_start = Instant::now();
                let step_cycles = run_one_step(&mut chip, frontier, &mut carry, &mut scratch);
                shared.step_wall.record_micros(wall_start.elapsed().as_micros() as u64);
                // Publish this step's per-core work into the live plane
                // (delta vs the last published sample, like the
                // coordinator's fault/profile counters).
                for (ci, core) in chip.cores.iter().enumerate() {
                    let now = core.profile_sample();
                    shared.live.add(ci, &now.delta_since(&prof_last[ci]));
                    prof_last[ci] = now;
                }
                expected_seq += 1;
                last_step = Some(step.step);
                c.steps_executed.fetch_add(1, Ordering::Relaxed);
                let mut out = crate::snn::SpikeTrain::new(chip.cores.last().unwrap().out_dim(), 1);
                out.spikes[0] = carry.clone();
                let ack =
                    ShardAckFrame { seq: step.seq, step: step.step, step_cycles, frontier: out };
                if write_frame(&mut stream, FrameKind::ShardAck, &ack.encode()).is_err() {
                    break; // driver gone mid-ack; fold stats and bail
                }
            }
            Some(FrameKind::Ping) => {
                if write_frame(&mut stream, FrameKind::Pong, &[]).is_err() {
                    break;
                }
            }
            Some(FrameKind::Stats) => {
                let payload = encode_stats_reply(&shared.stats_json());
                if write_frame(&mut stream, FrameKind::StatsReply, &payload).is_err() {
                    break;
                }
            }
            Some(FrameKind::Shutdown) => {
                if shared.cfg.allow_remote_shutdown {
                    shared.remote_shutdown.store(true, Ordering::Relaxed);
                    let _ = write_frame(&mut stream, FrameKind::Pong, &[]);
                } else {
                    send_host_error(
                        &mut stream,
                        ErrorCode::Unsupported,
                        "remote shutdown is disabled on this shard-host",
                    );
                }
            }
            // Well-framed but meaningless to a shard host (INFER etc.):
            // answer and keep the connection — alignment is intact.
            Some(other) => {
                send_host_error(
                    &mut stream,
                    ErrorCode::Unsupported,
                    format!("shard-host does not serve {other:?} frames"),
                );
            }
            None => {
                send_host_error(
                    &mut stream,
                    ErrorCode::Unsupported,
                    format!("unknown frame kind {}", frame.kind),
                );
            }
        }
    }
    let _ = stream.shutdown(Shutdown::Both);
    // Session over: fold this chip's stats into the host aggregate so
    // STATS stays comparable with in-process folded CoreStats.
    let mut agg = lock_recover(&shared.agg);
    for (into, core) in agg.iter_mut().zip(chip.cores.iter()) {
        fold_core_stats(into, &core.stats);
    }
}

/// Validate a SHARD_STEP against the connection's stream state; `Err` is
/// the BadRequest message.
fn check_step(
    shared: &HostShared,
    chip: &Menage,
    expected_seq: u64,
    last_step: Option<u32>,
    step: &ShardStepFrame,
) -> std::result::Result<(), String> {
    if step.seq != expected_seq {
        return Err(format!(
            "sequence gap: got seq {}, expected {expected_seq} — stream state lost, reconnect and replay from step 0",
            step.seq
        ));
    }
    let step_ok = step.step == 0 || last_step.is_some_and(|p| step.step == p + 1);
    if !step_ok {
        return Err(match last_step {
            Some(p) => format!("step {} does not follow step {p} (and is not a fresh input's step 0)", step.step),
            None => format!("first step of a connection must be 0, got {}", step.step),
        });
    }
    let want = chip.cores[0].in_dim();
    if step.frontier.num_neurons != want {
        return Err(format!(
            "frontier has {} neurons, shard {} expects {want}",
            step.frontier.num_neurons, shared.index
        ));
    }
    Ok(())
}

/// Run one frontier through the shard's core chain — the inner body of
/// `ShardedMenage::run_into` for a single shard and step: core 0 consumes
/// the wire frontier, each later core consumes its predecessor's output
/// of the same step (spikes ripple through the chain within the step),
/// and the step's cost is the busiest core's cycle delta (synchronous
/// clock). `carry` ends as the shard's outbound frontier.
fn run_one_step(
    chip: &mut Menage,
    frontier: &[u32],
    carry: &mut Vec<u32>,
    scratch: &mut Vec<u32>,
) -> u64 {
    let mut step_cycles = 0u64;
    for (ci, core) in chip.cores.iter_mut().enumerate() {
        let events: &[u32] = if ci == 0 { frontier } else { &*carry };
        core.push_events(events);
        let before = core.stats.cycles;
        core.step_into(scratch);
        step_cycles = step_cycles.max(core.stats.cycles - before);
        std::mem::swap(carry, scratch);
    }
    step_cycles
}

/// Fold one session chip's per-core stats into the host aggregate:
/// scalars sum (`peak_event_queue` maxes — it is a high-water mark), the
/// per-step series append, mirroring the CLI's `merge_chips`.
fn fold_core_stats(into: &mut CoreStats, from: &CoreStats) {
    into.cycles += from.cycles;
    into.events_dispatched += from.events_dispatched;
    into.sn_rows_read += from.sn_rows_read;
    into.macs += from.macs;
    into.integrations += from.integrations;
    into.fire_ops += from.fire_ops;
    into.spikes_out += from.spikes_out;
    into.peak_event_queue = into.peak_event_queue.max(from.peak_event_queue);
    into.dropped_events += from.dropped_events;
    into.stuck_row_hits += from.stuck_row_hits;
    into.dead_slot_hits += from.dead_slot_hits;
    into.events_bit_flipped += from.events_bit_flipped;
    into.sn_rows_touched_per_step.extend_from_slice(&from.sn_rows_touched_per_step);
    into.cycles_per_step.extend_from_slice(&from.cycles_per_step);
}
