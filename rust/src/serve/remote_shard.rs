//! The distributed-pipeline driver: streams boundary frontiers between
//! `menage shard-host` processes (see [`super::shard_host`]).
//!
//! A [`RemoteShardPipeline`] holds one [`Client`] connection per shard
//! host, in pipeline order. Per input it feeds the input train's steps to
//! link 0 and forwards every SHARD_ACK frontier to the next link, keeping
//! up to `window` timesteps in flight **per link** — so shard k executes
//! step t while shard k+1 executes step t−1 and pipeline throughput
//! approaches one-chip throughput regardless of depth.
//!
//! **Scheduling.** The driver is send-preferring: each round it first
//! sends every frontier that is ready on a link with window room, and
//! only when nothing can be sent does it block (bounded by `io_timeout`)
//! on the earliest link with outstanding acks. This makes the pipeline
//! fill deterministic — with `window ≥ 2` and enough timesteps every
//! link reaches `window` steps in flight (pinned by
//! `tests/dist_identity.rs`) — and means a dead or wedged host surfaces
//! as a typed error naming the shard within one `io_timeout`, never a
//! hang.
//!
//! **Bit-identity.** The cores live on the hosts (built from the same
//! `(model, seed, fault plan)` the in-process [`crate::shard::ShardedMenage`]
//! uses), the frontier hand-off is the same spike sets the in-process
//! loop forwards, and the modeled clock is reassembled exactly: each
//! SHARD_ACK carries the shard's max per-core cycle delta for its step,
//! and the driver folds `Σ_t max_k step_cycles[k][t]` — the monolithic
//! synchronous-clock cost model. Per-cut `boundary_events` counts
//! distinct frontier sources, matching the fixed in-process accounting
//! spike for spike.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::accel::RunOutput;
use crate::shard::distinct_sources;
use crate::snn::SpikeTrain;
use crate::util::json::Json;

use super::client::{Client, Reply};
use super::protocol::ShardStepFrame;

/// Driver knobs; `Default` matches the CLI defaults.
#[derive(Debug, Clone)]
pub struct RemoteShardConfig {
    /// Max timesteps in flight per link (≥ 1; 2 is enough to hide one
    /// link's latency behind the neighbour's compute).
    pub window: usize,
    /// How long a blocked ack wait may last before the driver declares
    /// the host dead (typed error, not a hang).
    pub io_timeout: Duration,
    /// Connect retries per host (jittered backoff, base `connect_delay`).
    pub connect_attempts: usize,
    pub connect_delay: Duration,
}

impl Default for RemoteShardConfig {
    fn default() -> Self {
        Self {
            window: 2,
            io_timeout: Duration::from_secs(5),
            connect_attempts: 10,
            connect_delay: Duration::from_millis(50),
        }
    }
}

/// Live per-link gauges and per-cut counters, shared by every clone of a
/// pipeline (the serving layer's worker clones) — the STATS
/// `remote_links` block.
#[derive(Debug)]
pub struct RemoteLinkStats {
    /// Distinct frontier sources forwarded into shard `c+1` (len =
    /// shards − 1) — the wire-traffic observable, defined exactly as
    /// [`crate::shard::ShardedMenage::boundary_events`].
    pub boundary_events: Vec<AtomicU64>,
    /// SHARD_STEPs currently awaiting their ack, per link.
    pub in_flight: Vec<AtomicU64>,
    /// High-water mark of `in_flight`, per link — ≥ 2 here proves the
    /// pipeline actually overlaps timesteps on that link.
    pub max_in_flight: Vec<AtomicU64>,
    /// SHARD_STEP frames sent, per link.
    pub steps_sent: Vec<AtomicU64>,
    /// SHARD_ACKs received, per link.
    pub acks: Vec<AtomicU64>,
    /// Σ host-reported `step_cycles` over acked steps, per link — the
    /// remote half of the execution profile (each host's own STATS has
    /// the per-core breakdown).
    pub step_cycles: Vec<AtomicU64>,
    /// Σ send→ack round-trip per link, µs (wire + remote compute) —
    /// divide by `acks` for the mean RTT a link contributes.
    pub wire_us: Vec<AtomicU64>,
    /// Σ wall time the driver spent *blocked* on this link's ack with no
    /// send it could still issue, µs. `wire_us` says how slow a link is;
    /// `wait_us` says whether that slowness actually stalls the pipeline —
    /// the host-by-host attribution of distributed step latency.
    pub wait_us: Vec<AtomicU64>,
}

impl RemoteLinkStats {
    fn new(num_shards: usize) -> Self {
        let zeros = |n: usize| (0..n).map(|_| AtomicU64::new(0)).collect();
        Self {
            boundary_events: zeros(num_shards.saturating_sub(1)),
            in_flight: zeros(num_shards),
            max_in_flight: zeros(num_shards),
            steps_sent: zeros(num_shards),
            acks: zeros(num_shards),
            step_cycles: zeros(num_shards),
            wire_us: zeros(num_shards),
            wait_us: zeros(num_shards),
        }
    }

    pub fn boundary_events_vec(&self) -> Vec<u64> {
        self.boundary_events.iter().map(|a| a.load(Ordering::Relaxed)).collect()
    }

    pub fn max_in_flight_vec(&self) -> Vec<u64> {
        self.max_in_flight.iter().map(|a| a.load(Ordering::Relaxed)).collect()
    }

    pub fn to_json(&self) -> Json {
        let arr = |v: &[AtomicU64]| {
            Json::Arr(
                v.iter().map(|a| Json::Num(a.load(Ordering::Relaxed) as f64)).collect(),
            )
        };
        Json::obj(vec![
            ("boundary_events", arr(&self.boundary_events)),
            ("in_flight", arr(&self.in_flight)),
            ("max_in_flight", arr(&self.max_in_flight)),
            ("steps_sent", arr(&self.steps_sent)),
            ("acks", arr(&self.acks)),
            ("step_cycles", arr(&self.step_cycles)),
            ("wire_us", arr(&self.wire_us)),
            ("wait_us", arr(&self.wait_us)),
        ])
    }
}

/// What the driver learned about one host during the probe.
#[derive(Debug, Clone)]
struct ShardInfo {
    input_dim: usize,
    output_dim: usize,
}

/// A connected pipeline of shard hosts (module docs). `Clone` yields a
/// disconnected copy with the same topology and shared [`RemoteLinkStats`]
/// that lazily reconnects on first use — what the coordinator's worker
/// template needs.
pub struct RemoteShardPipeline {
    addrs: Vec<String>,
    cfg: RemoteShardConfig,
    shards: Vec<ShardInfo>,
    timesteps: usize,
    /// One connection per shard host, pipeline order; `None` = not (yet)
    /// connected. The per-connection SHARD_STEP sequence number lives
    /// beside its link because it is connection state: a reconnect resets
    /// both together.
    links: Vec<Option<Client>>,
    seqs: Vec<u64>,
    stats: Arc<RemoteLinkStats>,
}

impl Clone for RemoteShardPipeline {
    fn clone(&self) -> Self {
        Self {
            addrs: self.addrs.clone(),
            cfg: self.cfg.clone(),
            shards: self.shards.clone(),
            timesteps: self.timesteps,
            links: self.addrs.iter().map(|_| None).collect(),
            seqs: vec![0; self.addrs.len()],
            stats: Arc::clone(&self.stats),
        }
    }
}

impl RemoteShardPipeline {
    /// Connect to every host (with backoff — hosts may still be binding),
    /// probe each one's STATS, and validate the topology: host k must
    /// serve shard k of a k-shard plan, dimensions must chain, and every
    /// host must agree on the timestep count.
    pub fn connect(addrs: &[String], cfg: RemoteShardConfig) -> Result<Self> {
        if addrs.is_empty() {
            bail!("--remote-shards needs at least one host:port");
        }
        if cfg.window == 0 {
            bail!("the in-flight window must be ≥ 1");
        }
        let mut links = Vec::with_capacity(addrs.len());
        let mut shards = Vec::with_capacity(addrs.len());
        let mut timesteps = None;
        for (k, addr) in addrs.iter().enumerate() {
            let (client, info, t) = Self::connect_one(addr, k, addrs.len(), &cfg)?;
            match timesteps {
                None => timesteps = Some(t),
                Some(t0) if t0 != t => bail!(
                    "shard-host {k} at {addr} runs {t} timesteps, shard-host 0 runs {t0}"
                ),
                Some(_) => {}
            }
            if let Some(prev) = shards.last() {
                let prev: &ShardInfo = prev;
                if prev.output_dim != info.input_dim {
                    bail!(
                        "shard-host {k} at {addr} expects {} inputs, predecessor emits {}",
                        info.input_dim,
                        prev.output_dim
                    );
                }
            }
            shards.push(info);
            links.push(Some(client));
        }
        let stats = Arc::new(RemoteLinkStats::new(addrs.len()));
        Ok(Self {
            addrs: addrs.to_vec(),
            cfg,
            shards,
            timesteps: timesteps.expect("≥1 host"),
            seqs: vec![0; links.len()],
            links,
            stats,
        })
    }

    /// Connect + probe one host and check it serves the expected shard.
    fn connect_one(
        addr: &str,
        k: usize,
        num_shards: usize,
        cfg: &RemoteShardConfig,
    ) -> Result<(Client, ShardInfo, usize)> {
        let mut client =
            Client::connect_retry(addr, cfg.connect_attempts.max(1), cfg.connect_delay)
                .with_context(|| format!("connecting to shard-host {k} at {addr}"))?;
        let j = client
            .stats()
            .with_context(|| format!("probing shard-host {k} at {addr}"))?;
        let shard = j
            .get("shard")
            .with_context(|| format!("{addr} is not a shard-host (no `shard` STATS block)"))?;
        let index = shard.get("index")?.as_usize()?;
        let hosted_of = shard.get("num_shards")?.as_usize()?;
        if index != k || hosted_of != num_shards {
            bail!(
                "shard-host at {addr} serves shard {index} of {hosted_of}, \
                 but position {k} of {num_shards} was expected — check --remote-shards order"
            );
        }
        let info = ShardInfo {
            input_dim: shard.get("input_dim")?.as_usize()?,
            output_dim: shard.get("output_dim")?.as_usize()?,
        };
        let timesteps = j.get("model")?.get("timesteps")?.as_usize()?;
        Ok((client, info, timesteps))
    }

    pub fn num_shards(&self) -> usize {
        self.addrs.len()
    }

    pub fn input_dim(&self) -> usize {
        self.shards[0].input_dim
    }

    pub fn output_dim(&self) -> usize {
        self.shards.last().expect("≥1 shard").output_dim
    }

    pub fn timesteps(&self) -> usize {
        self.timesteps
    }

    pub fn addrs(&self) -> &[String] {
        &self.addrs
    }

    /// The shared per-link gauges (every clone reports into the same
    /// registry).
    pub fn stats(&self) -> Arc<RemoteLinkStats> {
        Arc::clone(&self.stats)
    }

    /// Static topology block for STATS — shaped like the in-process
    /// `shards` block, with the host address in place of core counts.
    pub fn topology_json(&self) -> Json {
        Json::Arr(
            self.addrs
                .iter()
                .zip(&self.shards)
                .enumerate()
                .map(|(k, (addr, info))| {
                    Json::obj(vec![
                        ("shard", k.into()),
                        ("addr", Json::Str(addr.clone())),
                        ("input_dim", info.input_dim.into()),
                        ("output_dim", info.output_dim.into()),
                    ])
                })
                .collect(),
        )
    }

    /// (Re)establish any missing link. A reconnected link starts a fresh
    /// sequence space, which the host accepts because connection state is
    /// per-connection on its side too.
    fn ensure_connected(&mut self) -> Result<()> {
        for k in 0..self.addrs.len() {
            if self.links[k].is_some() {
                continue;
            }
            let (client, info, t) =
                Self::connect_one(&self.addrs[k], k, self.addrs.len(), &self.cfg)?;
            if info.input_dim != self.shards[k].input_dim
                || info.output_dim != self.shards[k].output_dim
                || t != self.timesteps
            {
                bail!(
                    "shard-host {k} at {} changed shape across reconnect \
                     ({}→{} in, {}→{} out)",
                    self.addrs[k],
                    self.shards[k].input_dim,
                    info.input_dim,
                    self.shards[k].output_dim,
                    info.output_dim
                );
            }
            self.links[k] = Some(client);
            self.seqs[k] = 0;
        }
        Ok(())
    }

    /// Drop every connection (and its sequence space). Called after any
    /// mid-run failure: partially-executed state behind the links can no
    /// longer be trusted, so the next run starts from fresh connections
    /// (and fresh membrane state via its step-0 resets).
    fn reset_links(&mut self) {
        for l in self.links.iter_mut() {
            *l = None;
        }
        for s in self.seqs.iter_mut() {
            *s = 0;
        }
        for g in self.stats.in_flight.iter() {
            g.store(0, Ordering::Relaxed);
        }
    }

    /// Run one input through the distributed pipeline (fresh output).
    pub fn run(&mut self, input: &SpikeTrain) -> Result<RunOutput> {
        let mut out = RunOutput::default();
        self.run_into(input, &mut out)?;
        Ok(out)
    }

    /// [`crate::accel::Menage::run_into`] semantics across hosts. The
    /// returned [`RunOutput`] carries the classifier train only (the
    /// intermediate layers live on the hosts); `cycles` is bit-identical
    /// to the in-process sharded/monolithic cost model.
    pub fn run_into(&mut self, input: &SpikeTrain, out: &mut RunOutput) -> Result<()> {
        let r = self.run_into_inner(input, out);
        if r.is_err() {
            self.reset_links();
        }
        r
    }

    fn run_into_inner(&mut self, input: &SpikeTrain, out: &mut RunOutput) -> Result<()> {
        if input.num_neurons != self.input_dim() {
            bail!(
                "input has {} neurons, first shard expects {}",
                input.num_neurons,
                self.input_dim()
            );
        }
        self.ensure_connected()?;
        let t_steps = input.timesteps();
        let k_links = self.addrs.len();
        out.trains.resize_with(1, SpikeTrain::default);
        out.trains[0].reset_to(self.output_dim(), t_steps);
        out.cycles = 0;
        if t_steps == 0 {
            return Ok(());
        }

        // Frontiers ready to send per link. Link 0's are the input's own
        // steps; link k>0's arrive as acks from link k−1.
        let mut ready: Vec<VecDeque<(u32, SpikeTrain)>> =
            (0..k_links).map(|_| VecDeque::new()).collect();
        for (t, step) in input.spikes.iter().enumerate() {
            let mut train = SpikeTrain::new(input.num_neurons, 1);
            train.spikes[0] = step.clone();
            ready[0].push_back((t as u32, train));
        }
        // Outstanding (seq, step, sent-at) per link, send order — acks
        // must come back in exactly this order (hosts execute
        // sequentially). The send instant feeds the per-link `wire_us`
        // RTT attribution.
        let mut inflight: Vec<VecDeque<(u64, u32, Instant)>> =
            (0..k_links).map(|_| VecDeque::new()).collect();
        // Per-step max of the shards' cycle deltas — the synchronous
        // clock: chips tick together, the busiest shard sets the step.
        let mut step_max = vec![0u64; t_steps];
        let mut completed = 0usize;

        while completed < t_steps {
            // Send pass: everything ready, every link, while window room
            // lasts. Preferring sends keeps the pipeline as deep as the
            // window allows before the driver ever blocks.
            let mut sent_any = false;
            for k in 0..k_links {
                while inflight[k].len() < self.cfg.window {
                    let Some((step, frontier)) = ready[k].pop_front() else { break };
                    if k > 0 {
                        self.stats.boundary_events[k - 1]
                            .fetch_add(distinct_sources(&frontier.spikes[0]), Ordering::Relaxed);
                    }
                    let seq = self.seqs[k];
                    let frame = ShardStepFrame { seq, step, frontier };
                    self.links[k]
                        .as_mut()
                        .expect("ensure_connected")
                        .send_shard_step(&frame)
                        .with_context(|| self.link_name(k))?;
                    self.seqs[k] += 1;
                    inflight[k].push_back((seq, step, Instant::now()));
                    self.stats.steps_sent[k].fetch_add(1, Ordering::Relaxed);
                    let depth = inflight[k].len() as u64;
                    self.stats.in_flight[k].store(depth, Ordering::Relaxed);
                    self.stats.max_in_flight[k].fetch_max(depth, Ordering::Relaxed);
                    sent_any = true;
                }
            }
            if sent_any {
                continue;
            }
            // Nothing to send: block on the earliest link with outstanding
            // acks (its ack is what unblocks everything downstream).
            let k = (0..k_links)
                .find(|&k| !inflight[k].is_empty())
                .ok_or_else(|| anyhow!("pipeline stalled with no steps in flight"))?;
            // Blocked-wait attribution: the driver has nothing to send and
            // is stalled on this specific link — `wait_us` is the wall
            // time this link's slowness actually costs the pipeline.
            let wait_start = Instant::now();
            let reply = self.links[k]
                .as_mut()
                .expect("ensure_connected")
                .recv_reply_timeout(self.cfg.io_timeout)
                .with_context(|| self.link_name(k))?;
            self.stats.wait_us[k]
                .fetch_add(wait_start.elapsed().as_micros() as u64, Ordering::Relaxed);
            let ack = match reply {
                Some(Reply::ShardAck(a)) => a,
                Some(Reply::Error(e)) => bail!(
                    "{} rejected step: [{}] {}",
                    self.link_name(k),
                    e.code.name(),
                    e.message
                ),
                Some(other) => {
                    bail!("{} sent unexpected reply {other:?}", self.link_name(k))
                }
                None => bail!(
                    "{} sent no SHARD_ACK within {:?} ({} steps outstanding) — host dead or wedged",
                    self.link_name(k),
                    self.cfg.io_timeout,
                    inflight[k].len()
                ),
            };
            let Some(&(exp_seq, exp_step, sent_at)) = inflight[k].front() else {
                bail!("{} acked seq {} with nothing outstanding", self.link_name(k), ack.seq);
            };
            if ack.seq != exp_seq || ack.step != exp_step {
                bail!(
                    "{} acked (seq {}, step {}), expected (seq {exp_seq}, step {exp_step})",
                    self.link_name(k),
                    ack.seq,
                    ack.step
                );
            }
            inflight[k].pop_front();
            self.stats.in_flight[k].store(inflight[k].len() as u64, Ordering::Relaxed);
            // Per-link profile: ack count, host-reported step cycles, and
            // the send→ack RTT (wire + remote compute).
            self.stats.acks[k].fetch_add(1, Ordering::Relaxed);
            self.stats.step_cycles[k].fetch_add(ack.step_cycles, Ordering::Relaxed);
            self.stats.wire_us[k]
                .fetch_add(sent_at.elapsed().as_micros() as u64, Ordering::Relaxed);
            let t = ack.step as usize;
            if t >= t_steps {
                bail!("{} acked step {t} of a {t_steps}-step input", self.link_name(k));
            }
            step_max[t] = step_max[t].max(ack.step_cycles);
            if k + 1 < k_links {
                if ack.frontier.num_neurons != self.shards[k + 1].input_dim {
                    bail!(
                        "{} emitted a {}-neuron frontier, shard {} expects {}",
                        self.link_name(k),
                        ack.frontier.num_neurons,
                        k + 1,
                        self.shards[k + 1].input_dim
                    );
                }
                ready[k + 1].push_back((ack.step, ack.frontier));
            } else {
                out.trains[0].spikes[t] =
                    ack.frontier.spikes.into_iter().next().expect("1-step frontier");
                completed += 1;
            }
        }
        out.cycles = step_max.iter().sum();
        Ok(())
    }

    /// Sequential per-input execution with the lane-call signature, so the
    /// coordinator's lane-packed workers can ride a remote backend. Remote
    /// shard hosts serialize steps per connection anyway, and sequential
    /// execution is bit-identical to lanes by the engine's lane-differential
    /// guarantee — so this does not change results, only overlap.
    pub fn run_lanes_into(
        &mut self,
        inputs: &[SpikeTrain],
        outs: &mut Vec<RunOutput>,
    ) -> Result<()> {
        outs.resize_with(inputs.len(), RunOutput::default);
        for (input, out) in inputs.iter().zip(outs.iter_mut()) {
            self.run_into(input, out)?;
        }
        Ok(())
    }

    fn link_name(&self, k: usize) -> String {
        format!("shard-host {k} at {}", self.addrs[k])
    }
}
