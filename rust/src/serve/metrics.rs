//! Per-request serving metrics: counters, latency distribution, and the
//! JSON snapshot the STATS frame returns.
//!
//! Everything is lock-free (atomics) so the hot ingress/egress paths never
//! contend: latency goes into a fixed-size log₂-bucketed histogram
//! (bounded memory no matter how long the server lives — unlike a
//! retained-sample quantile sketch, which would grow without bound under
//! production traffic), and the percentiles reported over STATS are
//! bucket-resolution estimates, which is plenty for an ops dashboard. The
//! load generator computes *exact* client-side percentiles from its own
//! samples; `BENCH_serve.json` carries those.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::obs::{SlowTraceRing, StageHistograms};
use crate::util::json::Json;

/// Number of log₂ microsecond buckets: bucket `i` counts latencies in
/// `[2^i, 2^(i+1))` µs (bucket 0 is `[0, 2)`), so the top bucket starts at
/// 2³⁹ µs ≈ 6.4 days — effectively unbounded.
const BUCKETS: usize = 40;

/// Fixed-size, lock-free latency histogram (microseconds).
#[derive(Debug)]
pub struct LatencyHistogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_micros: AtomicU64,
    max_micros: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_micros: AtomicU64::new(0),
            max_micros: AtomicU64::new(0),
        }
    }
}

impl LatencyHistogram {
    fn bucket_of(micros: u64) -> usize {
        // 0 and 1 µs land in bucket 0; otherwise floor(log2(v)).
        (63 - micros.max(1).leading_zeros() as usize).min(BUCKETS - 1)
    }

    pub fn record_micros(&self, micros: u64) {
        self.buckets[Self::bucket_of(micros)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_micros.fetch_add(micros, Ordering::Relaxed);
        self.max_micros.fetch_max(micros, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_micros(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return f64::NAN;
        }
        self.sum_micros.load(Ordering::Relaxed) as f64 / n as f64
    }

    pub fn max_micros(&self) -> u64 {
        self.max_micros.load(Ordering::Relaxed)
    }

    /// Bucket-resolution quantile estimate in microseconds (the geometric
    /// midpoint of the bucket holding the rank-`q` sample), clamped to the
    /// observed maximum. `NaN` when empty.
    ///
    /// **Bias direction:** the geometric midpoint of bucket `[2^i, 2^(i+1))`
    /// is `2^(i+0.5)`, so the estimate is at most a factor of √2 off in
    /// either direction — but for samples sitting **exactly on a bucket
    /// boundary** `2^k` (the bucket's lower edge) the bias is strictly
    /// **upward** by that full √2 factor, unless the max-clamp catches it
    /// (which it always does when the rank bucket is also the max bucket —
    /// e.g. a single-valued histogram reports exact quantiles). Upward bias
    /// is the safe direction for an ops dashboard: tail estimates
    /// overstate, never flatter.
    pub fn quantile_micros(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return f64::NAN;
        }
        let rank = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= rank {
                let lo = if i == 0 { 0.0 } else { (1u64 << i) as f64 };
                let hi = (1u64 << (i + 1)) as f64;
                let est = (lo.max(1.0) * hi).sqrt();
                return est.min(self.max_micros() as f64);
            }
        }
        self.max_micros() as f64
    }

    /// The standard JSON summary of one histogram —
    /// `{mean, p50, p90, p99, max, count}`, `null` percentiles when empty.
    /// Used verbatim for the endpoint `latency_us` block and for every
    /// per-stage histogram in the `profile` block, so pollers parse one
    /// shape everywhere.
    pub fn summary_json(&self) -> Json {
        let q = |p: f64| -> Json {
            let v = self.quantile_micros(p);
            if v.is_nan() {
                Json::Null
            } else {
                Json::Num(v)
            }
        };
        Json::obj(vec![
            (
                "mean",
                if self.count() == 0 { Json::Null } else { Json::Num(self.mean_micros()) },
            ),
            ("p50", q(0.50)),
            ("p90", q(0.90)),
            ("p99", q(0.99)),
            ("max", (self.max_micros() as usize).into()),
            ("count", (self.count() as usize).into()),
        ])
    }
}

/// The serving-layer metrics registry. One instance per [`super::Server`],
/// shared by every connection reader, the response router, and the STATS
/// snapshot.
#[derive(Debug, Default)]
pub struct ServeMetrics {
    /// Connections ever accepted.
    pub connections_opened: AtomicU64,
    /// Connections currently open.
    pub connections_active: AtomicU64,
    /// Requests admitted into the coordinator queue.
    pub accepted: AtomicU64,
    /// Responses routed back to a client (includes deadline-expired ones).
    pub completed: AtomicU64,
    /// Requests refused because the in-flight cap was reached.
    pub rejected_overload: AtomicU64,
    /// Requests refused before submission (wrong width, bad payload).
    pub rejected_bad_request: AtomicU64,
    /// Responses that completed after their request's deadline (the client
    /// got `ERROR DeadlineExceeded` instead of the result).
    pub deadline_expired: AtomicU64,
    /// Frame-layer violations (bad magic, truncation, oversized frames).
    pub protocol_errors: AtomicU64,
    /// Errors the simulator workers reported for admitted requests.
    pub worker_errors: AtomicU64,
    /// Responses dropped because their connection had gone away.
    pub dropped_responses: AtomicU64,
    /// Faults injected by the serve-layer chaos knobs (dropped/delayed
    /// responses, socket resets). Always 0 in production; lets the chaos
    /// suite separate injected losses from organic ones.
    pub chaos_injected: AtomicU64,
    /// Input spikes (events) across admitted requests — the event-delivery
    /// throughput the host-side path is sized by.
    pub events_in: AtomicU64,
    /// Modeled accelerator cycles across completed requests.
    pub total_cycles: AtomicU64,
    /// Accept→route latency distribution.
    pub latency: LatencyHistogram,
    /// Per-stage span histograms (admit/queue/dispatch/step/egress) — the
    /// trace-span half of the STATS `profile` block.
    pub stages: StageHistograms,
    /// The K slowest complete traces (tail forensics).
    pub slowest: SlowTraceRing,
}

impl ServeMetrics {
    #[inline]
    pub fn bump(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    pub fn get(counter: &AtomicU64) -> u64 {
        counter.load(Ordering::Relaxed)
    }

    /// Snapshot as JSON — the STATS_REPLY payload. `queue_depth` and
    /// `in_flight` are gauges sampled by the caller (they live on the
    /// coordinator handle and the server's admission counter).
    pub fn to_json(&self, started: Instant, queue_depth: usize, in_flight: usize) -> Json {
        let uptime = started.elapsed().as_secs_f64().max(1e-9);
        let completed = Self::get(&self.completed);
        let events = Self::get(&self.events_in);
        Json::obj(vec![
            ("uptime_s", uptime.into()),
            ("queue_depth", queue_depth.into()),
            ("in_flight", in_flight.into()),
            (
                "counters",
                Json::obj(vec![
                    ("connections_opened", (Self::get(&self.connections_opened) as usize).into()),
                    ("connections_active", (Self::get(&self.connections_active) as usize).into()),
                    ("accepted", (Self::get(&self.accepted) as usize).into()),
                    ("completed", (completed as usize).into()),
                    ("rejected_overload", (Self::get(&self.rejected_overload) as usize).into()),
                    (
                        "rejected_bad_request",
                        (Self::get(&self.rejected_bad_request) as usize).into(),
                    ),
                    ("deadline_expired", (Self::get(&self.deadline_expired) as usize).into()),
                    ("protocol_errors", (Self::get(&self.protocol_errors) as usize).into()),
                    ("worker_errors", (Self::get(&self.worker_errors) as usize).into()),
                    ("dropped_responses", (Self::get(&self.dropped_responses) as usize).into()),
                    ("chaos_injected", (Self::get(&self.chaos_injected) as usize).into()),
                    ("events_in", (events as usize).into()),
                    ("total_cycles", (Self::get(&self.total_cycles) as usize).into()),
                ]),
            ),
            (
                "throughput",
                Json::obj(vec![
                    ("requests_per_s", (completed as f64 / uptime).into()),
                    ("events_per_s", (events as f64 / uptime).into()),
                ]),
            ),
            ("latency_us", self.latency.summary_json()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_and_quantiles() {
        let h = LatencyHistogram::default();
        assert!(h.quantile_micros(0.5).is_nan());
        assert!(h.mean_micros().is_nan());
        // 90 fast (≈100 µs) + 10 slow (≈100 ms) samples.
        for _ in 0..90 {
            h.record_micros(100);
        }
        for _ in 0..10 {
            h.record_micros(100_000);
        }
        assert_eq!(h.count(), 100);
        assert_eq!(h.max_micros(), 100_000);
        let p50 = h.quantile_micros(0.50);
        assert!((64.0..256.0).contains(&p50), "p50 estimate {p50} off-bucket");
        let p99 = h.quantile_micros(0.99);
        assert!((65_536.0..=100_000.0).contains(&p99), "p99 estimate {p99} off-bucket");
        let mean = h.mean_micros();
        assert!((mean - (90.0 * 100.0 + 10.0 * 100_000.0) / 100.0).abs() < 1e-9);
        // Quantiles never exceed the observed max.
        assert!(h.quantile_micros(1.0) <= 100_000.0);
    }

    #[test]
    fn histogram_edge_values() {
        let h = LatencyHistogram::default();
        h.record_micros(0);
        h.record_micros(1);
        h.record_micros(u64::MAX);
        assert_eq!(h.count(), 3);
        assert_eq!(h.max_micros(), u64::MAX);
        assert!(h.quantile_micros(0.0) >= 0.0);
    }

    /// Empty histogram: every percentile and the mean are NaN (rendered as
    /// JSON null by the STATS snapshot), max and count are zero — a fresh
    /// server must not report fabricated latencies.
    #[test]
    fn histogram_empty_percentiles() {
        let h = LatencyHistogram::default();
        for q in [0.0, 0.5, 0.9, 0.99, 1.0] {
            assert!(h.quantile_micros(q).is_nan(), "q={q} on empty histogram");
        }
        assert!(h.mean_micros().is_nan());
        assert_eq!(h.count(), 0);
        assert_eq!(h.max_micros(), 0);
    }

    /// One sample: every quantile collapses to that sample (the bucket
    /// midpoint estimate is clamped to the observed maximum).
    #[test]
    fn histogram_single_sample() {
        let h = LatencyHistogram::default();
        h.record_micros(300);
        for q in [0.0, 0.25, 0.5, 0.99, 1.0] {
            assert_eq!(h.quantile_micros(q), 300.0, "q={q}");
        }
        assert_eq!(h.mean_micros(), 300.0);
        assert_eq!(h.max_micros(), 300);
        assert_eq!(h.count(), 1);
    }

    /// Exact powers of two sit on bucket boundaries: 2^k must land in
    /// bucket k (half-open `[2^k, 2^(k+1))`), quantiles stay monotone in
    /// q, and no estimate exceeds the observed maximum.
    #[test]
    fn histogram_bucket_boundary_values() {
        for k in 0..12u32 {
            let v = 1u64 << k;
            assert_eq!(
                LatencyHistogram::bucket_of(v),
                k as usize,
                "2^{k} must open bucket {k}"
            );
            assert_eq!(
                LatencyHistogram::bucket_of(v.saturating_sub(1).max(1)),
                (k as usize).saturating_sub(1).max(0),
                "2^{k}-1 must close bucket {}",
                (k as usize).saturating_sub(1)
            );
        }
        // 0 and 1 µs share bucket 0.
        assert_eq!(LatencyHistogram::bucket_of(0), 0);
        assert_eq!(LatencyHistogram::bucket_of(1), 0);
        let h = LatencyHistogram::default();
        for k in 0..10u32 {
            h.record_micros(1 << k);
        }
        let qs: Vec<f64> =
            [0.1, 0.3, 0.5, 0.7, 0.9, 1.0].iter().map(|&q| h.quantile_micros(q)).collect();
        for w in qs.windows(2) {
            assert!(w[0] <= w[1], "quantiles must be monotone: {qs:?}");
        }
        for &q in &qs {
            assert!(q <= h.max_micros() as f64);
        }
        assert_eq!(h.count(), 10);
    }

    /// Percentile estimation at exact bucket boundaries. Samples sitting
    /// on a bucket's lower edge (1 µs, 2 µs, any 2^k µs) expose the
    /// estimator's documented upward bias: the geometric-midpoint estimate
    /// is 2^(k+0.5) ≈ √2·2^k, clamped to the observed max — so a
    /// single-valued histogram reports the exact value, and a mixed one
    /// overstates boundary samples by at most √2.
    #[test]
    fn histogram_percentiles_at_bucket_boundaries() {
        // Single-valued at each boundary: max-clamp makes quantiles exact.
        for k in 0..16u32 {
            let v = 1u64 << k; // 1, 2, 4, ..., 2^15 µs
            let h = LatencyHistogram::default();
            for _ in 0..10 {
                h.record_micros(v);
            }
            for q in [0.5, 0.9, 0.99, 1.0] {
                assert_eq!(h.quantile_micros(q), v as f64, "2^{k} µs, q={q}");
            }
        }
        // Mixed: 2^10 boundary samples dominate, one far-max sample defeats
        // the clamp, so p50 shows the raw midpoint — biased UP, within √2.
        let h = LatencyHistogram::default();
        for _ in 0..99 {
            h.record_micros(1 << 10);
        }
        h.record_micros(1 << 20);
        let p50 = h.quantile_micros(0.50);
        let true_val = (1u64 << 10) as f64;
        assert!(p50 >= true_val, "boundary estimate must not understate: {p50}");
        assert!(p50 <= true_val * 2f64.sqrt() + 1e-9, "bias bounded by √2: {p50}");
        // 1 µs is bucket 0's interior (lo clamped to 1): estimate √2,
        // max-clamped back to 1 when 1 µs is also the max.
        let h = LatencyHistogram::default();
        h.record_micros(1);
        assert_eq!(h.quantile_micros(0.5), 1.0);
    }

    #[test]
    fn snapshot_shape() {
        let m = ServeMetrics::default();
        ServeMetrics::bump(&m.completed);
        ServeMetrics::bump(&m.accepted);
        m.events_in.fetch_add(500, Ordering::Relaxed);
        m.latency.record_micros(250);
        let j = m.to_json(Instant::now(), 3, 2);
        assert_eq!(j.get("queue_depth").unwrap().as_usize().unwrap(), 3);
        assert_eq!(j.get("in_flight").unwrap().as_usize().unwrap(), 2);
        let counters = j.get("counters").unwrap();
        assert_eq!(counters.get("completed").unwrap().as_usize().unwrap(), 1);
        assert_eq!(counters.get("events_in").unwrap().as_usize().unwrap(), 500);
        assert!(j.get("throughput").unwrap().get("events_per_s").unwrap().as_f64().unwrap() > 0.0);
        assert!(j.get("latency_us").unwrap().get("p50").unwrap().as_f64().unwrap() > 0.0);
        // Round-trips through the JSON writer/parser (what STATS does).
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }
}
