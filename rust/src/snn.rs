//! Quantized spiking-network containers and the bit-exact reference model.
//!
//! This module owns the data the mapper and the cycle-accurate simulator
//! share: 8-bit quantized, (optionally) pruned synaptic layers stored both
//! densely and in CSR-by-source form (the natural layout for event-driven
//! dispatch — an incoming spike from source neuron `s` walks `row(s)`).
//!
//! It also provides [`reference_forward`], the "Python-level spiking neural
//! network behaviour" that Algorithm 1 (step 4) says the hardware must
//! mimic: a discrete-time LIF network evaluated with the same quantized
//! weights. The accelerator simulator in ideal-analog mode must reproduce
//! it spike-for-spike; equivalence tests in `accel` enforce that.

use anyhow::{bail, Context, Result};

use crate::config::ModelConfig;
use crate::util::rng::Rng;
use crate::util::tensorfile::TensorFile;

/// LIF neuron parameters shared by a layer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LifParams {
    /// Discrete-time leak factor β: `v ← β·v + i`.
    pub beta: f32,
    /// Firing threshold.
    pub v_threshold: f32,
    /// Reset value applied after a spike (reset-to-value, as in the paper's
    /// "membrane potential is reset to V_reset").
    pub v_reset: f32,
}

impl Default for LifParams {
    fn default() -> Self {
        Self { beta: 0.9, v_threshold: 1.0, v_reset: 0.0 }
    }
}

/// Geometry of a 2-D convolutional layer mapped onto the accelerator.
///
/// Source neurons are the flattened `[in_channels][in_h][in_w]` input
/// volume; destination neurons the flattened `[out_channels][out_h][out_w]`
/// output volume. A compressed layer stores one `[oc][ic][kh][kw]` kernel
/// and *generates* each source's synapse row arithmetically (arxiv
/// 2112.07019) instead of materializing the `out_dim × in_dim` table.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvSpec {
    pub in_channels: usize,
    pub in_h: usize,
    pub in_w: usize,
    pub out_channels: usize,
    pub kernel_h: usize,
    pub kernel_w: usize,
    pub stride: usize,
    pub padding: usize,
}

impl ConvSpec {
    pub fn out_h(&self) -> usize {
        (self.in_h + 2 * self.padding - self.kernel_h) / self.stride + 1
    }

    pub fn out_w(&self) -> usize {
        (self.in_w + 2 * self.padding - self.kernel_w) / self.stride + 1
    }

    /// Flattened source count `ic·in_h·in_w`.
    pub fn in_dim(&self) -> usize {
        self.in_channels * self.in_h * self.in_w
    }

    /// Flattened destination count `oc·out_h·out_w`.
    pub fn out_dim(&self) -> usize {
        self.out_channels * self.out_h() * self.out_w()
    }

    /// Stored kernel taps `oc·ic·kh·kw` — the compressed weight footprint.
    pub fn kernel_len(&self) -> usize {
        self.out_channels * self.in_channels * self.kernel_h * self.kernel_w
    }

    pub fn validate(&self) -> Result<()> {
        if self.in_channels == 0
            || self.in_h == 0
            || self.in_w == 0
            || self.out_channels == 0
            || self.kernel_h == 0
            || self.kernel_w == 0
        {
            bail!("conv spec has a zero dimension: {self:?}");
        }
        if self.stride == 0 {
            bail!("conv stride must be ≥ 1");
        }
        if self.in_h + 2 * self.padding < self.kernel_h
            || self.in_w + 2 * self.padding < self.kernel_w
        {
            bail!(
                "kernel {}×{} larger than padded input {}×{}",
                self.kernel_h,
                self.kernel_w,
                self.in_h + 2 * self.padding,
                self.in_w + 2 * self.padding
            );
        }
        Ok(())
    }

    /// Enumerate the non-zero `(dst, w_q)` pairs a spike from `src` reaches,
    /// **in ascending destination order** — the generator that replaces a
    /// MEM_S&N row lookup. Both the reference model and the engine's
    /// generator fetch ([`crate::engine::ConvGen`]) call this, so the
    /// enumeration order is defined in exactly one place.
    ///
    /// Order proof: for fixed `oc`, each valid `ky` yields one output row
    /// `oy = (iy + padding − ky)/stride`, strictly increasing as `ky`
    /// decreases; likewise `kx → ox`. So iterating `oc` ascending, `ky`
    /// descending, `kx` descending emits `dst = (oc·out_h + oy)·out_w + ox`
    /// ascending, and no `(dst, src)` pair is emitted twice.
    pub fn for_each_target(&self, kernel: &[i8], src: usize, mut f: impl FnMut(u32, i8)) {
        if src >= self.in_dim() {
            return;
        }
        let hw = self.in_h * self.in_w;
        let (ic, rem) = (src / hw, src % hw);
        let (iy, ix) = (rem / self.in_w, rem % self.in_w);
        let (out_h, out_w) = (self.out_h(), self.out_w());
        let (py, px) = (iy + self.padding, ix + self.padding);
        for oc in 0..self.out_channels {
            for ky in (0..self.kernel_h).rev() {
                if py < ky || (py - ky) % self.stride != 0 {
                    continue;
                }
                let oy = (py - ky) / self.stride;
                if oy >= out_h {
                    continue;
                }
                for kx in (0..self.kernel_w).rev() {
                    if px < kx || (px - kx) % self.stride != 0 {
                        continue;
                    }
                    let ox = (px - kx) / self.stride;
                    if ox >= out_w {
                        continue;
                    }
                    let w = kernel
                        [((oc * self.in_channels + ic) * self.kernel_h + ky) * self.kernel_w + kx];
                    if w != 0 {
                        f(((oc * out_h + oy) * out_w + ox) as u32, w);
                    }
                }
            }
        }
    }

    /// Logical non-zero synapse count of the expanded matrix: each non-zero
    /// tap `(oc,ic,ky,kx)` contributes one synapse per valid `(oy,ox)` pair
    /// (tap→position pairs never collide, so this equals the expanded
    /// layer's `nnz()` exactly).
    fn expanded_nnz(&self, kernel: &[i8]) -> usize {
        let count = |k: usize, pad: usize, in_len: usize, out_len: usize| {
            (0..out_len)
                .filter(|o| {
                    let p = o * self.stride + k;
                    p >= pad && p - pad < in_len
                })
                .count()
        };
        let ys: Vec<usize> = (0..self.kernel_h)
            .map(|ky| count(ky, self.padding, self.in_h, self.out_h()))
            .collect();
        let xs: Vec<usize> = (0..self.kernel_w)
            .map(|kx| count(kx, self.padding, self.in_w, self.out_w()))
            .collect();
        let mut nnz = 0usize;
        for oc in 0..self.out_channels {
            for ic in 0..self.in_channels {
                for (ky, &cy) in ys.iter().enumerate() {
                    for (kx, &cx) in xs.iter().enumerate() {
                        let w = kernel[((oc * self.in_channels + ic) * self.kernel_h + ky)
                            * self.kernel_w
                            + kx];
                        if w != 0 {
                            nnz += cy * cx;
                        }
                    }
                }
            }
        }
        nnz
    }
}

/// One quantized synaptic layer: `out_dim × in_dim` 8-bit weights plus a
/// scale, so the effective weight is `w_q · scale`.
///
/// Two storage representations share this type:
/// - **dense/CSR** (`weights` + the CSR mirror) — the MLP layout;
/// - **compressed conv** (`conv: Some`, `kernel` non-empty) — one kernel
///   stored once, synapse rows generated on demand via
///   [`ConvSpec::for_each_target`]. `weights`/CSR stay empty.
///
/// A layer produced by [`QuantLayer::expand_conv`] is dense/CSR but keeps
/// `conv: Some(spec)` so the mapper places it identically to its compressed
/// twin — that is what makes the two execution paths bit-comparable.
#[derive(Debug, Clone)]
pub struct QuantLayer {
    pub in_dim: usize,
    pub out_dim: usize,
    /// Dense row-major `[out_dim][in_dim]` quantized weights. Pruned
    /// connections are exactly zero.
    pub weights: Vec<i8>,
    /// Dequantization scale.
    pub scale: f32,
    /// LIF parameters of the destination neurons.
    pub lif: LifParams,
    /// Convolutional geometry, when this layer is a conv layer (compressed
    /// or expanded). `None` for MLP layers.
    pub conv: Option<ConvSpec>,
    /// Compressed conv kernel `[oc][ic][kh][kw]`. Non-empty exactly when
    /// this layer is stored compressed (see [`Self::is_compressed`]).
    pub kernel: Vec<i8>,
    /// Cached logical nnz of a compressed layer (equals the expanded
    /// matrix's nnz; see [`ConvSpec::expanded_nnz`]).
    conv_nnz: usize,
    /// CSR by *source*: `csr_index[s] .. csr_index[s+1]` indexes
    /// `csr_targets` with `(dst, w_q)` pairs — the event-driven layout.
    /// Empty for compressed layers (rows are generated, not stored).
    csr_index: Vec<u32>,
    csr_targets: Vec<(u32, i8)>,
}

impl QuantLayer {
    /// Build from dense weights, deriving the CSR-by-source structure.
    pub fn new(
        in_dim: usize,
        out_dim: usize,
        weights: Vec<i8>,
        scale: f32,
        lif: LifParams,
    ) -> Result<Self> {
        if weights.len() != in_dim * out_dim {
            bail!(
                "weight buffer has {} entries, expected {}×{}",
                weights.len(),
                out_dim,
                in_dim
            );
        }
        if !(scale > 0.0) {
            bail!("scale must be positive, got {scale}");
        }
        let mut layer = Self {
            in_dim,
            out_dim,
            weights,
            scale,
            lif,
            conv: None,
            kernel: vec![],
            conv_nnz: 0,
            csr_index: vec![],
            csr_targets: vec![],
        };
        layer.rebuild_csr();
        Ok(layer)
    }

    /// Build a **compressed** conv layer: one `[oc][ic][kh][kw]` kernel,
    /// no dense/CSR table. Synapse rows are generated on demand.
    pub fn conv2d(spec: ConvSpec, kernel: Vec<i8>, scale: f32, lif: LifParams) -> Result<Self> {
        spec.validate()?;
        if kernel.len() != spec.kernel_len() {
            bail!(
                "kernel buffer has {} entries, expected {} ({}×{}×{}×{})",
                kernel.len(),
                spec.kernel_len(),
                spec.out_channels,
                spec.in_channels,
                spec.kernel_h,
                spec.kernel_w
            );
        }
        if !(scale > 0.0) {
            bail!("scale must be positive, got {scale}");
        }
        let conv_nnz = spec.expanded_nnz(&kernel);
        Ok(Self {
            in_dim: spec.in_dim(),
            out_dim: spec.out_dim(),
            weights: vec![],
            scale,
            lif,
            conv: Some(spec),
            kernel,
            conv_nnz,
            csr_index: vec![],
            csr_targets: vec![],
        })
    }

    /// Whether this layer stores its weights compressed (kernel-only).
    #[inline]
    pub fn is_compressed(&self) -> bool {
        !self.kernel.is_empty()
    }

    /// Weights actually resident in A-SYN SRAM: the kernel taps for a
    /// compressed layer, one entry per non-zero synapse otherwise (what
    /// [`crate::mapping::distill`] emits into `weight_mem`).
    pub fn stored_weights(&self) -> usize {
        if self.is_compressed() {
            self.kernel.len()
        } else {
            self.csr_targets.len()
        }
    }

    /// Densify a compressed conv layer into the `out_dim × in_dim`
    /// dense/CSR representation — the expansion oracle the compressed
    /// execution path is pinned bit-identical against. The result keeps
    /// `conv: Some(spec)` so the mapper places it exactly like the
    /// compressed layer.
    pub fn expand_conv(&self) -> Result<Self> {
        let Some(spec) = self.conv else {
            bail!("expand_conv on a non-conv layer");
        };
        if !self.is_compressed() {
            return Ok(self.clone());
        }
        let mut weights = vec![0i8; self.in_dim * self.out_dim];
        for src in 0..self.in_dim {
            spec.for_each_target(&self.kernel, src, |d, w| {
                weights[d as usize * self.in_dim + src] = w;
            });
        }
        let mut layer = Self::new(self.in_dim, self.out_dim, weights, self.scale, self.lif)?;
        layer.conv = Some(spec);
        Ok(layer)
    }

    /// Dense weight at `(dst, src)` — derived from the kernel for a
    /// compressed layer.
    #[inline]
    pub fn weight(&self, dst: usize, src: usize) -> i8 {
        if self.is_compressed() {
            let spec = self.conv.unwrap();
            let (out_h, out_w) = (spec.out_h(), spec.out_w());
            let (oc, orem) = (dst / (out_h * out_w), dst % (out_h * out_w));
            let (oy, ox) = (orem / out_w, orem % out_w);
            let hw = spec.in_h * spec.in_w;
            let (ic, irem) = (src / hw, src % hw);
            let (iy, ix) = (irem / spec.in_w, irem % spec.in_w);
            let (py, px) = (iy + spec.padding, ix + spec.padding);
            if py < oy * spec.stride || px < ox * spec.stride {
                return 0;
            }
            let (ky, kx) = (py - oy * spec.stride, px - ox * spec.stride);
            if ky >= spec.kernel_h || kx >= spec.kernel_w {
                return 0;
            }
            return self.kernel
                [((oc * spec.in_channels + ic) * spec.kernel_h + ky) * spec.kernel_w + kx];
        }
        self.weights[dst * self.in_dim + src]
    }

    /// Non-zero `(dst, w_q)` pairs for a source neuron — the connection rows
    /// a MEM_S&N lookup returns for one incoming event. Panics on a
    /// compressed layer (rows are generated, not stored — use
    /// [`Self::for_each_target`], which handles both representations).
    #[inline]
    pub fn targets_of(&self, src: usize) -> &[(u32, i8)] {
        let lo = self.csr_index[src] as usize;
        let hi = self.csr_index[src + 1] as usize;
        &self.csr_targets[lo..hi]
    }

    /// Visit the non-zero `(dst, w_q)` pairs for a source neuron in
    /// ascending destination order, for either representation: a CSR slice
    /// walk for dense layers, kernel-generated for compressed ones.
    #[inline]
    pub fn for_each_target(&self, src: usize, mut f: impl FnMut(u32, i8)) {
        if self.is_compressed() {
            self.conv.unwrap().for_each_target(&self.kernel, src, f);
        } else {
            for &(d, w) in self.targets_of(src) {
                f(d, w);
            }
        }
    }

    /// Number of non-zero synapses (logical — identical for a compressed
    /// layer and its expansion).
    pub fn nnz(&self) -> usize {
        if self.is_compressed() {
            self.conv_nnz
        } else {
            self.csr_targets.len()
        }
    }

    /// Fraction of pruned (zero) weights.
    pub fn sparsity(&self) -> f64 {
        1.0 - self.nnz() as f64 / (self.in_dim * self.out_dim) as f64
    }

    /// Fan-out (non-zero out-degree) of a source neuron.
    pub fn fanout(&self, src: usize) -> usize {
        if self.is_compressed() {
            let mut n = 0usize;
            self.for_each_target(src, |_, _| n += 1);
            n
        } else {
            self.targets_of(src).len()
        }
    }

    /// Recompute the CSR mirror after mutating `weights` (e.g. pruning).
    /// Not meaningful for compressed layers (there is no dense buffer).
    pub fn rebuild_csr(&mut self) {
        assert!(!self.is_compressed(), "rebuild_csr on a compressed conv layer");
        let mut index = Vec::with_capacity(self.in_dim + 1);
        let mut targets = Vec::new();
        index.push(0u32);
        for s in 0..self.in_dim {
            for d in 0..self.out_dim {
                let w = self.weights[d * self.in_dim + s];
                if w != 0 {
                    targets.push((d as u32, w));
                }
            }
            index.push(targets.len() as u32);
        }
        self.csr_index = index;
        self.csr_targets = targets;
    }

    /// Prune the smallest-magnitude weights until `frac` of all weights are
    /// zero (global L1 unstructured pruning within the layer).
    pub fn prune_l1(&mut self, frac: f64) {
        assert!(!self.is_compressed(), "prune_l1 on a compressed conv layer");
        assert!((0.0..=1.0).contains(&frac));
        let mut mags: Vec<(u8, usize)> = self
            .weights
            .iter()
            .enumerate()
            .filter(|(_, &w)| w != 0)
            .map(|(i, &w)| (w.unsigned_abs(), i))
            .collect();
        let target_zero = ((self.weights.len() as f64) * frac).round() as usize;
        let already_zero = self.weights.len() - mags.len();
        if target_zero <= already_zero {
            return;
        }
        let to_zero = target_zero - already_zero;
        mags.sort_unstable();
        for &(_, i) in mags.iter().take(to_zero) {
            self.weights[i] = 0;
        }
        self.rebuild_csr();
    }
}

/// A fully quantized, mapped-ready network.
#[derive(Debug, Clone)]
pub struct QuantNetwork {
    pub name: String,
    pub layers: Vec<QuantLayer>,
    /// Time steps the model is evaluated for.
    pub timesteps: usize,
}

impl QuantNetwork {
    /// Layer widths including input: `[in, h1, ..., out]`.
    pub fn layer_sizes(&self) -> Vec<usize> {
        let mut v = vec![self.layers[0].in_dim];
        v.extend(self.layers.iter().map(|l| l.out_dim));
        v
    }

    pub fn input_dim(&self) -> usize {
        self.layers[0].in_dim
    }

    pub fn output_dim(&self) -> usize {
        self.layers.last().unwrap().out_dim
    }

    /// Total non-zero synapses.
    pub fn nnz(&self) -> usize {
        self.layers.iter().map(|l| l.nnz()).sum()
    }

    /// Total dense parameter count.
    pub fn num_params(&self) -> usize {
        self.layers.iter().map(|l| l.in_dim * l.out_dim).sum()
    }

    /// Overall sparsity.
    pub fn sparsity(&self) -> f64 {
        1.0 - self.nnz() as f64 / self.num_params() as f64
    }

    /// Weights actually resident in A-SYN SRAM across all layers (kernel
    /// taps for compressed conv layers, nnz otherwise).
    pub fn stored_weights(&self) -> usize {
        self.layers.iter().map(|l| l.stored_weights()).sum()
    }

    /// Whether any layer is stored compressed.
    pub fn has_compressed(&self) -> bool {
        self.layers.iter().any(|l| l.is_compressed())
    }

    /// Densify every compressed conv layer ([`QuantLayer::expand_conv`]) —
    /// the dense-expansion oracle network for differential tests.
    pub fn expand_convs(&self) -> Result<Self> {
        let layers = self
            .layers
            .iter()
            .map(|l| if l.is_compressed() { l.expand_conv() } else { Ok(l.clone()) })
            .collect::<Result<Vec<_>>>()?;
        Ok(Self { name: self.name.clone(), layers, timesteps: self.timesteps })
    }

    /// Check layer dimensions chain correctly.
    pub fn validate(&self) -> Result<()> {
        if self.layers.is_empty() {
            bail!("{}: no layers", self.name);
        }
        for (i, w) in self.layers.windows(2).enumerate() {
            if w[0].out_dim != w[1].in_dim {
                bail!(
                    "{}: layer {} out_dim {} != layer {} in_dim {}",
                    self.name,
                    i,
                    w[0].out_dim,
                    i + 1,
                    w[1].in_dim
                );
            }
        }
        if self.timesteps == 0 {
            bail!("{}: zero timesteps", self.name);
        }
        Ok(())
    }

    /// Generate a random quantized network for tests/benches: weights are
    /// zero with probability `sparsity`, otherwise uniform in ±[1, 127].
    /// The scale is chosen so a neuron receiving a typical number of spikes
    /// crosses threshold within a few steps (keeps activity alive).
    pub fn random(cfg: &ModelConfig, sparsity: f64, rng: &mut Rng) -> Self {
        let lif = LifParams {
            beta: cfg.beta as f32,
            v_threshold: cfg.v_threshold as f32,
            v_reset: cfg.v_reset as f32,
        };
        let layers = cfg
            .layer_sizes
            .windows(2)
            .map(|w| {
                let (in_dim, out_dim) = (w[0], w[1]);
                let mut weights = vec![0i8; in_dim * out_dim];
                for wq in weights.iter_mut() {
                    if !rng.bernoulli(sparsity) {
                        let mag = rng.range_inclusive(1, 127) as i8;
                        *wq = if rng.bernoulli(0.5) { mag } else { -mag };
                    }
                }
                // Heuristic scale: E[|w|]≈64; expect ~2% of inputs active;
                // aim for sum ≈ threshold so spiking is neither dead nor
                // saturated.
                let expected_active = (in_dim as f32 * 0.02).max(1.0);
                let scale = lif.v_threshold / (64.0 * expected_active);
                QuantLayer::new(in_dim, out_dim, weights, scale, lif).unwrap()
            })
            .collect();
        let net = Self { name: cfg.name.clone(), layers, timesteps: cfg.timesteps };
        net.validate().unwrap();
        net
    }

    /// Generate a random **compressed-conv** network for tests/benches: a
    /// chain of compressed conv layers (kernel taps zero with probability
    /// `sparsity`, otherwise uniform in ±[1, 127]) followed by one dense
    /// classifier head of `classes` outputs. Scales follow the same
    /// keep-activity-alive heuristic as [`Self::random`], driven by the
    /// per-destination fan-in instead of the layer width.
    pub fn random_conv(
        name: &str,
        specs: &[ConvSpec],
        classes: usize,
        timesteps: usize,
        sparsity: f64,
        rng: &mut Rng,
    ) -> Result<Self> {
        if specs.is_empty() {
            bail!("random_conv needs at least one conv spec");
        }
        let lif = LifParams::default();
        let mut random_w = |buf: &mut [i8]| {
            for wq in buf.iter_mut() {
                if !rng.bernoulli(sparsity) {
                    let mag = rng.range_inclusive(1, 127) as i8;
                    *wq = if rng.bernoulli(0.5) { mag } else { -mag };
                }
            }
        };
        let mut layers: Vec<QuantLayer> = Vec::new();
        for spec in specs {
            if let Some(prev) = layers.last() {
                if prev.out_dim != spec.in_dim() {
                    bail!(
                        "conv chain breaks: previous out_dim {} != spec in_dim {}",
                        prev.out_dim,
                        spec.in_dim()
                    );
                }
            }
            let mut kernel = vec![0i8; spec.kernel_len()];
            random_w(&mut kernel);
            // Per-destination fan-in is ic·kh·kw; expect ~15% of the
            // receptive field active per step in an event stream.
            let fan_in = (spec.in_channels * spec.kernel_h * spec.kernel_w) as f32;
            let scale = lif.v_threshold / (64.0 * (fan_in * 0.15).max(1.0));
            layers.push(QuantLayer::conv2d(*spec, kernel, scale, lif)?);
        }
        let head_in = layers.last().unwrap().out_dim;
        let mut weights = vec![0i8; head_in * classes];
        random_w(&mut weights);
        let expected_active = (head_in as f32 * 0.02).max(1.0);
        let scale = lif.v_threshold / (64.0 * expected_active);
        layers.push(QuantLayer::new(head_in, classes, weights, scale, lif)?);
        let net = Self { name: name.to_string(), layers, timesteps };
        net.validate()?;
        Ok(net)
    }

    /// Load a network exported by `python/compile/aot.py` from a `.mtz`
    /// tensor file. Per layer, either a dense tensor `w{i}` (i8 `[out,in]`)
    /// or a compressed conv kernel `k{i}` (i8 `[oc,ic,kh,kw]`) with its
    /// geometry `conv{i}` (i32 `[4]` = in_h, in_w, stride, padding), plus
    /// `scale{i}` (f32 `[1]`); globally `meta_lif` (f32 `[3]` = beta, v_th,
    /// v_reset) and `meta_timesteps` (i32 `[1]`).
    pub fn from_tensorfile(name: &str, tf: &TensorFile) -> Result<Self> {
        let lif_t = tf.get("meta_lif")?.as_f32()?;
        if lif_t.len() != 3 {
            bail!("meta_lif must have 3 entries");
        }
        let lif = LifParams { beta: lif_t[0], v_threshold: lif_t[1], v_reset: lif_t[2] };
        let timesteps = tf.get("meta_timesteps")?.as_i32()?[0] as usize;
        let mut layers = Vec::new();
        for i in 0.. {
            let wname = format!("w{i}");
            let kname = format!("k{i}");
            let scale_of = |tf: &TensorFile| -> Result<f32> {
                Ok(tf
                    .get(&format!("scale{i}"))
                    .with_context(|| format!("scale for layer {i}"))?
                    .as_f32()?[0])
            };
            if tf.tensors.get(&wname).is_some() {
                let wt = tf.get(&wname)?;
                let dims = wt.dims().to_vec();
                if dims.len() != 2 {
                    bail!("{wname} must be 2-D, got {dims:?}");
                }
                layers.push(QuantLayer::new(
                    dims[1],
                    dims[0],
                    wt.as_i8()?.to_vec(),
                    scale_of(tf)?,
                    lif,
                )?);
            } else if tf.tensors.get(&kname).is_some() {
                let kt = tf.get(&kname)?;
                let dims = kt.dims().to_vec();
                if dims.len() != 4 {
                    bail!("{kname} must be 4-D [oc,ic,kh,kw], got {dims:?}");
                }
                let geo = tf
                    .get(&format!("conv{i}"))
                    .with_context(|| format!("conv geometry for layer {i}"))?
                    .as_i32()?
                    .to_vec();
                if geo.len() != 4 || geo.iter().any(|&v| v < 0) {
                    bail!("conv{i} must be 4 non-negative entries [in_h,in_w,stride,padding]");
                }
                let spec = ConvSpec {
                    out_channels: dims[0],
                    in_channels: dims[1],
                    kernel_h: dims[2],
                    kernel_w: dims[3],
                    in_h: geo[0] as usize,
                    in_w: geo[1] as usize,
                    stride: geo[2] as usize,
                    padding: geo[3] as usize,
                };
                layers.push(QuantLayer::conv2d(spec, kt.as_i8()?.to_vec(), scale_of(tf)?, lif)?);
            } else {
                break;
            }
        }
        if layers.is_empty() {
            bail!("tensor file contains no layers (no w0 or k0)");
        }
        let net = Self { name: name.to_string(), layers, timesteps };
        net.validate()?;
        Ok(net)
    }

    /// Export to a `.mtz` tensor file (inverse of [`Self::from_tensorfile`]).
    pub fn to_tensorfile(&self) -> TensorFile {
        use crate::util::tensorfile::Tensor;
        let mut tf = TensorFile::new();
        let lif = self.layers[0].lif;
        tf.insert(
            "meta_lif",
            Tensor::F32 { dims: vec![3], data: vec![lif.beta, lif.v_threshold, lif.v_reset] },
        );
        tf.insert(
            "meta_timesteps",
            Tensor::I32 { dims: vec![1], data: vec![self.timesteps as i32] },
        );
        for (i, l) in self.layers.iter().enumerate() {
            if l.is_compressed() {
                let s = l.conv.unwrap();
                tf.insert(
                    format!("k{i}"),
                    Tensor::I8 {
                        dims: vec![s.out_channels, s.in_channels, s.kernel_h, s.kernel_w],
                        data: l.kernel.clone(),
                    },
                );
                tf.insert(
                    format!("conv{i}"),
                    Tensor::I32 {
                        dims: vec![4],
                        data: vec![
                            s.in_h as i32,
                            s.in_w as i32,
                            s.stride as i32,
                            s.padding as i32,
                        ],
                    },
                );
            } else {
                tf.insert(
                    format!("w{i}"),
                    Tensor::I8 { dims: vec![l.out_dim, l.in_dim], data: l.weights.clone() },
                );
            }
            tf.insert(
                format!("scale{i}"),
                Tensor::F32 { dims: vec![1], data: vec![l.scale] },
            );
        }
        tf
    }
}

/// Wire-decode cap on a train's neuron count — no real model here comes
/// close, and it stops a hostile/corrupt frame from driving huge
/// allocations before validation can reject it.
pub const WIRE_MAX_NEURONS: usize = 1 << 24;

/// Wire-decode cap on a train's timestep count (same rationale).
pub const WIRE_MAX_TIMESTEPS: usize = 1 << 20;

/// Spike activity of one layer over time: `spikes[t]` is the sorted list of
/// neuron indices that fired at step `t`. Index lists (not bitmaps) because
/// event-based activity is sparse — this mirrors what travels between
/// MX-NEURACOREs.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct SpikeTrain {
    pub num_neurons: usize,
    pub spikes: Vec<Vec<u32>>,
}

impl SpikeTrain {
    pub fn new(num_neurons: usize, timesteps: usize) -> Self {
        Self { num_neurons, spikes: vec![Vec::new(); timesteps] }
    }

    /// Random train: each neuron fires independently with probability
    /// `rate` per step (sorted, valid). The canonical synthetic workload
    /// for tests and benches — one definition instead of a copy per file.
    pub fn bernoulli(num_neurons: usize, timesteps: usize, rate: f64, rng: &mut Rng) -> Self {
        let mut st = Self::new(num_neurons, timesteps);
        for step in st.spikes.iter_mut() {
            for i in 0..num_neurons {
                if rng.bernoulli(rate) {
                    step.push(i as u32);
                }
            }
        }
        st
    }

    /// Append a duplicate of every step's events (each source "fires
    /// twice" in the step, with the copies forming an unsorted tail) —
    /// the canonical duplicate-event workload for the coalescing and
    /// ×multiplicity-accounting differential tests, one definition
    /// instead of an inline copy per suite.
    pub fn duplicate_events(&mut self) {
        for step in self.spikes.iter_mut() {
            let extra: Vec<u32> = step.clone();
            step.extend(extra);
        }
    }

    /// Sub-train covering timesteps `range` of this train (same width).
    ///
    /// The canonical chunking helper for streaming sessions: a train
    /// split at arbitrary step boundaries and streamed chunk-by-chunk
    /// re-joins to exactly the original, which is what lets chunked
    /// session runs be compared bit-for-bit against one-shot runs
    /// (`tests/stream_differential.rs`, `menage loadgen --stream`).
    pub fn slice_steps(&self, range: std::ops::Range<usize>) -> SpikeTrain {
        SpikeTrain { num_neurons: self.num_neurons, spikes: self.spikes[range].to_vec() }
    }

    /// Reshape in place for buffer reuse (the allocation-free batch path):
    /// sets the dimensions and empties every step's spike list while
    /// keeping the per-step `Vec` allocations alive.
    pub fn reset_to(&mut self, num_neurons: usize, timesteps: usize) {
        self.num_neurons = num_neurons;
        self.spikes.truncate(timesteps);
        for step in self.spikes.iter_mut() {
            step.clear();
        }
        if self.spikes.len() < timesteps {
            self.spikes.resize_with(timesteps, Vec::new);
        }
    }

    pub fn timesteps(&self) -> usize {
        self.spikes.len()
    }

    /// Total number of spikes.
    pub fn total_spikes(&self) -> usize {
        self.spikes.iter().map(|s| s.len()).sum()
    }

    /// Mean firing rate (spikes per neuron per step).
    pub fn rate(&self) -> f64 {
        if self.num_neurons == 0 || self.spikes.is_empty() {
            return 0.0;
        }
        self.total_spikes() as f64 / (self.num_neurons * self.spikes.len()) as f64
    }

    /// Per-neuron spike counts.
    pub fn counts(&self) -> Vec<u32> {
        let mut c = vec![0u32; self.num_neurons];
        for step in &self.spikes {
            for &n in step {
                c[n as usize] += 1;
            }
        }
        c
    }

    /// The class decision: neuron with the highest spike count (rate code),
    /// ties broken toward the lower index (deterministic).
    pub fn argmax_class(&self) -> usize {
        let c = self.counts();
        let mut best = 0usize;
        for (i, &v) in c.iter().enumerate() {
            if v > c[best] {
                best = i;
            }
        }
        best
    }

    /// Append the wire encoding of this train to `out` (little-endian):
    ///
    /// ```text
    /// u32 num_neurons | u32 timesteps | timesteps × (u32 count, count × u32 index)
    /// ```
    ///
    /// This is the payload format the TCP serving layer's INFER frames
    /// carry (see `serve::protocol`); [`Self::read_wire`] is the inverse.
    pub fn write_wire(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&(self.num_neurons as u32).to_le_bytes());
        out.extend_from_slice(&(self.spikes.len() as u32).to_le_bytes());
        for step in &self.spikes {
            out.extend_from_slice(&(step.len() as u32).to_le_bytes());
            for &n in step {
                out.extend_from_slice(&n.to_le_bytes());
            }
        }
    }

    /// Wire-encoded size in bytes (what [`Self::write_wire`] appends).
    pub fn wire_len(&self) -> usize {
        8 + self.spikes.iter().map(|s| 4 + 4 * s.len()).sum::<usize>()
    }

    /// Decode a train from the front of `buf` (inverse of
    /// [`Self::write_wire`]), returning it plus the bytes consumed.
    ///
    /// Fully validating — a decoded train is safe to hand straight to the
    /// simulator: dimensions are bounded ([`WIRE_MAX_NEURONS`] /
    /// [`WIRE_MAX_TIMESTEPS`]), every step's count fits the remaining
    /// buffer, and indices are strictly ascending and in range (the
    /// [`Self::validate`] invariant, enforced during the single decode
    /// pass). Truncated or malformed input is an error, never a panic.
    pub fn read_wire(buf: &[u8]) -> Result<(Self, usize)> {
        let mut pos = 0usize;
        let mut take_u32 = |what: &str| -> Result<u32> {
            let Some(bytes) = buf.get(pos..pos + 4) else {
                bail!("spike train truncated at {what} (offset {pos})");
            };
            pos += 4;
            Ok(u32::from_le_bytes(bytes.try_into().unwrap()))
        };
        let num_neurons = take_u32("num_neurons")? as usize;
        if num_neurons > WIRE_MAX_NEURONS {
            bail!("num_neurons {num_neurons} exceeds wire cap {WIRE_MAX_NEURONS}");
        }
        let timesteps = take_u32("timesteps")? as usize;
        if timesteps > WIRE_MAX_TIMESTEPS {
            bail!("timesteps {timesteps} exceeds wire cap {WIRE_MAX_TIMESTEPS}");
        }
        // Each claimed step needs at least its 4-byte count field: reject
        // an absurd header before allocating `timesteps` step vectors.
        if buf.len().saturating_sub(8) / 4 < timesteps {
            bail!("spike train truncated: {timesteps} steps claimed, {} bytes", buf.len());
        }
        let mut st = SpikeTrain::new(num_neurons, timesteps);
        for t in 0..timesteps {
            let count = take_u32("step count")? as usize;
            if count > num_neurons {
                bail!("step {t}: {count} spikes for {num_neurons} neurons");
            }
            let step = &mut st.spikes[t];
            step.reserve_exact(count);
            let mut prev: Option<u32> = None;
            for _ in 0..count {
                let n = take_u32("spike index")?;
                if n as usize >= num_neurons {
                    bail!("step {t}: index {n} out of range {num_neurons}");
                }
                if prev.is_some_and(|p| p >= n) {
                    bail!("step {t}: spike indices not strictly sorted");
                }
                prev = Some(n);
                step.push(n);
            }
        }
        Ok((st, pos))
    }

    /// Validate indices are in range, sorted, and unique per step.
    pub fn validate(&self) -> Result<()> {
        for (t, step) in self.spikes.iter().enumerate() {
            for w in step.windows(2) {
                if w[0] >= w[1] {
                    bail!("step {t}: spike indices not strictly sorted");
                }
            }
            if let Some(&last) = step.last() {
                if last as usize >= self.num_neurons {
                    bail!("step {t}: index {last} out of range {}", self.num_neurons);
                }
            }
        }
        Ok(())
    }
}

/// Result of the reference forward pass: the output-layer spike train plus
/// every hidden layer's train (used for per-layer golden checks and for the
/// memory-utilization figures).
#[derive(Debug, Clone)]
pub struct ReferenceOutput {
    /// `trains[l]` is the spike train of layer `l`'s *output* (so
    /// `trains.last()` is the classifier output).
    pub trains: Vec<SpikeTrain>,
}

impl ReferenceOutput {
    pub fn output(&self) -> &SpikeTrain {
        self.trains.last().unwrap()
    }

    pub fn predicted_class(&self) -> usize {
        self.output().argmax_class()
    }
}

/// Bit-exact discrete-time LIF forward pass over quantized weights — the
/// golden model the accelerator must match (Algorithm 1 step 4: "mimic the
/// Python-level spiking neural network behaviour").
///
/// Numerics: membrane update is `v ← β·v + scale·Σ w_q` with f32 arithmetic
/// accumulated in i32 (exact — |Σ w_q| < 2³¹), then one f32 multiply. This
/// is exactly the quantity the C2C ladder + integrator computes in the
/// ideal-analog limit, so simulator equivalence is meaningful.
pub fn reference_forward(net: &QuantNetwork, input: &SpikeTrain) -> Result<ReferenceOutput> {
    if input.num_neurons != net.input_dim() {
        bail!(
            "input has {} neurons, network expects {}",
            input.num_neurons,
            net.input_dim()
        );
    }
    input.validate()?;
    let t_steps = input.timesteps();

    let mut trains: Vec<SpikeTrain> =
        net.layers.iter().map(|l| SpikeTrain::new(l.out_dim, t_steps)).collect();
    // Integer accumulators (per layer, per neuron) and f32 membranes.
    let mut acc: Vec<Vec<i32>> = net.layers.iter().map(|l| vec![0i32; l.out_dim]).collect();
    let mut mem: Vec<Vec<f32>> = net
        .layers
        .iter()
        .map(|l| vec![l.lif.v_reset; l.out_dim])
        .collect();

    for t in 0..t_steps {
        for (li, layer) in net.layers.iter().enumerate() {
            // Gather this step's input spikes for the layer.
            let in_spikes: &[u32] = if li == 0 {
                &input.spikes[t]
            } else {
                // Previous layer's output at the same step: the paper's
                // chained MX-NEURACOREs pass pulses forward within the
                // global time step.
                &trains[li - 1].spikes[t]
            };
            let a = &mut acc[li];
            for &s in in_spikes {
                layer.for_each_target(s as usize, |d, w| {
                    a[d as usize] += w as i32;
                });
            }
            // Membrane update + fire + leak for every neuron.
            let lif = layer.lif;
            let out = &mut trains[li].spikes[t];
            for (n, m) in mem[li].iter_mut().enumerate() {
                let input_current = a[n] as f32 * layer.scale;
                let v = lif.beta * *m + input_current;
                if v >= lif.v_threshold {
                    out.push(n as u32);
                    *m = lif.v_reset;
                } else {
                    *m = v;
                }
                a[n] = 0;
            }
        }
    }
    Ok(ReferenceOutput { trains })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_layer() -> QuantLayer {
        // 3 inputs, 2 outputs.
        // w = [[10, 0, -5], [0, 20, 0]]
        QuantLayer::new(
            3,
            2,
            vec![10, 0, -5, 0, 20, 0],
            0.1,
            LifParams::default(),
        )
        .unwrap()
    }

    #[test]
    fn csr_matches_dense() {
        let l = tiny_layer();
        assert_eq!(l.targets_of(0), &[(0u32, 10i8)]);
        assert_eq!(l.targets_of(1), &[(1u32, 20i8)]);
        assert_eq!(l.targets_of(2), &[(0u32, -5i8)]);
        assert_eq!(l.nnz(), 3);
        assert!((l.sparsity() - 0.5).abs() < 1e-12);
        assert_eq!(l.fanout(0), 1);
        assert_eq!(l.weight(0, 2), -5);
    }

    fn random_kernel(spec: &ConvSpec, sparsity: f64, rng: &mut Rng) -> Vec<i8> {
        let mut kernel = vec![0i8; spec.kernel_len()];
        for w in kernel.iter_mut() {
            if !rng.bernoulli(sparsity) {
                let mag = rng.range_inclusive(1, 127) as i8;
                *w = if rng.bernoulli(0.5) { mag } else { -mag };
            }
        }
        kernel
    }

    #[test]
    fn conv_generator_matches_expansion() {
        let mut rng = Rng::new(42);
        for (stride, padding) in [(1, 0), (1, 1), (2, 1), (3, 2)] {
            let spec = ConvSpec {
                in_channels: 2,
                in_h: 6,
                in_w: 5,
                out_channels: 3,
                kernel_h: 3,
                kernel_w: 3,
                stride,
                padding,
            };
            let kernel = random_kernel(&spec, 0.3, &mut rng);
            let compressed =
                QuantLayer::conv2d(spec, kernel, 0.01, LifParams::default()).unwrap();
            let expanded = compressed.expand_conv().unwrap();
            assert!(compressed.is_compressed() && !expanded.is_compressed());
            assert_eq!(expanded.conv, Some(spec), "oracle keeps the spec for mapping");
            assert_eq!(compressed.nnz(), expanded.nnz(), "s{stride} p{padding}");
            for src in 0..spec.in_dim() {
                let mut gen: Vec<(u32, i8)> = Vec::new();
                compressed.for_each_target(src, |d, w| gen.push((d, w)));
                assert!(
                    gen.windows(2).all(|p| p[0].0 < p[1].0),
                    "generator must emit ascending dsts (src {src})"
                );
                assert_eq!(gen.as_slice(), expanded.targets_of(src), "src {src}");
            }
            for dst in 0..spec.out_dim() {
                for src in 0..spec.in_dim() {
                    assert_eq!(compressed.weight(dst, src), expanded.weight(dst, src));
                }
            }
        }
    }

    #[test]
    fn conv_reference_matches_expanded_oracle() {
        let mut rng = Rng::new(7);
        let specs = [
            ConvSpec {
                in_channels: 2,
                in_h: 8,
                in_w: 8,
                out_channels: 4,
                kernel_h: 3,
                kernel_w: 3,
                stride: 2,
                padding: 1,
            },
            ConvSpec {
                in_channels: 4,
                in_h: 4,
                in_w: 4,
                out_channels: 4,
                kernel_h: 3,
                kernel_w: 3,
                stride: 1,
                padding: 1,
            },
        ];
        let net = QuantNetwork::random_conv("conv-ref", &specs, 5, 6, 0.2, &mut rng).unwrap();
        let oracle = net.expand_convs().unwrap();
        assert_eq!(net.nnz(), oracle.nnz());
        assert!(net.stored_weights() < oracle.stored_weights());
        let input = SpikeTrain::bernoulli(net.input_dim(), net.timesteps, 0.25, &mut rng);
        let a = reference_forward(&net, &input).unwrap();
        let b = reference_forward(&oracle, &input).unwrap();
        assert_eq!(a.trains, b.trains);
    }

    #[test]
    fn conv_tensorfile_roundtrips() {
        let mut rng = Rng::new(12);
        let spec = ConvSpec {
            in_channels: 2,
            in_h: 6,
            in_w: 6,
            out_channels: 3,
            kernel_h: 3,
            kernel_w: 3,
            stride: 2,
            padding: 1,
        };
        let net = QuantNetwork::random_conv("conv-rt", &[spec], 4, 5, 0.3, &mut rng).unwrap();
        let back = QuantNetwork::from_tensorfile("conv-rt", &net.to_tensorfile()).unwrap();
        assert_eq!(back.layers.len(), net.layers.len());
        assert_eq!(back.layers[0].conv, Some(spec));
        assert_eq!(back.layers[0].kernel, net.layers[0].kernel);
        assert_eq!(back.layers[0].nnz(), net.layers[0].nnz());
        assert_eq!(back.layers[1].weights, net.layers[1].weights);
        assert_eq!(back.timesteps, net.timesteps);
    }

    #[test]
    fn conv_spec_rejects_bad_geometry() {
        let good = ConvSpec {
            in_channels: 1,
            in_h: 4,
            in_w: 4,
            out_channels: 1,
            kernel_h: 3,
            kernel_w: 3,
            stride: 1,
            padding: 0,
        };
        assert!(good.validate().is_ok());
        assert!(ConvSpec { stride: 0, ..good }.validate().is_err());
        assert!(ConvSpec { in_channels: 0, ..good }.validate().is_err());
        assert!(ConvSpec { kernel_h: 9, ..good }.validate().is_err());
        // Kernel buffer must match the spec.
        assert!(QuantLayer::conv2d(good, vec![0; 5], 0.1, LifParams::default()).is_err());
        assert!(QuantLayer::conv2d(good, vec![0; 9], -1.0, LifParams::default()).is_err());
    }

    #[test]
    #[should_panic(expected = "compressed")]
    fn prune_on_compressed_panics() {
        let spec = ConvSpec {
            in_channels: 1,
            in_h: 3,
            in_w: 3,
            out_channels: 1,
            kernel_h: 2,
            kernel_w: 2,
            stride: 1,
            padding: 0,
        };
        let mut l = QuantLayer::conv2d(spec, vec![1; 4], 0.1, LifParams::default()).unwrap();
        l.prune_l1(0.5);
    }

    #[test]
    fn layer_rejects_bad_dims() {
        assert!(QuantLayer::new(3, 2, vec![0; 5], 0.1, LifParams::default()).is_err());
        assert!(QuantLayer::new(3, 2, vec![0; 6], -1.0, LifParams::default()).is_err());
    }

    #[test]
    fn prune_l1_removes_smallest() {
        let mut l = QuantLayer::new(
            2,
            2,
            vec![1, -2, 3, -4],
            0.1,
            LifParams::default(),
        )
        .unwrap();
        l.prune_l1(0.5);
        assert_eq!(l.weights, vec![0, 0, 3, -4]);
        assert_eq!(l.nnz(), 2);
        // Idempotent at same fraction.
        l.prune_l1(0.5);
        assert_eq!(l.nnz(), 2);
        // Full prune.
        l.prune_l1(1.0);
        assert_eq!(l.nnz(), 0);
    }

    #[test]
    fn spike_train_reset_to_reuses_and_clears() {
        let mut st = SpikeTrain::new(4, 3);
        st.spikes[0] = vec![0, 2];
        st.spikes[2] = vec![1];
        st.reset_to(6, 2);
        assert_eq!(st.num_neurons, 6);
        assert_eq!(st.timesteps(), 2);
        assert_eq!(st.total_spikes(), 0);
        st.reset_to(6, 5);
        assert_eq!(st.timesteps(), 5);
        assert_eq!(st.total_spikes(), 0);
    }

    #[test]
    fn bernoulli_train_is_valid() {
        let mut rng = crate::util::rng::Rng::new(5);
        let st = SpikeTrain::bernoulli(20, 10, 0.3, &mut rng);
        st.validate().unwrap();
        assert!(st.total_spikes() > 0);
        assert_eq!(st.timesteps(), 10);
        assert_eq!(st.num_neurons, 20);
    }

    #[test]
    fn spike_train_stats() {
        let mut st = SpikeTrain::new(4, 3);
        st.spikes[0] = vec![0, 2];
        st.spikes[1] = vec![2];
        st.spikes[2] = vec![1, 2, 3];
        st.validate().unwrap();
        assert_eq!(st.total_spikes(), 6);
        assert_eq!(st.rate(), 0.5);
        assert_eq!(st.counts(), vec![1, 1, 3, 1]);
        assert_eq!(st.argmax_class(), 2);
    }

    #[test]
    fn spike_train_validation() {
        let mut st = SpikeTrain::new(3, 1);
        st.spikes[0] = vec![2, 1];
        assert!(st.validate().is_err()); // unsorted
        st.spikes[0] = vec![1, 1];
        assert!(st.validate().is_err()); // duplicate
        st.spikes[0] = vec![3];
        assert!(st.validate().is_err()); // out of range
        st.spikes[0] = vec![0, 2];
        assert!(st.validate().is_ok());
    }

    #[test]
    fn wire_roundtrips() {
        let mut rng = crate::util::rng::Rng::new(9);
        for (n, t, rate) in [(1usize, 1usize, 1.0), (30, 6, 0.3), (100, 12, 0.0), (7, 0, 0.5)] {
            let st = SpikeTrain::bernoulli(n, t, rate, &mut rng);
            let mut buf = vec![0xAAu8; 3]; // nonzero prefix: encoding appends
            st.write_wire(&mut buf);
            assert_eq!(buf.len() - 3, st.wire_len());
            let (back, consumed) = SpikeTrain::read_wire(&buf[3..]).unwrap();
            assert_eq!(consumed, st.wire_len());
            assert_eq!(back, st);
        }
    }

    /// Property: any Bernoulli train (including degenerate 0-neuron and
    /// 0-step shapes) wire-round-trips exactly, `wire_len` matches the
    /// encoder, and every strict truncation errors instead of panicking.
    #[test]
    fn prop_wire_roundtrip_randomized() {
        crate::util::prop::check("spiketrain-wire-roundtrip", |rng| {
            let n = rng.below(120); // 0..=119 neurons
            let t = rng.below(16); // 0..=15 steps
            let rate = rng.f64();
            let st = SpikeTrain::bernoulli(n, t, rate, rng);
            let mut buf = Vec::new();
            st.write_wire(&mut buf);
            if buf.len() != st.wire_len() {
                return Err(format!("wire_len {} != encoded {}", st.wire_len(), buf.len()));
            }
            let (back, consumed) =
                SpikeTrain::read_wire(&buf).map_err(|e| format!("decode failed: {e}"))?;
            if consumed != buf.len() {
                return Err(format!("consumed {consumed} of {}", buf.len()));
            }
            if back != st {
                return Err("round-trip changed the train".to_string());
            }
            back.validate().map_err(|e| format!("decoded train invalid: {e}"))?;
            // A random strict truncation must be a clean error.
            if !buf.is_empty() {
                let cut = rng.below(buf.len());
                if SpikeTrain::read_wire(&buf[..cut]).is_ok() {
                    return Err(format!("truncation at {cut}/{} decoded", buf.len()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn wire_decode_consumes_prefix_only() {
        let mut rng = crate::util::rng::Rng::new(10);
        let st = SpikeTrain::bernoulli(20, 4, 0.4, &mut rng);
        let mut buf = Vec::new();
        st.write_wire(&mut buf);
        buf.extend_from_slice(&[1, 2, 3, 4, 5]); // trailing bytes untouched
        let (back, consumed) = SpikeTrain::read_wire(&buf).unwrap();
        assert_eq!(back, st);
        assert_eq!(consumed, buf.len() - 5);
    }

    #[test]
    fn wire_rejects_malformed() {
        let mut rng = crate::util::rng::Rng::new(11);
        let st = SpikeTrain::bernoulli(16, 3, 0.5, &mut rng);
        let mut good = Vec::new();
        st.write_wire(&mut good);
        // Truncations at every length must error, never panic.
        for cut in 0..good.len() {
            assert!(SpikeTrain::read_wire(&good[..cut]).is_err(), "cut at {cut}");
        }
        // Out-of-range index.
        let mut bad = Vec::new();
        let mut big = SpikeTrain::new(4, 1);
        big.spikes[0] = vec![1, 9];
        big.write_wire(&mut bad);
        assert!(SpikeTrain::read_wire(&bad).is_err());
        // Unsorted / duplicate indices.
        let mut dup = SpikeTrain::new(8, 1);
        dup.spikes[0] = vec![3, 3];
        let mut bad = Vec::new();
        dup.write_wire(&mut bad);
        assert!(SpikeTrain::read_wire(&bad).is_err());
        // count > num_neurons.
        let mut bad = Vec::new();
        bad.extend_from_slice(&2u32.to_le_bytes()); // 2 neurons
        bad.extend_from_slice(&1u32.to_le_bytes()); // 1 step
        bad.extend_from_slice(&3u32.to_le_bytes()); // 3 spikes claimed
        bad.extend_from_slice(&[0; 12]);
        assert!(SpikeTrain::read_wire(&bad).is_err());
        // Absurd dimension headers rejected before allocation.
        let mut bad = Vec::new();
        bad.extend_from_slice(&u32::MAX.to_le_bytes());
        bad.extend_from_slice(&1u32.to_le_bytes());
        assert!(SpikeTrain::read_wire(&bad).is_err());
        let mut bad = Vec::new();
        bad.extend_from_slice(&4u32.to_le_bytes());
        bad.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(SpikeTrain::read_wire(&bad).is_err());
    }

    #[test]
    fn argmax_tie_breaks_low() {
        let mut st = SpikeTrain::new(3, 2);
        st.spikes[0] = vec![1, 2];
        st.spikes[1] = vec![1, 2];
        assert_eq!(st.argmax_class(), 1);
    }

    fn single_neuron_net(beta: f32, th: f32, w: i8, scale: f32, t: usize) -> QuantNetwork {
        QuantNetwork {
            name: "single".into(),
            layers: vec![QuantLayer::new(
                1,
                1,
                vec![w],
                scale,
                LifParams { beta, v_threshold: th, v_reset: 0.0 },
            )
            .unwrap()],
            timesteps: t,
        }
    }

    #[test]
    fn reference_integrates_and_fires() {
        // w·scale = 0.4 per spike, β = 1 (no leak), threshold 1.0:
        // continuous input spikes → fires on step 2 (0.4, 0.8, 1.2→fire) etc.
        let net = single_neuron_net(1.0, 1.0, 40, 0.01, 6);
        let mut input = SpikeTrain::new(1, 6);
        for t in 0..6 {
            input.spikes[t] = vec![0];
        }
        let out = reference_forward(&net, &input).unwrap();
        let fired: Vec<usize> = out.output()
            .spikes
            .iter()
            .enumerate()
            .filter(|(_, s)| !s.is_empty())
            .map(|(t, _)| t)
            .collect();
        assert_eq!(fired, vec![2, 5], "v accumulates 0.4/step, fires at 1.2 then resets");
    }

    #[test]
    fn reference_leak_prevents_firing() {
        // With strong leak the neuron never reaches threshold.
        let net = single_neuron_net(0.1, 1.0, 40, 0.01, 20);
        let mut input = SpikeTrain::new(1, 20);
        for t in 0..20 {
            input.spikes[t] = vec![0];
        }
        let out = reference_forward(&net, &input).unwrap();
        assert_eq!(out.output().total_spikes(), 0);
        // v converges to 0.4/(1-0.1) ≈ 0.444 < 1.
    }

    #[test]
    fn inhibitory_weights_suppress() {
        // Two inputs: +0.6 and -0.6 per step cancel.
        let net = QuantNetwork {
            name: "inhib".into(),
            layers: vec![QuantLayer::new(
                2,
                1,
                vec![60, -60],
                0.01,
                LifParams { beta: 1.0, v_threshold: 1.0, v_reset: 0.0 },
            )
            .unwrap()],
            timesteps: 10,
        };
        let mut input = SpikeTrain::new(2, 10);
        for t in 0..10 {
            input.spikes[t] = vec![0, 1];
        }
        let out = reference_forward(&net, &input).unwrap();
        assert_eq!(out.output().total_spikes(), 0);
    }

    #[test]
    fn multilayer_propagation() {
        // Layer 1 fires every 2nd step; layer 2 sees those spikes.
        let l1 = QuantLayer::new(
            1,
            1,
            vec![50],
            0.01,
            LifParams { beta: 1.0, v_threshold: 1.0, v_reset: 0.0 },
        )
        .unwrap();
        let l2 = QuantLayer::new(
            1,
            1,
            vec![127],
            0.01,
            LifParams { beta: 1.0, v_threshold: 1.0, v_reset: 0.0 },
        )
        .unwrap();
        let net = QuantNetwork { name: "two".into(), layers: vec![l1, l2], timesteps: 8 };
        net.validate().unwrap();
        let mut input = SpikeTrain::new(1, 8);
        for t in 0..8 {
            input.spikes[t] = vec![0];
        }
        let out = reference_forward(&net, &input).unwrap();
        // l1 fires when 0.5k >= 1 -> steps 1,3,5,7 (k=2,4,..).
        assert_eq!(out.trains[0].total_spikes(), 4);
        // l2 receives 1.27 at those steps -> fires same step.
        assert_eq!(out.trains[1].total_spikes(), 4);
    }

    #[test]
    fn reference_rejects_dim_mismatch() {
        let net = single_neuron_net(0.9, 1.0, 1, 0.1, 2);
        let input = SpikeTrain::new(3, 2);
        assert!(reference_forward(&net, &input).is_err());
    }

    #[test]
    fn random_network_is_valid_and_tensorfile_roundtrips() {
        let cfg = ModelConfig {
            name: "t".into(),
            layer_sizes: vec![50, 20, 10],
            timesteps: 5,
            beta: 0.9,
            v_threshold: 1.0,
            v_reset: 0.0,
        };
        let mut rng = Rng::new(1);
        let net = QuantNetwork::random(&cfg, 0.5, &mut rng);
        assert_eq!(net.num_params(), 50 * 20 + 20 * 10);
        assert!(net.sparsity() > 0.4 && net.sparsity() < 0.6, "{}", net.sparsity());
        let tf = net.to_tensorfile();
        let back = QuantNetwork::from_tensorfile("t", &tf).unwrap();
        assert_eq!(back.layers.len(), net.layers.len());
        assert_eq!(back.timesteps, net.timesteps);
        for (a, b) in back.layers.iter().zip(&net.layers) {
            assert_eq!(a.weights, b.weights);
            assert_eq!(a.scale, b.scale);
        }
    }

    #[test]
    fn from_tensorfile_error_paths() {
        let tf = TensorFile::new();
        assert!(QuantNetwork::from_tensorfile("x", &tf).is_err());
    }
}
