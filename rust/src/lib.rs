//! # MENAGE — Mixed-Signal Event-Driven Neuromorphic Accelerator
//!
//! A full-system reproduction of *"MENAGE: Mixed-Signal Event-Driven
//! Neuromorphic Accelerator for Edge Applications"* (Abdollahi, Kamal,
//! Pedram, 2024).
//!
//! The crate contains every substrate the paper's evaluation depends on:
//!
//! * [`ilp`] — a from-scratch integer linear programming solver (revised
//!   simplex LP relaxation + branch & bound) plus a min-cost-flow fast path
//!   used by the mapping layer.
//! * [`analog`] — behavioural models of the mixed-signal circuits: op-amp
//!   integrator, comparator, C2C capacitor ladder, sample/hold capacitors
//!   with leak (replaces the paper's HSpice runs).
//! * [`snn`] — quantized spiking-network containers (layers, LIF parameters,
//!   pruning masks, spike trains) shared by the mapper and the simulator.
//! * [`datasets`] — synthetic event-stream generators standing in for
//!   N-MNIST and CIFAR10-DVS (see DESIGN.md for the substitution argument).
//! * [`mapping`] — the paper's ILP formulation (eqs. 3–7), heuristic
//!   baselines, and the *distiller* that turns a mapping solution into the
//!   controller memory images (MEM_E2A / MEM_S&N).
//! * [`engine`] — the unified lane-major SoA execution engine: one
//!   definition of the step semantics shared by sequential (L=1) and
//!   lane-batched execution, ideal and non-ideal analog mode.
//! * [`neuracore`] — cycle-accurate MX-NEURACORE simulator: event memory,
//!   polling controller FSM, A-SYN bank, A-NEURON bank with virtual neurons.
//! * [`accel`] — the full chip: a chain of MX-NEURACOREs with inter-core
//!   spike links and a run-to-completion engine.
//! * [`energy`] — the energy/performance model that produces the TOPS/W
//!   numbers of Table II, including the published baseline rows.
//! * [`trace`] — memory-utilization and event traces (Figures 6–7).
//! * [`runtime`] — PJRT bridge that loads the JAX-lowered golden model
//!   (`artifacts/*.hlo.txt`) and executes it from rust.
//! * [`shard`] — multi-chip pipeline-parallel sharding: the layer chain is
//!   split across several chips by an ILP/DP partitioner that minimizes
//!   inter-shard spike traffic, with boundary frontiers forwarded
//!   chip-to-chip per time step, bit-identical to monolithic execution.
//! * [`coordinator`] — the thin L3 driver: async inference request loop,
//!   batching across simulator workers, metrics.
//! * [`obs`] — the observability plane: per-request trace spans (admit/
//!   queue/dispatch/step/egress histograms + a ring of the K slowest
//!   traces) and the live per-core/per-shard execution profile behind
//!   the STATS `profile` block and `menage top`.
//! * [`serve`] — the network layer: a std-only TCP inference server whose
//!   per-connection readers feed the coordinator's shared queue (micro-
//!   batching across sockets), with admission control, per-request
//!   deadlines, a wire-protocol client library, and a metrics registry.
//! * [`config`] — TOML-backed accelerator / model / run configuration with
//!   the paper's Accel₁ and Accel₂ presets.
//!
//! See `DESIGN.md` for the experiment index and `EXPERIMENTS.md` for
//! paper-vs-measured results.

pub mod accel;
pub mod analog;
pub mod bench;
pub mod config;
pub mod coordinator;
pub mod datasets;
pub mod energy;
pub mod engine;
pub mod fault;
pub mod ilp;
pub mod mapping;
pub mod neuracore;
pub mod obs;
pub mod runtime;
pub mod serve;
pub mod shard;
pub mod snn;
pub mod trace;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
