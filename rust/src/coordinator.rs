//! L3 coordinator: the inference service wrapped around the simulator.
//!
//! MENAGE's contribution is the hardware architecture, so the coordinator
//! is deliberately thin (per the architecture brief): process lifecycle, a
//! multi-worker request loop with batching, metrics, and the golden-model
//! cross-check. tokio is not available in the offline vendor set, so the
//! runtime is std::thread workers + a shared queue — an arrangement that is
//! arguably better suited to a CPU-bound simulator anyway (no async I/O on
//! the hot path).
//!
//! Scheduling: requests go into one shared work-stealing queue
//! (`Mutex<VecDeque>` + condvar — no extra deps) from which every idle
//! worker pulls. Unlike the previous round-robin assignment, one slow
//! sample can no longer idle the other W−1 workers while their private
//! queues sit empty: whoever finishes first steals the next request.
//!
//! Topology:
//!
//! ```text
//!            requests                       results
//!   client ───────────► [shared deque] ──────────► client
//!                        ▲ steal  ▲ steal
//!              ┌─────────┼────────┼───────┐
//!          [worker 0] [worker 1] … [worker W-1]
//!           Menage      Menage       Menage      (one chip clone each)
//! ```
//!
//! Consumption: [`Coordinator::drain`] blocks for everything in flight and
//! returns submission order; [`Coordinator::run_batch_streaming`] yields
//! responses in *completion* order as they arrive.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::accel::Menage;
use crate::snn::SpikeTrain;
use crate::util::stats::Summary;

/// One inference request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub input: SpikeTrain,
    /// Optional ground-truth label (accuracy accounting).
    pub label: Option<usize>,
}

/// One inference response.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub predicted: usize,
    /// Modeled on-accelerator cycles.
    pub cycles: u64,
    /// Wall-clock simulation latency.
    pub sim_latency: Duration,
    pub label: Option<usize>,
}

/// Aggregated service metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    pub completed: AtomicU64,
    pub correct: AtomicU64,
    pub labelled: AtomicU64,
    /// Simulated cycles across completed requests.
    pub total_cycles: AtomicU64,
    pub latency: Mutex<Summary>,
}

impl Metrics {
    pub fn accuracy(&self) -> f64 {
        let l = self.labelled.load(Ordering::Relaxed);
        if l == 0 {
            return f64::NAN;
        }
        self.correct.load(Ordering::Relaxed) as f64 / l as f64
    }

    pub fn throughput(&self, elapsed: Duration) -> f64 {
        self.completed.load(Ordering::Relaxed) as f64 / elapsed.as_secs_f64().max(1e-9)
    }
}

/// The shared work-stealing queue: pending requests plus the shutdown
/// latch, guarded by one mutex; the condvar wakes idle workers.
struct SharedQueue {
    state: Mutex<QueueState>,
    available: Condvar,
}

struct QueueState {
    jobs: VecDeque<Request>,
    /// When set, workers exit once the queue is empty (pending jobs are
    /// still drained first).
    shutdown: bool,
}

impl SharedQueue {
    fn new() -> Self {
        Self {
            state: Mutex::new(QueueState { jobs: VecDeque::new(), shutdown: false }),
            available: Condvar::new(),
        }
    }

    /// Block until a job is available (returns `None` on shutdown with an
    /// empty queue).
    fn steal(&self) -> Option<Request> {
        let mut s = self.state.lock().unwrap();
        loop {
            if let Some(req) = s.jobs.pop_front() {
                return Some(req);
            }
            if s.shutdown {
                return None;
            }
            s = self.available.wait(s).unwrap();
        }
    }

    fn push(&self, req: Request) {
        self.state.lock().unwrap().jobs.push_back(req);
        self.available.notify_one();
    }

    fn shutdown(&self) {
        self.state.lock().unwrap().shutdown = true;
        self.available.notify_all();
    }
}

/// Multi-worker inference service over cloned [`Menage`] chips with a
/// shared work-stealing request queue (module docs).
pub struct Coordinator {
    workers: Vec<JoinHandle<Menage>>,
    queue: Arc<SharedQueue>,
    results_rx: Receiver<Result<Response>>,
    pub metrics: Arc<Metrics>,
    next_id: u64,
    in_flight: usize,
    started: Instant,
}

impl Coordinator {
    /// Spawn `num_workers` workers, each owning a clone of `chip`, all
    /// pulling from one shared queue.
    pub fn new(chip: &Menage, num_workers: usize) -> Self {
        assert!(num_workers > 0);
        let metrics = Arc::new(Metrics::default());
        let queue = Arc::new(SharedQueue::new());
        let (results_tx, results_rx) = mpsc::channel::<Result<Response>>();
        let mut workers = Vec::with_capacity(num_workers);
        for _ in 0..num_workers {
            let results_tx = results_tx.clone();
            let metrics = Arc::clone(&metrics);
            let queue = Arc::clone(&queue);
            let mut chip = chip.clone();
            workers.push(std::thread::spawn(move || {
                let mut out = crate::accel::RunOutput::default();
                while let Some(req) = queue.steal() {
                    let t0 = Instant::now();
                    let res = chip.run_into(&req.input, &mut out).map(|()| {
                        let predicted = out.predicted_class();
                        let sim_latency = t0.elapsed();
                        metrics.completed.fetch_add(1, Ordering::Relaxed);
                        metrics
                            .total_cycles
                            .fetch_add(out.cycles, Ordering::Relaxed);
                        if let Some(label) = req.label {
                            metrics.labelled.fetch_add(1, Ordering::Relaxed);
                            if label == predicted {
                                metrics.correct.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                        metrics
                            .latency
                            .lock()
                            .unwrap()
                            .add(sim_latency.as_secs_f64());
                        Response {
                            id: req.id,
                            predicted,
                            cycles: out.cycles,
                            sim_latency,
                            label: req.label,
                        }
                    });
                    if results_tx.send(res).is_err() {
                        break; // coordinator dropped
                    }
                }
                chip
            }));
        }
        Self {
            workers,
            queue,
            results_rx,
            metrics,
            next_id: 0,
            in_flight: 0,
            started: Instant::now(),
        }
    }

    /// Submit a request to the shared queue (any idle worker will pick it
    /// up). Returns its id.
    pub fn submit(&mut self, input: SpikeTrain, label: Option<usize>) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push(Request { id, input, label });
        self.in_flight += 1;
        id
    }

    /// Number of submitted requests whose responses have not been received.
    pub fn in_flight(&self) -> usize {
        self.in_flight
    }

    /// Block until one result is available. A received `Err` still counts
    /// as a consumed in-flight request (so a failed sample cannot make
    /// [`Self::drain`] wait forever).
    pub fn recv(&mut self) -> Result<Response> {
        let res = self
            .results_rx
            .recv()
            .map_err(|_| anyhow!("all workers terminated"))?;
        // Decrement before propagating a worker error: the request is done
        // either way.
        self.in_flight -= 1;
        res
    }

    /// Drain all in-flight requests, returning them in submission order.
    pub fn drain(&mut self) -> Result<Vec<Response>> {
        let mut out = Vec::with_capacity(self.in_flight);
        while self.in_flight > 0 {
            out.push(self.recv()?);
        }
        out.sort_by_key(|r| r.id);
        Ok(out)
    }

    /// Submit a whole labelled batch and wait for every result (submission
    /// order).
    pub fn run_batch(
        &mut self,
        inputs: Vec<(SpikeTrain, Option<usize>)>,
    ) -> Result<Vec<Response>> {
        for (input, label) in inputs {
            self.submit(input, label);
        }
        self.drain()
    }

    /// Submit a whole labelled batch and return an iterator that yields
    /// each response **as it completes** (completion order, not submission
    /// order) — lets the caller stream results while slow samples are
    /// still in flight. Dropping the iterator leaves the remaining
    /// responses in flight; [`Self::drain`] collects them.
    pub fn run_batch_streaming(
        &mut self,
        inputs: Vec<(SpikeTrain, Option<usize>)>,
    ) -> StreamingResults<'_> {
        for (input, label) in inputs {
            self.submit(input, label);
        }
        StreamingResults { coordinator: self }
    }

    /// Requests/sec since construction.
    pub fn throughput(&self) -> f64 {
        self.metrics.throughput(self.started.elapsed())
    }

    /// Shut down workers (pending requests are still processed) and return
    /// their chips (with accumulated stats).
    pub fn shutdown(mut self) -> Vec<Menage> {
        self.queue.shutdown();
        std::mem::take(&mut self.workers)
            .into_iter()
            .map(|h| h.join().expect("worker panicked"))
            .collect()
    }
}

impl Drop for Coordinator {
    /// A coordinator dropped without [`Coordinator::shutdown`] must not
    /// leave workers parked on the condvar forever: raise the shutdown
    /// latch so they drain the queue and exit on their own (they are not
    /// joined here).
    fn drop(&mut self) {
        self.queue.shutdown();
    }
}

/// Completion-order response stream over everything currently in flight
/// (see [`Coordinator::run_batch_streaming`]).
pub struct StreamingResults<'a> {
    coordinator: &'a mut Coordinator,
}

impl Iterator for StreamingResults<'_> {
    type Item = Result<Response>;

    fn next(&mut self) -> Option<Self::Item> {
        if self.coordinator.in_flight == 0 {
            None
        } else {
            Some(self.coordinator.recv())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analog::AnalogParams;
    use crate::config::{AcceleratorConfig, ModelConfig};
    use crate::mapping::Strategy;
    use crate::snn::{reference_forward, QuantNetwork};
    use crate::util::rng::Rng;

    fn test_chip() -> (Menage, QuantNetwork) {
        let mcfg = ModelConfig {
            name: "c".into(),
            layer_sizes: vec![30, 16, 8],
            timesteps: 6,
            beta: 0.9,
            v_threshold: 1.0,
            v_reset: 0.0,
        };
        let mut cfg = AcceleratorConfig::accel1();
        cfg.num_cores = 2;
        cfg.a_neurons_per_core = 4;
        cfg.a_syns_per_core = 4;
        cfg.virtual_per_a_neuron = 4;
        let mut rng = Rng::new(8);
        let net = QuantNetwork::random(&mcfg, 0.5, &mut rng);
        let chip =
            Menage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 2).unwrap();
        (chip, net)
    }

    fn inputs(n: usize) -> Vec<(SpikeTrain, Option<usize>)> {
        (0..n)
            .map(|s| {
                let mut rng = Rng::new(1000 + s as u64);
                let mut st = SpikeTrain::new(30, 6);
                for step in st.spikes.iter_mut() {
                    for i in 0..30 {
                        if rng.bernoulli(0.25) {
                            step.push(i as u32);
                        }
                    }
                }
                (st, Some(s % 8))
            })
            .collect()
    }

    #[test]
    fn batch_completes_and_orders() {
        let (chip, _) = test_chip();
        let mut coord = Coordinator::new(&chip, 3);
        let res = coord.run_batch(inputs(20)).unwrap();
        assert_eq!(res.len(), 20);
        for (i, r) in res.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert!(r.predicted < 8);
            assert!(r.cycles > 0);
        }
        assert_eq!(coord.metrics.completed.load(Ordering::Relaxed), 20);
        assert!(coord.throughput() > 0.0);
        let chips = coord.shutdown();
        assert_eq!(chips.len(), 3);
        let total: u64 = chips.iter().map(|c| c.inputs_processed).sum();
        assert_eq!(total, 20);
    }

    #[test]
    fn results_match_reference_regardless_of_worker() {
        let (chip, net) = test_chip();
        let mut coord = Coordinator::new(&chip, 4);
        let ins = inputs(12);
        let golden: Vec<usize> = ins
            .iter()
            .map(|(st, _)| reference_forward(&net, st).unwrap().predicted_class())
            .collect();
        let res = coord.run_batch(ins).unwrap();
        for (r, g) in res.iter().zip(&golden) {
            assert_eq!(r.predicted, *g, "request {}", r.id);
        }
        coord.shutdown();
    }

    #[test]
    fn metrics_accuracy_accounting() {
        let (chip, net) = test_chip();
        let mut coord = Coordinator::new(&chip, 2);
        // Label every input with the reference prediction → accuracy 1.0.
        let ins: Vec<(SpikeTrain, Option<usize>)> = inputs(10)
            .into_iter()
            .map(|(st, _)| {
                let label = reference_forward(&net, &st).unwrap().predicted_class();
                (st, Some(label))
            })
            .collect();
        coord.run_batch(ins).unwrap();
        assert_eq!(coord.metrics.accuracy(), 1.0);
        assert_eq!(coord.metrics.labelled.load(Ordering::Relaxed), 10);
        let lat = coord.metrics.latency.lock().unwrap().clone();
        assert_eq!(lat.count(), 10);
        coord.shutdown();
    }

    /// Build one very heavy input (many busy timesteps) and `n` light ones.
    fn skewed_inputs(n: usize) -> Vec<(SpikeTrain, Option<usize>)> {
        // The heavy sample must dominate even a single-vCPU scheduler's
        // timeslice (~1500 busy steps vs 2 per light sample), so the other
        // worker always drains a light request before it finishes.
        let heavy = {
            let mut rng = Rng::new(77);
            let mut st = SpikeTrain::new(30, 1500);
            for step in st.spikes.iter_mut() {
                for i in 0..30 {
                    if rng.bernoulli(0.5) {
                        step.push(i as u32);
                    }
                }
            }
            (st, Some(0))
        };
        let mut v = vec![heavy];
        for s in 0..n {
            let mut rng = Rng::new(2000 + s as u64);
            let mut st = SpikeTrain::new(30, 2);
            for step in st.spikes.iter_mut() {
                for i in 0..30 {
                    if rng.bernoulli(0.1) {
                        step.push(i as u32);
                    }
                }
            }
            v.push((st, Some(0)));
        }
        v
    }

    /// With heterogeneous per-sample latencies and >1 worker, streaming
    /// yields light samples while the heavy one (submitted first) is still
    /// running — completion order ≠ submission order — while a subsequent
    /// drain()-based batch still returns submission order.
    #[test]
    fn streaming_yields_completion_order_drain_yields_submission_order() {
        let (chip, _) = test_chip();
        let mut coord = Coordinator::new(&chip, 2);

        let completion: Vec<u64> = coord
            .run_batch_streaming(skewed_inputs(8))
            .map(|r| r.unwrap().id)
            .collect();
        assert_eq!(completion.len(), 9);
        let mut sorted = completion.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..9).collect::<Vec<u64>>(), "all ids accounted for");
        // The heavy request has id 0 and was submitted first; a second
        // worker finishes (several) light samples long before it.
        assert_ne!(
            completion[0], 0,
            "heavy sample finished first — streaming produced submission order"
        );
        assert_eq!(coord.in_flight(), 0);

        // Same skewed workload through the blocking API: submission order.
        let res = coord.run_batch(skewed_inputs(8)).unwrap();
        let ids: Vec<u64> = res.iter().map(|r| r.id).collect();
        assert_eq!(ids, (9..18).collect::<Vec<u64>>(), "drain must sort by id");
        coord.shutdown();
    }

    /// A worker error (wrong input width) must still decrement the
    /// in-flight count, so drain() terminates and the coordinator stays
    /// usable afterwards.
    #[test]
    fn worker_error_does_not_leak_in_flight() {
        let (chip, _) = test_chip();
        let mut coord = Coordinator::new(&chip, 2);
        coord.submit(SpikeTrain::new(99, 6), None); // wrong width → Err
        assert_eq!(coord.in_flight(), 1);
        assert!(coord.recv().is_err());
        assert_eq!(coord.in_flight(), 0, "recv leaked in_flight on Err");
        // drain() over an empty in-flight set returns immediately.
        assert!(coord.drain().unwrap().is_empty());
        // And the service still works.
        let res = coord.run_batch(inputs(4)).unwrap();
        assert_eq!(res.len(), 4);
        // Mixed batch: drain propagates the error but does not over-wait.
        coord.submit(SpikeTrain::new(99, 6), None);
        for (st, l) in inputs(3) {
            coord.submit(st, l);
        }
        assert!(coord.drain().is_err());
        let leftover = coord.drain().unwrap().len();
        assert!(leftover <= 3, "over-waited: {leftover}");
        coord.shutdown();
    }

    #[test]
    fn single_worker_is_deterministic() {
        let (chip, _) = test_chip();
        let run = |chip: &Menage| {
            let mut coord = Coordinator::new(chip, 1);
            let res = coord.run_batch(inputs(6)).unwrap();
            coord.shutdown();
            res.iter().map(|r| (r.predicted, r.cycles)).collect::<Vec<_>>()
        };
        assert_eq!(run(&chip), run(&chip));
    }
}
