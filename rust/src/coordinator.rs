//! L3 coordinator: the inference service wrapped around the simulator.
//!
//! MENAGE's contribution is the hardware architecture, so the coordinator
//! is deliberately thin (per the architecture brief): process lifecycle, a
//! multi-worker request loop with batching, metrics, and the golden-model
//! cross-check. tokio is not available in the offline vendor set, so the
//! runtime is std::thread workers + a shared queue — an arrangement that is
//! arguably better suited to a CPU-bound simulator anyway (no async I/O on
//! the hot path).
//!
//! Scheduling: requests go into one shared work-stealing queue
//! (`Mutex<VecDeque>` + condvar — no extra deps) from which every idle
//! worker pulls. Unlike the previous round-robin assignment, one slow
//! sample can no longer idle the other W−1 workers while their private
//! queues sit empty: whoever finishes first steals the next request.
//!
//! **Lane packing** ([`Coordinator::with_lanes`]): instead of scaling
//! concurrency by cloning whole chips (W workers ⇒ W copies of the model
//! images), each worker steals up to L requests at a time and runs them as
//! SIMD-style lanes through [`Menage::run_lanes`] — a W×L grid of
//! (worker, lane) slots over only W model copies, so memory scales as
//! B×state instead of W×model while each shared CSR walk serves every
//! lane. Every stolen request receives exactly one response, including
//! when part of a lane batch fails (per-request `Err`s, never a dropped
//! response — the mid-batch-error regression tests pin this).
//! [`Coordinator::with_lanes_wait`] adds **adaptive packing**: a bounded
//! `fill_wait` (condvar timeout) during which a worker that drained a
//! shallow queue keeps collecting late arrivals before dispatching, so
//! lane batches stay full under trickle traffic.
//!
//! Topology:
//!
//! ```text
//!            requests                       results
//!   client ───────────► [shared deque] ──────────► client
//!                        ▲ steal ≤L  ▲ steal ≤L
//!              ┌─────────┼────────┼───────┐
//!          [worker 0] [worker 1] … [worker W-1]
//!           Menage      Menage       Menage      (one chip clone each,
//!           L lanes     L lanes      L lanes      B = W×L lane slots)
//! ```
//!
//! Consumption: [`Coordinator::drain`] blocks for everything in flight and
//! returns submission order; [`Coordinator::run_batch_streaming`] yields
//! responses in *completion* order as they arrive. `drain` consumes *all*
//! in-flight responses before propagating the first error — otherwise a
//! mid-batch failure would leave stale responses in the channel to be
//! misattributed to the next batch's drain (an ordering violation under
//! lane packing, where one failure arrives alongside many successes). The
//! successes a failing drain consumed stay retrievable via
//! [`Coordinator::take_salvaged_responses`].

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::accel::Menage;
use crate::shard::ShardedMenage;
use crate::snn::SpikeTrain;
use crate::util::stats::Summary;

/// What a worker thread executes requests on: one chip, or a sharded
/// pipeline of chips. Both expose the same run surface (the sharded path
/// is bit-identical to the monolithic one — `tests/shard_differential.rs`)
/// so the scheduling, lane-packing, and error-routing machinery is
/// backend-agnostic.
#[derive(Clone)]
enum Backend {
    Mono(Menage),
    Sharded(ShardedMenage),
}

impl Backend {
    fn input_dim(&self) -> usize {
        match self {
            Backend::Mono(c) => c.cores[0].in_dim(),
            Backend::Sharded(s) => s.input_dim(),
        }
    }

    fn run_into(&mut self, input: &SpikeTrain, out: &mut crate::accel::RunOutput) -> anyhow::Result<()> {
        match self {
            Backend::Mono(c) => c.run_into(input, out),
            Backend::Sharded(s) => s.run_into(input, out),
        }
    }

    fn run_lanes_into(
        &mut self,
        inputs: &[SpikeTrain],
        outs: &mut Vec<crate::accel::RunOutput>,
    ) -> anyhow::Result<()> {
        match self {
            Backend::Mono(c) => c.run_lanes_into(inputs, outs),
            Backend::Sharded(s) => s.run_lanes_into(inputs, outs),
        }
    }

    fn fold_lane_stats(&mut self) {
        match self {
            Backend::Mono(c) => c.fold_lane_stats(),
            Backend::Sharded(s) => s.fold_lane_stats(),
        }
    }

    /// Collapse into the monolithic-shaped stats carrier shutdown hands
    /// back (sharded cores are reassembled in global layer order).
    fn into_chip(self) -> Menage {
        match self {
            Backend::Mono(c) => c,
            Backend::Sharded(s) => s.into_monolithic(),
        }
    }
}

/// One inference request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub input: SpikeTrain,
    /// Optional ground-truth label (accuracy accounting).
    pub label: Option<usize>,
}

/// One inference response.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub predicted: usize,
    /// Modeled on-accelerator cycles.
    pub cycles: u64,
    /// Wall-clock simulation latency. Under lane packing this is the wall
    /// time of the whole lane batch the request rode in — the latency the
    /// request actually experienced (lanes complete together), NOT its
    /// marginal compute cost. Compare per-sample cost across modes with
    /// `cycles` (bit-identical to sequential), not with this field.
    pub sim_latency: Duration,
    pub label: Option<usize>,
    /// The classifier (last-layer) output spike train — what a remote
    /// caller needs to verify bit-identical execution against an
    /// in-process [`Menage::run`] (the serving layer ships it over the
    /// wire). Small: `classes × timesteps` sparse indices.
    pub output: SpikeTrain,
}

/// Aggregated service metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    pub completed: AtomicU64,
    pub correct: AtomicU64,
    pub labelled: AtomicU64,
    /// Simulated cycles across completed requests.
    pub total_cycles: AtomicU64,
    pub latency: Mutex<Summary>,
    /// Worker dispatches (one per batch handed to a chip — a singleton
    /// request and a full lane batch each count once).
    pub dispatches: AtomicU64,
    /// Requests across all dispatches (Σ batch widths); divided by
    /// `dispatches` this is the mean lane occupancy — how full
    /// micro-batches actually run under the live traffic pattern.
    pub lanes_dispatched: AtomicU64,
    /// Widest batch any worker dispatched (≤ `lane_capacity` always).
    pub max_lane_occupancy: AtomicU64,
    /// The configured lanes-per-worker L (set at construction; the bound
    /// the occupancy gauges are read against).
    pub lane_capacity: AtomicU64,
}

impl Metrics {
    /// Mean requests per dispatch (`NaN` before the first dispatch);
    /// bounded by [`Self::lane_capacity`].
    pub fn mean_lane_occupancy(&self) -> f64 {
        let d = self.dispatches.load(Ordering::Relaxed);
        if d == 0 {
            return f64::NAN;
        }
        self.lanes_dispatched.load(Ordering::Relaxed) as f64 / d as f64
    }

    pub fn accuracy(&self) -> f64 {
        let l = self.labelled.load(Ordering::Relaxed);
        if l == 0 {
            return f64::NAN;
        }
        self.correct.load(Ordering::Relaxed) as f64 / l as f64
    }

    pub fn throughput(&self, elapsed: Duration) -> f64 {
        self.completed.load(Ordering::Relaxed) as f64 / elapsed.as_secs_f64().max(1e-9)
    }
}

/// The shared work-stealing queue: pending requests plus the shutdown
/// latch, guarded by one mutex; the condvar wakes idle workers.
struct SharedQueue {
    state: Mutex<QueueState>,
    available: Condvar,
    /// Worker count, used to cap greedy batch steals (see
    /// [`Self::steal_batch`]).
    workers: usize,
    /// Adaptive lane packing: after a steal drains the queue below a full
    /// lane batch, keep the worker parked on the condvar up to this long
    /// collecting late arrivals, so a shallow queue still packs lanes
    /// instead of dispatching singleton batches. Zero = dispatch whatever
    /// was grabbed immediately (the pre-adaptive behaviour).
    fill_wait: Duration,
}

struct QueueState {
    jobs: VecDeque<Request>,
    /// When set, workers exit once the queue is empty (pending jobs are
    /// still drained first).
    shutdown: bool,
}

impl SharedQueue {
    fn new(workers: usize, fill_wait: Duration) -> Self {
        Self {
            state: Mutex::new(QueueState { jobs: VecDeque::new(), shutdown: false }),
            available: Condvar::new(),
            workers,
            fill_wait,
        }
    }

    /// Block until at least one job is available, then grab up to `max`.
    /// Returns `false` on shutdown with an empty queue.
    ///
    /// The grab is capped at the worker's fair share,
    /// `ceil(queued / workers)`: otherwise one worker's L-deep steal
    /// could take a small batch whole while the other W−1 workers sleep
    /// on an empty queue — re-creating exactly the idling the shared
    /// queue exists to prevent.
    ///
    /// **Adaptive packing:** when the initial grab *drained* the queue
    /// without filling the batch (the shallow-queue case — fairness took
    /// nothing from anyone), the worker keeps waiting up to `fill_wait`
    /// for late arrivals, stealing its fair share of each, and dispatches
    /// as soon as the batch is full, the timeout lapses, or shutdown is
    /// raised. Jobs left in the queue by the fair-share cap are *not*
    /// waited on — they belong to the other workers.
    fn steal_batch(&self, max: usize, out: &mut Vec<Request>) -> bool {
        out.clear();
        let mut s = self.state.lock().unwrap();
        loop {
            if !s.jobs.is_empty() {
                let fair = s.jobs.len().div_ceil(self.workers).max(1);
                let grab = max.min(fair);
                while out.len() < grab {
                    match s.jobs.pop_front() {
                        Some(req) => out.push(req),
                        None => break,
                    }
                }
                break;
            }
            if s.shutdown {
                return false;
            }
            s = self.available.wait(s).unwrap();
        }
        if out.len() >= max || self.fill_wait.is_zero() || !s.jobs.is_empty() {
            return true;
        }
        // Shallow queue: collect late arrivals for up to fill_wait.
        let deadline = Instant::now() + self.fill_wait;
        while out.len() < max && !s.shutdown {
            let now = Instant::now();
            let Some(left) = deadline.checked_duration_since(now).filter(|d| !d.is_zero())
            else {
                break;
            };
            let (guard, timeout) = self.available.wait_timeout(s, left).unwrap();
            s = guard;
            // Fair share of whatever arrived while parked.
            let fair = s.jobs.len().div_ceil(self.workers).max(1);
            let grab = (max - out.len()).min(fair);
            for _ in 0..grab {
                match s.jobs.pop_front() {
                    Some(req) => out.push(req),
                    None => break,
                }
            }
            if timeout.timed_out() {
                break;
            }
        }
        true
    }

    fn push(&self, req: Request) {
        self.state.lock().unwrap().jobs.push_back(req);
        self.available.notify_one();
    }

    /// Requests queued but not yet stolen by a worker — the backpressure
    /// signal the serving layer's admission control and STATS report read.
    fn depth(&self) -> usize {
        self.state.lock().unwrap().jobs.len()
    }

    fn shutdown(&self) {
        self.state.lock().unwrap().shutdown = true;
        self.available.notify_all();
    }
}

/// Multi-worker inference service over cloned [`Menage`] chips with a
/// shared work-stealing request queue (module docs).
pub struct Coordinator {
    workers: Vec<JoinHandle<Menage>>,
    queue: Arc<SharedQueue>,
    results_rx: Receiver<Result<Response>>,
    pub metrics: Arc<Metrics>,
    /// Shared with every [`SubmitHandle`] so concurrent submitters (e.g.
    /// the TCP server's per-connection readers) allocate disjoint ids.
    next_id: Arc<AtomicU64>,
    /// Shared with [`SubmitHandle`]s: incremented at submission (from any
    /// thread), decremented by whoever consumes the results channel.
    in_flight: Arc<AtomicUsize>,
    started: Instant,
    /// Successful responses consumed by a failing [`Coordinator::drain`]
    /// (retrievable via [`Coordinator::take_salvaged_responses`] so a
    /// single bad request does not cost the whole batch's results).
    salvaged: Vec<Response>,
}

impl Coordinator {
    /// Spawn `num_workers` workers, each owning a clone of `chip`, all
    /// pulling from one shared queue — one request per worker at a time
    /// (`lanes_per_worker == 1`).
    pub fn new(chip: &Menage, num_workers: usize) -> Self {
        Self::with_lanes(chip, num_workers, 1)
    }

    /// Spawn `num_workers` workers each serving up to `lanes_per_worker`
    /// requests at once as SIMD lanes over its single chip clone (module
    /// docs §Lane packing). Concurrency is W×L request slots with only W
    /// copies of the model images; per-request outputs stay bit-identical
    /// to single-request execution.
    ///
    /// Workers dispatch whatever is queued immediately (`fill_wait` of
    /// zero); use [`Self::with_lanes_wait`] to let a shallow queue pack
    /// fuller lane batches.
    pub fn with_lanes(
        chip: &Menage,
        num_workers: usize,
        lanes_per_worker: usize,
    ) -> Self {
        Self::with_lanes_wait(chip, num_workers, lanes_per_worker, Duration::ZERO)
    }

    /// [`Self::with_lanes`] with **adaptive lane packing**: a worker whose
    /// steal drained the queue below a full lane batch keeps collecting
    /// late arrivals for up to `fill_wait` before dispatching, so a
    /// shallow request stream still amortizes the shared CSR walk across
    /// lanes instead of degenerating into singleton batches. Bounded:
    /// the batch goes out as soon as it is full, the wait lapses, or
    /// shutdown is raised — `fill_wait` is the worst-case added latency
    /// for a lone request, never a liveness hazard.
    pub fn with_lanes_wait(
        chip: &Menage,
        num_workers: usize,
        lanes_per_worker: usize,
        fill_wait: Duration,
    ) -> Self {
        Self::with_backend(Backend::Mono(chip.clone()), num_workers, lanes_per_worker, fill_wait)
    }

    /// [`Self::new`] over a sharded pipeline: each worker owns a clone of
    /// the whole multi-chip [`ShardedMenage`] and serves one request at a
    /// time through it. Outputs are bit-identical to the monolithic
    /// coordinator (`tests/shard_differential.rs`).
    pub fn sharded(chip: &ShardedMenage, num_workers: usize) -> Self {
        Self::sharded_with_lanes_wait(chip, num_workers, 1, Duration::ZERO)
    }

    /// [`Self::with_lanes_wait`] over a sharded pipeline — W workers × L
    /// lanes, every lane flowing through all shards with boundary
    /// frontiers forwarded per (step, lane).
    pub fn sharded_with_lanes_wait(
        chip: &ShardedMenage,
        num_workers: usize,
        lanes_per_worker: usize,
        fill_wait: Duration,
    ) -> Self {
        Self::with_backend(
            Backend::Sharded(chip.clone()),
            num_workers,
            lanes_per_worker,
            fill_wait,
        )
    }

    fn with_backend(
        backend: Backend,
        num_workers: usize,
        lanes_per_worker: usize,
        fill_wait: Duration,
    ) -> Self {
        assert!(num_workers > 0);
        assert!(lanes_per_worker > 0);
        let metrics = Arc::new(Metrics::default());
        metrics.lane_capacity.store(lanes_per_worker as u64, Ordering::Relaxed);
        let queue = Arc::new(SharedQueue::new(num_workers, fill_wait));
        let (results_tx, results_rx) = mpsc::channel::<Result<Response>>();
        let mut workers = Vec::with_capacity(num_workers);
        for _ in 0..num_workers {
            let results_tx = results_tx.clone();
            let metrics = Arc::clone(&metrics);
            let queue = Arc::clone(&queue);
            let mut chip = backend.clone();
            workers.push(std::thread::spawn(move || {
                let record = |out: &crate::accel::RunOutput,
                              req: &Request,
                              sim_latency: Duration|
                 -> Response {
                    let predicted = out.predicted_class();
                    metrics.completed.fetch_add(1, Ordering::Relaxed);
                    metrics.total_cycles.fetch_add(out.cycles, Ordering::Relaxed);
                    if let Some(label) = req.label {
                        metrics.labelled.fetch_add(1, Ordering::Relaxed);
                        if label == predicted {
                            metrics.correct.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    metrics.latency.lock().unwrap().add(sim_latency.as_secs_f64());
                    Response {
                        id: req.id,
                        predicted,
                        cycles: out.cycles,
                        sim_latency,
                        label: req.label,
                        output: out.output().clone(),
                    }
                };
                let mut out = crate::accel::RunOutput::default();
                let mut lane_outs: Vec<crate::accel::RunOutput> = Vec::new();
                let mut batch: Vec<Request> = Vec::new();
                let mut lane_reqs: Vec<Request> = Vec::new();
                let mut inputs: Vec<SpikeTrain> = Vec::new();
                let mut disconnected = false;
                while !disconnected && queue.steal_batch(lanes_per_worker, &mut batch) {
                    if batch.len() == 1 {
                        // Single request: the sequential engine (identical
                        // to the pre-lane coordinator).
                        let req = batch.pop().unwrap();
                        // Occupancy gauges count only valid dispatched
                        // requests — the lane path filters width
                        // mismatches before its gauges, so the singleton
                        // path must too or the metric's meaning would
                        // shift with queue depth.
                        if req.input.num_neurons == chip.input_dim() {
                            metrics.dispatches.fetch_add(1, Ordering::Relaxed);
                            metrics.lanes_dispatched.fetch_add(1, Ordering::Relaxed);
                            metrics.max_lane_occupancy.fetch_max(1, Ordering::Relaxed);
                        }
                        let t0 = Instant::now();
                        let res = chip
                            .run_into(&req.input, &mut out)
                            .map(|()| record(&out, &req, t0.elapsed()))
                            // Every worker error carries the `request {id}:`
                            // prefix (see [`request_id_of_error`]) so a
                            // response router can attribute it.
                            .map_err(|e| anyhow!("request {}: {e:#}", req.id));
                        disconnected = results_tx.send(res).is_err();
                        continue;
                    }
                    // Lane packing. Width mismatches are answered
                    // individually up front so one bad request cannot
                    // poison (or drop responses for) the rest of the
                    // batch.
                    let expect = chip.input_dim();
                    let t0 = Instant::now();
                    lane_reqs.clear();
                    inputs.clear();
                    for mut req in batch.drain(..) {
                        if req.input.num_neurons != expect {
                            let err = anyhow!(
                                "request {}: input has {} neurons, first core expects {expect}",
                                req.id,
                                req.input.num_neurons
                            );
                            disconnected |= results_tx.send(Err(err)).is_err();
                        } else {
                            // Move the train into the lane staging buffer
                            // (no clone); the Request keeps id/label for
                            // the response.
                            inputs.push(std::mem::take(&mut req.input));
                            lane_reqs.push(req);
                        }
                    }
                    if lane_reqs.is_empty() || disconnected {
                        continue;
                    }
                    metrics.dispatches.fetch_add(1, Ordering::Relaxed);
                    metrics
                        .lanes_dispatched
                        .fetch_add(lane_reqs.len() as u64, Ordering::Relaxed);
                    metrics
                        .max_lane_occupancy
                        .fetch_max(lane_reqs.len() as u64, Ordering::Relaxed);
                    match chip.run_lanes_into(&inputs, &mut lane_outs) {
                        Ok(()) => {
                            let sim_latency = t0.elapsed();
                            for (req, o) in lane_reqs.iter().zip(lane_outs.iter()) {
                                let resp = record(o, req, sim_latency);
                                disconnected |= results_tx.send(Ok(resp)).is_err();
                            }
                        }
                        Err(e) => {
                            // One response per request, even on a whole-
                            // batch failure: nothing may be lost.
                            for req in &lane_reqs {
                                let err =
                                    anyhow!("request {}: lane batch failed: {e}", req.id);
                                disconnected |= results_tx.send(Err(err)).is_err();
                            }
                        }
                    }
                }
                // Collapse lane-attributed work into the core totals so
                // the chips handed back by shutdown() report everything
                // they served (merge_chips/energy/trace read core stats).
                chip.fold_lane_stats();
                // Sharded pipelines hand back one monolithic-shaped stats
                // carrier (cores reassembled in global layer order).
                chip.into_chip()
            }));
        }
        Self {
            workers,
            queue,
            results_rx,
            metrics,
            next_id: Arc::new(AtomicU64::new(0)),
            in_flight: Arc::new(AtomicUsize::new(0)),
            started: Instant::now(),
            salvaged: Vec::new(),
        }
    }

    /// Submit a request to the shared queue (any idle worker will pick it
    /// up). Returns its id.
    pub fn submit(&mut self, input: SpikeTrain, label: Option<usize>) -> u64 {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.in_flight.fetch_add(1, Ordering::Relaxed);
        self.queue.push(Request { id, input, label });
        id
    }

    /// A cloneable handle that submits requests into this coordinator's
    /// shared queue from any thread — the ingress hook the TCP serving
    /// layer's per-connection readers use, so requests from many sockets
    /// land in one queue and get micro-batched into lane-packed dispatches
    /// by [`Self::with_lanes_wait`]'s fill-wait workers.
    ///
    /// The handle shares the coordinator's id allocator and in-flight
    /// counter; responses still arrive on the coordinator's results
    /// channel (consume them with [`Self::recv`] / [`Self::recv_timeout`]
    /// / [`Self::drain`], typically from a dedicated router thread).
    pub fn handle(&self) -> SubmitHandle {
        SubmitHandle {
            queue: Arc::clone(&self.queue),
            next_id: Arc::clone(&self.next_id),
            in_flight: Arc::clone(&self.in_flight),
        }
    }

    /// Requests queued but not yet stolen by a worker (the backpressure
    /// introspection hook; see also [`SubmitHandle::queue_depth`]).
    pub fn queue_depth(&self) -> usize {
        self.queue.depth()
    }

    /// Number of submitted requests whose responses have not been received.
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::Relaxed)
    }

    /// One blocking receive. `None` means the results channel is dead (all
    /// workers terminated) — distinct from a worker-sent `Err`, which does
    /// consume an in-flight request.
    fn recv_inner(&mut self) -> Option<Result<Response>> {
        match self.results_rx.recv() {
            Ok(res) => {
                // Decrement before propagating a worker error: the request
                // is done either way.
                self.in_flight.fetch_sub(1, Ordering::Relaxed);
                Some(res)
            }
            Err(_) => None,
        }
    }

    /// Bounded [`Self::recv`]: block up to `timeout` for one result.
    /// `None` means the timeout lapsed with nothing in the channel (not an
    /// error — retry, or check a stop flag, as the serving layer's router
    /// thread does). A dead results channel yields the same terminal error
    /// as [`Self::recv`], with the in-flight count zeroed so caller loops
    /// terminate.
    pub fn recv_timeout(&mut self, timeout: Duration) -> Option<Result<Response>> {
        match self.results_rx.recv_timeout(timeout) {
            Ok(res) => {
                self.in_flight.fetch_sub(1, Ordering::Relaxed);
                Some(res)
            }
            Err(RecvTimeoutError::Timeout) => None,
            Err(RecvTimeoutError::Disconnected) => {
                let n = self.in_flight.swap(0, Ordering::Relaxed);
                Some(Err(anyhow!(
                    "all workers terminated with {n} requests in flight"
                )))
            }
        }
    }

    /// Block until one result is available. A received `Err` still counts
    /// as a consumed in-flight request (so a failed sample cannot make
    /// [`Self::drain`] wait forever). If the results channel is dead (all
    /// workers terminated), nothing in flight can ever arrive: the
    /// in-flight count is zeroed so `recv`/`drain`/streaming loops
    /// terminate instead of yielding the same error forever.
    pub fn recv(&mut self) -> Result<Response> {
        match self.recv_inner() {
            Some(res) => res,
            None => {
                let n = self.in_flight.swap(0, Ordering::Relaxed);
                Err(anyhow!("all workers terminated with {n} requests in flight"))
            }
        }
    }

    /// Drain all in-flight requests, returning them in submission order.
    ///
    /// Every in-flight response is consumed **before** the first error (if
    /// any) is propagated: stopping at the first `Err` would leave the
    /// remaining responses in the channel, where the *next* drain would
    /// collect and misattribute them — under lane packing a single bad
    /// request completes alongside a batch of good ones, making that
    /// ordering violation the common case rather than a corner. On error
    /// the successfully completed responses are not lost: retrieve them
    /// with [`Self::take_salvaged_responses`].
    pub fn drain(&mut self) -> Result<Vec<Response>> {
        let mut out = Vec::with_capacity(self.in_flight());
        let mut first_err = None;
        while self.in_flight() > 0 {
            match self.recv_inner() {
                Some(Ok(r)) => out.push(r),
                Some(Err(e)) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
                None => {
                    // Channel dead: nothing else will ever arrive.
                    if first_err.is_none() {
                        first_err = Some(anyhow!(
                            "all workers terminated with {} requests in flight",
                            self.in_flight()
                        ));
                    }
                    self.in_flight.store(0, Ordering::Relaxed);
                    break;
                }
            }
        }
        out.sort_by_key(|r| r.id);
        if let Some(e) = first_err {
            self.salvaged = out;
            return Err(e);
        }
        // A successful drain invalidates any stale, un-taken salvage from
        // an earlier failure: after this point `take_salvaged_responses`
        // is empty, so old responses can never be misattributed to the
        // batch that just drained cleanly.
        self.salvaged.clear();
        Ok(out)
    }

    /// The successful responses a failing [`Self::drain`] consumed
    /// (submission order). Returns them once, clearing the buffer; a later
    /// failing drain overwrites any un-taken salvage and a *successful*
    /// drain discards it (so this is always empty after a clean drain).
    /// Never mixed into a drain's own results — responses carry their
    /// `id` for attribution.
    pub fn take_salvaged_responses(&mut self) -> Vec<Response> {
        std::mem::take(&mut self.salvaged)
    }

    /// Submit a whole labelled batch and wait for every result (submission
    /// order).
    pub fn run_batch(
        &mut self,
        inputs: Vec<(SpikeTrain, Option<usize>)>,
    ) -> Result<Vec<Response>> {
        for (input, label) in inputs {
            self.submit(input, label);
        }
        self.drain()
    }

    /// Submit a whole labelled batch and return an iterator that yields
    /// each response **as it completes** (completion order, not submission
    /// order) — lets the caller stream results while slow samples are
    /// still in flight. Dropping the iterator leaves the remaining
    /// responses in flight; [`Self::drain`] collects them.
    pub fn run_batch_streaming(
        &mut self,
        inputs: Vec<(SpikeTrain, Option<usize>)>,
    ) -> StreamingResults<'_> {
        for (input, label) in inputs {
            self.submit(input, label);
        }
        StreamingResults { coordinator: self }
    }

    /// Requests/sec since construction.
    pub fn throughput(&self) -> f64 {
        self.metrics.throughput(self.started.elapsed())
    }

    /// Shut down workers (pending requests are still processed) and return
    /// their chips (with accumulated stats).
    pub fn shutdown(mut self) -> Vec<Menage> {
        self.queue.shutdown();
        std::mem::take(&mut self.workers)
            .into_iter()
            .map(|h| h.join().expect("worker panicked"))
            .collect()
    }
}

impl Drop for Coordinator {
    /// A coordinator dropped without [`Coordinator::shutdown`] must not
    /// leave workers parked on the condvar forever: raise the shutdown
    /// latch so they drain the queue and exit on their own (they are not
    /// joined here).
    fn drop(&mut self) {
        self.queue.shutdown();
    }
}

/// Cloneable, thread-safe submission handle into a [`Coordinator`]'s
/// shared queue (see [`Coordinator::handle`]). Lets many producers (e.g.
/// per-connection socket readers) feed one coordinator concurrently while
/// a single router thread consumes the results channel.
///
/// When a producer must publish bookkeeping *before* the request becomes
/// runnable (the serving layer registers a pending-response entry first,
/// so the router can never see a response for an unregistered id), use
/// [`Self::reserve_id`] + [`Self::submit_reserved`]; otherwise
/// [`Self::submit`] does both.
#[derive(Clone)]
pub struct SubmitHandle {
    queue: Arc<SharedQueue>,
    next_id: Arc<AtomicU64>,
    in_flight: Arc<AtomicUsize>,
}

impl SubmitHandle {
    /// Allocate the next request id without enqueueing anything.
    pub fn reserve_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Enqueue a request under an id from [`Self::reserve_id`].
    pub fn submit_reserved(&self, id: u64, input: SpikeTrain, label: Option<usize>) {
        self.in_flight.fetch_add(1, Ordering::Relaxed);
        self.queue.push(Request { id, input, label });
    }

    /// [`Self::reserve_id`] + [`Self::submit_reserved`].
    pub fn submit(&self, input: SpikeTrain, label: Option<usize>) -> u64 {
        let id = self.reserve_id();
        self.submit_reserved(id, input, label);
        id
    }

    /// Requests queued but not yet stolen by a worker (backpressure).
    pub fn queue_depth(&self) -> usize {
        self.queue.depth()
    }

    /// Submitted requests whose responses have not been consumed yet.
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::Relaxed)
    }
}

/// Recover the request id from a worker-sent error. Every error a worker
/// puts on the results channel is prefixed `request <id>: ` (both the
/// single-request and the lane-packed path), which is what lets an
/// id-keyed response router — the TCP serving layer — attribute a failure
/// to the connection that submitted it. Returns `None` for errors that do
/// not originate from a worker (e.g. the all-workers-terminated error).
pub fn request_id_of_error(e: &anyhow::Error) -> Option<u64> {
    let msg = e.root_message();
    let rest = msg.strip_prefix("request ")?;
    let digits: &str = &rest[..rest.find(':')?];
    digits.parse().ok()
}

/// Completion-order response stream over everything currently in flight
/// (see [`Coordinator::run_batch_streaming`]).
pub struct StreamingResults<'a> {
    coordinator: &'a mut Coordinator,
}

impl Iterator for StreamingResults<'_> {
    type Item = Result<Response>;

    fn next(&mut self) -> Option<Self::Item> {
        if self.coordinator.in_flight() == 0 {
            None
        } else {
            Some(self.coordinator.recv())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analog::AnalogParams;
    use crate::config::{AcceleratorConfig, ModelConfig};
    use crate::mapping::Strategy;
    use crate::snn::{reference_forward, QuantNetwork};
    use crate::util::rng::Rng;

    fn test_chip() -> (Menage, QuantNetwork) {
        let mcfg = ModelConfig {
            name: "c".into(),
            layer_sizes: vec![30, 16, 8],
            timesteps: 6,
            beta: 0.9,
            v_threshold: 1.0,
            v_reset: 0.0,
        };
        let mut cfg = AcceleratorConfig::accel1();
        cfg.num_cores = 2;
        cfg.a_neurons_per_core = 4;
        cfg.a_syns_per_core = 4;
        cfg.virtual_per_a_neuron = 4;
        let mut rng = Rng::new(8);
        let net = QuantNetwork::random(&mcfg, 0.5, &mut rng);
        let chip =
            Menage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 2).unwrap();
        (chip, net)
    }

    fn inputs(n: usize) -> Vec<(SpikeTrain, Option<usize>)> {
        (0..n)
            .map(|s| {
                let mut rng = Rng::new(1000 + s as u64);
                let mut st = SpikeTrain::new(30, 6);
                for step in st.spikes.iter_mut() {
                    for i in 0..30 {
                        if rng.bernoulli(0.25) {
                            step.push(i as u32);
                        }
                    }
                }
                (st, Some(s % 8))
            })
            .collect()
    }

    #[test]
    fn batch_completes_and_orders() {
        let (chip, _) = test_chip();
        let mut coord = Coordinator::new(&chip, 3);
        let res = coord.run_batch(inputs(20)).unwrap();
        assert_eq!(res.len(), 20);
        for (i, r) in res.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert!(r.predicted < 8);
            assert!(r.cycles > 0);
        }
        assert_eq!(coord.metrics.completed.load(Ordering::Relaxed), 20);
        assert!(coord.throughput() > 0.0);
        let chips = coord.shutdown();
        assert_eq!(chips.len(), 3);
        let total: u64 = chips.iter().map(|c| c.inputs_processed).sum();
        assert_eq!(total, 20);
    }

    #[test]
    fn results_match_reference_regardless_of_worker() {
        let (chip, net) = test_chip();
        let mut coord = Coordinator::new(&chip, 4);
        let ins = inputs(12);
        let golden: Vec<usize> = ins
            .iter()
            .map(|(st, _)| reference_forward(&net, st).unwrap().predicted_class())
            .collect();
        let res = coord.run_batch(ins).unwrap();
        for (r, g) in res.iter().zip(&golden) {
            assert_eq!(r.predicted, *g, "request {}", r.id);
        }
        coord.shutdown();
    }

    #[test]
    fn metrics_accuracy_accounting() {
        let (chip, net) = test_chip();
        let mut coord = Coordinator::new(&chip, 2);
        // Label every input with the reference prediction → accuracy 1.0.
        let ins: Vec<(SpikeTrain, Option<usize>)> = inputs(10)
            .into_iter()
            .map(|(st, _)| {
                let label = reference_forward(&net, &st).unwrap().predicted_class();
                (st, Some(label))
            })
            .collect();
        coord.run_batch(ins).unwrap();
        assert_eq!(coord.metrics.accuracy(), 1.0);
        assert_eq!(coord.metrics.labelled.load(Ordering::Relaxed), 10);
        let lat = coord.metrics.latency.lock().unwrap().clone();
        assert_eq!(lat.count(), 10);
        coord.shutdown();
    }

    /// Build one very heavy input (many busy timesteps) and `n` light ones.
    fn skewed_inputs(n: usize) -> Vec<(SpikeTrain, Option<usize>)> {
        // The heavy sample must dominate even a single-vCPU scheduler's
        // timeslice (~1500 busy steps vs 2 per light sample), so the other
        // worker always drains a light request before it finishes.
        let mut rng = Rng::new(77);
        let mut v = vec![(SpikeTrain::bernoulli(30, 1500, 0.5, &mut rng), Some(0))];
        for s in 0..n {
            let mut rng = Rng::new(2000 + s as u64);
            v.push((SpikeTrain::bernoulli(30, 2, 0.1, &mut rng), Some(0)));
        }
        v
    }

    /// With heterogeneous per-sample latencies and >1 worker, streaming
    /// yields light samples while the heavy one (submitted first) is still
    /// running — completion order ≠ submission order — while a subsequent
    /// drain()-based batch still returns submission order.
    #[test]
    fn streaming_yields_completion_order_drain_yields_submission_order() {
        let (chip, _) = test_chip();
        let mut coord = Coordinator::new(&chip, 2);

        let completion: Vec<u64> = coord
            .run_batch_streaming(skewed_inputs(8))
            .map(|r| r.unwrap().id)
            .collect();
        assert_eq!(completion.len(), 9);
        let mut sorted = completion.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..9).collect::<Vec<u64>>(), "all ids accounted for");
        // The heavy request has id 0 and was submitted first; a second
        // worker finishes (several) light samples long before it.
        assert_ne!(
            completion[0], 0,
            "heavy sample finished first — streaming produced submission order"
        );
        assert_eq!(coord.in_flight(), 0);

        // Same skewed workload through the blocking API: submission order.
        let res = coord.run_batch(skewed_inputs(8)).unwrap();
        let ids: Vec<u64> = res.iter().map(|r| r.id).collect();
        assert_eq!(ids, (9..18).collect::<Vec<u64>>(), "drain must sort by id");
        coord.shutdown();
    }

    /// A worker error (wrong input width) must still decrement the
    /// in-flight count, so drain() terminates and the coordinator stays
    /// usable afterwards.
    #[test]
    fn worker_error_does_not_leak_in_flight() {
        let (chip, _) = test_chip();
        let mut coord = Coordinator::new(&chip, 2);
        coord.submit(SpikeTrain::new(99, 6), None); // wrong width → Err
        assert_eq!(coord.in_flight(), 1);
        assert!(coord.recv().is_err());
        assert_eq!(coord.in_flight(), 0, "recv leaked in_flight on Err");
        // drain() over an empty in-flight set returns immediately.
        assert!(coord.drain().unwrap().is_empty());
        // And the service still works.
        let res = coord.run_batch(inputs(4)).unwrap();
        assert_eq!(res.len(), 4);
        // Mixed batch: drain consumes *everything* in flight before
        // propagating the error, so nothing is left to leak into (and
        // corrupt the ordering of) the next batch's drain.
        coord.submit(SpikeTrain::new(99, 6), None);
        for (st, l) in inputs(3) {
            coord.submit(st, l);
        }
        assert!(coord.drain().is_err());
        assert_eq!(coord.in_flight(), 0, "drain must consume all in-flight on error");
        // The 3 completed responses are salvageable, not lost…
        let salvaged = coord.take_salvaged_responses();
        assert_eq!(salvaged.len(), 3, "completed responses must be salvageable");
        assert!(salvaged.windows(2).all(|w| w[0].id < w[1].id));
        assert!(coord.take_salvaged_responses().is_empty(), "salvage is take-once");
        // …and never leak into the next drain.
        assert!(coord.drain().unwrap().is_empty(), "stale responses leaked");
        // And the next batch's ids are exactly its own.
        let res = coord.run_batch(inputs(2)).unwrap();
        let first_new_id = res[0].id;
        assert_eq!(
            res.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![first_new_id, first_new_id + 1]
        );
        coord.shutdown();
    }

    /// Lane packing (W×L grid) must produce reference-exact predictions
    /// and the same cycles as sequential execution, with drain returning
    /// submission order.
    #[test]
    fn lane_packed_results_match_reference() {
        let (chip, net) = test_chip();
        let mut plain = Coordinator::new(&chip, 1);
        let baseline: Vec<(usize, u64)> = plain
            .run_batch(inputs(24))
            .unwrap()
            .iter()
            .map(|r| (r.predicted, r.cycles))
            .collect();
        plain.shutdown();

        let mut coord = Coordinator::with_lanes(&chip, 2, 4);
        let ins = inputs(24);
        let golden: Vec<usize> = ins
            .iter()
            .map(|(st, _)| reference_forward(&net, st).unwrap().predicted_class())
            .collect();
        let res = coord.run_batch(ins).unwrap();
        assert_eq!(res.len(), 24);
        for (i, r) in res.iter().enumerate() {
            assert_eq!(r.id, i as u64, "drain must return submission order");
            assert_eq!(r.predicted, golden[i], "request {i}: prediction");
            // Lanes are bit-identical to the sequential engine: modeled
            // cycles match the plain coordinator's regardless of how the
            // requests were packed into (worker, lane) slots.
            assert_eq!((r.predicted, r.cycles), baseline[i], "request {i}: cycles");
        }
        assert_eq!(coord.metrics.completed.load(Ordering::Relaxed), 24);
        let chips = coord.shutdown();
        let total: u64 = chips.iter().map(|c| c.inputs_processed).sum();
        assert_eq!(total, 24);
        // Lane-served work is folded into core stats at shutdown, so the
        // energy/trace consumers (which read core totals) see it.
        let macs: u64 = chips.iter().map(|c| c.total_macs()).sum();
        assert!(macs > 0, "lane work invisible to core stats after shutdown");
    }

    /// Adaptive lane packing: with a bounded fill_wait, a trickle of
    /// requests into a shallow queue still packs into a multi-lane batch
    /// instead of dispatching singletons. Observable via the worker
    /// chip's lane count: a singleton steal takes the worker's
    /// `batch.len() == 1` `run_into` path, which never configures lanes,
    /// so `num_lanes() >= 2` proves a multi-request batch was packed
    /// (lanes never shrink).
    #[test]
    fn fill_wait_packs_shallow_queue_into_lanes() {
        let (chip, _) = test_chip();
        let mut coord =
            Coordinator::with_lanes_wait(&chip, 1, 4, Duration::from_secs(5));
        for (st, l) in inputs(4) {
            coord.submit(st, l);
            // Trickle: the worker steals the first request, drains the
            // queue, and fill-waits while the rest arrive.
            std::thread::sleep(Duration::from_millis(5));
        }
        let res = coord.drain().unwrap();
        assert_eq!(res.len(), 4);
        let chips = coord.shutdown();
        assert!(
            chips[0].cores[0].num_lanes() >= 2,
            "shallow queue dispatched singleton batches despite fill_wait"
        );
    }

    /// fill_wait is a latency bound, not a liveness hazard: shutdown
    /// releases a fill-waiting worker immediately, and the partial batch
    /// it was holding is still processed, not dropped.
    #[test]
    fn fill_wait_releases_on_shutdown() {
        let (chip, _) = test_chip();
        let mut coord =
            Coordinator::with_lanes_wait(&chip, 1, 4, Duration::from_secs(30));
        let (st, l) = inputs(1).pop().unwrap();
        coord.submit(st, l);
        // Give the worker time to steal the request and park in its
        // fill_wait window.
        std::thread::sleep(Duration::from_millis(50));
        let t0 = Instant::now();
        let chips = coord.shutdown();
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "shutdown blocked on fill_wait"
        );
        assert_eq!(chips[0].inputs_processed, 1, "parked request was dropped");
    }

    /// B > worker count: more in-flight requests than workers must pack
    /// into lanes and all complete.
    #[test]
    fn lane_packing_handles_b_greater_than_workers() {
        let (chip, _) = test_chip();
        let mut coord = Coordinator::with_lanes(&chip, 2, 8);
        let res = coord.run_batch(inputs(40)).unwrap();
        assert_eq!(res.len(), 40);
        assert_eq!(coord.in_flight(), 0);
        coord.shutdown();
    }

    /// A worker error mid-batch under lane packing must neither deadlock
    /// nor lose any in-flight response: every request gets exactly one
    /// response, the batch's good samples still complete, and the next
    /// batch is unaffected.
    #[test]
    fn lane_packed_worker_error_mid_batch() {
        let (chip, _) = test_chip();
        let mut coord = Coordinator::with_lanes(&chip, 2, 4);
        // Interleave bad-width requests among good ones so they land in
        // the middle of stolen lane batches.
        let mut expected_good = 0usize;
        for (k, (st, l)) in inputs(10).into_iter().enumerate() {
            if k % 3 == 1 {
                coord.submit(SpikeTrain::new(99, 6), None);
            } else {
                coord.submit(st, l);
                expected_good += 1;
            }
        }
        let submitted = 10;
        assert_eq!(coord.in_flight(), submitted);
        // Streaming yields exactly one item per request (Ok or Err) and
        // terminates — no deadlock, no lost response.
        let items: Vec<Result<Response>> =
            coord.run_batch_streaming(Vec::new()).collect();
        assert_eq!(items.len(), submitted);
        let ok = items.iter().filter(|r| r.is_ok()).count();
        assert_eq!(ok, expected_good, "every valid request must complete");
        assert_eq!(coord.in_flight(), 0);
        // The service stays healthy for the next (clean) batch.
        let res = coord.run_batch(inputs(6)).unwrap();
        assert_eq!(res.len(), 6);
        coord.shutdown();
    }

    /// drain() under lane packing: all in-flight consumed before the first
    /// error propagates; a follow-up drain is empty.
    #[test]
    fn lane_packed_drain_consumes_all_before_error() {
        let (chip, _) = test_chip();
        let mut coord = Coordinator::with_lanes(&chip, 2, 4);
        coord.submit(SpikeTrain::new(99, 6), None);
        for (st, l) in inputs(7) {
            coord.submit(st, l);
        }
        assert!(coord.drain().is_err());
        assert_eq!(coord.in_flight(), 0);
        // The 7 good requests' responses survive via salvage.
        assert_eq!(coord.take_salvaged_responses().len(), 7);
        assert!(coord.drain().unwrap().is_empty());
        coord.shutdown();
    }

    /// Concurrent producers through cloned SubmitHandles: every request
    /// gets exactly one response with a unique id, and the router-side
    /// consumer (recv_timeout) sees them all. This is the serving layer's
    /// ingress pattern — many socket readers, one results consumer.
    #[test]
    fn submit_handles_feed_from_many_threads() {
        let (chip, _) = test_chip();
        let mut coord = Coordinator::with_lanes(&chip, 2, 4);
        let handle = coord.handle();
        let producers: Vec<_> = (0..4)
            .map(|_p| {
                let h = handle.clone();
                std::thread::spawn(move || {
                    let mut ids = Vec::new();
                    for (st, l) in inputs(6) {
                        let id = h.reserve_id();
                        h.submit_reserved(id, st, l);
                        ids.push(id);
                    }
                    ids
                })
            })
            .collect();
        let mut all_ids: Vec<u64> = producers
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect();
        all_ids.sort_unstable();
        assert_eq!(all_ids, (0..24).collect::<Vec<u64>>(), "ids must be disjoint");
        let mut seen = Vec::new();
        while seen.len() < 24 {
            match coord.recv_timeout(Duration::from_secs(10)) {
                Some(Ok(r)) => seen.push(r.id),
                Some(Err(e)) => panic!("worker error: {e}"),
                None => panic!("timed out with {} responses", seen.len()),
            }
        }
        seen.sort_unstable();
        assert_eq!(seen, all_ids);
        assert_eq!(coord.in_flight(), 0);
        assert_eq!(handle.in_flight(), 0);
        assert_eq!(coord.queue_depth(), 0);
        coord.shutdown();
    }

    /// recv_timeout: times out (None) on an idle service without consuming
    /// anything, then yields the response once work completes.
    #[test]
    fn recv_timeout_times_out_then_delivers() {
        let (chip, _) = test_chip();
        let mut coord = Coordinator::new(&chip, 1);
        assert!(coord.recv_timeout(Duration::from_millis(10)).is_none());
        let (st, l) = inputs(1).pop().unwrap();
        coord.submit(st, l);
        let r = coord
            .recv_timeout(Duration::from_secs(10))
            .expect("response within timeout")
            .unwrap();
        assert_eq!(r.id, 0);
        assert_eq!(coord.in_flight(), 0);
        coord.shutdown();
    }

    /// Responses carry the classifier output train, bit-identical to the
    /// reference — the payload the wire protocol ships back to clients.
    #[test]
    fn response_output_train_matches_reference() {
        let (chip, net) = test_chip();
        let mut coord = Coordinator::with_lanes(&chip, 2, 3);
        let ins = inputs(9);
        let golden: Vec<SpikeTrain> = ins
            .iter()
            .map(|(st, _)| reference_forward(&net, st).unwrap().output().clone())
            .collect();
        let res = coord.run_batch(ins).unwrap();
        for (r, g) in res.iter().zip(&golden) {
            assert_eq!(&r.output, g, "request {}: output train", r.id);
        }
        coord.shutdown();
    }

    /// Worker errors are attributable: both the single-request and the
    /// lane-packed path prefix `request <id>:` and the helper parses it.
    #[test]
    fn worker_errors_carry_request_id() {
        let (chip, _) = test_chip();
        // Single-request path (1 lane).
        let mut coord = Coordinator::new(&chip, 1);
        let id = coord.submit(SpikeTrain::new(99, 6), None);
        let e = coord.recv().unwrap_err();
        assert_eq!(request_id_of_error(&e), Some(id), "single path: {e}");
        coord.shutdown();
        // Lane-packed path.
        let mut coord = Coordinator::with_lanes(&chip, 1, 4);
        let mut bad_ids = Vec::new();
        for (k, (st, l)) in inputs(6).into_iter().enumerate() {
            if k % 2 == 0 {
                bad_ids.push(coord.submit(SpikeTrain::new(99, 6), None));
            } else {
                coord.submit(st, l);
            }
        }
        let mut seen_bad = Vec::new();
        for item in coord.run_batch_streaming(Vec::new()) {
            if let Err(e) = item {
                seen_bad.push(request_id_of_error(&e).expect("id-prefixed error"));
            }
        }
        seen_bad.sort_unstable();
        assert_eq!(seen_bad, bad_ids);
        coord.shutdown();
        // Non-worker errors parse to None.
        assert_eq!(request_id_of_error(&anyhow!("all workers terminated")), None);
        assert_eq!(request_id_of_error(&anyhow!("request x: nope")), None);
    }

    /// Lane-occupancy gauges (the STATS follow-up): every dispatch is
    /// counted, the request total matches, and mean/max occupancy are
    /// bounded by the configured lanes-per-worker L.
    #[test]
    fn lane_occupancy_reported_and_bounded() {
        let (chip, _) = test_chip();
        let lanes = 4usize;
        let mut coord = Coordinator::with_lanes(&chip, 2, lanes);
        let res = coord.run_batch(inputs(24)).unwrap();
        assert_eq!(res.len(), 24);
        let m = &coord.metrics;
        assert_eq!(m.lane_capacity.load(Ordering::Relaxed), lanes as u64);
        let d = m.dispatches.load(Ordering::Relaxed);
        assert!(d > 0, "no dispatches recorded");
        assert_eq!(
            m.lanes_dispatched.load(Ordering::Relaxed),
            24,
            "every request must be attributed to exactly one dispatch"
        );
        let mean = m.mean_lane_occupancy();
        assert!(
            (1.0..=lanes as f64).contains(&mean),
            "mean occupancy {mean} outside [1, L={lanes}]"
        );
        let max = m.max_lane_occupancy.load(Ordering::Relaxed);
        assert!(
            (1..=lanes as u64).contains(&max),
            "max occupancy {max} outside [1, L={lanes}]"
        );
        coord.shutdown();
        // An idle coordinator reports NaN mean (no dispatches yet).
        let (chip, _) = test_chip();
        let coord = Coordinator::new(&chip, 1);
        assert!(coord.metrics.mean_lane_occupancy().is_nan());
        coord.shutdown();
    }

    #[test]
    fn single_worker_is_deterministic() {
        let (chip, _) = test_chip();
        let run = |chip: &Menage| {
            let mut coord = Coordinator::new(chip, 1);
            let res = coord.run_batch(inputs(6)).unwrap();
            coord.shutdown();
            res.iter().map(|r| (r.predicted, r.cycles)).collect::<Vec<_>>()
        };
        assert_eq!(run(&chip), run(&chip));
    }
}
