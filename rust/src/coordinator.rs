//! L3 coordinator: the inference service wrapped around the simulator.
//!
//! MENAGE's contribution is the hardware architecture, so the coordinator
//! is deliberately thin (per the architecture brief): process lifecycle, a
//! multi-worker request loop with batching, metrics, and the golden-model
//! cross-check. tokio is not available in the offline vendor set, so the
//! runtime is std::thread workers + a shared queue — an arrangement that is
//! arguably better suited to a CPU-bound simulator anyway (no async I/O on
//! the hot path).
//!
//! Scheduling: requests go into one shared work-stealing queue
//! (`Mutex<VecDeque>` + condvar — no extra deps) from which every idle
//! worker pulls. Unlike the previous round-robin assignment, one slow
//! sample can no longer idle the other W−1 workers while their private
//! queues sit empty: whoever finishes first steals the next request.
//!
//! **Lane packing** ([`Coordinator::with_lanes`]): instead of scaling
//! concurrency by cloning whole chips (W workers ⇒ W copies of the model
//! images), each worker steals up to L requests at a time and runs them as
//! SIMD-style lanes through [`Menage::run_lanes`] — a W×L grid of
//! (worker, lane) slots over only W model copies, so memory scales as
//! B×state instead of W×model while each shared CSR walk serves every
//! lane. Every stolen request receives exactly one response, including
//! when part of a lane batch fails (per-request `Err`s, never a dropped
//! response — the mid-batch-error regression tests pin this).
//! [`Coordinator::with_lanes_wait`] adds **adaptive packing**: a bounded
//! `fill_wait` (condvar timeout) during which a worker that drained a
//! shallow queue keeps collecting late arrivals before dispatching, so
//! lane batches stay full under trickle traffic.
//!
//! Topology:
//!
//! ```text
//!            requests                       results
//!   client ───────────► [shared deque] ──────────► client
//!                        ▲ steal ≤L  ▲ steal ≤L
//!              ┌─────────┼────────┼───────┐
//!          [worker 0] [worker 1] … [worker W-1]
//!           Menage      Menage       Menage      (one chip clone each,
//!           L lanes     L lanes      L lanes      B = W×L lane slots)
//! ```
//!
//! Consumption: [`Coordinator::drain`] blocks for everything in flight and
//! returns submission order; [`Coordinator::run_batch_streaming`] yields
//! responses in *completion* order as they arrive. `drain` consumes *all*
//! in-flight responses before propagating the first error — otherwise a
//! mid-batch failure would leave stale responses in the channel to be
//! misattributed to the next batch's drain (an ordering violation under
//! lane packing, where one failure arrives alongside many successes). The
//! successes a failing drain consumed stay retrievable via
//! [`Coordinator::take_salvaged_responses`].
//!
//! **Worker supervision** ([`Coordinator::heal`]): a worker parks every
//! batch it steals in a per-worker *held slot* (an `Arc<Mutex<Vec<Request>>>`)
//! and removes each request only after its response is on the results
//! channel. If the worker panics — injected via
//! [`Coordinator::inject_worker_panics`] or real — the thread dies with
//! the slot still populated; `heal` (called from every receive path's
//! poll loop) detects the dead thread, salvages the held requests through
//! the poisoned lock, resubmits each at most once (then fails it with a
//! typed, id-prefixed error), and respawns the worker from a pristine
//! backend clone so capacity self-heals. Locking throughout uses
//! [`crate::fault::lock_recover`]: a poisoned mutex is a fact to recover
//! from, not a reason for 40 other threads to cascade-panic. The
//! coordinator keeps a clone of the results sender, so the channel stays
//! open across worker deaths and recovery errors always have somewhere to
//! go; the price is that "all workers terminated" is detected by
//! supervision (`heal` fails queued work when no worker is left) rather
//! than by channel disconnection.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::accel::Menage;
use crate::fault::{lock_recover, recover, RecoveryStats};
use crate::shard::ShardedMenage;
use crate::snn::SpikeTrain;
use crate::util::stats::Summary;

/// Supervision poll period: how long the receive paths block on the
/// results channel between [`Coordinator::heal`] passes.
const HEAL_POLL: Duration = Duration::from_millis(25);

/// What a worker thread executes requests on: one chip, or a sharded
/// pipeline of chips. Both expose the same run surface (the sharded path
/// is bit-identical to the monolithic one — `tests/shard_differential.rs`)
/// so the scheduling, lane-packing, and error-routing machinery is
/// backend-agnostic.
#[derive(Clone)]
pub(crate) enum Backend {
    Mono(Menage),
    Sharded(ShardedMenage),
    /// Shards live in other processes behind `shard-host` listeners; the
    /// worker drives them over TCP ([`crate::serve::RemoteShardPipeline`]).
    /// The chips — and therefore the stats, fault realizations, and
    /// membrane state — are remote, which is why `into_chip` has nothing
    /// local to hand back.
    Remote(crate::serve::RemoteShardPipeline),
}

impl Backend {
    pub(crate) fn input_dim(&self) -> usize {
        match self {
            Backend::Mono(c) => c.cores[0].in_dim(),
            Backend::Sharded(s) => s.input_dim(),
            Backend::Remote(p) => p.input_dim(),
        }
    }

    fn run_into(&mut self, input: &SpikeTrain, out: &mut crate::accel::RunOutput) -> anyhow::Result<()> {
        match self {
            Backend::Mono(c) => c.run_into(input, out),
            Backend::Sharded(s) => s.run_into(input, out),
            Backend::Remote(p) => p.run_into(input, out),
        }
    }

    fn run_lanes_into(
        &mut self,
        inputs: &[SpikeTrain],
        outs: &mut Vec<crate::accel::RunOutput>,
    ) -> anyhow::Result<()> {
        match self {
            Backend::Mono(c) => c.run_lanes_into(inputs, outs),
            Backend::Sharded(s) => s.run_lanes_into(inputs, outs),
            Backend::Remote(p) => p.run_lanes_into(inputs, outs),
        }
    }

    pub(crate) fn fold_lane_stats(&mut self) {
        match self {
            Backend::Mono(c) => c.fold_lane_stats(),
            Backend::Sharded(s) => s.fold_lane_stats(),
            // Remote stats accumulate on the hosts; nothing local to fold.
            Backend::Remote(_) => {}
        }
    }

    /// Open (or recycle) streaming-session lane `lane`: grow the lane grid
    /// if needed and reset exactly that lane's membranes to quiescent,
    /// leaving every other resident session's state untouched. Remote
    /// backends cannot host sessions — the membrane state lives in the
    /// shard-host processes, which the session layer has no way to pin to
    /// one client.
    pub(crate) fn open_session_lane(&mut self, lane: usize) -> anyhow::Result<()> {
        match self {
            Backend::Mono(c) => c.open_session_lane(lane),
            Backend::Sharded(s) => s.open_session_lane(lane),
            Backend::Remote(_) => {
                return Err(anyhow!("remote backends do not host streaming sessions"))
            }
        }
        Ok(())
    }

    /// Fold one session lane's per-lane stats into the core totals. MUST
    /// run before the lane is recycled for another session — an evicted
    /// session's work would otherwise vanish from the energy report and
    /// the profile plane (pinned by `session_eviction_folds_lane_stats`).
    pub(crate) fn fold_session_lane(&mut self, lane: usize) {
        match self {
            Backend::Mono(c) => c.fold_session_lane(lane),
            Backend::Sharded(s) => s.fold_session_lane(lane),
            Backend::Remote(_) => {}
        }
    }

    /// Run one chunk on each of several resident session lanes without
    /// resetting membranes first (suspend/resume). `jobs` must carry
    /// strictly ascending lanes, each previously opened.
    pub(crate) fn run_session_chunks_into(
        &mut self,
        jobs: &[(usize, &SpikeTrain)],
        outs: &mut Vec<crate::accel::RunOutput>,
    ) -> anyhow::Result<()> {
        match self {
            Backend::Mono(c) => c.run_session_chunks_into(jobs, outs),
            Backend::Sharded(s) => s.run_session_chunks_into(jobs, outs),
            Backend::Remote(_) => Err(anyhow!("remote backends do not host streaming sessions")),
        }
    }

    fn has_faults(&self) -> bool {
        match self {
            Backend::Mono(c) => c.has_faults(),
            Backend::Sharded(s) => s.has_faults(),
            // Fault plans are installed host-side; the driver cannot see
            // them (and must not double-report deltas the hosts own).
            Backend::Remote(_) => false,
        }
    }

    /// `(stuck_row_hits, dead_slot_hits, events_bit_flipped)` accumulated
    /// across every core (lane stats included, pre-fold).
    fn fault_counters(&self) -> (u64, u64, u64) {
        match self {
            Backend::Mono(c) => c.fault_counters(),
            Backend::Sharded(s) => s.fault_counters(),
            Backend::Remote(_) => (0, 0, 0),
        }
    }

    /// `shard_of[c]` for every local core, in the same global core order
    /// [`Self::profile_samples_into`] appends — the shape of the
    /// coordinator's [`ProfilePlane`]. Empty for remote backends (their
    /// cores profile host-side).
    fn profile_shape(&self) -> Vec<usize> {
        match self {
            Backend::Mono(c) => vec![0; c.num_cores()],
            Backend::Sharded(s) => s.core_shard_map(),
            Backend::Remote(_) => Vec::new(),
        }
    }

    /// Clear `out` and append every local core's monotonic profile sample
    /// (mirrors [`Self::fault_counters`]'s accumulation semantics: core
    /// stats + lane stats, pre-fold).
    fn profile_samples_into(&self, out: &mut Vec<crate::obs::CoreSample>) {
        out.clear();
        match self {
            Backend::Mono(c) => c.profile_samples_into(out),
            Backend::Sharded(s) => s.profile_samples_into(out),
            Backend::Remote(_) => {}
        }
    }

    /// Collapse into the monolithic-shaped stats carrier shutdown hands
    /// back (sharded cores are reassembled in global layer order). A
    /// remote backend owns no cores — its stats live in the shard hosts'
    /// STATS registries — so it yields `None`.
    pub(crate) fn into_chip(self) -> Option<Menage> {
        match self {
            Backend::Mono(c) => Some(c),
            Backend::Sharded(s) => Some(s.into_monolithic()),
            Backend::Remote(_) => None,
        }
    }
}

/// One inference request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub input: SpikeTrain,
    /// Optional ground-truth label (accuracy accounting).
    pub label: Option<usize>,
    /// Times this request has been resubmitted after losing its worker to
    /// a panic. At most one retry: the second loss yields a typed error —
    /// a request that kills two workers is presumed to be the murder
    /// weapon, not a bystander.
    pub attempts: u8,
    /// When the request entered the shared queue — the trace-span anchor
    /// workers measure queue wait against. Resubmission after a worker
    /// death keeps the original instant (the requeue wait is part of the
    /// latency the client experienced).
    pub submitted: Instant,
}

/// One inference response.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub predicted: usize,
    /// Modeled on-accelerator cycles.
    pub cycles: u64,
    /// Wall-clock simulation latency. Under lane packing this is the wall
    /// time of the whole lane batch the request rode in — the latency the
    /// request actually experienced (lanes complete together), NOT its
    /// marginal compute cost. Compare per-sample cost across modes with
    /// `cycles` (bit-identical to sequential), not with this field.
    pub sim_latency: Duration,
    pub label: Option<usize>,
    /// The classifier (last-layer) output spike train — what a remote
    /// caller needs to verify bit-identical execution against an
    /// in-process [`Menage::run`] (the serving layer ships it over the
    /// wire). Small: `classes × timesteps` sparse indices.
    pub output: SpikeTrain,
    /// Trace span: time spent in the shared queue (submit → steal),
    /// including adaptive fill-wait and any post-worker-death requeue.
    pub queue_wait: Duration,
    /// Trace span: steal → engine start (width filtering, lane staging,
    /// occupancy gauge updates on the worker thread).
    pub dispatch_wait: Duration,
    /// When the worker finished this request (engine done, response
    /// built); the router's `done.elapsed()` is the egress span.
    pub done: Instant,
}

/// Aggregated service metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    pub completed: AtomicU64,
    pub correct: AtomicU64,
    pub labelled: AtomicU64,
    /// Simulated cycles across completed requests.
    pub total_cycles: AtomicU64,
    pub latency: Mutex<Summary>,
    /// Worker dispatches (one per batch handed to a chip — a singleton
    /// request and a full lane batch each count once).
    pub dispatches: AtomicU64,
    /// Requests across all dispatches (Σ batch widths); divided by
    /// `dispatches` this is the mean lane occupancy — how full
    /// micro-batches actually run under the live traffic pattern.
    pub lanes_dispatched: AtomicU64,
    /// Widest batch any worker dispatched (≤ `lane_capacity` always).
    pub max_lane_occupancy: AtomicU64,
    /// The configured lanes-per-worker L (set at construction; the bound
    /// the occupancy gauges are read against).
    pub lane_capacity: AtomicU64,
}

impl Metrics {
    /// Mean requests per dispatch (`NaN` before the first dispatch);
    /// bounded by [`Self::lane_capacity`].
    pub fn mean_lane_occupancy(&self) -> f64 {
        let d = self.dispatches.load(Ordering::Relaxed);
        if d == 0 {
            return f64::NAN;
        }
        self.lanes_dispatched.load(Ordering::Relaxed) as f64 / d as f64
    }

    pub fn accuracy(&self) -> f64 {
        let l = self.labelled.load(Ordering::Relaxed);
        if l == 0 {
            return f64::NAN;
        }
        self.correct.load(Ordering::Relaxed) as f64 / l as f64
    }

    pub fn throughput(&self, elapsed: Duration) -> f64 {
        self.completed.load(Ordering::Relaxed) as f64 / elapsed.as_secs_f64().max(1e-9)
    }
}

/// The shared work-stealing queue: pending requests plus the shutdown
/// latch, guarded by one mutex; the condvar wakes idle workers.
struct SharedQueue {
    state: Mutex<QueueState>,
    available: Condvar,
    /// Worker count, used to cap greedy batch steals (see
    /// [`Self::steal_batch`]).
    workers: usize,
    /// Adaptive lane packing: after a steal drains the queue below a full
    /// lane batch, keep the worker parked on the condvar up to this long
    /// collecting late arrivals, so a shallow queue still packs lanes
    /// instead of dispatching singleton batches. Zero = dispatch whatever
    /// was grabbed immediately (the pre-adaptive behaviour).
    fill_wait: Duration,
}

struct QueueState {
    jobs: VecDeque<Request>,
    /// When set, workers exit once the queue is empty (pending jobs are
    /// still drained first).
    shutdown: bool,
}

impl SharedQueue {
    fn new(workers: usize, fill_wait: Duration) -> Self {
        Self {
            state: Mutex::new(QueueState { jobs: VecDeque::new(), shutdown: false }),
            available: Condvar::new(),
            workers,
            fill_wait,
        }
    }

    /// Block until at least one job is available, then grab up to `max`.
    /// Returns `false` on shutdown with an empty queue.
    ///
    /// The grab is capped at the worker's fair share,
    /// `ceil(queued / workers)`: otherwise one worker's L-deep steal
    /// could take a small batch whole while the other W−1 workers sleep
    /// on an empty queue — re-creating exactly the idling the shared
    /// queue exists to prevent.
    ///
    /// **Adaptive packing:** when the initial grab *drained* the queue
    /// without filling the batch (the shallow-queue case — fairness took
    /// nothing from anyone), the worker keeps waiting up to `fill_wait`
    /// for late arrivals, stealing its fair share of each, and dispatches
    /// as soon as the batch is full, the timeout lapses, or shutdown is
    /// raised. Jobs left in the queue by the fair-share cap are *not*
    /// waited on — they belong to the other workers.
    fn steal_batch(&self, max: usize, out: &mut Vec<Request>) -> bool {
        out.clear();
        let mut s = lock_recover(&self.state);
        loop {
            if !s.jobs.is_empty() {
                let fair = s.jobs.len().div_ceil(self.workers).max(1);
                let grab = max.min(fair);
                while out.len() < grab {
                    match s.jobs.pop_front() {
                        Some(req) => out.push(req),
                        None => break,
                    }
                }
                break;
            }
            if s.shutdown {
                return false;
            }
            s = recover(self.available.wait(s));
        }
        if out.len() >= max || self.fill_wait.is_zero() || !s.jobs.is_empty() {
            return true;
        }
        // Shallow queue: collect late arrivals for up to fill_wait.
        let deadline = Instant::now() + self.fill_wait;
        while out.len() < max && !s.shutdown {
            let now = Instant::now();
            let Some(left) = deadline.checked_duration_since(now).filter(|d| !d.is_zero())
            else {
                break;
            };
            let (guard, timeout) = recover(self.available.wait_timeout(s, left));
            s = guard;
            // Fair share of whatever arrived while parked.
            let fair = s.jobs.len().div_ceil(self.workers).max(1);
            let grab = (max - out.len()).min(fair);
            for _ in 0..grab {
                match s.jobs.pop_front() {
                    Some(req) => out.push(req),
                    None => break,
                }
            }
            if timeout.timed_out() {
                break;
            }
        }
        true
    }

    fn push(&self, req: Request) {
        lock_recover(&self.state).jobs.push_back(req);
        self.available.notify_one();
    }

    /// Requeue a salvaged request at the *front* so a retry does not also
    /// pay the queue's full latency a second time.
    fn push_front(&self, req: Request) {
        lock_recover(&self.state).jobs.push_front(req);
        self.available.notify_one();
    }

    /// Requests queued but not yet stolen by a worker — the backpressure
    /// signal the serving layer's admission control and STATS report read.
    fn depth(&self) -> usize {
        lock_recover(&self.state).jobs.len()
    }

    /// Take everything still queued — used when no worker is left to
    /// serve it, so each request can be failed with a typed error instead
    /// of waiting forever.
    fn drain_remaining(&self) -> Vec<Request> {
        lock_recover(&self.state).jobs.drain(..).collect()
    }

    fn is_shutdown(&self) -> bool {
        lock_recover(&self.state).shutdown
    }

    fn shutdown(&self) {
        lock_recover(&self.state).shutdown = true;
        self.available.notify_all();
    }
}

/// Everything a worker thread shares with the coordinator — bundled so
/// [`Coordinator::heal`] can respawn a worker with one clone-per-field.
struct WorkerCtx {
    queue: Arc<SharedQueue>,
    metrics: Arc<Metrics>,
    recovery: Arc<RecoveryStats>,
    /// Live per-core execution-profile counters; the worker publishes
    /// monotonic deltas after every batch, like the fault counters.
    profile: Arc<crate::obs::ProfilePlane>,
    results_tx: Sender<Result<Response>>,
    /// This worker's held slot: the batch it is currently processing.
    held: Arc<Mutex<Vec<Request>>>,
    lanes_per_worker: usize,
}

/// Multi-worker inference service over cloned [`Menage`] chips with a
/// shared work-stealing request queue (module docs).
pub struct Coordinator {
    /// `None` marks a worker slot whose thread died and was not (or could
    /// no longer be) respawned.
    workers: Vec<Option<JoinHandle<Option<Menage>>>>,
    /// Per-worker held slots (module docs §Worker supervision).
    held: Vec<Arc<Mutex<Vec<Request>>>>,
    queue: Arc<SharedQueue>,
    results_rx: Receiver<Result<Response>>,
    /// Kept open so supervision can emit typed errors for salvaged
    /// requests and the channel never disconnects under worker deaths.
    results_tx: Sender<Result<Response>>,
    pub metrics: Arc<Metrics>,
    /// Fault/recovery counters + chaos triggers, shared with workers and
    /// the serving layer's STATS report.
    recovery: Arc<RecoveryStats>,
    /// Live per-core/per-shard execution profile, shared with workers and
    /// the serving layer's STATS `profile` block.
    profile: Arc<crate::obs::ProfilePlane>,
    /// Pristine backend template used to rebuild panicked workers.
    template: Backend,
    lanes_per_worker: usize,
    /// Respawn budget: after this many respawns the coordinator stops
    /// rebuilding workers (a fault so repeatable that every worker dies on
    /// it must degrade capacity, not burn CPU rebuilding chips forever).
    respawns_left: usize,
    /// Chips recovered from workers that exited cleanly during a
    /// shutdown/heal race — handed back by [`Self::shutdown`].
    dead_chips: Vec<Menage>,
    /// Shared with every [`SubmitHandle`] so concurrent submitters (e.g.
    /// the TCP server's per-connection readers) allocate disjoint ids.
    next_id: Arc<AtomicU64>,
    /// Shared with [`SubmitHandle`]s: incremented at submission (from any
    /// thread), decremented by whoever consumes the results channel.
    in_flight: Arc<AtomicUsize>,
    started: Instant,
    /// Successful responses consumed by a failing [`Coordinator::drain`]
    /// (retrievable via [`Coordinator::take_salvaged_responses`] so a
    /// single bad request does not cost the whole batch's results).
    salvaged: Vec<Response>,
}

impl Coordinator {
    /// Spawn `num_workers` workers, each owning a clone of `chip`, all
    /// pulling from one shared queue — one request per worker at a time
    /// (`lanes_per_worker == 1`).
    pub fn new(chip: &Menage, num_workers: usize) -> Self {
        Self::with_lanes(chip, num_workers, 1)
    }

    /// Spawn `num_workers` workers each serving up to `lanes_per_worker`
    /// requests at once as SIMD lanes over its single chip clone (module
    /// docs §Lane packing). Concurrency is W×L request slots with only W
    /// copies of the model images; per-request outputs stay bit-identical
    /// to single-request execution.
    ///
    /// Workers dispatch whatever is queued immediately (`fill_wait` of
    /// zero); use [`Self::with_lanes_wait`] to let a shallow queue pack
    /// fuller lane batches.
    pub fn with_lanes(
        chip: &Menage,
        num_workers: usize,
        lanes_per_worker: usize,
    ) -> Self {
        Self::with_lanes_wait(chip, num_workers, lanes_per_worker, Duration::ZERO)
    }

    /// [`Self::with_lanes`] with **adaptive lane packing**: a worker whose
    /// steal drained the queue below a full lane batch keeps collecting
    /// late arrivals for up to `fill_wait` before dispatching, so a
    /// shallow request stream still amortizes the shared CSR walk across
    /// lanes instead of degenerating into singleton batches. Bounded:
    /// the batch goes out as soon as it is full, the wait lapses, or
    /// shutdown is raised — `fill_wait` is the worst-case added latency
    /// for a lone request, never a liveness hazard.
    pub fn with_lanes_wait(
        chip: &Menage,
        num_workers: usize,
        lanes_per_worker: usize,
        fill_wait: Duration,
    ) -> Self {
        Self::with_backend(Backend::Mono(chip.clone()), num_workers, lanes_per_worker, fill_wait)
    }

    /// [`Self::new`] over a sharded pipeline: each worker owns a clone of
    /// the whole multi-chip [`ShardedMenage`] and serves one request at a
    /// time through it. Outputs are bit-identical to the monolithic
    /// coordinator (`tests/shard_differential.rs`).
    pub fn sharded(chip: &ShardedMenage, num_workers: usize) -> Self {
        Self::sharded_with_lanes_wait(chip, num_workers, 1, Duration::ZERO)
    }

    /// [`Self::with_lanes_wait`] over a sharded pipeline — W workers × L
    /// lanes, every lane flowing through all shards with boundary
    /// frontiers forwarded per (step, lane).
    pub fn sharded_with_lanes_wait(
        chip: &ShardedMenage,
        num_workers: usize,
        lanes_per_worker: usize,
        fill_wait: Duration,
    ) -> Self {
        Self::with_backend(
            Backend::Sharded(chip.clone()),
            num_workers,
            lanes_per_worker,
            fill_wait,
        )
    }

    /// [`Self::with_lanes_wait`] over a **distributed** pipeline of
    /// `shard-host` processes. Each worker clones the pipeline (topology
    /// + shared link gauges; connections are lazily re-established per
    /// clone) and drives the remote chips over TCP. Worker supervision
    /// still applies — a panicked worker respawns from the template and
    /// reconnects — but shutdown hands back no chips: the stats live in
    /// the hosts' STATS registries.
    pub fn remote_with_lanes_wait(
        pipeline: &crate::serve::RemoteShardPipeline,
        num_workers: usize,
        lanes_per_worker: usize,
        fill_wait: Duration,
    ) -> Self {
        Self::with_backend(
            Backend::Remote(pipeline.clone()),
            num_workers,
            lanes_per_worker,
            fill_wait,
        )
    }

    fn with_backend(
        backend: Backend,
        num_workers: usize,
        lanes_per_worker: usize,
        fill_wait: Duration,
    ) -> Self {
        assert!(num_workers > 0);
        assert!(lanes_per_worker > 0);
        let metrics = Arc::new(Metrics::default());
        metrics.lane_capacity.store(lanes_per_worker as u64, Ordering::Relaxed);
        let recovery = Arc::new(RecoveryStats::default());
        let profile = Arc::new(crate::obs::ProfilePlane::new(backend.profile_shape()));
        let queue = Arc::new(SharedQueue::new(num_workers, fill_wait));
        let (results_tx, results_rx) = mpsc::channel::<Result<Response>>();
        let mut workers = Vec::with_capacity(num_workers);
        let mut held = Vec::with_capacity(num_workers);
        for _ in 0..num_workers {
            let slot: Arc<Mutex<Vec<Request>>> = Arc::new(Mutex::new(Vec::new()));
            workers.push(Some(spawn_worker(
                backend.clone(),
                WorkerCtx {
                    queue: Arc::clone(&queue),
                    metrics: Arc::clone(&metrics),
                    recovery: Arc::clone(&recovery),
                    profile: Arc::clone(&profile),
                    results_tx: results_tx.clone(),
                    held: Arc::clone(&slot),
                    lanes_per_worker,
                },
            )));
            held.push(slot);
        }
        Self {
            workers,
            held,
            queue,
            results_rx,
            results_tx,
            metrics,
            recovery,
            profile,
            template: backend,
            lanes_per_worker,
            // 8 rebuilds per configured worker before supervision stops
            // throwing silicon at a fault that keeps killing it.
            respawns_left: num_workers * 8,
            dead_chips: Vec::new(),
            next_id: Arc::new(AtomicU64::new(0)),
            in_flight: Arc::new(AtomicUsize::new(0)),
            started: Instant::now(),
            salvaged: Vec::new(),
        }
    }

    /// Submit a request to the shared queue (any idle worker will pick it
    /// up). Returns its id.
    pub fn submit(&mut self, input: SpikeTrain, label: Option<usize>) -> u64 {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.in_flight.fetch_add(1, Ordering::Relaxed);
        self.queue.push(Request { id, input, label, attempts: 0, submitted: Instant::now() });
        id
    }

    /// A cloneable handle that submits requests into this coordinator's
    /// shared queue from any thread — the ingress hook the TCP serving
    /// layer's per-connection readers use, so requests from many sockets
    /// land in one queue and get micro-batched into lane-packed dispatches
    /// by [`Self::with_lanes_wait`]'s fill-wait workers.
    ///
    /// The handle shares the coordinator's id allocator and in-flight
    /// counter; responses still arrive on the coordinator's results
    /// channel (consume them with [`Self::recv`] / [`Self::recv_timeout`]
    /// / [`Self::drain`], typically from a dedicated router thread).
    pub fn handle(&self) -> SubmitHandle {
        SubmitHandle {
            queue: Arc::clone(&self.queue),
            next_id: Arc::clone(&self.next_id),
            in_flight: Arc::clone(&self.in_flight),
        }
    }

    /// Requests queued but not yet stolen by a worker (the backpressure
    /// introspection hook; see also [`SubmitHandle::queue_depth`]).
    pub fn queue_depth(&self) -> usize {
        self.queue.depth()
    }

    /// Number of submitted requests whose responses have not been received.
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::Relaxed)
    }

    /// Consume one in-flight slot, saturating at zero (a panic window can
    /// in principle produce a duplicate response for a resubmitted
    /// request; an underflowed counter must never wedge the service).
    fn consume_in_flight(&self) {
        let _ = self
            .in_flight
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |n| n.checked_sub(1));
    }

    /// Bounded [`Self::recv`]: block up to `timeout` for one result.
    /// `None` means the timeout lapsed with nothing in the channel (not an
    /// error — retry, or check a stop flag, as the serving layer's router
    /// thread does); a [`Self::heal`] pass runs on every timeout so dead
    /// workers are detected even on an idle service. A dead results
    /// channel yields the same terminal error as [`Self::recv`], with the
    /// in-flight count zeroed so caller loops terminate.
    pub fn recv_timeout(&mut self, timeout: Duration) -> Option<Result<Response>> {
        match self.results_rx.recv_timeout(timeout) {
            Ok(res) => {
                self.consume_in_flight();
                Some(res)
            }
            Err(RecvTimeoutError::Timeout) => {
                self.heal();
                None
            }
            Err(RecvTimeoutError::Disconnected) => {
                // Defensive: the coordinator keeps a sender, so this arm
                // is unreachable in practice — but if it ever fires,
                // terminate caller loops instead of spinning.
                let n = self.in_flight.swap(0, Ordering::Relaxed);
                Some(Err(anyhow!(
                    "all workers terminated with {n} requests in flight"
                )))
            }
        }
    }

    /// Block until one result is available. A received `Err` still counts
    /// as a consumed in-flight request (so a failed sample cannot make
    /// [`Self::drain`] wait forever). The wait is a poll loop with a
    /// [`Self::heal`] pass per [`HEAL_POLL`] tick: a panicked worker's
    /// held requests are salvaged (resubmitted once, then failed typed)
    /// instead of blocking this receive forever.
    pub fn recv(&mut self) -> Result<Response> {
        loop {
            match self.results_rx.recv_timeout(HEAL_POLL) {
                Ok(res) => {
                    self.consume_in_flight();
                    return res;
                }
                Err(RecvTimeoutError::Timeout) => {
                    self.heal();
                }
                Err(RecvTimeoutError::Disconnected) => {
                    let n = self.in_flight.swap(0, Ordering::Relaxed);
                    return Err(anyhow!(
                        "all workers terminated with {n} requests in flight"
                    ));
                }
            }
        }
    }

    /// Drain all in-flight requests, returning them in submission order.
    ///
    /// Every in-flight response is consumed **before** the first error (if
    /// any) is propagated: stopping at the first `Err` would leave the
    /// remaining responses in the channel, where the *next* drain would
    /// collect and misattribute them — under lane packing a single bad
    /// request completes alongside a batch of good ones, making that
    /// ordering violation the common case rather than a corner. On error
    /// the successfully completed responses are not lost: retrieve them
    /// with [`Self::take_salvaged_responses`].
    pub fn drain(&mut self) -> Result<Vec<Response>> {
        let mut out = Vec::with_capacity(self.in_flight());
        let mut first_err = None;
        while self.in_flight() > 0 {
            match self.results_rx.recv_timeout(HEAL_POLL) {
                Ok(res) => {
                    self.consume_in_flight();
                    match res {
                        Ok(r) => out.push(r),
                        Err(e) => {
                            if first_err.is_none() {
                                first_err = Some(e);
                            }
                        }
                    }
                }
                Err(RecvTimeoutError::Timeout) => {
                    // A dead worker is the only way a drain can stall:
                    // salvage its held requests (retry once, then typed
                    // error) so this loop always terminates.
                    self.heal();
                }
                Err(RecvTimeoutError::Disconnected) => {
                    // Defensive (the coordinator keeps a sender): nothing
                    // else will ever arrive.
                    if first_err.is_none() {
                        first_err = Some(anyhow!(
                            "all workers terminated with {} requests in flight",
                            self.in_flight()
                        ));
                    }
                    self.in_flight.store(0, Ordering::Relaxed);
                    break;
                }
            }
        }
        out.sort_by_key(|r| r.id);
        if let Some(e) = first_err {
            self.salvaged = out;
            return Err(e);
        }
        // A successful drain invalidates any stale, un-taken salvage from
        // an earlier failure: after this point `take_salvaged_responses`
        // is empty, so old responses can never be misattributed to the
        // batch that just drained cleanly.
        self.salvaged.clear();
        Ok(out)
    }

    /// The successful responses a failing [`Self::drain`] consumed
    /// (submission order). Returns them once, clearing the buffer; a later
    /// failing drain overwrites any un-taken salvage and a *successful*
    /// drain discards it (so this is always empty after a clean drain).
    /// Never mixed into a drain's own results — responses carry their
    /// `id` for attribution.
    pub fn take_salvaged_responses(&mut self) -> Vec<Response> {
        std::mem::take(&mut self.salvaged)
    }

    /// Submit a whole labelled batch and wait for every result (submission
    /// order).
    pub fn run_batch(
        &mut self,
        inputs: Vec<(SpikeTrain, Option<usize>)>,
    ) -> Result<Vec<Response>> {
        for (input, label) in inputs {
            self.submit(input, label);
        }
        self.drain()
    }

    /// Submit a whole labelled batch and return an iterator that yields
    /// each response **as it completes** (completion order, not submission
    /// order) — lets the caller stream results while slow samples are
    /// still in flight. Dropping the iterator leaves the remaining
    /// responses in flight; [`Self::drain`] collects them.
    pub fn run_batch_streaming(
        &mut self,
        inputs: Vec<(SpikeTrain, Option<usize>)>,
    ) -> StreamingResults<'_> {
        for (input, label) in inputs {
            self.submit(input, label);
        }
        StreamingResults { coordinator: self }
    }

    /// Requests/sec since construction.
    pub fn throughput(&self) -> f64 {
        self.metrics.throughput(self.started.elapsed())
    }

    /// The shared fault/recovery counter block (the STATS frame's
    /// `recovery`/`faults` source).
    pub fn recovery(&self) -> Arc<RecoveryStats> {
        Arc::clone(&self.recovery)
    }

    /// The live per-core/per-shard execution profile (the serving layer's
    /// STATS `profile` source). Counters are cumulative; pollers diff
    /// successive snapshots for windowed rates.
    pub fn profile(&self) -> Arc<crate::obs::ProfilePlane> {
        Arc::clone(&self.profile)
    }

    /// Chaos knob: make workers panic on every `every`-th stolen batch
    /// (0 disarms). The panic fires after the batch is parked in the held
    /// slot and before anything is answered, so supervision has the full
    /// batch to salvage — the honest worst case.
    pub fn inject_worker_panics(&self, every: u64) {
        self.recovery.panic_trigger.arm(every);
    }

    /// Worker threads currently believed alive.
    pub fn alive_workers(&self) -> usize {
        self.workers
            .iter()
            .filter(|w| w.as_ref().is_some_and(|h| !h.is_finished()))
            .count()
    }

    /// One supervision pass (module docs §Worker supervision): detect dead
    /// worker threads, salvage their held requests (resubmit each at most
    /// once, then fail it with a typed `request <id>:` error), respawn the
    /// worker from the pristine backend template while the respawn budget
    /// lasts, and — if no worker is left alive — fail everything still
    /// queued so no request waits on a service that cannot serve it.
    /// Returns the number of workers respawned. Cheap when nothing is
    /// wrong (one `is_finished` check per worker); runs automatically from
    /// every receive path's poll loop.
    pub fn heal(&mut self) -> usize {
        if self.queue.is_shutdown() {
            // Workers exiting after shutdown is the normal drain-and-leave
            // path, not a fault; shutdown() handles their remains.
            return 0;
        }
        let mut respawned = 0;
        for w in 0..self.workers.len() {
            let finished = self.workers[w].as_ref().is_some_and(|h| h.is_finished());
            if !finished {
                continue;
            }
            let handle = self.workers[w].take().expect("checked above");
            match handle.join() {
                Ok(chip) => {
                    // Clean exit can only mean a shutdown race; keep the
                    // chip (if the backend owned one — remote backends
                    // don't) so shutdown() still hands back its stats.
                    self.dead_chips.extend(chip);
                    continue;
                }
                Err(_) => {
                    self.recovery.worker_panics.fetch_add(1, Ordering::Relaxed);
                }
            }
            // Salvage the batch the dead worker was holding.
            let orphans: Vec<Request> = lock_recover(&self.held[w]).drain(..).collect();
            for mut req in orphans {
                if req.attempts == 0 {
                    req.attempts = 1;
                    self.recovery.requests_resubmitted.fetch_add(1, Ordering::Relaxed);
                    // in_flight is untouched: the request is still in
                    // flight, just riding a different worker now.
                    self.queue.push_front(req);
                } else {
                    self.recovery.requests_failed.fetch_add(1, Ordering::Relaxed);
                    let _ = self.results_tx.send(Err(anyhow!(
                        "request {}: lost to a worker panic (already retried once)",
                        req.id
                    )));
                }
            }
            if self.respawns_left > 0 {
                self.respawns_left -= 1;
                // Fresh (unpoisoned) held slot for the replacement.
                let slot: Arc<Mutex<Vec<Request>>> = Arc::new(Mutex::new(Vec::new()));
                self.held[w] = Arc::clone(&slot);
                self.workers[w] = Some(spawn_worker(
                    self.template.clone(),
                    WorkerCtx {
                        queue: Arc::clone(&self.queue),
                        metrics: Arc::clone(&self.metrics),
                        recovery: Arc::clone(&self.recovery),
                        profile: Arc::clone(&self.profile),
                        results_tx: self.results_tx.clone(),
                        held: slot,
                        lanes_per_worker: self.lanes_per_worker,
                    },
                ));
                self.recovery.workers_respawned.fetch_add(1, Ordering::Relaxed);
                respawned += 1;
            }
        }
        if self.workers.iter().all(|w| w.is_none()) {
            // Respawn budget exhausted with every worker dead: nothing
            // queued can ever run. Exactly-one-response still holds —
            // each queued request gets a typed error now.
            for req in self.queue.drain_remaining() {
                self.recovery.requests_failed.fetch_add(1, Ordering::Relaxed);
                let _ = self.results_tx.send(Err(anyhow!(
                    "request {}: no workers alive (respawn budget exhausted)",
                    req.id
                )));
            }
        }
        respawned
    }

    /// Fail every request still parked in worker `w`'s held slot with a
    /// typed error (shutdown-time salvage: no retries, bounded exit).
    fn fail_held(&self, w: usize, why: &str) {
        let orphans: Vec<Request> = lock_recover(&self.held[w]).drain(..).collect();
        for req in orphans {
            self.recovery.requests_failed.fetch_add(1, Ordering::Relaxed);
            let _ = self
                .results_tx
                .send(Err(anyhow!("request {}: {why}", req.id)));
        }
    }

    /// Shut down workers (pending requests are still processed) and return
    /// their chips (with accumulated stats).
    ///
    /// Bounded even with dead workers: a panicked worker's join is
    /// tolerated (its held requests are failed with typed errors on the
    /// still-open results channel), and anything left queued when no
    /// worker survived is failed the same way — never a hang, and fewer
    /// (possibly zero) chips come back instead.
    pub fn shutdown(mut self) -> Vec<Menage> {
        self.queue.shutdown();
        let mut chips: Vec<Menage> = Vec::new();
        for w in 0..self.workers.len() {
            match self.workers[w].take() {
                Some(handle) => match handle.join() {
                    Ok(chip) => chips.extend(chip),
                    Err(_) => {
                        self.recovery.worker_panics.fetch_add(1, Ordering::Relaxed);
                        self.fail_held(w, "lost to a worker panic at shutdown");
                    }
                },
                None => self.fail_held(w, "lost to a dead worker at shutdown"),
            }
        }
        chips.append(&mut self.dead_chips);
        // A live worker drains the queue before exiting, so anything still
        // here was stranded by dead workers — fail it, don't strand it.
        for req in self.queue.drain_remaining() {
            self.recovery.requests_failed.fetch_add(1, Ordering::Relaxed);
            let _ = self.results_tx.send(Err(anyhow!(
                "request {}: shutdown with no workers alive",
                req.id
            )));
        }
        chips
    }
}

/// Spawn one worker thread. The worker parks every stolen batch in its
/// held slot and keeps the slot's lock for the whole batch: a panic
/// anywhere in processing leaves the unanswered requests sitting in the
/// (poisoned, recoverable) slot for [`Coordinator::heal`] to salvage. A
/// request is removed from the slot immediately after its response is on
/// the results channel, so the slot always holds exactly the requests
/// that would otherwise be lost.
fn spawn_worker(mut chip: Backend, ctx: WorkerCtx) -> JoinHandle<Option<Menage>> {
    std::thread::spawn(move || {
        let WorkerCtx { queue, metrics, recovery, profile, results_tx, held, lanes_per_worker } =
            ctx;
        // Trace-span stamps ride the response: queue wait is measured from
        // the request's own `submitted` anchor to the batch's steal
        // instant, dispatch from steal to engine start — one `Instant` per
        // batch, never per spike (hot-path budget: module docs).
        let record = |out: &crate::accel::RunOutput,
                      req: &Request,
                      sim_latency: Duration,
                      stolen: Instant,
                      t0: Instant|
         -> Response {
            let predicted = out.predicted_class();
            metrics.completed.fetch_add(1, Ordering::Relaxed);
            metrics.total_cycles.fetch_add(out.cycles, Ordering::Relaxed);
            if let Some(label) = req.label {
                metrics.labelled.fetch_add(1, Ordering::Relaxed);
                if label == predicted {
                    metrics.correct.fetch_add(1, Ordering::Relaxed);
                }
            }
            lock_recover(&metrics.latency).add(sim_latency.as_secs_f64());
            Response {
                id: req.id,
                predicted,
                cycles: out.cycles,
                sim_latency,
                label: req.label,
                output: out.output().clone(),
                queue_wait: stolen.saturating_duration_since(req.submitted),
                dispatch_wait: t0.saturating_duration_since(stolen),
                done: Instant::now(),
            }
        };
        let mut out = crate::accel::RunOutput::default();
        let mut lane_outs: Vec<crate::accel::RunOutput> = Vec::new();
        let mut batch: Vec<Request> = Vec::new();
        let mut inputs: Vec<SpikeTrain> = Vec::new();
        // Last-published hardware fault counters (delta publishing).
        let mut hw_last = (0u64, 0u64, 0u64);
        // Last-published execution-profile samples, same delta pattern
        // (pre-sized once; the per-batch snapshot reuses `prof_now`).
        let mut prof_last = vec![crate::obs::CoreSample::default(); profile.num_cores()];
        let mut prof_now: Vec<crate::obs::CoreSample> = Vec::with_capacity(profile.num_cores());
        let mut disconnected = false;
        while !disconnected && queue.steal_batch(lanes_per_worker, &mut batch) {
            let stolen = Instant::now();
            let mut held_g = lock_recover(&held);
            held_g.clear();
            held_g.append(&mut batch);
            // Chaos hook: the injected panic fires with the whole batch
            // parked in the held slot and nothing answered yet — the
            // maximum salvage surface, and the honest worst case.
            if recovery.panic_trigger.fire() {
                panic!("injected worker panic");
            }
            if held_g.len() == 1 {
                // Single request: the sequential engine (identical to the
                // pre-lane coordinator).
                let req = &held_g[0];
                // Occupancy gauges count only valid dispatched requests —
                // the lane path filters width mismatches before its
                // gauges, so the singleton path must too or the metric's
                // meaning would shift with queue depth.
                if req.input.num_neurons == chip.input_dim() {
                    metrics.dispatches.fetch_add(1, Ordering::Relaxed);
                    metrics.lanes_dispatched.fetch_add(1, Ordering::Relaxed);
                    metrics.max_lane_occupancy.fetch_max(1, Ordering::Relaxed);
                }
                let t0 = Instant::now();
                let res = chip
                    .run_into(&req.input, &mut out)
                    .map(|()| record(&out, req, t0.elapsed(), stolen, t0))
                    // Every worker error carries the `request {id}:`
                    // prefix (see [`request_id_of_error`]) so a
                    // response router can attribute it.
                    .map_err(|e| anyhow!("request {}: {e:#}", req.id));
                disconnected = results_tx.send(res).is_err();
                held_g.clear();
            } else {
                // Lane packing. Width mismatches are answered individually
                // up front so one bad request cannot poison (or drop
                // responses for) the rest of the batch.
                let expect = chip.input_dim();
                let t0 = Instant::now();
                let mut i = 0;
                while i < held_g.len() {
                    if held_g[i].input.num_neurons != expect {
                        let req = held_g.remove(i);
                        let err = anyhow!(
                            "request {}: input has {} neurons, first core expects {expect}",
                            req.id,
                            req.input.num_neurons
                        );
                        disconnected |= results_tx.send(Err(err)).is_err();
                    } else {
                        i += 1;
                    }
                }
                if held_g.is_empty() || disconnected {
                    continue;
                }
                metrics.dispatches.fetch_add(1, Ordering::Relaxed);
                metrics
                    .lanes_dispatched
                    .fetch_add(held_g.len() as u64, Ordering::Relaxed);
                metrics
                    .max_lane_occupancy
                    .fetch_max(held_g.len() as u64, Ordering::Relaxed);
                // The staging buffer clones the trains (instead of the old
                // move-out) so a held request stays whole until answered —
                // a resubmitted request must carry its real input.
                inputs.clear();
                inputs.extend(held_g.iter().map(|r| r.input.clone()));
                match chip.run_lanes_into(&inputs, &mut lane_outs) {
                    Ok(()) => {
                        let sim_latency = t0.elapsed();
                        for o in lane_outs.iter() {
                            let resp = record(o, &held_g[0], sim_latency, stolen, t0);
                            disconnected |= results_tx.send(Ok(resp)).is_err();
                            held_g.remove(0);
                        }
                    }
                    Err(e) => {
                        // One response per request, even on a whole-batch
                        // failure: nothing may be lost.
                        while !held_g.is_empty() {
                            let err = anyhow!(
                                "request {}: lane batch failed: {e}",
                                held_g[0].id
                            );
                            disconnected |= results_tx.send(Err(err)).is_err();
                            held_g.remove(0);
                        }
                    }
                }
            }
            drop(held_g);
            // Publish hardware fault-counter deltas so live STATS readers
            // see degradation without waiting for shutdown's stats fold.
            if chip.has_faults() {
                let now = chip.fault_counters();
                recovery.add_hw(
                    now.0.saturating_sub(hw_last.0),
                    now.1.saturating_sub(hw_last.1),
                    now.2.saturating_sub(hw_last.2),
                );
                hw_last = now;
            }
            // Publish execution-profile deltas the same way: live STATS
            // readers see per-core work attribution batch by batch.
            if profile.num_cores() > 0 {
                chip.profile_samples_into(&mut prof_now);
                for (c, last) in prof_last.iter_mut().enumerate() {
                    let d = prof_now[c].delta_since(last);
                    profile.add(c, &d);
                    *last = prof_now[c];
                }
            }
        }
        // Collapse lane-attributed work into the core totals so the chips
        // handed back by shutdown() report everything they served
        // (merge_chips/energy/trace read core stats).
        chip.fold_lane_stats();
        // Sharded pipelines hand back one monolithic-shaped stats carrier
        // (cores reassembled in global layer order).
        chip.into_chip()
    })
}

impl Drop for Coordinator {
    /// A coordinator dropped without [`Coordinator::shutdown`] must not
    /// leave workers parked on the condvar forever: raise the shutdown
    /// latch so they drain the queue and exit on their own (they are not
    /// joined here).
    fn drop(&mut self) {
        self.queue.shutdown();
    }
}

/// Cloneable, thread-safe submission handle into a [`Coordinator`]'s
/// shared queue (see [`Coordinator::handle`]). Lets many producers (e.g.
/// per-connection socket readers) feed one coordinator concurrently while
/// a single router thread consumes the results channel.
///
/// When a producer must publish bookkeeping *before* the request becomes
/// runnable (the serving layer registers a pending-response entry first,
/// so the router can never see a response for an unregistered id), use
/// [`Self::reserve_id`] + [`Self::submit_reserved`]; otherwise
/// [`Self::submit`] does both.
#[derive(Clone)]
pub struct SubmitHandle {
    queue: Arc<SharedQueue>,
    next_id: Arc<AtomicU64>,
    in_flight: Arc<AtomicUsize>,
}

impl SubmitHandle {
    /// Allocate the next request id without enqueueing anything.
    pub fn reserve_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Enqueue a request under an id from [`Self::reserve_id`].
    pub fn submit_reserved(&self, id: u64, input: SpikeTrain, label: Option<usize>) {
        self.in_flight.fetch_add(1, Ordering::Relaxed);
        self.queue.push(Request { id, input, label, attempts: 0, submitted: Instant::now() });
    }

    /// [`Self::reserve_id`] + [`Self::submit_reserved`].
    pub fn submit(&self, input: SpikeTrain, label: Option<usize>) -> u64 {
        let id = self.reserve_id();
        self.submit_reserved(id, input, label);
        id
    }

    /// Requests queued but not yet stolen by a worker (backpressure).
    pub fn queue_depth(&self) -> usize {
        self.queue.depth()
    }

    /// Submitted requests whose responses have not been consumed yet.
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::Relaxed)
    }
}

/// Recover the request id from a worker-sent error. Every error a worker
/// puts on the results channel is prefixed `request <id>: ` (both the
/// single-request and the lane-packed path), which is what lets an
/// id-keyed response router — the TCP serving layer — attribute a failure
/// to the connection that submitted it. Returns `None` for errors that do
/// not originate from a worker (e.g. the all-workers-terminated error).
pub fn request_id_of_error(e: &anyhow::Error) -> Option<u64> {
    let msg = e.root_message();
    let rest = msg.strip_prefix("request ")?;
    let digits: &str = &rest[..rest.find(':')?];
    digits.parse().ok()
}

/// Completion-order response stream over everything currently in flight
/// (see [`Coordinator::run_batch_streaming`]).
pub struct StreamingResults<'a> {
    coordinator: &'a mut Coordinator,
}

impl Iterator for StreamingResults<'_> {
    type Item = Result<Response>;

    fn next(&mut self) -> Option<Self::Item> {
        if self.coordinator.in_flight() == 0 {
            None
        } else {
            Some(self.coordinator.recv())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analog::AnalogParams;
    use crate::config::{AcceleratorConfig, ModelConfig};
    use crate::mapping::Strategy;
    use crate::snn::{reference_forward, QuantNetwork};
    use crate::util::rng::Rng;

    fn test_chip() -> (Menage, QuantNetwork) {
        let mcfg = ModelConfig {
            name: "c".into(),
            layer_sizes: vec![30, 16, 8],
            timesteps: 6,
            beta: 0.9,
            v_threshold: 1.0,
            v_reset: 0.0,
        };
        let mut cfg = AcceleratorConfig::accel1();
        cfg.num_cores = 2;
        cfg.a_neurons_per_core = 4;
        cfg.a_syns_per_core = 4;
        cfg.virtual_per_a_neuron = 4;
        let mut rng = Rng::new(8);
        let net = QuantNetwork::random(&mcfg, 0.5, &mut rng);
        let chip =
            Menage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 2).unwrap();
        (chip, net)
    }

    fn inputs(n: usize) -> Vec<(SpikeTrain, Option<usize>)> {
        (0..n)
            .map(|s| {
                let mut rng = Rng::new(1000 + s as u64);
                let mut st = SpikeTrain::new(30, 6);
                for step in st.spikes.iter_mut() {
                    for i in 0..30 {
                        if rng.bernoulli(0.25) {
                            step.push(i as u32);
                        }
                    }
                }
                (st, Some(s % 8))
            })
            .collect()
    }

    #[test]
    fn batch_completes_and_orders() {
        let (chip, _) = test_chip();
        let mut coord = Coordinator::new(&chip, 3);
        let res = coord.run_batch(inputs(20)).unwrap();
        assert_eq!(res.len(), 20);
        for (i, r) in res.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert!(r.predicted < 8);
            assert!(r.cycles > 0);
        }
        assert_eq!(coord.metrics.completed.load(Ordering::Relaxed), 20);
        assert!(coord.throughput() > 0.0);
        let chips = coord.shutdown();
        assert_eq!(chips.len(), 3);
        let total: u64 = chips.iter().map(|c| c.inputs_processed).sum();
        assert_eq!(total, 20);
    }

    /// The live profile plane must account for exactly the work the
    /// chips report at shutdown: every worker publishes per-batch deltas,
    /// so after the last response the cumulative plane totals equal the
    /// folded per-core stats summed across worker chips — and the
    /// responses carry sane trace-span stamps.
    #[test]
    fn profile_plane_matches_folded_chip_totals() {
        let (chip, _) = test_chip();
        let mut coord = Coordinator::with_lanes(&chip, 2, 2);
        let plane = coord.profile();
        assert_eq!(plane.num_cores(), 2);
        assert_eq!(plane.num_shards(), 1);
        let res = coord.run_batch(inputs(16)).unwrap();
        for r in &res {
            // Span stamps: monotone fields a router can fold into stage
            // histograms. `done` precedes now; the waits are bounded by
            // the test's own wall time (sanity, not timing assertions).
            assert!(r.done.elapsed() < Duration::from_secs(120));
            assert!(r.queue_wait < Duration::from_secs(120));
            assert!(r.dispatch_wait < Duration::from_secs(120));
        }
        let chips = coord.shutdown();
        let mut macs = 0u64;
        let mut cycles = 0u64;
        let mut events = 0u64;
        let mut spikes = 0u64;
        for c in &chips {
            for core in &c.cores {
                macs += core.stats.macs;
                cycles += core.stats.cycles;
                events += core.stats.events_dispatched;
                spikes += core.stats.spikes_out;
            }
        }
        let shard_totals = plane.shard_samples();
        assert_eq!(shard_totals.len(), 1);
        let per_core: Vec<_> = (0..plane.num_cores()).map(|c| plane.core_sample(c)).collect();
        let plane_macs: u64 = per_core.iter().map(|s| s.macs).sum();
        let plane_cycles: u64 = per_core.iter().map(|s| s.cycles).sum();
        let plane_events: u64 = per_core.iter().map(|s| s.events).sum();
        let plane_spikes: u64 = per_core.iter().map(|s| s.spikes).sum();
        assert_eq!(plane_macs, macs);
        assert_eq!(plane_cycles, cycles);
        assert_eq!(plane_events, events);
        assert_eq!(plane_spikes, spikes);
        assert_eq!(shard_totals[0].macs, macs);
        assert!(macs > 0 && cycles > 0);
    }

    #[test]
    fn results_match_reference_regardless_of_worker() {
        let (chip, net) = test_chip();
        let mut coord = Coordinator::new(&chip, 4);
        let ins = inputs(12);
        let golden: Vec<usize> = ins
            .iter()
            .map(|(st, _)| reference_forward(&net, st).unwrap().predicted_class())
            .collect();
        let res = coord.run_batch(ins).unwrap();
        for (r, g) in res.iter().zip(&golden) {
            assert_eq!(r.predicted, *g, "request {}", r.id);
        }
        coord.shutdown();
    }

    #[test]
    fn metrics_accuracy_accounting() {
        let (chip, net) = test_chip();
        let mut coord = Coordinator::new(&chip, 2);
        // Label every input with the reference prediction → accuracy 1.0.
        let ins: Vec<(SpikeTrain, Option<usize>)> = inputs(10)
            .into_iter()
            .map(|(st, _)| {
                let label = reference_forward(&net, &st).unwrap().predicted_class();
                (st, Some(label))
            })
            .collect();
        coord.run_batch(ins).unwrap();
        assert_eq!(coord.metrics.accuracy(), 1.0);
        assert_eq!(coord.metrics.labelled.load(Ordering::Relaxed), 10);
        let lat = lock_recover(&coord.metrics.latency).clone();
        assert_eq!(lat.count(), 10);
        coord.shutdown();
    }

    /// Build one very heavy input (many busy timesteps) and `n` light ones.
    fn skewed_inputs(n: usize) -> Vec<(SpikeTrain, Option<usize>)> {
        // The heavy sample must dominate even a single-vCPU scheduler's
        // timeslice (~1500 busy steps vs 2 per light sample), so the other
        // worker always drains a light request before it finishes.
        let mut rng = Rng::new(77);
        let mut v = vec![(SpikeTrain::bernoulli(30, 1500, 0.5, &mut rng), Some(0))];
        for s in 0..n {
            let mut rng = Rng::new(2000 + s as u64);
            v.push((SpikeTrain::bernoulli(30, 2, 0.1, &mut rng), Some(0)));
        }
        v
    }

    /// With heterogeneous per-sample latencies and >1 worker, streaming
    /// yields light samples while the heavy one (submitted first) is still
    /// running — completion order ≠ submission order — while a subsequent
    /// drain()-based batch still returns submission order.
    #[test]
    fn streaming_yields_completion_order_drain_yields_submission_order() {
        let (chip, _) = test_chip();
        let mut coord = Coordinator::new(&chip, 2);

        let completion: Vec<u64> = coord
            .run_batch_streaming(skewed_inputs(8))
            .map(|r| r.unwrap().id)
            .collect();
        assert_eq!(completion.len(), 9);
        let mut sorted = completion.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..9).collect::<Vec<u64>>(), "all ids accounted for");
        // The heavy request has id 0 and was submitted first; a second
        // worker finishes (several) light samples long before it.
        assert_ne!(
            completion[0], 0,
            "heavy sample finished first — streaming produced submission order"
        );
        assert_eq!(coord.in_flight(), 0);

        // Same skewed workload through the blocking API: submission order.
        let res = coord.run_batch(skewed_inputs(8)).unwrap();
        let ids: Vec<u64> = res.iter().map(|r| r.id).collect();
        assert_eq!(ids, (9..18).collect::<Vec<u64>>(), "drain must sort by id");
        coord.shutdown();
    }

    /// A worker error (wrong input width) must still decrement the
    /// in-flight count, so drain() terminates and the coordinator stays
    /// usable afterwards.
    #[test]
    fn worker_error_does_not_leak_in_flight() {
        let (chip, _) = test_chip();
        let mut coord = Coordinator::new(&chip, 2);
        coord.submit(SpikeTrain::new(99, 6), None); // wrong width → Err
        assert_eq!(coord.in_flight(), 1);
        assert!(coord.recv().is_err());
        assert_eq!(coord.in_flight(), 0, "recv leaked in_flight on Err");
        // drain() over an empty in-flight set returns immediately.
        assert!(coord.drain().unwrap().is_empty());
        // And the service still works.
        let res = coord.run_batch(inputs(4)).unwrap();
        assert_eq!(res.len(), 4);
        // Mixed batch: drain consumes *everything* in flight before
        // propagating the error, so nothing is left to leak into (and
        // corrupt the ordering of) the next batch's drain.
        coord.submit(SpikeTrain::new(99, 6), None);
        for (st, l) in inputs(3) {
            coord.submit(st, l);
        }
        assert!(coord.drain().is_err());
        assert_eq!(coord.in_flight(), 0, "drain must consume all in-flight on error");
        // The 3 completed responses are salvageable, not lost…
        let salvaged = coord.take_salvaged_responses();
        assert_eq!(salvaged.len(), 3, "completed responses must be salvageable");
        assert!(salvaged.windows(2).all(|w| w[0].id < w[1].id));
        assert!(coord.take_salvaged_responses().is_empty(), "salvage is take-once");
        // …and never leak into the next drain.
        assert!(coord.drain().unwrap().is_empty(), "stale responses leaked");
        // And the next batch's ids are exactly its own.
        let res = coord.run_batch(inputs(2)).unwrap();
        let first_new_id = res[0].id;
        assert_eq!(
            res.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![first_new_id, first_new_id + 1]
        );
        coord.shutdown();
    }

    /// Lane packing (W×L grid) must produce reference-exact predictions
    /// and the same cycles as sequential execution, with drain returning
    /// submission order.
    #[test]
    fn lane_packed_results_match_reference() {
        let (chip, net) = test_chip();
        let mut plain = Coordinator::new(&chip, 1);
        let baseline: Vec<(usize, u64)> = plain
            .run_batch(inputs(24))
            .unwrap()
            .iter()
            .map(|r| (r.predicted, r.cycles))
            .collect();
        plain.shutdown();

        let mut coord = Coordinator::with_lanes(&chip, 2, 4);
        let ins = inputs(24);
        let golden: Vec<usize> = ins
            .iter()
            .map(|(st, _)| reference_forward(&net, st).unwrap().predicted_class())
            .collect();
        let res = coord.run_batch(ins).unwrap();
        assert_eq!(res.len(), 24);
        for (i, r) in res.iter().enumerate() {
            assert_eq!(r.id, i as u64, "drain must return submission order");
            assert_eq!(r.predicted, golden[i], "request {i}: prediction");
            // Lanes are bit-identical to the sequential engine: modeled
            // cycles match the plain coordinator's regardless of how the
            // requests were packed into (worker, lane) slots.
            assert_eq!((r.predicted, r.cycles), baseline[i], "request {i}: cycles");
        }
        assert_eq!(coord.metrics.completed.load(Ordering::Relaxed), 24);
        let chips = coord.shutdown();
        let total: u64 = chips.iter().map(|c| c.inputs_processed).sum();
        assert_eq!(total, 24);
        // Lane-served work is folded into core stats at shutdown, so the
        // energy/trace consumers (which read core totals) see it.
        let macs: u64 = chips.iter().map(|c| c.total_macs()).sum();
        assert!(macs > 0, "lane work invisible to core stats after shutdown");
    }

    /// Adaptive lane packing: with a bounded fill_wait, a trickle of
    /// requests into a shallow queue still packs into a multi-lane batch
    /// instead of dispatching singletons. Observable via the worker
    /// chip's lane count: a singleton steal takes the worker's
    /// `batch.len() == 1` `run_into` path, which never configures lanes,
    /// so `num_lanes() >= 2` proves a multi-request batch was packed
    /// (lanes never shrink).
    #[test]
    fn fill_wait_packs_shallow_queue_into_lanes() {
        let (chip, _) = test_chip();
        let mut coord =
            Coordinator::with_lanes_wait(&chip, 1, 4, Duration::from_secs(5));
        for (st, l) in inputs(4) {
            coord.submit(st, l);
            // Trickle: the worker steals the first request, drains the
            // queue, and fill-waits while the rest arrive.
            std::thread::sleep(Duration::from_millis(5));
        }
        let res = coord.drain().unwrap();
        assert_eq!(res.len(), 4);
        let chips = coord.shutdown();
        assert!(
            chips[0].cores[0].num_lanes() >= 2,
            "shallow queue dispatched singleton batches despite fill_wait"
        );
    }

    /// fill_wait is a latency bound, not a liveness hazard: shutdown
    /// releases a fill-waiting worker immediately, and the partial batch
    /// it was holding is still processed, not dropped.
    #[test]
    fn fill_wait_releases_on_shutdown() {
        let (chip, _) = test_chip();
        let mut coord =
            Coordinator::with_lanes_wait(&chip, 1, 4, Duration::from_secs(30));
        let (st, l) = inputs(1).pop().unwrap();
        coord.submit(st, l);
        // Give the worker time to steal the request and park in its
        // fill_wait window.
        std::thread::sleep(Duration::from_millis(50));
        let t0 = Instant::now();
        let chips = coord.shutdown();
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "shutdown blocked on fill_wait"
        );
        assert_eq!(chips[0].inputs_processed, 1, "parked request was dropped");
    }

    /// B > worker count: more in-flight requests than workers must pack
    /// into lanes and all complete.
    #[test]
    fn lane_packing_handles_b_greater_than_workers() {
        let (chip, _) = test_chip();
        let mut coord = Coordinator::with_lanes(&chip, 2, 8);
        let res = coord.run_batch(inputs(40)).unwrap();
        assert_eq!(res.len(), 40);
        assert_eq!(coord.in_flight(), 0);
        coord.shutdown();
    }

    /// A worker error mid-batch under lane packing must neither deadlock
    /// nor lose any in-flight response: every request gets exactly one
    /// response, the batch's good samples still complete, and the next
    /// batch is unaffected.
    #[test]
    fn lane_packed_worker_error_mid_batch() {
        let (chip, _) = test_chip();
        let mut coord = Coordinator::with_lanes(&chip, 2, 4);
        // Interleave bad-width requests among good ones so they land in
        // the middle of stolen lane batches.
        let mut expected_good = 0usize;
        for (k, (st, l)) in inputs(10).into_iter().enumerate() {
            if k % 3 == 1 {
                coord.submit(SpikeTrain::new(99, 6), None);
            } else {
                coord.submit(st, l);
                expected_good += 1;
            }
        }
        let submitted = 10;
        assert_eq!(coord.in_flight(), submitted);
        // Streaming yields exactly one item per request (Ok or Err) and
        // terminates — no deadlock, no lost response.
        let items: Vec<Result<Response>> =
            coord.run_batch_streaming(Vec::new()).collect();
        assert_eq!(items.len(), submitted);
        let ok = items.iter().filter(|r| r.is_ok()).count();
        assert_eq!(ok, expected_good, "every valid request must complete");
        assert_eq!(coord.in_flight(), 0);
        // The service stays healthy for the next (clean) batch.
        let res = coord.run_batch(inputs(6)).unwrap();
        assert_eq!(res.len(), 6);
        coord.shutdown();
    }

    /// drain() under lane packing: all in-flight consumed before the first
    /// error propagates; a follow-up drain is empty.
    #[test]
    fn lane_packed_drain_consumes_all_before_error() {
        let (chip, _) = test_chip();
        let mut coord = Coordinator::with_lanes(&chip, 2, 4);
        coord.submit(SpikeTrain::new(99, 6), None);
        for (st, l) in inputs(7) {
            coord.submit(st, l);
        }
        assert!(coord.drain().is_err());
        assert_eq!(coord.in_flight(), 0);
        // The 7 good requests' responses survive via salvage.
        assert_eq!(coord.take_salvaged_responses().len(), 7);
        assert!(coord.drain().unwrap().is_empty());
        coord.shutdown();
    }

    /// Concurrent producers through cloned SubmitHandles: every request
    /// gets exactly one response with a unique id, and the router-side
    /// consumer (recv_timeout) sees them all. This is the serving layer's
    /// ingress pattern — many socket readers, one results consumer.
    #[test]
    fn submit_handles_feed_from_many_threads() {
        let (chip, _) = test_chip();
        let mut coord = Coordinator::with_lanes(&chip, 2, 4);
        let handle = coord.handle();
        let producers: Vec<_> = (0..4)
            .map(|_p| {
                let h = handle.clone();
                std::thread::spawn(move || {
                    let mut ids = Vec::new();
                    for (st, l) in inputs(6) {
                        let id = h.reserve_id();
                        h.submit_reserved(id, st, l);
                        ids.push(id);
                    }
                    ids
                })
            })
            .collect();
        let mut all_ids: Vec<u64> = producers
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect();
        all_ids.sort_unstable();
        assert_eq!(all_ids, (0..24).collect::<Vec<u64>>(), "ids must be disjoint");
        let mut seen = Vec::new();
        while seen.len() < 24 {
            match coord.recv_timeout(Duration::from_secs(10)) {
                Some(Ok(r)) => seen.push(r.id),
                Some(Err(e)) => panic!("worker error: {e}"),
                None => panic!("timed out with {} responses", seen.len()),
            }
        }
        seen.sort_unstable();
        assert_eq!(seen, all_ids);
        assert_eq!(coord.in_flight(), 0);
        assert_eq!(handle.in_flight(), 0);
        assert_eq!(coord.queue_depth(), 0);
        coord.shutdown();
    }

    /// recv_timeout: times out (None) on an idle service without consuming
    /// anything, then yields the response once work completes.
    #[test]
    fn recv_timeout_times_out_then_delivers() {
        let (chip, _) = test_chip();
        let mut coord = Coordinator::new(&chip, 1);
        assert!(coord.recv_timeout(Duration::from_millis(10)).is_none());
        let (st, l) = inputs(1).pop().unwrap();
        coord.submit(st, l);
        let r = coord
            .recv_timeout(Duration::from_secs(10))
            .expect("response within timeout")
            .unwrap();
        assert_eq!(r.id, 0);
        assert_eq!(coord.in_flight(), 0);
        coord.shutdown();
    }

    /// Responses carry the classifier output train, bit-identical to the
    /// reference — the payload the wire protocol ships back to clients.
    #[test]
    fn response_output_train_matches_reference() {
        let (chip, net) = test_chip();
        let mut coord = Coordinator::with_lanes(&chip, 2, 3);
        let ins = inputs(9);
        let golden: Vec<SpikeTrain> = ins
            .iter()
            .map(|(st, _)| reference_forward(&net, st).unwrap().output().clone())
            .collect();
        let res = coord.run_batch(ins).unwrap();
        for (r, g) in res.iter().zip(&golden) {
            assert_eq!(&r.output, g, "request {}: output train", r.id);
        }
        coord.shutdown();
    }

    /// Worker errors are attributable: both the single-request and the
    /// lane-packed path prefix `request <id>:` and the helper parses it.
    #[test]
    fn worker_errors_carry_request_id() {
        let (chip, _) = test_chip();
        // Single-request path (1 lane).
        let mut coord = Coordinator::new(&chip, 1);
        let id = coord.submit(SpikeTrain::new(99, 6), None);
        let e = coord.recv().unwrap_err();
        assert_eq!(request_id_of_error(&e), Some(id), "single path: {e}");
        coord.shutdown();
        // Lane-packed path.
        let mut coord = Coordinator::with_lanes(&chip, 1, 4);
        let mut bad_ids = Vec::new();
        for (k, (st, l)) in inputs(6).into_iter().enumerate() {
            if k % 2 == 0 {
                bad_ids.push(coord.submit(SpikeTrain::new(99, 6), None));
            } else {
                coord.submit(st, l);
            }
        }
        let mut seen_bad = Vec::new();
        for item in coord.run_batch_streaming(Vec::new()) {
            if let Err(e) = item {
                seen_bad.push(request_id_of_error(&e).expect("id-prefixed error"));
            }
        }
        seen_bad.sort_unstable();
        assert_eq!(seen_bad, bad_ids);
        coord.shutdown();
        // Non-worker errors parse to None.
        assert_eq!(request_id_of_error(&anyhow!("all workers terminated")), None);
        assert_eq!(request_id_of_error(&anyhow!("request x: nope")), None);
    }

    /// Lane-occupancy gauges (the STATS follow-up): every dispatch is
    /// counted, the request total matches, and mean/max occupancy are
    /// bounded by the configured lanes-per-worker L.
    #[test]
    fn lane_occupancy_reported_and_bounded() {
        let (chip, _) = test_chip();
        let lanes = 4usize;
        let mut coord = Coordinator::with_lanes(&chip, 2, lanes);
        let res = coord.run_batch(inputs(24)).unwrap();
        assert_eq!(res.len(), 24);
        let m = &coord.metrics;
        assert_eq!(m.lane_capacity.load(Ordering::Relaxed), lanes as u64);
        let d = m.dispatches.load(Ordering::Relaxed);
        assert!(d > 0, "no dispatches recorded");
        assert_eq!(
            m.lanes_dispatched.load(Ordering::Relaxed),
            24,
            "every request must be attributed to exactly one dispatch"
        );
        let mean = m.mean_lane_occupancy();
        assert!(
            (1.0..=lanes as f64).contains(&mean),
            "mean occupancy {mean} outside [1, L={lanes}]"
        );
        let max = m.max_lane_occupancy.load(Ordering::Relaxed);
        assert!(
            (1..=lanes as u64).contains(&max),
            "max occupancy {max} outside [1, L={lanes}]"
        );
        coord.shutdown();
        // An idle coordinator reports NaN mean (no dispatches yet).
        let (chip, _) = test_chip();
        let coord = Coordinator::new(&chip, 1);
        assert!(coord.metrics.mean_lane_occupancy().is_nan());
        coord.shutdown();
    }

    #[test]
    fn single_worker_is_deterministic() {
        let (chip, _) = test_chip();
        let run = |chip: &Menage| {
            let mut coord = Coordinator::new(chip, 1);
            let res = coord.run_batch(inputs(6)).unwrap();
            coord.shutdown();
            res.iter().map(|r| (r.predicted, r.cycles)).collect::<Vec<_>>()
        };
        assert_eq!(run(&chip), run(&chip));
    }

    /// Worker supervision: an injected panic kills the worker mid-batch,
    /// yet every request still completes (the held request is resubmitted
    /// exactly once), the worker is respawned, and the recovery counters
    /// say so. W=1, L=1 makes the steal schedule deterministic: 8 fresh
    /// requests + 1 retry = 9 steals, so a fire-on-5th trigger fires
    /// exactly once.
    #[test]
    fn injected_panic_recovers_without_losing_requests() {
        let (chip, _) = test_chip();
        let mut coord = Coordinator::new(&chip, 1);
        coord.inject_worker_panics(5);
        let res = coord.run_batch(inputs(8)).unwrap();
        assert_eq!(res.len(), 8, "every request must be answered");
        assert_eq!(
            res.iter().map(|r| r.id).collect::<Vec<_>>(),
            (0..8).collect::<Vec<u64>>(),
            "drain order must survive a resubmission"
        );
        let rec = coord.recovery();
        assert_eq!(rec.worker_panics.load(Ordering::Relaxed), 1);
        assert_eq!(rec.workers_respawned.load(Ordering::Relaxed), 1);
        assert_eq!(rec.requests_resubmitted.load(Ordering::Relaxed), 1);
        assert_eq!(rec.requests_failed.load(Ordering::Relaxed), 0);
        coord.inject_worker_panics(0);
        // Capacity self-healed: the next batch is clean.
        let res = coord.run_batch(inputs(4)).unwrap();
        assert_eq!(res.len(), 4);
        let chips = coord.shutdown();
        assert_eq!(chips.len(), 1, "respawned worker must hand back a chip");
    }

    /// Every stolen batch panics (`every = 1`): each request is retried
    /// once, then failed with a typed id-prefixed error. Exactly one
    /// response per request, drain terminates, shutdown is bounded.
    #[test]
    fn permanent_panic_fails_typed_and_bounded() {
        let (chip, _) = test_chip();
        let mut coord = Coordinator::with_lanes(&chip, 1, 2);
        coord.inject_worker_panics(1);
        let n = 6;
        for (st, l) in inputs(n) {
            coord.submit(st, l);
        }
        let t0 = Instant::now();
        let items: Vec<Result<Response>> = coord.run_batch_streaming(Vec::new()).collect();
        assert_eq!(items.len(), n, "exactly one response per request");
        for item in &items {
            let e = item.as_ref().expect_err("all batches panicked");
            assert!(
                request_id_of_error(e).is_some(),
                "recovery error must be id-attributable: {e}"
            );
        }
        assert!(t0.elapsed() < Duration::from_secs(60), "drain not bounded");
        let rec = coord.recovery();
        assert_eq!(rec.requests_failed.load(Ordering::Relaxed), n as u64);
        assert_eq!(rec.requests_resubmitted.load(Ordering::Relaxed), n as u64);
        let t0 = Instant::now();
        coord.shutdown();
        assert!(t0.elapsed() < Duration::from_secs(30), "shutdown not bounded");
    }

    /// drain() with a panicked worker and disarmed respawn trigger: the
    /// mixed batch (successes + salvaged retries) comes back complete.
    #[test]
    fn drain_survives_single_worker_death() {
        let (chip, _) = test_chip();
        let mut coord = Coordinator::with_lanes(&chip, 2, 4);
        for (st, l) in inputs(8) {
            coord.submit(st, l);
        }
        // Arm late so some work may already be done; the 1st batch stolen
        // after arming dies.
        coord.inject_worker_panics(1);
        coord.inject_worker_panics(0);
        let res = coord.drain().unwrap();
        assert_eq!(res.len(), 8);
        assert_eq!(coord.in_flight(), 0);
        coord.shutdown();
    }
}
