//! L3 coordinator: the inference service wrapped around the simulator.
//!
//! MENAGE's contribution is the hardware architecture, so the coordinator
//! is deliberately thin (per the architecture brief): process lifecycle, a
//! multi-worker request loop with batching, metrics, and the golden-model
//! cross-check. tokio is not available in the offline vendor set, so the
//! runtime is std::thread workers + mpsc channels — an arrangement that is
//! arguably better suited to a CPU-bound simulator anyway (no async I/O on
//! the hot path).
//!
//! Topology:
//!
//! ```text
//!            requests                 results
//!   client ───────────► [dispatcher] ────────► client
//!                         │  round-robin
//!              ┌──────────┼──────────┐
//!          [worker 0] [worker 1] … [worker W-1]
//!           Menage      Menage       Menage      (one chip clone each)
//! ```

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::accel::Menage;
use crate::snn::SpikeTrain;
use crate::util::stats::Summary;

/// One inference request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub input: SpikeTrain,
    /// Optional ground-truth label (accuracy accounting).
    pub label: Option<usize>,
}

/// One inference response.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub predicted: usize,
    /// Modeled on-accelerator cycles.
    pub cycles: u64,
    /// Wall-clock simulation latency.
    pub sim_latency: Duration,
    pub label: Option<usize>,
}

/// Aggregated service metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    pub completed: AtomicU64,
    pub correct: AtomicU64,
    pub labelled: AtomicU64,
    /// Simulated cycles across completed requests.
    pub total_cycles: AtomicU64,
    pub latency: Mutex<Summary>,
}

impl Metrics {
    pub fn accuracy(&self) -> f64 {
        let l = self.labelled.load(Ordering::Relaxed);
        if l == 0 {
            return f64::NAN;
        }
        self.correct.load(Ordering::Relaxed) as f64 / l as f64
    }

    pub fn throughput(&self, elapsed: Duration) -> f64 {
        self.completed.load(Ordering::Relaxed) as f64 / elapsed.as_secs_f64().max(1e-9)
    }
}

enum WorkerMsg {
    Work(Request),
    Shutdown,
}

/// Multi-worker inference service over cloned [`Menage`] chips.
pub struct Coordinator {
    workers: Vec<JoinHandle<Menage>>,
    senders: Vec<Sender<WorkerMsg>>,
    results_rx: Receiver<Result<Response>>,
    pub metrics: Arc<Metrics>,
    next_id: u64,
    next_worker: usize,
    in_flight: usize,
    started: Instant,
}

impl Coordinator {
    /// Spawn `num_workers` workers, each owning a clone of `chip`.
    pub fn new(chip: &Menage, num_workers: usize) -> Self {
        assert!(num_workers > 0);
        let metrics = Arc::new(Metrics::default());
        let (results_tx, results_rx) = mpsc::channel::<Result<Response>>();
        let mut workers = Vec::with_capacity(num_workers);
        let mut senders = Vec::with_capacity(num_workers);
        for _ in 0..num_workers {
            let (tx, rx) = mpsc::channel::<WorkerMsg>();
            let results_tx = results_tx.clone();
            let metrics = Arc::clone(&metrics);
            let mut chip = chip.clone();
            workers.push(std::thread::spawn(move || {
                while let Ok(msg) = rx.recv() {
                    match msg {
                        WorkerMsg::Shutdown => break,
                        WorkerMsg::Work(req) => {
                            let t0 = Instant::now();
                            let res = chip.run(&req.input).map(|out| {
                                let predicted = out.predicted_class();
                                let sim_latency = t0.elapsed();
                                metrics.completed.fetch_add(1, Ordering::Relaxed);
                                metrics
                                    .total_cycles
                                    .fetch_add(out.cycles, Ordering::Relaxed);
                                if let Some(label) = req.label {
                                    metrics.labelled.fetch_add(1, Ordering::Relaxed);
                                    if label == predicted {
                                        metrics.correct.fetch_add(1, Ordering::Relaxed);
                                    }
                                }
                                metrics
                                    .latency
                                    .lock()
                                    .unwrap()
                                    .add(sim_latency.as_secs_f64());
                                Response {
                                    id: req.id,
                                    predicted,
                                    cycles: out.cycles,
                                    sim_latency,
                                    label: req.label,
                                }
                            });
                            if results_tx.send(res).is_err() {
                                break; // coordinator dropped
                            }
                        }
                    }
                }
                chip
            }));
            senders.push(tx);
        }
        Self {
            workers,
            senders,
            results_rx,
            metrics,
            next_id: 0,
            next_worker: 0,
            in_flight: 0,
            started: Instant::now(),
        }
    }

    /// Submit a request (round-robin across workers). Returns its id.
    pub fn submit(&mut self, input: SpikeTrain, label: Option<usize>) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        let w = self.next_worker;
        self.next_worker = (self.next_worker + 1) % self.senders.len();
        self.senders[w]
            .send(WorkerMsg::Work(Request { id, input, label }))
            .expect("worker channel closed");
        self.in_flight += 1;
        id
    }

    /// Block until one result is available.
    pub fn recv(&mut self) -> Result<Response> {
        let res = self
            .results_rx
            .recv()
            .map_err(|_| anyhow!("all workers terminated"))??;
        self.in_flight -= 1;
        Ok(res)
    }

    /// Drain all in-flight requests.
    pub fn drain(&mut self) -> Result<Vec<Response>> {
        let mut out = Vec::with_capacity(self.in_flight);
        while self.in_flight > 0 {
            out.push(self.recv()?);
        }
        out.sort_by_key(|r| r.id);
        Ok(out)
    }

    /// Submit a whole labelled batch and wait for every result.
    pub fn run_batch(
        &mut self,
        inputs: Vec<(SpikeTrain, Option<usize>)>,
    ) -> Result<Vec<Response>> {
        for (input, label) in inputs {
            self.submit(input, label);
        }
        self.drain()
    }

    /// Requests/sec since construction.
    pub fn throughput(&self) -> f64 {
        self.metrics.throughput(self.started.elapsed())
    }

    /// Shut down workers and return their chips (with accumulated stats);
    /// the first chip's statistics cover ~1/W of the traffic each.
    pub fn shutdown(self) -> Vec<Menage> {
        for tx in &self.senders {
            let _ = tx.send(WorkerMsg::Shutdown);
        }
        self.workers
            .into_iter()
            .map(|h| h.join().expect("worker panicked"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analog::AnalogParams;
    use crate::config::{AcceleratorConfig, ModelConfig};
    use crate::mapping::Strategy;
    use crate::snn::{reference_forward, QuantNetwork};
    use crate::util::rng::Rng;

    fn test_chip() -> (Menage, QuantNetwork) {
        let mcfg = ModelConfig {
            name: "c".into(),
            layer_sizes: vec![30, 16, 8],
            timesteps: 6,
            beta: 0.9,
            v_threshold: 1.0,
            v_reset: 0.0,
        };
        let mut cfg = AcceleratorConfig::accel1();
        cfg.num_cores = 2;
        cfg.a_neurons_per_core = 4;
        cfg.a_syns_per_core = 4;
        cfg.virtual_per_a_neuron = 4;
        let mut rng = Rng::new(8);
        let net = QuantNetwork::random(&mcfg, 0.5, &mut rng);
        let chip =
            Menage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 2).unwrap();
        (chip, net)
    }

    fn inputs(n: usize) -> Vec<(SpikeTrain, Option<usize>)> {
        (0..n)
            .map(|s| {
                let mut rng = Rng::new(1000 + s as u64);
                let mut st = SpikeTrain::new(30, 6);
                for step in st.spikes.iter_mut() {
                    for i in 0..30 {
                        if rng.bernoulli(0.25) {
                            step.push(i as u32);
                        }
                    }
                }
                (st, Some(s % 8))
            })
            .collect()
    }

    #[test]
    fn batch_completes_and_orders() {
        let (chip, _) = test_chip();
        let mut coord = Coordinator::new(&chip, 3);
        let res = coord.run_batch(inputs(20)).unwrap();
        assert_eq!(res.len(), 20);
        for (i, r) in res.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert!(r.predicted < 8);
            assert!(r.cycles > 0);
        }
        assert_eq!(coord.metrics.completed.load(Ordering::Relaxed), 20);
        assert!(coord.throughput() > 0.0);
        let chips = coord.shutdown();
        assert_eq!(chips.len(), 3);
        let total: u64 = chips.iter().map(|c| c.inputs_processed).sum();
        assert_eq!(total, 20);
    }

    #[test]
    fn results_match_reference_regardless_of_worker() {
        let (chip, net) = test_chip();
        let mut coord = Coordinator::new(&chip, 4);
        let ins = inputs(12);
        let golden: Vec<usize> = ins
            .iter()
            .map(|(st, _)| reference_forward(&net, st).unwrap().predicted_class())
            .collect();
        let res = coord.run_batch(ins).unwrap();
        for (r, g) in res.iter().zip(&golden) {
            assert_eq!(r.predicted, *g, "request {}", r.id);
        }
        coord.shutdown();
    }

    #[test]
    fn metrics_accuracy_accounting() {
        let (chip, net) = test_chip();
        let mut coord = Coordinator::new(&chip, 2);
        // Label every input with the reference prediction → accuracy 1.0.
        let ins: Vec<(SpikeTrain, Option<usize>)> = inputs(10)
            .into_iter()
            .map(|(st, _)| {
                let label = reference_forward(&net, &st).unwrap().predicted_class();
                (st, Some(label))
            })
            .collect();
        coord.run_batch(ins).unwrap();
        assert_eq!(coord.metrics.accuracy(), 1.0);
        assert_eq!(coord.metrics.labelled.load(Ordering::Relaxed), 10);
        let lat = coord.metrics.latency.lock().unwrap().clone();
        assert_eq!(lat.count(), 10);
        coord.shutdown();
    }

    #[test]
    fn single_worker_is_deterministic() {
        let (chip, _) = test_chip();
        let run = |chip: &Menage| {
            let mut coord = Coordinator::new(chip, 1);
            let res = coord.run_batch(inputs(6)).unwrap();
            coord.shutdown();
            res.iter().map(|r| (r.predicted, r.cycles)).collect::<Vec<_>>()
        };
        assert_eq!(run(&chip), run(&chip));
    }
}
