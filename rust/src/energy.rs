//! Energy/performance model — produces the TOPS/W numbers of Table II.
//!
//! The paper extracts digital power from Synopsys DC (90 nm) and analog
//! power from HSpice, then reports end-to-end energy efficiency:
//! 3.4 TOPS/W (Accel₁ / N-MNIST) and 12.1 TOPS/W (Accel₂ / CIFAR10-DVS).
//! We replace the EDA flow with an explicit per-component energy budget
//! (DESIGN.md §2): every counted operation of the cycle-accurate simulator
//! is priced with a 90 nm-plausible constant, and the constants are
//! calibrated (once, globally — not per experiment) so the two headline
//! design points land near the paper's numbers. Baseline rows of Table II
//! are the *published* numbers, exactly as the paper cites them.
//!
//! Why Accel₂ is more efficient than Accel₁ despite the bigger memories:
//! wider MEM_S&N rows drive 20 A-SYN/A-NEURON columns per row read instead
//! of 10, and CIFAR10-DVS's much higher event rate amortizes the
//! controller/static overhead over ~50× more MACs per step — both effects
//! fall straight out of the budget below.

use crate::accel::Menage;

/// Per-component energy constants (Joules) and static power (Watts).
#[derive(Debug, Clone, PartialEq)]
pub struct EnergyModel {
    /// A-SYN C2C MAC (ladder charge + polarity stage).
    pub e_mac: f64,
    /// A-NEURON operation (integrate or sweep; paper op point 0.652 fJ).
    pub e_neuron_op: f64,
    /// Weight SRAM read, per 8-bit weight.
    pub e_weight_read: f64,
    /// MEM_S&N row read, per engine column (scales with M).
    pub e_sn_col_read: f64,
    /// MEM_E2A lookup per dispatched event.
    pub e_e2a_read: f64,
    /// MEM_E push+pop per event.
    pub e_event_mem: f64,
    /// Controller FSM + clock tree, per active cycle per core.
    pub e_ctrl_cycle: f64,
    /// Static (leakage) power per MX-NEURACORE.
    pub p_static_core: f64,
    /// Clock period (s).
    pub clock_period: f64,
    /// Real-time duration of one global time step (s). Event-based
    /// recordings play out in real time (a DVS bins events over tens of
    /// microseconds); the chip burns leakage over the whole recording,
    /// not just the busy cycles — this is what makes the sparse N-MNIST
    /// workload less efficient (3.4 TOPS/W) than the dense CIFAR10-DVS
    /// one (12.1) in the paper.
    pub timestep_real: f64,
}

impl EnergyModel {
    /// 90 nm-calibrated constants (see module docs; calibration recorded in
    /// EXPERIMENTS.md §Table II).
    pub fn paper_90nm(clock_hz: f64) -> Self {
        Self {
            e_mac: 0.30e-15,
            e_neuron_op: 97e-9 * 6.72e-9,
            e_weight_read: 120e-15,
            e_sn_col_read: 12.0e-15,
            e_e2a_read: 35e-15,
            e_event_mem: 20e-15,
            e_ctrl_cycle: 140e-15,
            p_static_core: 10e-6,
            clock_period: 1.0 / clock_hz,
            timestep_real: 50e-6,
        }
    }
}

/// Energy breakdown of a finished run (Joules).
#[derive(Debug, Clone, Default)]
pub struct EnergyBreakdown {
    pub analog_mac: f64,
    pub analog_neuron: f64,
    pub weight_sram: f64,
    pub sn_sram: f64,
    pub e2a_sram: f64,
    pub event_mem: f64,
    pub controller: f64,
    pub static_leak: f64,
}

impl EnergyBreakdown {
    pub fn total(&self) -> f64 {
        self.analog_mac
            + self.analog_neuron
            + self.weight_sram
            + self.sn_sram
            + self.e2a_sram
            + self.event_mem
            + self.controller
            + self.static_leak
    }
}

/// Full efficiency report for a workload run on a [`Menage`] chip.
#[derive(Debug, Clone)]
pub struct EfficiencyReport {
    pub breakdown: EnergyBreakdown,
    /// Total synaptic operations (MAC counted as 2 ops: multiply + add —
    /// the standard TOPS accounting).
    pub total_ops: u64,
    /// Wall-clock seconds at the modeled clock.
    pub seconds: f64,
    /// Tera-operations per second.
    pub tops: f64,
    /// Tera-operations per second per Watt (the paper's headline metric).
    pub tops_per_watt: f64,
    /// Average power (W).
    pub avg_power: f64,
}

/// Price a chip's accumulated statistics with the energy model.
pub fn report(chip: &Menage, model: &EnergyModel) -> EfficiencyReport {
    let mut b = EnergyBreakdown::default();
    let mut total_cycles_busy = 0u64;
    let mut max_core_cycles = 0u64;
    for core in &chip.cores {
        let s = &core.stats;
        b.analog_mac += s.macs as f64 * model.e_mac;
        b.analog_neuron +=
            (s.integrations + s.fire_ops) as f64 * model.e_neuron_op;
        b.weight_sram += s.macs as f64 * model.e_weight_read;
        b.sn_sram +=
            s.sn_rows_read as f64 * model.e_sn_col_read * chip.config.a_neurons_per_core as f64;
        b.e2a_sram += s.events_dispatched as f64 * model.e_e2a_read;
        b.event_mem += s.events_dispatched as f64 * model.e_event_mem;
        b.controller += s.cycles as f64 * model.e_ctrl_cycle;
        total_cycles_busy += s.cycles;
        max_core_cycles = max_core_cycles.max(s.cycles);
    }
    // Busy (compute) time: cores run concurrently, set by the busiest core.
    let seconds = max_core_cycles as f64 * model.clock_period;
    let _ = total_cycles_busy;
    // Static leakage burns over the *real-time* duration of the event
    // streams (see EnergyModel::timestep_real), in all cores.
    let realtime = chip.inputs_processed as f64
        * chip.timesteps as f64
        * model.timestep_real;
    b.static_leak =
        model.p_static_core * chip.cores.len() as f64 * realtime.max(seconds);

    let total_ops = 2 * chip.total_macs();
    let energy = b.total();
    let tops = if seconds > 0.0 { total_ops as f64 / seconds / 1e12 } else { 0.0 };
    let tops_per_watt = if energy > 0.0 { total_ops as f64 / energy / 1e12 } else { 0.0 };
    let avg_power = if seconds > 0.0 { energy / seconds } else { 0.0 };
    EfficiencyReport { breakdown: b, total_ops, seconds, tops, tops_per_watt, avg_power }
}

/// One row of Table II.
#[derive(Debug, Clone)]
pub struct Table2Row {
    pub author: &'static str,
    pub neural_ops: &'static str,
    pub tops_per_watt: String,
    pub bit_width: &'static str,
    pub technology: &'static str,
    pub dataset: &'static str,
    pub neurons: &'static str,
}

/// The published prior-work rows of Table II (cited, not simulated — the
/// paper compares against reported numbers too).
pub fn table2_baselines() -> Vec<Table2Row> {
    vec![
        Table2Row {
            author: "Liu et al. 2023 [29]",
            neural_ops: "Mixed Signal LIF",
            tops_per_watt: "1.88".into(),
            bit_width: "4",
            technology: "180nm",
            dataset: "MIT-BIH Arrhythmia",
            neurons: "102",
        },
        Table2Row {
            author: "Qi et al. 2024 [36]",
            neural_ops: "Mixed Signal LIF",
            tops_per_watt: "0.67-5.4".into(),
            bit_width: "8",
            technology: "55nm",
            dataset: "N/A",
            neurons: "128-256",
        },
        Table2Row {
            author: "Zhang et al. 2024 [37]",
            neural_ops: "Digital LIF",
            tops_per_watt: "0.66".into(),
            bit_width: "8-10",
            technology: "28nm",
            dataset: "N-MNIST, DVS-Gesture, N-TIDIGIT, SeNic",
            neurons: "522",
        },
        Table2Row {
            author: "Liu et al. 2024 [38]",
            neural_ops: "Digital LIF",
            tops_per_watt: "0.26".into(),
            bit_width: "N/A",
            technology: "22nm",
            dataset: "N-MNIST, DVS-Gesture",
            neurons: "N/A",
        },
    ]
}

/// Paper-reported MENAGE rows (targets for the reproduction).
pub const PAPER_ACCEL1_TOPS_W: f64 = 3.4;
pub const PAPER_ACCEL2_TOPS_W: f64 = 12.1;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analog::AnalogParams;
    use crate::config::{AcceleratorConfig, ModelConfig};
    use crate::mapping::Strategy;
    use crate::snn::{QuantNetwork, SpikeTrain};
    use crate::util::rng::Rng;

    fn run_workload(m: usize, n: usize, rate: f64) -> (Menage, EnergyModel) {
        let mcfg = ModelConfig {
            name: "w".into(),
            layer_sizes: vec![40, 24, 8],
            timesteps: 10,
            beta: 0.9,
            v_threshold: 1.0,
            v_reset: 0.0,
        };
        let mut cfg = AcceleratorConfig::accel1();
        cfg.num_cores = 2;
        cfg.a_neurons_per_core = m;
        cfg.a_syns_per_core = m;
        cfg.virtual_per_a_neuron = n;
        let mut rng = Rng::new(5);
        let net = QuantNetwork::random(&mcfg, 0.5, &mut rng);
        let mut chip =
            Menage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 3).unwrap();
        let mut input = SpikeTrain::new(40, 10);
        let mut r2 = Rng::new(9);
        for step in input.spikes.iter_mut() {
            for i in 0..40 {
                if r2.bernoulli(rate) {
                    step.push(i as u32);
                }
            }
        }
        chip.run(&input).unwrap();
        let model = EnergyModel::paper_90nm(cfg.clock_hz);
        (chip, model)
    }

    #[test]
    fn report_is_consistent() {
        let (chip, model) = run_workload(4, 6, 0.3);
        let r = report(&chip, &model);
        assert!(r.breakdown.total() > 0.0);
        assert_eq!(r.total_ops, 2 * chip.total_macs());
        assert!(r.seconds > 0.0);
        assert!(r.tops > 0.0);
        assert!(r.tops_per_watt > 0.0);
        // P = E/t consistency.
        assert!((r.avg_power - r.breakdown.total() / r.seconds).abs() < 1e-12);
        // TOPS/W = TOPS / P.
        assert!((r.tops_per_watt - r.tops / r.avg_power).abs() / r.tops_per_watt < 1e-9);
    }

    #[test]
    fn higher_activity_is_more_efficient() {
        // More MACs per cycle amortize controller + static overhead — the
        // effect behind Accel₂ > Accel₁ in the paper.
        let (quiet, model) = run_workload(4, 6, 0.05);
        let (busy, _) = run_workload(4, 6, 0.6);
        let rq = report(&quiet, &model);
        let rb = report(&busy, &model);
        assert!(
            rb.tops_per_watt > rq.tops_per_watt,
            "busy {} ≤ quiet {}",
            rb.tops_per_watt,
            rq.tops_per_watt
        );
    }

    #[test]
    fn tops_per_watt_in_plausible_range() {
        let (chip, model) = run_workload(8, 8, 0.4);
        let r = report(&chip, &model);
        // Mixed-signal neuromorphic designs land between ~0.1 and ~100
        // TOPS/W; the calibrated budget must stay in that decade band.
        assert!(
            r.tops_per_watt > 0.1 && r.tops_per_watt < 100.0,
            "TOPS/W = {}",
            r.tops_per_watt
        );
    }

    #[test]
    fn baselines_match_paper_table2() {
        let rows = table2_baselines();
        assert_eq!(rows.len(), 4);
        assert_eq!(rows[0].tops_per_watt, "1.88");
        assert_eq!(rows[2].technology, "28nm");
        assert_eq!(PAPER_ACCEL1_TOPS_W, 3.4);
        assert_eq!(PAPER_ACCEL2_TOPS_W, 12.1);
    }

    #[test]
    fn zero_work_report_is_finite() {
        let mcfg = ModelConfig {
            name: "z".into(),
            layer_sizes: vec![10, 4],
            timesteps: 2,
            beta: 0.9,
            v_threshold: 1.0,
            v_reset: 0.0,
        };
        let mut cfg = AcceleratorConfig::accel1();
        cfg.num_cores = 1;
        cfg.a_neurons_per_core = 2;
        cfg.a_syns_per_core = 2;
        cfg.virtual_per_a_neuron = 2;
        let mut rng = Rng::new(1);
        let net = QuantNetwork::random(&mcfg, 0.5, &mut rng);
        let chip =
            Menage::build(&net, &cfg, Strategy::Greedy, &AnalogParams::ideal(), 1).unwrap();
        let model = EnergyModel::paper_90nm(cfg.clock_hz);
        let r = report(&chip, &model);
        assert_eq!(r.total_ops, 0);
        assert!(r.tops_per_watt == 0.0 && r.tops == 0.0);
        assert!(r.breakdown.total() >= 0.0);
    }
}
