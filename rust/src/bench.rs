//! In-tree micro/macro-bench harness (criterion is unavailable offline).
//!
//! Each `benches/*.rs` target is a plain `fn main()` that uses [`Bencher`]
//! for timing and [`Table`] to print the paper's tables/figure series in a
//! stable, grep-able format. Output conventions:
//!
//! * `BENCH <name> mean=<t> p50=<t> p99=<t> iters=<n>` — timing lines
//! * aligned ASCII tables for the paper artifacts (Table I/II)
//! * `SERIES <name> x=[..] y=[..]` — figure series (Figures 5–7), also
//!   dumped as JSON next to the bench output when `MENAGE_BENCH_DIR` is set.

use std::time::{Duration, Instant};

use crate::util::json::Json;
use crate::util::stats::Quantiles;

/// Format a duration compactly (ns/µs/ms/s).
pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Timing result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub p50: Duration,
    pub p99: Duration,
    pub min: Duration,
}

impl BenchResult {
    /// Mean throughput given `items` processed per iteration.
    pub fn throughput(&self, items: f64) -> f64 {
        items / self.mean.as_secs_f64()
    }

    /// How many times faster this result's mean is than `baseline`'s
    /// (>1 means `self` is faster). Both must do the same work per
    /// iteration for the ratio to be meaningful.
    pub fn speedup_over(&self, baseline: &BenchResult) -> f64 {
        baseline.mean.as_secs_f64() / self.mean.as_secs_f64().max(1e-12)
    }
}

/// Adaptive-iteration bencher: warms up, then runs until `budget` elapses
/// (min 10 / max `max_iters` iterations), reporting the distribution.
pub struct Bencher {
    pub warmup: Duration,
    pub budget: Duration,
    pub max_iters: u64,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            budget: Duration::from_secs(2),
            max_iters: 1_000_000,
        }
    }
}

impl Bencher {
    /// Quick preset for CI-ish runs (also used by `cargo test` smoke tests).
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(10),
            budget: Duration::from_millis(200),
            max_iters: 10_000,
        }
    }

    /// Benchmark `f`, which must do one unit of work per call. The closure's
    /// return value is passed through `std::hint::black_box`.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // Measure.
        let mut q = Quantiles::new();
        let mut total = Duration::ZERO;
        let mut iters = 0u64;
        let start = Instant::now();
        while (start.elapsed() < self.budget || iters < 10) && iters < self.max_iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            let dt = t0.elapsed();
            q.add(dt.as_secs_f64());
            total += dt;
            iters += 1;
        }
        let res = BenchResult {
            name: name.to_string(),
            iters,
            mean: Duration::from_secs_f64(total.as_secs_f64() / iters as f64),
            p50: Duration::from_secs_f64(q.quantile(0.5)),
            p99: Duration::from_secs_f64(q.quantile(0.99)),
            min: Duration::from_secs_f64(q.quantile(0.0)),
        };
        println!(
            "BENCH {name} mean={} p50={} p99={} min={} iters={}",
            fmt_duration(res.mean),
            fmt_duration(res.p50),
            fmt_duration(res.p99),
            fmt_duration(res.min),
            res.iters
        );
        res
    }
}

/// Aligned ASCII table printer for the paper's tables.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Self {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                let pad = widths[i] - c.chars().count();
                s.push(' ');
                s.push_str(c);
                s.push_str(&" ".repeat(pad + 1));
                s.push('|');
            }
            s
        };
        let sep = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        println!("\n== {} ==", self.title);
        println!("{sep}");
        println!("{}", line(&self.headers));
        println!("{sep}");
        for r in &self.rows {
            println!("{}", line(r));
        }
        println!("{sep}");
    }
}

/// Persist a machine-readable bench artifact: writes `filename` into
/// `MENAGE_BENCH_DIR` (if set) or the current directory. Used for the
/// cross-PR perf trajectory (`BENCH_hotpath.json`). Errors are printed,
/// not fatal — benches must not die on a read-only checkout.
pub fn emit_json_file(filename: &str, j: &Json) {
    let dir = std::env::var("MENAGE_BENCH_DIR").unwrap_or_else(|_| ".".into());
    let path = std::path::Path::new(&dir).join(filename);
    let _ = std::fs::create_dir_all(&dir);
    match std::fs::write(&path, j.to_string()) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("warning: could not write {}: {e}", path.display()),
    }
}

/// Print (and optionally persist) a figure series.
pub fn emit_series(name: &str, x: &[f64], y: &[f64]) {
    assert_eq!(x.len(), y.len());
    let xs: Vec<String> = x.iter().map(|v| format!("{v:.4}")).collect();
    let ys: Vec<String> = y.iter().map(|v| format!("{v:.6}")).collect();
    println!("SERIES {name} x=[{}] y=[{}]", xs.join(","), ys.join(","));
    if let Ok(dir) = std::env::var("MENAGE_BENCH_DIR") {
        let j = Json::obj(vec![
            ("name", name.into()),
            ("x", Json::arr_f64(x)),
            ("y", Json::arr_f64(y)),
        ]);
        let path = std::path::Path::new(&dir).join(format!("{name}.json"));
        let _ = std::fs::create_dir_all(&dir);
        let _ = std::fs::write(path, j.to_string());
    }
}

/// Render a series as an ASCII sparkline chart (for bench stdout).
pub fn ascii_chart(name: &str, y: &[f64], height: usize) -> String {
    if y.is_empty() {
        return format!("{name}: (empty)\n");
    }
    let max = y.iter().cloned().fold(f64::NEG_INFINITY, f64::max).max(1e-12);
    let min = y.iter().cloned().fold(f64::INFINITY, f64::min).min(0.0);
    let span = (max - min).max(1e-12);
    let mut out = format!("{name} (min={min:.3}, max={max:.3})\n");
    for row in (0..height).rev() {
        let lo = min + span * row as f64 / height as f64;
        let mut line = String::new();
        for &v in y {
            line.push(if v >= lo + span / (2.0 * height as f64) && v > min {
                '█'
            } else if v >= lo {
                '▄'
            } else {
                ' '
            });
        }
        out.push_str(&format!("{lo:>10.3} |{line}\n"));
    }
    out.push_str(&format!("{:>10} +{}\n", "", "-".repeat(y.len())));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bencher_measures_work() {
        let b = Bencher {
            warmup: Duration::from_millis(1),
            budget: Duration::from_millis(20),
            max_iters: 1000,
        };
        let r = b.run("noop", || 1 + 1);
        assert!(r.iters >= 10);
        assert!(r.mean >= Duration::ZERO);
        assert!(r.p99 >= r.p50);
        let tp = r.throughput(100.0);
        assert!(tp > 0.0);
    }

    #[test]
    fn speedup_ratio() {
        let mk = |ns: u64| BenchResult {
            name: "x".into(),
            iters: 10,
            mean: Duration::from_nanos(ns),
            p50: Duration::from_nanos(ns),
            p99: Duration::from_nanos(ns),
            min: Duration::from_nanos(ns),
        };
        let fast = mk(100);
        let slow = mk(400);
        assert!((fast.speedup_over(&slow) - 4.0).abs() < 1e-9);
        assert!((slow.speedup_over(&fast) - 0.25).abs() < 1e-9);
    }

    #[test]
    fn table_prints_aligned() {
        let mut t = Table::new("Test", &["a", "bb"]);
        t.row(&["1".into(), "2".into()]);
        t.row(&["333".into(), "4".into()]);
        t.print(); // visually checked; assert no panic + shape
        assert_eq!(t.rows.len(), 2);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("T", &["a"]);
        t.row(&["1".into(), "2".into()]);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(Duration::from_nanos(500)), "500ns");
        assert_eq!(fmt_duration(Duration::from_micros(1500)), "1.50ms");
        assert_eq!(fmt_duration(Duration::from_millis(2500)), "2.500s");
    }

    #[test]
    fn chart_renders() {
        let s = ascii_chart("spikes", &[0.0, 0.5, 1.0, 0.25], 4);
        assert!(s.contains("spikes"));
        assert!(s.lines().count() >= 5);
        assert_eq!(ascii_chart("e", &[], 3), "e: (empty)\n");
    }

    #[test]
    fn emit_series_runs() {
        emit_series("test_series", &[0.0, 1.0], &[2.0, 3.0]);
    }
}
