//! Memory-utilization and event traces (paper Figures 6–7).
//!
//! Figures 6 and 7 plot the *average MEM_S&N memory usage* per time step
//! while one input streams through Accel₁ (N-MNIST) / Accel₂
//! (CIFAR10-DVS), per layer. In our simulator the equivalent quantity is
//! the number of MEM_S&N rows the controller touches in each step,
//! converted to kilobytes with the row width of the configured core
//! (per-engine column: NI bit + virtual index + weight address).

use crate::accel::Menage;
use crate::config::AcceleratorConfig;
use crate::util::json::Json;

/// MEM_S&N row width in bytes for a given accelerator config: per engine
/// column — NI flag (1 bit), virtual-neuron index (⌈log₂N⌉ bits), weight
/// address (⌈log₂ weight-capacity⌉ bits) — times M columns.
pub fn sn_row_bytes(cfg: &AcceleratorConfig) -> f64 {
    let virt_bits = (cfg.virtual_per_a_neuron.max(2) as f64).log2().ceil();
    let addr_bits = (cfg.weight_capacity().max(2) as f64).log2().ceil();
    let col_bits = 1.0 + virt_bits + addr_bits;
    col_bits * cfg.a_neurons_per_core as f64 / 8.0
}

/// Utilization series of one core: KB of MEM_S&N touched per time step.
#[derive(Debug, Clone)]
pub struct CoreTrace {
    pub core: usize,
    /// KB touched per time step (averaged across inputs when aggregated).
    pub kb_per_step: Vec<f64>,
}

/// The full Figures 6–7 artifact: one series per MX-NEURACORE.
#[derive(Debug, Clone)]
pub struct MemoryTrace {
    pub accel_name: String,
    pub dataset: String,
    pub cores: Vec<CoreTrace>,
    /// Number of inputs averaged over.
    pub samples: usize,
}

impl MemoryTrace {
    /// Extract the per-step series from a chip's accumulated statistics,
    /// averaging over `samples` inputs of `timesteps` steps each.
    ///
    /// The chip's `sn_rows_touched_per_step` is a flat history across all
    /// inputs; it is folded modulo `timesteps`.
    pub fn from_chip(
        chip: &Menage,
        dataset: &str,
        timesteps: usize,
        samples: usize,
    ) -> Self {
        let row_kb = sn_row_bytes(&chip.config) / 1024.0;
        let cores = chip
            .cores
            .iter()
            .map(|core| {
                let mut acc = vec![0.0f64; timesteps];
                let mut cnt = vec![0u32; timesteps];
                for (i, &rows) in core.stats.sn_rows_touched_per_step.iter().enumerate() {
                    let t = i % timesteps;
                    acc[t] += rows as f64 * row_kb;
                    cnt[t] += 1;
                }
                for (a, &c) in acc.iter_mut().zip(&cnt) {
                    if c > 0 {
                        *a /= c as f64;
                    }
                }
                CoreTrace { core: core.index, kb_per_step: acc }
            })
            .collect();
        Self {
            accel_name: chip.config.name.clone(),
            dataset: dataset.to_string(),
            cores,
            samples,
        }
    }

    /// Mean utilization across steps and cores (headline summary).
    pub fn mean_kb(&self) -> f64 {
        let mut sum = 0.0;
        let mut n = 0usize;
        for c in &self.cores {
            for &v in &c.kb_per_step {
                sum += v;
                n += 1;
            }
        }
        if n == 0 {
            0.0
        } else {
            sum / n as f64
        }
    }

    /// Peak utilization across steps and cores.
    pub fn peak_kb(&self) -> f64 {
        self.cores
            .iter()
            .flat_map(|c| c.kb_per_step.iter().copied())
            .fold(0.0, f64::max)
    }

    /// Export as JSON (one object per core with x = step, y = KB).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("accel", self.accel_name.as_str().into()),
            ("dataset", self.dataset.as_str().into()),
            ("samples", self.samples.into()),
            (
                "cores",
                Json::Arr(
                    self.cores
                        .iter()
                        .map(|c| {
                            Json::obj(vec![
                                ("core", c.core.into()),
                                ("kb_per_step", Json::arr_f64(&c.kb_per_step)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analog::AnalogParams;
    use crate::config::ModelConfig;
    use crate::mapping::Strategy;
    use crate::snn::{QuantNetwork, SpikeTrain};
    use crate::util::rng::Rng;

    #[test]
    fn row_bytes_reflects_config() {
        let a1 = AcceleratorConfig::accel1();
        let a2 = AcceleratorConfig::accel2();
        let b1 = sn_row_bytes(&a1);
        let b2 = sn_row_bytes(&a2);
        // Accel2 has twice the columns and wider fields — rows are bigger.
        assert!(b2 > b1, "{b2} ≤ {b1}");
        // Accel1: 10 cols × (1 + 4 + ~19 bits) / 8 ≈ 30 B.
        assert!(b1 > 10.0 && b1 < 100.0, "{b1}");
    }

    fn chip_with_history(samples: usize, timesteps: usize) -> Menage {
        let mcfg = ModelConfig {
            name: "t".into(),
            layer_sizes: vec![30, 12, 6],
            timesteps,
            beta: 0.9,
            v_threshold: 1.0,
            v_reset: 0.0,
        };
        let mut cfg = AcceleratorConfig::accel1();
        cfg.num_cores = 2;
        cfg.a_neurons_per_core = 3;
        cfg.a_syns_per_core = 3;
        cfg.virtual_per_a_neuron = 4;
        let mut rng = Rng::new(4);
        let net = QuantNetwork::random(&mcfg, 0.5, &mut rng);
        let mut chip =
            Menage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 1).unwrap();
        for s in 0..samples {
            let mut input = SpikeTrain::new(30, timesteps);
            let mut r = Rng::new(100 + s as u64);
            for step in input.spikes.iter_mut() {
                for i in 0..30 {
                    if r.bernoulli(0.25) {
                        step.push(i as u32);
                    }
                }
            }
            chip.run(&input).unwrap();
        }
        chip
    }

    #[test]
    fn trace_shapes_and_averaging() {
        let chip = chip_with_history(3, 6);
        let tr = MemoryTrace::from_chip(&chip, "syn", 6, 3);
        assert_eq!(tr.cores.len(), 2);
        for c in &tr.cores {
            assert_eq!(c.kb_per_step.len(), 6);
        }
        assert!(tr.mean_kb() > 0.0);
        assert!(tr.peak_kb() >= tr.mean_kb());
    }

    #[test]
    fn json_export_roundtrips() {
        let chip = chip_with_history(2, 5);
        let tr = MemoryTrace::from_chip(&chip, "syn", 5, 2);
        let j = tr.to_json();
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("accel").unwrap().as_str().unwrap(), "accel1");
        assert_eq!(parsed.get("cores").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn empty_chip_trace_is_zero() {
        let chip = {
            let mcfg = ModelConfig {
                name: "z".into(),
                layer_sizes: vec![10, 4],
                timesteps: 3,
                beta: 0.9,
                v_threshold: 1.0,
                v_reset: 0.0,
            };
            let mut cfg = AcceleratorConfig::accel1();
            cfg.num_cores = 1;
            cfg.a_neurons_per_core = 2;
            cfg.a_syns_per_core = 2;
            cfg.virtual_per_a_neuron = 2;
            let mut rng = Rng::new(1);
            let net = QuantNetwork::random(&mcfg, 0.5, &mut rng);
            Menage::build(&net, &cfg, Strategy::Greedy, &AnalogParams::ideal(), 1).unwrap()
        };
        let tr = MemoryTrace::from_chip(&chip, "none", 3, 0);
        assert_eq!(tr.mean_kb(), 0.0);
        assert_eq!(tr.peak_kb(), 0.0);
    }
}
