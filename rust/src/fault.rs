//! Unified fault-injection subsystem: deterministic hardware faults for
//! the analog simulator, chaos knobs for the serving stack, and the
//! poison-tolerant locking/recovery primitives that make injected faults
//! survivable.
//!
//! # Two planes
//!
//! **Hardware plane** — a seeded [`FaultPlan`] describes the silicon-level
//! defects the memristive/analog-SNN literature identifies as the dominant
//! deployment risk for mixed-signal neuromorphic chips:
//!
//! * *stuck-at synapse rows*: a C2C ladder column is dead — every MEM_S&N
//!   entry driving that A-SYN engine deposits nothing;
//! * *dead neuron slots*: an op-amp failed — the virtual-neuron capacitor's
//!   membrane is frozen, accumulated charge drains away, the neuron never
//!   fires;
//! * *transient MEM_E bit flips*: an event's source id is corrupted with a
//!   single-bit flip at latch time (out-of-range results address no
//!   MEM_E2A entry and are dropped by the dispatcher, exactly like a
//!   malformed input spike);
//! * *analog drift escalation*: the per-deposit analog error term is
//!   scaled by `drift_scale`, modeling aged/hot silicon drifting beyond
//!   its calibration point (non-ideal analog mode only).
//!
//! The plan is *deterministic*: [`FaultPlan::core_faults`] derives each
//! core's defect pattern and transient-fault RNG stream from the plan seed
//! and the core index, so a faulty run is exactly reproducible. An empty
//! plan installs nothing and the engine's hot loops take the identical
//! code path as before — bit-identity with fault-free execution is pinned
//! by the existing differential suites.
//!
//! **System plane** — [`SystemChaos`] gates injectable process-level
//! faults into the serving stack: worker panics every Nth request,
//! dropped/delayed responses, and socket resets mid-frame. All knobs
//! default to off; the production path pays one predicted-false branch.
//!
//! # Recovery primitives
//!
//! [`lock_recover`]/[`recover`] replace bare `lock().unwrap()`: a
//! `Mutex` poisoned by a panicking thread yields its guard instead of
//! cascading the panic into every peer (the data under our mutexes is
//! queue/routing state whose invariants are re-validated by the
//! consumers, not broken mid-transaction by the panic). [`RecoveryStats`]
//! is the shared counter block the coordinator's worker supervision and
//! the serving layer's STATS frame report recovery activity through.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError};

use anyhow::{bail, Result};

use crate::util::json::Json;
use crate::util::rng::Rng;

/// Recover the value inside a poisoned-lock result. A poisoned `Mutex`
/// (or `Condvar` wait) only means *some* thread panicked while holding
/// the guard; the shared state this crate protects (request queues,
/// routing maps, latency histograms) stays structurally valid across a
/// panic, so the guard is safe to use and the alternative — propagating
/// the panic into every thread that ever touches the lock — is exactly
/// the cascade this helper exists to stop.
#[inline]
pub fn recover<T>(r: Result<T, PoisonError<T>>) -> T {
    r.unwrap_or_else(|e| e.into_inner())
}

/// Poison-tolerant `Mutex::lock`: the drop-in replacement for
/// `lock().unwrap()` (see [`recover`]).
#[inline]
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    recover(m.lock())
}

// ---------------------------------------------------------------------
// Hardware plane
// ---------------------------------------------------------------------

/// Chip-level hardware fault specification (module docs). Deterministic:
/// the realized per-core defect patterns are a pure function of
/// `(seed, core index)`.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// Seed for defect placement and transient-fault streams.
    pub seed: u64,
    /// Fraction of A-SYN engines (C2C ladder columns) stuck dead per core,
    /// in `[0, 1]`.
    pub stuck_row_frac: f64,
    /// Fraction of physical virtual-neuron capacitor slots dead per core
    /// (op-amp failure), in `[0, 1]`.
    pub dead_slot_frac: f64,
    /// Per-latched-event probability of a transient single-bit flip in the
    /// event's source id.
    pub bit_flip_p: f64,
    /// Multiplier on the per-deposit analog error term (1.0 = nominal;
    /// only observable in non-ideal analog mode).
    pub drift_scale: f64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        Self { seed: 0, stuck_row_frac: 0.0, dead_slot_frac: 0.0, bit_flip_p: 0.0, drift_scale: 1.0 }
    }
}

impl FaultPlan {
    /// Whether the plan injects nothing (installation is a no-op).
    pub fn is_empty(&self) -> bool {
        self.stuck_row_frac <= 0.0
            && self.dead_slot_frac <= 0.0
            && self.bit_flip_p <= 0.0
            && self.drift_scale == 1.0
    }

    /// Parse the CLI spec: comma-separated `key=value` pairs with keys
    /// `seed`, `stuck`, `dead`, `flip`, `drift` — e.g.
    /// `"seed=9,stuck=0.05,dead=0.02,flip=0.001,drift=2.0"`. Unknown keys
    /// and out-of-range values are errors.
    pub fn parse(spec: &str) -> Result<Self> {
        let mut plan = Self::default();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (k, v) = part
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("--faults: expected key=value, got {part:?}"))?;
            let (k, v) = (k.trim(), v.trim());
            let frac = |name: &str| -> Result<f64> {
                let x: f64 = v.parse().map_err(|_| {
                    anyhow::anyhow!("--faults: {name}={v:?} is not a number")
                })?;
                if !(0.0..=1.0).contains(&x) {
                    bail!("--faults: {name} must be in [0, 1], got {x}");
                }
                Ok(x)
            };
            match k {
                "seed" => {
                    plan.seed = v
                        .parse()
                        .map_err(|_| anyhow::anyhow!("--faults: seed={v:?} is not an integer"))?
                }
                "stuck" => plan.stuck_row_frac = frac("stuck")?,
                "dead" => plan.dead_slot_frac = frac("dead")?,
                "flip" => plan.bit_flip_p = frac("flip")?,
                "drift" => {
                    let x: f64 = v.parse().map_err(|_| {
                        anyhow::anyhow!("--faults: drift={v:?} is not a number")
                    })?;
                    if !x.is_finite() || x < 0.0 {
                        bail!("--faults: drift must be finite and ≥ 0, got {x}");
                    }
                    plan.drift_scale = x;
                }
                other => bail!(
                    "--faults: unknown key {other:?} (valid: seed, stuck, dead, flip, drift)"
                ),
            }
        }
        Ok(plan)
    }

    /// Realize this plan for one core with `engines` A-SYN columns and
    /// `caps_per_engine` capacitors per A-NEURON. Returns `None` when the
    /// plan is empty, so fault-free cores carry no per-event overhead.
    pub fn core_faults(
        &self,
        core_index: usize,
        engines: usize,
        caps_per_engine: usize,
    ) -> Option<CoreFaults> {
        if self.is_empty() {
            return None;
        }
        // Per-core stream: independent of every other core, stable under
        // re-installation (reinstalling the same plan replays the same
        // transient faults — the determinism the chaos suite pins).
        let mut rng = Rng::new(
            self.seed ^ (core_index as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        let stuck_row: Vec<bool> =
            (0..engines).map(|_| rng.bernoulli(self.stuck_row_frac)).collect();
        let dead_slot: Vec<bool> = (0..engines * caps_per_engine)
            .map(|_| rng.bernoulli(self.dead_slot_frac))
            .collect();
        Some(CoreFaults {
            stuck_row,
            dead_slot,
            bit_flip_p: self.bit_flip_p,
            drift_scale: self.drift_scale,
            rng,
        })
    }
}

/// Realized hardware faults of one core (see [`FaultPlan::core_faults`]).
#[derive(Debug, Clone)]
pub struct CoreFaults {
    /// `stuck_row[j]`: A-SYN engine `j`'s C2C ladder is dead — its
    /// deposits are suppressed.
    pub stuck_row: Vec<bool>,
    /// `dead_slot[slot]` for physical slot `j·N + k`: the op-amp is dead —
    /// membrane frozen, accumulated charge discarded, never fires. The
    /// physical capacitor is reused by every mapping round, so the defect
    /// applies to all rounds.
    pub dead_slot: Vec<bool>,
    /// Per-event transient bit-flip probability at MEM_E latch time.
    pub bit_flip_p: f64,
    /// Analog error-term multiplier (non-ideal mode only).
    pub drift_scale: f64,
    /// Deterministic stream driving the transient faults.
    pub rng: Rng,
}

impl CoreFaults {
    /// Whether any stuck row is present (cheap gate for the deposit loop).
    pub fn any_stuck(&self) -> bool {
        self.stuck_row.iter().any(|&b| b)
    }

    /// Whether any dead slot is present (cheap gate for the sweep loop).
    pub fn any_dead(&self) -> bool {
        self.dead_slot.iter().any(|&b| b)
    }
}

// ---------------------------------------------------------------------
// System plane
// ---------------------------------------------------------------------

/// Config-gated chaos injection for the serving stack. All knobs are
/// "every Nth occurrence" counters with 0 = disabled; the production
/// default is fully off.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct SystemChaos {
    /// Panic a coordinator worker after every Nth request it begins
    /// processing (0 = off).
    pub worker_panic_every: u64,
    /// Drop every Nth completed response at the router instead of writing
    /// it to the client (0 = off) — the client sees a lost reply.
    pub drop_response_every: u64,
    /// Delay every Nth completed response by [`Self::delay_ms`] before
    /// writing it (0 = off).
    pub delay_response_every: u64,
    /// Delay applied by `delay_response_every`, in milliseconds.
    pub delay_ms: u64,
    /// Reset (short-write then sever) every Nth connection's socket after
    /// a response frame (0 = off).
    pub reset_conn_every: u64,
}

impl SystemChaos {
    /// Whether any knob is armed.
    pub fn enabled(&self) -> bool {
        self.worker_panic_every > 0
            || self.drop_response_every > 0
            || self.delay_response_every > 0
            || self.reset_conn_every > 0
    }

    /// Parse the CLI spec: comma-separated `key=value` pairs with keys
    /// `panic`, `drop`, `delay`, `delay_ms`, `reset` — e.g.
    /// `"panic=40,drop=64,reset=0"`.
    pub fn parse(spec: &str) -> Result<Self> {
        let mut c = Self::default();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (k, v) = part
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("--chaos: expected key=value, got {part:?}"))?;
            let n: u64 = v
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("--chaos: {k}={v:?} is not an integer"))?;
            match k.trim() {
                "panic" => c.worker_panic_every = n,
                "drop" => c.drop_response_every = n,
                "delay" => c.delay_response_every = n,
                "delay_ms" => c.delay_ms = n,
                "reset" => c.reset_conn_every = n,
                other => bail!(
                    "--chaos: unknown key {other:?} (valid: panic, drop, delay, delay_ms, reset)"
                ),
            }
        }
        Ok(c)
    }
}

/// A deterministic "every Nth occurrence" trigger backed by an atomic
/// counter — the shared gate every chaos knob runs through.
#[derive(Debug, Default)]
pub struct ChaosTrigger {
    every: AtomicU64,
    count: AtomicU64,
}

impl ChaosTrigger {
    /// Arm the trigger to fire on every `every`-th [`Self::fire`] call
    /// (0 disarms).
    pub fn arm(&self, every: u64) {
        self.every.store(every, Ordering::Relaxed);
    }

    /// Whether the trigger is armed at all (cheap fast-path gate).
    pub fn armed(&self) -> bool {
        self.every.load(Ordering::Relaxed) > 0
    }

    /// Count one occurrence; returns `true` on every Nth call while armed.
    pub fn fire(&self) -> bool {
        let every = self.every.load(Ordering::Relaxed);
        if every == 0 {
            return false;
        }
        let n = self.count.fetch_add(1, Ordering::Relaxed) + 1;
        n % every == 0
    }
}

// ---------------------------------------------------------------------
// Shared recovery / fault observability
// ---------------------------------------------------------------------

/// Shared fault-and-recovery counters: written by the coordinator's
/// worker supervision and (for the hardware counters) published by
/// workers after each batch, read by the serving layer's STATS frame.
/// All fields are monotonic.
#[derive(Debug, Default)]
pub struct RecoveryStats {
    /// Worker panics observed (injected or real).
    pub worker_panics: AtomicU64,
    /// Worker threads respawned from a pristine backend.
    pub workers_respawned: AtomicU64,
    /// Requests resubmitted after their worker died mid-flight.
    pub requests_resubmitted: AtomicU64,
    /// Requests failed with a typed error after the single retry was
    /// also lost.
    pub requests_failed: AtomicU64,
    /// Hardware plane: deposits suppressed by stuck-at synapse rows.
    pub hw_stuck_row_hits: AtomicU64,
    /// Hardware plane: charge discarded by dead neuron slots.
    pub hw_dead_slot_hits: AtomicU64,
    /// Hardware plane: transient MEM_E bit flips injected.
    pub hw_events_bit_flipped: AtomicU64,
    /// Chaos: worker-panic trigger (armed by [`SystemChaos`] or tests).
    pub panic_trigger: ChaosTrigger,
}

impl RecoveryStats {
    fn get(a: &AtomicU64) -> usize {
        a.load(Ordering::Relaxed) as usize
    }

    /// Add a hardware fault-counter delta (published by workers).
    pub fn add_hw(&self, stuck: u64, dead: u64, flips: u64) {
        if stuck > 0 {
            self.hw_stuck_row_hits.fetch_add(stuck, Ordering::Relaxed);
        }
        if dead > 0 {
            self.hw_dead_slot_hits.fetch_add(dead, Ordering::Relaxed);
        }
        if flips > 0 {
            self.hw_events_bit_flipped.fetch_add(flips, Ordering::Relaxed);
        }
    }

    /// The `recovery` block of the STATS frame.
    pub fn recovery_json(&self) -> Json {
        Json::obj(vec![
            ("worker_panics", Self::get(&self.worker_panics).into()),
            ("workers_respawned", Self::get(&self.workers_respawned).into()),
            ("requests_resubmitted", Self::get(&self.requests_resubmitted).into()),
            ("requests_failed", Self::get(&self.requests_failed).into()),
        ])
    }

    /// The `faults` block of the STATS frame (hardware plane).
    pub fn faults_json(&self) -> Json {
        Json::obj(vec![
            ("stuck_row_hits", Self::get(&self.hw_stuck_row_hits).into()),
            ("dead_slot_hits", Self::get(&self.hw_dead_slot_hits).into()),
            ("events_bit_flipped", Self::get(&self.hw_events_bit_flipped).into()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_installs_nothing() {
        let plan = FaultPlan::default();
        assert!(plan.is_empty());
        assert!(plan.core_faults(0, 4, 4).is_none());
    }

    #[test]
    fn parse_full_spec_and_rejects_garbage() {
        let p = FaultPlan::parse("seed=9, stuck=0.5,dead=0.25,flip=0.001,drift=2.0").unwrap();
        assert_eq!(p.seed, 9);
        assert_eq!(p.stuck_row_frac, 0.5);
        assert_eq!(p.dead_slot_frac, 0.25);
        assert_eq!(p.bit_flip_p, 0.001);
        assert_eq!(p.drift_scale, 2.0);
        assert!(!p.is_empty());
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse("stuck=1.5").is_err());
        assert!(FaultPlan::parse("bogus=1").is_err());
        assert!(FaultPlan::parse("stuck").is_err());
        assert!(FaultPlan::parse("drift=-1").is_err());
    }

    #[test]
    fn core_faults_deterministic_and_per_core_distinct() {
        let plan = FaultPlan { seed: 7, stuck_row_frac: 0.5, dead_slot_frac: 0.5, ..Default::default() };
        let a = plan.core_faults(0, 8, 8).unwrap();
        let b = plan.core_faults(0, 8, 8).unwrap();
        assert_eq!(a.stuck_row, b.stuck_row, "same (seed, core) must realize identically");
        assert_eq!(a.dead_slot, b.dead_slot);
        let c = plan.core_faults(1, 8, 8).unwrap();
        // 16 independent fair coin draws matching across cores is 2^-16;
        // this is a fixed-seed check, not a statistical one.
        assert!(
            a.stuck_row != c.stuck_row || a.dead_slot != c.dead_slot,
            "cores must not share a defect pattern"
        );
        assert_eq!(a.dead_slot.len(), 64);
        assert_eq!(a.stuck_row.len(), 8);
    }

    #[test]
    fn chaos_parse_and_trigger_cadence() {
        let c = SystemChaos::parse("panic=3,drop=2").unwrap();
        assert_eq!(c.worker_panic_every, 3);
        assert_eq!(c.drop_response_every, 2);
        assert!(c.enabled());
        assert!(!SystemChaos::default().enabled());
        assert!(SystemChaos::parse("panic=x").is_err());
        assert!(SystemChaos::parse("warp=1").is_err());

        let t = ChaosTrigger::default();
        assert!(!t.fire(), "disarmed trigger never fires");
        t.arm(3);
        let fires: Vec<bool> = (0..9).map(|_| t.fire()).collect();
        assert_eq!(fires, vec![false, false, true, false, false, true, false, false, true]);
        t.arm(0);
        assert!(!t.fire());
    }

    #[test]
    fn lock_recover_survives_poison() {
        use std::sync::Arc;
        let m = Arc::new(Mutex::new(41usize));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.is_poisoned());
        let mut g = lock_recover(&m);
        *g += 1;
        assert_eq!(*g, 42, "poisoned data stays usable");
    }

    #[test]
    fn recovery_stats_json_shape() {
        let rs = RecoveryStats::default();
        rs.worker_panics.fetch_add(2, Ordering::Relaxed);
        rs.add_hw(5, 3, 1);
        let r = rs.recovery_json();
        assert_eq!(r.get("worker_panics").unwrap().as_usize().unwrap(), 2);
        let f = rs.faults_json();
        assert_eq!(f.get("stuck_row_hits").unwrap().as_usize().unwrap(), 5);
        assert_eq!(f.get("dead_slot_hits").unwrap().as_usize().unwrap(), 3);
        assert_eq!(f.get("events_bit_flipped").unwrap().as_usize().unwrap(), 1);
    }
}
