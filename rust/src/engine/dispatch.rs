//! The unified event-dispatch loop: one definition of the MX-NEURACORE
//! step semantics for every execution path.
//!
//! [`step`] executes one global time step for an arbitrary set of active
//! lanes over a lane-major [`SoaState`]. The sequential engine calls it
//! with a stride-1 state and `active == [0]` (the literal L=1
//! instantiation); the lane engine calls it with a stride-B state and the
//! batch's active lane set. There are no other step implementations.
//!
//! # Canonical event order
//!
//! Every lane's MEM_E queue is sorted and folded into ascending
//! `(src, multiplicity)` runs before dispatch — in *every* mode, ideal and
//! non-ideal. This canonical order is what makes lane sharing exact: a
//! lane's deposit sequence is identical whether it runs alone (L=1) or
//! shares the walk with B−1 other lanes, because per-lane state is
//! private and each lane always sees its own events in ascending source
//! order. Ideal-mode deposits are exact integer adds (order-free anyway);
//! the non-ideal error sidecar is made order-robust on top by Neumaier
//! compensation ([`crate::analog::kahan_add`]) and is applied per slot at
//! sweep time. Consequently lane-shared non-ideal runs are **bit-identical**
//! to sequential non-ideal runs — the documented tolerance
//! ([`crate::engine::NONIDEAL_ORACLE_TOLERANCE`]) is only needed against
//! the *fixed-order per-event oracle* (the pre-refactor arithmetic; see
//! [`CoreView::legacy_error_oracle`]).
//!
//! # Merged walk (k-way merge)
//!
//! The dispatcher advances one cursor per active lane through its run
//! list via a min-heap keyed on source id: each distinct source is popped
//! once, its MEM_E2A entry and MEM_S&N row slice are fetched **once**, and
//! the deposit loop writes the contiguous lane block of every carrying
//! lane. Exhausted lanes simply leave the heap — unlike the previous
//! O(L) min-scan per distinct source, cost is O(Σ runs · log L) and lanes
//! that ran out of events are never rescanned.
//!
//! # Accounting
//!
//! Every [`CoreStats`] counter is charged to each carrying lane exactly
//! as a lone sequential dispatch would charge it, ×multiplicity (the
//! controller pops each event individually). Only the A-SYN MAC energy is
//! core-level: the engine fills `mac_count` and the core flushes it to the
//! shared A-SYN accounts once per step.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::analog::{kahan_add, ASyn, AnalogParams};
use crate::engine::convgen::{ConvGen, ConvScratch};
use crate::engine::state::{LaneCtl, RoundSoa, SoaState};
use crate::engine::sweep::sweep_round;
use crate::fault::CoreFaults;
use crate::mapping::CoreImage;
use crate::neuracore::{CoreStats, STEP_SERIES_CAP};
use crate::snn::LifParams;

/// Borrowed view of everything immutable the engine needs from a core:
/// the distilled image, its CSR mirror and precomputed sweep data, the
/// numeric parameters, and the test/debug knobs. Built fresh per step from
/// `NeuraCore` fields (field-level borrows keep it disjoint from the
/// mutable state).
pub struct CoreView<'a> {
    /// Distilled control memories (MEM_E2A per round, dims, scale).
    pub image: &'a CoreImage,
    /// CSR row index per round: row `r` of round `k` covers
    /// `row_entries[k][rows_index[k][r] .. rows_index[k][r+1]]`.
    pub rows_index: &'a [Vec<u32>],
    /// CSR entries per round as `(engine, virt, weight)`.
    pub row_entries: &'a [Vec<(u8, u16, i8)>],
    /// Generator-based row fetch for compressed conv images: `Some` makes
    /// the dispatcher enumerate each source's rows arithmetically from the
    /// kernel instead of the (empty) MEM_E2A/MEM_S&N mirror. The generated
    /// block is structurally identical to what distilling the expanded
    /// layer would store, so accounting downstream is unchanged.
    pub conv: Option<&'a ConvGen>,
    /// Flattened `(slot, dst)` residents per round, sorted by destination.
    pub residents_sorted: &'a [Vec<(u32, u32)>],
    /// Per-round sweep cycle cost (max per-engine occupancy).
    pub sweep_cost: &'a [u64],
    /// Whether clean slots may skip the sweep arithmetic
    /// ([`crate::engine::sweep::quiescent_fixed_point`]).
    pub sweep_skip: bool,
    /// LIF parameters of the mapped layer.
    pub lif: LifParams,
    /// Analog operating point (selects ideal vs non-ideal dispatch).
    pub analog: &'a AnalogParams,
    /// A-SYN engines — read-only here (C2C ladder deviation); their energy
    /// accounts are updated by the core from `mac_count` after the step.
    pub syns: &'a [ASyn],
    /// Capacitors per A-NEURON (N).
    pub caps_per_engine: usize,
    /// Installed hardware faults ([`crate::fault::FaultPlan`]); `None`
    /// keeps the deposit and sweep loops on the identical fault-free code
    /// path (bit-identity with pre-fault builds is structural).
    pub faults: Option<&'a CoreFaults>,
    /// Test/debug knob: full sweep arithmetic for every resident slot.
    pub force_dense_sweep: bool,
    /// Test/debug knob: dispatch each MEM_E entry individually (runs of
    /// multiplicity 1) instead of coalescing duplicates.
    pub force_per_event_dispatch: bool,
    /// Test/debug knob: the **fixed-order oracle** — per-event dispatch
    /// with *uncompensated* error accumulation, i.e. the pre-refactor
    /// sequential engine's exact non-ideal arithmetic for inputs that
    /// arrive sorted and duplicate-free. The non-ideal differential tests
    /// pin the default (coalesced, Kahan) engine to this oracle within
    /// [`crate::engine::NONIDEAL_ORACLE_TOLERANCE`].
    pub legacy_error_oracle: bool,
}

/// Reusable per-step scratch (no allocation on the steady state): per
/// active lane cycle/row accumulators and run cursors, the merge heap, and
/// the per-source carrier list.
#[derive(Debug, Clone, Default)]
pub struct StepScratch {
    lane_cycles: Vec<u64>,
    lane_rows: Vec<u64>,
    /// Cursor into each active lane's run list (indexed by active position).
    pos: Vec<usize>,
    /// Min-heap of `(next source, active position)` lane cursors.
    heap: BinaryHeap<Reverse<(u32, u32)>>,
    /// Lanes carrying the current source: `(lane id, active pos, mult)`.
    carriers: Vec<(u32, u32, u32)>,
    /// Generated-row buffer for compressed conv images.
    conv: ConvScratch,
}

/// Execute one global time step for the lanes listed in `active`
/// (strictly ascending lane indices within `state`'s stride), writing lane
/// `active[i]`'s emitted spikes into `outs[i]` (cleared first).
///
/// `ctl` and `stats` are indexed by *lane id*; `outs` by active position.
/// The sequential engine passes one-element slices built from the core's
/// own queue and `stats` field — sequential execution *is* this function
/// at stride 1.
#[allow(clippy::too_many_arguments)]
pub fn step(
    view: &CoreView<'_>,
    state: &mut SoaState,
    ctl: &mut [LaneCtl],
    stats: &mut [CoreStats],
    active: &[usize],
    outs: &mut [Vec<u32>],
    mac_count: &mut [u64],
    scratch: &mut StepScratch,
) {
    assert_eq!(active.len(), outs.len(), "one output buffer per active lane");
    debug_assert!(active.windows(2).all(|w| w[0] < w[1]));
    let stride = state.lanes();
    let n = view.caps_per_engine;
    let m = view.image.num_engines;
    let ideal = view.analog.is_ideal();
    let per_event = view.force_per_event_dispatch || view.legacy_error_oracle;
    let num_rounds = view.image.rounds.len();

    // Canonical order: sort each lane's MEM_E and fold it into ascending
    // (src, multiplicity) runs — per-event runs under the oracle knobs,
    // so duplicate deposits replay individually in the same fixed order.
    for &li in active {
        let c = &mut ctl[li];
        let q = &mut c.queue;
        if q.len() > 1 && !q.windows(2).all(|w| w[0] <= w[1]) {
            q.sort_unstable();
        }
        c.runs.clear();
        if per_event {
            c.runs.extend(q.iter().map(|&s| (s, 1u32)));
        } else {
            let mut i = 0usize;
            while i < q.len() {
                let src = q[i];
                let mut cnt = 1usize;
                while i + cnt < q.len() && q[i + cnt] == src {
                    cnt += 1;
                }
                c.runs.push((src, cnt as u32));
                i += cnt;
            }
        }
    }
    for out in outs.iter_mut() {
        out.clear();
    }

    let nl = active.len();
    scratch.lane_cycles.clear();
    scratch.lane_cycles.resize(nl, 0);
    scratch.lane_rows.clear();
    scratch.lane_rows.resize(nl, 0);

    for round_idx in 0..num_rounds {
        let round = &view.image.rounds[round_idx];
        let residents = &view.residents_sorted[round_idx];
        let ridx = &view.rows_index[round_idx];
        let ents = &view.row_entries[round_idx];
        if num_rounds > 1 {
            // Capacitor reassignment: every lane reloads its own parked
            // state (charge transfer is per-lane, the image walk is not).
            let reload = (residents.len() as u64).div_ceil(m as u64);
            for c in scratch.lane_cycles.iter_mut() {
                *c += reload;
            }
        }

        // Merged dispatch: k-way merge of the lanes' run cursors,
        // ascending distinct sources, one MEM_E2A lookup + row-slice
        // fetch per source. Exhausted lanes fall out of the heap.
        scratch.pos.clear();
        scratch.pos.resize(nl, 0);
        scratch.heap.clear();
        for (ai, &li) in active.iter().enumerate() {
            if let Some(&(s, _)) = ctl[li].runs.first() {
                scratch.heap.push(Reverse((s, ai as u32)));
            }
        }
        let st = &mut state.rounds[round_idx];
        while let Some(&Reverse((src, _))) = scratch.heap.peek() {
            // Gather every lane cursor parked at `src` (a lane can appear
            // more than once under per-event runs — each duplicate event
            // is its own run and dispatches individually).
            scratch.carriers.clear();
            while let Some(&Reverse((s, ai))) = scratch.heap.peek() {
                if s != src {
                    break;
                }
                scratch.heap.pop();
                let a = ai as usize;
                let li = active[a];
                let (_, mult) = ctl[li].runs[scratch.pos[a]];
                scratch.pos[a] += 1;
                scratch.carriers.push((li as u32, ai, mult));
                if let Some(&(next, _)) = ctl[li].runs.get(scratch.pos[a]) {
                    scratch.heap.push(Reverse((next, ai)));
                }
            }

            // Image fetch, once per distinct source: generated from the
            // kernel for compressed conv images, MEM_E2A + MEM_S&N row
            // slice otherwise. Both paths yield the same (row count,
            // row-major entries) shape, so everything downstream —
            // accounting, deposits, faults — is representation-blind.
            let s = src as usize;
            let (row_count, entries) = if let Some(gen) = view.conv {
                let rows = gen.fetch(src, round_idx, &mut scratch.conv);
                (rows, scratch.conv.entries.as_slice())
            } else if s < round.e2a.len() && round.e2a[s].count > 0 {
                let e2a = round.e2a[s];
                let lo = ridx[e2a.start as usize] as usize;
                let hi = ridx[(e2a.start + e2a.count) as usize] as usize;
                (e2a.count as u64, &ents[lo..hi])
            } else {
                (0u64, &ents[0..0])
            };

            // Per-lane accounting, identical to a lone sequential
            // dispatch: the controller pops each event individually, so
            // every cost is charged ×multiplicity.
            for &(li, ai, mult) in scratch.carriers.iter() {
                let (li, ai, mult_u) = (li as usize, ai as usize, mult as u64);
                stats[li].events_dispatched += mult_u;
                scratch.lane_cycles[ai] += mult_u; // MEM_E pop + MEM_E2A read
                if row_count == 0 {
                    continue;
                }
                scratch.lane_cycles[ai] += mult_u * row_count; // one row/cycle
                scratch.lane_rows[ai] += mult_u * row_count;
                stats[li].sn_rows_read += mult_u * row_count;
                stats[li].macs += mult_u * entries.len() as u64;
                stats[li].integrations += mult_u * entries.len() as u64;
            }
            if !entries.is_empty() {
                deposit(view, st, stride, &scratch.carriers, entries, n, ideal, mac_count, stats);
            }
        }

        sweep_round(view, st, stride, active, stats, outs, residents);
        for c in scratch.lane_cycles.iter_mut() {
            *c += view.sweep_cost[round_idx];
        }
    }

    // Finalize per lane: MEM_E consumed, cycle totals and the capped
    // per-step series recorded, multi-round outputs re-sorted if the
    // round interleaving actually violated ascending order.
    for (ai, &li) in active.iter().enumerate() {
        ctl[li].queue.clear();
        let s = &mut stats[li];
        s.cycles += scratch.lane_cycles[ai];
        if s.cycles_per_step.len() < STEP_SERIES_CAP {
            s.cycles_per_step.push(scratch.lane_cycles[ai]);
            s.sn_rows_touched_per_step.push(scratch.lane_rows[ai]);
        }
        let out = &mut outs[ai];
        if num_rounds > 1 && !out.windows(2).all(|w| w[0] <= w[1]) {
            out.sort_unstable();
        }
    }
}

/// Deposit one source's row slice into every carrying lane. Per entry the
/// inner loop writes the slot's contiguous lane block (`slot·stride + lane`)
/// — the SoA layout's B-wide update.
#[allow(clippy::too_many_arguments)]
fn deposit(
    view: &CoreView<'_>,
    st: &mut RoundSoa,
    stride: usize,
    carriers: &[(u32, u32, u32)],
    entries: &[(u8, u16, i8)],
    n: usize,
    ideal: bool,
    mac_count: &mut [u64],
    stats: &mut [CoreStats],
) {
    let scale = view.image.scale;
    let legacy = view.legacy_error_oracle;
    // Fault gates (both None/absent on the fault-free path): a stuck row
    // suppresses the charge while the silicon still streams and prices the
    // row; drift scales the analog error term beyond its calibration point.
    let stuck_rows: Option<&[bool]> =
        view.faults.filter(|f| f.any_stuck()).map(|f| f.stuck_row.as_slice());
    for &(j, virt, w) in entries {
        let j = j as usize;
        if let Some(sr) = stuck_rows {
            if sr[j] {
                // Dead C2C ladder column: the row read, MAC activity, and
                // energy still happen (the controller streams the row
                // regardless), but no charge reaches any capacitor.
                let mut group_mult = 0u64;
                for &(li, _, mult) in carriers {
                    stats[li as usize].stuck_row_hits += mult as u64;
                    group_mult += mult as u64;
                }
                mac_count[j] += group_mult;
                continue;
            }
        }
        let base = (j * n + virt as usize) * stride;
        // Analog sidecar term: deviation of the real C2C packet from the
        // ideal deposit, plus switch injection — identical for every lane
        // carrying the event, so it is computed once per entry.
        let err_term = if ideal {
            0.0
        } else {
            let real = view.syns[j].ladder.convert_signed(w, view.analog.v_ref)
                * 256.0
                * scale as f64
                / view.analog.v_ref;
            let mut e = real - w as f64 * scale as f64 + view.analog.switch_injection * 0.01;
            if let Some(f) = view.faults {
                if f.drift_scale != 1.0 {
                    e *= f.drift_scale;
                }
            }
            e
        };
        let mut group_mult = 0u64;
        for &(li, _, mult) in carriers {
            let idx = base + li as usize;
            // Ideal C2C charge: exactly w·mult (integer, exact).
            st.acc[idx] += w as i32 * mult as i32;
            st.dirty[idx] = true;
            group_mult += mult as u64;
            if !ideal {
                if legacy {
                    // Pre-refactor arithmetic: plain per-deposit add
                    // (mult == 1 on this path — the oracle forces
                    // per-event runs).
                    st.err[idx] += err_term;
                } else {
                    kahan_add(&mut st.err[idx], &mut st.err_c[idx], err_term * mult as f64);
                }
            }
        }
        // Batched per-engine MAC energy bookkeeping, flushed by the core
        // once per step (keeps the inner loop free of float adds).
        mac_count[j] += group_mult;
    }
}
