//! The unified end-of-step sweep: leak + integrate + compare-to-threshold
//! for every resident virtual neuron, over the lane-major SoA state.
//!
//! Residents are iterated in destination order (outer loop) with the
//! active lanes inner, so
//!
//! * each lane's spikes come out pre-sorted per round exactly as the
//!   sequential engine emits them, and
//! * the inner loop reads one slot's contiguous lane block
//!   (`slot·stride + lane`) — the SoA layout's B-wide sweep.
//!
//! The activity-tracked skip (clean slots bypass the arithmetic) is valid
//! only when [`quiescent_fixed_point`] holds; the non-ideal branch applies
//! the Kahan error sidecar per slot here — *at sweep time* — plus hold
//! droop and the supply-rail clamp, bit-identical to the pre-refactor
//! sequential sweep (the compensation term is zero under the legacy
//! oracle).

use crate::analog::AnalogParams;
use crate::engine::dispatch::CoreView;
use crate::engine::state::RoundSoa;
use crate::neuracore::CoreStats;
use crate::snn::LifParams;

/// Whether `v_reset` is a quiescent fixed point of the sweep: a slot with
/// `mem == v_reset`, `acc == 0`, `err == 0` must come out of the full
/// leak/integrate/compare arithmetic bit-identical and below threshold.
/// When this holds the sweep may skip clean slots; when it does not
/// (e.g. `β·v_reset != v_reset`), skipping is disabled and every slot
/// stays permanently dirty.
pub fn quiescent_fixed_point(lif: &LifParams, analog: &AnalogParams) -> bool {
    let ideal = analog.is_ideal();
    let q = lif.v_reset;
    // Mirror the sweep arithmetic exactly, with acc == 0 and err == 0.
    let mut v = lif.beta * q;
    if !ideal {
        v -= (q * analog.hold_leak as f32).abs();
        if analog.v_sat.is_finite() {
            v = v.clamp(-analog.v_sat as f32, analog.v_sat as f32);
        }
    }
    v == q && v < lif.v_threshold
}

/// Sweep one round's residents for every active lane: full arithmetic for
/// dirty slots, provable no-op skip for clean ones. Spikes are pushed to
/// `outs[active position]`; `fire_ops`/`spikes_out` are charged per lane
/// (the hardware sweeps every occupied capacitor regardless of charge).
pub(crate) fn sweep_round(
    view: &CoreView<'_>,
    st: &mut RoundSoa,
    stride: usize,
    active: &[usize],
    stats: &mut [CoreStats],
    outs: &mut [Vec<u32>],
    residents: &[(u32, u32)],
) {
    let ideal = view.analog.is_ideal();
    let beta = view.lif.beta;
    let th = view.lif.v_threshold;
    let q = view.lif.v_reset;
    let scale = view.image.scale;
    let skip = view.sweep_skip;
    let dense = view.force_dense_sweep;
    // Dead-slot fault gate (absent on the fault-free path): a dead op-amp
    // freezes the membrane, drains deposited charge, and never fires.
    let dead_slots: Option<&[bool]> =
        view.faults.filter(|f| f.any_dead()).map(|f| f.dead_slot.as_slice());
    for &li in active {
        stats[li].fire_ops += residents.len() as u64;
    }
    for &(slot, dst) in residents {
        let base = slot as usize * stride;
        let dead = dead_slots.is_some_and(|d| d[slot as usize]);
        for (ai, &li) in active.iter().enumerate() {
            let idx = base + li;
            if !dense && !st.dirty[idx] {
                continue; // provably a no-op (quiescent fixed point)
            }
            if dead {
                // Op-amp failure: discard the step's charge and error,
                // keep the membrane frozen, emit nothing. Counted only
                // when charge was actually lost.
                if st.acc[idx] != 0 {
                    stats[li].dead_slot_hits += 1;
                }
                st.acc[idx] = 0;
                st.err[idx] = 0.0;
                st.err_c[idx] = 0.0;
                st.dirty[idx] = !skip;
                continue;
            }
            // Reference-exact arithmetic (see neuracore module docs).
            let mut v = beta * st.mem[idx] + st.acc[idx] as f32 * scale;
            if !ideal {
                // Apply the accumulated analog error (Neumaier value =
                // sum + compensation) and hold droop, then the rail clamp.
                v += (st.err[idx] + st.err_c[idx]) as f32;
                v -= (st.mem[idx] * view.analog.hold_leak as f32).abs();
                if view.analog.v_sat.is_finite() {
                    v = v.clamp(-(view.analog.v_sat as f32), view.analog.v_sat as f32);
                }
            }
            st.acc[idx] = 0;
            st.err[idx] = 0.0;
            st.err_c[idx] = 0.0;
            if v >= th {
                outs[ai].push(dst);
                st.mem[idx] = q;
                stats[li].spikes_out += 1;
                // Post-fire state is (v_reset, 0, 0): clean iff that is
                // the quiescent fixed point.
                st.dirty[idx] = !skip;
            } else {
                st.mem[idx] = v;
                st.dirty[idx] = !(skip && v == q);
            }
        }
    }
}
