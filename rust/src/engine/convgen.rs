//! Generator-based synapse row fetch for compressed conv layers.
//!
//! For a dense/CSR layer the dispatcher answers "what does a spike from
//! source `s` touch this round?" with a MEM_E2A lookup plus a MEM_S&N row
//! slice. For a compressed conv layer those memories are empty — the
//! A-SYN SRAM holds one `[oc][ic][kh][kw]` kernel and [`ConvGen::fetch`]
//! *generates* the same row block arithmetically (arxiv 2112.07019):
//! decode `s → (ic, y, x)`, enumerate the kernel taps that land in this
//! round's canonical slot window, group them into per-engine rows.
//!
//! The contract is exact structural equality with the distilled expansion:
//! for the same source and round, `fetch` returns the same row count and
//! the same row-major `(engine, virt, weight)` sequence that
//! [`crate::mapping::distill`] + the core's CSR flattening would produce
//! for the expanded layer under the same canonical mapping. The dispatcher
//! therefore charges cycles, rows, and MACs identically on both paths —
//! bit-identical `CoreStats` is structural, not coincidental.

use crate::snn::ConvSpec;

/// The per-core row generator: kernel + canonical-layout geometry.
#[derive(Debug, Clone)]
pub struct ConvGen {
    spec: ConvSpec,
    /// Kernel `[oc][ic][kh][kw]` — the core's A-SYN weight SRAM contents.
    kernel: Vec<i8>,
    /// Canonical slots per round (M·N).
    slots_per_round: usize,
    /// Capacitors per A-NEURON (N).
    caps_per_engine: usize,
    out_dim: usize,
}

/// Reusable fetch scratch (no allocation on the steady state).
#[derive(Debug, Clone, Default)]
pub struct ConvScratch {
    /// This source's in-round targets in ascending destination order, as
    /// `(engine, virt, weight)`. Engine ids are non-decreasing along the
    /// list — a consequence of the canonical layout (`j = pos/N` grows
    /// with the destination id), which is what makes grouping a single
    /// linear pass.
    tgt: Vec<(u8, u16, i8)>,
    /// Contiguous per-engine runs within `tgt`, as `(start, len)`.
    groups: Vec<(u32, u32)>,
    /// Row-major generated entries — the drop-in replacement for the
    /// MEM_S&N row slice the CSR path would have fetched.
    pub entries: Vec<(u8, u16, i8)>,
}

impl ConvGen {
    /// Build from a distilled compressed image's parts: the layer spec,
    /// the kernel (weight SRAM contents), and the core geometry (M, N).
    pub fn new(spec: ConvSpec, kernel: Vec<i8>, m: usize, n: usize) -> Self {
        let out_dim = spec.out_dim();
        Self { spec, kernel, slots_per_round: m * n, caps_per_engine: n, out_dim }
    }

    /// Generate the row block a spike from `src` triggers in `round_idx`,
    /// filling `scratch.entries` row-major (row 0's engine columns in
    /// ascending engine order, then row 1's, …) and returning the row
    /// count — the generated `B_i` of the paper's MEM_E2A entry. Sources
    /// out of range (e.g. bit-flipped MEM_E words) generate zero rows,
    /// exactly like a missing E2A entry on the CSR path.
    pub fn fetch(&self, src: u32, round_idx: usize, scratch: &mut ConvScratch) -> u64 {
        scratch.entries.clear();
        scratch.tgt.clear();
        let lo = round_idx * self.slots_per_round;
        let hi = (lo + self.slots_per_round).min(self.out_dim);
        let n = self.caps_per_engine;
        let tgt = &mut scratch.tgt;
        self.spec.for_each_target(&self.kernel, src as usize, |d, w| {
            let d = d as usize;
            if d < lo || d >= hi {
                return;
            }
            let pos = d - lo;
            tgt.push(((pos / n) as u8, (pos % n) as u16, w));
        });
        if tgt.is_empty() {
            return 0;
        }
        // Group the ascending-destination list into contiguous per-engine
        // runs (engine ids are non-decreasing, so one pass suffices), then
        // emit row-major: row r takes each group's r-th element.
        scratch.groups.clear();
        let mut start = 0usize;
        for i in 1..=tgt.len() {
            if i == tgt.len() || tgt[i].0 != tgt[start].0 {
                scratch.groups.push((start as u32, (i - start) as u32));
                start = i;
            }
        }
        let rows = scratch.groups.iter().map(|&(_, len)| len).max().unwrap();
        for r in 0..rows {
            for &(gs, glen) in scratch.groups.iter() {
                if r < glen {
                    scratch.entries.push(tgt[(gs + r) as usize]);
                }
            }
        }
        rows as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AcceleratorConfig;
    use crate::mapping::{distill, map_layer, Strategy};
    use crate::snn::{LifParams, QuantLayer};
    use crate::util::rng::Rng;

    fn small_cfg(m: usize, n: usize) -> AcceleratorConfig {
        let mut c = AcceleratorConfig::accel1();
        c.a_neurons_per_core = m;
        c.a_syns_per_core = m;
        c.virtual_per_a_neuron = n;
        c
    }

    /// The layout contract, pinned directly against the distiller: for
    /// every (round, source), `fetch` must return exactly the row count
    /// and row-major entry sequence that distilling the expanded layer
    /// yields under the same canonical mapping.
    #[test]
    fn fetch_matches_distilled_expansion() {
        let mut rng = Rng::new(21);
        for (stride, padding, m, n) in [(1, 1, 3, 7), (2, 0, 4, 4), (2, 1, 2, 9)] {
            let spec = ConvSpec {
                in_channels: 2,
                in_h: 6,
                in_w: 6,
                out_channels: 3,
                kernel_h: 3,
                kernel_w: 3,
                stride,
                padding,
            };
            let mut kernel = vec![0i8; spec.kernel_len()];
            for w in kernel.iter_mut() {
                if !rng.bernoulli(0.25) {
                    let mag = rng.range_inclusive(1, 127) as i8;
                    *w = if rng.bernoulli(0.5) { mag } else { -mag };
                }
            }
            let compressed =
                QuantLayer::conv2d(spec, kernel.clone(), 0.01, LifParams::default()).unwrap();
            let expanded = compressed.expand_conv().unwrap();
            let cfg = small_cfg(m, n);
            let mp = map_layer(&expanded, &cfg, Strategy::IlpFlow).unwrap();
            let img = distill(&expanded, &mp, &cfg).unwrap();
            assert!(img.rounds.len() > 1, "want multi-round coverage (m{m} n{n})");

            let gen = ConvGen::new(spec, kernel, m, n);
            let mut scratch = ConvScratch::default();
            for (ri, round) in img.rounds.iter().enumerate() {
                for s in 0..spec.in_dim() {
                    // Flatten the distilled rows exactly like the core's
                    // CSR build: per row, engine columns ascending.
                    let e2a = round.e2a[s];
                    let mut want: Vec<(u8, u16, i8)> = Vec::new();
                    for r in 0..e2a.count {
                        let row = &round.sn_rows[(e2a.start + r) as usize];
                        for (j, e) in row.per_engine.iter().enumerate() {
                            if let Some(e) = e {
                                want.push((
                                    j as u8,
                                    e.virt,
                                    img.weight_mem[e.weight_addr as usize],
                                ));
                            }
                        }
                    }
                    let rows = gen.fetch(s as u32, ri, &mut scratch);
                    assert_eq!(rows, e2a.count as u64, "round {ri} src {s}");
                    assert_eq!(scratch.entries, want, "round {ri} src {s}");
                }
                // Out-of-range sources generate nothing.
                let rows = gen.fetch(spec.in_dim() as u32 + 5, ri, &mut scratch);
                assert_eq!(rows, 0);
                assert!(scratch.entries.is_empty());
            }
        }
    }
}
