//! Lane-major SoA execution state.
//!
//! One [`RoundSoa`] holds the membrane state of every lane for one mapping
//! round as four flat arrays indexed `slot * lanes + lane`. The layout is
//! **lane-major per slot**: all B lanes of a slot are contiguous, so
//!
//! * one synapse entry's deposit (`acc[slot]` across every carrying lane)
//!   touches one contiguous B-wide block, and
//! * one resident's sweep (`mem`/`acc`/`err` of a slot across lanes) walks
//!   three contiguous B-wide blocks
//!
//! — the inner loops the dispatcher and sweeper run are stride-1 and
//! autovectorization-friendly, instead of hopping between per-lane
//! AoS allocations (the pre-refactor `Vec<RoundState>`-per-lane layout).
//!
//! The sequential engine is the `lanes == 1` instantiation of the same
//! structures: a stride-1 `SoaState` *is* the old per-slot layout, so there
//! is exactly one definition of the step semantics (see
//! [`crate::engine::dispatch`]).

use crate::neuracore::CoreStats;

/// State of one mapping round for all lanes, lane-major
/// (`index = slot * lanes + lane`).
#[derive(Debug, Clone, Default)]
pub struct RoundSoa {
    /// f32 membrane per (slot, lane), reference-exact arithmetic.
    pub mem: Vec<f32>,
    /// Integer charge accumulated this step (Σ quantized weights · mult).
    pub acc: Vec<i32>,
    /// Analog error sidecar per (slot, lane): Kahan–Babuška (Neumaier)
    /// running sum. Exactly zero in ideal mode.
    pub err: Vec<f64>,
    /// Neumaier compensation term of `err`; the sidecar's value is
    /// `err + err_c`, applied per slot at sweep time
    /// (see [`crate::analog::kahan_add`]).
    pub err_c: Vec<f64>,
    /// Activity tracking: `true` when the (slot, lane) state differs from
    /// the quiescent fixed point and the sweep must do full arithmetic.
    pub dirty: Vec<bool>,
}

impl RoundSoa {
    /// Quiescent state for `cells = slots · lanes` entries.
    fn fresh(cells: usize, v_reset: f32, sweep_skip: bool) -> Self {
        Self {
            mem: vec![v_reset; cells],
            acc: vec![0i32; cells],
            err: vec![0.0f64; cells],
            err_c: vec![0.0f64; cells],
            dirty: vec![!sweep_skip; cells],
        }
    }

    /// Reset to the quiescent state in place (buffers reused).
    fn reset(&mut self, v_reset: f32, sweep_skip: bool) {
        self.mem.fill(v_reset);
        self.acc.fill(0);
        self.err.fill(0.0);
        self.err_c.fill(0.0);
        self.dirty.fill(!sweep_skip);
    }

    /// Re-stride from `old` to `new` lanes (`new > old`): existing lanes
    /// keep their state at the same (slot, lane) coordinates, new lanes
    /// start quiescent.
    fn restride(&mut self, slots: usize, old: usize, new: usize, v_reset: f32, sweep_skip: bool) {
        let mut next = Self::fresh(slots * new, v_reset, sweep_skip);
        for slot in 0..slots {
            for lane in 0..old {
                let s = slot * old + lane;
                let d = slot * new + lane;
                next.mem[d] = self.mem[s];
                next.acc[d] = self.acc[s];
                next.err[d] = self.err[s];
                next.err_c[d] = self.err_c[s];
                next.dirty[d] = self.dirty[s];
            }
        }
        *self = next;
    }
}

/// Per-round lane-major state of one core: the only mutable numeric state
/// the unified engine operates on. The sequential path owns a stride-1
/// instance; the lane path owns a stride-B instance.
#[derive(Debug, Clone, Default)]
pub struct SoaState {
    lanes: usize,
    slots: usize,
    pub rounds: Vec<RoundSoa>,
}

impl SoaState {
    /// Quiescent state for `rounds` mapping rounds of `slots` capacitors
    /// and `lanes` lanes.
    pub fn new(rounds: usize, slots: usize, lanes: usize, v_reset: f32, sweep_skip: bool) -> Self {
        Self {
            lanes,
            slots,
            rounds: (0..rounds)
                .map(|_| RoundSoa::fresh(slots * lanes, v_reset, sweep_skip))
                .collect(),
        }
    }

    /// Configured lane count (the stride of every round array).
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Capacitor slots per round.
    pub fn slots(&self) -> usize {
        self.slots
    }

    /// Reset every round of every lane to the quiescent state in place.
    pub fn reset(&mut self, v_reset: f32, sweep_skip: bool) {
        for r in self.rounds.iter_mut() {
            r.reset(v_reset, sweep_skip);
        }
    }

    /// Grow to at least `lanes` lanes, re-striding the arrays so existing
    /// lanes keep their state and new lanes start quiescent. Lanes never
    /// shrink (lane identity is positional across batches).
    pub fn grow_lanes(&mut self, lanes: usize, v_reset: f32, sweep_skip: bool) {
        if lanes <= self.lanes {
            return;
        }
        let (slots, old) = (self.slots, self.lanes);
        for r in self.rounds.iter_mut() {
            r.restride(slots, old, lanes, v_reset, sweep_skip);
        }
        self.lanes = lanes;
    }

    /// Reset every round of **one** lane to the quiescent state, leaving
    /// every other lane's membranes untouched — the primitive behind
    /// streaming-session lane reuse: a session slot is recycled by
    /// resetting only its own lane-major column.
    pub fn reset_lane(&mut self, lane: usize, v_reset: f32, sweep_skip: bool) {
        assert!(lane < self.lanes, "lane {lane} out of {} lanes", self.lanes);
        for r in self.rounds.iter_mut() {
            for slot in 0..self.slots {
                let i = slot * self.lanes + lane;
                r.mem[i] = v_reset;
                r.acc[i] = 0;
                r.err[i] = 0.0;
                r.err_c[i] = 0.0;
                r.dirty[i] = !sweep_skip;
            }
        }
    }

    /// Debug/test introspection: `(mem, acc, dirty)` per slot of one
    /// round of one lane.
    pub fn slot_states(&self, round: usize, lane: usize) -> Vec<(f32, i32, bool)> {
        let r = &self.rounds[round];
        (0..self.slots)
            .map(|s| {
                let i = s * self.lanes + lane;
                (r.mem[i], r.acc[i], r.dirty[i])
            })
            .collect()
    }
}

/// Per-lane controller state: the MEM_E queue and its coalesced
/// `(src, multiplicity)` run list, rebuilt each step. Everything numeric
/// lives in [`SoaState`]; everything statistical in the caller's
/// [`CoreStats`] slice — this split is what lets the sequential engine
/// borrow the core's own `stats` field as lane 0's statistics.
#[derive(Debug, Clone, Default)]
pub struct LaneCtl {
    /// MEM_E: pending events for the current step.
    pub queue: Vec<u32>,
    /// Scratch: the queue folded into ascending `(src, multiplicity)`
    /// runs (per-event runs of mult 1 under the oracle knobs).
    pub runs: Vec<(u32, u32)>,
}

/// The MEM_E latch, shared by the sequential and lane paths so the
/// overflow policy (append up to the memory depth, drop the rest, count
/// drops and the occupancy high-water mark) cannot diverge between them.
pub fn latch_events(
    queue: &mut Vec<u32>,
    stats: &mut CoreStats,
    depth: usize,
    events: &[u32],
) -> usize {
    let space = depth.saturating_sub(queue.len());
    let take = events.len().min(space);
    queue.extend_from_slice(&events[..take]);
    let dropped = events.len() - take;
    stats.dropped_events += dropped as u64;
    stats.peak_event_queue = stats.peak_event_queue.max(queue.len());
    dropped
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grow_preserves_state_and_adds_quiescent_lanes() {
        let mut st = SoaState::new(2, 3, 2, 0.5, true);
        st.rounds[1].mem[2 * 2 + 1] = 9.0; // slot 2, lane 1
        st.rounds[1].acc[0] = 7; // slot 0, lane 0
        st.rounds[1].dirty[0] = true;
        st.grow_lanes(4, 0.5, true);
        assert_eq!(st.lanes(), 4);
        assert_eq!(st.rounds[1].mem[2 * 4 + 1], 9.0);
        assert_eq!(st.rounds[1].acc[0], 7);
        assert!(st.rounds[1].dirty[0]);
        // New lanes are quiescent.
        assert_eq!(st.rounds[1].mem[2 * 4 + 3], 0.5);
        assert_eq!(st.rounds[1].acc[2 * 4 + 3], 0);
        assert!(!st.rounds[1].dirty[2 * 4 + 3]);
        // Growing to fewer/equal lanes is a no-op.
        st.grow_lanes(3, 0.5, true);
        assert_eq!(st.lanes(), 4);
    }

    #[test]
    fn reset_lane_touches_only_its_column() {
        let mut st = SoaState::new(2, 3, 3, 0.25, false);
        for r in st.rounds.iter_mut() {
            for i in 0..r.mem.len() {
                r.mem[i] = i as f32;
                r.acc[i] = i as i32;
                r.err[i] = i as f64;
                r.err_c[i] = -(i as f64);
                r.dirty[i] = true;
            }
        }
        st.reset_lane(1, 0.25, true);
        for r in &st.rounds {
            for slot in 0..3 {
                for lane in 0..3 {
                    let i = slot * 3 + lane;
                    if lane == 1 {
                        assert_eq!(r.mem[i], 0.25);
                        assert_eq!(r.acc[i], 0);
                        assert_eq!(r.err[i], 0.0);
                        assert_eq!(r.err_c[i], 0.0);
                        assert!(!r.dirty[i]);
                    } else {
                        assert_eq!(r.mem[i], i as f32, "other lane clobbered");
                        assert_eq!(r.acc[i], i as i32);
                        assert!(r.dirty[i]);
                    }
                }
            }
        }
    }

    #[test]
    fn slot_states_reads_strided() {
        let mut st = SoaState::new(1, 2, 3, 0.0, false);
        st.rounds[0].mem[3 + 2] = 4.0; // slot 1, lane 2
        st.rounds[0].acc[2] = -3; // slot 0, lane 2
        let dump = st.slot_states(0, 2);
        assert_eq!(dump, vec![(0.0, -3, true), (4.0, 0, true)]);
    }

    #[test]
    fn latch_respects_depth_and_counts() {
        let mut q = vec![1u32, 2];
        let mut stats = CoreStats::default();
        let dropped = latch_events(&mut q, &mut stats, 4, &[7, 8, 9]);
        assert_eq!(dropped, 1);
        assert_eq!(q, vec![1, 2, 7, 8]);
        assert_eq!(stats.dropped_events, 1);
        assert_eq!(stats.peak_event_queue, 4);
    }
}
