//! # The unified lane-major execution engine
//!
//! Every execution path of the MX-NEURACORE simulator — sequential
//! single-sample runs, SIMD-style lane batches, ideal and non-ideal
//! analog mode, and all the differential-test oracle knobs — funnels into
//! **one** step implementation: [`dispatch::step`] over the lane-major
//! SoA state of [`state::SoaState`]. This module replaces the three
//! divergent copies of the step semantics the simulator used to carry
//! (`step_into`, `step_lanes_into`, and the non-ideal state-swap
//! fallback).
//!
//! ## Lane-major SoA layout
//!
//! Per mapping round, the membrane state of all B lanes lives in four
//! flat arrays indexed `slot · B + lane` ([`state::RoundSoa`]): all lanes
//! of one capacitor slot are contiguous. One synapse entry's deposit and
//! one resident's sweep therefore run contiguous B-wide inner loops —
//! stride-1 accesses amenable to autovectorization — instead of chasing
//! per-lane `Vec` allocations.
//!
//! ## Sequential execution is the L=1 instantiation
//!
//! [`crate::neuracore::NeuraCore::step_into`] calls [`dispatch::step`]
//! with a stride-1 [`state::SoaState`], `active == [0]`, and the core's
//! own `stats` field as lane 0's statistics. There is no separate
//! sequential step body, so "lane results are bit-identical to sequential
//! results" is structural: both are the same machine code over the same
//! state layout, differing only in stride.
//!
//! ## Non-ideal tolerance contract
//!
//! The non-ideal error sidecar (C2C mismatch deviation + switch
//! injection) is accumulated per `(slot, lane)` with Neumaier-compensated
//! addition ([`crate::analog::kahan_add`]) and applied to the membrane
//! once per slot at sweep time. Because every mode dispatches events in
//! the same canonical ascending order (see [`dispatch`]), lane-shared
//! non-ideal runs are **bit-identical** to sequential (L=1) non-ideal
//! runs — mismatch studies batch exactly like ideal-mode inference.
//!
//! Against the **pre-refactor** arithmetic (per-event, uncompensated
//! accumulation — reproducible via the fixed-order oracle knob
//! [`dispatch::CoreView::legacy_error_oracle`] on sorted duplicate-free
//! inputs) results are value-equal within [`NONIDEAL_ORACLE_TOLERANCE`]
//! per membrane per step: coalescing folds a duplicate event's deposits
//! into one `err · mult` term and Neumaier compensation re-associates the
//! sum, each a ≤1-ulp-per-add perturbation of a sidecar that is itself
//! orders of magnitude below the threshold scale.

pub mod convgen;
pub mod dispatch;
pub mod state;
pub mod sweep;

pub use convgen::{ConvGen, ConvScratch};
pub use dispatch::{step, CoreView, StepScratch};
pub use state::{latch_events, LaneCtl, RoundSoa, SoaState};
pub use sweep::quiescent_fixed_point;

/// Documented bound on the absolute per-slot membrane divergence (f32,
/// per step) between the default engine (coalesced dispatch, Kahan
/// error sidecar) and the fixed-order per-event oracle
/// ([`dispatch::CoreView::legacy_error_oracle`]) in non-ideal analog
/// mode. The true divergence is at the f64 rounding level (≈1e-16
/// relative) before the f32 membrane cast; 1e-4 in membrane volts leaves
/// five orders of magnitude of headroom while still catching any real
/// semantic drift.
pub const NONIDEAL_ORACLE_TOLERANCE: f32 = 1e-4;
