//! Behavioural models of MENAGE's mixed-signal circuits.
//!
//! The paper characterises its analog blocks with HSpice; this module is
//! the substitution (DESIGN.md §2): behavioural — not transistor-level —
//! models that expose the same externally visible transfer functions,
//! non-idealities and timing/energy operating points the paper reports:
//!
//! * [`C2cLadder`] — the A-SYN multiplier, `V_out = V_ref · Σ W_i·2^(i-n)`
//!   (paper eq. 2) with optional per-stage capacitor mismatch.
//! * [`OpAmpIntegrator`] — the A-NEURON front-end: finite gain, slew and
//!   saturation; integrates scaled synaptic charge onto the active virtual
//!   neuron's capacitor.
//! * [`Comparator`] — the A-NEURON back-end: threshold crossing with
//!   hysteresis and propagation delay; produces the output pulse.
//! * [`VirtualNeuronBank`] — the N storage capacitors of one A-NEURON with
//!   per-step leak discharge (the controller's "discharge command").
//! * [`ANeuron`] — the assembled neuron engine; `fire-restore-integrate-
//!   store` sequence per dispatched event batch, with waveform capture for
//!   Figure 5.
//!
//! All voltages in volts, times in seconds. The paper's operating point —
//! 97 nW and 6.72 ns per A-NEURON operation at 103.2 MHz — parameterises
//! the defaults ([`AnalogParams::paper`]); `AnalogParams::ideal()` removes
//! every non-ideality so the accelerator simulator can be checked
//! bit-exactly against the reference model.

use crate::util::rng::Rng;

/// Neumaier (Kahan–Babuška) compensated addition: adds `x` into the
/// running pair `(sum, comp)` whose value is `sum + comp`. The
/// compensation term captures the low-order bits lost by each add, so the
/// accumulated total is accurate to ~1 ulp of the exact sum *independent
/// of accumulation order* — which is what lets the simulator's non-ideal
/// error sidecar be shared across execution paths that visit deposits in
/// different groupings (see `engine::dispatch`). Unlike classic Kahan,
/// the Neumaier variant also survives the `|x| > |sum|` case, which the
/// sidecar hits on the first deposit after every sweep reset.
#[inline]
pub fn kahan_add(sum: &mut f64, comp: &mut f64, x: f64) {
    let t = *sum + x;
    if sum.abs() >= x.abs() {
        *comp += (*sum - t) + x;
    } else {
        *comp += (x - t) + *sum;
    }
    *sum = t;
}

/// Non-ideality and operating-point parameters for the analog blocks.
#[derive(Debug, Clone, PartialEq)]
pub struct AnalogParams {
    /// Reference voltage fed to C2C ladders (V).
    pub v_ref: f64,
    /// Supply rail: op-amp output saturates at ±v_sat.
    pub v_sat: f64,
    /// Op-amp open-loop DC gain (ideal → ∞; finite gain causes a small
    /// integration error v/A).
    pub opamp_gain: f64,
    /// Op-amp slew rate (V/s); bounds how much the integrator can move in
    /// one clock period.
    pub slew_rate: f64,
    /// Comparator hysteresis half-width (V).
    pub comparator_hysteresis: f64,
    /// Comparator propagation delay (s). Paper: contributes to 6.72 ns.
    pub comparator_delay: f64,
    /// Fractional σ of C2C per-stage capacitor mismatch (0 = ideal).
    pub c2c_mismatch_sigma: f64,
    /// Per-step fractional charge leak of a storage capacitor *while
    /// holding* (droop between visits).
    pub hold_leak: f64,
    /// Charge-injection offset per sample/restore switch event (V).
    pub switch_injection: f64,
    /// A-NEURON energy per integrate-and-fire operation (J). Paper: 97 nW
    /// at 6.72 ns per op → 97 nW × 6.72 ns ≈ 0.652 fJ per op.
    pub neuron_energy_per_op: f64,
    /// A-NEURON operation latency (s). Paper: 6.72 ns.
    pub neuron_delay: f64,
}

impl AnalogParams {
    /// Paper operating point (90 nm, HSpice-characterised) with mild,
    /// realistic non-idealities.
    pub fn paper() -> Self {
        Self {
            v_ref: 1.0,
            v_sat: 1.2,
            opamp_gain: 5e3,
            slew_rate: 2.5e9, // 2.5 V/ns-class: full-scale in < clock period
            comparator_hysteresis: 2e-3,
            comparator_delay: 0.9e-9,
            c2c_mismatch_sigma: 0.002,
            hold_leak: 2e-4,
            switch_injection: 0.5e-3,
            neuron_energy_per_op: 97e-9 * 6.72e-9, // ≈ 0.652 fJ
            neuron_delay: 6.72e-9,
        }
    }

    /// Perfectly ideal analog blocks — used by equivalence tests against
    /// the digital reference model.
    pub fn ideal() -> Self {
        Self {
            v_ref: 1.0,
            v_sat: f64::INFINITY,
            opamp_gain: f64::INFINITY,
            slew_rate: f64::INFINITY,
            comparator_hysteresis: 0.0,
            comparator_delay: 0.0,
            c2c_mismatch_sigma: 0.0,
            hold_leak: 0.0,
            switch_injection: 0.0,
            neuron_energy_per_op: 97e-9 * 6.72e-9,
            neuron_delay: 6.72e-9,
        }
    }

    /// Whether every modeled non-ideality the *simulator's membrane path*
    /// applies is off: no C2C mismatch, no switch injection, no hold
    /// droop, no supply-rail clamp. This single predicate gates all of the
    /// simulator's exactness-dependent fast paths (the sweep-skip
    /// fixed-point check, duplicate-event coalescing, shared lane
    /// dispatch) — one definition so the gates cannot drift apart when a
    /// new non-ideality knob is added.
    pub fn is_ideal(&self) -> bool {
        self.c2c_mismatch_sigma == 0.0
            && self.switch_injection == 0.0
            && self.hold_leak == 0.0
            && !self.v_sat.is_finite()
    }
}

impl Default for AnalogParams {
    fn default() -> Self {
        Self::paper()
    }
}

/// C2C capacitor-ladder multiplying DAC (paper eq. 2, Figure 3).
///
/// One analog input (`v_ref`) and an n-bit digital input `w` produce
/// `v_out = v_ref · Σ_{i=0}^{n-1} w_i · 2^{i-n}` — i.e. `v_ref · w / 2ⁿ`
/// for unsigned `w`. MENAGE drives it with 8-bit signed weights: sign is
/// handled by the surrounding switched-capacitor stage (add/subtract
/// charge), magnitude by the ladder.
#[derive(Debug, Clone)]
pub struct C2cLadder {
    bits: u32,
    /// Per-bit effective weight, nominally 2^(i-n), perturbed by mismatch.
    bit_weight: Vec<f64>,
}

impl C2cLadder {
    /// Ideal ladder with `bits` stages.
    pub fn new(bits: u32) -> Self {
        let bit_weight =
            (0..bits).map(|i| 2f64.powi(i as i32 - bits as i32)).collect();
        Self { bits, bit_weight }
    }

    /// Ladder with per-stage capacitor mismatch ~ N(0, σ) (relative).
    /// MOM-capacitor ladders (paper §III-B) have σ well under 1%.
    pub fn with_mismatch(bits: u32, sigma: f64, rng: &mut Rng) -> Self {
        let mut l = Self::new(bits);
        if sigma > 0.0 {
            for w in l.bit_weight.iter_mut() {
                *w *= 1.0 + rng.normal(0.0, sigma);
            }
        }
        l
    }

    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// Multiply: unsigned digital magnitude × v_ref (paper eq. 2).
    pub fn convert(&self, w_mag: u8, v_ref: f64) -> f64 {
        let mut acc = 0.0;
        for i in 0..self.bits.min(8) {
            if (w_mag >> i) & 1 == 1 {
                acc += self.bit_weight[i as usize];
            }
        }
        acc * v_ref
    }

    /// Signed convenience: `convert(|w|) · sign(w)` — the switched-cap
    /// polarity stage of the A-SYN.
    pub fn convert_signed(&self, w: i8, v_ref: f64) -> f64 {
        let mag = w.unsigned_abs();
        let v = self.convert(mag, v_ref);
        if w < 0 {
            -v
        } else {
            v
        }
    }
}

/// Op-amp integrator behavioural model: finite gain, slew limiting, rail
/// saturation.
#[derive(Debug, Clone)]
pub struct OpAmpIntegrator {
    gain: f64,
    slew_rate: f64,
    v_sat: f64,
}

impl OpAmpIntegrator {
    pub fn new(p: &AnalogParams) -> Self {
        Self { gain: p.opamp_gain, slew_rate: p.slew_rate, v_sat: p.v_sat }
    }

    /// Integrate a charge packet that would ideally move the output by
    /// `dv`, over window `dt`. Returns the achieved new output voltage.
    pub fn integrate(&self, v_now: f64, dv: f64, dt: f64) -> f64 {
        // Finite-gain error: the virtual ground sits at -v/A, skimming a
        // fraction of the packet.
        let gain_err = if self.gain.is_finite() { 1.0 - 1.0 / self.gain } else { 1.0 };
        let mut step = dv * gain_err;
        // Slew limiting.
        let max_step = self.slew_rate * dt;
        if step.abs() > max_step {
            step = step.signum() * max_step;
        }
        // Rail clamp.
        (v_now + step).clamp(-self.v_sat, self.v_sat)
    }
}

/// Latched comparator with hysteresis and propagation delay.
#[derive(Debug, Clone)]
pub struct Comparator {
    hysteresis: f64,
    pub delay: f64,
    /// Last output state (for hysteresis).
    state: bool,
}

impl Comparator {
    pub fn new(p: &AnalogParams) -> Self {
        Self { hysteresis: p.comparator_hysteresis, delay: p.comparator_delay, state: false }
    }

    /// Evaluate at a clock edge: `v` against `v_th`. Returns the (post-
    /// delay) logic level.
    pub fn compare(&mut self, v: f64, v_th: f64) -> bool {
        let th = if self.state {
            v_th - self.hysteresis
        } else {
            v_th + self.hysteresis
        };
        self.state = v >= th;
        self.state
    }

    pub fn reset(&mut self) {
        self.state = false;
    }
}

/// The N storage capacitors ("virtual neurons") of one A-NEURON.
#[derive(Debug, Clone)]
pub struct VirtualNeuronBank {
    /// Stored membrane voltage per capacitor.
    v: Vec<f64>,
    hold_leak: f64,
}

impl VirtualNeuronBank {
    pub fn new(n: usize, p: &AnalogParams) -> Self {
        Self { v: vec![0.0; n], hold_leak: p.hold_leak }
    }

    pub fn len(&self) -> usize {
        self.v.len()
    }

    pub fn is_empty(&self) -> bool {
        self.v.is_empty()
    }

    pub fn read(&self, k: usize) -> f64 {
        self.v[k]
    }

    pub fn write(&mut self, k: usize, v: f64) {
        self.v[k] = v;
    }

    /// Hold droop applied to every capacitor except the active one (it is
    /// connected to the op-amp, not floating).
    pub fn droop(&mut self, active: Option<usize>) {
        if self.hold_leak == 0.0 {
            return;
        }
        for (k, v) in self.v.iter_mut().enumerate() {
            if Some(k) != active {
                *v *= 1.0 - self.hold_leak;
            }
        }
    }

    /// The controller's per-time-step leak command: discharge every
    /// capacitor by the factor implementing the LIF β (paper §III-A:
    /// "a portion of the stored voltage ... is discharged at each time
    /// step").
    pub fn lif_leak(&mut self, beta: f64) {
        for v in self.v.iter_mut() {
            *v *= beta;
        }
    }

    /// Reset capacitor `k` to the reset potential.
    pub fn reset(&mut self, k: usize, v_reset: f64) {
        self.v[k] = v_reset;
    }
}

/// A captured waveform sample for Figure 5.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WavePoint {
    /// Simulation time (s).
    pub t: f64,
    /// A-SYN output (integrator input) voltage.
    pub v_in: f64,
    /// Integrator (op-amp 1) output — the membrane voltage.
    pub v_integ: f64,
    /// Comparator (op-amp 2) output pulse, as a logic voltage.
    pub v_out: f64,
}

/// One assembled A-NEURON engine (Figure 2): integrator + comparator +
/// virtual-neuron capacitor bank, with optional waveform capture and
/// energy accounting.
#[derive(Debug, Clone)]
pub struct ANeuron {
    pub params: AnalogParams,
    integ: OpAmpIntegrator,
    comp: Comparator,
    pub bank: VirtualNeuronBank,
    /// Total energy consumed (J).
    pub energy: f64,
    /// Total busy time (s).
    pub busy_time: f64,
    /// Number of integrate-and-fire operations performed.
    pub ops: u64,
    /// Waveform capture buffer (enabled via [`Self::enable_capture`]).
    capture: Option<Vec<WavePoint>>,
    /// Current simulation time for capture (advanced by the caller).
    pub now: f64,
}

impl ANeuron {
    pub fn new(virtual_neurons: usize, params: AnalogParams) -> Self {
        Self {
            integ: OpAmpIntegrator::new(&params),
            comp: Comparator::new(&params),
            bank: VirtualNeuronBank::new(virtual_neurons, &params),
            energy: 0.0,
            busy_time: 0.0,
            ops: 0,
            capture: None,
            now: 0.0,
            params,
        }
    }

    /// Start capturing waveforms (Figure 5).
    pub fn enable_capture(&mut self) {
        self.capture = Some(Vec::new());
    }

    pub fn waveform(&self) -> &[WavePoint] {
        self.capture.as_deref().unwrap_or(&[])
    }

    /// Process one dispatched event batch for virtual neuron `k`:
    /// restore the stored voltage, integrate the summed synaptic packet
    /// `v_packet` (A-SYN bank output), compare against threshold, store
    /// back or reset. Returns `true` if the neuron fired.
    ///
    /// This is the paper's restore→integrate→store sequence (§III-A) and
    /// costs one A-NEURON operation (6.72 ns / 0.652 fJ at the paper's
    /// operating point).
    pub fn process(&mut self, k: usize, v_packet: f64, v_th: f64, v_reset: f64) -> bool {
        let dt = self.params.neuron_delay;
        // Restore: switch the capacitor onto the op-amp feedback path;
        // charge injection perturbs the restored voltage.
        let v_restored = self.bank.read(k) + self.params.switch_injection;
        // Integrate the packet.
        let v_new = self.integ.integrate(v_restored, v_packet, dt);
        // Compare.
        let fired = self.comp.compare(v_new, v_th);
        // Store back (or reset on fire). Second switch event injects again.
        let v_stored = if fired {
            v_reset
        } else {
            v_new - self.params.switch_injection
        };
        self.bank.write(k, v_stored);
        // Hold droop on the idle capacitors.
        self.bank.droop(Some(k));
        // Accounting.
        self.energy += self.params.neuron_energy_per_op;
        self.busy_time += dt;
        self.ops += 1;
        if let Some(cap) = self.capture.as_mut() {
            let t0 = self.now;
            cap.push(WavePoint { t: t0, v_in: v_packet, v_integ: v_restored, v_out: 0.0 });
            cap.push(WavePoint {
                t: t0 + dt * 0.6,
                v_in: v_packet,
                v_integ: v_new,
                v_out: 0.0,
            });
            cap.push(WavePoint {
                t: t0 + dt * 0.6 + self.params.comparator_delay,
                v_in: 0.0,
                v_integ: if fired { v_reset } else { v_new },
                v_out: if fired { self.params.v_ref } else { 0.0 },
            });
        }
        self.now += dt;
        fired
    }

    /// Apply the controller's per-time-step leak command to all virtual
    /// neurons of this engine.
    pub fn lif_leak(&mut self, beta: f64) {
        self.bank.lif_leak(beta);
        if let Some(cap) = self.capture.as_mut() {
            // Leak shows as a droop sample on the integration trace.
            if let Some(&last) = cap.last() {
                cap.push(WavePoint {
                    t: self.now,
                    v_in: 0.0,
                    v_integ: last.v_integ * beta,
                    v_out: 0.0,
                });
            }
        }
    }

    /// Average power over the busy time (W) — comparable to the paper's
    /// 97 nW figure when exercised continuously.
    pub fn average_power(&self) -> f64 {
        if self.busy_time == 0.0 {
            0.0
        } else {
            self.energy / self.busy_time
        }
    }
}

/// The A-SYN engine (Figure 3): SRAM-backed weight row driving a C2C
/// ladder. [`Self::mac`] turns a signed weight into an analog packet
/// voltage contribution.
#[derive(Debug, Clone)]
pub struct ASyn {
    pub ladder: C2cLadder,
    v_ref: f64,
    /// Energy per MAC (C2C conversion + SRAM read), J.
    pub energy_per_mac: f64,
    pub energy: f64,
    pub macs: u64,
}

impl ASyn {
    pub fn new(bits: u32, params: &AnalogParams, rng: Option<&mut Rng>) -> Self {
        let ladder = match rng {
            Some(r) if params.c2c_mismatch_sigma > 0.0 => {
                C2cLadder::with_mismatch(bits, params.c2c_mismatch_sigma, r)
            }
            _ => C2cLadder::new(bits),
        };
        Self {
            ladder,
            v_ref: params.v_ref,
            // C2C MAC energy: dominated by ladder cap charging + SRAM read.
            // Sized so the synapse array tracks the paper's TOPS/W balance
            // (see energy.rs for the full budget).
            energy_per_mac: 0.30e-15,
            energy: 0.0,
            macs: 0,
        }
    }

    /// One multiply: signed 8-bit weight → analog voltage contribution,
    /// where `scale_to_volts` maps one quantized unit to membrane volts.
    pub fn mac(&mut self, w: i8, scale_to_volts: f64) -> f64 {
        self.energy += self.energy_per_mac;
        self.macs += 1;
        // Ladder computes |w|/2ⁿ · v_ref; multiply back by 2ⁿ·scale/v_ref
        // to land in membrane-volt units: net effect w · scale (plus
        // mismatch error if configured).
        let n = 2f64.powi(self.ladder.bits() as i32);
        self.ladder.convert_signed(w, self.v_ref) * n * scale_to_volts / self.v_ref
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kahan_add_recovers_order_lost_bits() {
        // 1.0 followed by 1e-16 four times: plain f64 addition loses the
        // small terms entirely; the compensated pair keeps them.
        let (mut s, mut c) = (0.0f64, 0.0f64);
        let mut plain = 0.0f64;
        for x in [1.0, 1e-16, 1e-16, 1e-16, 1e-16] {
            kahan_add(&mut s, &mut c, x);
            plain += x;
        }
        assert_eq!(plain, 1.0, "plain addition must actually lose the bits");
        assert!((s + c - (1.0 + 4e-16)).abs() < 1e-18, "compensated sum {}", s + c);
    }

    #[test]
    fn kahan_add_is_order_insensitive() {
        // The same multiset summed in opposite orders lands on the same
        // compensated value to within 1 ulp (here: exactly).
        let xs = [1e9, 1.0, -1e9, 1e-9, 3.5, -7.25, 1e-9];
        let sum_in = |iter: &mut dyn Iterator<Item = f64>| {
            let (mut s, mut c) = (0.0, 0.0);
            for x in iter {
                kahan_add(&mut s, &mut c, x);
            }
            s + c
        };
        let fwd = sum_in(&mut xs.iter().copied());
        let rev = sum_in(&mut xs.iter().rev().copied());
        assert!((fwd - rev).abs() <= f64::EPSILON * fwd.abs().max(1.0), "{fwd} vs {rev}");
    }

    #[test]
    fn c2c_matches_equation2() {
        let l = C2cLadder::new(8);
        // V_out = V_ref · Σ W_i 2^{i-n}; for w = 255: (2⁸-1)/2⁸.
        let v = l.convert(255, 1.0);
        assert!((v - 255.0 / 256.0).abs() < 1e-12);
        assert_eq!(l.convert(0, 1.0), 0.0);
        let v128 = l.convert(128, 1.0);
        assert!((v128 - 0.5).abs() < 1e-12);
        // Linear in v_ref.
        assert!((l.convert(77, 2.0) - 2.0 * l.convert(77, 1.0)).abs() < 1e-12);
    }

    #[test]
    fn c2c_signed() {
        let l = C2cLadder::new(8);
        assert!(l.convert_signed(-64, 1.0) < 0.0);
        assert!((l.convert_signed(-64, 1.0) + l.convert_signed(64, 1.0)).abs() < 1e-12);
        // i8::MIN magnitude 128 wraps to 128 via unsigned_abs — but the
        // ladder is 8-bit (max 255), bit 7 set → 0.5·v_ref. Must not panic.
        let v = l.convert_signed(i8::MIN, 1.0);
        assert!((v + 0.5).abs() < 1e-12);
    }

    #[test]
    fn c2c_mismatch_bounded() {
        let mut rng = Rng::new(5);
        let l = C2cLadder::with_mismatch(8, 0.002, &mut rng);
        for w in [1u8, 37, 128, 255] {
            let ideal = C2cLadder::new(8).convert(w, 1.0);
            let real = l.convert(w, 1.0);
            assert!(
                (real - ideal).abs() / ideal.max(1e-9) < 0.02,
                "w={w}: {real} vs {ideal}"
            );
        }
        // Zero sigma = exactly ideal.
        let l0 = C2cLadder::with_mismatch(8, 0.0, &mut rng);
        assert_eq!(l0.convert(200, 1.0), C2cLadder::new(8).convert(200, 1.0));
    }

    #[test]
    fn integrator_ideal_is_exact() {
        let p = AnalogParams::ideal();
        let o = OpAmpIntegrator::new(&p);
        let v = o.integrate(0.25, 0.5, 1e-9);
        assert_eq!(v, 0.75);
        let v = o.integrate(0.75, -1.0, 1e-9);
        assert_eq!(v, -0.25);
    }

    #[test]
    fn integrator_saturates_and_slews() {
        let mut p = AnalogParams::paper();
        p.v_sat = 1.0;
        p.slew_rate = 1e9; // 1 V/ns
        p.opamp_gain = f64::INFINITY;
        let o = OpAmpIntegrator::new(&p);
        // Slew: in 0.5 ns can move at most 0.5 V.
        let v = o.integrate(0.0, 2.0, 0.5e-9);
        assert!((v - 0.5).abs() < 1e-12, "v={v}");
        // Saturation clamp.
        let v = o.integrate(0.9, 0.5, 1e-6);
        assert_eq!(v, 1.0);
    }

    #[test]
    fn integrator_finite_gain_skims() {
        let mut p = AnalogParams::ideal();
        p.opamp_gain = 100.0;
        let o = OpAmpIntegrator::new(&p);
        let v = o.integrate(0.0, 1.0, 1.0);
        assert!((v - 0.99).abs() < 1e-12);
    }

    #[test]
    fn comparator_hysteresis() {
        let mut p = AnalogParams::paper();
        p.comparator_hysteresis = 0.1;
        let mut c = Comparator::new(&p);
        assert!(!c.compare(1.05, 1.0)); // below v_th + hyst
        assert!(c.compare(1.15, 1.0)); // crosses
        assert!(c.compare(0.95, 1.0)); // stays high until v_th - hyst
        assert!(!c.compare(0.85, 1.0)); // drops
        c.reset();
        assert!(!c.compare(1.05, 1.0));
    }

    #[test]
    fn bank_leak_and_droop() {
        let mut p = AnalogParams::ideal();
        p.hold_leak = 0.1;
        let mut b = VirtualNeuronBank::new(3, &p);
        b.write(0, 1.0);
        b.write(1, 1.0);
        b.write(2, 1.0);
        b.droop(Some(1));
        assert!((b.read(0) - 0.9).abs() < 1e-12);
        assert_eq!(b.read(1), 1.0); // active, no droop
        b.lif_leak(0.5);
        assert!((b.read(1) - 0.5).abs() < 1e-12);
        b.reset(1, 0.0);
        assert_eq!(b.read(1), 0.0);
        assert_eq!(b.len(), 3);
    }

    #[test]
    fn aneuron_ideal_matches_lif_math() {
        // Ideal A-NEURON must replicate v ← βv + i / fire / reset exactly.
        let mut an = ANeuron::new(4, AnalogParams::ideal());
        let (th, reset) = (1.0, 0.0);
        // Two packets of 0.6 on capacitor 2: fires on the second.
        assert!(!an.process(2, 0.6, th, reset));
        assert!((an.bank.read(2) - 0.6).abs() < 1e-12);
        assert!(an.process(2, 0.6, th, reset));
        assert_eq!(an.bank.read(2), 0.0);
        assert_eq!(an.ops, 2);
        // Leak β=0.9 across the bank.
        an.process(0, 0.5, th, reset);
        an.lif_leak(0.9);
        assert!((an.bank.read(0) - 0.45).abs() < 1e-12);
    }

    #[test]
    fn aneuron_power_matches_paper_operating_point() {
        let mut an = ANeuron::new(1, AnalogParams::paper());
        for _ in 0..1000 {
            an.process(0, 0.01, 1.0, 0.0);
        }
        let p = an.average_power();
        assert!((p - 97e-9).abs() / 97e-9 < 1e-9, "avg power {p} != 97nW");
        assert!((an.busy_time - 1000.0 * 6.72e-9).abs() < 1e-15);
    }

    #[test]
    fn aneuron_capture_produces_fig5_shape() {
        let mut an = ANeuron::new(1, AnalogParams::paper());
        an.enable_capture();
        // Drive sub-threshold packets then a firing one.
        an.process(0, 0.4, 1.0, 0.0);
        an.process(0, 0.4, 1.0, 0.0);
        let fired = an.process(0, 0.4, 1.0, 0.0);
        assert!(fired);
        let wf = an.waveform();
        assert!(!wf.is_empty());
        // Monotone time.
        assert!(wf.windows(2).all(|w| w[1].t >= w[0].t));
        // Integration voltage rose then reset; output pulsed exactly once.
        let pulses = wf.iter().filter(|p| p.v_out > 0.5).count();
        assert_eq!(pulses, 1);
        let vmax = wf.iter().map(|p| p.v_integ).fold(0.0, f64::max);
        assert!(vmax > 0.8, "integration ramp visible, vmax={vmax}");
    }

    #[test]
    fn asyn_mac_equals_w_times_scale_when_ideal() {
        let p = AnalogParams::ideal();
        let mut asyn = ASyn::new(8, &p, None);
        let scale = 0.01;
        for w in [-128i8, -77, -1, 0, 1, 77, 127] {
            let v = asyn.mac(w, scale);
            assert!(
                (v - w as f64 * scale).abs() < 1e-12,
                "w={w}: v={v} expected {}",
                w as f64 * scale
            );
        }
        assert_eq!(asyn.macs, 7);
        assert!(asyn.energy > 0.0);
    }

    #[test]
    fn asyn_mismatch_error_small() {
        let p = AnalogParams::paper();
        let mut rng = Rng::new(3);
        let mut asyn = ASyn::new(8, &p, Some(&mut rng));
        let scale = 0.01;
        let v = asyn.mac(100, scale);
        assert!((v / (100.0 * scale) - 1.0).abs() < 0.02, "v={v}");
    }
}
