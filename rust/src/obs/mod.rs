//! Observability plane: per-request trace spans and the dynamic execution
//! profile behind the STATS `profile` block (`menage top`, `loadgen
//! --profile`).
//!
//! Everything here is std-only and **bounded-memory**: fixed-size atomic
//! counters and log₂ histograms, one mutex-guarded K-slot ring. Nothing in
//! this module touches engine arithmetic — observability is bit-identity
//! neutral by construction (the differential suites run unchanged).
//!
//! ## Trace spans
//!
//! A request crossing the serving stack is stamped at five monotonic
//! points, yielding five spans that partition its server-side latency:
//!
//! ```text
//! admit    ingress decode + admission control    (reader thread)
//! queue    shared-queue wait incl. fill-wait     (submit → steal)
//! dispatch steal → engine start (width filter,
//!          staging, occupancy gauges)            (worker thread)
//! step     the engine run itself (sim_latency)   (worker thread)
//! egress   results channel + router routing      (done → route)
//! ```
//!
//! The stamps ride through [`crate::coordinator`]: `Request` carries its
//! submission instant, workers stamp steal/dispatch/done into the
//! `Response`, and the server's router folds the spans into
//! [`StageHistograms`] (one [`LatencyHistogram`] per stage) next to the
//! end-to-end latency histogram. Sampling is **per dispatch, not per
//! spike** — the hot path pays a handful of `Instant::now()` calls and
//! relaxed atomic adds per request, no allocation.
//!
//! The K slowest complete traces are retained in a [`SlowTraceRing`] for
//! tail forensics: when p99 moves, the ring says *which stage* of the
//! slowest requests moved.
//!
//! ## Execution profile
//!
//! [`ProfilePlane`] accumulates per-core monotonic execution counters
//! (cycles, distinct events dispatched, MEM_S&N rows, MAC-equivalents,
//! integrations, sweep ops, spikes) published by the coordinator's workers
//! as **deltas after every batch** — the exact pattern the hardware fault
//! counters use — so live STATS readers see work attributed per core and
//! per shard without waiting for shutdown's stats fold. Counters are
//! cumulative; *windowed* rates are computed by the poller (`menage top`
//! diffs successive snapshots, `loadgen --profile` diffs its pre/post
//! probes), which keeps the hot path free of epoch bookkeeping.
//!
//! This is the calibration feed the ROADMAP's measurement-driven placement
//! item needs: measured per-shard cycles/MACs/boundary traffic instead of
//! the static `out_dim + nnz` estimate.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::fault::lock_recover;
use crate::serve::metrics::LatencyHistogram;
use crate::util::json::Json;

/// How many slowest traces [`SlowTraceRing::default`] retains.
pub const SLOW_TRACE_CAP: usize = 8;

/// One core's monotonic execution counters, as sampled from the engine
/// (core stats + per-lane stats, pre-fold). A plain value type so workers
/// can snapshot/diff it without touching `CoreStats`' per-step series.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CoreSample {
    pub cycles: u64,
    /// Events popped from MEM_E (distinct per dispatch round).
    pub events: u64,
    /// MEM_S&N rows streamed.
    pub sn_rows: u64,
    /// Synaptic MAC-equivalents (A-SYN operations).
    pub macs: u64,
    /// A-NEURON integrate operations.
    pub integrations: u64,
    /// A-NEURON sweep operations.
    pub fire_ops: u64,
    /// Output spikes emitted.
    pub spikes: u64,
}

impl CoreSample {
    /// Monotonic counter delta (`self` sampled after `prev`); saturating,
    /// so a respawned worker's fresh chip (counters reset to 0) publishes
    /// zeros instead of wrapping.
    pub fn delta_since(&self, prev: &CoreSample) -> CoreSample {
        CoreSample {
            cycles: self.cycles.saturating_sub(prev.cycles),
            events: self.events.saturating_sub(prev.events),
            sn_rows: self.sn_rows.saturating_sub(prev.sn_rows),
            macs: self.macs.saturating_sub(prev.macs),
            integrations: self.integrations.saturating_sub(prev.integrations),
            fire_ops: self.fire_ops.saturating_sub(prev.fire_ops),
            spikes: self.spikes.saturating_sub(prev.spikes),
        }
    }

    fn accumulate(&mut self, d: &CoreSample) {
        self.cycles += d.cycles;
        self.events += d.events;
        self.sn_rows += d.sn_rows;
        self.macs += d.macs;
        self.integrations += d.integrations;
        self.fire_ops += d.fire_ops;
        self.spikes += d.spikes;
    }

    fn to_json_fields(self) -> Vec<(&'static str, Json)> {
        vec![
            ("cycles", (self.cycles as usize).into()),
            ("events", (self.events as usize).into()),
            ("sn_rows", (self.sn_rows as usize).into()),
            ("macs", (self.macs as usize).into()),
            ("integrations", (self.integrations as usize).into()),
            ("fire_ops", (self.fire_ops as usize).into()),
            ("spikes", (self.spikes as usize).into()),
        ]
    }
}

/// One core's shared atomic counter slot (the [`ProfilePlane`] cell every
/// worker clone of that core publishes deltas into).
#[derive(Debug, Default)]
struct CoreCounters {
    cycles: AtomicU64,
    events: AtomicU64,
    sn_rows: AtomicU64,
    macs: AtomicU64,
    integrations: AtomicU64,
    fire_ops: AtomicU64,
    spikes: AtomicU64,
}

/// The live per-core/per-shard execution-profile registry (module docs).
/// One instance per coordinator, shared (Arc) by every worker and the
/// serving layer's STATS snapshot. Counters sum work across all worker
/// clones of a core — the service-wide view, matching how the latency
/// histogram sums across connections.
#[derive(Debug, Default)]
pub struct ProfilePlane {
    /// `shard_of[c]` = the shard hosting core `c` (global core order).
    /// Empty for backends with no local cores (remote pipelines — their
    /// counters live in the shard hosts' own STATS registries).
    shard_of: Vec<usize>,
    cores: Vec<CoreCounters>,
}

impl ProfilePlane {
    /// A plane with one counter slot per core; `shard_of` maps each core
    /// to its shard (all zeros for a monolithic chip).
    pub fn new(shard_of: Vec<usize>) -> Self {
        let cores = (0..shard_of.len()).map(|_| CoreCounters::default()).collect();
        Self { shard_of, cores }
    }

    pub fn num_cores(&self) -> usize {
        self.cores.len()
    }

    /// Number of shards the cores span (0 when the plane is empty).
    pub fn num_shards(&self) -> usize {
        self.shard_of.iter().copied().max().map_or(0, |m| m + 1)
    }

    /// Publish one core's counter delta (relaxed adds — hot path safe).
    pub fn add(&self, core: usize, d: &CoreSample) {
        let c = &self.cores[core];
        c.cycles.fetch_add(d.cycles, Ordering::Relaxed);
        c.events.fetch_add(d.events, Ordering::Relaxed);
        c.sn_rows.fetch_add(d.sn_rows, Ordering::Relaxed);
        c.macs.fetch_add(d.macs, Ordering::Relaxed);
        c.integrations.fetch_add(d.integrations, Ordering::Relaxed);
        c.fire_ops.fetch_add(d.fire_ops, Ordering::Relaxed);
        c.spikes.fetch_add(d.spikes, Ordering::Relaxed);
    }

    /// Current cumulative totals of one core.
    pub fn core_sample(&self, core: usize) -> CoreSample {
        let c = &self.cores[core];
        CoreSample {
            cycles: c.cycles.load(Ordering::Relaxed),
            events: c.events.load(Ordering::Relaxed),
            sn_rows: c.sn_rows.load(Ordering::Relaxed),
            macs: c.macs.load(Ordering::Relaxed),
            integrations: c.integrations.load(Ordering::Relaxed),
            fire_ops: c.fire_ops.load(Ordering::Relaxed),
            spikes: c.spikes.load(Ordering::Relaxed),
        }
    }

    /// Cumulative totals summed per shard (index = shard).
    pub fn shard_samples(&self) -> Vec<CoreSample> {
        let mut out = vec![CoreSample::default(); self.num_shards()];
        for (c, &s) in self.shard_of.iter().enumerate() {
            out[s].accumulate(&self.core_sample(c));
        }
        out
    }

    /// The `cores`/`shards` halves of the STATS `profile` block.
    pub fn to_json(&self) -> (Json, Json) {
        let cores = Json::Arr(
            (0..self.num_cores())
                .map(|c| {
                    let mut fields = vec![
                        ("core", c.into()),
                        ("shard", self.shard_of[c].into()),
                    ];
                    fields.extend(self.core_sample(c).to_json_fields());
                    Json::obj(fields)
                })
                .collect(),
        );
        let shards = Json::Arr(
            self.shard_samples()
                .into_iter()
                .enumerate()
                .map(|(s, sample)| {
                    let mut fields = vec![("shard", s.into())];
                    fields.extend(sample.to_json_fields());
                    Json::obj(fields)
                })
                .collect(),
        );
        (cores, shards)
    }
}

/// Per-stage latency histograms (module docs §Trace spans): one bounded
/// log₂ histogram per span, recorded by the server's router (queue/
/// dispatch/step/egress) and readers (admit).
#[derive(Debug, Default)]
pub struct StageHistograms {
    pub admit: LatencyHistogram,
    pub queue: LatencyHistogram,
    pub dispatch: LatencyHistogram,
    pub step: LatencyHistogram,
    pub egress: LatencyHistogram,
}

impl StageHistograms {
    /// Iterate `(name, histogram)` in pipeline order.
    pub fn stages(&self) -> [(&'static str, &LatencyHistogram); 5] {
        [
            ("admit", &self.admit),
            ("queue", &self.queue),
            ("dispatch", &self.dispatch),
            ("step", &self.step),
            ("egress", &self.egress),
        ]
    }

    /// The `stages` half of the STATS `profile` block: one summary
    /// (`mean/p50/p90/p99/max/count`) per stage.
    pub fn to_json(&self) -> Json {
        Json::obj(
            self.stages().into_iter().map(|(name, h)| (name, h.summary_json())).collect(),
        )
    }
}

/// One completed request's span breakdown, microseconds. `total_us` is the
/// accept→route latency (the same value the endpoint histogram records);
/// the admit span is excluded (it precedes the trace's accept stamp).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TraceRecord {
    /// Server-internal (coordinator) request id.
    pub id: u64,
    pub total_us: u64,
    pub queue_us: u64,
    pub dispatch_us: u64,
    pub step_us: u64,
    pub egress_us: u64,
}

impl TraceRecord {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", (self.id as usize).into()),
            ("total_us", (self.total_us as usize).into()),
            ("queue_us", (self.queue_us as usize).into()),
            ("dispatch_us", (self.dispatch_us as usize).into()),
            ("step_us", (self.step_us as usize).into()),
            ("egress_us", (self.egress_us as usize).into()),
        ])
    }
}

/// Bounded registry of the K slowest complete traces (tail forensics).
///
/// The hot path is gated by an atomic floor: once the ring is full, a
/// trace no slower than the current K-th-slowest is rejected with one
/// relaxed load — the mutex is only taken for genuine tail entries, which
/// by definition are rare.
#[derive(Debug)]
pub struct SlowTraceRing {
    cap: usize,
    /// `total_us` of the fastest retained trace once full (0 before): the
    /// lock-free admission gate.
    floor: AtomicU64,
    ring: Mutex<Vec<TraceRecord>>,
}

impl Default for SlowTraceRing {
    fn default() -> Self {
        Self::new(SLOW_TRACE_CAP)
    }
}

impl SlowTraceRing {
    pub fn new(cap: usize) -> Self {
        Self {
            cap: cap.max(1),
            floor: AtomicU64::new(0),
            ring: Mutex::new(Vec::new()),
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Offer a completed trace; retained only if it ranks among the K
    /// slowest seen so far.
    pub fn offer(&self, rec: TraceRecord) {
        // Fast path: the ring is full and this trace is not slower than
        // its fastest member — drop without locking. (A racing floor is
        // only ever stale-low, which admits a borderline trace to the
        // locked path below; never the reverse.)
        if rec.total_us <= self.floor.load(Ordering::Relaxed) {
            return;
        }
        let mut ring = lock_recover(&self.ring);
        if ring.len() < self.cap {
            ring.push(rec);
        } else {
            let (mi, _) = ring
                .iter()
                .enumerate()
                .min_by_key(|(_, r)| r.total_us)
                .expect("ring is full, cap ≥ 1");
            if ring[mi].total_us >= rec.total_us {
                return;
            }
            ring[mi] = rec;
        }
        if ring.len() == self.cap {
            let floor = ring.iter().map(|r| r.total_us).min().unwrap_or(0);
            self.floor.store(floor, Ordering::Relaxed);
        }
    }

    /// The retained traces, slowest first.
    pub fn snapshot(&self) -> Vec<TraceRecord> {
        let mut v = lock_recover(&self.ring).clone();
        v.sort_by(|a, b| b.total_us.cmp(&a.total_us));
        v
    }

    /// The `slowest` half of the STATS `profile` block.
    pub fn to_json(&self) -> Json {
        Json::Arr(self.snapshot().iter().map(TraceRecord::to_json).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: u64, total: u64) -> TraceRecord {
        TraceRecord {
            id,
            total_us: total,
            queue_us: total / 4,
            dispatch_us: total / 8,
            step_us: total / 2,
            egress_us: total / 8,
        }
    }

    /// The ring keeps exactly the K slowest offers, in slowest-first
    /// snapshot order, regardless of offer order.
    #[test]
    fn slow_ring_keeps_k_slowest() {
        let ring = SlowTraceRing::new(3);
        for (i, t) in [50u64, 10, 900, 40, 300, 5, 700, 300].into_iter().enumerate() {
            ring.offer(rec(i as u64, t));
        }
        let snap = ring.snapshot();
        let totals: Vec<u64> = snap.iter().map(|r| r.total_us).collect();
        assert_eq!(totals, vec![900, 700, 300]);
        // The floor gate rejects anything ≤ the fastest retained trace.
        ring.offer(rec(99, 300));
        assert_eq!(ring.snapshot().iter().map(|r| r.total_us).collect::<Vec<_>>(), totals);
        // A new tail entry displaces the fastest member.
        ring.offer(rec(100, 301));
        let totals: Vec<u64> = ring.snapshot().iter().map(|r| r.total_us).collect();
        assert_eq!(totals, vec![900, 700, 301]);
    }

    /// Under capacity, everything offered is retained and the floor gate
    /// stays open (0) so later slower traces still enter.
    #[test]
    fn slow_ring_under_capacity_keeps_all() {
        let ring = SlowTraceRing::new(8);
        ring.offer(rec(0, 10));
        ring.offer(rec(1, 20));
        assert_eq!(ring.snapshot().len(), 2);
        assert_eq!(ring.snapshot()[0].id, 1);
        // JSON round-trips through the in-tree writer/parser.
        let j = ring.to_json();
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    /// Plane accounting: deltas accumulate per core, shard totals sum
    /// their cores, and the JSON block carries both halves.
    #[test]
    fn profile_plane_accumulates_and_aggregates() {
        let plane = ProfilePlane::new(vec![0, 0, 1]);
        assert_eq!(plane.num_cores(), 3);
        assert_eq!(plane.num_shards(), 2);
        let d = CoreSample {
            cycles: 10,
            events: 4,
            sn_rows: 3,
            macs: 20,
            integrations: 5,
            fire_ops: 6,
            spikes: 2,
        };
        plane.add(0, &d);
        plane.add(0, &d);
        plane.add(2, &d);
        assert_eq!(plane.core_sample(0).cycles, 20);
        assert_eq!(plane.core_sample(1), CoreSample::default());
        let shards = plane.shard_samples();
        assert_eq!(shards[0].macs, 40);
        assert_eq!(shards[1].macs, 20);
        let (cores, shards) = plane.to_json();
        let Json::Arr(cores) = cores else { panic!("cores must be an array") };
        assert_eq!(cores.len(), 3);
        assert_eq!(cores[2].get("shard").unwrap().as_usize().unwrap(), 1);
        assert_eq!(cores[0].get("cycles").unwrap().as_usize().unwrap(), 20);
        let Json::Arr(shards) = shards else { panic!("shards must be an array") };
        assert_eq!(shards.len(), 2);
        assert_eq!(shards[0].get("macs").unwrap().as_usize().unwrap(), 40);
    }

    /// Saturating deltas: a counter that went backwards (worker respawned
    /// on a fresh chip) publishes zero, never wraps.
    #[test]
    fn core_sample_delta_saturates() {
        let hi = CoreSample { cycles: 100, ..CoreSample::default() };
        let lo = CoreSample { cycles: 30, ..CoreSample::default() };
        assert_eq!(hi.delta_since(&lo).cycles, 70);
        assert_eq!(lo.delta_since(&hi).cycles, 0);
    }

    /// Stage histograms: names in pipeline order, JSON summaries present
    /// and null-safe when empty.
    #[test]
    fn stage_histograms_json_shape() {
        let st = StageHistograms::default();
        st.queue.record_micros(100);
        let j = st.to_json();
        let names: Vec<&str> =
            st.stages().into_iter().map(|(n, _)| n).collect();
        assert_eq!(names, vec!["admit", "queue", "dispatch", "step", "egress"]);
        assert_eq!(j.get("queue").unwrap().get("count").unwrap().as_usize().unwrap(), 1);
        // Empty stage: percentiles are null, not fabricated numbers.
        assert!(matches!(j.get("admit").unwrap().get("p50").unwrap(), Json::Null));
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }
}
